#!/usr/bin/env bash
# Perf-regression gates. Each gate runs a small-scale bench and compares
# it against its committed baseline via `sfut bench gate`, failing on a
# >25% (BENCH_GATE_THRESHOLD) jobs/sec drop in any comparable cell.
# Runs identically in CI (.github/workflows/ci.yml, job `bench-gate`)
# and locally:
#
#   ci/check_bench.sh [<target>|all]
#
# The gate set is DECLARED, not hard-coded here: ci/plans/gates.plan
# maps each target name to its committed baseline file and cargo bench
# target, and `sfut bench list gates` prints that mapping one target
# per line — this script just loops over it. Adding a gate means adding
# one line to gates.plan, not editing this script. Today's set:
#   * pipeline — `cargo bench --bench pipeline_throughput` vs
#                BENCH_pipeline.json (per (workload, shards) cell);
#   * ingress  — `cargo bench --bench ingress_wire` vs
#                BENCH_ingress.json: the framed-vs-text A/B — one
#                harness invocation sweeps BOTH wire modes, and the gate
#                hard-fails if either wire mode (or any framed poller
#                backend the baseline has cells for) is missing from the
#                current run;
#   * executor — `cargo bench --bench ablation_overhead` vs
#                BENCH_executor.json (like-labeled scheduler/deque
#                points; no baseline is committed yet, so this gate
#                seeds-and-arms).
#
# Behaviour (per gate):
#   * no committed baseline      → seed one (prints a reminder to commit
#                                  it), exit 0 — the gate arms itself on
#                                  the next run;
#   * baseline not comparable    → exit 0 with a SKIPPED note (profile or
#                                  run parameters differ — e.g. a
#                                  debug-seeded baseline vs this script's
#                                  release run; refresh the baseline);
#   * comparable + regression    → exit 1;
#   * malformed/empty current    → exit 1 with a ::error:: annotation —
#                                  a broken bench writer must FAIL the
#                                  gate, not disarm it into a skip.
#
# Latency gating: p95 latency growth beyond BENCH_GATE_LATENCY_THRESHOLD
# warns by default. Set BENCH_GATE_LATENCY_STRICT=1 to pass
# --latency-strict, which fails the gate on those findings instead —
# with one safety: while a committed baseline's "note" field still marks
# it a synthetic floor, strict mode auto-disarms back to warn-only (the
# gate must not fire on fictional ceilings).
#
# Refreshing a committed baseline with MEASURED numbers (the path off
# the synthetic floor):
#   1. Trigger the `bench-baseline` workflow
#      (.github/workflows/bench-baseline.yml) from the Actions tab
#      (workflow_dispatch) — or wait for its weekly cron run. It runs the
#      release-profile `pipeline_throughput` and `ablation_overhead`
#      benches with this script's exact env pins on the CI runner class
#      that executes the gate.
#   2. Download the `BENCH_pipeline-measured` artifact and copy it over
#      the repo-root BENCH_pipeline.json (dropping the synthetic "note"
#      field arms strict latency gating; BENCH_executor-measured is the
#      executor trajectory counterpart).
#   3. Commit. From that run on, the gate compares against measured
#      numbers, and BENCH_GATE_LATENCY_STRICT=1 has teeth.
#   Alternatively run the bench on a quiet machine matching CI's core
#   count and commit the overwritten trajectory file, e.g.
#   `SFUT_SCALE=0.05 cargo bench --bench pipeline_throughput` or
#   `SFUT_SCALE=0.05 cargo bench --bench ingress_wire`.
set -euo pipefail
cd "$(dirname "$0")/.."

TARGET="${1:-all}"
THRESHOLD="${BENCH_GATE_THRESHOLD:-0.25}"
# p95 latency / queue-wait growth tolerated before a finding
# (warn-only unless BENCH_GATE_LATENCY_STRICT=1; see
# `sfut bench gate --latency-threshold/--latency-strict`).
LATENCY_THRESHOLD="${BENCH_GATE_LATENCY_THRESHOLD:-0.25}"
STRICT_ARGS=()
if [[ "${BENCH_GATE_LATENCY_STRICT:-0}" == "1" ]]; then
    STRICT_ARGS+=(--latency-strict)
fi

# Pinned small-scale run parameters (override via environment).
export SFUT_SCALE="${SFUT_SCALE:-0.05}"
export SFUT_BENCH_SAMPLES="${SFUT_BENCH_SAMPLES:-3}"
export SFUT_BENCH_WARMUP="${SFUT_BENCH_WARMUP:-1}"
export SFUT_PIPELINE_CLIENTS="${SFUT_PIPELINE_CLIENTS:-2}"
export SFUT_PIPELINE_JOBS="${SFUT_PIPELINE_JOBS:-3}"
# Ingress gate ladders (pollers default to every backend the platform
# has — poll+epoll on linux, poll elsewhere; leave SFUT_INGRESS_POLLERS
# unset so the gate exercises them all).
export SFUT_INGRESS_CONNS="${SFUT_INGRESS_CONNS:-1,2}"
export SFUT_INGRESS_REACTORS="${SFUT_INGRESS_REACTORS:-1,2}"
export SFUT_NO_KERNEL=1

trap 'rm -f BENCH_*.json.baseline' EXIT

# run_gate <label> <baseline file> <bench target>
run_gate() {
    local label="$1" baseline="$2" bench="$3"

    if [[ ! -f "$baseline" ]]; then
        # A committed floor baseline normally prevents this branch;
        # landing here means this gate is NOT enforcing anything.
        echo "::warning title=bench-gate unarmed::no committed $baseline — seeding a baseline; commit it to arm the $label gate"
        cargo bench --bench "$bench"
        echo "seeded $baseline; the $label gate is a no-op until it is committed"
        return 0
    fi

    cp "$baseline" "$baseline.baseline"

    # The bench overwrites $baseline with the fresh run (uploaded as the
    # CI artifact); the copy above is the committed baseline we compare
    # against.
    cargo bench --bench "$bench"

    # Teeth: a bench run that produced no/empty output is a broken
    # writer — fail loudly instead of letting the compare step skip on a
    # half-parsed document.
    if [[ ! -s "$baseline" ]]; then
        echo "::error title=bench-gate::$bench run left no (or empty) $baseline — failing the $label gate, not skipping it"
        return 1
    fi

    local status=0
    cargo run --release --quiet --bin sfut -- \
        bench gate "$label" "$baseline.baseline" "$baseline" \
        --threshold "$THRESHOLD" --latency-threshold "$LATENCY_THRESHOLD" \
        ${STRICT_ARGS[@]+"${STRICT_ARGS[@]}"} || status=$?
    if [[ "$status" -ne 0 ]]; then
        echo "::error title=bench-gate::sfut bench gate failed for $label (exit $status) — regression, or malformed current run"
        return "$status"
    fi
}

# One loop over the plan-declared gate set replaces the old hand-copied
# per-target case arms (which had drifted to duplicate the invocation).
# The listing is load-bearing: if it fails (broken build, unparseable
# gates.plan) or comes back empty, every gate would silently skip — fail
# the job instead. The explicit guard (rather than trusting `set -e`
# with the command substitution) also survives this block ever being
# moved into an `if`/`||` context where -e stops firing.
if ! GATE_SET="$(cargo run --release --quiet --bin sfut -- bench list gates)"; then
    echo "::error title=bench-gate::\`sfut bench list gates\` failed — cannot enumerate the gate set, failing instead of skipping every gate"
    exit 1
fi
if [[ -z "${GATE_SET//[[:space:]]/}" ]]; then
    echo "::error title=bench-gate::\`sfut bench list gates\` returned an empty gate set — ci/plans/gates.plan declares no targets, failing instead of skipping every gate"
    exit 1
fi
MATCHED=0
while read -r name baseline bench; do
    [[ -z "$name" ]] && continue
    if [[ "$TARGET" == "all" || "$TARGET" == "$name" ]]; then
        MATCHED=1
        # </dev/null so nothing in run_gate can eat the gate-set stream
        run_gate "$name" "$baseline" "$bench" < /dev/null
    fi
done <<< "$GATE_SET"

if [[ "$MATCHED" -eq 0 ]]; then
    echo "usage: ci/check_bench.sh [<target>|all]; declared targets:" >&2
    echo "$GATE_SET" | awk '{print "  " $1}' >&2
    exit 2
fi

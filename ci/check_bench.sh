#!/usr/bin/env bash
# Pipeline perf-regression gate. Runs the small-scale pipeline bench and
# compares it against the committed BENCH_pipeline.json baseline via
# `sfut check-bench`, failing on a >25% (BENCH_GATE_THRESHOLD) jobs/sec
# drop in any (workload, shards) cell. Runs identically in CI
# (.github/workflows/ci.yml, job `bench-gate`) and locally:
#
#   ci/check_bench.sh
#
# Behaviour:
#   * no committed baseline      → seed one (prints a reminder to commit
#                                  it), exit 0 — the gate arms itself on
#                                  the next run;
#   * baseline not comparable    → exit 0 with a SKIPPED note (profile or
#                                  run parameters differ — e.g. a
#                                  debug-seeded baseline vs this script's
#                                  release run; refresh the baseline);
#   * comparable + regression    → exit 1;
#   * malformed/empty current    → exit 1 with a ::error:: annotation —
#                                  a broken bench writer must FAIL the
#                                  gate, not disarm it into a skip.
#
# Latency gating: p95 job latency and p95 queue-wait growth beyond
# BENCH_GATE_LATENCY_THRESHOLD warns by default. Set
# BENCH_GATE_LATENCY_STRICT=1 to pass --latency-strict, which fails the
# gate on those findings instead — with one safety: while the committed
# baseline's "note" field still marks it a synthetic floor, strict mode
# auto-disarms back to warn-only (the gate must not fire on fictional
# ceilings).
#
# Refreshing the committed baseline with MEASURED numbers (the path off
# the synthetic floor):
#   1. Trigger the `bench-baseline` workflow
#      (.github/workflows/bench-baseline.yml) from the Actions tab
#      (workflow_dispatch) — or wait for its weekly cron run. It runs the
#      release-profile `pipeline_throughput` and `ablation_overhead`
#      benches with this script's exact env pins on the CI runner class
#      that executes the gate.
#   2. Download the `BENCH_pipeline-measured` artifact and copy it over
#      the repo-root BENCH_pipeline.json (dropping the synthetic "note"
#      field arms strict latency gating; BENCH_executor-measured is the
#      executor trajectory counterpart, gated via
#      `sfut check-bench` on like-labeled scheduler/deque points).
#   3. Commit. From that run on, the gate compares against measured
#      numbers, and BENCH_GATE_LATENCY_STRICT=1 has teeth.
#   Alternatively run `SFUT_SCALE=0.05 cargo bench --bench
#   pipeline_throughput` on a quiet machine matching CI's core count and
#   commit the overwritten BENCH_pipeline.json.
set -euo pipefail
cd "$(dirname "$0")/.."

BASELINE="BENCH_pipeline.json"
THRESHOLD="${BENCH_GATE_THRESHOLD:-0.25}"
# p95 latency / queue-wait growth tolerated before a finding
# (warn-only unless BENCH_GATE_LATENCY_STRICT=1; see
# `sfut check-bench --latency-threshold/--latency-strict`).
LATENCY_THRESHOLD="${BENCH_GATE_LATENCY_THRESHOLD:-0.25}"
STRICT_ARGS=()
if [[ "${BENCH_GATE_LATENCY_STRICT:-0}" == "1" ]]; then
    STRICT_ARGS+=(--latency-strict)
fi

# Pinned small-scale run parameters (override via environment).
export SFUT_SCALE="${SFUT_SCALE:-0.05}"
export SFUT_BENCH_SAMPLES="${SFUT_BENCH_SAMPLES:-3}"
export SFUT_BENCH_WARMUP="${SFUT_BENCH_WARMUP:-1}"
export SFUT_PIPELINE_CLIENTS="${SFUT_PIPELINE_CLIENTS:-2}"
export SFUT_PIPELINE_JOBS="${SFUT_PIPELINE_JOBS:-3}"
export SFUT_NO_KERNEL=1

if [[ ! -f "$BASELINE" ]]; then
    # A committed floor baseline normally prevents this branch; landing
    # here means the gate is NOT enforcing anything this run.
    echo "::warning title=bench-gate unarmed::no committed $BASELINE — seeding a baseline; commit it to arm the gate"
    cargo bench --bench pipeline_throughput
    echo "seeded $BASELINE; the gate is a no-op until it is committed"
    exit 0
fi

cp "$BASELINE" "$BASELINE.baseline"
trap 'rm -f "$BASELINE.baseline"' EXIT

# The bench overwrites $BASELINE with the fresh run (uploaded as the CI
# artifact); the copy above is the committed baseline we compare against.
cargo bench --bench pipeline_throughput

# Teeth: a bench run that produced no/empty output is a broken writer —
# fail loudly instead of letting the compare step skip on a half-parsed
# document.
if [[ ! -s "$BASELINE" ]]; then
    echo "::error title=bench-gate::bench run left no (or empty) $BASELINE — failing the gate, not skipping it"
    exit 1
fi

set +e
cargo run --release --quiet --bin sfut -- \
    check-bench "$BASELINE.baseline" "$BASELINE" \
    --threshold "$THRESHOLD" --latency-threshold "$LATENCY_THRESHOLD" \
    ${STRICT_ARGS[@]+"${STRICT_ARGS[@]}"}
status=$?
set -e
if [[ "$status" -ne 0 ]]; then
    echo "::error title=bench-gate::sfut check-bench failed (exit $status) — regression, or malformed current run"
    exit "$status"
fi

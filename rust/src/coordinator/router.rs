//! The pipeline router: `(workload, mode)` → algorithm × strategy ×
//! shard.
//!
//! Monomorphization meets runtime dispatch here: the algorithms are
//! generic over [`Eval`](crate::susp::Eval), the request is a runtime
//! value, so [`PipelineCore`] holds the `match` that instantiates the
//! right combination — exactly the substitution the paper performs by
//! editing one import.
//!
//! Since the ingress rework, [`Pipeline`] is a cloneable handle over two
//! halves:
//!
//! * [`PipelineCore`] — config, optional PJRT engine, metrics, the
//!   [`ShardSet`], and the execute/verify/report logic
//!   ([`PipelineCore::execute_routed`]). It knows nothing about queues.
//! * [`Ingress`](super::ingress::Ingress) — the staged admission path
//!   (admit → route → execute → report). [`Pipeline::submit`] enqueues a
//!   request and returns a [`JobTicket`] immediately; dispatcher threads
//!   route it to a shard's run queue; shard runner threads execute it
//!   (stealing whole queued jobs across shards when one backs up) and
//!   fulfill the ticket.
//!
//! The synchronous API survives as a veneer: [`Pipeline::run`] is
//! `submit` + [`JobTicket::wait`], so every job — CLI, serve session,
//! bench client — flows through the same admission queue and backpressure
//! policy.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};
use log::{debug, info, warn};

use super::ingress::{Ingress, JobTicket, SubmitError};
use super::job::{JobRequest, JobResult, ResultDetail};
use super::shard::{Shard, ShardSet};
use crate::config::{ChunkPolicy, Config, Mode, Workload};
use crate::metrics::MetricsRegistry;
use crate::poly::{
    chunked_times, chunked_times_adaptive_cached, list_times_par, list_times_seq, stream_times,
    BlockMultiplier, Coeff, Polynomial, RustMultiplier,
};
use crate::runtime::{KernelMultiplier, KernelSiever, XlaEngine};
use crate::sieve::{self, BlockSiever, RustSiever};
use crate::susp::{FutureEval, LazyEval, StrictEval};
use crate::workload::{fateman_pair, fateman_pair_big, Sizes};

/// Long-lived coordinator state: config, optional PJRT engine, metrics,
/// the shard group, and the execution logic. Shared (via `Arc`) between
/// the [`Pipeline`] handle and the ingress worker threads.
pub(super) struct PipelineCore {
    cfg: Config,
    sizes: Sizes,
    engine: Option<Arc<XlaEngine>>,
    metrics: MetricsRegistry,
    shards: ShardSet,
}

/// Handle to a running coordinator: cheap to clone, shared across serve
/// sessions. Dropping the last handle shuts the ingress down (draining
/// queued jobs, resolving their tickets).
#[derive(Clone)]
pub struct Pipeline {
    core: Arc<PipelineCore>,
    ingress: Arc<Ingress>,
}

impl Pipeline {
    /// Build a pipeline and start its ingress (dispatcher + shard runner
    /// threads). When `cfg.use_kernel` is set and the artifacts directory
    /// exists, the PJRT engine is started (compiling every artifact);
    /// otherwise chunked workloads run on the pure-Rust block backend.
    pub fn new(cfg: Config) -> Result<Pipeline> {
        cfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        let engine = if cfg.use_kernel && cfg.artifacts_dir.join("manifest.toml").exists() {
            let engine = XlaEngine::start(&cfg.artifacts_dir)
                .context("starting PJRT engine (set use_kernel=false to skip)")?;
            Some(Arc::new(engine))
        } else {
            info!("pjrt engine disabled (use_kernel={} artifacts at {:?})",
                  cfg.use_kernel, cfg.artifacts_dir);
            None
        };
        if cfg.chunk_policy == ChunkPolicy::Adaptive
            && cfg.chunk_size != Config::default().chunk_size
        {
            warn!(
                "chunk_size={} is ignored under chunk_policy=adaptive (the sizer probes \
                 its own edge); set chunk_policy=fixed to pin it",
                cfg.chunk_size
            );
        }
        let sizes = Sizes::from_config(&cfg);
        let shards = ShardSet::new(&cfg);
        info!(
            "coordinator sharded {} way(s); ingress queue_depth={} admission={}",
            shards.len(),
            cfg.queue_depth,
            cfg.admission.label()
        );
        let metrics = MetricsRegistry::new();
        // Register every shard's gauges up front; per-job publishing
        // only refreshes the routed shard.
        shards.publish(&metrics);
        let core = Arc::new(PipelineCore { cfg, sizes, engine, metrics, shards });
        let ingress = Arc::new(Ingress::start(Arc::clone(&core))?);
        Ok(Pipeline { core, ingress })
    }

    pub fn config(&self) -> &Config {
        &self.core.cfg
    }

    pub fn metrics(&self) -> &MetricsRegistry {
        &self.core.metrics
    }

    pub fn engine(&self) -> Option<&Arc<XlaEngine>> {
        self.core.engine.as_ref()
    }

    /// The coordinator's shard group.
    pub fn shards(&self) -> &ShardSet {
        &self.core.shards
    }

    /// The ingress stage: admission-queue introspection and per-shard
    /// drain control (see [`Ingress`]).
    pub fn ingress(&self) -> &Ingress {
        &self.ingress
    }

    /// The block multiplier chunked workloads will use.
    pub fn multiplier(&self) -> Arc<dyn BlockMultiplier> {
        self.core.multiplier()
    }

    /// The block siever the chunked sieve will use.
    pub fn siever(&self) -> Arc<dyn BlockSiever> {
        self.core.siever()
    }

    /// Stage 1 of the request path: admit the request into the bounded
    /// ingress queue and return a [`JobTicket`] immediately. The ticket
    /// is a [`Fut`](crate::susp::Fut) cell — callers `and_then`/`bind`
    /// continuations on it exactly like the paper's stream cells, or
    /// [`JobTicket::wait`] for the synchronous result.
    ///
    /// What happens when the queue is full is the configured
    /// [`AdmissionPolicy`](crate::config::AdmissionPolicy): block, shed
    /// ([`SubmitError::Shed`]), or bounded wait ([`SubmitError::Timeout`]).
    pub fn submit(&self, req: &JobRequest) -> Result<JobTicket, SubmitError> {
        self.submit_opts(req, true)
    }

    /// [`Pipeline::submit`] with verification made optional (the bench
    /// harness verifies one pre-flight job per cell and skips the oracle
    /// on the timed ones).
    pub fn submit_opts(&self, req: &JobRequest, verify: bool) -> Result<JobTicket, SubmitError> {
        self.ingress.submit(*req, verify)
    }

    /// Synchronous veneer over the staged path: admit, then block on the
    /// ticket. Under the default `admission = block` policy this has the
    /// pre-ingress semantics (never sheds, waits for capacity).
    pub fn run(&self, req: &JobRequest) -> Result<JobResult> {
        self.run_opts(req, true)
    }

    /// [`Pipeline::run`] with verification made optional.
    pub fn run_opts(&self, req: &JobRequest, verify: bool) -> Result<JobResult> {
        self.submit_opts(req, verify).map_err(|e| anyhow!("{e}"))?.wait()
    }
}

impl PipelineCore {
    pub(super) fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    pub(super) fn shards(&self) -> &ShardSet {
        &self.shards
    }

    pub(super) fn config(&self) -> &Config {
        &self.cfg
    }

    fn multiplier(&self) -> Arc<dyn BlockMultiplier> {
        match &self.engine {
            Some(engine) => Arc::new(KernelMultiplier::new(Arc::clone(engine))),
            None => Arc::new(RustMultiplier),
        }
    }

    fn siever(&self) -> Arc<dyn BlockSiever> {
        match &self.engine {
            Some(engine) => Arc::new(KernelSiever::new(Arc::clone(engine))),
            None => Arc::new(RustSiever),
        }
    }

    /// Stage 3 + 4 of the request path: execute one already-routed job on
    /// the calling thread (an ingress runner, spawned with the configured
    /// big stack) and report. Publishes timing to the metrics registry
    /// and verifies the result against the independent oracle. Only the
    /// workload itself is timed — queue wait arrives as an input, and
    /// verification runs after the clock stops.
    pub(super) fn execute_routed(
        &self,
        req: JobRequest,
        shard: &Arc<Shard>,
        verify: bool,
        queue_wait: Duration,
        migrated: bool,
    ) -> Result<JobResult> {
        let label = req.label();
        let timer = self.metrics.timer(&format!("job.{label}"));
        let steals_before = shard.stats().tasks_stolen;

        let started = Instant::now();
        let detail = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.workload_body(req, shard.as_ref())
        }))
        .map_err(|p| anyhow!("workload panicked: {}", crate::susp::panic_text(&*p)))??;
        let took = started.elapsed();

        timer.record(took);
        debug!(
            "job {label} finished in {:.3}s on shard {} (queue_wait {:.3}s migrated={})",
            took.as_secs_f64(),
            shard.id(),
            queue_wait.as_secs_f64(),
            migrated
        );
        self.metrics.counter("jobs.completed").inc();
        let stats_after = shard.stats();
        let steals = stats_after.tasks_stolen.saturating_sub(steals_before);
        shard.publish_stats(&self.metrics, &stats_after);
        let verified = !verify || self.verify(req.workload, &detail);
        if !verified {
            self.metrics.counter("jobs.verification_failed").inc();
        }
        let backend = match req.workload {
            Workload::Chunked | Workload::ChunkedBig => self.multiplier().name().to_string(),
            Workload::PrimesChunked => self.siever().name().to_string(),
            _ => "-".to_string(),
        };
        Ok(JobResult {
            request: req,
            seconds: took.as_secs_f64(),
            detail,
            verified,
            backend,
            shard: shard.id(),
            steals,
            queue_wait: queue_wait.as_secs_f64(),
            migrated,
        })
    }

    fn workload_body(&self, req: JobRequest, shard: &Shard) -> Result<ResultDetail> {
        let sizes = &self.sizes;
        match req.workload {
            Workload::Primes => Ok(self.run_sieve(shard, req.mode, sizes.primes_n)),
            Workload::PrimesX3 => Ok(self.run_sieve(shard, req.mode, sizes.primes_x3_n)),
            Workload::PrimesChunked => {
                Ok(self.run_sieve_chunked(shard, req.mode, sizes.primes_n))
            }
            Workload::Stream => {
                let (p, q) = fateman_pair(sizes.fateman_vars, sizes.fateman_degree);
                let prod = self.run_stream_times(shard, req.mode, &p, &q);
                Ok(poly_detail(&prod))
            }
            Workload::StreamBig => {
                let (p, q) = fateman_pair_big(
                    sizes.fateman_vars,
                    sizes.fateman_degree,
                    sizes.big_factor,
                );
                let prod = self.run_stream_times(shard, req.mode, &p, &q);
                Ok(poly_detail(&prod))
            }
            Workload::List => {
                let (p, q) = fateman_pair(sizes.fateman_vars, sizes.fateman_degree);
                let prod = self.run_list_times(shard, req.mode, &p, &q);
                Ok(poly_detail(&prod))
            }
            Workload::ListBig => {
                let (p, q) = fateman_pair_big(
                    sizes.fateman_vars,
                    sizes.fateman_degree,
                    sizes.big_factor,
                );
                let prod = self.run_list_times(shard, req.mode, &p, &q);
                Ok(poly_detail(&prod))
            }
            Workload::Chunked => {
                let (p, q) = fateman_pair(sizes.fateman_vars, sizes.fateman_degree);
                let prod = self.run_chunked_times(shard, req.workload, req.mode, &p, &q);
                Ok(poly_detail(&prod))
            }
            Workload::ChunkedBig => {
                let (p, q) = fateman_pair_big(
                    sizes.fateman_vars,
                    sizes.fateman_degree,
                    sizes.big_factor,
                );
                let prod = self.run_chunked_times(shard, req.workload, req.mode, &p, &q);
                Ok(poly_detail(&prod))
            }
        }
    }

    fn run_sieve(&self, shard: &Shard, mode: Mode, n: u32) -> ResultDetail {
        let primes = match mode {
            Mode::Seq => sieve::primes(LazyEval, n),
            Mode::Strict => sieve::primes(StrictEval, n),
            Mode::Par(k) => sieve::primes(FutureEval::new(shard.executor(k)), n),
        };
        ResultDetail::Primes {
            count: primes.len(),
            largest: primes.last().copied().unwrap_or(0),
        }
    }

    /// The §7 block-granular sieve. Adaptive chunking by default, with
    /// the probe cost cached on the shard; `ChunkPolicy::Fixed` keeps
    /// the constant `chunk_size` for A/B runs.
    fn run_sieve_chunked(&self, shard: &Shard, mode: Mode, n: u32) -> ResultDetail {
        let siever = self.siever();
        let primes = match self.cfg.chunk_policy {
            ChunkPolicy::Fixed => {
                let chunk = self.sizes.chunk_size;
                match mode {
                    Mode::Seq => sieve::chunked_primes_with_runtime(LazyEval, n, chunk, siever),
                    Mode::Strict => {
                        sieve::chunked_primes_with_runtime(StrictEval, n, chunk, siever)
                    }
                    Mode::Par(k) => sieve::chunked_primes_with_runtime(
                        FutureEval::new(shard.executor(k)),
                        n,
                        chunk,
                        siever,
                    ),
                }
            }
            ChunkPolicy::Adaptive => {
                let cost = shard.cost_cache(Workload::PrimesChunked.name());
                match mode {
                    Mode::Seq => {
                        sieve::chunked_primes_adaptive_cached(LazyEval, n, siever, &cost)
                    }
                    Mode::Strict => {
                        sieve::chunked_primes_adaptive_cached(StrictEval, n, siever, &cost)
                    }
                    Mode::Par(k) => sieve::chunked_primes_adaptive_cached(
                        FutureEval::new(shard.executor(k)),
                        n,
                        siever,
                        &cost,
                    ),
                }
            }
        };
        ResultDetail::Primes {
            count: primes.len(),
            largest: primes.last().copied().unwrap_or(0),
        }
    }

    fn run_stream_times<C: Coeff>(
        &self,
        shard: &Shard,
        mode: Mode,
        p: &Polynomial<C>,
        q: &Polynomial<C>,
    ) -> Polynomial<C> {
        match mode {
            Mode::Seq => stream_times(&LazyEval, p, q),
            Mode::Strict => stream_times(&StrictEval, p, q),
            Mode::Par(k) => stream_times(&FutureEval::new(shard.executor(k)), p, q),
        }
    }

    fn run_list_times<C: Coeff>(
        &self,
        shard: &Shard,
        mode: Mode,
        p: &Polynomial<C>,
        q: &Polynomial<C>,
    ) -> Polynomial<C> {
        match mode {
            Mode::Seq | Mode::Strict => list_times_seq(p, q),
            Mode::Par(k) => list_times_par(&shard.executor(k), p, q),
        }
    }

    /// Chunked multiply. Adaptive block edges by default (probe cost
    /// cached per (shard, workload)); `ChunkPolicy::Fixed` pins
    /// `chunk_size` — the pre-sharding behaviour, kept for A/B (the A1
    /// chunk-sweep ablation sets it explicitly).
    fn run_chunked_times<C: Coeff>(
        &self,
        shard: &Shard,
        workload: Workload,
        mode: Mode,
        p: &Polynomial<C>,
        q: &Polynomial<C>,
    ) -> Polynomial<C> {
        let mult = self.multiplier();
        match self.cfg.chunk_policy {
            ChunkPolicy::Fixed => {
                let chunk = self.sizes.chunk_size;
                match mode {
                    Mode::Seq => chunked_times(&LazyEval, p, q, chunk, mult),
                    Mode::Strict => chunked_times(&StrictEval, p, q, chunk, mult),
                    Mode::Par(k) => {
                        chunked_times(&FutureEval::new(shard.executor(k)), p, q, chunk, mult)
                    }
                }
            }
            ChunkPolicy::Adaptive => {
                let cost = shard.cost_cache(workload.name());
                match mode {
                    Mode::Seq => chunked_times_adaptive_cached(&LazyEval, p, q, mult, &cost),
                    Mode::Strict => {
                        chunked_times_adaptive_cached(&StrictEval, p, q, mult, &cost)
                    }
                    Mode::Par(k) => chunked_times_adaptive_cached(
                        &FutureEval::new(shard.executor(k)),
                        p,
                        q,
                        mult,
                        &cost,
                    ),
                }
            }
        }
    }

    /// Check against the independent oracle: Eratosthenes for primes,
    /// classical multiplication for polynomials.
    fn verify(&self, workload: Workload, detail: &ResultDetail) -> bool {
        let sizes = &self.sizes;
        match (workload, detail) {
            (
                Workload::Primes | Workload::PrimesChunked,
                ResultDetail::Primes { count, largest },
            ) => {
                let oracle = sieve::eratosthenes(sizes.primes_n);
                oracle.len() == *count && oracle.last().copied().unwrap_or(0) == *largest
            }
            (Workload::PrimesX3, ResultDetail::Primes { count, largest }) => {
                let oracle = sieve::eratosthenes(sizes.primes_x3_n);
                oracle.len() == *count && oracle.last().copied().unwrap_or(0) == *largest
            }
            (Workload::Stream | Workload::List | Workload::Chunked, d) => {
                let (p, q) = fateman_pair(sizes.fateman_vars, sizes.fateman_degree);
                poly_detail(&p.mul(&q)) == *d
            }
            (Workload::StreamBig | Workload::ListBig | Workload::ChunkedBig, d) => {
                let (p, q) = fateman_pair_big(
                    sizes.fateman_vars,
                    sizes.fateman_degree,
                    sizes.big_factor,
                );
                poly_detail(&p.mul(&q)) == *d
            }
            _ => false,
        }
    }
}

fn poly_detail<C: Coeff>(p: &Polynomial<C>) -> ResultDetail {
    ResultDetail::Poly {
        terms: p.num_terms(),
        leading_coeff: p.leading().map(|(_, c)| c.to_string()).unwrap_or_else(|| "0".into()),
    }
}

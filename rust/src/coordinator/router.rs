//! The pipeline router: `(workload, mode)` → algorithm × strategy.
//!
//! Monomorphization meets runtime dispatch here: the algorithms are
//! generic over [`Eval`], the request is a runtime value, so the router
//! holds the `match` that instantiates the right combination — exactly
//! the substitution the paper performs by editing one import.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};
use log::{debug, info};

use super::job::{JobRequest, JobResult, ResultDetail};
use crate::config::{Config, Mode, Workload};
use crate::exec::{Executor, ExecutorConfig};
use crate::metrics::MetricsRegistry;
use crate::poly::{
    chunked_times, list_times_par, list_times_seq, stream_times, BlockMultiplier, Coeff,
    Polynomial, RustMultiplier,
};
use crate::runtime::{KernelMultiplier, XlaEngine};
use crate::sieve;
use crate::susp::{FutureEval, LazyEval, StrictEval};
use crate::workload::{fateman_pair, fateman_pair_big, Sizes};

/// Long-lived coordinator state: config, optional PJRT engine, metrics.
pub struct Pipeline {
    cfg: Config,
    sizes: Sizes,
    engine: Option<Arc<XlaEngine>>,
    metrics: MetricsRegistry,
}

impl Pipeline {
    /// Build a pipeline. When `cfg.use_kernel` is set and the artifacts
    /// directory exists, the PJRT engine is started (compiling every
    /// artifact); otherwise chunked workloads run on the pure-Rust block
    /// backend.
    pub fn new(cfg: Config) -> Result<Pipeline> {
        cfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        let engine = if cfg.use_kernel && cfg.artifacts_dir.join("manifest.toml").exists() {
            let engine = XlaEngine::start(&cfg.artifacts_dir)
                .context("starting PJRT engine (set use_kernel=false to skip)")?;
            Some(Arc::new(engine))
        } else {
            info!("pjrt engine disabled (use_kernel={} artifacts at {:?})",
                  cfg.use_kernel, cfg.artifacts_dir);
            None
        };
        let sizes = Sizes::from_config(&cfg);
        Ok(Pipeline { cfg, sizes, engine, metrics: MetricsRegistry::new() })
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    pub fn engine(&self) -> Option<&Arc<XlaEngine>> {
        self.engine.as_ref()
    }

    /// The block multiplier chunked workloads will use.
    pub fn multiplier(&self) -> Arc<dyn BlockMultiplier> {
        match &self.engine {
            Some(engine) => Arc::new(KernelMultiplier::new(Arc::clone(engine))),
            None => Arc::new(RustMultiplier),
        }
    }

    /// Run one job on a dedicated big-stack driver thread; publishes
    /// timing to the metrics registry and verifies the result against
    /// the independent oracle. Only the workload itself is timed —
    /// verification runs after the clock stops.
    pub fn run(&self, req: &JobRequest) -> Result<JobResult> {
        self.run_opts(req, true)
    }

    /// [`Pipeline::run`] with verification made optional: the bench
    /// harness verifies the first sample of a cell and skips the oracle
    /// (a full classical multiplication) on the remaining ones.
    pub fn run_opts(&self, req: &JobRequest, verify: bool) -> Result<JobResult> {
        let req = *req;
        let label = req.label();
        let timer = self.metrics.timer(&format!("job.{label}"));

        let started = Instant::now();
        let detail = self.run_on_driver(req)?;
        let took = started.elapsed();

        timer.record(took);
        debug!("job {label} finished in {:.3}s", took.as_secs_f64());
        self.metrics.counter("jobs.completed").inc();
        let verified = !verify || self.verify(req.workload, &detail);
        if !verified {
            self.metrics.counter("jobs.verification_failed").inc();
        }
        let backend = match req.workload {
            Workload::Chunked | Workload::ChunkedBig => self.multiplier().name().to_string(),
            _ => "-".to_string(),
        };
        Ok(JobResult {
            request: req,
            seconds: took.as_secs_f64(),
            detail,
            verified,
            backend,
        })
    }

    /// Execute the workload body on a thread with the configured stack.
    fn run_on_driver(&self, req: JobRequest) -> Result<ResultDetail> {
        let stack = self.cfg.stack_size;
        std::thread::scope(|s| {
            std::thread::Builder::new()
                .name(format!("sfut-driver-{}", req.label()))
                .stack_size(stack)
                .spawn_scoped(s, || self.workload_body(req))
                .context("spawning driver thread")?
                .join()
                .map_err(|p| {
                    anyhow::anyhow!(
                        "workload panicked: {}",
                        crate::susp::panic_text(&*p)
                    )
                })?
        })
    }

    fn executor(&self, n: usize) -> Executor {
        let mut cfg = ExecutorConfig::with_parallelism(n);
        cfg.stack_size = self.cfg.stack_size;
        Executor::with_config(cfg)
    }

    fn workload_body(&self, req: JobRequest) -> Result<ResultDetail> {
        let sizes = &self.sizes;
        match req.workload {
            Workload::Primes => Ok(self.run_sieve(req.mode, sizes.primes_n)),
            Workload::PrimesX3 => Ok(self.run_sieve(req.mode, sizes.primes_x3_n)),
            Workload::Stream => {
                let (p, q) = fateman_pair(sizes.fateman_vars, sizes.fateman_degree);
                let prod = self.run_stream_times(req.mode, &p, &q);
                Ok(poly_detail(&prod))
            }
            Workload::StreamBig => {
                let (p, q) = fateman_pair_big(
                    sizes.fateman_vars,
                    sizes.fateman_degree,
                    sizes.big_factor,
                );
                let prod = self.run_stream_times(req.mode, &p, &q);
                Ok(poly_detail(&prod))
            }
            Workload::List => {
                let (p, q) = fateman_pair(sizes.fateman_vars, sizes.fateman_degree);
                let prod = self.run_list_times(req.mode, &p, &q);
                Ok(poly_detail(&prod))
            }
            Workload::ListBig => {
                let (p, q) = fateman_pair_big(
                    sizes.fateman_vars,
                    sizes.fateman_degree,
                    sizes.big_factor,
                );
                let prod = self.run_list_times(req.mode, &p, &q);
                Ok(poly_detail(&prod))
            }
            Workload::Chunked => {
                let (p, q) = fateman_pair(sizes.fateman_vars, sizes.fateman_degree);
                let prod = self.run_chunked_times(req.mode, &p, &q);
                Ok(poly_detail(&prod))
            }
            Workload::ChunkedBig => {
                let (p, q) = fateman_pair_big(
                    sizes.fateman_vars,
                    sizes.fateman_degree,
                    sizes.big_factor,
                );
                let prod = self.run_chunked_times(req.mode, &p, &q);
                Ok(poly_detail(&prod))
            }
        }
    }

    fn run_sieve(&self, mode: Mode, n: u32) -> ResultDetail {
        let primes = match mode {
            Mode::Seq => sieve::primes(LazyEval, n),
            Mode::Strict => sieve::primes(StrictEval, n),
            Mode::Par(k) => sieve::primes(FutureEval::new(self.executor(k)), n),
        };
        ResultDetail::Primes {
            count: primes.len(),
            largest: primes.last().copied().unwrap_or(0),
        }
    }

    fn run_stream_times<C: Coeff>(
        &self,
        mode: Mode,
        p: &Polynomial<C>,
        q: &Polynomial<C>,
    ) -> Polynomial<C> {
        match mode {
            Mode::Seq => stream_times(&LazyEval, p, q),
            Mode::Strict => stream_times(&StrictEval, p, q),
            Mode::Par(k) => stream_times(&FutureEval::new(self.executor(k)), p, q),
        }
    }

    fn run_list_times<C: Coeff>(
        &self,
        mode: Mode,
        p: &Polynomial<C>,
        q: &Polynomial<C>,
    ) -> Polynomial<C> {
        match mode {
            Mode::Seq | Mode::Strict => list_times_seq(p, q),
            Mode::Par(k) => list_times_par(&self.executor(k), p, q),
        }
    }

    fn run_chunked_times<C: Coeff>(
        &self,
        mode: Mode,
        p: &Polynomial<C>,
        q: &Polynomial<C>,
    ) -> Polynomial<C> {
        let mult = self.multiplier();
        let chunk = self.sizes.chunk_size;
        match mode {
            Mode::Seq => chunked_times(&LazyEval, p, q, chunk, mult),
            Mode::Strict => chunked_times(&StrictEval, p, q, chunk, mult),
            Mode::Par(k) => {
                chunked_times(&FutureEval::new(self.executor(k)), p, q, chunk, mult)
            }
        }
    }

    /// Check against the independent oracle: Eratosthenes for primes,
    /// classical multiplication for polynomials.
    fn verify(&self, workload: Workload, detail: &ResultDetail) -> bool {
        let sizes = &self.sizes;
        match (workload, detail) {
            (Workload::Primes, ResultDetail::Primes { count, largest }) => {
                let oracle = sieve::eratosthenes(sizes.primes_n);
                oracle.len() == *count && oracle.last().copied().unwrap_or(0) == *largest
            }
            (Workload::PrimesX3, ResultDetail::Primes { count, largest }) => {
                let oracle = sieve::eratosthenes(sizes.primes_x3_n);
                oracle.len() == *count && oracle.last().copied().unwrap_or(0) == *largest
            }
            (Workload::Stream | Workload::List | Workload::Chunked, d) => {
                let (p, q) = fateman_pair(sizes.fateman_vars, sizes.fateman_degree);
                poly_detail(&p.mul(&q)) == *d
            }
            (Workload::StreamBig | Workload::ListBig | Workload::ChunkedBig, d) => {
                let (p, q) = fateman_pair_big(
                    sizes.fateman_vars,
                    sizes.fateman_degree,
                    sizes.big_factor,
                );
                poly_detail(&p.mul(&q)) == *d
            }
            _ => false,
        }
    }
}

fn poly_detail<C: Coeff>(p: &Polynomial<C>) -> ResultDetail {
    ResultDetail::Poly {
        terms: p.num_terms(),
        leading_coeff: p.leading().map(|(_, c)| c.to_string()).unwrap_or_else(|| "0".into()),
    }
}

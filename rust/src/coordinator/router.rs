//! The pipeline router: `(workload name, mode)` → plugin × strategy ×
//! shard.
//!
//! Monomorphization meets runtime dispatch here — but since the
//! workload-plugin redesign, *no per-workload code lives in the
//! coordinator*. The request names a workload; [`PipelineCore`] resolves
//! it in the [`WorkloadRegistry`], builds a
//! [`WorkloadCtx`](crate::workload::WorkloadCtx) from the routed shard's
//! resources, and the plugin's generic body (written once over
//! `E: Eval`) runs under whatever strategy the mode selects — exactly
//! the substitution the paper performs by editing one import, now a
//! registry lookup plus a virtual call.
//!
//! Since the ingress rework, [`Pipeline`] is a cloneable handle over two
//! halves:
//!
//! * [`PipelineCore`] — config, optional PJRT engine, metrics, the
//!   [`ShardSet`], the [`WorkloadRegistry`], and the
//!   execute/verify/report logic ([`PipelineCore::execute_routed`]). It
//!   knows nothing about queues.
//! * [`Ingress`](super::ingress::Ingress) — the staged admission path
//!   (validate → admit → route → execute → report). [`Pipeline::submit`]
//!   schema-checks the request against the registry, enqueues it, and
//!   returns a [`JobTicket`] immediately; dispatcher threads route it to
//!   a shard's run queue; shard runner threads execute it (stealing
//!   whole queued jobs across shards when one backs up) and fulfill the
//!   ticket.
//!
//! The synchronous API survives as a veneer: [`Pipeline::run`] is
//! `submit` + [`JobTicket::wait`], so every job — CLI, serve session,
//! bench client — flows through the same admission queue and
//! backpressure policy.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};
use log::{debug, info, warn};

use super::ingress::{Ingress, JobTicket, SubmitError};
use super::job::{JobRequest, JobResult, ResultDetail};
use super::shard::{Shard, ShardSet};
use crate::config::{ChunkPolicy, Config};
use crate::metrics::MetricsRegistry;
use crate::poly::BlockMultiplier;
use crate::runtime::{KernelMultiplier, KernelSiever, XlaEngine};
use crate::sieve::{BlockSiever, RustSiever};
use crate::susp::{CancelScope, CancelToken};
use crate::workload::{Sizes, WorkloadCtx, WorkloadError, WorkloadRegistry};

/// Reserved wire parameter: per-job deadline in milliseconds. Consumed
/// by the coordinator (admission validation + the deadline reaper);
/// stripped before the plugin's schema validation, so every workload
/// accepts it without declaring it.
pub(super) const DEADLINE_PARAM: &str = "deadline_ms";

/// Classified result of one execution attempt — the router reports *what
/// happened*, the ingress decides *what to do about it* (complete the
/// ticket, retry on another shard, trip a breaker).
pub(super) enum ExecOutcome {
    /// Completed (boxed: the success payload is much larger than the
    /// failure arms).
    Done(Box<JobResult>),
    /// Deterministic failure (validation-style error from the plugin, or
    /// an unknown workload). Not retried.
    Failed(String),
    /// The workload body panicked. Transient from the coordinator's
    /// point of view: eligible for retry on a different shard.
    Panicked(String),
    /// The job's cancel token tripped (deadline reaper) and the body
    /// unwound — or finished too late to count. Eligible for retry.
    TimedOut,
}

/// Long-lived coordinator state: config, optional PJRT engine, metrics,
/// the shard group, the workload registry, and the execution logic.
/// Shared (via `Arc`) between the [`Pipeline`] handle and the ingress
/// worker threads.
pub(super) struct PipelineCore {
    cfg: Config,
    sizes: Sizes,
    engine: Option<Arc<XlaEngine>>,
    metrics: MetricsRegistry,
    shards: ShardSet,
    registry: WorkloadRegistry,
}

/// Handle to a running coordinator: cheap to clone, shared across serve
/// sessions. Dropping the last handle shuts the ingress down (draining
/// queued jobs, resolving their tickets).
#[derive(Clone)]
pub struct Pipeline {
    core: Arc<PipelineCore>,
    ingress: Arc<Ingress>,
}

impl Pipeline {
    /// Build a pipeline over the builtin workload registry and start its
    /// ingress (dispatcher + shard runner threads). When `cfg.use_kernel`
    /// is set and the artifacts directory exists, the PJRT engine is
    /// started (compiling every artifact); otherwise chunked workloads
    /// run on the pure-Rust block backend.
    pub fn new(cfg: Config) -> Result<Pipeline> {
        Pipeline::with_registry(cfg, WorkloadRegistry::builtin())
    }

    /// [`Pipeline::new`] with a caller-supplied registry — the open
    /// workload world's front door: register custom
    /// [`StreamWorkload`](crate::workload::StreamWorkload) plugins and
    /// the whole coordinator (routing, verification, serve protocol,
    /// bench harness) serves them with no further edits.
    pub fn with_registry(cfg: Config, registry: WorkloadRegistry) -> Result<Pipeline> {
        cfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        if registry.is_empty() {
            return Err(anyhow!("workload registry is empty — nothing to serve"));
        }
        let engine = if cfg.use_kernel && cfg.artifacts_dir.join("manifest.toml").exists() {
            let engine = XlaEngine::start(&cfg.artifacts_dir)
                .context("starting PJRT engine (set use_kernel=false to skip)")?;
            Some(Arc::new(engine))
        } else {
            info!("pjrt engine disabled (use_kernel={} artifacts at {:?})",
                  cfg.use_kernel, cfg.artifacts_dir);
            None
        };
        if cfg.chunk_policy == ChunkPolicy::Adaptive
            && cfg.chunk_size != Config::default().chunk_size
        {
            warn!(
                "chunk_size={} is ignored under chunk_policy=adaptive (the sizer probes \
                 its own edge); set chunk_policy=fixed to pin it",
                cfg.chunk_size
            );
        }
        let sizes = Sizes::from_config(&cfg);
        let shards = ShardSet::new(&cfg);
        info!(
            "coordinator sharded {} way(s); {} workload(s) registered; ingress queue_depth={} \
             admission={}",
            shards.len(),
            registry.len(),
            cfg.queue_depth,
            cfg.admission.label()
        );
        let metrics = MetricsRegistry::new();
        // Register every shard's gauges up front; per-job publishing
        // only refreshes the routed shard.
        shards.publish(&metrics);
        let core = Arc::new(PipelineCore { cfg, sizes, engine, metrics, shards, registry });
        let ingress = Arc::new(Ingress::start(Arc::clone(&core))?);
        Ok(Pipeline { core, ingress })
    }

    pub fn config(&self) -> &Config {
        &self.core.cfg
    }

    pub fn metrics(&self) -> &MetricsRegistry {
        &self.core.metrics
    }

    pub fn engine(&self) -> Option<&Arc<XlaEngine>> {
        self.core.engine.as_ref()
    }

    /// The coordinator's shard group.
    pub fn shards(&self) -> &ShardSet {
        &self.core.shards
    }

    /// The open workload set this pipeline serves.
    pub fn registry(&self) -> &WorkloadRegistry {
        &self.core.registry
    }

    /// The ingress stage: admission-queue introspection and per-shard
    /// drain control (see [`Ingress`]).
    pub fn ingress(&self) -> &Ingress {
        &self.ingress
    }

    /// The block multiplier chunked workloads will use.
    pub fn multiplier(&self) -> Arc<dyn BlockMultiplier> {
        self.core.multiplier()
    }

    /// The block siever the chunked sieve will use.
    pub fn siever(&self) -> Arc<dyn BlockSiever> {
        self.core.siever()
    }

    /// Stage 1 of the request path: schema-check the request against the
    /// registry, admit it into the bounded ingress queue, and return a
    /// [`JobTicket`] immediately. The ticket is a
    /// [`Fut`](crate::susp::Fut) cell — callers `and_then`/`bind`
    /// continuations on it exactly like the paper's stream cells, or
    /// [`JobTicket::wait`] for the synchronous result.
    ///
    /// Unknown workload names and out-of-schema params answer
    /// [`SubmitError::Rejected`] *before* taking any queue capacity.
    /// What happens when the queue is full is the configured
    /// [`AdmissionPolicy`](crate::config::AdmissionPolicy): block, shed
    /// ([`SubmitError::Shed`]), or bounded wait ([`SubmitError::Timeout`]).
    pub fn submit(&self, req: &JobRequest) -> Result<JobTicket, SubmitError> {
        self.submit_opts(req, true)
    }

    /// [`Pipeline::submit`] with verification made optional (the bench
    /// harness verifies one pre-flight job per cell and skips the oracle
    /// on the timed ones).
    pub fn submit_opts(&self, req: &JobRequest, verify: bool) -> Result<JobTicket, SubmitError> {
        self.ingress.submit(req.clone(), verify)
    }

    /// Synchronous veneer over the staged path: admit, then block on the
    /// ticket. Under the default `admission = block` policy this has the
    /// pre-ingress semantics (never sheds, waits for capacity).
    pub fn run(&self, req: &JobRequest) -> Result<JobResult> {
        self.run_opts(req, true)
    }

    /// [`Pipeline::run`] with verification made optional.
    pub fn run_opts(&self, req: &JobRequest, verify: bool) -> Result<JobResult> {
        self.submit_opts(req, verify).map_err(|e| anyhow!("{e}"))?.wait()
    }
}

impl PipelineCore {
    pub(super) fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    pub(super) fn shards(&self) -> &ShardSet {
        &self.shards
    }

    pub(super) fn config(&self) -> &Config {
        &self.cfg
    }

    fn multiplier(&self) -> Arc<dyn BlockMultiplier> {
        match &self.engine {
            Some(engine) => Arc::new(KernelMultiplier::new(Arc::clone(engine))),
            None => Arc::new(crate::poly::RustMultiplier),
        }
    }

    fn siever(&self) -> Arc<dyn BlockSiever> {
        match &self.engine {
            Some(engine) => Arc::new(KernelSiever::new(Arc::clone(engine))),
            None => Arc::new(RustSiever),
        }
    }

    /// The per-job plugin context: configured sizes + chunk policy +
    /// block backends + the routed shard's warm pools and cost caches.
    fn workload_ctx<'a>(&'a self, shard: &'a Shard) -> WorkloadCtx<'a> {
        WorkloadCtx::new(
            &self.sizes,
            self.cfg.chunk_policy,
            self.multiplier(),
            self.siever(),
            shard,
        )
    }

    /// Submit-time gate: the workload must be registered and the params
    /// must pass its schema. Runs before any queue slot is taken, so
    /// malformed requests answer immediately.
    pub(super) fn validate_request(&self, req: &JobRequest) -> Result<(), WorkloadError> {
        let Some(plugin) = self.registry.get(&req.workload) else {
            return Err(WorkloadError::new(format!(
                "unknown workload: {} (registered: {})",
                req.workload,
                self.registry.names().join(" ")
            )));
        };
        if let Some(v) = req.params.get(DEADLINE_PARAM) {
            // Type-check the reserved key here (it never reaches the
            // plugin schema), then validate the rest without it.
            if v.parse::<u64>().is_err() {
                return Err(WorkloadError::new(format!(
                    "bad value for param {DEADLINE_PARAM}: {v:?} (want u64)"
                )));
            }
            let mut stripped = req.params.clone();
            stripped.remove(DEADLINE_PARAM);
            return plugin.validate(&stripped);
        }
        plugin.validate(&req.params)
    }

    /// Stage 3 + 4 of the request path: execute one already-routed job on
    /// the calling thread (an ingress runner, spawned with the configured
    /// big stack) and report a classified [`ExecOutcome`]. Publishes
    /// timing to the metrics registry and verifies the result against the
    /// plugin's independent oracle — but only on the `Done` arm; failed,
    /// panicked, and timed-out attempts record nothing so that retries
    /// don't double-count. Only the workload itself is timed — queue wait
    /// arrives as an input, and verification runs after the clock stops.
    ///
    /// `cancel` is installed both on the [`WorkloadCtx`] (explicit
    /// polling) and as the ambient [`CancelScope`] (stream traversal
    /// loops) for the duration of the body; a body that unwinds with the
    /// cancellation marker — or completes after the token tripped — is
    /// classified `TimedOut`, not `Panicked`.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn execute_routed(
        &self,
        req: JobRequest,
        shard: &Arc<Shard>,
        verify: bool,
        queue_wait: Duration,
        migrated: bool,
        cancel: &CancelToken,
        attempt: u32,
    ) -> ExecOutcome {
        let label = req.label();
        // Timer names use the bare workload name, not the full param
        // spec: metric entries live forever, and params come straight
        // off the wire — `job.primes(n=1).seq`, `job.primes(n=2).seq`,
        // … would grow the registry without bound under a param sweep.
        let timer =
            self.metrics.timer(&format!("job.{}.{}", req.workload, req.mode.label()));
        let steals_before = shard.stats().tasks_stolen;
        // Resolved at submit time too; a miss here means the registry
        // changed under a queued job, which cannot happen (the registry
        // is immutable once the pipeline is built).
        let Some(plugin) = self.registry.get(&req.workload) else {
            return ExecOutcome::Failed(format!("unknown workload: {}", req.workload));
        };
        let plugin = Arc::clone(plugin);
        let ctx = self
            .workload_ctx(shard.as_ref())
            .with_cancel(cancel.clone())
            .with_attempt(attempt);

        let started = Instant::now();
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ambient = CancelScope::enter(cancel.clone());
            plugin.run(&ctx, req.mode, &req.params)
        }));
        let took = started.elapsed();
        let detail: ResultDetail = match run {
            Err(payload) => {
                return if crate::susp::cancel::was_cancelled(&*payload) || cancel.is_cancelled()
                {
                    ExecOutcome::TimedOut
                } else {
                    ExecOutcome::Panicked(crate::susp::panic_text(&*payload))
                };
            }
            Ok(Err(e)) => {
                return if cancel.is_cancelled() {
                    ExecOutcome::TimedOut
                } else {
                    ExecOutcome::Failed(format!("workload {} failed: {e}", req.workload))
                };
            }
            // Completed after the deadline tripped: the outcome already
            // counts as a timeout (and may have been superseded by a
            // retry); discard the late result.
            Ok(Ok(_)) if cancel.is_cancelled() => return ExecOutcome::TimedOut,
            Ok(Ok(detail)) => detail,
        };

        timer.record(took);
        debug!(
            "job {label} finished in {:.3}s on shard {} (queue_wait {:.3}s migrated={})",
            took.as_secs_f64(),
            shard.id(),
            queue_wait.as_secs_f64(),
            migrated
        );
        self.metrics.counter("jobs.completed").inc();
        let stats_after = shard.stats();
        let steals = stats_after.tasks_stolen.saturating_sub(steals_before);
        shard.publish_stats(&self.metrics, &stats_after);
        let verified = !verify || plugin.verify(&ctx, &req.params, &detail);
        if !verified {
            self.metrics.counter("jobs.verification_failed").inc();
        }
        let backend = plugin.backend(&ctx, &req.params);
        ExecOutcome::Done(Box::new(JobResult {
            request: req,
            seconds: took.as_secs_f64(),
            detail,
            verified,
            backend,
            shard: shard.id(),
            steals,
            queue_wait: queue_wait.as_secs_f64(),
            migrated,
        }))
    }
}

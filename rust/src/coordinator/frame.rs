//! Length-prefixed binary frame codec for the framed wire protocol.
//!
//! The layout is KLV-style, deliberately minimal (rebar's `FORMAT.md`
//! is the exemplar): a connection opens with a 5-byte preamble — the
//! magic `b"SFUT"` followed by a `u8` protocol version — and every
//! subsequent message in either direction is one frame:
//!
//! ```text
//! +----------------+--------+-----------------+
//! | u32 LE length  | u8 kind| payload (length)|
//! +----------------+--------+-----------------+
//! ```
//!
//! `length` counts the payload only (not the 5-byte header). Payloads
//! are capped at [`MAX_FRAME_LEN`]; a declared length beyond the cap is
//! a protocol error answered before any payload bytes are buffered, so
//! a hostile client cannot make the server allocate unboundedly.
//!
//! The decoder is incremental: [`FrameDecoder::feed`] accepts bytes in
//! whatever chunks the socket delivers (one byte at a time from a
//! slow-loris client, a hundred pipelined frames in one read) and
//! [`FrameDecoder::next`] yields complete frames. EOF mid-frame is not
//! a decoder error — the session layer distinguishes "clean close at a
//! frame boundary" from "mid-frame disconnect" via
//! [`FrameDecoder::has_partial`].
//!
//! See the "Wire protocol" section of [`crate::coordinator`] for the
//! kind table and the mapping onto the text protocol.

use std::io::Read;

/// Connection preamble magic (client → server, before any frame).
pub const MAGIC: [u8; 4] = *b"SFUT";

/// Current protocol version, echoed back in the server's `Hello` frame.
pub const VERSION: u8 = 1;

/// Hard cap on a frame payload, in bytes. Large enough for any result
/// line or workload listing; small enough that a malicious length
/// prefix cannot drive allocation.
pub const MAX_FRAME_LEN: usize = 256 * 1024;

/// Frame header size: u32 length + u8 kind.
pub const HEADER_LEN: usize = 5;

/// Frame kinds. Client-originated kinds are low numbers, server replies
/// start at 16 — the split makes a direction bug visible in a hex dump.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Client: submit a job. Payload is the UTF-8 text-protocol spec
    /// (`workload(params) mode`), reusing the text parser.
    Submit = 1,
    /// Client: block (server-side) until a ticket resolves. Payload is
    /// a u64 LE ticket id.
    Wait = 2,
    /// Client: nonblocking ticket state query. Payload is a u64 LE
    /// ticket id.
    Poll = 3,
    /// Client: list registered workloads. Empty payload.
    Workloads = 4,
    /// Server: handshake accepted. Payload is `[VERSION]`.
    Hello = 16,
    /// Server: a submit was admitted. Payload is u64 LE ticket id +
    /// u8 state code (0 empty, 1 running, 2 ready, 3 panicked — see
    /// the kind table in [`crate::coordinator`]'s wire-protocol docs).
    Ticket = 17,
    /// Server: a wait/poll resolved with a result. Payload is u64 LE
    /// ticket id + the UTF-8 `ok …` result line.
    Result = 18,
    /// Server: an error. Payload is u64 LE ticket id (0 when no ticket
    /// is involved) + the UTF-8 `err …` line, same taxonomy as the
    /// text protocol.
    Err = 19,
    /// Server: reply to [`FrameKind::Workloads`]. Payload is the UTF-8
    /// listing, newline-separated.
    WorkloadsReply = 20,
}

impl FrameKind {
    pub fn from_u8(b: u8) -> Option<FrameKind> {
        Some(match b {
            1 => FrameKind::Submit,
            2 => FrameKind::Wait,
            3 => FrameKind::Poll,
            4 => FrameKind::Workloads,
            16 => FrameKind::Hello,
            17 => FrameKind::Ticket,
            18 => FrameKind::Result,
            19 => FrameKind::Err,
            20 => FrameKind::WorkloadsReply,
            _ => return None,
        })
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }
}

/// One decoded frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn new(kind: FrameKind, payload: Vec<u8>) -> Frame {
        Frame { kind, payload }
    }

    /// Serialize to header + payload bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + self.payload.len());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.push(self.kind.as_u8());
        out.extend_from_slice(&self.payload);
        out
    }

    /// Append the encoded frame to an existing buffer (the reactor's
    /// per-session write buffer).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.push(self.kind.as_u8());
        out.extend_from_slice(&self.payload);
    }
}

/// Protocol violations the decoder (or handshake check) can detect.
/// Each maps to exactly one `err` frame followed by connection close.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// Declared payload length exceeds [`MAX_FRAME_LEN`].
    Oversized { len: usize },
    /// Frame kind byte is not in the [`FrameKind`] table.
    UnknownKind(u8),
    /// Connection preamble did not start with [`MAGIC`].
    BadMagic,
    /// Preamble magic matched but the version is unsupported.
    BadVersion(u8),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Oversized { len } => {
                write!(f, "frame payload {len} bytes exceeds cap {MAX_FRAME_LEN}")
            }
            FrameError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            FrameError::BadMagic => write!(f, "bad connection magic (want SFUT)"),
            FrameError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (want {VERSION})")
            }
        }
    }
}

/// Incremental frame decoder over an internal byte buffer.
///
/// Feed it whatever the socket yields; pull complete frames with
/// [`FrameDecoder::next`]. The decoder validates the header (length
/// cap, kind table) as soon as the 5 header bytes are present — before
/// waiting for the payload — so oversized declarations fail fast.
#[derive(Debug, Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Buffer incoming bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered (partial frame or not-yet-pulled
    /// complete frames).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// True when buffered bytes form an incomplete frame — i.e. EOF now
    /// would be a mid-frame disconnect, not a clean close.
    pub fn has_partial(&self) -> bool {
        !self.buf.is_empty()
    }

    /// Pull the next complete frame, if the buffer holds one.
    ///
    /// `Ok(None)` means "need more bytes"; `Err` is a protocol
    /// violation (the buffer is left as-is — the session is dead and
    /// should be closed after one `err` frame).
    pub fn next(&mut self) -> Result<Option<Frame>, FrameError> {
        if self.buf.len() < HEADER_LEN {
            return Ok(None);
        }
        let len = u32::from_le_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]]) as usize;
        if len > MAX_FRAME_LEN {
            return Err(FrameError::Oversized { len });
        }
        let Some(kind) = FrameKind::from_u8(self.buf[4]) else {
            return Err(FrameError::UnknownKind(self.buf[4]));
        };
        if self.buf.len() < HEADER_LEN + len {
            return Ok(None);
        }
        let payload = self.buf[HEADER_LEN..HEADER_LEN + len].to_vec();
        self.buf.drain(..HEADER_LEN + len);
        Ok(Some(Frame { kind, payload }))
    }
}

/// Validate a 5-byte connection preamble.
pub fn check_preamble(bytes: &[u8; 5]) -> Result<(), FrameError> {
    if bytes[..4] != MAGIC {
        return Err(FrameError::BadMagic);
    }
    if bytes[4] != VERSION {
        return Err(FrameError::BadVersion(bytes[4]));
    }
    Ok(())
}

/// Encode the client preamble (magic + version).
pub fn preamble() -> [u8; 5] {
    [MAGIC[0], MAGIC[1], MAGIC[2], MAGIC[3], VERSION]
}

// ---- payload helpers -------------------------------------------------

/// u64 LE ticket id prefix shared by Ticket/Result/Err payloads.
pub fn put_ticket_id(out: &mut Vec<u8>, id: u64) {
    out.extend_from_slice(&id.to_le_bytes());
}

/// Read the u64 LE ticket id prefix off a payload; `None` if short.
pub fn take_ticket_id(payload: &[u8]) -> Option<(u64, &[u8])> {
    if payload.len() < 8 {
        return None;
    }
    let mut id = [0u8; 8];
    id.copy_from_slice(&payload[..8]);
    Some((u64::from_le_bytes(id), &payload[8..]))
}

/// Build a `Ticket` frame payload: id + state code.
pub fn ticket_payload(id: u64, state_code: u8) -> Vec<u8> {
    let mut p = Vec::with_capacity(9);
    put_ticket_id(&mut p, id);
    p.push(state_code);
    p
}

/// Build a `Result`/`Err`/`WorkloadsReply`-style payload: id + UTF-8
/// line.
pub fn line_payload(id: u64, line: &str) -> Vec<u8> {
    let mut p = Vec::with_capacity(8 + line.len());
    put_ticket_id(&mut p, id);
    p.extend_from_slice(line.as_bytes());
    p
}

/// Blocking read of exactly one frame from a stream — test/bench client
/// helper, not used by the reactor (which decodes incrementally).
pub fn read_frame(stream: &mut impl Read) -> std::io::Result<Option<Frame>> {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0;
    while got < HEADER_LEN {
        match stream.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof mid-frame-header",
                ))
            }
            Ok(n) => got += n,
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes([header[0], header[1], header[2], header[3]]) as usize;
    if len > MAX_FRAME_LEN {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame payload {len} exceeds cap"),
        ));
    }
    let Some(kind) = FrameKind::from_u8(header[4]) else {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("unknown frame kind {}", header[4]),
        ));
    };
    let mut payload = vec![0u8; len];
    let mut got = 0;
    while got < len {
        match stream.read(&mut payload[got..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof mid-frame-payload",
                ))
            }
            Ok(n) => got += n,
            Err(e) => return Err(e),
        }
    }
    Ok(Some(Frame { kind, payload }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_single_frame() {
        let frame = Frame::new(FrameKind::Submit, b"primes(n=10) seq".to_vec());
        let bytes = frame.encode();
        let mut dec = FrameDecoder::new();
        dec.feed(&bytes);
        assert_eq!(dec.next().unwrap(), Some(frame));
        assert!(!dec.has_partial());
        assert_eq!(dec.next().unwrap(), None);
    }

    #[test]
    fn byte_at_a_time_slow_loris() {
        let frame = Frame::new(FrameKind::Wait, 42u64.to_le_bytes().to_vec());
        let bytes = frame.encode();
        let mut dec = FrameDecoder::new();
        for (i, b) in bytes.iter().enumerate() {
            dec.feed(std::slice::from_ref(b));
            let got = dec.next().unwrap();
            if i + 1 < bytes.len() {
                assert!(got.is_none(), "frame complete too early at byte {i}");
                assert!(dec.has_partial());
            } else {
                assert_eq!(got, Some(frame.clone()));
            }
        }
    }

    #[test]
    fn pipelined_batch_in_one_feed() {
        let mut bytes = Vec::new();
        for i in 0..100u64 {
            Frame::new(FrameKind::Poll, i.to_le_bytes().to_vec()).encode_into(&mut bytes);
        }
        let mut dec = FrameDecoder::new();
        dec.feed(&bytes);
        for i in 0..100u64 {
            let f = dec.next().unwrap().expect("frame {i} missing");
            assert_eq!(f.kind, FrameKind::Poll);
            assert_eq!(take_ticket_id(&f.payload).unwrap().0, i);
        }
        assert_eq!(dec.next().unwrap(), None);
        assert!(!dec.has_partial());
    }

    #[test]
    fn oversized_length_rejected_before_payload() {
        let mut dec = FrameDecoder::new();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&((MAX_FRAME_LEN as u32) + 1).to_le_bytes());
        bytes.push(FrameKind::Submit.as_u8());
        // No payload bytes at all — the header alone must trip the cap.
        dec.feed(&bytes);
        assert_eq!(dec.next(), Err(FrameError::Oversized { len: MAX_FRAME_LEN + 1 }));
    }

    #[test]
    fn unknown_kind_rejected() {
        let mut dec = FrameDecoder::new();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.push(99);
        dec.feed(&bytes);
        assert_eq!(dec.next(), Err(FrameError::UnknownKind(99)));
    }

    #[test]
    fn preamble_checks() {
        assert!(check_preamble(&preamble()).is_ok());
        assert_eq!(check_preamble(b"NOPE\x01"), Err(FrameError::BadMagic));
        assert_eq!(check_preamble(b"SFUT\x07"), Err(FrameError::BadVersion(7)));
    }

    #[test]
    fn ticket_id_helpers_roundtrip() {
        let p = line_payload(7, "ok done");
        let (id, rest) = take_ticket_id(&p).unwrap();
        assert_eq!(id, 7);
        assert_eq!(rest, b"ok done");
        assert_eq!(take_ticket_id(&[1, 2, 3]), None);
    }
}

//! TCP front-end for the request server: `sfut serve --tcp ADDR`.
//!
//! Two wire modes, selected per-listener ([`Config::wire`], `--wire`,
//! `SFUT_WIRE`):
//!
//! * **text** (compat + A/B baseline) — one session thread per
//!   connection speaking the line protocol of `server.rs`, including
//!   the ticketed `submit`/`wait` commands and the `err admission=…`
//!   shed/timeout lines.
//! * **framed** — a pool of reactor threads (`reactor.rs`) over a
//!   pluggable readiness backend (`poller.rs`: poll(2) or epoll,
//!   `Config::poller`) speaking the length-prefixed binary frame
//!   protocol of `frame.rs`; no per-connection threads, accepts fanned
//!   out across reactors (`Config::reactors`), each session pinned to
//!   one reactor, write backpressure wired into the admission policy.
//!
//! Both modes share the [`Pipeline`] (and therefore the PJRT engine,
//! the metrics registry, and the config), the same job taxonomy, and
//! this handle's `local_addr`/`sessions`/`live_sessions`/`shutdown`
//! surface.
//!
//! Session threads are tracked: [`TcpServer::shutdown`] stops accepting,
//! then waits (bounded) for in-flight sessions to finish so their jobs
//! complete before the pipeline drops; stragglers hung on a live client
//! socket are detached with a warning rather than blocking shutdown
//! forever.
//!
//! Sessions share the server's stop flag: a client parked on `wait`
//! during shutdown is drained by `serve_with_stop` — it gets either the
//! job's real result (if it lands within the drain grace) or a final
//! well-formed `err closed ticket=N` line, never a silently dropped
//! connection mid-command.

use std::io::BufReader;
use std::net::{TcpListener, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use log::{info, warn};

use super::router::Pipeline;
use super::server::serve_with_stop;
use crate::config::WireProtocol;

/// How long [`TcpServer::shutdown`] waits for in-flight sessions before
/// detaching them.
const SESSION_DRAIN_WINDOW: Duration = Duration::from_secs(5);

/// Handle to a running TCP server (for tests and graceful shutdown),
/// uniform across both wire modes.
pub struct TcpServer {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    sessions: Arc<AtomicU64>,
    session_threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
    /// Text mode: the accept-loop thread.
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// Framed mode: the reactor pool's threads, joined on shutdown with
    /// the same bounded drain as text sessions.
    reactor_threads: Vec<JoinHandle<()>>,
    /// Framed mode: one waker per reactor (interrupts its wait on
    /// shutdown); cleared after the pool joins so the self-pipe write
    /// fds close with shutdown, not process exit.
    #[cfg(unix)]
    wakers: Vec<super::reactor::Waker>,
    /// Framed mode: live sessions per reactor (text mode counts
    /// tracked session threads instead).
    reactor_live: Arc<Vec<AtomicU64>>,
    /// Framed mode: sessions ever pinned to each reactor — the
    /// accept-fanout distribution.
    pinned: Arc<Vec<AtomicU64>>,
}

impl TcpServer {
    /// Bind and start accepting under the pipeline's configured wire
    /// protocol ([`Config::wire`]). `pipeline` is shared across
    /// sessions.
    pub fn start(pipeline: Arc<Pipeline>, addr: impl ToSocketAddrs) -> Result<TcpServer> {
        let wire = pipeline.config().wire;
        TcpServer::start_wire(pipeline, addr, wire)
    }

    /// [`TcpServer::start`] with the wire protocol chosen per-listener
    /// (the A/B harness runs one framed and one text listener over
    /// identical pipelines).
    pub fn start_wire(
        pipeline: Arc<Pipeline>,
        addr: impl ToSocketAddrs,
        wire: WireProtocol,
    ) -> Result<TcpServer> {
        let stop = Arc::new(AtomicBool::new(false));
        let sessions = Arc::new(AtomicU64::new(0));
        let session_threads = Arc::new(Mutex::new(Vec::new()));
        match wire {
            WireProtocol::Text => {
                let listener = TcpListener::bind(addr).context("binding TCP listener")?;
                let local_addr = listener.local_addr()?;
                listener.set_nonblocking(true)?;
                info!("sfut tcp server listening on {local_addr} (wire={})", wire.label());
                let stop2 = Arc::clone(&stop);
                let sessions2 = Arc::clone(&sessions);
                let threads2 = Arc::clone(&session_threads);
                let accept_thread = std::thread::Builder::new()
                    .name("sfut-tcp-accept".to_string())
                    .spawn(move || {
                        accept_loop(listener, pipeline, stop2, sessions2, threads2);
                    })
                    .context("spawning accept thread")?;
                Ok(TcpServer {
                    local_addr,
                    stop,
                    sessions,
                    session_threads,
                    accept_thread: Some(accept_thread),
                    reactor_threads: Vec::new(),
                    #[cfg(unix)]
                    wakers: Vec::new(),
                    reactor_live: Arc::new(Vec::new()),
                    pinned: Arc::new(Vec::new()),
                })
            }
            #[cfg(unix)]
            WireProtocol::Framed => {
                // The pool binds for itself: an SO_REUSEPORT listener
                // group must set the option before bind, which a
                // std-bound listener cannot retrofit.
                let sock_addr = addr
                    .to_socket_addrs()
                    .context("resolving listen address")?
                    .next()
                    .context("listen address resolved to nothing")?;
                let handle = super::reactor::start_pool(
                    sock_addr,
                    pipeline,
                    Arc::clone(&stop),
                    Arc::clone(&sessions),
                )?;
                Ok(TcpServer {
                    local_addr: handle.local_addr,
                    stop,
                    sessions,
                    session_threads,
                    accept_thread: None,
                    reactor_threads: handle.threads,
                    wakers: handle.wakers,
                    reactor_live: handle.live,
                    pinned: handle.pinned,
                })
            }
            #[cfg(not(unix))]
            WireProtocol::Framed => {
                anyhow::bail!("wire=framed needs a unix platform (poll); use wire=text")
            }
        }
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Total sessions accepted so far.
    pub fn sessions(&self) -> u64 {
        self.sessions.load(Ordering::Relaxed)
    }

    /// Sessions currently live: tracked (unjoined) session threads in
    /// text mode, open reactor sessions in framed mode. 0 after a
    /// clean [`TcpServer::shutdown`].
    pub fn live_sessions(&self) -> usize {
        let reactor: u64 = self.reactor_live.iter().map(|a| a.load(Ordering::Relaxed)).sum();
        self.session_threads.lock().unwrap().len() + reactor as usize
    }

    /// Framed mode: how many sessions each reactor has ever been
    /// pinned — the accept-fanout distribution, one slot per reactor.
    /// Empty in text mode.
    pub fn sessions_per_reactor(&self) -> Vec<u64> {
        self.pinned.iter().map(|a| a.load(Ordering::Relaxed)).collect()
    }

    /// Stop accepting new connections, join the accept thread, then wait
    /// (up to [`SESSION_DRAIN_WINDOW`]) for in-flight session threads so
    /// their jobs finish before the pipeline drops. Sessions still
    /// blocked on a live client after the window are detached with a
    /// warning — they keep draining on their own but no longer pin
    /// shutdown.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        #[cfg(unix)]
        for waker in &self.wakers {
            waker.wake();
        }
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        // Reactor pool threads drain under the same bounded window as
        // text sessions (their own in-loop grace is shorter than it).
        let mut handles: Vec<JoinHandle<()>> =
            self.session_threads.lock().unwrap().drain(..).collect();
        handles.append(&mut self.reactor_threads);
        let deadline = Instant::now() + SESSION_DRAIN_WINDOW;
        while !handles.is_empty() {
            let (done, still_running): (Vec<_>, Vec<_>) =
                handles.into_iter().partition(|h| h.is_finished());
            for h in done {
                let _ = h.join();
            }
            handles = still_running;
            if handles.is_empty() {
                break;
            }
            if Instant::now() >= deadline {
                warn!(
                    "{} session(s) still running after {:?} drain window; detaching",
                    handles.len(),
                    SESSION_DRAIN_WINDOW
                );
                handles.clear();
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        // Drop the waker handles now that the pool has joined: the
        // self-pipe write fds close here, not at process exit.
        #[cfg(unix)]
        self.wakers.clear();
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    pipeline: Arc<Pipeline>,
    stop: Arc<AtomicBool>,
    sessions: Arc<AtomicU64>,
    session_threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((socket, peer)) => {
                sessions.fetch_add(1, Ordering::Relaxed);
                info!("accepted session from {peer}");
                let pipeline = Arc::clone(&pipeline);
                let session_stop = Arc::clone(&stop);
                let name = format!("sfut-session-{peer}");
                let spawned = std::thread::Builder::new().name(name).spawn(move || {
                    let reader = match socket.try_clone() {
                        Ok(r) => BufReader::new(r),
                        Err(e) => {
                            warn!("session {peer}: clone failed: {e}");
                            return;
                        }
                    };
                    match serve_with_stop(&pipeline, reader, socket, &session_stop) {
                        Ok(jobs) => info!("session {peer} done ({jobs} jobs)"),
                        Err(e) => warn!("session {peer} errored: {e:#}"),
                    }
                });
                match spawned {
                    Ok(handle) => {
                        let mut threads = session_threads.lock().unwrap();
                        // Opportunistically reap finished sessions so a
                        // long-lived server doesn't accumulate handles.
                        let mut kept = Vec::with_capacity(threads.len() + 1);
                        for h in threads.drain(..) {
                            if h.is_finished() {
                                let _ = h.join();
                            } else {
                                kept.push(h);
                            }
                        }
                        *threads = kept;
                        threads.push(handle);
                    }
                    Err(e) => warn!("could not spawn session thread: {e}"),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => {
                warn!("accept error: {e}");
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use std::io::{BufRead, BufReader as StdBufReader, Write};
    use std::net::TcpStream;

    fn pipeline() -> Arc<Pipeline> {
        let mut cfg = Config::default();
        cfg.primes_n = 200;
        cfg.fateman_degree = 2;
        cfg.use_kernel = false;
        Arc::new(Pipeline::new(cfg).unwrap())
    }

    fn session(addr: std::net::SocketAddr, script: &str) -> Vec<String> {
        let mut sock = TcpStream::connect(addr).unwrap();
        sock.write_all(script.as_bytes()).unwrap();
        sock.flush().unwrap();
        // Half-close: server sees EOF after our last command.
        sock.shutdown(std::net::Shutdown::Write).unwrap();
        StdBufReader::new(sock).lines().map(|l| l.unwrap()).collect()
    }

    #[test]
    fn tcp_roundtrip_single_session() {
        let server = TcpServer::start(pipeline(), "127.0.0.1:0").unwrap();
        let lines = session(server.local_addr(), "run primes seq\nquit\n");
        assert!(lines.iter().any(|l| l.contains("ok workload=primes")), "{lines:?}");
    }

    #[test]
    fn tcp_ticketed_submit_wait_roundtrip() {
        let server = TcpServer::start(pipeline(), "127.0.0.1:0").unwrap();
        let lines =
            session(server.local_addr(), "submit primes par(2)\nwait 1\nquit\n");
        assert!(lines.iter().any(|l| l.starts_with("ticket id=1")), "{lines:?}");
        assert!(
            lines.iter().any(|l| l.starts_with("ok ") && l.contains("verified=true")),
            "{lines:?}"
        );
    }

    #[test]
    fn tcp_concurrent_sessions_share_metrics() {
        let p = pipeline();
        let server = TcpServer::start(Arc::clone(&p), "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(move || {
                    let lines = session(addr, "run primes seq\n");
                    assert!(lines.iter().any(|l| l.starts_with("ok")), "{lines:?}");
                });
            }
        });
        assert_eq!(p.metrics().snapshot().counters["jobs.completed"], 3);
        assert!(server.sessions() >= 3);
    }

    #[test]
    fn tcp_eight_sessions_across_two_shards() {
        let mut cfg = Config::default();
        cfg.primes_n = 300;
        cfg.fateman_degree = 2;
        cfg.use_kernel = false;
        cfg.shards = 2;
        let p = Arc::new(Pipeline::new(cfg).unwrap());
        // FNV-1a affinity is deterministic: with two shards, `primes`
        // and `primes_chunked` have different home shards, so this mix
        // is guaranteed to exercise both.
        let home_a = p.shards().home_index("primes");
        let home_b = p.shards().home_index("primes_chunked");
        assert_ne!(home_a, home_b, "test premise: distinct home shards");

        let server = TcpServer::start(Arc::clone(&p), "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        std::thread::scope(|s| {
            for i in 0..8 {
                s.spawn(move || {
                    let script = if i % 2 == 0 {
                        "run primes par(2)\nrun primes seq\n"
                    } else {
                        "run primes_chunked par(2)\nrun primes_chunked seq\n"
                    };
                    let lines = session(addr, script);
                    let oks: Vec<_> = lines.iter().filter(|l| l.starts_with("ok")).collect();
                    assert_eq!(oks.len(), 2, "{lines:?}");
                    for l in oks {
                        assert!(l.contains("verified=true"), "{l}");
                        assert!(l.contains("shard="), "{l}");
                    }
                });
            }
        });
        assert_eq!(p.metrics().snapshot().counters["jobs.completed"], 16);
        assert!(server.sessions() >= 8);
        // Both shards actually served traffic (affinity guarantees it
        // even without fallback spill).
        let routed: Vec<u64> = p.shards().iter().map(|s| s.jobs_routed()).collect();
        assert!(
            routed.iter().filter(|&&r| r > 0).count() >= 2,
            "expected ≥2 active shards, got {routed:?}"
        );
        // All leases returned.
        assert!(p.shards().iter().all(|s| s.inflight() == 0));
    }

    #[test]
    fn tcp_workloads_verb_and_params_roundtrip() {
        let p = pipeline();
        let server = TcpServer::start(Arc::clone(&p), "127.0.0.1:0").unwrap();
        let lines = session(
            server.local_addr(),
            "workloads\nrun fib(n=32) par(2)\nrun msort(n=64,seed=5) seq\nquit\n",
        );
        // The registry listing arrives over the wire, schema included.
        let listed = lines.iter().filter(|l| l.starts_with("workload name=")).count();
        assert_eq!(listed, p.registry().len(), "{lines:?}");
        assert!(lines.iter().any(|l| l.contains("name=fib") && l.contains("n:u32")), "{lines:?}");
        // Parameterized runs of both post-enum workloads, verified.
        assert!(
            lines
                .iter()
                .any(|l| l.contains("ok workload=fib(n=32)") && l.contains("verified=true")),
            "{lines:?}"
        );
        assert!(
            lines.iter().any(|l| l.contains("ok workload=msort(n=64,seed=5)")
                && l.contains("verified=true")),
            "{lines:?}"
        );
    }

    #[test]
    fn tcp_shutdown_stops_accepting() {
        let mut server = TcpServer::start(pipeline(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        server.shutdown();
        // Connection may be refused or accepted-then-dropped; either way
        // no job response should come back.
        if let Ok(mut sock) = TcpStream::connect(addr) {
            let _ = sock.write_all(b"run primes seq\n");
            let _ = sock.shutdown(std::net::Shutdown::Write);
            let mut buf = String::new();
            use std::io::Read;
            let _ = sock.read_to_string(&mut buf);
            assert!(!buf.contains("ok workload"), "server answered after shutdown: {buf}");
        }
    }

    #[test]
    fn tcp_shutdown_joins_finished_sessions() {
        let p = pipeline();
        let mut server = TcpServer::start(Arc::clone(&p), "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        // Run three complete sessions (responses read back, so the jobs
        // definitely executed), then shut down: every session thread must
        // be joined — no detached leftovers.
        for _ in 0..3 {
            let lines = session(addr, "run primes seq\nquit\n");
            assert!(lines.iter().any(|l| l.starts_with("ok")), "{lines:?}");
        }
        server.shutdown();
        assert_eq!(server.live_sessions(), 0, "shutdown must join session threads");
        assert_eq!(p.metrics().snapshot().counters["jobs.completed"], 3);
        // Idempotent.
        server.shutdown();
        assert_eq!(server.live_sessions(), 0);
    }

    #[test]
    fn tcp_shutdown_drains_inflight_waiter_with_closed_line() {
        let mut cfg = Config::default();
        cfg.primes_n = 200;
        cfg.fateman_degree = 2;
        cfg.use_kernel = false;
        cfg.shards = 1;
        cfg.shard_parallelism = 1;
        let p = Arc::new(Pipeline::new(cfg).unwrap());
        // Park the only shard so the waited job cannot resolve before
        // shutdown; the waiter must still get a final well-formed line.
        p.ingress().set_runner_hold(0, true);
        let mut server = TcpServer::start(Arc::clone(&p), "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let waiter = std::thread::spawn(move || session(addr, "submit primes seq\nwait 1\n"));
        // Regardless of whether shutdown wins the race with the submit,
        // the session processes both commands and the raised stop flag
        // drains the parked waiter deterministically.
        while server.sessions() == 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
        server.shutdown();
        let lines = waiter.join().unwrap();
        assert!(lines.iter().any(|l| l.starts_with("ticket id=1")), "{lines:?}");
        assert!(lines.iter().any(|l| l == "err closed ticket=1"), "{lines:?}");
        assert_eq!(server.live_sessions(), 0, "drained session must be joined");
        p.ingress().set_runner_hold(0, false);
    }

    #[test]
    fn bad_commands_get_errors_over_tcp() {
        let server = TcpServer::start(pipeline(), "127.0.0.1:0").unwrap();
        let lines = session(server.local_addr(), "frobnicate\nrun nope seq\n");
        assert_eq!(lines.iter().filter(|l| l.starts_with("err")).count(), 2, "{lines:?}");
    }
}

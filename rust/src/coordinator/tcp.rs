//! TCP front-end for the line-protocol server: `sfut serve --tcp ADDR`.
//!
//! One session thread per connection, all sharing the [`Pipeline`] (and
//! therefore the PJRT engine, the metrics registry, and the config).
//! The protocol is identical to the stdio server (`server.rs`).

use std::io::BufReader;
use std::net::{TcpListener, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};
use log::{info, warn};

use super::router::Pipeline;
use super::server::serve;

/// Handle to a running TCP server (for tests and graceful shutdown).
pub struct TcpServer {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    sessions: Arc<AtomicU64>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpServer {
    /// Bind and start accepting. `pipeline` is shared across sessions.
    pub fn start(pipeline: Arc<Pipeline>, addr: impl ToSocketAddrs) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr).context("binding TCP listener")?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        info!("sfut tcp server listening on {local_addr}");
        let stop = Arc::new(AtomicBool::new(false));
        let sessions = Arc::new(AtomicU64::new(0));
        let stop2 = Arc::clone(&stop);
        let sessions2 = Arc::clone(&sessions);
        let accept_thread = std::thread::Builder::new()
            .name("sfut-tcp-accept".to_string())
            .spawn(move || {
                accept_loop(listener, pipeline, stop2, sessions2);
            })
            .context("spawning accept thread")?;
        Ok(TcpServer { local_addr, stop, sessions, accept_thread: Some(accept_thread) })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Total sessions accepted so far.
    pub fn sessions(&self) -> u64 {
        self.sessions.load(Ordering::Relaxed)
    }

    /// Stop accepting new connections and join the accept thread.
    /// In-flight sessions drain on their own threads.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    pipeline: Arc<Pipeline>,
    stop: Arc<AtomicBool>,
    sessions: Arc<AtomicU64>,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((socket, peer)) => {
                sessions.fetch_add(1, Ordering::Relaxed);
                info!("accepted session from {peer}");
                let pipeline = Arc::clone(&pipeline);
                let name = format!("sfut-session-{peer}");
                let spawned = std::thread::Builder::new().name(name).spawn(move || {
                    let reader = match socket.try_clone() {
                        Ok(r) => BufReader::new(r),
                        Err(e) => {
                            warn!("session {peer}: clone failed: {e}");
                            return;
                        }
                    };
                    match serve(&pipeline, reader, socket) {
                        Ok(jobs) => info!("session {peer} done ({jobs} jobs)"),
                        Err(e) => warn!("session {peer} errored: {e:#}"),
                    }
                });
                if let Err(e) = spawned {
                    warn!("could not spawn session thread: {e}");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => {
                warn!("accept error: {e}");
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use std::io::{BufRead, BufReader as StdBufReader, Write};
    use std::net::TcpStream;

    fn pipeline() -> Arc<Pipeline> {
        let mut cfg = Config::default();
        cfg.primes_n = 200;
        cfg.fateman_degree = 2;
        cfg.use_kernel = false;
        Arc::new(Pipeline::new(cfg).unwrap())
    }

    fn session(addr: std::net::SocketAddr, script: &str) -> Vec<String> {
        let mut sock = TcpStream::connect(addr).unwrap();
        sock.write_all(script.as_bytes()).unwrap();
        sock.flush().unwrap();
        // Half-close: server sees EOF after our last command.
        sock.shutdown(std::net::Shutdown::Write).unwrap();
        StdBufReader::new(sock).lines().map(|l| l.unwrap()).collect()
    }

    #[test]
    fn tcp_roundtrip_single_session() {
        let server = TcpServer::start(pipeline(), "127.0.0.1:0").unwrap();
        let lines = session(server.local_addr(), "run primes seq\nquit\n");
        assert!(lines.iter().any(|l| l.contains("ok workload=primes")), "{lines:?}");
    }

    #[test]
    fn tcp_concurrent_sessions_share_metrics() {
        let p = pipeline();
        let server = TcpServer::start(Arc::clone(&p), "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(move || {
                    let lines = session(addr, "run primes seq\n");
                    assert!(lines.iter().any(|l| l.starts_with("ok")), "{lines:?}");
                });
            }
        });
        assert_eq!(p.metrics().snapshot().counters["jobs.completed"], 3);
        assert!(server.sessions() >= 3);
    }

    #[test]
    fn tcp_eight_sessions_across_two_shards() {
        let mut cfg = Config::default();
        cfg.primes_n = 300;
        cfg.fateman_degree = 2;
        cfg.use_kernel = false;
        cfg.shards = 2;
        let p = Arc::new(Pipeline::new(cfg).unwrap());
        // FNV-1a affinity is deterministic: with two shards, `primes`
        // and `primes_chunked` have different home shards, so this mix
        // is guaranteed to exercise both.
        let home_a = p.shards().home_index(crate::config::Workload::Primes);
        let home_b = p.shards().home_index(crate::config::Workload::PrimesChunked);
        assert_ne!(home_a, home_b, "test premise: distinct home shards");

        let server = TcpServer::start(Arc::clone(&p), "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        std::thread::scope(|s| {
            for i in 0..8 {
                s.spawn(move || {
                    let script = if i % 2 == 0 {
                        "run primes par(2)\nrun primes seq\n"
                    } else {
                        "run primes_chunked par(2)\nrun primes_chunked seq\n"
                    };
                    let lines = session(addr, script);
                    let oks: Vec<_> = lines.iter().filter(|l| l.starts_with("ok")).collect();
                    assert_eq!(oks.len(), 2, "{lines:?}");
                    for l in oks {
                        assert!(l.contains("verified=true"), "{l}");
                        assert!(l.contains("shard="), "{l}");
                    }
                });
            }
        });
        assert_eq!(p.metrics().snapshot().counters["jobs.completed"], 16);
        assert!(server.sessions() >= 8);
        // Both shards actually served traffic (affinity guarantees it
        // even without fallback spill).
        let routed: Vec<u64> = p.shards().iter().map(|s| s.jobs_routed()).collect();
        assert!(
            routed.iter().filter(|&&r| r > 0).count() >= 2,
            "expected ≥2 active shards, got {routed:?}"
        );
        // All leases returned.
        assert!(p.shards().iter().all(|s| s.inflight() == 0));
    }

    #[test]
    fn tcp_shutdown_stops_accepting() {
        let mut server = TcpServer::start(pipeline(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        server.shutdown();
        // Connection may be refused or accepted-then-dropped; either way
        // no job response should come back.
        if let Ok(mut sock) = TcpStream::connect(addr) {
            let _ = sock.write_all(b"run primes seq\n");
            let _ = sock.shutdown(std::net::Shutdown::Write);
            let mut buf = String::new();
            use std::io::Read;
            let _ = sock.read_to_string(&mut buf);
            assert!(!buf.contains("ok workload"), "server answered after shutdown: {buf}");
        }
    }

    #[test]
    fn bad_commands_get_errors_over_tcp() {
        let server = TcpServer::start(pipeline(), "127.0.0.1:0").unwrap();
        let lines = session(server.local_addr(), "frobnicate\nrun nope seq\n");
        assert_eq!(lines.iter().filter(|l| l.starts_with("err")).count(), 2, "{lines:?}");
    }
}

//! Job requests and results.
//!
//! A [`JobRequest`] names a workload by *registry name* (the open
//! plugin world — nothing here enumerates workloads) and carries a
//! [`Params`] map that rides the wire protocol end to end: parsed from
//! `workload(k=v,...)` specs, echoed in [`JobRequest::label`] and
//! [`JobResult::render_line`], and schema-checked against the plugin at
//! submit time.

use crate::config::Mode;
use crate::workload::Params;

pub use crate::workload::ResultDetail;

/// A request routed through the [`Pipeline`](super::Pipeline): one
/// registered workload under one evaluation mode, with optional
/// plugin parameters — one cell of the paper's (now open-ended)
/// Table 1.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobRequest {
    /// Registry name (validated against the pipeline's
    /// [`WorkloadRegistry`](crate::workload::WorkloadRegistry) at
    /// submit time, not here — parsing stays open-world).
    pub workload: String,
    /// Plugin parameters (`k=v` pairs; schema-checked at submit).
    pub params: Params,
    pub mode: Mode,
}

impl JobRequest {
    /// A request with no parameters.
    pub fn named(workload: impl Into<String>, mode: Mode) -> JobRequest {
        JobRequest { workload: workload.into(), params: Params::new(), mode }
    }

    /// A request with explicit parameters.
    pub fn with_params(workload: impl Into<String>, params: Params, mode: Mode) -> JobRequest {
        JobRequest { workload: workload.into(), params, mode }
    }

    /// Parse a job spec (the serve protocol / CLI form):
    ///
    /// ```text
    /// <workload>[(k=v,...)] <mode>      e.g.  primes par(2)
    /// <workload>[(k=v,...)]:<mode>      e.g.  fib(n=64):seq
    /// ```
    ///
    /// Errors are precise about what is missing or malformed; workload
    /// *existence* is the registry's business at submit time.
    pub fn parse(s: &str) -> Result<JobRequest, String> {
        let s = s.trim();
        if s.is_empty() {
            return Err("missing workload (want <workload>[(k=v,...)] <mode>)".to_string());
        }
        let (spec, mode_text) = split_spec_and_mode(s)?;
        let (workload, params) = parse_workload_spec(spec)?;
        let mode_text = mode_text.trim();
        if mode_text.is_empty() {
            return Err(format!(
                "missing mode in job spec {s:?} (want <workload>[(k=v,...)] <mode>)"
            ));
        }
        if mode_text.split_whitespace().count() > 1 {
            return Err(format!("trailing input in job spec: {s}"));
        }
        let mode = Mode::parse(mode_text).map_err(|e| e.to_string())?;
        Ok(JobRequest { workload, params, mode })
    }

    /// The workload spec as written on the wire: bare name, or
    /// `name(k=v,...)` when parameters are present. Round-trips through
    /// [`JobRequest::parse`].
    pub fn workload_spec(&self) -> String {
        if self.params.is_empty() {
            self.workload.clone()
        } else {
            format!("{}({})", self.workload, self.params.render())
        }
    }

    pub fn label(&self) -> String {
        format!("{}.{}", self.workload_spec(), self.mode.label())
    }
}

/// Split `spec mode` / `spec:mode` at the first separator *outside*
/// parentheses (param lists contain commas/equals but never parens).
fn split_spec_and_mode(s: &str) -> Result<(&str, &str), String> {
    let mut depth = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth = depth.checked_sub(1).ok_or_else(|| {
                    format!("unbalanced ')' in job spec {s:?} (want workload(k=v,...))")
                })?;
            }
            c if depth == 0 && (c == ':' || c.is_whitespace()) => {
                return Ok((&s[..i], &s[i + c.len_utf8()..]));
            }
            _ => {}
        }
    }
    if depth > 0 {
        return Err(format!("unbalanced '(' in job spec {s:?} (want workload(k=v,...))"));
    }
    Err(format!("missing mode in job spec {s:?} (want <workload>[(k=v,...)] <mode>)"))
}

/// Parse `name` or `name(k=v,...)` into a (name, params) pair.
fn parse_workload_spec(spec: &str) -> Result<(String, Params), String> {
    let spec = spec.trim();
    match spec.find('(') {
        None => {
            if spec.is_empty() {
                return Err("missing workload name".to_string());
            }
            Ok((spec.to_string(), Params::new()))
        }
        Some(open) => {
            if !spec.ends_with(')') {
                return Err(format!(
                    "unbalanced parameter list in {spec:?} (want workload(k=v,...))"
                ));
            }
            let name = &spec[..open];
            if name.is_empty() {
                return Err(format!("missing workload name before '(' in {spec:?}"));
            }
            let inner = &spec[open + 1..spec.len() - 1];
            let params = Params::parse(inner).map_err(|e| e.to_string())?;
            Ok((name.to_string(), params))
        }
    }
}

/// Outcome of one job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobResult {
    pub request: JobRequest,
    pub seconds: f64,
    pub detail: ResultDetail,
    /// Result checked against the plugin's independent oracle.
    pub verified: bool,
    /// Which block backend served the workload ("rust-scalar",
    /// "pjrt-kernel", or "-" for workloads without block offload).
    pub backend: String,
    /// Coordinator shard the job was routed to.
    pub shard: usize,
    /// Tasks stolen across the shard's pools while this job was in
    /// flight (work-stealing balance indicator; attribution is
    /// shard-level, so concurrent jobs on one shard share it).
    pub steals: u64,
    /// Seconds between admission (`Pipeline::submit`) and execution
    /// start — admission-queue plus run-queue time. 0 for jobs that
    /// never waited.
    pub queue_wait: f64,
    /// The job was stolen off a backed-up shard's run queue by an idle
    /// shard (cross-shard migration); `shard` is the shard that actually
    /// executed it.
    pub migrated: bool,
}

impl JobResult {
    /// One-line rendering for the serve protocol. The `workload=` field
    /// echoes the full spec (params included), so clients can replay a
    /// result line verbatim as a new request.
    pub fn render_line(&self) -> String {
        let detail = match &self.detail {
            ResultDetail::Primes { count, largest } => {
                format!("primes={count} largest={largest}")
            }
            ResultDetail::Poly { terms, leading_coeff } => {
                format!("terms={terms} leading={leading_coeff}")
            }
            ResultDetail::Scalar { value } => format!("value={value}"),
        };
        format!(
            "ok workload={} mode={} seconds={:.3} verified={} backend={} shard={} steals={} \
             queue_wait={:.3} migrated={} {detail}",
            self.request.workload_spec(),
            self.request.mode.label(),
            self.seconds,
            self.verified,
            self.backend,
            self.shard,
            self.steals,
            self.queue_wait,
            self.migrated,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_job_specs() {
        let j = JobRequest::parse("primes seq").unwrap();
        assert_eq!(j.workload, "primes");
        assert_eq!(j.mode, Mode::Seq);
        assert!(j.params.is_empty());
        let j = JobRequest::parse("stream_big par(4)").unwrap();
        assert_eq!(j.mode, Mode::Par(4));
        // Open world: unknown names parse — the registry rejects them
        // at submit time, with its own err line.
        assert_eq!(JobRequest::parse("warp seq").unwrap().workload, "warp");
        assert!(JobRequest::parse("primes").is_err());
        assert!(JobRequest::parse("primes seq extra").is_err());
        assert!(JobRequest::parse("primes warp").is_err());
        assert!(JobRequest::parse("").is_err());
    }

    #[test]
    fn parse_param_specs_and_colon_form() {
        let j = JobRequest::parse("fib(n=64) par(2)").unwrap();
        assert_eq!(j.workload, "fib");
        assert_eq!(j.params.get("n"), Some("64"));
        assert_eq!(j.mode, Mode::Par(2));
        let j = JobRequest::parse("fib(n=64):par(2)").unwrap();
        assert_eq!(j.params.get("n"), Some("64"));
        assert_eq!(j.mode, Mode::Par(2));
        let j = JobRequest::parse("msort(n=100, seed=7) seq").unwrap();
        assert_eq!(j.params.len(), 2);
        // Empty parameter lists are allowed.
        let j = JobRequest::parse("primes() seq").unwrap();
        assert!(j.params.is_empty());
        assert_eq!(j.workload, "primes");
        let j = JobRequest::parse("primes:seq").unwrap();
        assert_eq!(j.mode, Mode::Seq);
    }

    #[test]
    fn parse_errors_are_precise() {
        let e = JobRequest::parse("fib(n=64 seq").unwrap_err();
        assert!(e.contains("unbalanced"), "{e}");
        let e = JobRequest::parse("fib(n) seq").unwrap_err();
        assert!(e.contains("want key=value"), "{e}");
        let e = JobRequest::parse("fib(n=64)").unwrap_err();
        assert!(e.contains("missing mode"), "{e}");
        let e = JobRequest::parse("(n=64) seq").unwrap_err();
        assert!(e.contains("missing workload name"), "{e}");
        let e = JobRequest::parse("fib) seq").unwrap_err();
        assert!(e.contains("unbalanced"), "{e}");
        let e = JobRequest::parse("fib(n=1,n=2) seq").unwrap_err();
        assert!(e.contains("duplicate parameter"), "{e}");
    }

    #[test]
    fn labels_and_specs_roundtrip() {
        let j = JobRequest::named("stream_big", Mode::Par(2));
        assert_eq!(j.label(), "stream_big.par(2)");
        assert_eq!(j.workload_spec(), "stream_big");
        let j = JobRequest::parse("fib(n=64,deep=true) par(2)").unwrap();
        assert_eq!(j.workload_spec(), "fib(deep=true,n=64)");
        assert_eq!(j.label(), "fib(deep=true,n=64).par(2)");
        // The spec round-trips through parse.
        let back = JobRequest::parse(&format!("{} {}", j.workload_spec(), j.mode.label()));
        assert_eq!(back.unwrap(), j);
    }

    #[test]
    fn render_line_roundtrips_key_fields() {
        let mut params = Params::new();
        params.set("n", "50");
        let r = JobResult {
            request: JobRequest::with_params("primes", params, Mode::Seq),
            seconds: 1.5,
            detail: ResultDetail::Primes { count: 15, largest: 47 },
            verified: true,
            backend: "-".into(),
            shard: 3,
            steals: 12,
            queue_wait: 0.25,
            migrated: true,
        };
        let line = r.render_line();
        assert!(line.contains("workload=primes(n=50)"), "{line}");
        assert!(line.contains("seconds=1.500"));
        assert!(line.contains("primes=15"));
        assert!(line.contains("verified=true"));
        assert!(line.contains("shard=3"));
        assert!(line.contains("steals=12"));
        assert!(line.contains("queue_wait=0.250"));
        assert!(line.contains("migrated=true"));
        // The workload field replays as a request (params round-trip).
        let token = line
            .split_whitespace()
            .find_map(|t| t.strip_prefix("workload="))
            .unwrap();
        let mode = line.split_whitespace().find_map(|t| t.strip_prefix("mode=")).unwrap();
        let back = JobRequest::parse(&format!("{token} {mode}")).unwrap();
        assert_eq!(back, r.request);
    }

    #[test]
    fn scalar_detail_renders_value() {
        let r = JobResult {
            request: JobRequest::named("fib", Mode::Seq),
            seconds: 0.1,
            detail: ResultDetail::Scalar { value: "88".into() },
            verified: true,
            backend: "-".into(),
            shard: 0,
            steals: 0,
            queue_wait: 0.0,
            migrated: false,
        };
        assert!(r.render_line().contains("value=88"));
    }
}

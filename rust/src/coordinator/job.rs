//! Job requests and results.

use crate::config::{Mode, Workload};

/// A request routed through the [`Pipeline`](super::Pipeline): one
/// workload under one evaluation mode — one cell of the paper's Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobRequest {
    pub workload: Workload,
    pub mode: Mode,
}

impl JobRequest {
    /// Parse `"<workload> <mode>"` (the serve protocol / CLI form).
    pub fn parse(s: &str) -> Result<JobRequest, String> {
        let mut parts = s.split_whitespace();
        let w = parts.next().ok_or("missing workload")?;
        let m = parts.next().ok_or("missing mode")?;
        if parts.next().is_some() {
            return Err(format!("trailing input in job spec: {s}"));
        }
        Ok(JobRequest {
            workload: Workload::parse(w).map_err(|e| e.to_string())?,
            mode: Mode::parse(m).map_err(|e| e.to_string())?,
        })
    }

    pub fn label(&self) -> String {
        format!("{}.{}", self.workload.name(), self.mode.label())
    }
}

/// Workload-specific result summary, used for verification and
/// reporting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ResultDetail {
    Primes {
        count: usize,
        largest: u32,
    },
    Poly {
        terms: usize,
        /// Decimal rendering of the leading coefficient (ring-agnostic).
        leading_coeff: String,
    },
}

/// Outcome of one job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobResult {
    pub request: JobRequest,
    pub seconds: f64,
    pub detail: ResultDetail,
    /// Result checked against the independent oracle (Eratosthenes /
    /// classical multiplication).
    pub verified: bool,
    /// Which block backend served chunked workloads ("rust-scalar",
    /// "pjrt-kernel", or "-" for non-chunked).
    pub backend: String,
    /// Coordinator shard the job was routed to.
    pub shard: usize,
    /// Tasks stolen across the shard's pools while this job was in
    /// flight (work-stealing balance indicator; attribution is
    /// shard-level, so concurrent jobs on one shard share it).
    pub steals: u64,
    /// Seconds between admission (`Pipeline::submit`) and execution
    /// start — admission-queue plus run-queue time. 0 for jobs that
    /// never waited.
    pub queue_wait: f64,
    /// The job was stolen off a backed-up shard's run queue by an idle
    /// shard (cross-shard migration); `shard` is the shard that actually
    /// executed it.
    pub migrated: bool,
}

impl JobResult {
    /// One-line rendering for the serve protocol.
    pub fn render_line(&self) -> String {
        let detail = match &self.detail {
            ResultDetail::Primes { count, largest } => {
                format!("primes={count} largest={largest}")
            }
            ResultDetail::Poly { terms, leading_coeff } => {
                format!("terms={terms} leading={leading_coeff}")
            }
        };
        format!(
            "ok workload={} mode={} seconds={:.3} verified={} backend={} shard={} steals={} \
             queue_wait={:.3} migrated={} {detail}",
            self.request.workload.name(),
            self.request.mode.label(),
            self.seconds,
            self.verified,
            self.backend,
            self.shard,
            self.steals,
            self.queue_wait,
            self.migrated,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_job_specs() {
        let j = JobRequest::parse("primes seq").unwrap();
        assert_eq!(j.workload, Workload::Primes);
        assert_eq!(j.mode, Mode::Seq);
        let j = JobRequest::parse("stream_big par(4)").unwrap();
        assert_eq!(j.mode, Mode::Par(4));
        assert!(JobRequest::parse("primes").is_err());
        assert!(JobRequest::parse("primes seq extra").is_err());
        assert!(JobRequest::parse("warp seq").is_err());
    }

    #[test]
    fn labels() {
        let j = JobRequest { workload: Workload::StreamBig, mode: Mode::Par(2) };
        assert_eq!(j.label(), "stream_big.par(2)");
    }

    #[test]
    fn render_line_roundtrips_key_fields() {
        let r = JobResult {
            request: JobRequest { workload: Workload::Primes, mode: Mode::Seq },
            seconds: 1.5,
            detail: ResultDetail::Primes { count: 25, largest: 97 },
            verified: true,
            backend: "-".into(),
            shard: 3,
            steals: 12,
            queue_wait: 0.25,
            migrated: true,
        };
        let line = r.render_line();
        assert!(line.contains("workload=primes"));
        assert!(line.contains("seconds=1.500"));
        assert!(line.contains("primes=25"));
        assert!(line.contains("verified=true"));
        assert!(line.contains("shard=3"));
        assert!(line.contains("steals=12"));
        assert!(line.contains("queue_wait=0.250"));
        assert!(line.contains("migrated=true"));
    }
}

//! The staged ingress: how every job enters, waits, runs, and reports.
//!
//! Before this module, `sfut serve` was thread-per-session calling
//! [`Pipeline::run`](super::Pipeline::run) inline: no admission control,
//! no backpressure, and no way for an idle shard to help a backed-up one.
//! The ingress replaces that with a four-stage path:
//!
//! 1. **Admit** — [`Pipeline::submit`](super::Pipeline::submit) places
//!    the request in a bounded MPMC admission queue and returns a
//!    [`JobTicket`] immediately. The bound (`Config::queue_depth`)
//!    covers every job admitted but not yet executing; at the bound the
//!    configured [`AdmissionPolicy`] decides: `block` the submitter,
//!    `shed` ([`SubmitError::Shed`]), or wait up to a deadline
//!    ([`SubmitError::Timeout`] — the timed-out submission leaves no
//!    residue in the queue).
//! 2. **Route** — a small dispatcher pool (`Config::dispatchers`) pops
//!    admitted jobs and routes them through the existing
//!    [`ShardSet`](super::ShardSet) affinity/least-loaded logic onto the
//!    chosen shard's run queue, lease in hand.
//! 3. **Execute** — each shard owns `Config::shard_parallelism` runner
//!    threads (spawned with the big workload stack). A runner drains its
//!    own queue first; when idle it steals the *oldest whole queued job*
//!    from the deepest shard whose run-queue depth exceeds
//!    `Config::migrate_threshold` — cross-shard migration, the
//!    queue-level complement of the executor's task stealing. Migration
//!    re-leases the job onto the thief shard and shows up in the
//!    `shard.<id>.migrated_in`/`migrated_out` counters and the result's
//!    `migrated=` field.
//! 4. **Report** — the runner executes via
//!    [`PipelineCore::execute_routed`](super::router::PipelineCore) and
//!    fulfills the ticket's [`Fut`] cell, running any registered
//!    continuations — the service layer rides the same lock-free future
//!    state machine as the paper's stream cells.
//!
//! Shutdown is graceful: dropping the last `Pipeline` handle closes
//! admission, lets the dispatchers drain the admission queue, then the
//! runners drain every run queue (ignoring holds and the migration
//! threshold) before joining — in-flight tickets always resolve.

use std::collections::VecDeque;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::job::{JobRequest, JobResult};
use super::router::PipelineCore;
use super::shard::ShardLease;
use crate::config::AdmissionPolicy;
use crate::exec::{Executor, ExecutorConfig};
use crate::susp::{Fut, FutPromise, FutState, Susp};

/// What a resolved [`JobTicket`] carries: the job's result, or the
/// error/panic message it failed with.
pub type TicketValue = Result<JobResult, String>;

/// A handle to a submitted job, returned by
/// [`Pipeline::submit`](super::Pipeline::submit) before the job runs.
///
/// Built directly on [`Fut`] — the same lock-free cell the paper's
/// stream tails suspend in — so it composes the same way:
/// [`JobTicket::and_then`]/[`JobTicket::bind`] chain continuations that
/// fire on completion, [`JobTicket::wait`] parks for the synchronous
/// result, and [`JobTicket::state`] is a lock-free peek.
#[derive(Clone)]
pub struct JobTicket {
    fut: Fut<TicketValue>,
}

impl JobTicket {
    /// The underlying future cell, for callers that want the full
    /// [`Fut`] combinator surface.
    pub fn fut(&self) -> &Fut<TicketValue> {
        &self.fut
    }

    /// Lock-free lifecycle peek (Empty until a runner picks the job up).
    pub fn state(&self) -> FutState {
        self.fut.state()
    }

    /// Whether the job has finished (never blocks).
    pub fn is_ready(&self) -> bool {
        self.fut.is_ready()
    }

    /// The outcome, if finished (never blocks).
    pub fn try_result(&self) -> Option<TicketValue> {
        self.fut.try_result().map(|r| match r {
            Ok(v) => v.clone(),
            Err(msg) => Err(msg.clone()),
        })
    }

    /// Park until the job finishes and return its result. Safe against
    /// abandoned cells (a dropped producer surfaces as an error).
    pub fn wait(&self) -> Result<JobResult> {
        match self.fut.wait_result() {
            Ok(Ok(res)) => Ok(res.clone()),
            Ok(Err(msg)) => Err(anyhow!("{msg}")),
            Err(msg) => Err(anyhow!("job ticket abandoned: {msg}")),
        }
    }

    /// Chain a transformation on the outcome, exactly like mapping a
    /// stream cell: runs when the job completes (inline if it already
    /// has).
    pub fn and_then<U, F>(&self, f: F) -> Fut<U>
    where
        U: Send + Sync + 'static,
        F: FnOnce(TicketValue) -> U + Send + 'static,
    {
        self.fut.and_then(f)
    }

    /// Monadic bind on the outcome (continuation returns another future).
    pub fn bind<U, F>(&self, f: F) -> Fut<U>
    where
        U: Clone + Send + Sync + 'static,
        F: FnOnce(TicketValue) -> Fut<U> + Send + 'static,
    {
        self.fut.bind(f)
    }
}

/// Why [`Pipeline::submit`](super::Pipeline::submit) rejected a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded queue full under `admission = shed`.
    Shed { queue_depth: usize },
    /// Queue stayed full for the whole `admission = timeout(ms)` window.
    /// The submission leaves no residue: its would-be slot stays with
    /// the queue.
    Timeout { waited_ms: u64, queue_depth: usize },
    /// The pipeline is shutting down.
    Closed,
    /// The request failed registry validation before admission: unknown
    /// workload name, or params outside the plugin's declared schema.
    /// Answered immediately — a malformed request never occupies queue
    /// capacity.
    Rejected { reason: String },
}

impl SubmitError {
    /// Serve-protocol rendering: a well-formed `err admission=…` /
    /// `err rejected …` line.
    pub fn render_line(&self, req: &JobRequest) -> String {
        let w = req.workload_spec();
        let m = req.mode.label();
        match self {
            SubmitError::Shed { queue_depth } => {
                format!("err admission=shed workload={w} mode={m} queue_depth={queue_depth}")
            }
            SubmitError::Timeout { waited_ms, queue_depth } => format!(
                "err admission=timeout workload={w} mode={m} waited_ms={waited_ms} \
                 queue_depth={queue_depth}"
            ),
            SubmitError::Closed => format!("err admission=closed workload={w} mode={m}"),
            SubmitError::Rejected { reason } => {
                format!("err rejected workload={w} mode={m} reason: {reason}")
            }
        }
    }
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Shed { queue_depth } => {
                write!(f, "admission=shed: ingress queue full (queue_depth={queue_depth})")
            }
            SubmitError::Timeout { waited_ms, queue_depth } => write!(
                f,
                "admission=timeout: no queue slot within {waited_ms}ms \
                 (queue_depth={queue_depth})"
            ),
            SubmitError::Closed => write!(f, "admission=closed: pipeline is shutting down"),
            SubmitError::Rejected { reason } => write!(f, "invalid request: {reason}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A job admitted but not yet routed.
struct Pending {
    req: JobRequest,
    verify: bool,
    promise: FutPromise<TicketValue>,
    submitted: Instant,
}

/// A job routed to a shard's run queue, lease in hand.
struct Routed {
    pending: Pending,
    lease: ShardLease,
}

/// Stage-1 state: the bounded admission queue.
struct Admission {
    queue: VecDeque<Pending>,
    /// Jobs admitted but not yet picked up by a runner — this (not the
    /// `queue` length) is what `queue_depth` bounds, so the run queues
    /// cannot become an unbounded overflow behind a "bounded" front
    /// door.
    pending: usize,
    closed: bool,
}

/// Stage-2/3 state: one FIFO run queue per shard.
struct RunQueues {
    queues: Vec<VecDeque<Routed>>,
    /// Per-shard runner gate: a held shard's runners neither execute nor
    /// steal. Drain/maintenance control, and what the migration tests
    /// use to build deterministic backlogs.
    held: Vec<bool>,
    closed: bool,
}

struct IngressShared {
    core: Arc<PipelineCore>,
    queue_depth: usize,
    policy: AdmissionPolicy,
    migrate_threshold: usize,
    admission: Mutex<Admission>,
    /// Signalled when a runner frees an admission slot.
    not_full: Condvar,
    /// Signalled when a submission lands in the admission queue.
    not_empty: Condvar,
    run: Mutex<RunQueues>,
    /// Signalled when a job lands in any run queue (or on shutdown).
    work: Condvar,
}

/// The staged ingress: admission queue, dispatcher pool, and per-shard
/// runner threads. Owned by [`Pipeline`](super::Pipeline) (reachable via
/// [`Pipeline::ingress`](super::Pipeline::ingress) for introspection and
/// drain control); dropping the owning pipeline drains and joins
/// everything.
pub struct Ingress {
    shared: Arc<IngressShared>,
    /// Executor backing ticket cells: continuations registered before
    /// completion run here (completed-cell continuations run inline,
    /// like any [`Fut`]).
    ticket_exec: Executor,
    dispatchers: Mutex<Vec<JoinHandle<()>>>,
    runners: Mutex<Vec<JoinHandle<()>>>,
}

impl Ingress {
    /// Spawn the dispatcher pool and the per-shard runners.
    pub(super) fn start(core: Arc<PipelineCore>) -> Result<Ingress> {
        let cfg = core.config();
        let queue_depth = cfg.queue_depth;
        let policy = cfg.admission;
        let migrate_threshold = cfg.migrate_threshold;
        let dispatcher_count = cfg.dispatchers;
        let runners_per_shard = cfg.shard_parallelism;
        let stack = cfg.stack_size;
        let shard_count = core.shards().len();
        let shared = Arc::new(IngressShared {
            queue_depth,
            policy,
            migrate_threshold,
            admission: Mutex::new(Admission {
                queue: VecDeque::new(),
                pending: 0,
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            run: Mutex::new(RunQueues {
                queues: (0..shard_count).map(|_| VecDeque::new()).collect(),
                held: vec![false; shard_count],
                closed: false,
            }),
            work: Condvar::new(),
            core,
        });

        let mut ticket_cfg = ExecutorConfig::with_parallelism(2);
        ticket_cfg.name = "sfut-ticket".to_string();
        ticket_cfg.deque = cfg.deque;
        let ticket_exec = Executor::with_config(ticket_cfg);

        // Built before any thread spawns so an error below (`?`) drops
        // the Ingress, whose shutdown joins whatever was already spawned
        // — a failed partial start must not leak parked threads.
        let ingress = Ingress {
            shared: Arc::clone(&shared),
            ticket_exec,
            dispatchers: Mutex::new(Vec::with_capacity(dispatcher_count)),
            runners: Mutex::new(Vec::with_capacity(shard_count * runners_per_shard)),
        };
        for i in 0..dispatcher_count {
            let shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("sfut-dispatch-{i}"))
                .spawn(move || dispatcher_loop(&shared))
                .context("spawning ingress dispatcher")?;
            ingress.dispatchers.lock().unwrap().push(handle);
        }
        for sid in 0..shard_count {
            for i in 0..runners_per_shard {
                let shared = Arc::clone(&shared);
                let handle = std::thread::Builder::new()
                    .name(format!("sfut-runner-s{sid}-{i}"))
                    // Runners execute workload bodies directly (deep Lazy
                    // chains need the big stack the per-job driver
                    // threads used to provide).
                    .stack_size(stack)
                    .spawn(move || runner_loop(&shared, sid))
                    .context("spawning shard runner")?;
                ingress.runners.lock().unwrap().push(handle);
            }
        }
        Ok(ingress)
    }

    /// Stage 1: validate against the registry, then admit under the
    /// configured policy. Returns the ticket immediately (the job may
    /// not even be routed yet).
    pub(super) fn submit(&self, req: JobRequest, verify: bool) -> Result<JobTicket, SubmitError> {
        let metrics = self.shared.core.metrics();
        metrics.counter("ingress.submitted").inc();
        // Open-world gate: resolve the workload name and schema-check
        // its params before taking any queue slot, so malformed
        // requests answer immediately and never occupy capacity.
        if let Err(e) = self.shared.core.validate_request(&req) {
            metrics.counter("ingress.rejected").inc();
            return Err(SubmitError::Rejected { reason: e.to_string() });
        }
        let depth = self.shared.queue_depth;
        let mut adm = self.shared.admission.lock().unwrap();
        if adm.closed {
            return Err(SubmitError::Closed);
        }
        if adm.pending >= depth {
            match self.shared.policy {
                AdmissionPolicy::Shed => {
                    metrics.counter("ingress.shed").inc();
                    return Err(SubmitError::Shed { queue_depth: depth });
                }
                AdmissionPolicy::Block => {
                    while adm.pending >= depth && !adm.closed {
                        adm = self.shared.not_full.wait(adm).unwrap();
                    }
                }
                AdmissionPolicy::Timeout(ms) => {
                    let deadline = Instant::now() + Duration::from_millis(ms);
                    while adm.pending >= depth && !adm.closed {
                        let now = Instant::now();
                        if now >= deadline {
                            metrics.counter("ingress.timed_out").inc();
                            return Err(SubmitError::Timeout {
                                waited_ms: ms,
                                queue_depth: depth,
                            });
                        }
                        let (guard, _timeout) =
                            self.shared.not_full.wait_timeout(adm, deadline - now).unwrap();
                        adm = guard;
                    }
                }
            }
            if adm.closed {
                return Err(SubmitError::Closed);
            }
        }
        let (fut, promise) = Fut::promise(&self.ticket_exec);
        adm.pending += 1;
        adm.queue.push_back(Pending { req, verify, promise, submitted: Instant::now() });
        metrics.counter("ingress.admitted").inc();
        metrics.gauge("ingress.queue_depth").set(adm.pending as u64);
        drop(adm);
        self.shared.not_empty.notify_one();
        Ok(JobTicket { fut })
    }

    /// Jobs admitted but not yet executing (the quantity `queue_depth`
    /// bounds).
    pub fn pending(&self) -> usize {
        self.shared.admission.lock().unwrap().pending
    }

    /// Depth of one shard's run queue.
    pub fn run_queue_depth(&self, shard: usize) -> usize {
        self.shared.run.lock().unwrap().queues[shard].len()
    }

    /// Gate a shard's runners: a held shard neither executes its own
    /// queue nor steals. Maintenance/drain control — hold a shard and
    /// its backlog migrates to its peers once it exceeds the threshold;
    /// the migration tests use it to build deterministic backlogs.
    /// Holds are cleared automatically on shutdown.
    pub fn set_runner_hold(&self, shard: usize, hold: bool) {
        {
            let mut run = self.shared.run.lock().unwrap();
            run.held[shard] = hold;
        }
        self.shared.work.notify_all();
    }

    /// Close admission, drain both stages, and join every thread.
    /// Queued jobs are *executed*, not dropped — every outstanding
    /// ticket resolves before this returns. Idempotent.
    fn shutdown(&self) {
        {
            let mut adm = self.shared.admission.lock().unwrap();
            adm.closed = true;
        }
        self.shared.not_full.notify_all();
        self.shared.not_empty.notify_all();
        for handle in self.dispatchers.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
        {
            let mut run = self.shared.run.lock().unwrap();
            run.closed = true;
            for hold in run.held.iter_mut() {
                *hold = false;
            }
        }
        self.shared.work.notify_all();
        for handle in self.runners.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for Ingress {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Stage 2: pop admitted jobs, route via the shard set, hand to the
/// chosen shard's run queue. Drains the admission queue fully before
/// exiting on shutdown.
fn dispatcher_loop(shared: &IngressShared) {
    loop {
        let pending = {
            let mut adm = shared.admission.lock().unwrap();
            loop {
                if let Some(p) = adm.queue.pop_front() {
                    break p;
                }
                if adm.closed {
                    return;
                }
                adm = shared.not_empty.wait(adm).unwrap();
            }
        };
        let lease = shared.core.shards().route(&pending.req.workload);
        let sid = lease.id();
        let depth = {
            let mut run = shared.run.lock().unwrap();
            // Shutdown invariant: run queues close only *after* every
            // dispatcher has been joined (see Ingress::shutdown), so a
            // live dispatcher can never observe a closed run stage. The
            // assert keeps that ordering honest if shutdown ever changes.
            debug_assert!(!run.closed, "run queues closed while a dispatcher is live");
            run.queues[sid].push_back(Routed { pending, lease });
            run.queues[sid].len()
        };
        let metrics = shared.core.metrics();
        metrics.gauge(&format!("shard.{sid}.run_queue_depth")).set(depth as u64);
        shared.work.notify_all();
    }
}

/// Pick the deepest run queue (≠ `sid`) whose depth exceeds the
/// migration threshold.
fn steal_victim(run: &RunQueues, sid: usize, threshold: usize) -> Option<usize> {
    run.queues
        .iter()
        .enumerate()
        .filter(|&(v, q)| v != sid && q.len() > threshold)
        .max_by_key(|&(_, q)| q.len())
        .map(|(v, _)| v)
}

/// Stage 3 (+4): execute jobs from this shard's run queue; steal whole
/// queued jobs from backed-up shards when idle; fulfill tickets.
fn runner_loop(shared: &IngressShared, sid: usize) {
    loop {
        // (job, migrated, gauge update) — the gauge write (a format! and
        // a registry lock) happens after the run lock is released; every
        // dequeue would otherwise lengthen the one critical section the
        // whole ingress contends on.
        let next = {
            let mut run = shared.run.lock().unwrap();
            loop {
                if run.closed {
                    // Drain mode: own queue first, then anything left
                    // anywhere (threshold and holds no longer apply).
                    // Cross-queue pops here are NOT migration — the job
                    // keeps its routed lease and shard attribution; the
                    // runner is just the thread that happens to drain it.
                    let victim = if !run.queues[sid].is_empty() {
                        Some(sid)
                    } else {
                        (0..run.queues.len()).find(|&v| !run.queues[v].is_empty())
                    };
                    // Wake peers: either there is more to drain, or all
                    // queues are empty and they should exit too.
                    shared.work.notify_all();
                    break victim.map(|v| {
                        let job = run.queues[v].pop_front().expect("checked non-empty");
                        (job, false, None)
                    });
                }
                if !run.held[sid] {
                    if let Some(job) = run.queues[sid].pop_front() {
                        let depth = run.queues[sid].len();
                        break Some((job, false, Some((sid, depth))));
                    }
                    if let Some(v) = steal_victim(&run, sid, shared.migrate_threshold) {
                        let job = run.queues[v].pop_front().expect("victim non-empty");
                        let depth = run.queues[v].len();
                        break Some((job, true, Some((v, depth))));
                    }
                }
                run = shared.work.wait(run).unwrap();
            }
        };
        let Some((routed, migrated, gauge)) = next else {
            return;
        };
        if let Some((shard_id, depth)) = gauge {
            shared
                .core
                .metrics()
                .gauge(&format!("shard.{shard_id}.run_queue_depth"))
                .set(depth as u64);
        }
        execute_one(shared, sid, routed, migrated);
    }
}

/// Stage 3 body: adopt the job (re-leasing on migration), release its
/// admission slot, execute, and fulfill the ticket.
fn execute_one(shared: &IngressShared, sid: usize, routed: Routed, migrated: bool) {
    let Routed { pending, lease } = routed;
    let metrics = shared.core.metrics();
    let lease = if migrated {
        let from = lease.id();
        drop(lease);
        let shards = shared.core.shards();
        shards.shard(from).note_migrated_out();
        let adopted = shards.lease_on(sid);
        shards.shard(sid).note_migrated_in();
        metrics.counter("ingress.migrated").inc();
        adopted
    } else {
        lease
    };
    // The job is starting: free its admission slot so blocked submitters
    // refill the queue while it runs.
    {
        let mut adm = shared.admission.lock().unwrap();
        adm.pending -= 1;
        metrics.gauge("ingress.queue_depth").set(adm.pending as u64);
    }
    shared.not_full.notify_one();
    // Flip the ticket to Running so pollers can tell executing from
    // queued (`serve`'s `poll` command surfaces this state).
    pending.promise.start();
    let queue_wait = pending.submitted.elapsed();
    let shard = Arc::clone(lease.shard());
    let outcome =
        shared.core.execute_routed(pending.req, &shard, pending.verify, queue_wait, migrated);
    drop(lease);
    match outcome {
        Ok(result) => pending.promise.fulfill(Ok(result)),
        Err(e) => {
            metrics.counter("jobs.failed").inc();
            pending.promise.fulfill(Err(format!("{e:#}")));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, Mode};
    use crate::coordinator::Pipeline;

    fn base_config() -> Config {
        let mut cfg = Config::default();
        cfg.primes_n = 500;
        cfg.fateman_degree = 3;
        cfg.chunk_size = 16;
        cfg.use_kernel = false;
        cfg.shards = 1;
        cfg.shard_parallelism = 1;
        cfg.dispatchers = 1;
        cfg
    }

    fn primes_req() -> JobRequest {
        JobRequest::named("primes", Mode::Par(2))
    }

    fn wait_until(what: &str, mut ok: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !ok() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn ticket_resolves_and_chains_like_a_stream_cell() {
        let pipeline = Pipeline::new(base_config()).unwrap();
        let ticket = pipeline.submit(&primes_req()).unwrap();
        // Dogfooding: chain a continuation on the ticket's Fut cell.
        let count = ticket.and_then(|outcome| {
            let res = outcome.expect("job failed");
            match res.detail {
                crate::coordinator::ResultDetail::Primes { count, .. } => count,
                _ => 0,
            }
        });
        let res = ticket.wait().unwrap();
        assert!(res.verified);
        assert!(!res.migrated);
        assert!(res.queue_wait >= 0.0);
        assert_eq!(*crate::susp::Susp::force(&count), 95); // π(500)
        assert_eq!(
            pipeline.metrics().snapshot().counters["ingress.admitted"],
            1
        );
    }

    #[test]
    fn shed_policy_rejects_at_the_bound() {
        let mut cfg = base_config();
        cfg.queue_depth = 2;
        cfg.admission = AdmissionPolicy::Shed;
        let pipeline = Pipeline::new(cfg).unwrap();
        pipeline.ingress().set_runner_hold(0, true);
        let t1 = pipeline.submit(&primes_req()).unwrap();
        let t2 = pipeline.submit(&primes_req()).unwrap();
        // Both slots occupied and nothing executing: the third submission
        // sheds, deterministically.
        match pipeline.submit(&primes_req()) {
            Err(SubmitError::Shed { queue_depth }) => assert_eq!(queue_depth, 2),
            other => panic!("expected shed, got {other:?}"),
        }
        let snap = pipeline.metrics().snapshot();
        assert_eq!(snap.counters["ingress.shed"], 1);
        assert_eq!(snap.counters["ingress.admitted"], 2);
        pipeline.ingress().set_runner_hold(0, false);
        assert!(t1.wait().unwrap().verified);
        assert!(t2.wait().unwrap().verified);
        // Capacity fully recovered after the shed.
        let t4 = pipeline.submit(&primes_req()).unwrap();
        assert!(t4.wait().unwrap().verified);
    }

    #[test]
    fn timeout_policy_sheds_late_and_releases_the_slot() {
        let mut cfg = base_config();
        cfg.queue_depth = 1;
        cfg.admission = AdmissionPolicy::Timeout(50);
        let pipeline = Pipeline::new(cfg).unwrap();
        pipeline.ingress().set_runner_hold(0, true);
        let t1 = pipeline.submit(&primes_req()).unwrap();
        let started = Instant::now();
        match pipeline.submit(&primes_req()) {
            Err(SubmitError::Timeout { waited_ms, queue_depth }) => {
                assert_eq!(waited_ms, 50);
                assert_eq!(queue_depth, 1);
            }
            other => panic!("expected timeout, got {other:?}"),
        }
        assert!(started.elapsed() >= Duration::from_millis(45), "timed out too early");
        assert_eq!(pipeline.metrics().snapshot().counters["ingress.timed_out"], 1);
        // The timed-out submission left no residue: once the held job
        // drains, the slot admits again.
        pipeline.ingress().set_runner_hold(0, false);
        assert!(t1.wait().unwrap().verified);
        let t3 = pipeline.submit(&primes_req()).unwrap();
        assert!(t3.wait().unwrap().verified);
        assert_eq!(pipeline.ingress().pending(), 0);
    }

    #[test]
    fn block_policy_waits_for_a_slot() {
        let mut cfg = base_config();
        cfg.queue_depth = 1;
        let pipeline = Pipeline::new(cfg).unwrap();
        pipeline.ingress().set_runner_hold(0, true);
        let t1 = pipeline.submit(&primes_req()).unwrap();
        let blocked = {
            let pipeline = pipeline.clone();
            std::thread::spawn(move || pipeline.submit(&primes_req()).unwrap().wait())
        };
        // Give the blocked submitter time to park, then open the gate:
        // both jobs must complete.
        std::thread::sleep(Duration::from_millis(30));
        pipeline.ingress().set_runner_hold(0, false);
        assert!(t1.wait().unwrap().verified);
        assert!(blocked.join().unwrap().unwrap().verified);
    }

    #[test]
    fn backed_up_shard_migrates_queued_jobs_to_idle_shard() {
        let mut cfg = base_config();
        cfg.shards = 2;
        cfg.queue_depth = 16;
        let pipeline = Pipeline::new(cfg).unwrap();
        let ingress = pipeline.ingress();
        let home = pipeline.shards().home_index("primes");
        let other = 1 - home;
        // Gate both shards so the 8 submissions build a deterministic
        // 4/4 backlog (single dispatcher routes in submit order;
        // affinity + least-loaded alternates H,O,H,O…).
        ingress.set_runner_hold(home, true);
        ingress.set_runner_hold(other, true);
        let tickets: Vec<JobTicket> =
            (0..8).map(|_| pipeline.submit(&primes_req()).unwrap()).collect();
        wait_until("4/4 routed backlog", || {
            ingress.run_queue_depth(home) == 4 && ingress.run_queue_depth(other) == 4
        });
        // Open only the idle shard: it drains its own 4 jobs, then
        // steals from the backed-up one while its depth exceeds the
        // migration threshold (1) — exactly 3 whole jobs, oldest first.
        ingress.set_runner_hold(other, false);
        for i in [1, 3, 5, 7] {
            let res = tickets[i].wait().unwrap();
            assert_eq!(res.shard, other, "ticket {i} belongs to the idle shard");
            assert!(!res.migrated);
            assert!(res.verified);
        }
        for i in [0, 2, 4] {
            let res = tickets[i].wait().unwrap();
            assert!(res.migrated, "ticket {i} must have been stolen");
            assert_eq!(res.shard, other, "migrated jobs execute on the thief shard");
            assert!(res.verified, "migration must preserve verification");
        }
        assert_eq!(pipeline.shards().shard(home).migrated_out(), 3);
        assert_eq!(pipeline.shards().shard(other).migrated_in(), 3);
        // The job below the threshold stayed home.
        assert!(!tickets[6].is_ready());
        ingress.set_runner_hold(home, false);
        let last = tickets[6].wait().unwrap();
        assert_eq!(last.shard, home);
        assert!(!last.migrated);
        assert!(last.verified);
        // Identical results regardless of where a job ran.
        let want = tickets[6].try_result().unwrap().unwrap().detail;
        for t in &tickets {
            assert_eq!(t.try_result().unwrap().unwrap().detail, want);
        }
        let snap = pipeline.metrics().snapshot();
        assert_eq!(snap.gauges[&format!("shard.{home}.migrated_out")], 3);
        assert_eq!(snap.gauges[&format!("shard.{other}.migrated_in")], 3);
        assert_eq!(snap.counters["ingress.migrated"], 3);
        // Every lease returned.
        assert!(pipeline.shards().iter().all(|s| s.inflight() == 0));
    }

    #[test]
    fn invalid_requests_are_rejected_before_admission() {
        let pipeline = Pipeline::new(base_config()).unwrap();
        // Unknown workload name.
        match pipeline.submit(&JobRequest::named("warp", Mode::Seq)) {
            Err(SubmitError::Rejected { reason }) => {
                assert!(reason.contains("unknown workload: warp"), "{reason}");
                assert!(reason.contains("primes"), "reason lists registered names: {reason}");
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        // Out-of-schema parameter.
        let req = JobRequest::parse("primes(frobnicate=1) seq").unwrap();
        match pipeline.submit(&req) {
            Err(SubmitError::Rejected { reason }) => {
                assert!(reason.contains("unknown parameter"), "{reason}")
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        // Mistyped parameter value.
        let req = JobRequest::parse("primes(n=banana) seq").unwrap();
        match pipeline.submit(&req) {
            Err(SubmitError::Rejected { reason }) => {
                assert!(reason.contains("bad value for param n"), "{reason}")
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        // Rejections never touched the queue.
        assert_eq!(pipeline.ingress().pending(), 0);
        let snap = pipeline.metrics().snapshot();
        assert_eq!(snap.counters["ingress.rejected"], 3);
        assert_eq!(snap.counters.get("ingress.admitted"), None);
        // A well-formed param request still runs (and its params bind).
        let req = JobRequest::parse("primes(n=100) par(2)").unwrap();
        let res = pipeline.run(&req).unwrap();
        assert!(res.verified);
        match res.detail {
            crate::coordinator::ResultDetail::Primes { count, largest } => {
                assert_eq!(count, 25); // π(100)
                assert_eq!(largest, 97);
            }
            other => panic!("wrong detail kind: {other:?}"),
        }
    }

    #[test]
    fn shutdown_drains_queued_jobs_before_returning() {
        let mut cfg = base_config();
        cfg.queue_depth = 8;
        let pipeline = Pipeline::new(cfg).unwrap();
        pipeline.ingress().set_runner_hold(0, true);
        let tickets: Vec<JobTicket> =
            (0..3).map(|_| pipeline.submit(&primes_req()).unwrap()).collect();
        assert!(tickets.iter().all(|t| !t.is_ready()));
        // Dropping the last handle shuts the ingress down; queued jobs
        // are executed (holds cleared), not abandoned.
        drop(pipeline);
        for t in &tickets {
            let res = t.wait().unwrap();
            assert!(res.verified);
        }
    }
}

//! The staged ingress: how every job enters, waits, runs, and reports.
//!
//! Before this module, `sfut serve` was thread-per-session calling
//! [`Pipeline::run`](super::Pipeline::run) inline: no admission control,
//! no backpressure, and no way for an idle shard to help a backed-up one.
//! The ingress replaces that with a four-stage path:
//!
//! 1. **Admit** — [`Pipeline::submit`](super::Pipeline::submit) places
//!    the request in a bounded MPMC admission queue and returns a
//!    [`JobTicket`] immediately. The bound (`Config::queue_depth`)
//!    covers every job admitted but not yet executing; at the bound the
//!    configured [`AdmissionPolicy`] decides: `block` the submitter,
//!    `shed` ([`SubmitError::Shed`]), or wait up to a deadline
//!    ([`SubmitError::Timeout`] — the timed-out submission leaves no
//!    residue in the queue).
//! 2. **Route** — a small dispatcher pool (`Config::dispatchers`) pops
//!    admitted jobs and routes them through the existing
//!    [`ShardSet`](super::ShardSet) affinity/least-loaded logic onto the
//!    chosen shard's run queue, lease in hand.
//! 3. **Execute** — each shard owns `Config::shard_parallelism` runner
//!    threads (spawned with the big workload stack). A runner drains its
//!    own queue first; when idle it steals the *oldest whole queued job*
//!    from the deepest shard whose run-queue depth exceeds
//!    `Config::migrate_threshold` — cross-shard migration, the
//!    queue-level complement of the executor's task stealing. Migration
//!    re-leases the job onto the thief shard and shows up in the
//!    `shard.<id>.migrated_in`/`migrated_out` counters and the result's
//!    `migrated=` field.
//! 4. **Report** — the runner executes via
//!    [`PipelineCore::execute_routed`](super::router::PipelineCore) and
//!    fulfills the ticket's [`Fut`] cell, running any registered
//!    continuations — the service layer rides the same lock-free future
//!    state machine as the paper's stream cells.
//!
//! Shutdown is graceful: dropping the last `Pipeline` handle closes
//! admission, lets the dispatchers drain the admission queue, then the
//! runners drain every run queue (ignoring holds and the migration
//! threshold) before joining — in-flight tickets always resolve.
//!
//! The execute stage is **fault-contained** (see the failure-semantics
//! section in the [module docs](super)):
//!
//! * A panicking workload body is caught at the job boundary and
//!   answered as a terminal `panicked …` error; the runner thread
//!   survives (the whole `execute_one` body runs under a second
//!   `catch_unwind`, so even coordinator-machinery panics only cost the
//!   one job, whose ticket the [`FutPromise`] drop guard resolves).
//! * A job with a deadline (`deadline_ms` wire param, or
//!   `Config::deadline_ms`) registers with the shard-set **reaper**
//!   thread, which trips the job's [`CancelToken`] when the deadline
//!   expires; the body unwinds cooperatively at its next safe point and
//!   the attempt is classified `timeout`, not a crash.
//! * Transient outcomes (panic, timeout) are **retried** up to
//!   `Config::retry_max` times with exponential backoff, each retry
//!   re-leased onto a *different* shard (a poisoned pool or wedged
//!   worker on one shard doesn't doom the job).
//! * Repeated panics from one workload open a per-workload **circuit
//!   breaker** (`Config::breaker_threshold`): further submissions answer
//!   `rejected … breaker open` immediately, without taking queue
//!   capacity, until the pipeline restarts.

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::job::{JobRequest, JobResult};
use super::router::{ExecOutcome, PipelineCore, DEADLINE_PARAM};
use super::shard::ShardLease;
use crate::config::AdmissionPolicy;
use crate::exec::{Executor, ExecutorConfig};
use crate::metrics::MetricsRegistry;
use crate::susp::{CancelToken, Fut, FutPromise, FutState, Susp};

/// What a resolved [`JobTicket`] carries: the job's result, or the
/// error/panic message it failed with.
pub type TicketValue = Result<JobResult, String>;

/// A handle to a submitted job, returned by
/// [`Pipeline::submit`](super::Pipeline::submit) before the job runs.
///
/// Built directly on [`Fut`] — the same lock-free cell the paper's
/// stream tails suspend in — so it composes the same way:
/// [`JobTicket::and_then`]/[`JobTicket::bind`] chain continuations that
/// fire on completion, [`JobTicket::wait`] parks for the synchronous
/// result, and [`JobTicket::state`] is a lock-free peek.
#[derive(Clone)]
pub struct JobTicket {
    fut: Fut<TicketValue>,
}

impl JobTicket {
    /// The underlying future cell, for callers that want the full
    /// [`Fut`] combinator surface.
    pub fn fut(&self) -> &Fut<TicketValue> {
        &self.fut
    }

    /// Lock-free lifecycle peek (Empty until a runner picks the job up).
    pub fn state(&self) -> FutState {
        self.fut.state()
    }

    /// Whether the job has finished (never blocks).
    pub fn is_ready(&self) -> bool {
        self.fut.is_ready()
    }

    /// The outcome, if finished (never blocks).
    pub fn try_result(&self) -> Option<TicketValue> {
        self.fut.try_result().map(|r| match r {
            Ok(v) => v.clone(),
            Err(msg) => Err(msg.clone()),
        })
    }

    /// Park until the job finishes and return its result. Safe against
    /// abandoned cells (a dropped producer surfaces as an error).
    pub fn wait(&self) -> Result<JobResult> {
        match self.fut.wait_result() {
            Ok(Ok(res)) => Ok(res.clone()),
            Ok(Err(msg)) => Err(anyhow!("{msg}")),
            Err(msg) => Err(anyhow!("job ticket abandoned: {msg}")),
        }
    }

    /// Bounded [`JobTicket::wait`]: park for at most `timeout`. `None`
    /// means the job is still queued or running — the ticket stays valid
    /// and may be waited on (or polled) again later. `Some` carries the
    /// same mapping `wait` produces.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<JobResult>> {
        self.fut.wait_timeout(timeout).map(|r| match r {
            Ok(Ok(res)) => Ok(res.clone()),
            Ok(Err(msg)) => Err(anyhow!("{msg}")),
            Err(msg) => Err(anyhow!("job ticket abandoned: {msg}")),
        })
    }

    /// Chain a transformation on the outcome, exactly like mapping a
    /// stream cell: runs when the job completes (inline if it already
    /// has).
    pub fn and_then<U, F>(&self, f: F) -> Fut<U>
    where
        U: Send + Sync + 'static,
        F: FnOnce(TicketValue) -> U + Send + 'static,
    {
        self.fut.and_then(f)
    }

    /// Monadic bind on the outcome (continuation returns another future).
    pub fn bind<U, F>(&self, f: F) -> Fut<U>
    where
        U: Clone + Send + Sync + 'static,
        F: FnOnce(TicketValue) -> Fut<U> + Send + 'static,
    {
        self.fut.bind(f)
    }
}

/// Why [`Pipeline::submit`](super::Pipeline::submit) rejected a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded queue full under `admission = shed`.
    Shed { queue_depth: usize },
    /// Queue stayed full for the whole `admission = timeout(ms)` window.
    /// The submission leaves no residue: its would-be slot stays with
    /// the queue.
    Timeout { waited_ms: u64, queue_depth: usize },
    /// The pipeline is shutting down.
    Closed,
    /// The request failed registry validation before admission: unknown
    /// workload name, or params outside the plugin's declared schema.
    /// Answered immediately — a malformed request never occupies queue
    /// capacity.
    Rejected { reason: String },
}

impl SubmitError {
    /// Serve-protocol rendering: a well-formed `err admission=…` /
    /// `err rejected …` line.
    pub fn render_line(&self, req: &JobRequest) -> String {
        let w = req.workload_spec();
        let m = req.mode.label();
        match self {
            SubmitError::Shed { queue_depth } => {
                format!("err admission=shed workload={w} mode={m} queue_depth={queue_depth}")
            }
            SubmitError::Timeout { waited_ms, queue_depth } => format!(
                "err admission=timeout workload={w} mode={m} waited_ms={waited_ms} \
                 queue_depth={queue_depth}"
            ),
            SubmitError::Closed => format!("err admission=closed workload={w} mode={m}"),
            SubmitError::Rejected { reason } => {
                format!("err rejected workload={w} mode={m} reason: {reason}")
            }
        }
    }
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Shed { queue_depth } => {
                write!(f, "admission=shed: ingress queue full (queue_depth={queue_depth})")
            }
            SubmitError::Timeout { waited_ms, queue_depth } => write!(
                f,
                "admission=timeout: no queue slot within {waited_ms}ms \
                 (queue_depth={queue_depth})"
            ),
            SubmitError::Closed => write!(f, "admission=closed: pipeline is shutting down"),
            SubmitError::Rejected { reason } => write!(f, "invalid request: {reason}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Outcome of one *nonblocking* admission attempt
/// ([`Ingress::try_submit`]) — what the reactor needs: either a ticket,
/// a terminal rejection, or the request handed back because the queue
/// is at its bound under a parking policy (the reactor defers it and
/// retries; a thread-per-session submitter would have parked instead).
pub(super) enum TryAdmit {
    /// Admitted; the ticket is live.
    Ticket(JobTicket),
    /// Terminal: validation/breaker rejection, shed at the bound, or
    /// closed. Never retried.
    Reject(SubmitError),
    /// Queue at its bound under `block`/`timeout(ms)`: the request is
    /// returned so the caller can defer and retry without cloning.
    Full(JobRequest),
}

/// A job admitted but not yet routed.
struct Pending {
    req: JobRequest,
    verify: bool,
    promise: FutPromise<TicketValue>,
    submitted: Instant,
}

/// A job routed to a shard's run queue, lease in hand.
struct Routed {
    pending: Pending,
    lease: ShardLease,
}

/// Per-workload circuit breaker: after `threshold` *consecutive*
/// panicking attempts of one workload, quarantine it — further
/// submissions are rejected at the front door (no queue capacity
/// consumed) with a `breaker open` reason. `threshold == 0` disables
/// the breaker entirely. A breaker, once open, stays open for the
/// pipeline's lifetime: a plugin that panics repeatedly is broken code,
/// and flapping half-open probes would keep feeding jobs into it.
struct Breaker {
    threshold: u32,
    entries: Mutex<BTreeMap<String, BreakerEntry>>,
}

#[derive(Default)]
struct BreakerEntry {
    consecutive: u32,
    open: bool,
}

impl Breaker {
    fn new(threshold: u32) -> Breaker {
        Breaker { threshold, entries: Mutex::new(BTreeMap::new()) }
    }

    fn is_open(&self, workload: &str) -> bool {
        self.threshold != 0
            && self.entries.lock().unwrap().get(workload).is_some_and(|e| e.open)
    }

    /// Record one panicking attempt; returns `true` if this one opened
    /// the breaker (the `breaker.<workload>.open` gauge flips to 1).
    fn note_panic(&self, workload: &str, metrics: &MetricsRegistry) -> bool {
        if self.threshold == 0 {
            return false;
        }
        let mut entries = self.entries.lock().unwrap();
        let entry = entries.entry(workload.to_string()).or_default();
        if entry.open {
            return false;
        }
        entry.consecutive += 1;
        if entry.consecutive >= self.threshold {
            entry.open = true;
            metrics.gauge(&format!("breaker.{workload}.open")).set(1);
            return true;
        }
        false
    }

    /// A completed attempt resets the consecutive-panic streak.
    fn note_ok(&self, workload: &str) {
        if self.threshold == 0 {
            return;
        }
        if let Some(entry) = self.entries.lock().unwrap().get_mut(workload) {
            if !entry.open {
                entry.consecutive = 0;
            }
        }
    }
}

/// The deadline reaper: one parked thread (`sfut-reaper`) holding every
/// in-flight job's `(deadline, CancelToken)`. It wakes at the earliest
/// registered deadline (or on registration/shutdown), trips expired
/// tokens, and goes back to sleep — enforcement is cooperative
/// cancellation, so the reaper never touches the job's thread.
struct Reaper {
    inner: Mutex<ReaperInner>,
    cv: Condvar,
}

struct ReaperInner {
    entries: Vec<(u64, Instant, CancelToken)>,
    next_id: u64,
    closed: bool,
}

impl Reaper {
    fn new() -> Arc<Reaper> {
        Arc::new(Reaper {
            inner: Mutex::new(ReaperInner { entries: Vec::new(), next_id: 0, closed: false }),
            cv: Condvar::new(),
        })
    }

    /// Watch `token` until `deadline`; deregistration is the returned
    /// guard's drop (the attempt finished first — the common case).
    fn register(self: Arc<Reaper>, deadline: Instant, token: CancelToken) -> DeadlineGuard {
        let id = {
            let mut inner = self.inner.lock().unwrap();
            let id = inner.next_id;
            inner.next_id += 1;
            inner.entries.push((id, deadline, token));
            id
        };
        self.cv.notify_all();
        DeadlineGuard { reaper: self, id }
    }

    fn run(&self) {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.closed {
                return;
            }
            let now = Instant::now();
            inner.entries.retain(|(_, deadline, token)| {
                if *deadline <= now {
                    token.cancel();
                    false
                } else {
                    true
                }
            });
            let earliest = inner.entries.iter().map(|(_, deadline, _)| *deadline).min();
            inner = match earliest {
                Some(deadline) => {
                    let wait = deadline.saturating_duration_since(now);
                    self.cv.wait_timeout(inner, wait).unwrap().0
                }
                None => self.cv.wait(inner).unwrap(),
            };
        }
    }

    fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

/// RAII deregistration for one reaper entry.
struct DeadlineGuard {
    reaper: Arc<Reaper>,
    id: u64,
}

impl Drop for DeadlineGuard {
    fn drop(&mut self) {
        let mut inner = self.reaper.inner.lock().unwrap();
        inner.entries.retain(|(id, _, _)| *id != self.id);
    }
}

/// Stage-1 state: the bounded admission queue.
struct Admission {
    queue: VecDeque<Pending>,
    /// Jobs admitted but not yet picked up by a runner — this (not the
    /// `queue` length) is what `queue_depth` bounds, so the run queues
    /// cannot become an unbounded overflow behind a "bounded" front
    /// door.
    pending: usize,
    closed: bool,
}

/// Stage-2/3 state: one FIFO run queue per shard.
struct RunQueues {
    queues: Vec<VecDeque<Routed>>,
    /// Per-shard runner gate: a held shard's runners neither execute nor
    /// steal. Drain/maintenance control, and what the migration tests
    /// use to build deterministic backlogs.
    held: Vec<bool>,
    closed: bool,
}

struct IngressShared {
    core: Arc<PipelineCore>,
    queue_depth: usize,
    policy: AdmissionPolicy,
    migrate_threshold: usize,
    admission: Mutex<Admission>,
    /// Signalled when a runner frees an admission slot.
    not_full: Condvar,
    /// Signalled when a submission lands in the admission queue.
    not_empty: Condvar,
    run: Mutex<RunQueues>,
    /// Signalled when a job lands in any run queue (or on shutdown).
    work: Condvar,
    /// Deadline enforcement for in-flight attempts.
    reaper: Arc<Reaper>,
    /// Per-workload panic quarantine.
    breaker: Breaker,
    /// Deterministic fault injection for the chaos harness: when
    /// nonzero, every `nth` execute_one call panics in coordinator
    /// machinery (after the admission slot is released, before the
    /// promise starts) — exercising the runner-recovery and
    /// ticket-drop-guard paths without touching any workload.
    #[cfg(feature = "chaos")]
    chaos_runner_panic_every: std::sync::atomic::AtomicU64,
    #[cfg(feature = "chaos")]
    chaos_runner_panic_count: std::sync::atomic::AtomicU64,
}

/// The staged ingress: admission queue, dispatcher pool, and per-shard
/// runner threads. Owned by [`Pipeline`](super::Pipeline) (reachable via
/// [`Pipeline::ingress`](super::Pipeline::ingress) for introspection and
/// drain control); dropping the owning pipeline drains and joins
/// everything.
pub struct Ingress {
    shared: Arc<IngressShared>,
    /// Executor backing ticket cells: continuations registered before
    /// completion run here (completed-cell continuations run inline,
    /// like any [`Fut`]).
    ticket_exec: Executor,
    dispatchers: Mutex<Vec<JoinHandle<()>>>,
    runners: Mutex<Vec<JoinHandle<()>>>,
    reaper_thread: Mutex<Option<JoinHandle<()>>>,
}

impl Ingress {
    /// Spawn the dispatcher pool and the per-shard runners.
    pub(super) fn start(core: Arc<PipelineCore>) -> Result<Ingress> {
        let cfg = core.config();
        let queue_depth = cfg.queue_depth;
        let policy = cfg.admission;
        let migrate_threshold = cfg.migrate_threshold;
        let dispatcher_count = cfg.dispatchers;
        let runners_per_shard = cfg.shard_parallelism;
        let stack = cfg.stack_size;
        let breaker_threshold = cfg.breaker_threshold;
        let shard_count = core.shards().len();
        let shared = Arc::new(IngressShared {
            queue_depth,
            policy,
            migrate_threshold,
            admission: Mutex::new(Admission {
                queue: VecDeque::new(),
                pending: 0,
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            run: Mutex::new(RunQueues {
                queues: (0..shard_count).map(|_| VecDeque::new()).collect(),
                held: vec![false; shard_count],
                closed: false,
            }),
            work: Condvar::new(),
            reaper: Reaper::new(),
            breaker: Breaker::new(breaker_threshold),
            #[cfg(feature = "chaos")]
            chaos_runner_panic_every: std::sync::atomic::AtomicU64::new(0),
            #[cfg(feature = "chaos")]
            chaos_runner_panic_count: std::sync::atomic::AtomicU64::new(0),
            core,
        });

        let mut ticket_cfg = ExecutorConfig::with_parallelism(2);
        ticket_cfg.name = "sfut-ticket".to_string();
        ticket_cfg.deque = cfg.deque;
        let ticket_exec = Executor::with_config(ticket_cfg);

        // Built before any thread spawns so an error below (`?`) drops
        // the Ingress, whose shutdown joins whatever was already spawned
        // — a failed partial start must not leak parked threads.
        let ingress = Ingress {
            shared: Arc::clone(&shared),
            ticket_exec,
            dispatchers: Mutex::new(Vec::with_capacity(dispatcher_count)),
            runners: Mutex::new(Vec::with_capacity(shard_count * runners_per_shard)),
            reaper_thread: Mutex::new(None),
        };
        {
            let reaper = Arc::clone(&shared.reaper);
            let handle = std::thread::Builder::new()
                .name("sfut-reaper".to_string())
                .spawn(move || reaper.run())
                .context("spawning deadline reaper")?;
            *ingress.reaper_thread.lock().unwrap() = Some(handle);
        }
        for i in 0..dispatcher_count {
            let shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("sfut-dispatch-{i}"))
                .spawn(move || dispatcher_loop(&shared))
                .context("spawning ingress dispatcher")?;
            ingress.dispatchers.lock().unwrap().push(handle);
        }
        for sid in 0..shard_count {
            for i in 0..runners_per_shard {
                let shared = Arc::clone(&shared);
                let handle = std::thread::Builder::new()
                    .name(format!("sfut-runner-s{sid}-{i}"))
                    // Runners execute workload bodies directly (deep Lazy
                    // chains need the big stack the per-job driver
                    // threads used to provide).
                    .stack_size(stack)
                    .spawn(move || runner_loop(&shared, sid))
                    .context("spawning shard runner")?;
                ingress.runners.lock().unwrap().push(handle);
            }
        }
        Ok(ingress)
    }

    /// Stage 1: validate against the registry, then admit under the
    /// configured policy. Returns the ticket immediately (the job may
    /// not even be routed yet).
    pub(super) fn submit(&self, req: JobRequest, verify: bool) -> Result<JobTicket, SubmitError> {
        let req = match self.try_submit(req, verify, true) {
            TryAdmit::Ticket(ticket) => return Ok(ticket),
            TryAdmit::Reject(err) => return Err(err),
            TryAdmit::Full(req) => req,
        };
        // Queue at the bound under a parking policy: wait for a slot
        // (bounded under `timeout(ms)`), then admit through the same
        // single admit site the nonblocking path uses.
        let metrics = self.shared.core.metrics();
        let depth = self.shared.queue_depth;
        let mut adm = self.shared.admission.lock().unwrap();
        match self.shared.policy {
            // `try_submit` sheds at the bound itself, so reaching here
            // under shed means a slot freed in between — the re-check
            // keeps the policy honest if it raced full again.
            AdmissionPolicy::Shed => {
                if adm.pending >= depth && !adm.closed {
                    metrics.counter("ingress.shed").inc();
                    return Err(SubmitError::Shed { queue_depth: depth });
                }
            }
            AdmissionPolicy::Block => {
                while adm.pending >= depth && !adm.closed {
                    adm = self.shared.not_full.wait(adm).unwrap();
                }
            }
            AdmissionPolicy::Timeout(ms) => {
                let deadline = Instant::now() + Duration::from_millis(ms);
                while adm.pending >= depth && !adm.closed {
                    let now = Instant::now();
                    if now >= deadline {
                        metrics.counter("ingress.timed_out").inc();
                        return Err(SubmitError::Timeout { waited_ms: ms, queue_depth: depth });
                    }
                    let (guard, _timeout) =
                        self.shared.not_full.wait_timeout(adm, deadline - now).unwrap();
                    adm = guard;
                }
            }
        }
        if adm.closed {
            return Err(SubmitError::Closed);
        }
        let ticket = self.admit_locked(&mut adm, req, verify);
        drop(adm);
        self.shared.not_empty.notify_one();
        Ok(ticket)
    }

    /// Nonblocking stage 1, for callers that must never park (the
    /// framed-wire reactor thread). Validation, breaker gate, and the
    /// shed policy behave exactly as [`Ingress::submit`]; the difference
    /// is at the bound under `block`/`timeout`: the request is handed
    /// back as [`TryAdmit::Full`] instead of parking the caller.
    ///
    /// `count_submission` gates the `ingress.submitted` counter so a
    /// deferred request retried across reactor ticks still counts as
    /// one submission.
    pub(super) fn try_submit(
        &self,
        req: JobRequest,
        verify: bool,
        count_submission: bool,
    ) -> TryAdmit {
        let metrics = self.shared.core.metrics();
        if count_submission {
            metrics.counter("ingress.submitted").inc();
        }
        // Open-world gate: resolve the workload name and schema-check
        // its params before taking any queue slot, so malformed
        // requests answer immediately and never occupy capacity.
        if let Err(e) = self.shared.core.validate_request(&req) {
            metrics.counter("ingress.rejected").inc();
            return TryAdmit::Reject(SubmitError::Rejected { reason: e.to_string() });
        }
        // Quarantine gate: a workload whose breaker opened answers here,
        // like any other rejection — before taking a queue slot.
        if self.shared.breaker.is_open(&req.workload) {
            metrics.counter("ingress.rejected").inc();
            return TryAdmit::Reject(SubmitError::Rejected {
                reason: format!(
                    "breaker open: workload {} quarantined after repeated panics",
                    req.workload
                ),
            });
        }
        let depth = self.shared.queue_depth;
        let mut adm = self.shared.admission.lock().unwrap();
        if adm.closed {
            return TryAdmit::Reject(SubmitError::Closed);
        }
        if adm.pending >= depth {
            if matches!(self.shared.policy, AdmissionPolicy::Shed) {
                metrics.counter("ingress.shed").inc();
                return TryAdmit::Reject(SubmitError::Shed { queue_depth: depth });
            }
            return TryAdmit::Full(req);
        }
        let ticket = self.admit_locked(&mut adm, req, verify);
        drop(adm);
        self.shared.not_empty.notify_one();
        TryAdmit::Ticket(ticket)
    }

    /// The one admit site: create the ticket's promise pair and enqueue
    /// the pending job. Caller holds the admission lock, has verified
    /// capacity and open-ness, and signals `not_empty` after unlocking.
    fn admit_locked(&self, adm: &mut Admission, req: JobRequest, verify: bool) -> JobTicket {
        let metrics = self.shared.core.metrics();
        let (fut, promise) = Fut::promise(&self.ticket_exec);
        adm.pending += 1;
        adm.queue.push_back(Pending { req, verify, promise, submitted: Instant::now() });
        metrics.counter("ingress.admitted").inc();
        metrics.gauge("ingress.queue_depth").set(adm.pending as u64);
        JobTicket { fut }
    }

    /// Count a deferred admission that expired under `timeout(ms)`
    /// without ever getting a slot — the reactor's analogue of the
    /// parking path's timeout bookkeeping.
    pub(super) fn note_deferred_timeout(&self) {
        self.shared.core.metrics().counter("ingress.timed_out").inc();
    }

    /// Jobs admitted but not yet executing (the quantity `queue_depth`
    /// bounds).
    pub fn pending(&self) -> usize {
        self.shared.admission.lock().unwrap().pending
    }

    /// Depth of one shard's run queue.
    pub fn run_queue_depth(&self, shard: usize) -> usize {
        self.shared.run.lock().unwrap().queues[shard].len()
    }

    /// Gate a shard's runners: a held shard neither executes its own
    /// queue nor steals. Maintenance/drain control — hold a shard and
    /// its backlog migrates to its peers once it exceeds the threshold;
    /// the migration tests use it to build deterministic backlogs.
    /// Holds are cleared automatically on shutdown.
    pub fn set_runner_hold(&self, shard: usize, hold: bool) {
        {
            let mut run = self.shared.run.lock().unwrap();
            run.held[shard] = hold;
        }
        self.shared.work.notify_all();
    }

    /// Fault injection: make every `nth` execute call panic inside
    /// coordinator machinery (0 disables; resets the counter). Per
    /// pipeline — parallel tests never see each other's faults.
    #[cfg(feature = "chaos")]
    pub fn chaos_set_runner_panic_every(&self, nth: u64) {
        use std::sync::atomic::Ordering;
        self.shared.chaos_runner_panic_every.store(nth, Ordering::SeqCst);
        self.shared.chaos_runner_panic_count.store(0, Ordering::SeqCst);
    }

    /// Close admission, drain both stages, and join every thread.
    /// Queued jobs are *executed*, not dropped — every outstanding
    /// ticket resolves before this returns. Idempotent.
    fn shutdown(&self) {
        {
            let mut adm = self.shared.admission.lock().unwrap();
            adm.closed = true;
        }
        self.shared.not_full.notify_all();
        self.shared.not_empty.notify_all();
        for handle in self.dispatchers.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
        {
            let mut run = self.shared.run.lock().unwrap();
            run.closed = true;
            for hold in run.held.iter_mut() {
                *hold = false;
            }
        }
        self.shared.work.notify_all();
        for handle in self.runners.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
        // Last: no runner is left to register deadlines.
        self.shared.reaper.close();
        if let Some(handle) = self.reaper_thread.lock().unwrap().take() {
            let _ = handle.join();
        }
    }
}

impl Drop for Ingress {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Stage 2: pop admitted jobs, route via the shard set, hand to the
/// chosen shard's run queue. Drains the admission queue fully before
/// exiting on shutdown.
fn dispatcher_loop(shared: &IngressShared) {
    loop {
        let pending = {
            let mut adm = shared.admission.lock().unwrap();
            loop {
                if let Some(p) = adm.queue.pop_front() {
                    break p;
                }
                if adm.closed {
                    return;
                }
                adm = shared.not_empty.wait(adm).unwrap();
            }
        };
        let lease = shared.core.shards().route(&pending.req.workload);
        let sid = lease.id();
        let depth = {
            let mut run = shared.run.lock().unwrap();
            // Shutdown invariant: run queues close only *after* every
            // dispatcher has been joined (see Ingress::shutdown), so a
            // live dispatcher can never observe a closed run stage. The
            // assert keeps that ordering honest if shutdown ever changes.
            debug_assert!(!run.closed, "run queues closed while a dispatcher is live");
            run.queues[sid].push_back(Routed { pending, lease });
            run.queues[sid].len()
        };
        let metrics = shared.core.metrics();
        metrics.gauge(&format!("shard.{sid}.run_queue_depth")).set(depth as u64);
        shared.work.notify_all();
    }
}

/// Pick the deepest run queue (≠ `sid`) whose depth exceeds the
/// migration threshold.
fn steal_victim(run: &RunQueues, sid: usize, threshold: usize) -> Option<usize> {
    run.queues
        .iter()
        .enumerate()
        .filter(|&(v, q)| v != sid && q.len() > threshold)
        .max_by_key(|&(_, q)| q.len())
        .map(|(v, _)| v)
}

/// Stage 3 (+4): execute jobs from this shard's run queue; steal whole
/// queued jobs from backed-up shards when idle; fulfill tickets.
fn runner_loop(shared: &IngressShared, sid: usize) {
    loop {
        // (job, migrated, gauge update) — the gauge write (a format! and
        // a registry lock) happens after the run lock is released; every
        // dequeue would otherwise lengthen the one critical section the
        // whole ingress contends on.
        let next = {
            let mut run = shared.run.lock().unwrap();
            loop {
                if run.closed {
                    // Drain mode: own queue first, then anything left
                    // anywhere (threshold and holds no longer apply).
                    // Cross-queue pops here are NOT migration — the job
                    // keeps its routed lease and shard attribution; the
                    // runner is just the thread that happens to drain it.
                    let victim = if !run.queues[sid].is_empty() {
                        Some(sid)
                    } else {
                        (0..run.queues.len()).find(|&v| !run.queues[v].is_empty())
                    };
                    // Wake peers: either there is more to drain, or all
                    // queues are empty and they should exit too.
                    shared.work.notify_all();
                    break victim.map(|v| {
                        let job = run.queues[v].pop_front().expect("checked non-empty");
                        (job, false, None)
                    });
                }
                if !run.held[sid] {
                    if let Some(job) = run.queues[sid].pop_front() {
                        let depth = run.queues[sid].len();
                        break Some((job, false, Some((sid, depth))));
                    }
                    if let Some(v) = steal_victim(&run, sid, shared.migrate_threshold) {
                        let job = run.queues[v].pop_front().expect("victim non-empty");
                        let depth = run.queues[v].len();
                        break Some((job, true, Some((v, depth))));
                    }
                }
                run = shared.work.wait(run).unwrap();
            }
        };
        let Some((routed, migrated, gauge)) = next else {
            return;
        };
        if let Some((shard_id, depth)) = gauge {
            shared
                .core
                .metrics()
                .gauge(&format!("shard.{shard_id}.run_queue_depth"))
                .set(depth as u64);
        }
        // Runner survival: a panic anywhere in the execute path — the
        // workload boundary catches its own, so this only fires for
        // coordinator machinery (or injected) faults — costs exactly one
        // job. The unwind drops the job's promise (its drop guard
        // resolves the ticket as abandoned) and its lease (inflight
        // decrements); the runner thread itself lives on.
        let survived = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            execute_one(shared, sid, routed, migrated);
        }));
        if survived.is_err() {
            shared.core.metrics().counter("ingress.runner_recovered").inc();
        }
    }
}

/// Cap on one exponential-backoff sleep between retries.
const MAX_RETRY_BACKOFF: Duration = Duration::from_millis(5000);

#[cfg(feature = "chaos")]
fn chaos_maybe_panic(shared: &IngressShared) {
    use std::sync::atomic::Ordering;
    let every = shared.chaos_runner_panic_every.load(Ordering::SeqCst);
    if every == 0 {
        return;
    }
    let n = shared.chaos_runner_panic_count.fetch_add(1, Ordering::SeqCst) + 1;
    if n % every == 0 {
        panic!("chaos: injected runner fault");
    }
}

/// Stage 3 body: release the job's admission slot, adopt it (re-leasing
/// on migration), execute — retrying transient failures with backoff on
/// a different shard — and fulfill the ticket with exactly one terminal
/// outcome.
fn execute_one(shared: &IngressShared, sid: usize, routed: Routed, migrated: bool) {
    let Routed { pending, lease } = routed;
    let metrics = shared.core.metrics();
    // Free the admission slot FIRST — before any machinery that could
    // unwind (lease adoption, chaos injection) — so a runner panic can
    // never leak queue capacity. Blocked submitters refill the queue
    // while the job runs.
    {
        let mut adm = shared.admission.lock().unwrap();
        adm.pending -= 1;
        metrics.gauge("ingress.queue_depth").set(adm.pending as u64);
    }
    shared.not_full.notify_one();
    #[cfg(feature = "chaos")]
    chaos_maybe_panic(shared);
    let mut lease = if migrated {
        let from = lease.id();
        drop(lease);
        let shards = shared.core.shards();
        shards.shard(from).note_migrated_out();
        let adopted = shards.lease_on(sid);
        shards.shard(sid).note_migrated_in();
        metrics.counter("ingress.migrated").inc();
        adopted
    } else {
        lease
    };
    // Flip the ticket to Running so pollers can tell executing from
    // queued (`serve`'s `poll` command surfaces this state).
    pending.promise.start();
    let queue_wait = pending.submitted.elapsed();
    let Pending { req, verify, promise, .. } = pending;
    let cfg = shared.core.config();
    // Per-attempt deadline: the wire param wins over the config default;
    // 0 = none. Type-checked at submit time, so the fallback never fires.
    let deadline_ms =
        req.params.get_u64(DEADLINE_PARAM, cfg.deadline_ms).unwrap_or(cfg.deadline_ms);
    let retry_max = cfg.retry_max;
    let backoff_ms = cfg.retry_backoff_ms;
    let workload_spec = req.workload_spec();
    let mode_label = req.mode.label();
    let mut attempt: u32 = 0;
    loop {
        // Fresh token per attempt: a retry must not start pre-cancelled
        // by the previous attempt's expired deadline.
        let token = CancelToken::new();
        let deadline_guard = (deadline_ms > 0).then(|| {
            Arc::clone(&shared.reaper)
                .register(Instant::now() + Duration::from_millis(deadline_ms), token.clone())
        });
        let shard = Arc::clone(lease.shard());
        let outcome = shared.core.execute_routed(
            req.clone(),
            &shard,
            verify,
            queue_wait,
            migrated,
            &token,
            attempt,
        );
        drop(deadline_guard);
        match outcome {
            ExecOutcome::Done(result) => {
                shared.breaker.note_ok(&req.workload);
                drop(lease);
                promise.fulfill(Ok(*result));
                return;
            }
            ExecOutcome::Failed(msg) => {
                // Deterministic failure: retrying would fail identically.
                metrics.counter("jobs.failed").inc();
                drop(lease);
                promise.fulfill(Err(msg));
                return;
            }
            ExecOutcome::Panicked(reason) => {
                metrics.counter("jobs.panicked").inc();
                shared.breaker.note_panic(&req.workload, metrics);
                if attempt >= retry_max {
                    drop(lease);
                    // `reason=` is last: it may contain spaces (see the
                    // failure-semantics grammar in the module docs).
                    promise.fulfill(Err(format!(
                        "panicked workload={workload_spec} mode={mode_label} reason={reason}"
                    )));
                    return;
                }
            }
            ExecOutcome::TimedOut => {
                metrics.counter("jobs.timed_out").inc();
                if attempt >= retry_max {
                    drop(lease);
                    promise.fulfill(Err(format!(
                        "timeout workload={workload_spec} mode={mode_label} \
                         deadline_ms={deadline_ms}"
                    )));
                    return;
                }
            }
        }
        // Transient failure with retry budget left: back off, then
        // re-lease onto the next shard — a wedged pool on this one must
        // not doom every attempt. (Not counted as migration: the job was
        // not stolen, it bounced.)
        metrics.counter("jobs.retried").inc();
        attempt += 1;
        let scaled_ms = backoff_ms.checked_shl(attempt - 1).unwrap_or(u64::MAX);
        let backoff = Duration::from_millis(scaled_ms).min(MAX_RETRY_BACKOFF);
        std::thread::sleep(backoff);
        let shards = shared.core.shards();
        let next = (lease.id() + 1) % shards.len();
        drop(lease);
        lease = shards.lease_on(next);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, Mode};
    use crate::coordinator::Pipeline;

    fn base_config() -> Config {
        let mut cfg = Config::default();
        cfg.primes_n = 500;
        cfg.fateman_degree = 3;
        cfg.chunk_size = 16;
        cfg.use_kernel = false;
        cfg.shards = 1;
        cfg.shard_parallelism = 1;
        cfg.dispatchers = 1;
        cfg
    }

    fn primes_req() -> JobRequest {
        JobRequest::named("primes", Mode::Par(2))
    }

    fn wait_until(what: &str, mut ok: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !ok() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn ticket_resolves_and_chains_like_a_stream_cell() {
        let pipeline = Pipeline::new(base_config()).unwrap();
        let ticket = pipeline.submit(&primes_req()).unwrap();
        // Dogfooding: chain a continuation on the ticket's Fut cell.
        let count = ticket.and_then(|outcome| {
            let res = outcome.expect("job failed");
            match res.detail {
                crate::coordinator::ResultDetail::Primes { count, .. } => count,
                _ => 0,
            }
        });
        let res = ticket.wait().unwrap();
        assert!(res.verified);
        assert!(!res.migrated);
        assert!(res.queue_wait >= 0.0);
        assert_eq!(*crate::susp::Susp::force(&count), 95); // π(500)
        assert_eq!(
            pipeline.metrics().snapshot().counters["ingress.admitted"],
            1
        );
    }

    #[test]
    fn shed_policy_rejects_at_the_bound() {
        let mut cfg = base_config();
        cfg.queue_depth = 2;
        cfg.admission = AdmissionPolicy::Shed;
        let pipeline = Pipeline::new(cfg).unwrap();
        pipeline.ingress().set_runner_hold(0, true);
        let t1 = pipeline.submit(&primes_req()).unwrap();
        let t2 = pipeline.submit(&primes_req()).unwrap();
        // Both slots occupied and nothing executing: the third submission
        // sheds, deterministically.
        match pipeline.submit(&primes_req()) {
            Err(SubmitError::Shed { queue_depth }) => assert_eq!(queue_depth, 2),
            other => panic!("expected shed, got {other:?}"),
        }
        let snap = pipeline.metrics().snapshot();
        assert_eq!(snap.counters["ingress.shed"], 1);
        assert_eq!(snap.counters["ingress.admitted"], 2);
        pipeline.ingress().set_runner_hold(0, false);
        assert!(t1.wait().unwrap().verified);
        assert!(t2.wait().unwrap().verified);
        // Capacity fully recovered after the shed.
        let t4 = pipeline.submit(&primes_req()).unwrap();
        assert!(t4.wait().unwrap().verified);
    }

    #[test]
    fn timeout_policy_sheds_late_and_releases_the_slot() {
        let mut cfg = base_config();
        cfg.queue_depth = 1;
        cfg.admission = AdmissionPolicy::Timeout(50);
        let pipeline = Pipeline::new(cfg).unwrap();
        pipeline.ingress().set_runner_hold(0, true);
        let t1 = pipeline.submit(&primes_req()).unwrap();
        let started = Instant::now();
        match pipeline.submit(&primes_req()) {
            Err(SubmitError::Timeout { waited_ms, queue_depth }) => {
                assert_eq!(waited_ms, 50);
                assert_eq!(queue_depth, 1);
            }
            other => panic!("expected timeout, got {other:?}"),
        }
        assert!(started.elapsed() >= Duration::from_millis(45), "timed out too early");
        assert_eq!(pipeline.metrics().snapshot().counters["ingress.timed_out"], 1);
        // The timed-out submission left no residue: once the held job
        // drains, the slot admits again.
        pipeline.ingress().set_runner_hold(0, false);
        assert!(t1.wait().unwrap().verified);
        let t3 = pipeline.submit(&primes_req()).unwrap();
        assert!(t3.wait().unwrap().verified);
        assert_eq!(pipeline.ingress().pending(), 0);
    }

    #[test]
    fn block_policy_waits_for_a_slot() {
        let mut cfg = base_config();
        cfg.queue_depth = 1;
        let pipeline = Pipeline::new(cfg).unwrap();
        pipeline.ingress().set_runner_hold(0, true);
        let t1 = pipeline.submit(&primes_req()).unwrap();
        let blocked = {
            let pipeline = pipeline.clone();
            std::thread::spawn(move || pipeline.submit(&primes_req()).unwrap().wait())
        };
        // Give the blocked submitter time to park, then open the gate:
        // both jobs must complete.
        std::thread::sleep(Duration::from_millis(30));
        pipeline.ingress().set_runner_hold(0, false);
        assert!(t1.wait().unwrap().verified);
        assert!(blocked.join().unwrap().unwrap().verified);
    }

    #[test]
    fn backed_up_shard_migrates_queued_jobs_to_idle_shard() {
        let mut cfg = base_config();
        cfg.shards = 2;
        cfg.queue_depth = 16;
        let pipeline = Pipeline::new(cfg).unwrap();
        let ingress = pipeline.ingress();
        let home = pipeline.shards().home_index("primes");
        let other = 1 - home;
        // Gate both shards so the 8 submissions build a deterministic
        // 4/4 backlog (single dispatcher routes in submit order;
        // affinity + least-loaded alternates H,O,H,O…).
        ingress.set_runner_hold(home, true);
        ingress.set_runner_hold(other, true);
        let tickets: Vec<JobTicket> =
            (0..8).map(|_| pipeline.submit(&primes_req()).unwrap()).collect();
        wait_until("4/4 routed backlog", || {
            ingress.run_queue_depth(home) == 4 && ingress.run_queue_depth(other) == 4
        });
        // Open only the idle shard: it drains its own 4 jobs, then
        // steals from the backed-up one while its depth exceeds the
        // migration threshold (1) — exactly 3 whole jobs, oldest first.
        ingress.set_runner_hold(other, false);
        for i in [1, 3, 5, 7] {
            let res = tickets[i].wait().unwrap();
            assert_eq!(res.shard, other, "ticket {i} belongs to the idle shard");
            assert!(!res.migrated);
            assert!(res.verified);
        }
        for i in [0, 2, 4] {
            let res = tickets[i].wait().unwrap();
            assert!(res.migrated, "ticket {i} must have been stolen");
            assert_eq!(res.shard, other, "migrated jobs execute on the thief shard");
            assert!(res.verified, "migration must preserve verification");
        }
        assert_eq!(pipeline.shards().shard(home).migrated_out(), 3);
        assert_eq!(pipeline.shards().shard(other).migrated_in(), 3);
        // The job below the threshold stayed home.
        assert!(!tickets[6].is_ready());
        ingress.set_runner_hold(home, false);
        let last = tickets[6].wait().unwrap();
        assert_eq!(last.shard, home);
        assert!(!last.migrated);
        assert!(last.verified);
        // Identical results regardless of where a job ran.
        let want = tickets[6].try_result().unwrap().unwrap().detail;
        for t in &tickets {
            assert_eq!(t.try_result().unwrap().unwrap().detail, want);
        }
        let snap = pipeline.metrics().snapshot();
        assert_eq!(snap.gauges[&format!("shard.{home}.migrated_out")], 3);
        assert_eq!(snap.gauges[&format!("shard.{other}.migrated_in")], 3);
        assert_eq!(snap.counters["ingress.migrated"], 3);
        // Every lease returned.
        assert!(pipeline.shards().iter().all(|s| s.inflight() == 0));
    }

    #[test]
    fn invalid_requests_are_rejected_before_admission() {
        let pipeline = Pipeline::new(base_config()).unwrap();
        // Unknown workload name.
        match pipeline.submit(&JobRequest::named("warp", Mode::Seq)) {
            Err(SubmitError::Rejected { reason }) => {
                assert!(reason.contains("unknown workload: warp"), "{reason}");
                assert!(reason.contains("primes"), "reason lists registered names: {reason}");
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        // Out-of-schema parameter.
        let req = JobRequest::parse("primes(frobnicate=1) seq").unwrap();
        match pipeline.submit(&req) {
            Err(SubmitError::Rejected { reason }) => {
                assert!(reason.contains("unknown parameter"), "{reason}")
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        // Mistyped parameter value.
        let req = JobRequest::parse("primes(n=banana) seq").unwrap();
        match pipeline.submit(&req) {
            Err(SubmitError::Rejected { reason }) => {
                assert!(reason.contains("bad value for param n"), "{reason}")
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        // Rejections never touched the queue.
        assert_eq!(pipeline.ingress().pending(), 0);
        let snap = pipeline.metrics().snapshot();
        assert_eq!(snap.counters["ingress.rejected"], 3);
        assert_eq!(snap.counters.get("ingress.admitted"), None);
        // A well-formed param request still runs (and its params bind).
        let req = JobRequest::parse("primes(n=100) par(2)").unwrap();
        let res = pipeline.run(&req).unwrap();
        assert!(res.verified);
        match res.detail {
            crate::coordinator::ResultDetail::Primes { count, largest } => {
                assert_eq!(count, 25); // π(100)
                assert_eq!(largest, 97);
            }
            other => panic!("wrong detail kind: {other:?}"),
        }
    }

    #[test]
    fn deadline_param_is_reserved_typed_and_accepted_everywhere() {
        let pipeline = Pipeline::new(base_config()).unwrap();
        // Every workload accepts the reserved key without declaring it;
        // a generous deadline never fires for a fast job.
        let req = JobRequest::parse("primes(n=100,deadline_ms=60000) par(2)").unwrap();
        let res = pipeline.run(&req).unwrap();
        assert!(res.verified);
        // Mistyped values die at validation, not on a runner.
        let req = JobRequest::parse("primes(deadline_ms=soon) seq").unwrap();
        match pipeline.submit(&req) {
            Err(SubmitError::Rejected { reason }) => {
                assert!(reason.contains("bad value for param deadline_ms"), "{reason}")
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        let snap = pipeline.metrics().snapshot();
        assert_eq!(snap.counters.get("jobs.timed_out"), None);
    }

    #[test]
    fn ticket_wait_timeout_gives_up_then_succeeds() {
        let pipeline = Pipeline::new(base_config()).unwrap();
        pipeline.ingress().set_runner_hold(0, true);
        let ticket = pipeline.submit(&primes_req()).unwrap();
        // Held: the bounded wait returns None and the ticket stays live.
        assert!(ticket.wait_timeout(Duration::from_millis(30)).is_none());
        assert!(!ticket.is_ready());
        pipeline.ingress().set_runner_hold(0, false);
        let res = ticket
            .wait_timeout(Duration::from_secs(30))
            .expect("released job finishes well within the bound")
            .unwrap();
        assert!(res.verified);
    }

    #[test]
    fn breaker_opens_after_threshold_and_quarantines() {
        let metrics = crate::metrics::MetricsRegistry::new();
        let breaker = Breaker::new(3);
        assert!(!breaker.note_panic("faulty", &metrics));
        assert!(!breaker.is_open("faulty"));
        // A success between panics resets the consecutive streak.
        breaker.note_ok("faulty");
        assert!(!breaker.note_panic("faulty", &metrics));
        assert!(!breaker.note_panic("faulty", &metrics));
        assert!(breaker.note_panic("faulty", &metrics), "third consecutive panic opens");
        assert!(breaker.is_open("faulty"));
        // Open is sticky: further panics and oks change nothing.
        assert!(!breaker.note_panic("faulty", &metrics));
        breaker.note_ok("faulty");
        assert!(breaker.is_open("faulty"));
        // Per workload, and visible as a gauge.
        assert!(!breaker.is_open("primes"));
        assert_eq!(metrics.snapshot().gauges["breaker.faulty.open"], 1);
        // Threshold 0 = disabled entirely.
        let off = Breaker::new(0);
        assert!(!off.note_panic("w", &metrics));
        assert!(!off.is_open("w"));
    }

    #[test]
    fn reaper_trips_expired_tokens_and_drop_deregisters() {
        let reaper = Reaper::new();
        let thread = {
            let reaper = Arc::clone(&reaper);
            std::thread::spawn(move || reaper.run())
        };
        // An expired deadline trips its token.
        let tripped = CancelToken::new();
        let guard = Arc::clone(&reaper)
            .register(Instant::now() + Duration::from_millis(10), tripped.clone());
        wait_until("deadline fires", || tripped.is_cancelled());
        drop(guard);
        // A deregistered (finished-first) entry never trips.
        let survivor = CancelToken::new();
        let guard = Arc::clone(&reaper)
            .register(Instant::now() + Duration::from_millis(40), survivor.clone());
        drop(guard);
        std::thread::sleep(Duration::from_millis(80));
        assert!(!survivor.is_cancelled(), "drop must deregister before the deadline");
        reaper.close();
        thread.join().unwrap();
    }

    #[test]
    fn shutdown_drains_queued_jobs_before_returning() {
        let mut cfg = base_config();
        cfg.queue_depth = 8;
        let pipeline = Pipeline::new(cfg).unwrap();
        pipeline.ingress().set_runner_hold(0, true);
        let tickets: Vec<JobTicket> =
            (0..3).map(|_| pipeline.submit(&primes_req()).unwrap()).collect();
        assert!(tickets.iter().all(|t| !t.is_ready()));
        // Dropping the last handle shuts the ingress down; queued jobs
        // are executed (holds cleared), not abandoned.
        drop(pipeline);
        for t in &tickets {
            let res = t.wait().unwrap();
            assert!(res.verified);
        }
    }
}

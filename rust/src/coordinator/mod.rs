//! The coordinator: L3's service layer.
//!
//! The paper's contribution is the stream/future construct itself, so the
//! coordinator is the thin-but-real system around it: a [`Pipeline`] that
//! owns the configuration, the optional PJRT engine, and the metrics
//! registry; a router ([`Pipeline::run`]) that maps `(workload, mode)`
//! requests onto the algorithm implementations with the right evaluation
//! strategy; and a [`serve`] line-protocol request loop (the `sfut serve`
//! subcommand) so workloads can be driven externally.
//!
//! Every run executes on a dedicated driver thread with the configured
//! stack size (deep Lazy filter chains need it), with per-stage timing
//! published to the metrics registry.

mod job;
mod router;
mod server;
mod tcp;

pub use job::{JobRequest, JobResult, ResultDetail};
pub use router::Pipeline;
pub use server::serve;
pub use tcp::TcpServer;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, Mode, Workload};

    fn small_config() -> Config {
        let mut cfg = Config::default();
        cfg.primes_n = 500;
        cfg.fateman_degree = 3;
        cfg.chunk_size = 16;
        cfg.use_kernel = false; // unit tests stay kernel-independent
        cfg
    }

    #[test]
    fn pipeline_runs_every_workload_seq() {
        let pipeline = Pipeline::new(small_config()).unwrap();
        for w in Workload::ALL {
            let res = pipeline.run(&JobRequest { workload: w, mode: Mode::Seq }).unwrap();
            assert!(res.verified, "{} failed verification", w.name());
            assert!(res.seconds >= 0.0);
        }
    }

    #[test]
    fn pipeline_runs_every_workload_par2() {
        let pipeline = Pipeline::new(small_config()).unwrap();
        for w in Workload::ALL {
            let res =
                pipeline.run(&JobRequest { workload: w, mode: Mode::Par(2) }).unwrap();
            assert!(res.verified, "{} failed verification", w.name());
        }
    }

    #[test]
    fn primes_detail_counts() {
        let pipeline = Pipeline::new(small_config()).unwrap();
        let res = pipeline
            .run(&JobRequest { workload: Workload::Primes, mode: Mode::Seq })
            .unwrap();
        match res.detail {
            ResultDetail::Primes { count, largest } => {
                assert_eq!(count, 95); // π(500)
                assert_eq!(largest, 499);
            }
            _ => panic!("wrong detail kind"),
        }
    }

    #[test]
    fn poly_detail_counts() {
        let pipeline = Pipeline::new(small_config()).unwrap();
        let res = pipeline
            .run(&JobRequest { workload: Workload::Stream, mode: Mode::Par(2) })
            .unwrap();
        match res.detail {
            ResultDetail::Poly { terms, .. } => {
                // (1+x+y+z+t)^3 · ((1+x+y+z+t)^3 + 1) over 4 vars:
                // support of degree-6 expansion = C(10,4) = 210.
                assert_eq!(terms, 210);
            }
            _ => panic!("wrong detail kind"),
        }
    }

    #[test]
    fn metrics_accumulate_across_runs() {
        let pipeline = Pipeline::new(small_config()).unwrap();
        let req = JobRequest { workload: Workload::Primes, mode: Mode::Seq };
        pipeline.run(&req).unwrap();
        pipeline.run(&req).unwrap();
        let snap = pipeline.metrics().snapshot();
        assert_eq!(snap.counters["jobs.completed"], 2);
        assert!(snap.timers.contains_key("job.primes.seq"));
    }

    #[test]
    fn strict_mode_works_as_control() {
        let pipeline = Pipeline::new(small_config()).unwrap();
        let res = pipeline
            .run(&JobRequest { workload: Workload::Stream, mode: Mode::Strict })
            .unwrap();
        assert!(res.verified);
    }
}

//! The coordinator: L3's service layer — sharded, queued, and
//! future-fronted for concurrent traffic.
//!
//! The paper's contribution is the stream/future construct itself, so the
//! coordinator is the thin-but-real system around it: a [`Pipeline`] that
//! owns the configuration, the optional PJRT engine, the metrics
//! registry, a [`ShardSet`] of executor-pool groups, and the staged
//! ingress; and a [`serve`] line-protocol request loop (the `sfut
//! serve` subcommand, stdio or TCP via [`TcpServer`]) so workloads can be
//! driven externally.
//!
//! Request flow — four stages, every job, every entry point:
//!
//! 1. **Admit** — [`Pipeline::submit`] places the request in a bounded
//!    MPMC admission queue and returns a [`JobTicket`] *immediately*.
//!    The bound is `Config::queue_depth` (jobs admitted but not yet
//!    executing); at the bound the configured admission policy
//!    (`Config::admission` = block | shed | timeout(ms)) applies —
//!    backpressure is explicit, not an unbounded thread pile-up. The
//!    ticket is built on the same lock-free [`Fut`](crate::susp::Fut)
//!    state machine as the paper's stream cells: callers
//!    `and_then`/`bind` continuations on results, or
//!    [`JobTicket::wait`] synchronously.
//! 2. **Route** — a small dispatcher pool hands each admitted job to a
//!    shard via [`ShardSet::route`] (workload-affinity hash,
//!    least-loaded fallback; see [`shard`]'s docs), lease in hand, onto
//!    that shard's run queue.
//! 3. **Execute** — per-shard runner threads (big workload stacks,
//!    `Config::shard_parallelism` per shard) drain their own queue
//!    first; idle runners steal whole queued jobs from any shard whose
//!    queue depth exceeds `Config::migrate_threshold` — cross-shard
//!    migration, surfacing as `shard.<id>.migrated_in/out`. `par(k)`
//!    jobs draw a warm, reusable `k`-worker pool from their shard, and
//!    chunked workloads size blocks adaptively by default
//!    ([`crate::config::ChunkPolicy`]) with the probe cost memoized per
//!    (shard, workload).
//! 4. **Report** — per-stage timing, `shard.<id>.*` executor gauges,
//!    ingress counters (`ingress.submitted/shed/timed_out/migrated`,
//!    `ingress.queue_depth`), and the job's shard / steal / queue-wait /
//!    migration fields land in the metrics registry and the
//!    [`JobResult`] line (`shard=… steals=… queue_wait=… migrated=…`);
//!    the runner fulfills the ticket, firing registered continuations.
//!
//! [`Pipeline::run`] survives as the synchronous veneer (`submit` +
//! `wait`), so CLI one-shots and tests keep their pre-ingress semantics
//! under the default `block` policy.
//!
//! # Writing a workload plugin
//!
//! The coordinator carries **no per-workload code**: requests name a
//! workload, the [`Pipeline`]'s
//! [`WorkloadRegistry`](crate::workload::WorkloadRegistry) resolves it,
//! and the plugin does the rest. To add a scenario:
//!
//! 1. **Implement
//!    [`StreamWorkload`](crate::workload::StreamWorkload)**. Write the
//!    algorithm once, generic over `E: Eval`, as an
//!    [`EvalBody`](crate::workload::EvalBody); `run` dispatches it with
//!    [`WorkloadCtx::run_mode`](crate::workload::WorkloadCtx::run_mode)
//!    so `seq`/`strict`/`par(k)` all execute the same code — the
//!    paper's monad substitution, per request. Declare parameters as
//!    [`ParamSpec`](crate::workload::ParamSpec)s (they arrive as typed
//!    [`Params`](crate::workload::Params), already schema-checked) and
//!    make `verify` recompute an *independent* oracle for the same
//!    effective parameters.
//! 2. **Register it**: build a registry with
//!    `WorkloadRegistry::builtin()` (or `::empty()`), `register` your
//!    plugin, and construct the coordinator with
//!    [`Pipeline::with_registry`]. Nothing else changes — routing
//!    (affinity hashes the *name*), the serve/TCP protocol
//!    (`run your_workload(k=v) par(2)`, the `workloads` listing), the
//!    conformance suite, and the bench harness all pick the plugin up
//!    from the registry.
//! 3. **Draw resources from the ctx**, never globally: warm `par(k)`
//!    pools via `ctx.executor(k)`, memoized chunk-probe costs via
//!    `ctx.cost_cache(...)`, block backends via
//!    `ctx.multiplier`/`ctx.siever`, configured sizes via `ctx.sizes`.
//!    That keeps plugins shard-warm under the coordinator and fully
//!    testable outside it
//!    ([`LocalResources`](crate::workload::LocalResources)).
//!
//! `workload::extra` (`fib`, `msort`) is the worked example: two
//! scenarios shipped against this API alone, with zero coordinator
//! edits.
//!
//! # Failure semantics
//!
//! Every submitted job resolves to **exactly one terminal outcome**, and
//! every failure reaches the wire as a machine-parseable `err` line.
//! The grammar below is stable — tools may match on it:
//!
//! * `err rejected workload=<spec> mode=<mode> reason: <text>` —
//!   refused at submit time, before the job occupied queue capacity:
//!   unknown workload, schema/validation failure, or
//!   `reason: breaker open: workload <name> quarantined after repeated
//!   panics` when the per-workload circuit breaker is open.
//! * `err admission=shed workload=<spec> …` /
//!   `err admission=timeout …` / `err admission=closed …` — the bounded
//!   admission queue applied its configured policy (shed | timeout(ms))
//!   or the pipeline is shutting down.
//! * `err panicked workload=<spec> mode=<mode> reason=<text>` — the
//!   plugin panicked on its final delivery attempt. The runner thread
//!   survives (`catch_unwind`); `reason` is the panic payload and is
//!   always the **last** field because it may contain spaces.
//! * `err timeout workload=<spec> mode=<mode> deadline_ms=<n>` — the
//!   job exceeded its deadline (`deadline_ms` wire param, falling back
//!   to `Config::deadline_ms`) on its final attempt; the reaper tripped
//!   the job's [`CancelToken`](crate::susp::CancelToken) and the
//!   cooperative checkpoints unwound it.
//! * `err timeout ticket=<id> waited_ms=<n>` — a serve-protocol `wait`
//!   gave up at the server-side cap; the ticket stays addressable and
//!   can be waited again.
//! * `err closed ticket=<id>` — session drain: the server is shutting
//!   down while this `wait` was parked. Emitted as the final line after
//!   a bounded grace in which a completing job still delivers its real
//!   result.
//! * `err job ticket abandoned: promise dropped before completion` —
//!   the executing runner died without fulfilling the ticket (only
//!   reachable via injected runner faults); the promise drop-guard
//!   resolved the ticket rather than leaving the waiter parked.
//!
//! Retry/breaker state machine: **transient** failures (panic, timeout)
//! are retried up to `Config::retry_max` times, each attempt re-leased
//! onto the *next* shard with exponential backoff
//! (`Config::retry_backoff_ms`, doubling, capped at 5 s); validation
//! rejects and wrong-result verifications are **not** transient and
//! never retry. Independently, `Config::breaker_threshold` consecutive
//! panics of one workload open that workload's circuit breaker
//! (`breaker.<name>.open` gauge = 1): further submissions are rejected
//! up front — without occupying queue capacity — for the pipeline's
//! lifetime. Counters: `jobs.panicked`, `jobs.timed_out`, `jobs.retried`
//! (per attempt), `ingress.runner_recovered`.
//!
//! # Wire protocol
//!
//! TCP listeners speak one of two wire protocols, chosen per listener
//! (`Config::wire` = `framed` | `text`, `--wire` flag, `SFUT_WIRE`
//! env). Both expose the same four operations and the same failure
//! taxonomy above; the **text** protocol (newline-delimited commands,
//! one blocking thread per session) is the compatibility baseline, the
//! **framed** protocol is the event-loop ingress: a pool of reactor
//! threads multiplexes every session, and job completion wakes the
//! owning reactor through the same [`Fut`](crate::susp::Fut)
//! promise/callback path the tickets are built on — no thread parked
//! per in-flight `wait`.
//!
//! ## Reactor pool
//!
//! The framed listener runs `Config::reactors` event-loop threads
//! (`--reactors`, `SFUT_REACTORS`; 0 = auto from cores), each with its
//! own readiness backend, self-pipe waker, and session table:
//!
//! * **Pinning** — a connection is adopted by exactly one reactor at
//!   accept and stays there for its lifetime. Session state (decode
//!   buffer, ticket table, write queue) is therefore single-threaded,
//!   and a parked `wait`'s completion callback wakes precisely the
//!   reactor that owns the session — per-reactor wakers never contend.
//! * **Accept fanout** — on Linux each reactor owns its own listener in
//!   an `SO_REUSEPORT` group and the kernel spreads connections with
//!   zero in-process coordination; elsewhere (or with
//!   `Config::reuseport = false`, which tests use for determinism)
//!   reactor 0 accepts and deals fds round-robin to per-reactor
//!   inboxes, waking the target.
//! * **Poller selection** — readiness is a trait with two backends
//!   (`Config::poller` = `poll | epoll | auto`; `--poller`,
//!   `SFUT_POLLER`): the portable poll(2) scan, O(sessions) per wakeup
//!   and kept as the A/B baseline, and Linux epoll, O(ready) per
//!   wakeup. `auto` picks epoll on Linux, poll elsewhere.
//!
//! Observability: per-reactor `wire.<r>.sessions` / `wire.<r>.*`
//! gauges and counters shadow the pool-wide `wire.*` totals, whose
//! meaning is unchanged from the single-reactor design — counter
//! reconciliation holds under any reactor count, and the per-reactor
//! split is what the session-pinning tests assert against.
//!
//! ## Frame layout
//!
//! A connection opens with a 5-byte preamble: the magic `b"SFUT"`
//! followed by a `u8` protocol version (currently `1`). The server
//! answers with a `Hello` frame echoing the version it speaks. After
//! the handshake the stream is a sequence of frames:
//!
//! ```text
//! +---------------+--------+-------------------------+
//! | u32 LE length | u8 kind| payload (length bytes)  |
//! +---------------+--------+-------------------------+
//! ```
//!
//! `length` counts only the payload and is capped at
//! [`frame::MAX_FRAME_LEN`]; an oversized header or an unknown kind is
//! a protocol error — the server sends one `Err` frame and closes.
//!
//! ## Frame kinds
//!
//! | kind | #  | dir | payload |
//! |------|----|-----|---------|
//! | `Submit` | 1 | c→s | UTF-8 request spec, e.g. `primes(n=500) par(2)` |
//! | `Wait` | 2 | c→s | `u64` LE ticket id |
//! | `Poll` | 3 | c→s | `u64` LE ticket id |
//! | `Workloads` | 4 | c→s | empty |
//! | `Hello` | 16 | s→c | `[version]` |
//! | `Ticket` | 17 | s→c | `u64` LE id + `u8` state (0 empty, 1 running, 2 ready, 3 panicked) |
//! | `Result` | 18 | s→c | `u64` LE id + UTF-8 `ok …` result line |
//! | `Err` | 19 | s→c | `u64` LE id (0 = no ticket) + UTF-8 err line |
//! | `WorkloadsReply` | 20 | s→c | UTF-8 workload listing |
//!
//! Submits may be pipelined: many `Submit` frames in one write produce
//! `Ticket` replies in submission order. When the admission queue is
//! full under the block/timeout policy the reactor *defers* the
//! session's submit (retrying each tick) instead of blocking the event
//! loop; shed/timeout/closed render the same `err admission=…` lines as
//! the text protocol, carried in `Err` frames. A session whose write
//! buffer exceeds the high-water mark stops being read until it drains
//! (`wire.read_paused`), so a non-draining client backs pressure up
//! into admission rather than buffering unboundedly.
//!
//! ## Versioning
//!
//! The version byte bumps on any breaking change to the preamble,
//! header, or an existing kind's payload; adding a new kind is
//! non-breaking (clients must ignore kinds they don't know only if
//! they negotiated a newer version — today's server rejects unknown
//! *client* kinds). A mismatched magic or version yields one `Err`
//! frame (`bad connection magic` / `unsupported protocol version`) and
//! a close, so misdirected text clients fail fast and loudly.
//!
//! ## Text-protocol mapping
//!
//! `Submit` ↔ `run <spec>` / bare spec line, `Wait` ↔ `wait <id>`,
//! `Poll` ↔ `poll <id>`, `Workloads` ↔ `workloads`. A `Result` payload
//! is exactly the text `ok …` line; an `Err` payload is exactly one
//! line of the failure taxonomy above — both protocols share a single
//! formatting site, so the grammars cannot drift.
//!
//! # Metrics taxonomy
//!
//! Every metric the coordinator emits belongs to one of these families
//! (`sfut lint` rejects names outside them — extend this list *first*
//! when adding a family):
//!
//! * `jobs.<event>` — job lifecycle counters: `jobs.submitted`,
//!   `jobs.completed`, `jobs.failed`, `jobs.panicked`,
//!   `jobs.timed_out`, `jobs.retried`, `jobs.rejected`, and the
//!   `jobs.queue_wait_ms` / `jobs.exec_ms` timers.
//! * `ingress.<event>` — admission/staging counters and gauges:
//!   `ingress.submitted`, `ingress.shed`, `ingress.timed_out`,
//!   `ingress.migrated`, `ingress.queue_depth`,
//!   `ingress.runner_recovered`.
//! * `breaker.<workload>.open` — per-workload circuit-breaker gauge
//!   (1 = open).
//! * `shard.<id>.<stat>` — per-shard executor and queue stats:
//!   `run_queue_depth`, `jobs_run`, `migrated_in`, `migrated_out`,
//!   `steals`, `jobs_migrated_per_steal`, …
//! * `wire.<stat>` — pool-wide wire/ingress totals: `wire.sessions`,
//!   `wire.frames_in`, `wire.frames_out`, `wire.read_paused`,
//!   `wire.protocol_errors`, …
//! * `wire.<reactor>.<stat>` — the per-reactor shadow of the same
//!   stats (see "Reactor pool" above).
//! * `job.<workload>.<mode>` — per-(workload, mode) execution timers.
//!
//! # Configuration reference
//!
//! Canonical `Config` keys, exactly as accepted by `--set k=v`, config
//! files, and the serve protocol (`sfut lint` keeps this list, the
//! `--help` text, and the `config/mod.rs` match in sync):
//!
//! * Workload sizing: `primes_n`, `fateman_vars`, `fateman_degree`,
//!   `big_factor`, `samples`, `warmup`, `scale`.
//! * Chunking: `chunk_size`, `chunk_policy`.
//! * Sharding/ingress: `shards`, `shard_parallelism`, `queue_depth`,
//!   `admission`, `dispatchers`, `migrate_threshold`.
//! * Fault handling: `deadline_ms`, `retry_max`, `retry_backoff_ms`,
//!   `breaker_threshold`.
//! * Engine/runtime: `artifacts_dir`, `use_kernel`, `stack_size`,
//!   `deque`.
//! * Wire/ingress backends: `wire`, `poller`, `reactors`, `reuseport`.
//!
//! # Correctness tooling
//!
//! The lock-free structures under the coordinator (the Chase–Lev deque
//! feeding every shard's executor, the `Fut` ticket cells) are model-
//! checked by the deterministic interleaving explorer in
//! [`crate::testkit::model`] (`cargo test --features model --test
//! model_check`; failing schedules print a seed replayable with
//! `SFUT_MODEL_SEED`). The invariants prose can't enforce — SAFETY
//! comments on every unsafe block, the metric and config lists above,
//! `err`-line parsing through `testkit::wire` — are enforced by
//! `sfut lint` as a blocking CI step, and CI's sanitizer job runs Miri
//! and ThreadSanitizer over the same structures nightly. See the
//! "Correctness tooling" section in the crate docs ([`crate`]) for the
//! full tour.

mod ingress;
mod job;
pub mod frame;
#[cfg(unix)]
mod poller;
#[cfg(unix)]
mod reactor;
#[cfg(unix)]
mod reuseport;
mod router;
mod server;
pub mod shard;
mod tcp;

pub use frame::{Frame, FrameDecoder, FrameError, FrameKind};
pub use ingress::{Ingress, JobTicket, SubmitError, TicketValue};
pub use job::{JobRequest, JobResult, ResultDetail};
pub use router::Pipeline;
pub use server::{serve, serve_with_stop};
pub use shard::{Shard, ShardLease, ShardSet};
pub use tcp::TcpServer;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, Mode};

    fn small_config() -> Config {
        let mut cfg = Config::default();
        cfg.primes_n = 500;
        cfg.fateman_degree = 3;
        cfg.chunk_size = 16;
        cfg.scale = 0.25; // shrinks fib/msort defaults for test speed
        cfg.use_kernel = false; // unit tests stay kernel-independent
        cfg
    }

    #[test]
    fn pipeline_runs_every_registered_workload_seq() {
        let pipeline = Pipeline::new(small_config()).unwrap();
        for w in pipeline.registry().names() {
            let res = pipeline.run(&JobRequest::named(&w, Mode::Seq)).unwrap();
            assert!(res.verified, "{w} failed verification");
            assert!(res.seconds >= 0.0);
        }
    }

    #[test]
    fn pipeline_runs_every_registered_workload_par2() {
        let pipeline = Pipeline::new(small_config()).unwrap();
        for w in pipeline.registry().names() {
            let res = pipeline.run(&JobRequest::named(&w, Mode::Par(2))).unwrap();
            assert!(res.verified, "{w} failed verification");
        }
    }

    #[test]
    fn primes_detail_counts() {
        let mut cfg = small_config();
        cfg.scale = 1.0; // pin primes_n at the configured 500
        let pipeline = Pipeline::new(cfg).unwrap();
        let res = pipeline.run(&JobRequest::named("primes", Mode::Seq)).unwrap();
        match res.detail {
            ResultDetail::Primes { count, largest } => {
                assert_eq!(count, 95); // π(500)
                assert_eq!(largest, 499);
            }
            _ => panic!("wrong detail kind"),
        }
    }

    #[test]
    fn poly_detail_counts() {
        let mut cfg = small_config();
        cfg.scale = 1.0; // pin fateman_degree at the configured 3
        let pipeline = Pipeline::new(cfg).unwrap();
        let res = pipeline.run(&JobRequest::named("stream", Mode::Par(2))).unwrap();
        match res.detail {
            ResultDetail::Poly { terms, .. } => {
                // (1+x+y+z+t)^3 · ((1+x+y+z+t)^3 + 1) over 4 vars:
                // support of degree-6 expansion = C(10,4) = 210.
                assert_eq!(terms, 210);
            }
            _ => panic!("wrong detail kind"),
        }
    }

    #[test]
    fn metrics_accumulate_across_runs() {
        let pipeline = Pipeline::new(small_config()).unwrap();
        let req = JobRequest::named("primes", Mode::Seq);
        pipeline.run(&req).unwrap();
        pipeline.run(&req).unwrap();
        let snap = pipeline.metrics().snapshot();
        assert_eq!(snap.counters["jobs.completed"], 2);
        assert!(snap.timers.contains_key("job.primes.seq"));
        // Per-shard executor stats are published after every job.
        assert!(snap.gauges.contains_key("shard.0.tasks_executed"));
        assert!(snap.gauges.contains_key("shard.0.jobs_routed"));
        // The synchronous path goes through the staged ingress too.
        assert_eq!(snap.counters["ingress.submitted"], 2);
        assert_eq!(snap.counters["ingress.admitted"], 2);
        assert_eq!(snap.gauges["ingress.queue_depth"], 0);
        assert!(snap.gauges.contains_key("shard.0.migrated_in"));
    }

    #[test]
    fn run_reports_queue_wait_and_migration_fields() {
        let pipeline = Pipeline::new(small_config()).unwrap();
        let res = pipeline.run(&JobRequest::named("primes", Mode::Seq)).unwrap();
        assert!(res.queue_wait >= 0.0);
        assert!(!res.migrated, "an uncontended run must not migrate");
        assert!(res.render_line().contains("queue_wait="));
    }

    #[test]
    fn jobs_report_their_shard_and_respect_affinity() {
        let mut cfg = small_config();
        cfg.shards = 2;
        let pipeline = Pipeline::new(cfg).unwrap();
        let home = pipeline.shards().home_index("primes");
        let req = JobRequest::named("primes", Mode::Par(2));
        for _ in 0..3 {
            let res = pipeline.run(&req).unwrap();
            assert!(res.verified);
            assert_eq!(res.shard, home, "sequential jobs must stick to the home shard");
        }
        assert_eq!(pipeline.shards().shard(home).jobs_routed(), 3);
        // The shard's pool was reused, not respawned: one pool executed
        // every task of all three jobs.
        let stats = pipeline.shards().shard(home).stats();
        assert!(stats.tasks_executed > 0);
    }

    #[test]
    fn fixed_chunk_policy_still_verifies() {
        let mut cfg = small_config();
        cfg.chunk_policy = crate::config::ChunkPolicy::Fixed;
        let pipeline = Pipeline::new(cfg).unwrap();
        for w in ["chunked", "primes_chunked"] {
            let res = pipeline.run(&JobRequest::named(w, Mode::Par(2))).unwrap();
            assert!(res.verified, "{w} failed under fixed chunking");
        }
    }

    #[test]
    fn strict_mode_works_as_control() {
        let pipeline = Pipeline::new(small_config()).unwrap();
        let res = pipeline.run(&JobRequest::named("stream", Mode::Strict)).unwrap();
        assert!(res.verified);
    }

    #[test]
    fn empty_registry_is_refused() {
        let err = Pipeline::with_registry(
            small_config(),
            crate::workload::WorkloadRegistry::empty(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("registry is empty"), "{err}");
    }
}

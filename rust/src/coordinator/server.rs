//! Line-protocol request server (the `sfut serve` subcommand).
//!
//! Protocol (one request per line):
//!
//! ```text
//! run <spec> <mode>          → ok workload=... seconds=... | err <message>
//! submit <spec> <mode>       → ticket id=N               | err admission=...
//! wait <id>                  → ok workload=... (blocks, bounded)
//!                              | err <message>
//!                              | err timeout ticket=N waited_ms=M (cap hit;
//!                                ticket stays addressable)
//!                              | err closed ticket=N (server shutting down;
//!                                session ends)
//! poll <id>                  → ticket id=N state=<empty|running|ready|panicked>
//! workloads                  → one line per registered workload (name,
//!                              param schema, description), terminated by "."
//! metrics                    → multi-line snapshot, terminated by "."
//! config                     → one line per effective config field
//! help                       → command summary
//! quit                       → closes the session
//! ```
//!
//! `<spec>` is a registry name with optional parameters —
//! `primes`, `fib(n=64)`, `stream(big_factor=7,chunked=true)` — the
//! open plugin world on the wire. Unknown names and out-of-schema
//! params answer well-formed `err rejected …` lines before any queue
//! capacity is taken.
//!
//! `run` is the synchronous veneer (admit + wait in one step); `submit`
//! exposes the staged ingress directly — the session gets a [`JobTicket`]
//! handle back *before* the job runs, can pipeline more submissions, and
//! collects results with `wait`. When the bounded admission queue is full
//! the configured policy answers: `err admission=shed …` /
//! `err admission=timeout …` lines (well-formed, machine-parseable)
//! instead of an ok line.
//!
//! Written against `BufRead`/`Write` so tests drive it with in-memory
//! buffers; `main.rs` connects it to stdin/stdout.

use std::collections::BTreeMap;
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::ingress::JobTicket;
use super::job::{JobRequest, JobResult};
use super::router::Pipeline;
use crate::susp::FutState;

/// Most tickets a session keeps addressable at once. A resolved ticket
/// pins its full `JobResult` (and `Fut` cell), so an unbounded table
/// would grow for the life of a long-running monitoring session; past
/// the cap the oldest resolved tickets are released (waiting them again
/// answers `err ticket released`).
pub(crate) const MAX_SESSION_TICKETS: usize = 1024;

/// Server-side cap on one `wait <id>` command. A generous bound — far
/// beyond any sane job — that exists so a session blocked on a wedged
/// job eventually gets a well-formed `err timeout ticket=…` line instead
/// of holding the connection forever. The ticket stays addressable; the
/// client may `wait`/`poll` it again.
const SERVE_WAIT_CAP: Duration = Duration::from_secs(600);

/// Poll slice for `wait`: how often a parked waiter re-checks the
/// session stop flag (shutdown drain latency, not result latency — a
/// completing job wakes the waiter immediately).
const WAIT_POLL_SLICE: Duration = Duration::from_millis(50);

/// Grace given to a waited job when the stop flag rises: a result that
/// lands within it still delivers; past it the waiter gets the final
/// `err closed` line. Comfortably inside the TCP server's session drain
/// window.
const STOP_DRAIN_GRACE: Duration = Duration::from_secs(1);

pub(crate) fn state_label(state: FutState) -> &'static str {
    match state {
        FutState::Empty => "empty",
        FutState::Running => "running",
        FutState::Ready => "ready",
        FutState::Panicked => "panicked",
    }
}

// Single formatting site for every ticket-lifecycle `err` line, shared
// by the text protocol here and the framed reactor — the taxonomy
// documented in the module docs of [`crate::coordinator`] cannot drift
// per wire. (Admission/terminal-job errors already have theirs:
// `SubmitError::render_line` and the `execute_one` terminal messages.)

/// `wait` exceeded the server-side cap; the ticket stays addressable.
pub(crate) fn err_wait_timeout_line(id: u64, waited_ms: u128) -> String {
    format!("err timeout ticket={id} waited_ms={waited_ms}")
}

/// Server shutting down while a wait was parked on this ticket.
pub(crate) fn err_closed_line(id: u64) -> String {
    format!("err closed ticket={id}")
}

/// The ticket was released from the session table (past the cap).
pub(crate) fn err_released_line(id: u64) -> String {
    format!("err ticket released: {id}")
}

/// The `workloads` listing, one `workload name=… params=[…] …` line per
/// registered plugin, "."-terminated — shared by the text protocol and
/// the framed `Workloads` reply.
pub(crate) fn workloads_listing(pipeline: &Pipeline) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for w in pipeline.registry().iter() {
        let params: Vec<String> =
            w.params().iter().map(crate::workload::ParamSpec::render).collect();
        let params = if params.is_empty() { "-".to_string() } else { params.join(",") };
        let _ = writeln!(out, "workload name={} params=[{params}] {}", w.name(), w.describe());
    }
    out.push_str(".\n");
    out
}

/// Serve requests from `input`, writing responses to `output`, until
/// `quit` or EOF. Returns the number of jobs whose results were
/// delivered (via `run` or `wait`).
pub fn serve(pipeline: &Pipeline, input: impl BufRead, output: impl Write) -> Result<u64> {
    serve_with_stop(pipeline, input, output, &AtomicBool::new(false))
}

/// [`serve`] with a caller-owned stop flag (the TCP server's shutdown
/// signal). A session parked in `wait <id>` when the flag rises answers
/// the waiter with a final well-formed `err closed ticket=<id>` line,
/// flushes, and ends the session — in-flight waiters are never left
/// hanging on a half-dead connection during shutdown/drain.
pub fn serve_with_stop(
    pipeline: &Pipeline,
    input: impl BufRead,
    mut output: impl Write,
    stop: &AtomicBool,
) -> Result<u64> {
    let mut jobs = 0u64;
    // Tickets this session has submitted; ids are 1-based submission
    // order. A waited ticket stays addressable (wait is idempotent)
    // until the table exceeds [`MAX_SESSION_TICKETS`] and it is among
    // the oldest resolved entries released to make room.
    let mut tickets: BTreeMap<u64, JobTicket> = BTreeMap::new();
    let mut next_ticket: u64 = 1;
    for line in input.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (cmd, rest) = match line.split_once(char::is_whitespace) {
            Some((c, r)) => (c, r.trim()),
            None => (line, ""),
        };
        match cmd {
            "quit" | "exit" => break,
            "help" => {
                writeln!(
                    output,
                    "commands: run <spec> <mode> | submit <spec> <mode> | wait <id> | \
                     poll <id> | workloads | metrics | config | quit"
                )?;
                writeln!(
                    output,
                    "workloads: {} (spec = name[(k=v,...)]; `workloads` lists params)",
                    pipeline.registry().names().join(" ")
                )?;
                writeln!(output, "modes: seq strict par(N)")?;
            }
            "workloads" => {
                write!(output, "{}", workloads_listing(pipeline))?;
            }
            "config" => {
                writeln!(output, "{:#?}", pipeline.config())?;
            }
            "metrics" => {
                write!(output, "{}", pipeline.metrics().snapshot().render())?;
                writeln!(output, ".")?;
            }
            "run" => match JobRequest::parse(rest) {
                Ok(req) => match pipeline.submit(&req) {
                    Ok(ticket) => match ticket.wait() {
                        Ok(result) => {
                            jobs += 1;
                            writeln!(output, "{}", result.render_line())?;
                        }
                        Err(e) => writeln!(output, "err {e:#}")?,
                    },
                    Err(adm) => writeln!(output, "{}", adm.render_line(&req))?,
                },
                Err(e) => writeln!(output, "err {e}")?,
            },
            "submit" => match JobRequest::parse(rest) {
                Ok(req) => match pipeline.submit(&req) {
                    Ok(ticket) => {
                        let state = state_label(ticket.state());
                        let id = next_ticket;
                        next_ticket += 1;
                        tickets.insert(id, ticket);
                        release_oldest_resolved(&mut tickets, MAX_SESSION_TICKETS);
                        writeln!(output, "ticket id={id} state={state}")?;
                    }
                    Err(adm) => writeln!(output, "{}", adm.render_line(&req))?,
                },
                Err(e) => writeln!(output, "err {e}")?,
            },
            "wait" => match parse_ticket_id(rest, next_ticket) {
                Ok(id) => match tickets.get(&id) {
                    Some(ticket) => {
                        let started = Instant::now();
                        let mut answered = false;
                        loop {
                            if let Some(result) = ticket.wait_timeout(WAIT_POLL_SLICE) {
                                deliver(&mut output, &mut jobs, result)?;
                                answered = true;
                                break;
                            }
                            if stop.load(Ordering::Acquire) {
                                // Drain grace: a job about to finish
                                // still delivers its result.
                                if let Some(result) = ticket.wait_timeout(STOP_DRAIN_GRACE) {
                                    deliver(&mut output, &mut jobs, result)?;
                                    answered = true;
                                }
                                break;
                            }
                            if started.elapsed() >= SERVE_WAIT_CAP {
                                // The ticket survives — poll/wait again later.
                                writeln!(
                                    output,
                                    "{}",
                                    err_wait_timeout_line(id, started.elapsed().as_millis())
                                )?;
                                answered = true;
                                break;
                            }
                        }
                        if !answered {
                            // Shutdown drain: one final well-formed line,
                            // then end the session.
                            writeln!(output, "{}", err_closed_line(id))?;
                            output.flush()?;
                            return Ok(jobs);
                        }
                    }
                    None => writeln!(output, "{}", err_released_line(id))?,
                },
                Err(e) => writeln!(output, "err {e}")?,
            },
            "poll" => match parse_ticket_id(rest, next_ticket) {
                Ok(id) => match tickets.get(&id) {
                    Some(ticket) => {
                        let state = state_label(ticket.state());
                        writeln!(output, "ticket id={id} state={state}")?;
                    }
                    None => writeln!(output, "{}", err_released_line(id))?,
                },
                Err(e) => writeln!(output, "err {e}")?,
            },
            other => writeln!(output, "err unknown command: {other}")?,
        }
        output.flush()?;
    }
    Ok(jobs)
}

/// Keep the session's ticket table bounded: past the cap, drop the
/// oldest *resolved* tickets (their jobs are done and delivered; the
/// dropped handles release their `JobResult`s). Unresolved tickets are
/// never dropped — their count is already bounded by the admission
/// queue and the runners.
pub(crate) fn release_oldest_resolved(tickets: &mut BTreeMap<u64, JobTicket>, cap: usize) {
    while tickets.len() > cap {
        let Some(oldest_done) =
            tickets.iter().find(|(_, t)| t.is_ready()).map(|(&id, _)| id)
        else {
            return;
        };
        tickets.remove(&oldest_done);
    }
}

/// Write one waited outcome as its protocol line (`ok …` / `err …`).
fn deliver(output: &mut impl Write, jobs: &mut u64, result: Result<JobResult>) -> Result<()> {
    match result {
        Ok(result) => {
            *jobs += 1;
            writeln!(output, "{}", result.render_line())?;
        }
        Err(e) => writeln!(output, "err {e:#}")?,
    }
    Ok(())
}

fn parse_ticket_id(rest: &str, next_ticket: u64) -> Result<u64, String> {
    let id: u64 = rest
        .trim()
        .parse()
        .map_err(|_| format!("bad ticket id: {rest:?} (want a number from submit)"))?;
    if id == 0 || id >= next_ticket {
        return Err(format!(
            "unknown ticket: {id} ({} issued this session)",
            next_ticket - 1
        ));
    }
    Ok(id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AdmissionPolicy, Config};

    fn config() -> Config {
        let mut cfg = Config::default();
        cfg.primes_n = 200;
        cfg.fateman_degree = 2;
        cfg.use_kernel = false;
        cfg
    }

    fn pipeline() -> Pipeline {
        Pipeline::new(config()).unwrap()
    }

    fn drive_pipeline(p: &Pipeline, input: &str) -> (u64, String) {
        let mut out = Vec::new();
        let jobs = serve(p, input.as_bytes(), &mut out).unwrap();
        (jobs, String::from_utf8(out).unwrap())
    }

    fn drive(input: &str) -> (u64, String) {
        drive_pipeline(&pipeline(), input)
    }

    #[test]
    fn runs_jobs_and_reports() {
        let (jobs, out) = drive("run primes seq\nrun stream par(2)\nquit\n");
        assert_eq!(jobs, 2);
        assert!(out.contains("ok workload=primes mode=seq"));
        assert!(out.contains("ok workload=stream mode=par(2)"));
        assert!(out.contains("verified=true"));
        assert!(out.contains("shard="), "results must report their shard");
        assert!(out.contains("queue_wait="), "results must report queue wait");
    }

    #[test]
    fn submit_wait_roundtrip() {
        let (jobs, out) = drive("submit primes seq\npoll 1\nwait 1\nwait 1\nquit\n");
        // Waiting the same ticket twice re-delivers the result.
        assert_eq!(jobs, 2);
        assert!(out.contains("ticket id=1 state="), "{out}");
        let oks: Vec<_> = out.lines().filter(|l| l.starts_with("ok ")).collect();
        assert_eq!(oks.len(), 2, "{out}");
        assert!(oks[0].contains("verified=true"));
        // The poll line reports a lifecycle state.
        assert!(
            out.lines().any(|l| l.starts_with("ticket id=1 state=")
                && (l.ends_with("empty")
                    || l.ends_with("running")
                    || l.ends_with("ready")
                    || l.ends_with("panicked"))),
            "{out}"
        );
    }

    #[test]
    fn submissions_pipeline_ahead_of_waits() {
        let (jobs, out) =
            drive("submit primes seq\nsubmit primes_chunked par(2)\nwait 2\nwait 1\nquit\n");
        assert_eq!(jobs, 2);
        assert!(out.contains("ticket id=1"));
        assert!(out.contains("ticket id=2"));
        let oks: Vec<_> = out.lines().filter(|l| l.starts_with("ok ")).collect();
        assert_eq!(oks.len(), 2);
        // wait 2 answered first: results come back in wait order, not
        // submit order.
        assert!(oks[0].contains("workload=primes_chunked"), "{out}");
        assert!(oks[1].contains("workload=primes mode=seq"), "{out}");
    }

    #[test]
    fn bad_ticket_ids_get_err_lines() {
        let (jobs, out) = drive("wait 1\npoll 0\nsubmit primes seq\nwait two\nwait 1\nquit\n");
        assert_eq!(jobs, 1);
        assert_eq!(out.lines().filter(|l| l.starts_with("err")).count(), 3, "{out}");
        assert!(out.contains("unknown ticket"));
        assert!(out.contains("bad ticket id"));
    }

    #[test]
    fn shed_admission_renders_err_line() {
        let mut cfg = config();
        cfg.shards = 1;
        cfg.shard_parallelism = 1;
        cfg.queue_depth = 1;
        cfg.admission = AdmissionPolicy::Shed;
        let p = Pipeline::new(cfg).unwrap();
        // Gate the only shard so submissions pile up deterministically:
        // slot taken by the first submit, second sheds.
        p.ingress().set_runner_hold(0, true);
        let mut out = Vec::new();
        let jobs =
            serve(&p, "submit primes seq\nsubmit primes seq\n".as_bytes(), &mut out).unwrap();
        assert_eq!(jobs, 0);
        let out = String::from_utf8(out).unwrap();
        assert!(out.contains("ticket id=1"), "{out}");
        assert!(
            out.contains("err admission=shed workload=primes mode=seq queue_depth=1"),
            "{out}"
        );
        p.ingress().set_runner_hold(0, false);
    }

    #[test]
    fn ticket_table_releases_oldest_resolved_past_cap() {
        let mut cfg = config();
        // One shard, one runner: holding shard 0 provably parks all
        // execution, so the pending tickets below stay unresolved.
        cfg.shards = 1;
        cfg.shard_parallelism = 1;
        let p = Pipeline::new(cfg).unwrap();
        let mut tickets: BTreeMap<u64, JobTicket> = BTreeMap::new();
        for id in 1..=4u64 {
            let req = JobRequest::parse("primes seq").unwrap();
            let ticket = p.submit(&req).unwrap();
            ticket.wait().unwrap();
            tickets.insert(id, ticket);
        }
        // Cap 2: the two oldest resolved tickets are released, newest
        // survive, ids untouched.
        release_oldest_resolved(&mut tickets, 2);
        assert_eq!(tickets.len(), 2);
        assert!(tickets.contains_key(&3) && tickets.contains_key(&4));
        // Unresolved tickets are never dropped, even over the cap.
        p.ingress().set_runner_hold(0, true);
        let req = JobRequest::parse("primes seq").unwrap();
        tickets.insert(5, p.submit(&req).unwrap());
        tickets.insert(6, p.submit(&req).unwrap());
        tickets.insert(7, p.submit(&req).unwrap());
        release_oldest_resolved(&mut tickets, 1);
        assert!(
            tickets.values().all(|t| !t.is_ready()),
            "resolved released first, pending retained"
        );
        assert_eq!(tickets.len(), 3);
        p.ingress().set_runner_hold(0, false);
    }

    #[test]
    fn bad_requests_get_err_lines() {
        let (jobs, out) = drive("run nope seq\nrun primes warp\nfrobnicate\n");
        assert_eq!(jobs, 0);
        assert_eq!(out.lines().filter(|l| l.starts_with("err")).count(), 3);
    }

    #[test]
    fn metrics_command_renders_snapshot() {
        let (_, out) = drive("run primes seq\nmetrics\nquit\n");
        assert!(out.contains("jobs.completed"));
        assert!(out.lines().any(|l| l == "."));
    }

    #[test]
    fn help_lists_workloads_and_ticket_commands() {
        let (_, out) = drive("help\n");
        assert!(out.contains("stream_big"));
        assert!(out.contains("par(N)"));
        assert!(out.contains("submit"));
        assert!(out.contains("wait <id>"));
    }

    #[test]
    fn workloads_verb_lists_registry_with_schemas() {
        let (jobs, out) = drive("workloads\nquit\n");
        assert_eq!(jobs, 0);
        // One line per registered workload, "."-terminated like metrics.
        let p = pipeline();
        let lines: Vec<_> =
            out.lines().filter(|l| l.starts_with("workload name=")).collect();
        assert_eq!(lines.len(), p.registry().len(), "{out}");
        for name in ["primes", "stream_big", "fib", "msort"] {
            assert!(
                lines.iter().any(|l| l.contains(&format!("name={name} "))),
                "missing {name} in:\n{out}"
            );
        }
        // Param schemas ride along.
        assert!(out.contains("n:u32"), "{out}");
        assert!(out.contains("seed:u64"), "{out}");
        assert!(out.lines().any(|l| l == "."), "{out}");
    }

    #[test]
    fn params_travel_the_wire_and_reject_cleanly() {
        let (jobs, out) = drive(
            "run primes(n=100) par(2)\nrun primes(frobnicate=1) seq\n\
             run warp(n=3) seq\nsubmit fib(n=banana) seq\n\
             run msort(n=99999999999) seq\nquit\n",
        );
        assert_eq!(jobs, 1);
        // Params echo on the ok line (round-trip through render_line).
        assert!(out.contains("ok workload=primes(n=100) mode=par(2)"), "{out}");
        assert!(out.contains("primes=25"), "{out}");
        // Unknown param / workload / bad value / out-of-range: all
        // well-formed err lines.
        let errs: Vec<_> = out.lines().filter(|l| l.starts_with("err ")).collect();
        assert_eq!(errs.len(), 4, "{out}");
        assert!(out.contains("unknown parameter"), "{out}");
        assert!(out.contains("unknown workload: warp"), "{out}");
        assert!(out.contains("bad value for param n"), "{out}");
        assert!(out.contains("out of range for param n"), "{out}");
        assert!(
            errs.iter().all(|l| l.starts_with("err rejected workload=")),
            "rejections are machine-parseable: {out}"
        );
    }

    #[test]
    fn wait_answers_closed_line_when_stop_flag_rises() {
        let mut cfg = config();
        cfg.shards = 1;
        cfg.shard_parallelism = 1;
        let p = Pipeline::new(cfg).unwrap();
        // Park the only shard so the waited job can never resolve; the
        // pre-raised stop flag must drain the waiter with a final line.
        p.ingress().set_runner_hold(0, true);
        let stop = AtomicBool::new(true);
        let mut out = Vec::new();
        let jobs = serve_with_stop(
            &p,
            "submit primes seq\nwait 1\nrun primes seq\n".as_bytes(),
            &mut out,
            &stop,
        )
        .unwrap();
        let out = String::from_utf8(out).unwrap();
        assert_eq!(jobs, 0);
        assert!(out.contains("ticket id=1"), "{out}");
        assert!(out.contains("err closed ticket=1"), "{out}");
        // The session ended at the drain: the trailing run never answered.
        assert!(!out.contains("ok workload="), "{out}");
        p.ingress().set_runner_hold(0, false);
    }

    #[test]
    fn wait_still_delivers_resolved_results_under_stop() {
        let p = pipeline();
        let stop = AtomicBool::new(true);
        let mut out = Vec::new();
        // The job resolves promptly; a raised stop flag must not eat a
        // deliverable result.
        let jobs =
            serve_with_stop(&p, "submit primes seq\nwait 1\n".as_bytes(), &mut out, &stop)
                .unwrap();
        let out = String::from_utf8(out).unwrap();
        assert_eq!(jobs, 1, "{out}");
        assert!(out.contains("ok workload=primes"), "{out}");
        assert!(!out.contains("err closed"), "{out}");
    }

    #[test]
    fn eof_terminates_cleanly() {
        let (jobs, _) = drive("run primes seq\n");
        assert_eq!(jobs, 1);
    }

    #[test]
    fn blank_lines_ignored() {
        let (jobs, out) = drive("\n\nrun primes seq\n\n");
        assert_eq!(jobs, 1);
        assert_eq!(out.lines().count(), 1);
    }
}

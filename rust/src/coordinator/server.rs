//! Line-protocol request server (the `sfut serve` subcommand).
//!
//! Protocol (one request per line):
//!
//! ```text
//! run <workload> <mode>   → ok workload=... seconds=... | err <message>
//! metrics                 → multi-line snapshot, terminated by "."
//! config                  → one line per effective config field
//! help                    → command summary
//! quit                    → closes the session
//! ```
//!
//! Written against `BufRead`/`Write` so tests drive it with in-memory
//! buffers; `main.rs` connects it to stdin/stdout.

use std::io::{BufRead, Write};

use anyhow::Result;

use super::job::JobRequest;
use super::router::Pipeline;

/// Serve requests from `input`, writing responses to `output`, until
/// `quit` or EOF. Returns the number of jobs executed.
pub fn serve(pipeline: &Pipeline, input: impl BufRead, mut output: impl Write) -> Result<u64> {
    let mut jobs = 0u64;
    for line in input.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (cmd, rest) = match line.split_once(char::is_whitespace) {
            Some((c, r)) => (c, r.trim()),
            None => (line, ""),
        };
        match cmd {
            "quit" | "exit" => break,
            "help" => {
                writeln!(output, "commands: run <workload> <mode> | metrics | config | quit")?;
                writeln!(
                    output,
                    "workloads: {}",
                    crate::config::Workload::ALL.map(|w| w.name()).join(" ")
                )?;
                writeln!(output, "modes: seq strict par(N)")?;
            }
            "config" => {
                writeln!(output, "{:#?}", pipeline.config())?;
            }
            "metrics" => {
                write!(output, "{}", pipeline.metrics().snapshot().render())?;
                writeln!(output, ".")?;
            }
            "run" => match JobRequest::parse(rest) {
                Ok(req) => match pipeline.run(&req) {
                    Ok(result) => {
                        jobs += 1;
                        writeln!(output, "{}", result.render_line())?;
                    }
                    Err(e) => writeln!(output, "err {e:#}")?,
                },
                Err(e) => writeln!(output, "err {e}")?,
            },
            other => writeln!(output, "err unknown command: {other}")?,
        }
        output.flush()?;
    }
    Ok(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn pipeline() -> Pipeline {
        let mut cfg = Config::default();
        cfg.primes_n = 200;
        cfg.fateman_degree = 2;
        cfg.use_kernel = false;
        Pipeline::new(cfg).unwrap()
    }

    fn drive(input: &str) -> (u64, String) {
        let p = pipeline();
        let mut out = Vec::new();
        let jobs = serve(&p, input.as_bytes(), &mut out).unwrap();
        (jobs, String::from_utf8(out).unwrap())
    }

    #[test]
    fn runs_jobs_and_reports() {
        let (jobs, out) = drive("run primes seq\nrun stream par(2)\nquit\n");
        assert_eq!(jobs, 2);
        assert!(out.contains("ok workload=primes mode=seq"));
        assert!(out.contains("ok workload=stream mode=par(2)"));
        assert!(out.contains("verified=true"));
        assert!(out.contains("shard="), "results must report their shard");
    }

    #[test]
    fn bad_requests_get_err_lines() {
        let (jobs, out) = drive("run nope seq\nrun primes warp\nfrobnicate\n");
        assert_eq!(jobs, 0);
        assert_eq!(out.lines().filter(|l| l.starts_with("err")).count(), 3);
    }

    #[test]
    fn metrics_command_renders_snapshot() {
        let (_, out) = drive("run primes seq\nmetrics\nquit\n");
        assert!(out.contains("jobs.completed"));
        assert!(out.lines().any(|l| l == "."));
    }

    #[test]
    fn help_lists_workloads() {
        let (_, out) = drive("help\n");
        assert!(out.contains("stream_big"));
        assert!(out.contains("par(N)"));
    }

    #[test]
    fn eof_terminates_cleanly() {
        let (jobs, _) = drive("run primes seq\n");
        assert_eq!(jobs, 1);
    }

    #[test]
    fn blank_lines_ignored() {
        let (jobs, out) = drive("\n\nrun primes seq\n\n");
        assert_eq!(jobs, 1);
        assert_eq!(out.lines().count(), 1);
    }
}

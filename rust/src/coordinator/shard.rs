//! Coordinator sharding: N independent executor-pool groups behind one
//! [`Pipeline`](super::Pipeline).
//!
//! PR 1 made a *single* executor fast; under concurrent traffic one pool
//! still serializes every job through one injector and one park condvar.
//! A [`ShardSet`] splits the coordinator into [`Shard`]s, each owning:
//!
//! * its **executor pools**, keyed by requested parallelism and created
//!   lazily on first use — repeated jobs reuse warm pools instead of
//!   paying thread spin-up per job (the pre-shard `Pipeline` built a
//!   fresh `Executor` for every `par(k)` request);
//! * its **probe-cost caches** ([`CostCache`]), one per workload, so the
//!   adaptive chunk sizer measures per-element cost once per
//!   (shard, workload) instead of once per job;
//! * its **load/routing counters** (`inflight`, `jobs_routed`,
//!   `affinity_hits`).
//!
//! Routing is **workload-affinity first, least-loaded fallback**: a
//! request's home shard is `fnv1a(workload name) % N`, which keeps a
//! workload's warm pools and cost caches hot; when the home shard is
//! busier than the least-loaded shard the request spills there instead.
//! Ties favor the home shard, so routing is stable on an idle set.
//!
//! Per-shard [`ExecutorStats`] aggregates are published into the
//! metrics registry (`shard.<id>.*` gauges) after every job.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::Config;
use crate::exec::{DequeKind, Executor, ExecutorConfig, ExecutorStats};
use crate::metrics::MetricsRegistry;
use crate::stream::CostCache;
use crate::workload::ExecResources;

/// Most distinct `par(k)` pools a shard keeps warm. Requests name
/// arbitrary parallelism (the serve protocol accepts any `par(N)`), so
/// without a bound a client cycling N values would strand unbounded
/// worker threads; past the cap the least-recently-used pool is evicted
/// (it drains and shuts down once its in-flight jobs drop their
/// handles).
const MAX_POOLS_PER_SHARD: usize = 8;

struct PoolEntry {
    executor: Executor,
    last_used: u64,
}

#[derive(Default)]
struct Pools {
    map: BTreeMap<usize, PoolEntry>,
    /// Monotonic use tick for LRU eviction.
    tick: u64,
    /// Final monotonic counters of evicted pools, folded into
    /// [`Shard::stats`] so aggregates (and the gauges/steal deltas built
    /// on them) never go backwards when a pool is evicted. Instantaneous
    /// fields (`queue_depth`, `live_threads`) stay zero here.
    retired: ExecutorStats,
}

/// Add `s`'s monotonic counters into `agg` (instantaneous fields are the
/// caller's business).
fn add_monotonic(agg: &mut ExecutorStats, s: &ExecutorStats) {
    agg.tasks_spawned += s.tasks_spawned;
    agg.tasks_executed += s.tasks_executed;
    agg.tasks_panicked += s.tasks_panicked;
    agg.tasks_stolen += s.tasks_stolen;
    agg.steals_batched += s.steals_batched;
    agg.jobs_migrated += s.jobs_migrated;
    agg.compensation_threads += s.compensation_threads;
    agg.blocking_sections += s.blocking_sections;
}

/// One coordinator shard: executor pools + cost caches + load counters.
pub struct Shard {
    id: usize,
    stack_size: usize,
    /// Deque implementation every pool this shard builds runs
    /// ([`Config::deque`]).
    deque: DequeKind,
    /// Requested parallelism → long-lived pool. Lazily populated (a
    /// shard that never sees `par(k)` never spawns k workers) and
    /// LRU-bounded at [`MAX_POOLS_PER_SHARD`].
    pools: Mutex<Pools>,
    /// Jobs currently leased to this shard (routing load signal).
    inflight: AtomicUsize,
    jobs_routed: AtomicU64,
    affinity_hits: AtomicU64,
    /// Queued jobs another shard's idle runner stole from this one
    /// (cross-shard migration, the backed-up side).
    migrated_out: AtomicU64,
    /// Queued jobs this shard's runners stole from a backed-up shard
    /// (cross-shard migration, the idle side).
    migrated_in: AtomicU64,
    /// Workload name → memoized adaptive-chunking probe cost.
    costs: Mutex<BTreeMap<String, CostCache>>,
}

impl Shard {
    fn new(id: usize, stack_size: usize, deque: DequeKind) -> Shard {
        Shard {
            id,
            stack_size,
            deque,
            pools: Mutex::new(Pools::default()),
            inflight: AtomicUsize::new(0),
            jobs_routed: AtomicU64::new(0),
            affinity_hits: AtomicU64::new(0),
            migrated_out: AtomicU64::new(0),
            migrated_in: AtomicU64::new(0),
            costs: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn id(&self) -> usize {
        self.id
    }

    /// The shard's pool for `parallelism` workers, created on first use
    /// and reused for every later job (same counters, warm threads).
    /// Keeps at most [`MAX_POOLS_PER_SHARD`] distinct pools, evicting
    /// the least recently used — an evicted pool finishes its in-flight
    /// jobs (they hold their own handles) and then shuts down.
    pub fn executor(&self, parallelism: usize) -> Executor {
        let parallelism = parallelism.max(1);
        let mut pools = self.pools.lock().unwrap();
        pools.tick += 1;
        let tick = pools.tick;
        if let Some(entry) = pools.map.get_mut(&parallelism) {
            entry.last_used = tick;
            return entry.executor.clone();
        }
        if pools.map.len() >= MAX_POOLS_PER_SHARD {
            let evict = pools
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&k, _)| k);
            if let Some(k) = evict {
                if let Some(entry) = pools.map.remove(&k) {
                    // Fold the evicted pool's counters into the retired
                    // tally so shard aggregates stay monotonic. (Work it
                    // finishes after eviction — its in-flight jobs hold
                    // their own handles — is undercounted, never
                    // negative.)
                    let last = entry.executor.stats();
                    add_monotonic(&mut pools.retired, &last);
                }
            }
        }
        let mut cfg = ExecutorConfig::with_parallelism(parallelism);
        cfg.stack_size = self.stack_size;
        cfg.deque = self.deque;
        cfg.name = format!("sfut-s{}w", self.id);
        let executor = Executor::with_config(cfg);
        pools
            .map
            .insert(parallelism, PoolEntry { executor: executor.clone(), last_used: tick });
        executor
    }

    /// Distinct pools currently kept warm (≤ [`MAX_POOLS_PER_SHARD`]).
    pub fn pool_count(&self) -> usize {
        self.pools.lock().unwrap().map.len()
    }

    /// The shard's memoized probe cost for `workload` (created empty on
    /// first request; see [`CostCache`]).
    pub fn cost_cache(&self, workload: &str) -> CostCache {
        self.costs
            .lock()
            .unwrap()
            .entry(workload.to_string())
            .or_default()
            .clone()
    }

    /// Jobs currently leased to this shard.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Total jobs ever routed here.
    pub fn jobs_routed(&self) -> u64 {
        self.jobs_routed.load(Ordering::Relaxed)
    }

    /// Jobs that landed here because this was their affinity home (the
    /// rest spilled in via least-loaded fallback).
    pub fn affinity_hits(&self) -> u64 {
        self.affinity_hits.load(Ordering::Relaxed)
    }

    /// Queued jobs stolen *from* this shard by idle shards.
    pub fn migrated_out(&self) -> u64 {
        self.migrated_out.load(Ordering::Relaxed)
    }

    /// Queued jobs this shard stole from backed-up shards.
    pub fn migrated_in(&self) -> u64 {
        self.migrated_in.load(Ordering::Relaxed)
    }

    pub(crate) fn note_migrated_out(&self) {
        self.migrated_out.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_migrated_in(&self) {
        self.migrated_in.fetch_add(1, Ordering::Relaxed);
    }

    /// Publish this shard's aggregates as `shard.<id>.*` gauges. Called
    /// per job for the routed shard only (O(1) in shard count — a full
    /// [`ShardSet::publish`] per job would bill every shard's stats
    /// lock to the job being timed).
    pub fn publish(&self, metrics: &MetricsRegistry) {
        let st = self.stats();
        self.publish_stats(metrics, &st);
    }

    /// [`Shard::publish`] with an already-aggregated snapshot, so a
    /// caller that just computed [`Shard::stats`] (e.g. for a steal
    /// delta) doesn't pay the pool locks twice.
    pub fn publish_stats(&self, metrics: &MetricsRegistry, st: &ExecutorStats) {
        let id = self.id;
        metrics.gauge(&format!("shard.{id}.tasks_executed")).set(st.tasks_executed);
        metrics.gauge(&format!("shard.{id}.tasks_stolen")).set(st.tasks_stolen);
        metrics.gauge(&format!("shard.{id}.steals_batched")).set(st.steals_batched);
        metrics.gauge(&format!("shard.{id}.jobs_migrated")).set(st.jobs_migrated);
        // Mean batch size, rounded to the nearest whole job (gauges are
        // integral).
        metrics
            .gauge(&format!("shard.{id}.jobs_migrated_per_steal"))
            .set(st.jobs_migrated_per_steal().round() as u64);
        metrics.gauge(&format!("shard.{id}.queue_depth")).set(st.queue_depth as u64);
        metrics.gauge(&format!("shard.{id}.live_threads")).set(st.live_threads as u64);
        metrics.gauge(&format!("shard.{id}.inflight")).set(self.inflight() as u64);
        metrics.gauge(&format!("shard.{id}.jobs_routed")).set(self.jobs_routed());
        metrics.gauge(&format!("shard.{id}.affinity_hits")).set(self.affinity_hits());
        metrics.gauge(&format!("shard.{id}.migrated_out")).set(self.migrated_out());
        metrics.gauge(&format!("shard.{id}.migrated_in")).set(self.migrated_in());
    }

    /// Aggregate [`ExecutorStats`] over every pool this shard owns,
    /// plus the retired tallies of evicted pools (monotonic counters
    /// never go backwards across evictions).
    pub fn stats(&self) -> ExecutorStats {
        let pools = self.pools.lock().unwrap();
        let mut agg = pools.retired.clone();
        for entry in pools.map.values() {
            let s = entry.executor.stats();
            add_monotonic(&mut agg, &s);
            agg.queue_depth += s.queue_depth;
            agg.live_threads += s.live_threads;
        }
        agg
    }
}

/// A [`Shard`] is what workload plugins draw execution resources from:
/// warm `par(k)` pools and the shared probe-cost caches, surfaced
/// through the plugin API's [`ExecResources`] capability so plugins
/// never see coordinator internals.
impl ExecResources for Shard {
    fn executor(&self, parallelism: usize) -> Executor {
        Shard::executor(self, parallelism)
    }

    fn cost_cache(&self, key: &str) -> CostCache {
        Shard::cost_cache(self, key)
    }
}

/// RAII routing lease: holds the shard's `inflight` slot for the
/// duration of one job so concurrent routing sees true load.
pub struct ShardLease {
    shard: Arc<Shard>,
}

impl ShardLease {
    pub fn shard(&self) -> &Arc<Shard> {
        &self.shard
    }

    pub fn id(&self) -> usize {
        self.shard.id
    }
}

impl Drop for ShardLease {
    fn drop(&mut self) {
        self.shard.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// The coordinator's shard group.
pub struct ShardSet {
    shards: Vec<Arc<Shard>>,
}

impl ShardSet {
    /// The auto shard count: physical cores / `shard_parallelism`, at
    /// least 1 (a 1-core box still gets one full shard).
    pub fn auto_count(shard_parallelism: usize) -> usize {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        (cores / shard_parallelism.max(1)).max(1)
    }

    /// Build from config: `cfg.shards` shards (0 = [`Self::auto_count`]).
    pub fn new(cfg: &Config) -> ShardSet {
        let n = if cfg.shards == 0 {
            Self::auto_count(cfg.shard_parallelism)
        } else {
            cfg.shards
        };
        ShardSet {
            shards: (0..n)
                .map(|id| Arc::new(Shard::new(id, cfg.stack_size, cfg.deque)))
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn shard(&self, index: usize) -> &Arc<Shard> {
        &self.shards[index]
    }

    pub fn iter(&self) -> std::slice::Iter<'_, Arc<Shard>> {
        self.shards.iter()
    }

    /// A workload's affinity home: stable across runs and processes
    /// (FNV-1a of the *registry name* — the open world hashes names,
    /// not enum discriminants), so repeated jobs land where their pools
    /// and cost caches are warm. Params deliberately don't feed the
    /// hash: `fib(n=64)` and `fib(n=128)` share pools and probe costs.
    pub fn home_index(&self, workload: &str) -> usize {
        (fnv1a(workload.as_bytes()) % self.shards.len() as u64) as usize
    }

    /// Route a request: home shard unless a strictly less-loaded shard
    /// exists (ties keep affinity). Returns the lease that both names
    /// the shard and holds its load slot.
    pub fn route(&self, workload: &str) -> ShardLease {
        let home = self.home_index(workload);
        let mut best = home;
        let mut best_load = self.shards[home].inflight.load(Ordering::Relaxed);
        for (i, shard) in self.shards.iter().enumerate() {
            let load = shard.inflight.load(Ordering::Relaxed);
            if load < best_load {
                best = i;
                best_load = load;
            }
        }
        let shard = Arc::clone(&self.shards[best]);
        shard.inflight.fetch_add(1, Ordering::Relaxed);
        shard.jobs_routed.fetch_add(1, Ordering::Relaxed);
        if best == home {
            shard.affinity_hits.fetch_add(1, Ordering::Relaxed);
        }
        ShardLease { shard }
    }

    /// A load lease on a *specific* shard, bypassing routing — the
    /// cross-shard migration path (the thief shard adopts a job that was
    /// routed elsewhere) and anything else that already knows its shard.
    /// Counts toward `inflight` like a routed lease but not toward
    /// `jobs_routed`/`affinity_hits`: migration is not routing.
    pub fn lease_on(&self, index: usize) -> ShardLease {
        let shard = Arc::clone(&self.shards[index]);
        shard.inflight.fetch_add(1, Ordering::Relaxed);
        ShardLease { shard }
    }

    /// Per-shard aggregate executor stats, by shard id.
    pub fn stats(&self) -> Vec<(usize, ExecutorStats)> {
        self.shards.iter().map(|s| (s.id, s.stats())).collect()
    }

    /// Publish every shard's aggregates as `shard.<id>.*` gauges
    /// (startup and snapshot use; the per-job hot path publishes only
    /// the routed shard via [`Shard::publish`]).
    pub fn publish(&self, metrics: &MetricsRegistry) {
        for shard in &self.shards {
            shard.publish(metrics);
        }
    }
}

/// FNV-1a, 64-bit: tiny, deterministic, good spread on short names.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
    }
    hash
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn set_of(n: usize) -> ShardSet {
        let mut cfg = Config::default();
        cfg.shards = n;
        ShardSet::new(&cfg)
    }

    #[test]
    fn affinity_is_stable_when_idle() {
        let set = set_of(4);
        let home = set.home_index("primes");
        for _ in 0..10 {
            let lease = set.route("primes");
            assert_eq!(lease.id(), home, "idle routing must stick to the home shard");
        }
        // Any name — registered or not — hashes somewhere in range; the
        // open world means routing never enumerates workloads.
        for w in ["primes", "stream_big", "fib", "msort", "some_future_plugin"] {
            assert!(set.home_index(w) < 4);
        }
    }

    #[test]
    fn least_loaded_fallback_spills_then_returns() {
        let set = set_of(2);
        let home = set.home_index("primes");
        let other = 1 - home;
        // Home busy, other idle: spill.
        let lease_home = set.route("primes");
        assert_eq!(lease_home.id(), home);
        let lease_spill = set.route("primes");
        assert_eq!(lease_spill.id(), other, "busy home must spill to the idle shard");
        // Both equally busy: tie goes back to home.
        let lease_tie = set.route("primes");
        assert_eq!(lease_tie.id(), home, "ties must keep affinity");
        // Dropping leases releases load; routing returns home.
        drop(lease_home);
        drop(lease_spill);
        drop(lease_tie);
        assert_eq!(set.shard(home).inflight(), 0);
        assert_eq!(set.shard(other).inflight(), 0);
        let lease = set.route("primes");
        assert_eq!(lease.id(), home);
        assert_eq!(set.shard(other).jobs_routed(), 1);
        assert_eq!(set.shard(other).affinity_hits(), 0, "spill is not an affinity hit");
    }

    #[test]
    fn executor_pools_are_reused_across_calls() {
        let set = set_of(1);
        let shard = set.shard(0);
        let a = shard.executor(2);
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let hits = Arc::clone(&hits);
            a.spawn(move || {
                hits.fetch_add(1, Ordering::SeqCst);
            });
        }
        a.wait_idle();
        assert_eq!(hits.load(Ordering::SeqCst), 10);
        // A second checkout of the same parallelism is the same pool:
        // its counters already include the work above.
        let b = shard.executor(2);
        assert_eq!(b.stats().tasks_executed, 10);
        // A different parallelism is a different pool.
        let c = shard.executor(1);
        assert_eq!(c.stats().tasks_executed, 0);
    }

    #[test]
    fn pool_map_is_lru_bounded() {
        let set = set_of(1);
        let shard = set.shard(0);
        // Distinct parallelism values beyond the cap must evict, not
        // accumulate (the serve protocol accepts arbitrary par(N)).
        for k in 1..=MAX_POOLS_PER_SHARD + 3 {
            let ex = shard.executor(k);
            ex.spawn(|| {});
            ex.wait_idle();
        }
        assert_eq!(shard.pool_count(), MAX_POOLS_PER_SHARD);
        // Evicted pools' counters fold into the retired tally: the
        // shard aggregate stays monotonic and still counts all jobs.
        assert_eq!(shard.stats().tasks_executed, (MAX_POOLS_PER_SHARD + 3) as u64);
        // The most recent requests survived; re-requesting the evicted
        // oldest builds a fresh pool (counters start over).
        let newest = shard.executor(MAX_POOLS_PER_SHARD + 3);
        assert_eq!(newest.stats().tasks_executed, 1, "recent pool kept warm");
        let oldest = shard.executor(1);
        assert_eq!(oldest.stats().tasks_executed, 0, "evicted pool was rebuilt");
    }

    #[test]
    fn stats_aggregate_across_pools_and_publish() {
        let set = set_of(2);
        let shard = set.shard(0);
        let p1 = shard.executor(1);
        for _ in 0..3 {
            p1.spawn(|| {});
        }
        p1.wait_idle();
        let p2 = shard.executor(2);
        for _ in 0..4 {
            p2.spawn(|| {});
        }
        p2.wait_idle();
        let agg = shard.stats();
        assert_eq!(agg.tasks_executed, 7, "aggregate must span both pools");
        assert!(agg.live_threads >= 1);

        let metrics = MetricsRegistry::new();
        set.publish(&metrics);
        let snap = metrics.snapshot();
        assert_eq!(snap.gauges["shard.0.tasks_executed"], 7);
        assert_eq!(snap.gauges["shard.1.tasks_executed"], 0);
        assert!(snap.gauges.contains_key("shard.0.tasks_stolen"));
        assert!(snap.gauges.contains_key("shard.1.jobs_routed"));
        // Steal-half batching gauges are published for every shard.
        assert!(snap.gauges.contains_key("shard.0.steals_batched"));
        assert!(snap.gauges.contains_key("shard.0.jobs_migrated"));
        assert!(snap.gauges.contains_key("shard.0.jobs_migrated_per_steal"));
    }

    #[test]
    fn cost_caches_are_per_workload() {
        let set = set_of(1);
        let shard = set.shard(0);
        let a = shard.cost_cache("chunked");
        a.get_or_measure(|| std::time::Duration::from_micros(3));
        // Same workload: shared slot.
        assert_eq!(
            shard.cost_cache("chunked").get(),
            Some(std::time::Duration::from_micros(3))
        );
        // Different workload: independent slot.
        assert_eq!(shard.cost_cache("chunked_big").get(), None);
    }

    #[test]
    fn direct_leases_and_migration_counters() {
        let set = set_of(2);
        // lease_on pins the named shard and counts load, but is not a
        // routing event.
        let lease = set.lease_on(1);
        assert_eq!(lease.id(), 1);
        assert_eq!(set.shard(1).inflight(), 1);
        assert_eq!(set.shard(1).jobs_routed(), 0);
        drop(lease);
        assert_eq!(set.shard(1).inflight(), 0);

        set.shard(0).note_migrated_out();
        set.shard(1).note_migrated_in();
        assert_eq!(set.shard(0).migrated_out(), 1);
        assert_eq!(set.shard(1).migrated_in(), 1);
        let metrics = MetricsRegistry::new();
        set.publish(&metrics);
        let snap = metrics.snapshot();
        assert_eq!(snap.gauges["shard.0.migrated_out"], 1);
        assert_eq!(snap.gauges["shard.1.migrated_in"], 1);
        assert_eq!(snap.gauges["shard.0.migrated_in"], 0);
    }

    #[test]
    fn auto_count_is_positive_and_config_driven() {
        assert!(ShardSet::auto_count(1) >= 1);
        assert!(ShardSet::auto_count(usize::MAX) == 1);
        let mut cfg = Config::default();
        cfg.shards = 0;
        assert!(ShardSet::new(&cfg).len() >= 1);
        cfg.shards = 3;
        assert_eq!(ShardSet::new(&cfg).len(), 3);
    }
}

//! SO_REUSEPORT listener groups for the reactor pool.
//!
//! Each reactor thread owning its *own* listener bound to the *same*
//! address is the zero-coordination accept fanout: the kernel hashes
//! incoming connections across the group, no in-process handoff, no
//! shared accept lock. The option must be set *before* bind on every
//! socket in the group — std's `TcpListener::bind` leaves no hook for
//! that, so the sockets are made by hand in the crate's minimal-FFI
//! style (the same libc-already-linked symbols idiom as
//! [`super::poller`]) and wrapped with `FromRawFd`.
//!
//! Linux-only (the semantics of connection balancing across a
//! REUSEPORT group are Linux's); elsewhere [`bind_group`] returns
//! `Unsupported` and the pool falls back to in-process fd handoff.
//! The fallback is also forced by `Config::reuseport = false`, whose
//! round-robin dispatch is deterministic — the fanout tests pin that.

use std::io;
use std::net::{SocketAddr, TcpListener};

/// Bind `count` nonblocking listeners sharing `addr` via SO_REUSEPORT.
/// A port-0 request resolves on the first socket; the rest join the
/// resolved port, so `group[0].local_addr()` names the group.
#[cfg(target_os = "linux")]
pub(super) fn bind_group(addr: SocketAddr, count: usize) -> io::Result<Vec<TcpListener>> {
    let first = bind_one(&addr)?;
    let local = first.local_addr()?;
    let mut group = vec![first];
    for _ in 1..count {
        group.push(bind_one(&local)?);
    }
    Ok(group)
}

#[cfg(not(target_os = "linux"))]
pub(super) fn bind_group(_addr: SocketAddr, _count: usize) -> io::Result<Vec<TcpListener>> {
    Err(io::Error::new(
        io::ErrorKind::Unsupported,
        "SO_REUSEPORT listener groups are linux-only; the pool falls back to fd handoff",
    ))
}

#[cfg(target_os = "linux")]
mod sys {
    pub const AF_INET: i32 = 2;
    pub const AF_INET6: i32 = 10;
    pub const SOCK_STREAM: i32 = 1;
    pub const SOCK_NONBLOCK: i32 = 0o4000;
    pub const SOCK_CLOEXEC: i32 = 0o2000000;
    pub const SOL_SOCKET: i32 = 1;
    pub const SO_REUSEADDR: i32 = 2;
    pub const SO_REUSEPORT: i32 = 15;

    extern "C" {
        pub fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        pub fn setsockopt(fd: i32, level: i32, name: i32, value: *const u8, len: u32) -> i32;
        pub fn bind(fd: i32, addr: *const u8, len: u32) -> i32;
        pub fn listen(fd: i32, backlog: i32) -> i32;
        pub fn close(fd: i32) -> i32;
    }
}

/// Closes the raw fd unless defused by `forget` (bind/listen error
/// paths must not leak sockets).
#[cfg(target_os = "linux")]
struct FdGuard(i32);

#[cfg(target_os = "linux")]
impl Drop for FdGuard {
    fn drop(&mut self) {
        // SAFETY: the guard is the fd's sole owner until `forget`
        // defuses it — on this path ownership was never transferred,
        // so closing cannot invalidate anyone else's descriptor.
        unsafe {
            sys::close(self.0);
        }
    }
}

#[cfg(target_os = "linux")]
fn bind_one(addr: &SocketAddr) -> io::Result<TcpListener> {
    use std::os::unix::io::FromRawFd;

    let domain = match addr {
        SocketAddr::V4(_) => sys::AF_INET,
        SocketAddr::V6(_) => sys::AF_INET6,
    };
    let ty = sys::SOCK_STREAM | sys::SOCK_NONBLOCK | sys::SOCK_CLOEXEC;
    // SAFETY: no pointer arguments; the returned fd (checked below) is
    // owned by the FdGuard until listen succeeds and ownership moves
    // into the TcpListener.
    let fd = unsafe { sys::socket(domain, ty, 0) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    let guard = FdGuard(fd);
    let one: i32 = 1;
    for opt in [sys::SO_REUSEADDR, sys::SO_REUSEPORT] {
        // SAFETY: `one` is a live i32 on this stack frame and the
        // length argument (4) matches its size; setsockopt only reads
        // it. Options are set BEFORE bind — SO_REUSEPORT after bind
        // would not join the listener group.
        let rc = unsafe {
            sys::setsockopt(fd, sys::SOL_SOCKET, opt, &one as *const i32 as *const u8, 4)
        };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
    }
    let sa = sockaddr_bytes(addr);
    // SAFETY: `sa` is a live byte buffer laid out as sockaddr_in{,6}
    // (see sockaddr_bytes) and the length passed is its exact size;
    // bind only reads it.
    let rc = unsafe { sys::bind(fd, sa.as_ptr(), sa.len() as u32) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    // SAFETY: no pointer arguments; `fd` is our guarded socket.
    let rc = unsafe { sys::listen(fd, 1024) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    std::mem::forget(guard);
    // SAFETY: the guard was just defused, so `fd` has exactly one owner
    // again — the TcpListener takes over closing it.
    Ok(unsafe { TcpListener::from_raw_fd(fd) })
}

/// `struct sockaddr_in{,6}` as raw bytes (family in host order, port
/// and addresses in network order) — layout-stable without a `repr(C)`
/// struct per family.
#[cfg(target_os = "linux")]
fn sockaddr_bytes(addr: &SocketAddr) -> Vec<u8> {
    match addr {
        SocketAddr::V4(v4) => {
            let mut b = Vec::with_capacity(16);
            b.extend_from_slice(&(sys::AF_INET as u16).to_ne_bytes());
            b.extend_from_slice(&v4.port().to_be_bytes());
            b.extend_from_slice(&v4.ip().octets());
            b.extend_from_slice(&[0u8; 8]);
            b
        }
        SocketAddr::V6(v6) => {
            let mut b = Vec::with_capacity(28);
            b.extend_from_slice(&(sys::AF_INET6 as u16).to_ne_bytes());
            b.extend_from_slice(&v6.port().to_be_bytes());
            b.extend_from_slice(&v6.flowinfo().to_be_bytes());
            b.extend_from_slice(&v6.ip().octets());
            b.extend_from_slice(&v6.scope_id().to_ne_bytes());
            b
        }
    }
}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;
    use std::io::ErrorKind;
    use std::net::TcpStream;
    use std::time::{Duration, Instant};

    #[test]
    fn group_shares_one_port_and_accepts() {
        let group = bind_group("127.0.0.1:0".parse().unwrap(), 3).unwrap();
        assert_eq!(group.len(), 3);
        let addr = group[0].local_addr().unwrap();
        assert_ne!(addr.port(), 0, "port 0 resolved on first bind");
        for l in &group[1..] {
            assert_eq!(l.local_addr().unwrap().port(), addr.port());
        }
        let clients: Vec<TcpStream> =
            (0..8).map(|_| TcpStream::connect(addr).unwrap()).collect();
        // The group's sockets are nonblocking by construction; sweep
        // accepts until the kernel has handed every connection to some
        // member.
        let mut accepted = 0;
        let deadline = Instant::now() + Duration::from_secs(5);
        while accepted < clients.len() && Instant::now() < deadline {
            let mut progressed = false;
            for l in &group {
                match l.accept() {
                    Ok(_) => {
                        accepted += 1;
                        progressed = true;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                    Err(e) => panic!("accept failed: {e}"),
                }
            }
            if !progressed {
                std::thread::sleep(Duration::from_millis(5));
            }
        }
        assert_eq!(accepted, clients.len(), "every connection lands on some group member");
    }
}

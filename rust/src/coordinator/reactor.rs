//! Poll-based event-loop session layer for the framed wire protocol.
//!
//! One `sfut-reactor` thread owns the nonblocking listener and every
//! framed session — no thread-per-connection. The async primitive is
//! the repo's own [`Fut`](crate::susp::Fut): a `wait` on an unresolved
//! ticket registers an `on_complete` continuation that pushes the
//! (session, ticket) pair onto a ready list and wakes the reactor
//! through a self-pipe, so job completion flows to the consumer over
//! the exact promise/callback path the paper's stream cells use —
//! never a dedicated waiting thread, never a poll of the job.
//!
//! Flow control is end-to-end:
//!
//! * **Read backpressure** — a session whose write buffer crosses
//!   [`HIGH_WATER`] (a client that stops draining results), or whose
//!   front submit is deferred on a full admission queue, stops being
//!   polled for readability. The kernel socket buffer fills, TCP
//!   pushes back on the client, and server memory stays bounded
//!   (`wire.read_paused` counts the transitions).
//! * **Admission backpressure** — submits go through the ingress's
//!   nonblocking [`try_submit`](super::ingress::Ingress::try_submit):
//!   `shed` answers its usual `err admission=shed` frame immediately;
//!   the parking policies (`block`, `timeout(ms)`) defer the frame
//!   in-session — FIFO order preserved so ticket ids still correlate
//!   by submit order — and retry each tick, `timeout` expiring into
//!   the same `err admission=timeout` line the text protocol emits.
//!
//! Protocol errors (bad magic, oversized length, unknown kind) answer
//! exactly one well-formed `Err` frame and then close; a mid-frame
//! disconnect is detected via the decoder's partial state and closed
//! without ceremony. Shutdown mirrors the text path's drain: parked
//! waits get a grace window to deliver late results, then a final
//! `err closed ticket=N` frame each, buffers are flushed best-effort,
//! and the thread exits.

use std::collections::{BTreeMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use log::{debug, info, warn};

use super::frame::{
    check_preamble, line_payload, take_ticket_id, ticket_payload, Frame, FrameDecoder, FrameKind,
    VERSION,
};
use super::ingress::{JobTicket, SubmitError, TryAdmit};
use super::job::{JobRequest, JobResult};
use super::router::Pipeline;
use super::server::{
    err_closed_line, err_released_line, release_oldest_resolved, workloads_listing,
    MAX_SESSION_TICKETS,
};
use crate::config::AdmissionPolicy;
use crate::metrics::MetricsRegistry;
use crate::susp::FutState;

/// Write-buffer level that pauses reading from a session until the
/// client drains results below it.
const HIGH_WATER: usize = 64 * 1024;

/// Poll timeout when idle; completion wakes arrive via the self-pipe
/// long before this fires.
const IDLE_POLL_MS: i32 = 50;

/// Poll timeout while any session has a deferred (queue-full) submit:
/// admission slots free without a wake, so tick faster.
const DEFERRED_POLL_MS: i32 = 5;

/// Shutdown drain: how long parked waits may still deliver real
/// results before being answered with `err closed` frames (mirrors the
/// text server's `STOP_DRAIN_GRACE`).
const DRAIN_GRACE: Duration = Duration::from_secs(1);

mod sys {
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
    }

    /// `poll(2)` with EINTR retry. The one FFI call in the crate — the
    /// toolchain ships no event-loop dependency, and one symbol from
    /// libc (already linked by std) is all a readiness loop needs.
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        loop {
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as u64, timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = std::io::Error::last_os_error();
            if err.kind() != std::io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

/// Completions waiting to be turned into `Result` frames:
/// `(session id, ticket id)` pairs pushed by `on_complete` callbacks.
type ReadyList = Arc<Mutex<Vec<(u64, u64)>>>;

/// Self-pipe wake handle: job-completion callbacks (and
/// [`TcpServer::shutdown`](super::TcpServer::shutdown)) call
/// [`Waker::wake`] to interrupt the reactor's `poll`.
#[derive(Clone)]
pub(super) struct Waker {
    tx: Arc<UnixStream>,
}

impl Waker {
    fn pair() -> std::io::Result<(Waker, UnixStream)> {
        let (tx, rx) = UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok((Waker { tx: Arc::new(tx) }, rx))
    }

    pub(super) fn wake(&self) {
        // A full pipe already guarantees a pending wake; errors (incl.
        // a reactor that already exited) are fine to drop.
        let _ = (&*self.tx).write(&[1u8]);
    }
}

/// What [`start`] hands back to the TCP front-end.
pub(super) struct ReactorHandle {
    pub(super) thread: JoinHandle<()>,
    pub(super) waker: Waker,
    /// Live framed sessions (the reactor's analogue of tracked session
    /// threads).
    pub(super) live: Arc<AtomicU64>,
}

/// Spawn the reactor thread over an already-bound nonblocking listener.
pub(super) fn start(
    listener: TcpListener,
    pipeline: Arc<Pipeline>,
    stop: Arc<AtomicBool>,
    sessions_total: Arc<AtomicU64>,
) -> Result<ReactorHandle> {
    let (waker, waker_rx) = Waker::pair().context("creating reactor self-pipe")?;
    let live = Arc::new(AtomicU64::new(0));
    let reactor = Reactor {
        pipeline,
        listener,
        stop,
        sessions_total,
        live: Arc::clone(&live),
        waker: waker.clone(),
        waker_rx,
        ready: Arc::new(Mutex::new(Vec::new())),
    };
    let thread = std::thread::Builder::new()
        .name("sfut-reactor".to_string())
        .spawn(move || reactor.run())
        .context("spawning reactor thread")?;
    Ok(ReactorHandle { thread, waker, live })
}

/// One framed connection's state, owned by the reactor thread.
struct Session {
    stream: TcpStream,
    peer: std::net::SocketAddr,
    /// Bytes collected toward the 5-byte connect preamble.
    pre: Vec<u8>,
    handshaken: bool,
    decoder: FrameDecoder,
    /// Decoded frames not yet processed — nonempty past index 0 only
    /// while the front is deferred (FIFO order is what lets a client
    /// correlate `Ticket` replies with its submit order).
    input: VecDeque<Frame>,
    /// When the front submit frame was first deferred on a full queue.
    deferred_since: Option<Instant>,
    /// Pending output bytes (encoded frames awaiting socket space).
    out: Vec<u8>,
    tickets: BTreeMap<u64, JobTicket>,
    next_ticket: u64,
    /// Outstanding `Wait`s per ticket (a wait may be issued twice).
    pending_waits: BTreeMap<u64, u32>,
    /// Close once `out` drains; no further input is processed.
    closing: bool,
    /// Client half-closed; finish pending work, then close.
    read_eof: bool,
    /// Currently not polled for readability (flow control).
    read_paused: bool,
}

impl Session {
    fn new(stream: TcpStream, peer: std::net::SocketAddr) -> Session {
        Session {
            stream,
            peer,
            pre: Vec::with_capacity(5),
            handshaken: false,
            decoder: FrameDecoder::new(),
            input: VecDeque::new(),
            deferred_since: None,
            out: Vec::new(),
            tickets: BTreeMap::new(),
            next_ticket: 1,
            pending_waits: BTreeMap::new(),
            closing: false,
            read_eof: false,
            read_paused: false,
        }
    }

    /// Nothing left to do for this client: all input processed, all
    /// waits answered, all output flushed.
    fn finished(&self) -> bool {
        (self.closing && self.out.is_empty())
            || (self.read_eof
                && self.input.is_empty()
                && self.pending_waits.is_empty()
                && self.out.is_empty()
                && self.deferred_since.is_none())
    }
}

struct Reactor {
    pipeline: Arc<Pipeline>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    sessions_total: Arc<AtomicU64>,
    live: Arc<AtomicU64>,
    waker: Waker,
    waker_rx: UnixStream,
    ready: ReadyList,
}

impl Reactor {
    fn run(self) {
        let Reactor { pipeline, listener, stop, sessions_total, live, waker, waker_rx, ready } =
            self;
        let mut sessions: BTreeMap<u64, Session> = BTreeMap::new();
        let mut next_session: u64 = 1;
        let mut drain_deadline: Option<Instant> = None;
        info!("sfut reactor serving framed wire on {:?}", listener.local_addr().ok());
        loop {
            let draining = stop.load(Ordering::SeqCst);
            if draining {
                let deadline = *drain_deadline.get_or_insert_with(|| Instant::now() + DRAIN_GRACE);
                let busy = sessions.values().any(|s| {
                    !s.pending_waits.is_empty() || !s.out.is_empty() || s.deferred_since.is_some()
                });
                if !busy || Instant::now() >= deadline {
                    final_drain(&pipeline, &mut sessions);
                    live.store(0, Ordering::Relaxed);
                    pipeline.metrics().gauge("wire.sessions").set(0);
                    return;
                }
            }

            // --- poll set: self-pipe, listener (unless draining), sessions.
            let metrics = pipeline.metrics();
            let mut fds: Vec<sys::PollFd> = Vec::with_capacity(2 + sessions.len());
            fds.push(sys::PollFd { fd: waker_rx.as_raw_fd(), events: sys::POLLIN, revents: 0 });
            if !draining {
                fds.push(sys::PollFd {
                    fd: listener.as_raw_fd(),
                    events: sys::POLLIN,
                    revents: 0,
                });
            }
            let base = fds.len();
            let mut ids: Vec<u64> = Vec::with_capacity(sessions.len());
            let mut any_deferred = false;
            for (&sid, s) in sessions.iter_mut() {
                let paused = s.out.len() >= HIGH_WATER || s.deferred_since.is_some();
                if paused && !s.read_paused {
                    metrics.counter("wire.read_paused").inc();
                }
                s.read_paused = paused;
                any_deferred |= s.deferred_since.is_some();
                let mut events: i16 = 0;
                if !s.read_eof && !s.closing && !paused {
                    events |= sys::POLLIN;
                }
                if !s.out.is_empty() {
                    events |= sys::POLLOUT;
                }
                ids.push(sid);
                fds.push(sys::PollFd { fd: s.stream.as_raw_fd(), events, revents: 0 });
            }
            let timeout = if draining {
                20
            } else if any_deferred {
                DEFERRED_POLL_MS
            } else {
                IDLE_POLL_MS
            };
            if let Err(e) = sys::poll_fds(&mut fds, timeout) {
                warn!("reactor poll failed: {e}");
                std::thread::sleep(Duration::from_millis(10));
            }

            // --- drain the self-pipe (level-triggered; always safe).
            let mut sink = [0u8; 64];
            while matches!((&waker_rx).read(&mut sink), Ok(n) if n > 0) {}

            // --- accept new sessions.
            if !draining {
                loop {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            if stream.set_nonblocking(true).is_err() {
                                continue;
                            }
                            let _ = stream.set_nodelay(true);
                            sessions_total.fetch_add(1, Ordering::Relaxed);
                            debug!("reactor accepted framed session from {peer}");
                            sessions.insert(next_session, Session::new(stream, peer));
                            next_session += 1;
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) => {
                            warn!("reactor accept error: {e}");
                            break;
                        }
                    }
                }
            }

            // --- read readable sessions, decode, process.
            for (i, &sid) in ids.iter().enumerate() {
                let revents = fds[base + i].revents;
                if revents & (sys::POLLIN | sys::POLLHUP | sys::POLLERR) != 0 {
                    if let Some(s) = sessions.get_mut(&sid) {
                        read_session(metrics, s);
                    }
                }
            }
            // Every tick, every session: drives deferred retries and
            // frames decoded this tick alike. Cheap when input is empty.
            for (&sid, s) in sessions.iter_mut() {
                process_input(&pipeline, &ready, &waker, sid, s);
            }

            // --- completed tickets → Result/Err frames.
            let completed: Vec<(u64, u64)> = std::mem::take(&mut *ready.lock().unwrap());
            for (sid, tid) in completed {
                let Some(s) = sessions.get_mut(&sid) else { continue };
                match s.pending_waits.get_mut(&tid) {
                    Some(cnt) => {
                        *cnt -= 1;
                        if *cnt == 0 {
                            s.pending_waits.remove(&tid);
                        }
                    }
                    None => continue,
                }
                answer_wait(metrics, s, tid);
            }

            // --- flush writable output; reap finished sessions.
            let mut dead: Vec<u64> = Vec::new();
            for (&sid, s) in sessions.iter_mut() {
                if !s.out.is_empty() {
                    if let Err(e) = flush_out(s) {
                        debug!("session {}: write failed ({e}); dropping", s.peer);
                        s.out.clear();
                        s.closing = true;
                    }
                }
                if s.finished() {
                    dead.push(sid);
                }
            }
            for sid in dead {
                if let Some(s) = sessions.remove(&sid) {
                    debug!("reactor closed session {}", s.peer);
                }
            }
            live.store(sessions.len() as u64, Ordering::Relaxed);
            metrics.gauge("wire.sessions").set(sessions.len() as u64);
        }
    }
}

fn state_code(state: FutState) -> u8 {
    match state {
        FutState::Empty => 0,
        FutState::Running => 1,
        FutState::Ready => 2,
        FutState::Panicked => 3,
    }
}

fn enqueue(metrics: &MetricsRegistry, s: &mut Session, frame: &Frame) {
    frame.encode_into(&mut s.out);
    metrics.counter("wire.frames_out").inc();
}

fn enqueue_err(metrics: &MetricsRegistry, s: &mut Session, id: u64, line: &str) {
    enqueue(metrics, s, &Frame::new(FrameKind::Err, line_payload(id, line)));
}

/// Pull whatever the socket has, run the handshake, decode frames.
fn read_session(metrics: &MetricsRegistry, s: &mut Session) {
    let mut buf = [0u8; 8192];
    loop {
        match s.stream.read(&mut buf) {
            Ok(0) => {
                if s.decoder.has_partial() || (!s.pre.is_empty() && !s.handshaken) {
                    // Mid-frame disconnect: nothing to answer — the
                    // bytes that would complete the frame can never
                    // arrive. Close without ceremony.
                    metrics.counter("wire.midframe_disconnects").inc();
                }
                s.read_eof = true;
                break;
            }
            Ok(n) => {
                let mut bytes = &buf[..n];
                if !s.handshaken {
                    let need = 5 - s.pre.len();
                    let take = need.min(bytes.len());
                    s.pre.extend_from_slice(&bytes[..take]);
                    bytes = &bytes[take..];
                    if s.pre.len() == 5 {
                        let mut p = [0u8; 5];
                        p.copy_from_slice(&s.pre);
                        match check_preamble(&p) {
                            Ok(()) => {
                                s.handshaken = true;
                                enqueue(metrics, s, &Frame::new(FrameKind::Hello, vec![VERSION]));
                            }
                            Err(e) => {
                                enqueue_err(metrics, s, 0, &format!("err {e}"));
                                s.closing = true;
                                return;
                            }
                        }
                    }
                }
                if s.handshaken && !bytes.is_empty() {
                    s.decoder.feed(bytes);
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => {
                debug!("session {}: read failed ({e}); dropping", s.peer);
                s.out.clear();
                s.closing = true;
                return;
            }
        }
    }
    if !s.handshaken || s.closing {
        return;
    }
    loop {
        match s.decoder.next() {
            Ok(Some(frame)) => {
                metrics.counter("wire.frames_in").inc();
                s.input.push_back(frame);
            }
            Ok(None) => break,
            Err(e) => {
                // One well-formed err frame, then close — never a
                // panic, never a stuck session.
                enqueue_err(metrics, s, 0, &format!("err {e}"));
                s.closing = true;
                break;
            }
        }
    }
}

/// Handle decoded frames in FIFO order. Stops at a submit that the
/// admission queue defers (queue full under a parking policy); the
/// frame stays at the front and is retried next tick.
fn process_input(pipeline: &Pipeline, ready: &ReadyList, waker: &Waker, sid: u64, s: &mut Session) {
    let metrics = pipeline.metrics();
    while !s.closing {
        let Some(frame) = s.input.front().cloned() else { return };
        match frame.kind {
            FrameKind::Submit => {
                let text = match std::str::from_utf8(&frame.payload) {
                    Ok(t) => t.trim().to_string(),
                    Err(_) => {
                        s.input.pop_front();
                        s.deferred_since = None;
                        enqueue_err(metrics, s, 0, "err submit payload is not valid utf-8");
                        continue;
                    }
                };
                let req = match JobRequest::parse(&text) {
                    Ok(req) => req,
                    Err(e) => {
                        s.input.pop_front();
                        s.deferred_since = None;
                        enqueue_err(metrics, s, 0, &format!("err {e}"));
                        continue;
                    }
                };
                // A deferred submit under `timeout(ms)` that never got a
                // slot expires into the same admission line the parking
                // path emits (same configured `waited_ms`, same counter).
                if let Some(since) = s.deferred_since {
                    if let AdmissionPolicy::Timeout(ms) = pipeline.config().admission {
                        if since.elapsed() >= Duration::from_millis(ms) {
                            pipeline.ingress().note_deferred_timeout();
                            let err = SubmitError::Timeout {
                                waited_ms: ms,
                                queue_depth: pipeline.config().queue_depth,
                            };
                            s.input.pop_front();
                            s.deferred_since = None;
                            enqueue_err(metrics, s, 0, &err.render_line(&req));
                            continue;
                        }
                    }
                }
                let first_attempt = s.deferred_since.is_none();
                match pipeline.ingress().try_submit(req.clone(), true, first_attempt) {
                    TryAdmit::Ticket(ticket) => {
                        s.input.pop_front();
                        s.deferred_since = None;
                        let id = s.next_ticket;
                        s.next_ticket += 1;
                        let code = state_code(ticket.state());
                        s.tickets.insert(id, ticket);
                        release_oldest_resolved(&mut s.tickets, MAX_SESSION_TICKETS);
                        enqueue(
                            metrics,
                            s,
                            &Frame::new(FrameKind::Ticket, ticket_payload(id, code)),
                        );
                    }
                    TryAdmit::Reject(err) => {
                        s.input.pop_front();
                        s.deferred_since = None;
                        enqueue_err(metrics, s, 0, &err.render_line(&req));
                    }
                    TryAdmit::Full(_) => {
                        if s.deferred_since.is_none() {
                            s.deferred_since = Some(Instant::now());
                        }
                        return;
                    }
                }
            }
            FrameKind::Wait | FrameKind::Poll => {
                s.input.pop_front();
                let Some((id, _)) = take_ticket_id(&frame.payload) else {
                    enqueue_err(metrics, s, 0, "err bad ticket payload (want u64 le id)");
                    continue;
                };
                if id == 0 || id >= s.next_ticket {
                    enqueue_err(
                        metrics,
                        s,
                        id,
                        &format!(
                            "err unknown ticket: {id} ({} issued this session)",
                            s.next_ticket - 1
                        ),
                    );
                    continue;
                }
                let Some(ticket) = s.tickets.get(&id) else {
                    enqueue_err(metrics, s, id, &err_released_line(id));
                    continue;
                };
                if frame.kind == FrameKind::Poll {
                    let code = state_code(ticket.state());
                    enqueue(metrics, s, &Frame::new(FrameKind::Ticket, ticket_payload(id, code)));
                } else if ticket.is_ready() {
                    answer_wait(metrics, s, id);
                } else {
                    // Park the wait on the ticket's Fut: completion
                    // pushes onto the ready list and wakes the poll.
                    *s.pending_waits.entry(id).or_insert(0) += 1;
                    let ready = Arc::clone(ready);
                    let waker = waker.clone();
                    ticket.fut().on_complete(move |_| {
                        if let Ok(mut queue) = ready.lock() {
                            queue.push((sid, id));
                        }
                        waker.wake();
                    });
                }
            }
            FrameKind::Workloads => {
                s.input.pop_front();
                let listing = workloads_listing(pipeline);
                enqueue(metrics, s, &Frame::new(FrameKind::WorkloadsReply, listing.into_bytes()));
            }
            // Server-to-client kinds arriving from a client are a
            // protocol violation: one err frame, then close.
            FrameKind::Hello
            | FrameKind::Ticket
            | FrameKind::Result
            | FrameKind::Err
            | FrameKind::WorkloadsReply => {
                s.input.pop_front();
                enqueue_err(
                    metrics,
                    s,
                    0,
                    &format!("err unexpected client frame kind {}", frame.kind.as_u8()),
                );
                s.closing = true;
            }
        }
    }
}

/// Emit the resolved outcome of `tid` as one `Result`/`Err` frame —
/// the framed analogue of the text server's `deliver`.
fn answer_wait(metrics: &MetricsRegistry, s: &mut Session, tid: u64) {
    let outcome = match s.tickets.get(&tid) {
        Some(ticket) => ticket.wait_timeout(Duration::from_millis(0)),
        None => {
            enqueue_err(metrics, s, tid, &err_released_line(tid));
            return;
        }
    };
    match outcome {
        Some(outcome) => deliver_outcome(metrics, s, tid, outcome),
        // Completion raced the release path; ask the client to retry.
        None => enqueue_err(metrics, s, tid, &format!("err ticket not ready: {tid}")),
    }
}

fn deliver_outcome(
    metrics: &MetricsRegistry,
    s: &mut Session,
    tid: u64,
    outcome: Result<JobResult>,
) {
    match outcome {
        Ok(result) => enqueue(
            metrics,
            s,
            &Frame::new(FrameKind::Result, line_payload(tid, &result.render_line())),
        ),
        Err(e) => enqueue_err(metrics, s, tid, &format!("err {e:#}")),
    }
}

/// Nonblocking write of whatever the socket will take.
fn flush_out(s: &mut Session) -> std::io::Result<()> {
    while !s.out.is_empty() {
        match s.stream.write(&s.out) {
            Ok(0) => return Err(ErrorKind::WriteZero.into()),
            Ok(n) => {
                s.out.drain(..n);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Shutdown endgame: every still-parked wait is answered — with the
/// real result if it landed during the grace window, else a final
/// `err closed ticket=N` frame — deferred submits answer `closed`,
/// buffers flush best-effort (briefly blocking), sockets close.
fn final_drain(pipeline: &Pipeline, sessions: &mut BTreeMap<u64, Session>) {
    let metrics = pipeline.metrics();
    for s in sessions.values_mut() {
        let waits: Vec<(u64, u32)> = s.pending_waits.iter().map(|(&k, &v)| (k, v)).collect();
        s.pending_waits.clear();
        for (tid, count) in waits {
            let resolved = s.tickets.get(&tid).is_some_and(JobTicket::is_ready);
            for _ in 0..count {
                if resolved {
                    answer_wait(metrics, s, tid);
                } else {
                    enqueue_err(metrics, s, tid, &err_closed_line(tid));
                }
            }
        }
        if s.deferred_since.take().is_some() {
            let line = s
                .input
                .front()
                .and_then(|f| std::str::from_utf8(&f.payload).ok())
                .and_then(|t| JobRequest::parse(t.trim()).ok())
                .map(|req| SubmitError::Closed.render_line(&req))
                .unwrap_or_else(|| "err admission=closed".to_string());
            enqueue_err(metrics, s, 0, &line);
        }
        s.input.clear();
        let _ = s.stream.set_nonblocking(false);
        let _ = s.stream.set_write_timeout(Some(Duration::from_millis(200)));
        let out = std::mem::take(&mut s.out);
        let _ = s.stream.write_all(&out);
        let _ = s.stream.shutdown(std::net::Shutdown::Both);
    }
    sessions.clear();
}

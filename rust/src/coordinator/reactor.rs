//! Event-loop session layer for the framed wire protocol: a pool of
//! reactor threads over a pluggable readiness backend.
//!
//! Each `sfut-reactor-<r>` thread owns a disjoint set of nonblocking
//! framed sessions — no thread-per-connection, and no cross-thread
//! session state: a connection is **pinned** to one reactor for its
//! lifetime, so decode buffers, ticket tables, and write queues stay
//! single-threaded and each reactor's waker/self-pipe stays
//! uncontended. The async primitive is the repo's own
//! [`Fut`](crate::susp::Fut): a `wait` on an unresolved ticket
//! registers an `on_complete` continuation that pushes the (session,
//! ticket) pair onto the owning reactor's ready list and wakes *that*
//! reactor through its self-pipe, so job completion flows to the
//! consumer over the exact promise/callback path the paper's stream
//! cells use — never a dedicated waiting thread, never a poll of the
//! job.
//!
//! **Readiness** is behind the [`super::poller::Poller`] trait: the
//! portable poll(2) scan or Linux epoll, selected by
//! [`Config::poller`](crate::config::Config) (`--poller`,
//! `SFUT_POLLER`; `auto` picks epoll where available).
//!
//! **Accept fanout** ([`Config::reactors`](crate::config::Config), 0 =
//! auto from cores): with an SO_REUSEPORT listener group
//! ([`super::reuseport`], Linux) every reactor accepts from its own
//! listener and the kernel balances connections — zero in-process
//! coordination. Where the group is unavailable (non-Linux, or
//! `Config::reuseport = false`), reactor 0 owns the single listener
//! and hands accepted fds round-robin to per-reactor inboxes, waking
//! the target; the session is adopted — pinned — by the receiving
//! reactor before its first byte is parsed.
//!
//! Flow control is end-to-end and unchanged from the single-reactor
//! design:
//!
//! * **Read backpressure** — a session whose write buffer crosses
//!   [`HIGH_WATER`] (a client that stops draining results), or whose
//!   front submit is deferred on a full admission queue, drops to an
//!   empty poll interest. The kernel socket buffer fills, TCP pushes
//!   back on the client, and server memory stays bounded
//!   (`wire.read_paused` counts the transitions).
//! * **Admission backpressure** — submits go through the ingress's
//!   nonblocking [`try_submit`](super::ingress::Ingress::try_submit):
//!   `shed` answers its usual `err admission=shed` frame immediately;
//!   the parking policies (`block`, `timeout(ms)`) defer the frame
//!   in-session — FIFO order preserved so ticket ids still correlate
//!   by submit order — and retry each tick, `timeout` expiring into
//!   the same `err admission=timeout` line the text protocol emits.
//!
//! Per-reactor observability: `wire.<r>.sessions`,
//! `wire.<r>.read_paused`, `wire.<r>.midframe_disconnects`, and
//! `wire.<r>.frames_in` shadow the pool-wide totals (`wire.sessions`,
//! `wire.read_paused`, …), which keep their exact pre-pool meaning —
//! every reconciliation that balances wire traffic against the
//! aggregate counters holds under any reactor count. The per-reactor
//! `frames_in` is also what makes the pinning invariant *testable*:
//! all frames of one connection land on exactly one `wire.<r>.*` set.
//!
//! Protocol errors (bad magic, oversized length, unknown kind) answer
//! exactly one well-formed `Err` frame and then close; a mid-frame
//! disconnect is detected via the decoder's partial state and closed
//! without ceremony. Shutdown mirrors the text path's drain in every
//! reactor: parked waits get a grace window to deliver late results,
//! then a final `err closed ticket=N` frame each, buffers are flushed
//! best-effort, and the thread exits; the TCP front-end joins all pool
//! threads and drops the waker handles so the self-pipe fds close.

use std::collections::{BTreeMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use log::{debug, info, warn};

use super::frame::{
    check_preamble, line_payload, take_ticket_id, ticket_payload, Frame, FrameDecoder, FrameKind,
    VERSION,
};
use super::ingress::{JobTicket, SubmitError, TryAdmit};
use super::job::{JobRequest, JobResult};
use super::poller::{self, Event, Interest, Poller};
use super::reuseport;
use super::router::Pipeline;
use super::server::{
    err_closed_line, err_released_line, release_oldest_resolved, workloads_listing,
    MAX_SESSION_TICKETS,
};
use crate::config::AdmissionPolicy;
use crate::metrics::{Counter, Gauge, MetricsRegistry};
use crate::susp::FutState;

/// Write-buffer level that pauses reading from a session until the
/// client drains results below it.
const HIGH_WATER: usize = 64 * 1024;

/// Wait timeout when idle; completion wakes arrive via the self-pipe
/// long before this fires.
const IDLE_POLL_MS: i32 = 50;

/// Wait timeout while any session has a deferred (queue-full) submit:
/// admission slots free without a wake, so tick faster.
const DEFERRED_POLL_MS: i32 = 5;

/// Shutdown drain: how long parked waits may still deliver real
/// results before being answered with `err closed` frames (mirrors the
/// text server's `STOP_DRAIN_GRACE`).
const DRAIN_GRACE: Duration = Duration::from_secs(1);

/// Poller token of a reactor's self-pipe read end.
const TOKEN_WAKER: u64 = 0;
/// Poller token of a reactor's listener (when it owns one).
const TOKEN_LISTENER: u64 = 1;
/// Session id `sid` registers under token `sid + TOKEN_SESSION_BASE`.
const TOKEN_SESSION_BASE: u64 = 2;

/// Auto reactor count (`Config::reactors = 0`): available cores, capped
/// — past this, accept fanout stops being the bottleneck anyway.
const MAX_AUTO_REACTORS: usize = 16;

/// Completions waiting to be turned into `Result` frames:
/// `(session id, ticket id)` pairs pushed by `on_complete` callbacks.
type ReadyList = Arc<Mutex<Vec<(u64, u64)>>>;

/// Accepted-but-not-yet-adopted connections handed to a reactor by the
/// fanout dispatcher (fd handoff mode only).
type Inbox = Arc<Mutex<VecDeque<(TcpStream, SocketAddr)>>>;

/// Self-pipe wake handle: job-completion callbacks (and
/// [`TcpServer::shutdown`](super::TcpServer::shutdown)) call
/// [`Waker::wake`] to interrupt the owning reactor's wait.
#[derive(Clone)]
pub(super) struct Waker {
    tx: Arc<UnixStream>,
}

impl Waker {
    fn pair() -> std::io::Result<(Waker, UnixStream)> {
        let (tx, rx) = UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok((Waker { tx: Arc::new(tx) }, rx))
    }

    pub(super) fn wake(&self) {
        // A full pipe already guarantees a pending wake; errors (incl.
        // a reactor that already exited) are fine to drop.
        let _ = (&*self.tx).write(&[1u8]);
    }
}

/// What [`start_pool`] hands back to the TCP front-end.
pub(super) struct PoolHandle {
    /// Where the pool actually listens (port 0 resolved).
    pub(super) local_addr: SocketAddr,
    /// One `sfut-reactor-<r>` thread per reactor, in id order.
    pub(super) threads: Vec<JoinHandle<()>>,
    /// One waker per reactor; dropping them after join closes the
    /// self-pipe write ends.
    pub(super) wakers: Vec<Waker>,
    /// Live framed sessions per reactor (the pool's analogue of
    /// tracked session threads).
    pub(super) live: Arc<Vec<AtomicU64>>,
    /// Sessions ever pinned to each reactor — the fanout distribution,
    /// observable without metrics scraping.
    pub(super) pinned: Arc<Vec<AtomicU64>>,
}

/// Resolve `Config::reactors` (0 = auto from available cores).
fn resolve_reactors(configured: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(MAX_AUTO_REACTORS)
}

fn bind_std(addr: SocketAddr) -> Result<TcpListener> {
    let listener = TcpListener::bind(addr).context("binding TCP listener")?;
    listener.set_nonblocking(true)?;
    Ok(listener)
}

/// Bind `addr` and spawn the reactor pool over it, per the pipeline's
/// `reactors`/`poller`/`reuseport` config. Binding happens in here —
/// not the caller — because an SO_REUSEPORT group must set the option
/// before bind on every member socket, which std's `TcpListener::bind`
/// cannot retrofit.
pub(super) fn start_pool(
    addr: SocketAddr,
    pipeline: Arc<Pipeline>,
    stop: Arc<AtomicBool>,
    sessions_total: Arc<AtomicU64>,
) -> Result<PoolHandle> {
    let cfg = pipeline.config();
    let n = resolve_reactors(cfg.reactors);
    let poller_kind = cfg.poller;
    // Build every backend up front so an unsupported selection (epoll
    // off Linux) fails the listener start, not a spawned thread.
    let mut pollers: Vec<Box<dyn Poller>> = Vec::with_capacity(n);
    for _ in 0..n {
        pollers.push(poller::build(poller_kind).context("building poller backend")?);
    }

    // Accept plan: per-reactor SO_REUSEPORT listeners where the group
    // binds, else one listener on reactor 0 with fd handoff.
    let mut listener_slots: Vec<Option<TcpListener>>;
    let handoff: bool;
    if n > 1 && cfg.reuseport {
        match reuseport::bind_group(addr, n) {
            Ok(group) => {
                listener_slots = group.into_iter().map(Some).collect();
                handoff = false;
            }
            Err(e) => {
                info!("SO_REUSEPORT group unavailable ({e}); using in-process fd handoff");
                let mut slots: Vec<Option<TcpListener>> = (0..n).map(|_| None).collect();
                slots[0] = Some(bind_std(addr)?);
                listener_slots = slots;
                handoff = true;
            }
        }
    } else {
        let mut slots: Vec<Option<TcpListener>> = (0..n).map(|_| None).collect();
        slots[0] = Some(bind_std(addr)?);
        listener_slots = slots;
        handoff = true;
    }
    let local_addr = listener_slots[0]
        .as_ref()
        .expect("reactor 0 always holds a listener")
        .local_addr()?;

    let live: Arc<Vec<AtomicU64>> = Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
    let pinned: Arc<Vec<AtomicU64>> = Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
    let mut wakers: Vec<Waker> = Vec::with_capacity(n);
    let mut waker_rxs: Vec<UnixStream> = Vec::with_capacity(n);
    for _ in 0..n {
        let (w, rx) = Waker::pair().context("creating reactor self-pipe")?;
        wakers.push(w);
        waker_rxs.push(rx);
    }
    let inboxes: Vec<Inbox> = (0..n).map(|_| Arc::new(Mutex::new(VecDeque::new()))).collect();
    let mut dispatch = if handoff && n > 1 {
        Some(Dispatch { inboxes: inboxes.clone(), wakers: wakers.clone(), next: 0 })
    } else {
        None
    };

    info!(
        "sfut reactor pool serving framed wire on {local_addr} (reactors={n}, fanout={}, \
         poller={})",
        if handoff { "handoff" } else { "reuseport" },
        poller_kind.label(),
    );

    let mut threads = Vec::with_capacity(n);
    let mut rx_iter = waker_rxs.into_iter();
    let mut poller_iter = pollers.into_iter();
    let mut listener_iter = listener_slots.drain(..);
    for r in 0..n {
        let reactor = Reactor {
            id: r,
            pipeline: Arc::clone(&pipeline),
            listener: listener_iter.next().unwrap(),
            dispatch: if r == 0 { dispatch.take() } else { None },
            inbox: Arc::clone(&inboxes[r]),
            stop: Arc::clone(&stop),
            sessions_total: Arc::clone(&sessions_total),
            live: Arc::clone(&live),
            pinned: Arc::clone(&pinned),
            waker: wakers[r].clone(),
            waker_rx: rx_iter.next().unwrap(),
            ready: Arc::new(Mutex::new(Vec::new())),
            poller: poller_iter.next().unwrap(),
        };
        let thread = std::thread::Builder::new()
            .name(format!("sfut-reactor-{r}"))
            .spawn(move || reactor.run())
            .context("spawning reactor thread")?;
        threads.push(thread);
    }
    Ok(PoolHandle { local_addr, threads, wakers, live, pinned })
}

/// Round-robin fd handoff state, held by the accepting reactor (id 0)
/// when there is no SO_REUSEPORT group.
struct Dispatch {
    inboxes: Vec<Inbox>,
    wakers: Vec<Waker>,
    next: usize,
}

/// Cached metric handles — totals plus this reactor's `wire.<r>.*`
/// shadows — so the hot loop never touches the registry mutex.
struct WireMetrics {
    frames_in: Arc<Counter>,
    frames_in_r: Arc<Counter>,
    frames_out: Arc<Counter>,
    midframe: Arc<Counter>,
    midframe_r: Arc<Counter>,
    read_paused: Arc<Counter>,
    read_paused_r: Arc<Counter>,
    sessions: Arc<Gauge>,
    sessions_r: Arc<Gauge>,
}

impl WireMetrics {
    fn new(m: &MetricsRegistry, r: usize) -> WireMetrics {
        WireMetrics {
            frames_in: m.counter("wire.frames_in"),
            frames_in_r: m.counter(&format!("wire.{r}.frames_in")),
            frames_out: m.counter("wire.frames_out"),
            midframe: m.counter("wire.midframe_disconnects"),
            midframe_r: m.counter(&format!("wire.{r}.midframe_disconnects")),
            read_paused: m.counter("wire.read_paused"),
            read_paused_r: m.counter(&format!("wire.{r}.read_paused")),
            sessions: m.gauge("wire.sessions"),
            sessions_r: m.gauge(&format!("wire.{r}.sessions")),
        }
    }
}

/// One framed connection's state, owned by its pinned reactor thread.
struct Session {
    stream: TcpStream,
    peer: SocketAddr,
    /// Bytes collected toward the 5-byte connect preamble.
    pre: Vec<u8>,
    handshaken: bool,
    decoder: FrameDecoder,
    /// Decoded frames not yet processed — nonempty past index 0 only
    /// while the front is deferred (FIFO order is what lets a client
    /// correlate `Ticket` replies with its submit order).
    input: VecDeque<Frame>,
    /// When the front submit frame was first deferred on a full queue.
    deferred_since: Option<Instant>,
    /// Pending output bytes (encoded frames awaiting socket space).
    out: Vec<u8>,
    tickets: BTreeMap<u64, JobTicket>,
    next_ticket: u64,
    /// Outstanding `Wait`s per ticket (a wait may be issued twice).
    pending_waits: BTreeMap<u64, u32>,
    /// Close once `out` drains; no further input is processed.
    closing: bool,
    /// Client half-closed; finish pending work, then close.
    read_eof: bool,
    /// Currently not polled for readability (flow control).
    read_paused: bool,
    /// Interest currently registered with the poller (None = not yet
    /// registered; a fresh session registers on its first tick).
    registered: Option<Interest>,
}

impl Session {
    fn new(stream: TcpStream, peer: SocketAddr) -> Session {
        Session {
            stream,
            peer,
            pre: Vec::with_capacity(5),
            handshaken: false,
            decoder: FrameDecoder::new(),
            input: VecDeque::new(),
            deferred_since: None,
            out: Vec::new(),
            tickets: BTreeMap::new(),
            next_ticket: 1,
            pending_waits: BTreeMap::new(),
            closing: false,
            read_eof: false,
            read_paused: false,
            registered: None,
        }
    }

    /// Nothing left to do for this client: all input processed, all
    /// waits answered, all output flushed.
    fn finished(&self) -> bool {
        (self.closing && self.out.is_empty())
            || (self.read_eof
                && self.input.is_empty()
                && self.pending_waits.is_empty()
                && self.out.is_empty()
                && self.deferred_since.is_none())
    }
}

struct Reactor {
    id: usize,
    pipeline: Arc<Pipeline>,
    /// This reactor's own listener (every reactor in reuseport mode;
    /// only reactor 0 in handoff mode).
    listener: Option<TcpListener>,
    /// Handoff round-robin (the accepting reactor in handoff mode).
    dispatch: Option<Dispatch>,
    /// Connections handed to this reactor by the dispatcher.
    inbox: Inbox,
    stop: Arc<AtomicBool>,
    sessions_total: Arc<AtomicU64>,
    live: Arc<Vec<AtomicU64>>,
    pinned: Arc<Vec<AtomicU64>>,
    waker: Waker,
    waker_rx: UnixStream,
    ready: ReadyList,
    poller: Box<dyn Poller>,
}

impl Reactor {
    fn run(mut self) {
        let wm = WireMetrics::new(self.pipeline.metrics(), self.id);
        let mut sessions: BTreeMap<u64, Session> = BTreeMap::new();
        let mut next_session: u64 = 1;
        let mut drain_deadline: Option<Instant> = None;
        let mut events: Vec<Event> = Vec::new();
        debug!("reactor {} up (poller={})", self.id, self.poller.label());
        if let Err(e) = self.poller.register(self.waker_rx.as_raw_fd(), TOKEN_WAKER, Interest::READ)
        {
            warn!("reactor {}: cannot register self-pipe ({e}); exiting", self.id);
            return;
        }
        if let Some(l) = &self.listener {
            if let Err(e) = self.poller.register(l.as_raw_fd(), TOKEN_LISTENER, Interest::READ) {
                warn!("reactor {}: cannot register listener ({e}); exiting", self.id);
                return;
            }
        }
        loop {
            let draining = self.stop.load(Ordering::SeqCst);
            if draining {
                let deadline = *drain_deadline.get_or_insert_with(|| Instant::now() + DRAIN_GRACE);
                let busy = sessions.values().any(|s| {
                    !s.pending_waits.is_empty() || !s.out.is_empty() || s.deferred_since.is_some()
                });
                if !busy || Instant::now() >= deadline {
                    final_drain(&wm, &mut sessions);
                    self.live[self.id].store(0, Ordering::Relaxed);
                    wm.sessions_r.set(0);
                    wm.sessions.set(self.live.iter().map(|a| a.load(Ordering::Relaxed)).sum());
                    return;
                }
            }

            // --- adopt connections the dispatcher handed over.
            loop {
                let item = self.inbox.lock().unwrap().pop_front();
                let Some((stream, peer)) = item else { break };
                Self::adopt(self.id, &self.pinned, &mut sessions, &mut next_session, stream, peer);
            }

            // --- interest pass: register fresh sessions, track pause
            // transitions, reconcile what the poller watches.
            let mut any_deferred = false;
            let mut unregisterable: Vec<u64> = Vec::new();
            for (&sid, s) in sessions.iter_mut() {
                let paused = s.out.len() >= HIGH_WATER || s.deferred_since.is_some();
                if paused && !s.read_paused {
                    wm.read_paused.inc();
                    wm.read_paused_r.inc();
                }
                s.read_paused = paused;
                any_deferred |= s.deferred_since.is_some();
                let want = Interest {
                    readable: !s.read_eof && !s.closing && !paused,
                    writable: !s.out.is_empty(),
                };
                let token = sid + TOKEN_SESSION_BASE;
                let outcome = match s.registered {
                    None => self.poller.register(s.stream.as_raw_fd(), token, want),
                    Some(cur) if cur != want => {
                        self.poller.reregister(s.stream.as_raw_fd(), token, want)
                    }
                    Some(_) => Ok(()),
                };
                match outcome {
                    Ok(()) => s.registered = Some(want),
                    Err(e) => {
                        let peer = s.peer;
                        warn!("reactor {}: cannot watch session {peer} ({e}); dropping", self.id);
                        unregisterable.push(sid);
                    }
                }
            }
            for sid in unregisterable {
                sessions.remove(&sid);
            }

            let timeout = if draining {
                20
            } else if any_deferred {
                DEFERRED_POLL_MS
            } else {
                IDLE_POLL_MS
            };
            if let Err(e) = self.poller.wait(&mut events, timeout) {
                warn!("reactor {} wait failed: {e}", self.id);
                std::thread::sleep(Duration::from_millis(10));
            }

            // --- drain the self-pipe (level-triggered; always safe).
            let mut sink = [0u8; 64];
            while matches!((&self.waker_rx).read(&mut sink), Ok(n) if n > 0) {}

            // --- accept new sessions (own listener, if any).
            if !draining {
                self.accept_tick(&mut sessions, &mut next_session);
            }

            // --- read readable sessions, decode, process.
            for ev in &events {
                if ev.token < TOKEN_SESSION_BASE || !ev.readable {
                    continue;
                }
                let sid = ev.token - TOKEN_SESSION_BASE;
                if let Some(s) = sessions.get_mut(&sid) {
                    read_session(&wm, s);
                }
            }
            // Every tick, every session: drives deferred retries and
            // frames decoded this tick alike. Cheap when input is empty.
            for (&sid, s) in sessions.iter_mut() {
                process_input(&self.pipeline, &wm, &self.ready, &self.waker, sid, s);
            }

            // --- completed tickets → Result/Err frames.
            let completed: Vec<(u64, u64)> = std::mem::take(&mut *self.ready.lock().unwrap());
            for (sid, tid) in completed {
                let Some(s) = sessions.get_mut(&sid) else { continue };
                match s.pending_waits.get_mut(&tid) {
                    Some(cnt) => {
                        *cnt -= 1;
                        if *cnt == 0 {
                            s.pending_waits.remove(&tid);
                        }
                    }
                    None => continue,
                }
                answer_wait(&wm, s, tid);
            }

            // --- flush writable output; reap finished sessions.
            let mut dead: Vec<u64> = Vec::new();
            for (&sid, s) in sessions.iter_mut() {
                if !s.out.is_empty() {
                    if let Err(e) = flush_out(s) {
                        debug!("session {}: write failed ({e}); dropping", s.peer);
                        s.out.clear();
                        s.closing = true;
                    }
                }
                if s.finished() {
                    dead.push(sid);
                }
            }
            for sid in dead {
                if let Some(s) = sessions.remove(&sid) {
                    if s.registered.is_some() {
                        let _ = self.poller.deregister(s.stream.as_raw_fd());
                    }
                    debug!("reactor {} closed session {}", self.id, s.peer);
                }
            }
            self.live[self.id].store(sessions.len() as u64, Ordering::Relaxed);
            wm.sessions_r.set(sessions.len() as u64);
            wm.sessions.set(self.live.iter().map(|a| a.load(Ordering::Relaxed)).sum());
        }
    }

    /// Accept whatever the listener has. In handoff mode the accepts
    /// are dealt round-robin across all reactors' inboxes (own sessions
    /// adopted directly); in reuseport mode everything accepted here is
    /// ours — the kernel already did the fanout.
    fn accept_tick(&mut self, sessions: &mut BTreeMap<u64, Session>, next_session: &mut u64) {
        let Some(listener) = &self.listener else { return };
        loop {
            match listener.accept() {
                Ok((stream, peer)) => {
                    self.sessions_total.fetch_add(1, Ordering::Relaxed);
                    match &mut self.dispatch {
                        Some(d) => {
                            let target = d.next % d.inboxes.len();
                            d.next = d.next.wrapping_add(1);
                            if target == self.id {
                                Self::adopt(
                                    self.id,
                                    &self.pinned,
                                    sessions,
                                    next_session,
                                    stream,
                                    peer,
                                );
                            } else {
                                d.inboxes[target].lock().unwrap().push_back((stream, peer));
                                d.wakers[target].wake();
                            }
                        }
                        None => {
                            Self::adopt(self.id, &self.pinned, sessions, next_session, stream, peer)
                        }
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) => {
                    warn!("reactor {} accept error: {e}", self.id);
                    break;
                }
            }
        }
    }

    /// Pin a connection to reactor `id`: from here on, every frame of
    /// this session is parsed, executed, and answered by that one
    /// thread. Registration with the poller happens on the next tick's
    /// interest pass (`registered: None`).
    fn adopt(
        id: usize,
        pinned: &Arc<Vec<AtomicU64>>,
        sessions: &mut BTreeMap<u64, Session>,
        next_session: &mut u64,
        stream: TcpStream,
        peer: SocketAddr,
    ) {
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        pinned[id].fetch_add(1, Ordering::Relaxed);
        debug!("reactor {id} adopted framed session from {peer}");
        sessions.insert(*next_session, Session::new(stream, peer));
        *next_session += 1;
    }
}

fn state_code(state: FutState) -> u8 {
    match state {
        FutState::Empty => 0,
        FutState::Running => 1,
        FutState::Ready => 2,
        FutState::Panicked => 3,
    }
}

fn enqueue(wm: &WireMetrics, s: &mut Session, frame: &Frame) {
    frame.encode_into(&mut s.out);
    wm.frames_out.inc();
}

fn enqueue_err(wm: &WireMetrics, s: &mut Session, id: u64, line: &str) {
    enqueue(wm, s, &Frame::new(FrameKind::Err, line_payload(id, line)));
}

/// Pull whatever the socket has, run the handshake, decode frames.
fn read_session(wm: &WireMetrics, s: &mut Session) {
    let mut buf = [0u8; 8192];
    loop {
        match s.stream.read(&mut buf) {
            Ok(0) => {
                if s.decoder.has_partial() || (!s.pre.is_empty() && !s.handshaken) {
                    // Mid-frame disconnect: nothing to answer — the
                    // bytes that would complete the frame can never
                    // arrive. Close without ceremony.
                    wm.midframe.inc();
                    wm.midframe_r.inc();
                }
                s.read_eof = true;
                break;
            }
            Ok(n) => {
                let mut bytes = &buf[..n];
                if !s.handshaken {
                    let need = 5 - s.pre.len();
                    let take = need.min(bytes.len());
                    s.pre.extend_from_slice(&bytes[..take]);
                    bytes = &bytes[take..];
                    if s.pre.len() == 5 {
                        let mut p = [0u8; 5];
                        p.copy_from_slice(&s.pre);
                        match check_preamble(&p) {
                            Ok(()) => {
                                s.handshaken = true;
                                enqueue(wm, s, &Frame::new(FrameKind::Hello, vec![VERSION]));
                            }
                            Err(e) => {
                                enqueue_err(wm, s, 0, &format!("err {e}"));
                                s.closing = true;
                                return;
                            }
                        }
                    }
                }
                if s.handshaken && !bytes.is_empty() {
                    s.decoder.feed(bytes);
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => {
                debug!("session {}: read failed ({e}); dropping", s.peer);
                s.out.clear();
                s.closing = true;
                return;
            }
        }
    }
    if !s.handshaken || s.closing {
        return;
    }
    loop {
        match s.decoder.next() {
            Ok(Some(frame)) => {
                wm.frames_in.inc();
                wm.frames_in_r.inc();
                s.input.push_back(frame);
            }
            Ok(None) => break,
            Err(e) => {
                // One well-formed err frame, then close — never a
                // panic, never a stuck session.
                enqueue_err(wm, s, 0, &format!("err {e}"));
                s.closing = true;
                break;
            }
        }
    }
}

/// Handle decoded frames in FIFO order. Stops at a submit that the
/// admission queue defers (queue full under a parking policy); the
/// frame stays at the front and is retried next tick.
fn process_input(
    pipeline: &Pipeline,
    wm: &WireMetrics,
    ready: &ReadyList,
    waker: &Waker,
    sid: u64,
    s: &mut Session,
) {
    while !s.closing {
        let Some(frame) = s.input.front().cloned() else { return };
        match frame.kind {
            FrameKind::Submit => {
                let text = match std::str::from_utf8(&frame.payload) {
                    Ok(t) => t.trim().to_string(),
                    Err(_) => {
                        s.input.pop_front();
                        s.deferred_since = None;
                        enqueue_err(wm, s, 0, "err submit payload is not valid utf-8");
                        continue;
                    }
                };
                let req = match JobRequest::parse(&text) {
                    Ok(req) => req,
                    Err(e) => {
                        s.input.pop_front();
                        s.deferred_since = None;
                        enqueue_err(wm, s, 0, &format!("err {e}"));
                        continue;
                    }
                };
                // A deferred submit under `timeout(ms)` that never got a
                // slot expires into the same admission line the parking
                // path emits (same configured `waited_ms`, same counter).
                if let Some(since) = s.deferred_since {
                    if let AdmissionPolicy::Timeout(ms) = pipeline.config().admission {
                        if since.elapsed() >= Duration::from_millis(ms) {
                            pipeline.ingress().note_deferred_timeout();
                            let err = SubmitError::Timeout {
                                waited_ms: ms,
                                queue_depth: pipeline.config().queue_depth,
                            };
                            s.input.pop_front();
                            s.deferred_since = None;
                            enqueue_err(wm, s, 0, &err.render_line(&req));
                            continue;
                        }
                    }
                }
                let first_attempt = s.deferred_since.is_none();
                match pipeline.ingress().try_submit(req.clone(), true, first_attempt) {
                    TryAdmit::Ticket(ticket) => {
                        s.input.pop_front();
                        s.deferred_since = None;
                        let id = s.next_ticket;
                        s.next_ticket += 1;
                        let code = state_code(ticket.state());
                        s.tickets.insert(id, ticket);
                        release_oldest_resolved(&mut s.tickets, MAX_SESSION_TICKETS);
                        enqueue(wm, s, &Frame::new(FrameKind::Ticket, ticket_payload(id, code)));
                    }
                    TryAdmit::Reject(err) => {
                        s.input.pop_front();
                        s.deferred_since = None;
                        enqueue_err(wm, s, 0, &err.render_line(&req));
                    }
                    TryAdmit::Full(_) => {
                        if s.deferred_since.is_none() {
                            s.deferred_since = Some(Instant::now());
                        }
                        return;
                    }
                }
            }
            FrameKind::Wait | FrameKind::Poll => {
                s.input.pop_front();
                let Some((id, _)) = take_ticket_id(&frame.payload) else {
                    enqueue_err(wm, s, 0, "err bad ticket payload (want u64 le id)");
                    continue;
                };
                if id == 0 || id >= s.next_ticket {
                    enqueue_err(
                        wm,
                        s,
                        id,
                        &format!(
                            "err unknown ticket: {id} ({} issued this session)",
                            s.next_ticket - 1
                        ),
                    );
                    continue;
                }
                let Some(ticket) = s.tickets.get(&id) else {
                    enqueue_err(wm, s, id, &err_released_line(id));
                    continue;
                };
                if frame.kind == FrameKind::Poll {
                    let code = state_code(ticket.state());
                    enqueue(wm, s, &Frame::new(FrameKind::Ticket, ticket_payload(id, code)));
                } else if ticket.is_ready() {
                    answer_wait(wm, s, id);
                } else {
                    // Park the wait on the ticket's Fut: completion
                    // pushes onto this reactor's ready list and wakes
                    // its self-pipe — the pinned reactor answers.
                    *s.pending_waits.entry(id).or_insert(0) += 1;
                    let ready = Arc::clone(ready);
                    let waker = waker.clone();
                    ticket.fut().on_complete(move |_| {
                        if let Ok(mut queue) = ready.lock() {
                            queue.push((sid, id));
                        }
                        waker.wake();
                    });
                }
            }
            FrameKind::Workloads => {
                s.input.pop_front();
                let listing = workloads_listing(pipeline);
                enqueue(wm, s, &Frame::new(FrameKind::WorkloadsReply, listing.into_bytes()));
            }
            // Server-to-client kinds arriving from a client are a
            // protocol violation: one err frame, then close.
            FrameKind::Hello
            | FrameKind::Ticket
            | FrameKind::Result
            | FrameKind::Err
            | FrameKind::WorkloadsReply => {
                s.input.pop_front();
                enqueue_err(
                    wm,
                    s,
                    0,
                    &format!("err unexpected client frame kind {}", frame.kind.as_u8()),
                );
                s.closing = true;
            }
        }
    }
}

/// Emit the resolved outcome of `tid` as one `Result`/`Err` frame —
/// the framed analogue of the text server's `deliver`.
fn answer_wait(wm: &WireMetrics, s: &mut Session, tid: u64) {
    let outcome = match s.tickets.get(&tid) {
        Some(ticket) => ticket.wait_timeout(Duration::from_millis(0)),
        None => {
            enqueue_err(wm, s, tid, &err_released_line(tid));
            return;
        }
    };
    match outcome {
        Some(outcome) => deliver_outcome(wm, s, tid, outcome),
        // Completion raced the release path; ask the client to retry.
        None => enqueue_err(wm, s, tid, &format!("err ticket not ready: {tid}")),
    }
}

fn deliver_outcome(wm: &WireMetrics, s: &mut Session, tid: u64, outcome: Result<JobResult>) {
    match outcome {
        Ok(result) => {
            enqueue(wm, s, &Frame::new(FrameKind::Result, line_payload(tid, &result.render_line())))
        }
        Err(e) => enqueue_err(wm, s, tid, &format!("err {e:#}")),
    }
}

/// Nonblocking write of whatever the socket will take.
fn flush_out(s: &mut Session) -> std::io::Result<()> {
    while !s.out.is_empty() {
        match s.stream.write(&s.out) {
            Ok(0) => return Err(ErrorKind::WriteZero.into()),
            Ok(n) => {
                s.out.drain(..n);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Shutdown endgame: every still-parked wait is answered — with the
/// real result if it landed during the grace window, else a final
/// `err closed ticket=N` frame — deferred submits answer `closed`,
/// buffers flush best-effort (briefly blocking), sockets close.
fn final_drain(wm: &WireMetrics, sessions: &mut BTreeMap<u64, Session>) {
    for s in sessions.values_mut() {
        let waits: Vec<(u64, u32)> = s.pending_waits.iter().map(|(&k, &v)| (k, v)).collect();
        s.pending_waits.clear();
        for (tid, count) in waits {
            let resolved = s.tickets.get(&tid).is_some_and(JobTicket::is_ready);
            for _ in 0..count {
                if resolved {
                    answer_wait(wm, s, tid);
                } else {
                    enqueue_err(wm, s, tid, &err_closed_line(tid));
                }
            }
        }
        if s.deferred_since.take().is_some() {
            let line = s
                .input
                .front()
                .and_then(|f| std::str::from_utf8(&f.payload).ok())
                .and_then(|t| JobRequest::parse(t.trim()).ok())
                .map(|req| SubmitError::Closed.render_line(&req))
                .unwrap_or_else(|| "err admission=closed".to_string());
            enqueue_err(wm, s, 0, &line);
        }
        s.input.clear();
        let _ = s.stream.set_nonblocking(false);
        let _ = s.stream.set_write_timeout(Some(Duration::from_millis(200)));
        let out = std::mem::take(&mut s.out);
        let _ = s.stream.write_all(&out);
        let _ = s.stream.shutdown(std::net::Shutdown::Both);
    }
    sessions.clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_reactor_count_is_bounded() {
        assert_eq!(resolve_reactors(3), 3, "explicit count wins");
        let auto = resolve_reactors(0);
        assert!(auto >= 1 && auto <= MAX_AUTO_REACTORS, "auto in 1..={MAX_AUTO_REACTORS}: {auto}");
    }
}

//! Readiness backends for the framed reactor pool.
//!
//! The reactor's event loop is written against one small [`Poller`]
//! trait — register a descriptor under a token with a read/write
//! [`Interest`], wait, get back [`Event`]s — so the O(n)-per-wakeup
//! poll(2) scan that shipped with the first reactor and Linux's
//! O(1)-delivery epoll are interchangeable at runtime
//! ([`crate::config::PollerKind`]: `Config::poller`, `--poller`,
//! `SFUT_POLLER`). The poll backend survives as the portable A/B
//! baseline the epoll numbers are measured against; both speak the
//! same minimal-FFI style (a handful of libc symbols std already
//! links, no event-loop dependency).
//!
//! Semantics both backends guarantee to the reactor:
//!
//! * level-triggered — an undrained socket reports again next wait;
//! * hangup/error readiness is folded into `readable`/`writable`, so a
//!   peer close surfaces even on a descriptor registered with an empty
//!   interest (a flow-control-paused session still notices EOF);
//! * registration state is per-backend and explicit: descriptors must
//!   be deregistered before close (the poll scan would otherwise keep
//!   a stale fd in its set; epoll would drop it silently — the trait
//!   pins the stricter contract).

use std::io;
use std::os::unix::io::RawFd;

use crate::config::PollerKind;

/// What a registered descriptor should be watched for. An empty
/// interest keeps the descriptor in the set for hangup/error
/// notification only (how the reactor parks a flow-controlled
/// session).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub(super) struct Interest {
    pub(super) readable: bool,
    pub(super) writable: bool,
}

impl Interest {
    pub(super) const READ: Interest = Interest { readable: true, writable: false };
}

/// One readiness notification. Hangup/error conditions set both
/// directions — the owner's read/write will surface the actual error.
#[derive(Clone, Copy, Debug)]
pub(super) struct Event {
    pub(super) token: u64,
    pub(super) readable: bool,
    pub(super) writable: bool,
}

/// A readiness backend. One instance per reactor thread; implementors
/// are `Send` (the pool builds them on the spawning thread) but never
/// shared.
pub(super) trait Poller: Send {
    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()>;
    fn reregister(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()>;
    fn deregister(&mut self, fd: RawFd) -> io::Result<()>;
    /// Clear `events`, then block up to `timeout_ms` (-1 = forever)
    /// collecting ready descriptors.
    fn wait(&mut self, events: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()>;
    /// The backend's bench/config label (`poll` / `epoll`).
    fn label(&self) -> &'static str;
}

/// Build the backend `kind` resolves to on this platform. `auto`
/// resolves to epoll on Linux and poll elsewhere; asking for epoll on
/// a non-Linux platform is an error (callers surface it at listener
/// start, mirroring framed-on-non-unix).
pub(super) fn build(kind: PollerKind) -> io::Result<Box<dyn Poller>> {
    match kind.resolved() {
        PollerKind::Poll => Ok(Box::new(PollBackend::new())),
        PollerKind::Epoll => new_epoll(),
        PollerKind::Auto => unreachable!("PollerKind::resolved never returns Auto"),
    }
}

#[cfg(target_os = "linux")]
fn new_epoll() -> io::Result<Box<dyn Poller>> {
    Ok(Box::new(EpollBackend::new()?))
}

#[cfg(not(target_os = "linux"))]
fn new_epoll() -> io::Result<Box<dyn Poller>> {
    Err(io::Error::new(
        io::ErrorKind::Unsupported,
        "poller=epoll requires linux (use poll, or auto to pick per platform)",
    ))
}

mod sys {
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
    }

    /// `poll(2)` with EINTR retry.
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        loop {
            // SAFETY: `fds` is a live, exclusively-borrowed slice of
            // repr(C) PollFd, so the pointer + length describe exactly
            // the array poll(2) may read and write for its duration.
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as u64, timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = std::io::Error::last_os_error();
            if err.kind() != std::io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

/// The portable baseline: a registration list rebuilt into a `pollfd`
/// array on every wait. Readiness costs O(registered descriptors) per
/// wakeup — exactly the scan the epoll backend exists to beat, kept
/// selectable so the saturation trajectory can measure the difference.
pub(super) struct PollBackend {
    entries: Vec<(RawFd, u64, Interest)>,
    /// Scratch reused across waits (no per-tick allocation once warm).
    fds: Vec<sys::PollFd>,
}

impl PollBackend {
    pub(super) fn new() -> PollBackend {
        PollBackend { entries: Vec::new(), fds: Vec::new() }
    }

    fn position(&self, fd: RawFd) -> Option<usize> {
        self.entries.iter().position(|&(f, _, _)| f == fd)
    }
}

impl Poller for PollBackend {
    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        if self.position(fd).is_some() {
            return Err(io::Error::new(
                io::ErrorKind::AlreadyExists,
                format!("fd {fd} already registered"),
            ));
        }
        self.entries.push((fd, token, interest));
        Ok(())
    }

    fn reregister(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match self.position(fd) {
            Some(i) => {
                self.entries[i] = (fd, token, interest);
                Ok(())
            }
            None => Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("fd {fd} not registered"),
            )),
        }
    }

    fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match self.position(fd) {
            Some(i) => {
                self.entries.remove(i);
                Ok(())
            }
            None => Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("fd {fd} not registered"),
            )),
        }
    }

    fn wait(&mut self, events: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
        events.clear();
        self.fds.clear();
        for &(fd, _, interest) in &self.entries {
            let mut ev: i16 = 0;
            if interest.readable {
                ev |= sys::POLLIN;
            }
            if interest.writable {
                ev |= sys::POLLOUT;
            }
            self.fds.push(sys::PollFd { fd, events: ev, revents: 0 });
        }
        sys::poll_fds(&mut self.fds, timeout_ms)?;
        for (i, pfd) in self.fds.iter().enumerate() {
            let hup = pfd.revents & (sys::POLLERR | sys::POLLHUP) != 0;
            let readable = pfd.revents & sys::POLLIN != 0 || hup;
            let writable = pfd.revents & sys::POLLOUT != 0 || hup;
            if readable || writable {
                events.push(Event { token: self.entries[i].1, readable, writable });
            }
        }
        Ok(())
    }

    fn label(&self) -> &'static str {
        "poll"
    }
}

#[cfg(target_os = "linux")]
mod esys {
    /// The kernel's `struct epoll_event`; packed on x86/x86_64 only
    /// (the one ABI quirk of the interface).
    #[repr(C)]
    #[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;

    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLL_CLOEXEC: i32 = 0o2000000;

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32)
            -> i32;
        pub fn close(fd: i32) -> i32;
    }
}

/// Linux epoll: the kernel holds the interest set, `epoll_wait`
/// returns only ready descriptors — wakeup cost no longer scales with
/// session count.
#[cfg(target_os = "linux")]
pub(super) struct EpollBackend {
    epfd: RawFd,
    /// Scratch event buffer (one `epoll_wait` batch; level-triggered
    /// delivery re-reports anything beyond it on the next wait).
    buf: Vec<esys::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl EpollBackend {
    const MAX_EVENTS: usize = 256;

    pub(super) fn new() -> io::Result<EpollBackend> {
        // SAFETY: no pointer arguments; the returned fd (checked below)
        // is owned by the EpollBackend until its Drop closes it.
        let epfd = unsafe { esys::epoll_create1(esys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        let buf = vec![esys::EpollEvent { events: 0, data: 0 }; Self::MAX_EVENTS];
        Ok(EpollBackend { epfd, buf })
    }

    fn ctl(&mut self, op: i32, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        let mut events: u32 = 0;
        if interest.readable {
            events |= esys::EPOLLIN;
        }
        if interest.writable {
            events |= esys::EPOLLOUT;
        }
        // DEL ignores the event argument on any kernel this runs on,
        // but pre-2.6.9 required it non-null — always pass one.
        let mut ev = esys::EpollEvent { events, data: token };
        // SAFETY: `epfd` is the live epoll fd this backend owns, and
        // `ev` is a stack value that outlives the call (epoll_ctl only
        // reads it; the kernel keeps its own copy).
        let rc = unsafe { esys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl Poller for EpollBackend {
    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        self.ctl(esys::EPOLL_CTL_ADD, fd, token, interest)
    }

    fn reregister(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        self.ctl(esys::EPOLL_CTL_MOD, fd, token, interest)
    }

    fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        self.ctl(esys::EPOLL_CTL_DEL, fd, 0, Interest::default())
    }

    fn wait(&mut self, events: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
        events.clear();
        let n = loop {
            // SAFETY: `buf` is a live Vec of MAX_EVENTS initialized
            // EpollEvents owned by self — the pointer + capacity bound
            // exactly the array epoll_wait may fill.
            let rc = unsafe {
                esys::epoll_wait(
                    self.epfd,
                    self.buf.as_mut_ptr(),
                    self.buf.len() as i32,
                    timeout_ms,
                )
            };
            if rc >= 0 {
                break rc as usize;
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        };
        for i in 0..n {
            // EpollEvent is repr(packed) on x86: copy the whole struct
            // out of the buffer first so the field reads below are from
            // an aligned local, never references into a packed array.
            let ev = self.buf[i];
            let mask = ev.events;
            let hup = mask & (esys::EPOLLERR | esys::EPOLLHUP) != 0;
            let readable = mask & esys::EPOLLIN != 0 || hup;
            let writable = mask & esys::EPOLLOUT != 0 || hup;
            if readable || writable {
                events.push(Event { token: ev.data, readable, writable });
            }
        }
        Ok(())
    }

    fn label(&self) -> &'static str {
        "epoll"
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollBackend {
    fn drop(&mut self) {
        // SAFETY: this backend is the sole owner of `epfd` (created in
        // `new`, never duplicated or exposed), so closing it here
        // cannot invalidate anyone else's descriptor.
        unsafe {
            esys::close(self.epfd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    /// The contract the reactor leans on, run against a backend:
    /// silence before data, readable after, interest swap to writable,
    /// silence after deregister.
    fn exercise(p: &mut dyn Poller) {
        let (mut a, b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        p.register(b.as_raw_fd(), 7, Interest::READ).unwrap();
        let mut events = Vec::new();
        p.wait(&mut events, 0).unwrap();
        assert!(events.is_empty(), "{}: no data, no events", p.label());
        a.write_all(b"x").unwrap();
        p.wait(&mut events, 1000).unwrap();
        assert_eq!(events.len(), 1, "{}: one ready fd", p.label());
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);
        let mut sink = [0u8; 8];
        let _ = (&b).read(&mut sink);
        p.reregister(b.as_raw_fd(), 7, Interest { readable: false, writable: true }).unwrap();
        p.wait(&mut events, 1000).unwrap();
        assert!(
            events.iter().any(|e| e.token == 7 && e.writable),
            "{}: unqueued socket is writable",
            p.label()
        );
        p.deregister(b.as_raw_fd()).unwrap();
        a.write_all(b"y").unwrap();
        p.wait(&mut events, 0).unwrap();
        assert!(events.is_empty(), "{}: deregistered fd reports nothing", p.label());
    }

    #[test]
    fn poll_backend_delivers_readiness() {
        let mut p = build(PollerKind::Poll).unwrap();
        assert_eq!(p.label(), "poll");
        exercise(p.as_mut());
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_backend_delivers_readiness() {
        let mut p = build(PollerKind::Epoll).unwrap();
        assert_eq!(p.label(), "epoll");
        exercise(p.as_mut());
    }

    #[test]
    fn auto_resolves_to_a_working_backend() {
        let mut p = build(PollerKind::Auto).unwrap();
        exercise(p.as_mut());
    }

    #[test]
    fn registration_errors_are_loud() {
        // Registration-list bookkeeping only exists in the poll scan;
        // epoll's is the kernel's (EEXIST/ENOENT), covered by `ctl`'s
        // error path.
        let mut p = PollBackend::new();
        let (_a, b) = UnixStream::pair().unwrap();
        p.register(b.as_raw_fd(), 1, Interest::READ).unwrap();
        assert!(p.register(b.as_raw_fd(), 2, Interest::READ).is_err());
        assert!(p.reregister(999, 1, Interest::READ).is_err());
        assert!(p.deregister(999).is_err());
        p.deregister(b.as_raw_fd()).unwrap();
    }

    #[cfg(not(target_os = "linux"))]
    #[test]
    fn epoll_off_linux_is_a_clean_error() {
        assert!(build(PollerKind::Epoll).is_err());
    }
}

//! Framed-vs-text wire saturation benchmark with a machine-readable
//! trajectory (`BENCH_ingress.json`).
//!
//! The event-loop ingress replaced thread-per-session TCP with a
//! reactor pool speaking length-prefixed frames; this harness is its
//! A/B evidence and regression tripwire. One invocation sweeps **both**
//! wire modes over a connection-count ladder against otherwise
//! identical pipelines — and, on the framed side, over the readiness
//! backends (`poll` vs `epoll`) and a reactor-count ladder, so the
//! O(n)-scan-vs-O(1)-delivery and single-vs-multi-reactor claims are
//! measured, not asserted. Per (wire, poller, reactors, connections)
//! cell, `connections` client threads each drive `jobs_per_connection`
//! submit→wait round-trips through a real TCP listener
//! ([`TcpServer::start_wire`]) — [`FramedClient`] frames on the
//! reactors, `run <spec>` lines on the thread-per-session baseline —
//! with the same warmup + median-of-samples discipline as the other
//! trajectories ([`measure`]). Reported per cell: jobs/sec, per-job
//! p50/p95, and the ingress shed rate over the cell. Text cells carry
//! `poller: "none"`, `reactors: 0` — the dimensions are meaningless
//! off the event loop.
//!
//! Seeding discipline matches `BENCH_pipeline.json`: the committed
//! file is a synthetic floor baseline, `cargo test` seeds only when
//! absent, and `cargo bench --bench ingress_wire` overwrites — that
//! bench target is how CI (`ci/check_bench.sh ingress`) regenerates
//! the current run for the gate. `SFUT_INGRESS_BENCH_FORCE=1` lets the
//! test-side seeder overwrite too.
//! [`gate`] compares like cells only and **hard-errors unless the
//! current run carries both framed and text rows**: a harness that
//! silently dropped one side of the A/B must fail the gate, not pass
//! it on the surviving half.

use std::io::{BufRead, BufReader, Write as _};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::{
    fmt_f64, measure, BenchOptions, BenchPoint, BenchReport, GateOutcome, GateReport,
    LatencyGate, Provenance, BENCH_SCHEMA_VERSION,
};
use crate::config::{Config, PollerKind, WireProtocol};
use crate::coordinator::{Pipeline, TcpServer};
use crate::testkit::wire::{FramedClient, SubmitReply};

/// Shape of one saturation sweep.
#[derive(Debug, Clone)]
pub struct IngressBenchParams {
    /// Wire modes to sweep — both, for the A/B (text-only off unix,
    /// where the poll reactor is unavailable).
    pub wires: Vec<WireProtocol>,
    /// Readiness backends the framed cells sweep (ignored for text).
    pub pollers: Vec<PollerKind>,
    /// Reactor counts the framed cells sweep (ignored for text).
    pub reactor_counts: Vec<usize>,
    /// Concurrent connections per cell, ascending.
    pub connections: Vec<usize>,
    /// Submit→wait round-trips each connection drives per sample.
    pub jobs_per_connection: usize,
    /// Request spec every job runs, e.g. `primes par(2)`.
    pub spec: String,
}

impl Default for IngressBenchParams {
    fn default() -> Self {
        IngressBenchParams {
            wires: default_wires(),
            pollers: default_pollers(),
            reactor_counts: vec![1, 2],
            connections: vec![1, 2],
            jobs_per_connection: 3,
            spec: "primes par(2)".to_string(),
        }
    }
}

/// Both wire modes on unix; the framed reactor needs poll(2), so other
/// platforms sweep the text baseline only.
pub fn default_wires() -> Vec<WireProtocol> {
    if cfg!(unix) {
        vec![WireProtocol::Framed, WireProtocol::Text]
    } else {
        vec![WireProtocol::Text]
    }
}

/// Both readiness backends where both exist: the poll/epoll A/B is the
/// point of the poller dimension, so Linux sweeps both; other unix
/// platforms only have the poll scan.
pub fn default_pollers() -> Vec<PollerKind> {
    if cfg!(target_os = "linux") {
        vec![PollerKind::Poll, PollerKind::Epoll]
    } else {
        vec![PollerKind::Poll]
    }
}

/// Poller-ladder override: `SFUT_INGRESS_POLLERS="poll,epoll"`.
/// `auto` is resolved to its concrete backend — cells name what ran.
pub fn pollers_from_env() -> Option<Vec<PollerKind>> {
    let raw = std::env::var("SFUT_INGRESS_POLLERS").ok()?;
    let pollers: Vec<PollerKind> = raw
        .split(',')
        .map(|t| {
            t.trim()
                .parse::<PollerKind>()
                .unwrap_or_else(|_| panic!("bad SFUT_INGRESS_POLLERS: {raw}"))
                .resolved()
        })
        .collect();
    assert!(!pollers.is_empty(), "SFUT_INGRESS_POLLERS must name at least one backend");
    Some(pollers)
}

/// Reactor-ladder override: `SFUT_INGRESS_REACTORS="1,2,4"`.
pub fn reactor_counts_from_env() -> Option<Vec<usize>> {
    let raw = std::env::var("SFUT_INGRESS_REACTORS").ok()?;
    let counts: Vec<usize> = raw
        .split(',')
        .map(|t| t.trim().parse().unwrap_or_else(|_| panic!("bad SFUT_INGRESS_REACTORS: {raw}")))
        .collect();
    assert!(!counts.is_empty(), "SFUT_INGRESS_REACTORS must name at least one count");
    Some(counts)
}

/// Connection ladder override: `SFUT_INGRESS_CONNS="1,2,4"`.
pub fn connections_from_env() -> Option<Vec<usize>> {
    let raw = std::env::var("SFUT_INGRESS_CONNS").ok()?;
    let conns: Vec<usize> = raw
        .split(',')
        .map(|t| t.trim().parse().unwrap_or_else(|_| panic!("bad SFUT_INGRESS_CONNS: {raw}")))
        .collect();
    assert!(!conns.is_empty(), "SFUT_INGRESS_CONNS must name at least one count");
    Some(conns)
}

/// Jobs-per-connection override: `SFUT_INGRESS_JOBS=5`.
pub fn jobs_from_env() -> Option<usize> {
    let raw = std::env::var("SFUT_INGRESS_JOBS").ok()?;
    Some(raw.parse().unwrap_or_else(|_| panic!("bad SFUT_INGRESS_JOBS: {raw}")))
}

/// One (wire, poller, reactors, connections) cell.
#[derive(Debug, Clone)]
pub struct WirePoint {
    pub wire: String,
    /// Readiness backend the framed cell ran on (`"none"` for text).
    pub poller: String,
    /// Reactor threads the framed cell ran (0 for text).
    pub reactors: usize,
    pub connections: usize,
    /// Jobs per timed sample (connections × jobs_per_connection).
    pub jobs_per_sample: u64,
    pub jobs_per_sec: f64,
    /// Per-job submit→result round-trip percentiles across post-warmup
    /// samples.
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// Ingress submissions shed or timed out ÷ submissions over the
    /// cell (0 under the default `block` policy).
    pub shed_rate: f64,
}

/// The full A/B sweep.
#[derive(Debug, Clone)]
pub struct IngressBench {
    pub profile: &'static str,
    pub scale: f64,
    pub spec: String,
    pub connections: Vec<usize>,
    pub jobs_per_connection: usize,
    pub warmup: usize,
    pub samples: usize,
    /// Where this run came from (commit, dirty flag, toolchain, …).
    pub provenance: Provenance,
    pub points: Vec<WirePoint>,
}

fn build_profile() -> &'static str {
    if cfg!(debug_assertions) {
        "debug"
    } else {
        "release"
    }
}

fn counter(pipeline: &Pipeline, name: &str) -> u64 {
    pipeline.metrics().snapshot().counters.get(name).copied().unwrap_or(0)
}

fn percentile_ms(sorted: &[Duration], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    super::sampler::percentile_sorted(sorted, q).as_secs_f64() * 1e3
}

/// One framed connection's share of a sample: submit→wait round-trips,
/// recording only completed (`ok`) jobs' latencies.
fn drive_framed(addr: std::net::SocketAddr, spec: &str, jobs: usize, lat: &Mutex<Vec<Duration>>) {
    let mut client = FramedClient::connect(addr).expect("bench framed connect");
    for _ in 0..jobs {
        let t = Instant::now();
        match client.submit(spec).expect("bench framed submit") {
            SubmitReply::Ticket { id, .. } => {
                let line = client.wait(id).expect("bench framed wait");
                if line.starts_with("ok ") {
                    lat.lock().unwrap().push(t.elapsed());
                }
            }
            SubmitReply::Err(_) => {} // shed — accounted via the counters
        }
    }
}

/// The text-baseline counterpart: `run <spec>` lines on one session.
fn drive_text(addr: std::net::SocketAddr, spec: &str, jobs: usize, lat: &Mutex<Vec<Duration>>) {
    let sock = TcpStream::connect(addr).expect("bench text connect");
    let mut reader = BufReader::new(sock.try_clone().expect("clone bench socket"));
    let mut sock = sock;
    for _ in 0..jobs {
        let t = Instant::now();
        writeln!(sock, "run {spec}").expect("bench text submit");
        sock.flush().expect("bench text flush");
        let mut line = String::new();
        reader.read_line(&mut line).expect("bench text reply");
        if line.starts_with("ok ") {
            lat.lock().unwrap().push(t.elapsed());
        }
    }
}

/// Run the sweep: per (wire, poller, reactors, connections) cell a
/// fresh [`Pipeline`] and listener, then `warmup + samples` batches of
/// `connections × jobs_per_connection` round-trips. Framed cells cross
/// the poller and reactor ladders; text has neither dimension and runs
/// one variant per connection count.
pub fn run(
    base: &Config,
    params: &IngressBenchParams,
    opts: &BenchOptions,
) -> Result<IngressBench> {
    let mut points = Vec::new();
    for &wire in &params.wires {
        let variants: Vec<(Option<PollerKind>, usize)> = match wire {
            WireProtocol::Framed => {
                let mut v = Vec::new();
                for &p in &params.pollers {
                    for &n in &params.reactor_counts {
                        v.push((Some(p.resolved()), n));
                    }
                }
                v
            }
            WireProtocol::Text => vec![(None, 0)],
        };
        for &(poller, reactors) in &variants {
            for &connections in &params.connections {
                let mut cfg = base.clone();
                if let Some(p) = poller {
                    cfg.poller = p;
                    cfg.reactors = reactors;
                }
                let pipeline = Arc::new(Pipeline::new(cfg)?);
                let server = TcpServer::start_wire(Arc::clone(&pipeline), "127.0.0.1:0", wire)
                    .with_context(|| format!("starting {} listener", wire.label()))?;
                let addr = server.local_addr();
                let batch = connections * params.jobs_per_connection;
                let submitted_before = counter(&pipeline, "ingress.submitted");
                let shed_before =
                    counter(&pipeline, "ingress.shed") + counter(&pipeline, "ingress.timed_out");
                let lat = Mutex::new(Vec::<Duration>::new());
                let label = match poller {
                    Some(p) => format!(
                        "ingress.framed.{}.r{reactors}.conns{connections}",
                        p.label()
                    ),
                    None => format!("ingress.text.conns{connections}"),
                };
                let timing = measure(&label, opts, || {
                    std::thread::scope(|s| {
                        for _ in 0..connections {
                            s.spawn(|| match wire {
                                WireProtocol::Framed => drive_framed(
                                    addr,
                                    &params.spec,
                                    params.jobs_per_connection,
                                    &lat,
                                ),
                                WireProtocol::Text => drive_text(
                                    addr,
                                    &params.spec,
                                    params.jobs_per_connection,
                                    &lat,
                                ),
                            });
                        }
                    });
                });
                // Drop the warmup batches' samples, same as
                // pipeline_bench.
                let mut all = lat.into_inner().unwrap();
                let keep_from = (opts.warmup * batch).min(all.len());
                let mut kept = all.split_off(keep_from);
                kept.sort_unstable();
                let submitted = counter(&pipeline, "ingress.submitted") - submitted_before;
                let shed = counter(&pipeline, "ingress.shed")
                    + counter(&pipeline, "ingress.timed_out")
                    - shed_before;
                points.push(WirePoint {
                    wire: wire.label().to_string(),
                    poller: poller.map_or_else(|| "none".to_string(), |p| p.label().to_string()),
                    reactors,
                    connections,
                    jobs_per_sample: batch as u64,
                    jobs_per_sec: batch as f64 / timing.median_secs().max(1e-9),
                    p50_ms: percentile_ms(&kept, 0.5),
                    p95_ms: percentile_ms(&kept, 0.95),
                    shed_rate: if submitted == 0 { 0.0 } else { shed as f64 / submitted as f64 },
                });
                drop(server);
            }
        }
    }
    Ok(IngressBench {
        profile: build_profile(),
        scale: base.scale,
        spec: params.spec.clone(),
        connections: params.connections.clone(),
        jobs_per_connection: params.jobs_per_connection,
        warmup: opts.warmup,
        samples: opts.samples,
        provenance: Provenance::capture(0, base.scale),
        points,
    })
}

/// Render one cell in the unified [`BenchPoint`] shape (schema v1):
/// the `(wire, poller, reactors, connections)` identity under `labels`,
/// the measurements under `metrics`. The plan runner
/// ([`super::plan::run_plan`]) reuses this to feed grid cells into the
/// results registry.
pub fn unified_point(p: &WirePoint) -> BenchPoint {
    let mut point = BenchPoint::default();
    point.labels.insert("wire".to_string(), p.wire.clone());
    point.labels.insert("poller".to_string(), p.poller.clone());
    point.labels.insert("reactors".to_string(), p.reactors.to_string());
    point.labels.insert("connections".to_string(), p.connections.to_string());
    for (key, value) in [
        ("jobs_per_sample", p.jobs_per_sample as f64),
        ("jobs_per_sec", p.jobs_per_sec),
        ("p50_ms", p.p50_ms),
        ("p95_ms", p.p95_ms),
        ("shed_rate", p.shed_rate),
    ] {
        point.metrics.insert(key.to_string(), value);
    }
    point
}

/// Serialize to the versioned `BENCH_ingress.json` schema (hand-rolled;
/// no serde offline). Readable back via [`BenchReport::parse`] /
/// [`gate`], which also still accept the pre-v1 flat point shape.
pub fn to_json(b: &IngressBench) -> String {
    let connections =
        b.connections.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(", ");
    let points = b
        .points
        .iter()
        .map(|p| format!("    {}", unified_point(p).to_json()))
        .collect::<Vec<_>>()
        .join(",\n");
    format!(
        "{{\n\
         \x20 \"schema_version\": {},\n\
         \x20 \"bench\": \"ingress_wire_saturation\",\n\
         \x20 \"profile\": \"{}\",\n\
         \x20 \"scale\": {},\n\
         \x20 \"spec\": \"{}\",\n\
         \x20 \"connections\": [{}],\n\
         \x20 \"jobs_per_connection\": {},\n\
         \x20 \"warmup\": {},\n\
         \x20 \"samples\": {},\n\
         \x20 \"provenance\": {},\n\
         \x20 \"points\": [\n{}\n  ]\n\
         }}\n",
        BENCH_SCHEMA_VERSION,
        b.profile,
        fmt_f64(b.scale),
        b.spec,
        connections,
        b.jobs_per_connection,
        b.warmup,
        b.samples,
        b.provenance.to_json(),
        points,
    )
}

pub fn write_json(b: &IngressBench, path: &Path) -> std::io::Result<()> {
    std::fs::write(path, to_json(b))
}

/// Default artifact location: the repository root.
pub fn default_output_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_ingress.json")
}

/// Seed the trajectory file only when absent — unless
/// `SFUT_INGRESS_BENCH_FORCE=1`, the CI hook that regenerates the
/// current run for the gate.
pub fn write_json_if_absent(b: &IngressBench) -> std::io::Result<bool> {
    let path = default_output_path();
    let force = std::env::var("SFUT_INGRESS_BENCH_FORCE").is_ok_and(|v| v == "1");
    if path.exists() && !force {
        return Ok(false);
    }
    write_json(b, &path).map(|()| true)
}

/// Absolute p95 growth ignored below this floor (micro-cells jitter).
const LATENCY_WARN_FLOOR_MS: f64 = 1.0;

/// Compare two `BENCH_ingress.json` documents. Semantics mirror
/// `pipeline_bench::gate` — jobs/sec throughput gate per comparable
/// (wire, poller, reactors, connections) cell, p95 warn-or-strict with
/// the synthetic-baseline disarm, Skipped on incomparable run
/// parameters, hard error on a malformed current run — plus extra
/// invariants:
///
/// * **the current run must carry at least one framed and one text
///   cell** — the trajectory exists to compare the two wires; a
///   one-sided run means the harness broke, and that fails the gate
///   rather than quietly gating the surviving mode;
/// * **multi-reactor cells compare only like-for-like** — a framed
///   cell matches a baseline cell only on identical poller *and*
///   reactor count (pre-pool baselines without the fields default to
///   `poll`/1 reactor for framed, `none`/0 for text, so old baselines
///   stay comparable);
/// * **a poller the baseline covers must appear in the current run** —
///   losing the epoll (or poll) column is a silent 100% regression on
///   that side of the backend A/B and fails the gate.
pub fn gate(
    baseline: &str,
    current: &str,
    threshold: f64,
    latency_threshold: f64,
    latency_strict: bool,
) -> Result<GateReport, String> {
    let b = BenchReport::parse(baseline).map_err(|e| format!("baseline: {e}"))?;
    let c = BenchReport::parse(current).map_err(|e| format!("current: {e}"))?;
    for doc in [&b, &c] {
        if doc.bench != "ingress_wire_saturation" {
            return Err("not an ingress_wire_saturation trajectory file".to_string());
        }
    }
    if c.param("profile").is_none() {
        return Err("current run is missing \"profile\" — bench writer broken".to_string());
    }
    struct Cell {
        wire: String,
        poller: String,
        reactors: u64,
        connections: u64,
        jobs_per_sec: f64,
        p95_ms: Option<f64>,
    }
    // Pre-pool baselines lack the poller/reactors labels; the
    // normalizer in [`BenchReport::parse`] already defaulted those cells
    // to (poll, 1) for framed / (none, 0) for text, so old baselines
    // stay comparable like-for-like.
    let cells = |doc: &BenchReport| -> Vec<Cell> {
        doc.points
            .iter()
            .filter_map(|p| {
                Some(Cell {
                    wire: p.label("wire")?.to_string(),
                    poller: p.label("poller").unwrap_or("none").to_string(),
                    reactors: p.label_u64("reactors").unwrap_or(0),
                    connections: p.label_u64("connections")?,
                    jobs_per_sec: p.metric("jobs_per_sec")?,
                    p95_ms: p.metric("p95_ms"),
                })
            })
            .collect()
    };
    let cur_cells = cells(&c);
    if cur_cells.is_empty() {
        return Err("current run has no points — bench writer broken".to_string());
    }
    // The A/B invariant: one harness invocation must produce both
    // sides. (Checked before comparability — a one-sided writer is
    // broken regardless of whether the baseline matches.)
    for wire in ["framed", "text"] {
        if !cur_cells.iter().any(|cell| cell.wire == wire) {
            return Err(format!(
                "current run has no {wire} cells — the A/B harness must sweep both wire \
                 modes in one invocation"
            ));
        }
    }
    let synthetic_baseline = b.note.as_deref().is_some_and(|n| n.contains("synthetic"));
    let latency_gate = if !latency_strict {
        LatencyGate::WarnOnly
    } else if synthetic_baseline {
        LatencyGate::StrictDisarmedSyntheticBaseline
    } else {
        LatencyGate::Strict
    };
    for key in ["profile", "scale", "spec", "jobs_per_connection", "warmup", "samples"] {
        let (bv, cv) = (b.param(key), c.param(key));
        if bv != cv {
            return Ok(GateReport {
                outcome: GateOutcome::Skipped {
                    reason: format!(
                        "{key} differs (baseline {bv:?}, current {cv:?}); runs are not \
                         comparable — refresh the committed baseline"
                    ),
                },
                warnings: Vec::new(),
                latency_gate,
            });
        }
    }
    let base_cells = cells(&b);
    let mut compared = 0usize;
    let mut regressions = Vec::new();
    let mut latency_findings = Vec::new();
    // A framed cell's performance is a function of its backend and its
    // reactor count — only identical (poller, reactors) cells compare.
    let cell_name = |cell: &Cell| -> String {
        if cell.wire == "framed" {
            format!("framed[{}, r{}]", cell.poller, cell.reactors)
        } else {
            cell.wire.clone()
        }
    };
    for cur in &cur_cells {
        let Some(base) = base_cells.iter().find(|b| {
            b.wire == cur.wire
                && b.poller == cur.poller
                && b.reactors == cur.reactors
                && b.connections == cur.connections
        }) else {
            continue;
        };
        compared += 1;
        if cur.jobs_per_sec < (1.0 - threshold) * base.jobs_per_sec {
            let drop_pct = (1.0 - cur.jobs_per_sec / base.jobs_per_sec.max(1e-9)) * 100.0;
            regressions.push(format!(
                "{} @ {} connection(s): {:.1} jobs/s vs baseline {:.1} (-{drop_pct:.0}%)",
                cell_name(cur),
                cur.connections,
                cur.jobs_per_sec,
                base.jobs_per_sec
            ));
        }
        if let (Some(b95), Some(c95)) = (base.p95_ms, cur.p95_ms) {
            if c95 > (1.0 + latency_threshold) * b95 && c95 - b95 > LATENCY_WARN_FLOOR_MS {
                let growth = if b95 > 0.01 {
                    format!("+{:.0}%", (c95 / b95 - 1.0) * 100.0)
                } else {
                    format!("+{:.2}ms", c95 - b95)
                };
                latency_findings.push(format!(
                    "{} @ {} connection(s): p95 latency {c95:.2}ms vs baseline \
                     {b95:.2}ms ({growth})",
                    cell_name(cur),
                    cur.connections
                ));
            }
        }
    }
    // A wire mode the baseline covered disappearing from the overlap is
    // a silent 100% regression on that side of the A/B.
    for wire in ["framed", "text"] {
        if base_cells.iter().any(|b| b.wire == wire) && !cur_cells.iter().any(|c| c.wire == wire) {
            regressions
                .push(format!("{wire} vanished: baseline has cells, current run has none"));
        }
    }
    // Same for a readiness backend: a baseline that measured a poller
    // the current run never ran means the backend A/B lost a column.
    let base_pollers: std::collections::BTreeSet<&str> = base_cells
        .iter()
        .filter(|b| b.wire == "framed")
        .map(|b| b.poller.as_str())
        .collect();
    for poller in base_pollers {
        if !cur_cells.iter().any(|c| c.wire == "framed" && c.poller == poller) {
            regressions.push(format!(
                "framed poller={poller} vanished: baseline has cells, current run has none"
            ));
        }
    }
    let mut warnings = Vec::new();
    if latency_gate == LatencyGate::Strict {
        regressions.extend(latency_findings.iter().map(|f| format!("latency (strict): {f}")));
    } else {
        warnings = latency_findings;
    }
    if compared == 0 && regressions.is_empty() {
        return Ok(GateReport {
            outcome: GateOutcome::Skipped {
                reason: "no overlapping (wire, connections) cells".to_string(),
            },
            warnings,
            latency_gate,
        });
    }
    let outcome = if regressions.is_empty() {
        GateOutcome::Passed { cells: compared }
    } else {
        GateOutcome::Failed { regressions }
    };
    Ok(GateReport { outcome, warnings, latency_gate })
}

#[cfg(test)]
mod tests {
    use super::*;

    const LT: f64 = super::super::DEFAULT_LATENCY_THRESHOLD;

    fn doc(profile: &str, framed_jps: f64, text_jps: f64) -> String {
        format!(
            "{{\"bench\": \"ingress_wire_saturation\", \"profile\": \"{profile}\", \
             \"scale\": 0.05, \"spec\": \"primes par(2)\", \"jobs_per_connection\": 3, \
             \"warmup\": 1, \"samples\": 3, \"points\": [\
             {{\"wire\": \"framed\", \"connections\": 1, \"jobs_per_sec\": {framed_jps}, \
               \"p95_ms\": 50.0}}, \
             {{\"wire\": \"text\", \"connections\": 1, \"jobs_per_sec\": {text_jps}, \
               \"p95_ms\": 50.0}}]}}"
        )
    }

    #[test]
    fn gate_passes_within_threshold_and_fails_beyond() {
        let base = doc("release", 100.0, 90.0);
        let ok = doc("release", 80.0, 80.0);
        assert_eq!(
            gate(&base, &ok, 0.25, LT, false).unwrap().outcome,
            GateOutcome::Passed { cells: 2 }
        );
        let bad = doc("release", 40.0, 90.0);
        match gate(&base, &bad, 0.25, LT, false).unwrap().outcome {
            GateOutcome::Failed { regressions } => {
                assert_eq!(regressions.len(), 1, "{regressions:?}");
                assert!(regressions[0].contains("framed"), "{regressions:?}");
            }
            other => panic!("expected failure, got {other:?}"),
        }
    }

    #[test]
    fn gate_requires_both_wire_modes_in_the_current_run() {
        let base = doc("release", 100.0, 90.0);
        let framed_only = "{\"bench\": \"ingress_wire_saturation\", \
             \"profile\": \"release\", \"scale\": 0.05, \"spec\": \"primes par(2)\", \
             \"jobs_per_connection\": 3, \"warmup\": 1, \"samples\": 3, \"points\": [\
             {\"wire\": \"framed\", \"connections\": 1, \"jobs_per_sec\": 100.0}]}";
        let err = gate(&base, framed_only, 0.25, LT, false).unwrap_err();
        assert!(err.contains("no text cells"), "{err}");
        // The inverse half-run fails the same way.
        let text_only = framed_only.replace("\"framed\"", "\"text\"");
        let err = gate(&base, &text_only, 0.25, LT, false).unwrap_err();
        assert!(err.contains("no framed cells"), "{err}");
        // An incomplete *baseline* (e.g. seeded before a mode existed)
        // does not error — only the current run carries the invariant.
        let report = gate(framed_only, &base, 0.25, LT, false).unwrap();
        assert_eq!(report.outcome, GateOutcome::Passed { cells: 1 });
    }

    #[test]
    fn gate_skips_incomparable_and_refuses_malformed_runs() {
        let base = doc("release", 100.0, 90.0);
        let debug = doc("debug", 10.0, 9.0);
        assert!(matches!(
            gate(&base, &debug, 0.25, LT, false).unwrap().outcome,
            GateOutcome::Skipped { .. }
        ));
        assert!(gate("{]", &base, 0.25, LT, false).is_err());
        assert!(gate(&base, "{\"bench\": \"pipeline_throughput\"}", 0.25, LT, false).is_err());
        let no_points = "{\"bench\": \"ingress_wire_saturation\", \"profile\": \"release\"}";
        assert!(gate(&base, no_points, 0.25, LT, false).is_err());
    }

    #[test]
    fn gate_fails_when_a_wire_mode_vanishes_from_the_overlap() {
        // Baseline covers connections {1}; current covers both modes
        // but framed only at a different connection count — framed
        // stays in the A/B (no hard error) yet loses its baseline
        // overlap. The throughput comparison still runs on text.
        let base = doc("release", 100.0, 90.0);
        let cur = "{\"bench\": \"ingress_wire_saturation\", \"profile\": \"release\", \
             \"scale\": 0.05, \"spec\": \"primes par(2)\", \"jobs_per_connection\": 3, \
             \"warmup\": 1, \"samples\": 3, \"points\": [\
             {\"wire\": \"framed\", \"connections\": 8, \"jobs_per_sec\": 100.0}, \
             {\"wire\": \"text\", \"connections\": 1, \"jobs_per_sec\": 90.0}]}";
        let report = gate(&base, cur, 0.25, LT, false).unwrap();
        assert_eq!(report.outcome, GateOutcome::Passed { cells: 1 });
    }

    #[test]
    fn strict_latency_gate_disarms_on_synthetic_baselines() {
        let base = doc("release", 100.0, 90.0);
        let synthetic = base.replacen(
            "{\"bench\"",
            "{\"note\": \"synthetic conservative floor baseline\", \"bench\"",
            1,
        );
        let slow = base.replace("\"p95_ms\": 50.0", "\"p95_ms\": 500.0");
        let strict = gate(&base, &slow, 0.25, LT, true).unwrap();
        assert_eq!(strict.latency_gate, LatencyGate::Strict);
        assert!(matches!(strict.outcome, GateOutcome::Failed { .. }));
        let disarmed = gate(&synthetic, &slow, 0.25, LT, true).unwrap();
        assert_eq!(disarmed.latency_gate, LatencyGate::StrictDisarmedSyntheticBaseline);
        assert_eq!(disarmed.outcome, GateOutcome::Passed { cells: 2 });
        assert_eq!(disarmed.warnings.len(), 2, "{:?}", disarmed.warnings);
    }

    /// New-schema doc: framed cells across two pollers and two reactor
    /// counts, plus the text baseline.
    fn pool_doc(epoll_r2_jps: f64) -> String {
        let framed = |poller: &str, reactors: u64, jps: f64| {
            format!(
                "{{\"wire\": \"framed\", \"poller\": \"{poller}\", \"reactors\": {reactors}, \
                 \"connections\": 1, \"jobs_per_sec\": {jps}, \"p95_ms\": 50.0}}"
            )
        };
        format!(
            "{{\"bench\": \"ingress_wire_saturation\", \"profile\": \"release\", \
             \"scale\": 0.05, \"spec\": \"primes par(2)\", \"jobs_per_connection\": 3, \
             \"warmup\": 1, \"samples\": 3, \"points\": [{}, {}, {}, \
             {{\"wire\": \"text\", \"poller\": \"none\", \"reactors\": 0, \
               \"connections\": 1, \"jobs_per_sec\": 90.0, \"p95_ms\": 50.0}}]}}",
            framed("poll", 1, 100.0),
            framed("poll", 2, 150.0),
            framed("epoll", 2, epoll_r2_jps),
        )
    }

    #[test]
    fn gate_matches_poller_and_reactor_cells_like_for_like() {
        // Identical runs: every cell finds its exact counterpart.
        let base = pool_doc(200.0);
        assert_eq!(
            gate(&base, &base, 0.25, LT, false).unwrap().outcome,
            GateOutcome::Passed { cells: 4 }
        );
        // A regression confined to the epoll/r2 cell is attributed to
        // it — the poll cells don't mask it.
        let bad = pool_doc(40.0);
        match gate(&base, &bad, 0.25, LT, false).unwrap().outcome {
            GateOutcome::Failed { regressions } => {
                assert_eq!(regressions.len(), 1, "{regressions:?}");
                assert!(regressions[0].contains("framed[epoll, r2]"), "{regressions:?}");
            }
            other => panic!("expected failure, got {other:?}"),
        }
    }

    #[test]
    fn gate_fails_when_a_baseline_poller_is_missing_from_the_current_run() {
        let base = pool_doc(200.0);
        // Current run kept both wires but never ran epoll.
        let no_epoll = "{\"bench\": \"ingress_wire_saturation\", \"profile\": \"release\", \
             \"scale\": 0.05, \"spec\": \"primes par(2)\", \"jobs_per_connection\": 3, \
             \"warmup\": 1, \"samples\": 3, \"points\": [\
             {\"wire\": \"framed\", \"poller\": \"poll\", \"reactors\": 1, \
              \"connections\": 1, \"jobs_per_sec\": 100.0, \"p95_ms\": 50.0}, \
             {\"wire\": \"text\", \"poller\": \"none\", \"reactors\": 0, \
              \"connections\": 1, \"jobs_per_sec\": 90.0, \"p95_ms\": 50.0}]}";
        match gate(&base, no_epoll, 0.25, LT, false).unwrap().outcome {
            GateOutcome::Failed { regressions } => {
                assert!(
                    regressions.iter().any(|r| r.contains("poller=epoll vanished")),
                    "{regressions:?}"
                );
            }
            other => panic!("expected failure, got {other:?}"),
        }
    }

    #[test]
    fn gate_defaults_legacy_cells_to_the_single_poll_reactor() {
        // A pre-pool baseline (no poller/reactors fields) must compare
        // against exactly the current run's (poll, r1) cells — not the
        // multi-reactor or epoll ones.
        let legacy = doc("release", 100.0, 90.0);
        let current = pool_doc(200.0);
        let report = gate(&legacy, &current, 0.25, LT, false).unwrap();
        assert_eq!(report.outcome, GateOutcome::Passed { cells: 2 });
        // And the reverse: dropping to (poll, r1)-only from a pool
        // baseline loses the epoll column loudly.
        let err_free = gate(&current, &legacy, 0.25, LT, false).unwrap();
        match err_free.outcome {
            GateOutcome::Failed { regressions } => {
                assert!(
                    regressions.iter().any(|r| r.contains("poller=epoll vanished")),
                    "{regressions:?}"
                );
            }
            other => panic!("expected failure, got {other:?}"),
        }
    }

    #[test]
    fn env_knobs_parse() {
        // No env set in the test harness: all fall through to None.
        if std::env::var("SFUT_INGRESS_CONNS").is_err() {
            assert!(connections_from_env().is_none());
        }
        if std::env::var("SFUT_INGRESS_JOBS").is_err() {
            assert!(jobs_from_env().is_none());
        }
        if std::env::var("SFUT_INGRESS_POLLERS").is_err() {
            assert!(pollers_from_env().is_none());
        }
        if std::env::var("SFUT_INGRESS_REACTORS").is_err() {
            assert!(reactor_counts_from_env().is_none());
        }
        assert!(!default_pollers().is_empty());
    }
}

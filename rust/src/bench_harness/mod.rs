//! Benchmark harness — sampling, statistics, the table/figure renderers
//! that regenerate the paper's Table 1 and Figures 3–4, and the perf
//! lab (declarative ablation plans + provenance-stamped registry).
//!
//! `criterion` is not available offline; this is a purpose-built
//! replacement: warmup + N timed samples per cell, median/MAD statistics
//! (robust against scheduler noise, which matters because the measured
//! quantity *is* scheduling behaviour), CSV output for plotting, and
//! ASCII bar charts mirroring the paper's figures.
//!
//! # Running the perf lab
//!
//! A perf question is a *plan*, not a hand-run. Plans are small
//! key=value files under `ci/plans/` declaring a grid sweep (axes over
//! config keys like `shards`, `deque`, `poller`, `admission`,
//! `chunk_policy`, plus backend parameters like `workload`), a sample
//! budget, and a seed; [`plan::run_plan`] expands the grid, runs every
//! cell through the existing pipeline/executor/ingress harnesses with
//! the usual warmup + median-of-samples discipline, and appends each
//! cell — stamped with full [`Provenance`] (commit, dirty flag, seed,
//! toolchain, scale, host cores) — to the `BENCH_registry.jsonl`
//! results registry ([`registry`]).
//!
//! ```text
//! sfut bench run ci/plans/msort_shards.plan   # "does steal-half help msort at 8 shards?"
//! sfut bench list                             # committed plans + the gate set
//! sfut bench report [<plan>]                  # diff registry cells across commits
//! sfut bench gate <target|all> [<a> <b>]      # the CI perf-regression gate
//! ```
//!
//! CI runs the committed `ci/plans/smoke.plan` on every push (the
//! `bench-plan-smoke` step) and uploads the registry as an artifact; the
//! gate set the `bench gate` family loops over is itself plan-declared
//! (`ci/plans/gates.plan`), so adding a bench trajectory means adding a
//! line to a plan file, not editing shell.
//!
//! All three trajectory writers emit one **versioned schema**
//! ([`BENCH_SCHEMA_VERSION`]): a top-level `bench` kind, run
//! parameters, a `provenance` block, and `points` whose `labels`
//! identify a cell and whose `metrics` measure it. [`BenchReport`] is
//! the one reader — it also tolerates all three legacy (v0) shapes, so
//! committed baselines keep parsing.

mod chart;
pub mod executor_bench;
pub mod ingress_bench;
pub mod paper;
pub mod pipeline_bench;
pub mod plan;
pub mod registry;
mod sampler;
mod table;
pub mod tiny_json;

use std::collections::BTreeMap;
use std::path::Path;

use tiny_json::Json;

pub use chart::ascii_bar_chart;
pub use executor_bench::{ExecutorBench, QueueDepthStats, SchedulerRun};
pub use ingress_bench::{IngressBench, IngressBenchParams, WirePoint};
pub use pipeline_bench::{
    GateOutcome, GateReport, LatencyGate, PipelineBench, PipelineBenchParams, WorkloadPoint,
    DEFAULT_LATENCY_THRESHOLD,
};
pub use plan::{run_plan, AblationPlan, Axis, GateTarget, PlanBackend, PlanReport};
pub use sampler::{measure, BenchOptions, Measurement};
pub use table::{render_csv, render_table, Cell, ReportTable};

/// Version stamp every unified trajectory/registry document carries.
/// Documents without the field are legacy (v0) and go through the
/// tolerant reader paths in [`BenchReport::parse`].
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// Where a measurement came from — stamped on every trajectory file and
/// every registry cell so numbers stay comparable (or visibly not)
/// across commits and machines.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    /// `git rev-parse --short=12 HEAD`, or "unknown" outside a repo.
    pub commit: String,
    /// Working tree had uncommitted changes when the bench ran.
    pub dirty: bool,
    /// The plan seed (0 for direct bench runs — they take no seed).
    pub seed: u64,
    /// `rustc --version`, or "unknown" when the toolchain is absent.
    pub toolchain: String,
    /// The `Config::scale` the run used.
    pub scale: f64,
    pub host_cores: usize,
}

impl Provenance {
    /// Capture the current environment. Never fails: fields degrade to
    /// "unknown"/false when git or rustc are unavailable.
    pub fn capture(seed: u64, scale: f64) -> Provenance {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let git = |args: &[&str]| -> Option<String> {
            let out = std::process::Command::new("git")
                .args(args)
                .current_dir(root)
                .output()
                .ok()?;
            if !out.status.success() {
                return None;
            }
            Some(String::from_utf8_lossy(&out.stdout).trim().to_string())
        };
        let commit =
            git(&["rev-parse", "--short=12", "HEAD"]).unwrap_or_else(|| "unknown".to_string());
        let dirty = git(&["status", "--porcelain"]).map(|s| !s.is_empty()).unwrap_or(false);
        let toolchain = std::process::Command::new("rustc")
            .arg("--version")
            .output()
            .ok()
            .filter(|o| o.status.success())
            .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
            .unwrap_or_else(|| "unknown".to_string());
        let host_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Provenance { commit, dirty, seed, toolchain, scale, host_cores }
    }

    /// Single-line JSON object (hand-rolled; no serde offline).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"commit\": {}, \"dirty\": {}, \"seed\": {}, \"toolchain\": {}, \
             \"scale\": {}, \"host_cores\": {}}}",
            json_string(&self.commit),
            self.dirty,
            self.seed,
            json_string(&self.toolchain),
            fmt_f64(self.scale),
            self.host_cores,
        )
    }

    /// Tolerant read: missing fields fall back to capture-failure
    /// defaults, so registries written by newer code stay readable.
    pub fn from_json(v: &Json) -> Provenance {
        Provenance {
            commit: v
                .get("commit")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            dirty: matches!(v.get("dirty"), Some(Json::Bool(true))),
            seed: v.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            toolchain: v
                .get("toolchain")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            scale: v.get("scale").and_then(Json::as_f64).unwrap_or(1.0),
            host_cores: v.get("host_cores").and_then(Json::as_f64).unwrap_or(0.0) as usize,
        }
    }
}

/// One measured cell in the unified schema: `labels` identify it (the
/// gate and the registry differ match cells only on identical labels),
/// `metrics` measure it, `flags` carry booleans like `verified`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BenchPoint {
    pub labels: BTreeMap<String, String>,
    pub metrics: BTreeMap<String, f64>,
    pub flags: BTreeMap<String, bool>,
}

impl BenchPoint {
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.get(key).map(String::as_str)
    }

    pub fn label_u64(&self, key: &str) -> Option<u64> {
        self.labels.get(key)?.parse().ok()
    }

    pub fn metric(&self, key: &str) -> Option<f64> {
        self.metrics.get(key).copied()
    }

    /// Single-line JSON object; `flags` is omitted when empty.
    pub fn to_json(&self) -> String {
        let join = |pairs: Vec<String>| pairs.join(", ");
        let labels: Vec<String> = self
            .labels
            .iter()
            .map(|(k, v)| format!("{}: {}", json_string(k), json_string(v)))
            .collect();
        let metrics: Vec<String> = self
            .metrics
            .iter()
            .map(|(k, v)| format!("{}: {}", json_string(k), fmt_f64(*v)))
            .collect();
        let mut out = format!(
            "{{\"labels\": {{{}}}, \"metrics\": {{{}}}",
            join(labels),
            join(metrics)
        );
        if !self.flags.is_empty() {
            let flags: Vec<String> = self
                .flags
                .iter()
                .map(|(k, v)| format!("{}: {}", json_string(k), v))
                .collect();
            out.push_str(&format!(", \"flags\": {{{}}}", join(flags)));
        }
        out.push('}');
        out
    }
}

/// A parsed trajectory document behind one reader: the v1 unified shape
/// *or* any of the three legacy (v0) shapes — flat pipeline/ingress
/// points, or the executor's `runs` array — normalized into
/// [`BenchPoint`]s. The raw document stays reachable via [`Self::param`]
/// for run-parameter comparability checks.
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// 0 for legacy documents without the field.
    pub schema_version: u64,
    /// The `bench` kind ("" when absent — the gates reject that).
    pub bench: String,
    /// The top-level `note` (the synthetic-floor marker lives here).
    pub note: Option<String>,
    pub provenance: Option<Provenance>,
    pub points: Vec<BenchPoint>,
    doc: Json,
}

impl BenchReport {
    pub fn parse(text: &str) -> Result<BenchReport, String> {
        let doc = tiny_json::parse(text)?;
        let bench = doc.get("bench").and_then(Json::as_str).unwrap_or("").to_string();
        let schema_version =
            doc.get("schema_version").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let note = doc.get("note").and_then(Json::as_str).map(str::to_string);
        let provenance = doc.get("provenance").map(Provenance::from_json);
        let points = doc
            .get("points")
            .or_else(|| doc.get("runs")) // legacy executor shape
            .and_then(Json::as_array)
            .unwrap_or(&[])
            .iter()
            .filter_map(|p| normalize_point(&bench, p))
            .collect();
        Ok(BenchReport { schema_version, bench, note, provenance, points, doc })
    }

    /// Top-level run-parameter lookup on the raw document (profile,
    /// scale, clients, …) — the gates compare these for comparability.
    pub fn param(&self, key: &str) -> Option<&Json> {
        self.doc.get(key)
    }
}

/// Label keys per legacy bench kind: fields under these names become
/// labels when normalizing a v0 point; everything else routes by JSON
/// type (numbers → metrics, bools → flags, strings → labels, nested
/// objects → dotted metric keys).
fn legacy_label_keys(bench: &str) -> &'static [&'static str] {
    match bench {
        "pipeline_throughput" => &["workload", "shards"],
        "executor_overhead" => &["scheduler", "deque"],
        "ingress_wire_saturation" => &["wire", "poller", "reactors", "connections"],
        _ => &[],
    }
}

fn json_to_label(v: &Json) -> Option<String> {
    match v {
        Json::Str(s) => Some(s.clone()),
        Json::Num(n) => Some(fmt_f64(*n)),
        Json::Bool(b) => Some(b.to_string()),
        _ => None,
    }
}

fn normalize_point(bench: &str, p: &Json) -> Option<BenchPoint> {
    let mut point = BenchPoint::default();
    if let Some(Json::Obj(_)) = p.get("labels") {
        // v1 shape: labels/metrics/flags objects.
        if let Some(Json::Obj(fields)) = p.get("labels") {
            for (k, v) in fields {
                if let Some(s) = json_to_label(v) {
                    point.labels.insert(k.clone(), s);
                }
            }
        }
        if let Some(Json::Obj(fields)) = p.get("metrics") {
            for (k, v) in fields {
                if let Some(n) = v.as_f64() {
                    point.metrics.insert(k.clone(), n);
                }
            }
        }
        if let Some(Json::Obj(fields)) = p.get("flags") {
            for (k, v) in fields {
                if let Json::Bool(b) = v {
                    point.flags.insert(k.clone(), *b);
                }
            }
        }
    } else {
        // v0 shape: one flat object per point.
        let Json::Obj(fields) = p else { return None };
        let label_keys = legacy_label_keys(bench);
        for (k, v) in fields {
            if label_keys.contains(&k.as_str()) {
                if let Some(s) = json_to_label(v) {
                    point.labels.insert(k.clone(), s);
                }
                continue;
            }
            match v {
                Json::Num(n) => {
                    point.metrics.insert(k.clone(), *n);
                }
                Json::Bool(b) => {
                    point.flags.insert(k.clone(), *b);
                }
                Json::Str(s) => {
                    point.labels.insert(k.clone(), s.clone());
                }
                Json::Obj(nested) => {
                    // e.g. the executor's queue_depth histogram block.
                    for (nk, nv) in nested {
                        if let Some(n) = nv.as_f64() {
                            point.metrics.insert(format!("{k}.{nk}"), n);
                        }
                    }
                }
                _ => {}
            }
        }
    }
    // Pre-pool ingress cells lack poller/reactors: they ran the single
    // poll(2) reactor (text cells have neither dimension). Defaulting
    // here keeps legacy baselines comparable like-for-like.
    if bench == "ingress_wire_saturation" {
        if let Some(framed) = point.label("wire").map(|w| w == "framed") {
            point
                .labels
                .entry("poller".to_string())
                .or_insert_with(|| if framed { "poll" } else { "none" }.to_string());
            point
                .labels
                .entry("reactors".to_string())
                .or_insert_with(|| u64::from(framed).to_string());
        }
    }
    Some(point)
}

/// JSON string literal with the escapes [`tiny_json`] reads back.
pub(crate) fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            _ => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Compact f64 formatting: integers drop the fraction, everything else
/// prints to 6 places with trailing zeros trimmed. Non-finite values
/// (the writers never produce them) clamp to 0.
pub(crate) fn fmt_f64(v: f64) -> String {
    if !v.is_finite() {
        return "0".to_string();
    }
    if v.fract() == 0.0 && v.abs() < 1e15 {
        return format!("{}", v as i64);
    }
    let s = format!("{v:.6}");
    s.trim_end_matches('0').trim_end_matches('.').to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn measure_reports_sane_stats() {
        let opts = BenchOptions { warmup: 1, samples: 5, ..Default::default() };
        let m = measure("sleepy", &opts, || {
            std::thread::sleep(Duration::from_millis(2));
        });
        assert_eq!(m.samples.len(), 5);
        assert!(m.median >= Duration::from_millis(1), "median={:?}", m.median);
        assert!(m.median < Duration::from_millis(200));
        assert!(m.mad <= m.median);
    }

    #[test]
    fn table_renders_rows_and_columns() {
        let mut t = ReportTable::new("Table 1. Timings (seconds)", vec!["seq", "par(1)", "par(2)"]);
        t.set("primes", "seq", Cell::Seconds(3.4));
        t.set("primes", "par(2)", Cell::Seconds(5.9));
        t.set("stream", "seq", Cell::Seconds(14.0));
        t.set("stream", "par(1)", Cell::Seconds(35.1));
        let text = render_table(&t);
        assert!(text.contains("primes"));
        assert!(text.contains("3.4"));
        assert!(text.contains("par(2)"));
        // Missing cells render as blanks, like the paper's table.
        assert!(text.contains("stream"));
        let csv = render_csv(&t);
        assert!(csv.starts_with("workload,seq,par(1),par(2)"));
        assert!(csv.contains("primes,3.40,,5.90"));
    }

    #[test]
    fn chart_draws_bars() {
        let series = vec![
            ("primes".to_string(), vec![("seq".to_string(), 3.4), ("par(2)".to_string(), 5.9)]),
        ];
        let chart = ascii_bar_chart("Timings for primes (seconds)", &series, 40);
        assert!(chart.contains("primes"));
        assert!(chart.contains('#'));
        assert!(chart.contains("5.9"));
    }

    #[test]
    fn fmt_f64_is_compact_and_json_strings_escape() {
        assert_eq!(fmt_f64(2.0), "2");
        assert_eq!(fmt_f64(0.05), "0.05");
        assert_eq!(fmt_f64(1234.5), "1234.5");
        assert_eq!(fmt_f64(f64::NAN), "0");
        assert_eq!(json_string("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn provenance_roundtrips_through_json() {
        let p = Provenance {
            commit: "abc123def456".to_string(),
            dirty: true,
            seed: 42,
            toolchain: "rustc 1.76.0".to_string(),
            scale: 0.05,
            host_cores: 8,
        };
        let parsed = tiny_json::parse(&p.to_json()).expect("provenance JSON parses");
        assert_eq!(Provenance::from_json(&parsed), p);
        // Tolerant of missing fields.
        let sparse = tiny_json::parse("{\"commit\": \"deadbeef\"}").unwrap();
        let q = Provenance::from_json(&sparse);
        assert_eq!(q.commit, "deadbeef");
        assert_eq!(q.toolchain, "unknown");
        assert!(!q.dirty);
    }

    #[test]
    fn provenance_capture_never_fails() {
        let p = Provenance::capture(7, 0.5);
        assert_eq!(p.seed, 7);
        assert_eq!(p.scale, 0.5);
        assert!(p.host_cores >= 1);
        assert!(!p.commit.is_empty());
        assert!(!p.toolchain.is_empty());
    }

    #[test]
    fn bench_point_serializes_and_reparses() {
        let mut p = BenchPoint::default();
        p.labels.insert("workload".to_string(), "msort".to_string());
        p.labels.insert("shards".to_string(), "8".to_string());
        p.metrics.insert("jobs_per_sec".to_string(), 123.25);
        p.flags.insert("verified".to_string(), true);
        let json = p.to_json();
        let doc = format!(
            "{{\"schema_version\": 1, \"bench\": \"pipeline_throughput\", \"points\": [{json}]}}"
        );
        let report = BenchReport::parse(&doc).unwrap();
        assert_eq!(report.schema_version, 1);
        assert_eq!(report.points.len(), 1);
        assert_eq!(report.points[0], p);
        assert_eq!(report.points[0].label_u64("shards"), Some(8));
        assert_eq!(report.points[0].metric("jobs_per_sec"), Some(123.25));
    }

    #[test]
    fn bench_report_reads_all_three_legacy_shapes() {
        // Legacy pipeline: flat point, numeric shards label.
        let pipeline = "{\"bench\": \"pipeline_throughput\", \"profile\": \"release\", \
             \"points\": [{\"workload\": \"primes\", \"shards\": 2, \
             \"jobs_per_sec\": 100.0, \"verified\": true}]}";
        let r = BenchReport::parse(pipeline).unwrap();
        assert_eq!(r.schema_version, 0);
        assert_eq!(r.points[0].label("workload"), Some("primes"));
        assert_eq!(r.points[0].label_u64("shards"), Some(2));
        assert_eq!(r.points[0].metric("jobs_per_sec"), Some(100.0));
        assert_eq!(r.points[0].flags.get("verified"), Some(&true));
        assert_eq!(r.param("profile").and_then(Json::as_str), Some("release"));

        // Legacy executor: "runs" array, nested queue_depth object.
        let executor = "{\"bench\": \"executor_overhead\", \"runs\": [\
             {\"scheduler\": \"work-stealing\", \"deque\": \"chase_lev\", \
              \"spawn_wave_tasks_per_sec\": 5000.0, \
              \"queue_depth\": {\"mean\": 3.5, \"p99\": 9}}]}";
        let r = BenchReport::parse(executor).unwrap();
        assert_eq!(r.points[0].label("deque"), Some("chase_lev"));
        assert_eq!(r.points[0].metric("spawn_wave_tasks_per_sec"), Some(5000.0));
        assert_eq!(r.points[0].metric("queue_depth.mean"), Some(3.5));

        // Legacy ingress without poller/reactors: framed defaults to
        // (poll, 1), text to (none, 0).
        let ingress = "{\"bench\": \"ingress_wire_saturation\", \"points\": [\
             {\"wire\": \"framed\", \"connections\": 1, \"jobs_per_sec\": 10.0}, \
             {\"wire\": \"text\", \"connections\": 1, \"jobs_per_sec\": 9.0}]}";
        let r = BenchReport::parse(ingress).unwrap();
        assert_eq!(r.points[0].label("poller"), Some("poll"));
        assert_eq!(r.points[0].label_u64("reactors"), Some(1));
        assert_eq!(r.points[1].label("poller"), Some("none"));
        assert_eq!(r.points[1].label_u64("reactors"), Some(0));
    }

    #[test]
    fn bench_report_surfaces_note_and_provenance() {
        let doc = "{\"note\": \"synthetic floor\", \"bench\": \"pipeline_throughput\", \
             \"provenance\": {\"commit\": \"cafe\", \"seed\": 3}, \"points\": []}";
        let r = BenchReport::parse(doc).unwrap();
        assert_eq!(r.note.as_deref(), Some("synthetic floor"));
        let p = r.provenance.expect("provenance parsed");
        assert_eq!(p.commit, "cafe");
        assert_eq!(p.seed, 3);
        assert!(r.points.is_empty());
    }
}

//! Benchmark harness — sampling, statistics, and the table/figure
//! renderers that regenerate the paper's Table 1 and Figures 3–4.
//!
//! `criterion` is not available offline; this is a purpose-built
//! replacement: warmup + N timed samples per cell, median/MAD statistics
//! (robust against scheduler noise, which matters because the measured
//! quantity *is* scheduling behaviour), CSV output for plotting, and
//! ASCII bar charts mirroring the paper's figures.

mod chart;
pub mod executor_bench;
pub mod ingress_bench;
pub mod paper;
pub mod pipeline_bench;
mod sampler;
mod table;
pub mod tiny_json;

pub use chart::ascii_bar_chart;
pub use executor_bench::{ExecutorBench, QueueDepthStats, SchedulerRun};
pub use ingress_bench::{IngressBench, IngressBenchParams, WirePoint};
pub use pipeline_bench::{
    GateOutcome, GateReport, LatencyGate, PipelineBench, PipelineBenchParams, WorkloadPoint,
    DEFAULT_LATENCY_THRESHOLD,
};
pub use sampler::{measure, BenchOptions, Measurement};
pub use table::{render_csv, render_table, Cell, ReportTable};

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn measure_reports_sane_stats() {
        let opts = BenchOptions { warmup: 1, samples: 5, ..Default::default() };
        let m = measure("sleepy", &opts, || {
            std::thread::sleep(Duration::from_millis(2));
        });
        assert_eq!(m.samples.len(), 5);
        assert!(m.median >= Duration::from_millis(1), "median={:?}", m.median);
        assert!(m.median < Duration::from_millis(200));
        assert!(m.mad <= m.median);
    }

    #[test]
    fn table_renders_rows_and_columns() {
        let mut t = ReportTable::new("Table 1. Timings (seconds)", vec!["seq", "par(1)", "par(2)"]);
        t.set("primes", "seq", Cell::Seconds(3.4));
        t.set("primes", "par(2)", Cell::Seconds(5.9));
        t.set("stream", "seq", Cell::Seconds(14.0));
        t.set("stream", "par(1)", Cell::Seconds(35.1));
        let text = render_table(&t);
        assert!(text.contains("primes"));
        assert!(text.contains("3.4"));
        assert!(text.contains("par(2)"));
        // Missing cells render as blanks, like the paper's table.
        assert!(text.contains("stream"));
        let csv = render_csv(&t);
        assert!(csv.starts_with("workload,seq,par(1),par(2)"));
        assert!(csv.contains("primes,3.40,,5.90"));
    }

    #[test]
    fn chart_draws_bars() {
        let series = vec![
            ("primes".to_string(), vec![("seq".to_string(), 3.4), ("par(2)".to_string(), 5.9)]),
        ];
        let chart = ascii_bar_chart("Timings for primes (seconds)", &series, 40);
        assert!(chart.contains("primes"));
        assert!(chart.contains('#'));
        assert!(chart.contains("5.9"));
    }
}

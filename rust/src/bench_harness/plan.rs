//! Declarative ablation plans — the perf lab's front door.
//!
//! A perf question ("does steal-half help msort at 8 shards?") becomes
//! a small key=value plan file under `ci/plans/` instead of a hand-run:
//! top-level keys pin the run shape (backend, sample budget, seed,
//! backend parameters), an `[axis]` section declares the grid sweep
//! (comma-separated values per key, crossed in file order), and a
//! `[fixed]` section pins config keys for every cell. [`run_plan`]
//! expands the grid, routes each cell through the existing
//! pipeline/executor/ingress harnesses with the usual warmup +
//! median-of-samples discipline ([`BenchOptions`]), and returns a
//! [`PlanReport`] of provenance-stamped [`BenchPoint`]s ready for the
//! results registry ([`super::registry`]).
//!
//! ```text
//! # ci/plans/msort_shards.plan
//! name = msort_shards
//! backend = pipeline
//! workload = msort
//! seed = 7
//! [axis]
//! shards = 1, 2, 4, 8
//! deque = chase_lev, locked
//! ```
//!
//! Axis and `[fixed]` keys are validated up front — config keys against
//! [`Config::set`] (so a typo'd key or value fails at parse time, not
//! mid-sweep), workloads against the registry, modes and specs against
//! their parsers. The CI gate set the `sfut bench gate` family loops
//! over lives in the same directory (`ci/plans/gates.plan`) in an even
//! smaller `name = baseline bench_target` format ([`parse_gate_set`]).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use super::ingress_bench::IngressBenchParams;
use super::pipeline_bench::PipelineBenchParams;
use super::{executor_bench, ingress_bench, pipeline_bench};
use super::{BenchOptions, BenchPoint, Provenance};
use crate::config::{Config, Mode};
use crate::coordinator::JobRequest;
use crate::workload::WorkloadRegistry;

/// Which harness runs a plan's cells.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanBackend {
    /// [`pipeline_bench`]: end-to-end jobs through a [`Pipeline`]
    /// (workload/mode/clients/jobs_per_client + any config axis).
    ///
    /// [`Pipeline`]: crate::coordinator::Pipeline
    Pipeline,
    /// [`executor_bench`]: the scheduler/deque A/B/C. Takes only
    /// `tasks`/`parallelism` — it builds executors directly, bypassing
    /// [`Config`], so config axes are rejected at validation.
    Executor,
    /// [`ingress_bench`]: TCP wire saturation
    /// (spec/connections/jobs_per_connection + any config axis; sweep
    /// `wire`/`poller`/`reactors` as config axes).
    Ingress,
}

impl PlanBackend {
    pub fn label(self) -> &'static str {
        match self {
            PlanBackend::Pipeline => "pipeline",
            PlanBackend::Executor => "executor",
            PlanBackend::Ingress => "ingress",
        }
    }
}

impl std::str::FromStr for PlanBackend {
    type Err = String;

    fn from_str(s: &str) -> Result<PlanBackend, String> {
        match s {
            "pipeline" => Ok(PlanBackend::Pipeline),
            "executor" => Ok(PlanBackend::Executor),
            "ingress" => Ok(PlanBackend::Ingress),
            _ => Err(format!("unknown backend: {s} (expected pipeline, executor or ingress)")),
        }
    }
}

/// One grid dimension: a key swept over its values, crossed with every
/// other axis in file order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Axis {
    pub key: String,
    pub values: Vec<String>,
}

/// A parsed plan file. Top-level keys not swept by an axis keep the
/// defaults below; `fixed` pins config keys for every cell.
#[derive(Debug, Clone)]
pub struct AblationPlan {
    pub name: String,
    pub backend: PlanBackend,
    /// Stamped into every cell's [`Provenance`]; reserved for workloads
    /// that take randomness.
    pub seed: u64,
    pub samples: usize,
    pub warmup: usize,
    pub axes: Vec<Axis>,
    /// Config keys pinned for every cell (applied before axis values).
    pub fixed: Vec<(String, String)>,
    // Backend parameter defaults, overridable per-cell via axes.
    pub mode: Mode,
    pub workload: String,
    pub clients: usize,
    pub jobs_per_client: usize,
    pub tasks: u64,
    pub parallelism: usize,
    pub spec: String,
    pub connections: usize,
    pub jobs_per_connection: usize,
}

impl Default for AblationPlan {
    fn default() -> Self {
        AblationPlan {
            name: String::new(),
            backend: PlanBackend::Pipeline,
            seed: 0,
            samples: 2,
            warmup: 1,
            axes: Vec::new(),
            fixed: Vec::new(),
            mode: Mode::Par(2),
            workload: "primes".to_string(),
            clients: 2,
            jobs_per_client: 2,
            tasks: 10_000,
            parallelism: 2,
            spec: "primes par(2)".to_string(),
            connections: 1,
            jobs_per_connection: 2,
        }
    }
}

impl AblationPlan {
    /// Cells the grid expands to (product of the axis value counts).
    pub fn grid_size(&self) -> usize {
        self.axes.iter().map(|a| a.values.len()).product()
    }

    /// Check the plan makes sense before anything runs: a name, at
    /// least one axis, a bounded grid, and every axis/fixed key + value
    /// valid for the backend (config values go through a scratch
    /// [`Config::set`], so a typo fails here, not mid-sweep).
    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty() {
            return Err("plan has no name".to_string());
        }
        if !self.name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_') {
            return Err(format!(
                "bad plan name {:?} (alphanumeric, '-' and '_' only)",
                self.name
            ));
        }
        if self.samples == 0 {
            return Err("samples must be >= 1".to_string());
        }
        if self.axes.is_empty() {
            return Err("plan declares no axes — the grid is empty".to_string());
        }
        let cells = self.grid_size();
        if cells > 1024 {
            return Err(format!("grid expands to {cells} cells — the cap is 1024"));
        }
        for axis in &self.axes {
            if self.fixed.iter().any(|(k, _)| *k == axis.key) {
                return Err(format!("axis {} collides with a [fixed] key", axis.key));
            }
            for value in &axis.values {
                check_key_value(self.backend, &axis.key, value)?;
            }
        }
        if self.backend == PlanBackend::Executor && !self.fixed.is_empty() {
            return Err(
                "executor plans take no [fixed] config — the executor bench bypasses Config"
                    .to_string(),
            );
        }
        for (key, value) in &self.fixed {
            check_key_value(self.backend, key, value)?;
        }
        Ok(())
    }
}

/// Backend parameter keys routable per-cell (everything else must be a
/// [`Config`] key).
fn backend_param_keys(backend: PlanBackend) -> &'static [&'static str] {
    match backend {
        PlanBackend::Pipeline => &["workload", "mode", "clients", "jobs_per_client"],
        PlanBackend::Executor => &["tasks", "parallelism"],
        PlanBackend::Ingress => &["spec", "connections", "jobs_per_connection"],
    }
}

fn check_key_value(backend: PlanBackend, key: &str, value: &str) -> Result<(), String> {
    if backend_param_keys(backend).contains(&key) {
        return match key {
            "workload" => {
                if WorkloadRegistry::builtin().contains(value) {
                    Ok(())
                } else {
                    Err(format!("unknown workload: {value}"))
                }
            }
            "mode" => Mode::parse(value).map(|_| ()).map_err(|e| e.to_string()),
            "spec" => JobRequest::parse(value).map(|_| ()),
            _ => value
                .parse::<u64>()
                .map(|_| ())
                .map_err(|_| format!("bad value for {key}: {value}")),
        };
    }
    if backend == PlanBackend::Executor {
        return Err(format!(
            "executor plans sweep only tasks/parallelism — {key} is not an executor axis"
        ));
    }
    let mut scratch = Config::default();
    scratch.set(key, value).map_err(|e| e.to_string())
}

/// Parse a plan file: `key = value` lines, `#` comments, `[axis]` and
/// `[fixed]` sections. Errors name their line.
pub fn parse(text: &str) -> Result<AblationPlan, String> {
    #[derive(PartialEq)]
    enum Section {
        Top,
        Axis,
        Fixed,
    }
    let mut plan = AblationPlan::default();
    let mut seen_top: Vec<String> = Vec::new();
    let mut section = Section::Top;
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        match line {
            "[axis]" => {
                section = Section::Axis;
                continue;
            }
            "[fixed]" => {
                section = Section::Fixed;
                continue;
            }
            _ if line.starts_with('[') => {
                return Err(format!(
                    "line {lineno}: unknown section {line} (expected [axis] or [fixed])"
                ));
            }
            _ => {}
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(format!("line {lineno}: expected key = value, got {line:?}"));
        };
        let key = key.trim().to_string();
        let value = value.trim().to_string();
        match section {
            Section::Top => {
                if seen_top.contains(&key) {
                    return Err(format!("line {lineno}: duplicate key {key}"));
                }
                set_top_key(&mut plan, &key, &value)
                    .map_err(|e| format!("line {lineno}: {e}"))?;
                seen_top.push(key);
            }
            Section::Axis => {
                if plan.axes.iter().any(|a| a.key == key) {
                    return Err(format!("line {lineno}: duplicate axis {key}"));
                }
                let values: Vec<String> = value
                    .split(',')
                    .map(|v| v.trim().to_string())
                    .filter(|v| !v.is_empty())
                    .collect();
                if values.is_empty() {
                    return Err(format!("line {lineno}: axis {key} has no values"));
                }
                plan.axes.push(Axis { key, values });
            }
            Section::Fixed => {
                if plan.fixed.iter().any(|(k, _)| *k == key) {
                    return Err(format!("line {lineno}: duplicate fixed key {key}"));
                }
                plan.fixed.push((key, value));
            }
        }
    }
    Ok(plan)
}

fn set_top_key(plan: &mut AblationPlan, key: &str, value: &str) -> Result<(), String> {
    fn num<T: std::str::FromStr>(key: &str, v: &str) -> Result<T, String> {
        v.parse().map_err(|_| format!("bad value for {key}: {v}"))
    }
    match key {
        "name" => plan.name = value.to_string(),
        "backend" => plan.backend = value.parse()?,
        "seed" => plan.seed = num(key, value)?,
        "samples" => plan.samples = num(key, value)?,
        "warmup" => plan.warmup = num(key, value)?,
        "mode" => plan.mode = Mode::parse(value).map_err(|e| e.to_string())?,
        "workload" => plan.workload = value.to_string(),
        "clients" => plan.clients = num(key, value)?,
        "jobs_per_client" => plan.jobs_per_client = num(key, value)?,
        "tasks" => plan.tasks = num(key, value)?,
        "parallelism" => plan.parallelism = num(key, value)?,
        "spec" => plan.spec = value.to_string(),
        "connections" => plan.connections = num(key, value)?,
        "jobs_per_connection" => plan.jobs_per_connection = num(key, value)?,
        _ => return Err(format!("unknown plan key: {key}")),
    }
    Ok(())
}

/// Expand axes into the full cartesian grid, file order outermost-first
/// (last axis varies fastest). No axes → one empty cell, which
/// [`AblationPlan::validate`] rejects before it matters.
pub fn grid(axes: &[Axis]) -> Vec<Vec<(String, String)>> {
    let mut cells: Vec<Vec<(String, String)>> = vec![Vec::new()];
    for axis in axes {
        let mut next = Vec::with_capacity(cells.len() * axis.values.len());
        for cell in &cells {
            for value in &axis.values {
                let mut grown = cell.clone();
                grown.push((axis.key.clone(), value.clone()));
                next.push(grown);
            }
        }
        cells = next;
    }
    cells
}

/// Everything one plan run produced: provenance-stamped grid cells
/// ready for [`super::registry::append`].
#[derive(Debug, Clone)]
pub struct PlanReport {
    pub name: String,
    pub backend: PlanBackend,
    /// "release" or "debug" — stamped on every registry record.
    pub profile: &'static str,
    pub seed: u64,
    pub grid_cells: usize,
    pub provenance: Provenance,
    pub points: Vec<BenchPoint>,
}

impl PlanReport {
    /// Human-readable summary: provenance header + one line per cell
    /// (labels, then the cell's primary throughput metric).
    pub fn render(&self) -> String {
        let p = &self.provenance;
        let mut out = format!(
            "plan {} ({} backend, {} grid cell(s), {} point(s), seed {}, {} build)\n",
            self.name,
            self.backend.label(),
            self.grid_cells,
            self.points.len(),
            self.seed,
            self.profile,
        );
        out.push_str(&format!(
            "  provenance: commit {}{} · {} · scale {} · {} core(s)\n",
            p.commit,
            if p.dirty { "*" } else { "" },
            p.toolchain,
            super::fmt_f64(p.scale),
            p.host_cores,
        ));
        for point in &self.points {
            let labels = point
                .labels
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(" ");
            let (metric, value) = super::registry::primary_metric(point);
            out.push_str(&format!("  {labels}: {metric} {}\n", super::fmt_f64(value)));
        }
        out
    }
}

fn parse_cell_num<T: std::str::FromStr>(key: &str, value: &str) -> Result<T> {
    value.parse().map_err(|_| anyhow!("bad value for {key}: {value}"))
}

/// Execute a plan: expand the grid, run every cell through its backend
/// harness with the plan's sample budget, and return the labeled,
/// provenance-stamped points. Cells inherit `base` (the session config)
/// with the plan's `[fixed]` keys pinned and the cell's axis values
/// applied on top; backend parameter axes route to harness parameters
/// instead of [`Config`].
pub fn run_plan(plan: &AblationPlan, base: &Config) -> Result<PlanReport> {
    plan.validate().map_err(|e| anyhow!("invalid plan {:?}: {e}", plan.name))?;
    let opts = BenchOptions { warmup: plan.warmup, samples: plan.samples, verbose: false };
    let mut pinned = base.clone();
    for (key, value) in &plan.fixed {
        pinned
            .set(key, value)
            .map_err(|e| anyhow!("plan {} [fixed] {key}: {e}", plan.name))?;
    }
    pinned.validate().map_err(|e| anyhow!("plan {}: {e}", plan.name))?;
    let cells = grid(&plan.axes);
    let grid_cells = cells.len();
    let mut points = Vec::new();
    for cell in &cells {
        let mut cfg = pinned.clone();
        let mut workload = plan.workload.clone();
        let mut mode = plan.mode;
        let mut clients = plan.clients;
        let mut jobs_per_client = plan.jobs_per_client;
        let mut tasks = plan.tasks;
        let mut parallelism = plan.parallelism;
        let mut spec = plan.spec.clone();
        let mut connections = plan.connections;
        let mut jobs_per_connection = plan.jobs_per_connection;
        for (key, value) in cell {
            match key.as_str() {
                "workload" => workload = value.clone(),
                "mode" => mode = Mode::parse(value).map_err(|e| anyhow!("{e}"))?,
                "clients" => clients = parse_cell_num(key, value)?,
                "jobs_per_client" => jobs_per_client = parse_cell_num(key, value)?,
                "tasks" => tasks = parse_cell_num(key, value)?,
                "parallelism" => parallelism = parse_cell_num(key, value)?,
                "spec" => spec = value.clone(),
                "connections" => connections = parse_cell_num(key, value)?,
                "jobs_per_connection" => jobs_per_connection = parse_cell_num(key, value)?,
                _ => cfg.set(key, value).map_err(|e| anyhow!("{e}"))?,
            }
        }
        cfg.validate().map_err(|e| anyhow!("{e}"))?;
        let mut cell_points: Vec<BenchPoint> = match plan.backend {
            PlanBackend::Pipeline => {
                let params = PipelineBenchParams {
                    clients,
                    jobs_per_client,
                    shard_counts: vec![cfg.shards.max(1)],
                    mode,
                    workloads: vec![workload.clone()],
                };
                let bench = pipeline_bench::run(&cfg, &params, &opts)?;
                bench
                    .points
                    .iter()
                    .map(pipeline_bench::unified_point)
                    .map(|mut p| {
                        p.labels.insert("mode".to_string(), mode.label());
                        p
                    })
                    .collect()
            }
            PlanBackend::Executor => {
                let bench = executor_bench::run(tasks, parallelism, &opts);
                bench.runs.iter().map(executor_bench::unified_point).collect()
            }
            PlanBackend::Ingress => {
                let params = IngressBenchParams {
                    wires: vec![cfg.wire],
                    pollers: vec![cfg.poller.resolved()],
                    reactor_counts: vec![cfg.reactors.max(1)],
                    connections: vec![connections],
                    jobs_per_connection,
                    spec: spec.clone(),
                };
                let bench = ingress_bench::run(&cfg, &params, &opts)?;
                bench.points.iter().map(ingress_bench::unified_point).collect()
            }
        };
        // Stamp the cell's axis coordinates onto every point. Backend
        // labels win on collision — e.g. the pipeline's `shards` label
        // reports the *actual* shard count, which an auto (`shards=0`)
        // axis value wouldn't.
        for point in &mut cell_points {
            for (key, value) in cell {
                point.labels.entry(key.clone()).or_insert_with(|| value.clone());
            }
        }
        points.extend(cell_points);
    }
    Ok(PlanReport {
        name: plan.name.clone(),
        backend: plan.backend,
        profile: if cfg!(debug_assertions) { "debug" } else { "release" },
        seed: plan.seed,
        grid_cells,
        provenance: Provenance::capture(plan.seed, pinned.scale),
        points,
    })
}

/// One CI gate target: a committed baseline file and the `cargo bench`
/// target that regenerates its current run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GateTarget {
    /// `sfut bench gate <name>` / `ci/check_bench.sh <name>`.
    pub name: String,
    /// Committed baseline filename at the repo root.
    pub baseline: String,
    /// `cargo bench --bench <bench_target>` regenerates the current run.
    pub bench_target: String,
}

/// The built-in gate set, used when `ci/plans/gates.plan` is absent.
/// Kept in sync with the committed file — the file is the source of
/// truth CI reads (`sfut bench list gates`).
pub const DEFAULT_GATE_SET: &str = "pipeline = BENCH_pipeline.json pipeline_throughput\n\
     ingress = BENCH_ingress.json ingress_wire\n\
     executor = BENCH_executor.json ablation_overhead\n";

/// Parse a gate-set file: `name = baseline bench_target` lines, `#`
/// comments. Errors name their line.
pub fn parse_gate_set(text: &str) -> Result<Vec<GateTarget>, String> {
    let mut targets: Vec<GateTarget> = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((name, rest)) = line.split_once('=') else {
            return Err(format!("line {lineno}: expected name = baseline bench_target"));
        };
        let name = name.trim().to_string();
        let parts: Vec<&str> = rest.split_whitespace().collect();
        if parts.len() != 2 {
            return Err(format!(
                "line {lineno}: expected name = baseline bench_target, got {} value \
                 token(s)",
                parts.len()
            ));
        }
        if name == "all" {
            return Err(format!("line {lineno}: \"all\" is reserved for the whole set"));
        }
        if targets.iter().any(|t| t.name == name) {
            return Err(format!("line {lineno}: duplicate gate target {name}"));
        }
        targets.push(GateTarget {
            name,
            baseline: parts[0].to_string(),
            bench_target: parts[1].to_string(),
        });
    }
    if targets.is_empty() {
        return Err("gate set declares no targets".to_string());
    }
    Ok(targets)
}

/// Where the committed plans live.
pub fn plans_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("ci").join("plans")
}

/// The committed gate-set file.
pub fn gate_set_path() -> PathBuf {
    plans_dir().join("gates.plan")
}

/// The plan-declared gate set: `ci/plans/gates.plan` when present,
/// [`DEFAULT_GATE_SET`] otherwise (e.g. a checkout that predates it).
pub fn load_gate_set() -> Result<Vec<GateTarget>, String> {
    match std::fs::read_to_string(gate_set_path()) {
        Ok(text) => {
            parse_gate_set(&text).map_err(|e| format!("{}: {e}", gate_set_path().display()))
        }
        Err(_) => parse_gate_set(DEFAULT_GATE_SET),
    }
}

/// Load one plan file: read, parse, validate.
pub fn load(path: &Path) -> Result<AblationPlan, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    let plan = parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    plan.validate().map_err(|e| format!("{}: {e}", path.display()))?;
    Ok(plan)
}

/// Every `*.plan` in a directory (excluding the gate set), sorted by
/// plan name. Cross-file duplicate names are an error — `sfut bench
/// run` addresses plans by file, but the registry groups by name.
pub fn load_all_plans_in(dir: &Path) -> Result<Vec<(AblationPlan, PathBuf)>, String> {
    let mut plans: Vec<(AblationPlan, PathBuf)> = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(entries) => entries,
        Err(_) => return Ok(plans), // no plans dir yet — an empty lab
    };
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "plan"))
        .filter(|p| p.file_name().is_some_and(|n| n != "gates.plan"))
        .collect();
    paths.sort();
    for path in paths {
        let plan = load(&path)?;
        if let Some((_, prev)) = plans.iter().find(|(p, _)| p.name == plan.name) {
            return Err(format!(
                "duplicate plan name {:?} in {} and {}",
                plan.name,
                prev.display(),
                path.display()
            ));
        }
        plans.push((plan, path));
    }
    plans.sort_by(|a, b| a.0.name.cmp(&b.0.name));
    Ok(plans)
}

/// [`load_all_plans_in`] on the committed [`plans_dir`].
pub fn load_all_plans() -> Result<Vec<(AblationPlan, PathBuf)>, String> {
    load_all_plans_in(&plans_dir())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SMOKE: &str = "\
        # a smoke plan\n\
        name = smoke\n\
        backend = pipeline\n\
        seed = 42\n\
        samples = 2\n\
        workload = primes\n\
        [axis]\n\
        shards = 1, 2\n\
        deque = chase_lev, locked\n\
        [fixed]\n\
        scale = 0.05\n";

    #[test]
    fn parses_a_plan_with_axes_and_fixed_keys() {
        let plan = parse(SMOKE).unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.name, "smoke");
        assert_eq!(plan.backend, PlanBackend::Pipeline);
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.samples, 2);
        assert_eq!(plan.axes.len(), 2);
        assert_eq!(plan.axes[0].key, "shards");
        assert_eq!(plan.axes[1].values, vec!["chase_lev", "locked"]);
        assert_eq!(plan.fixed, vec![("scale".to_string(), "0.05".to_string())]);
        assert_eq!(plan.grid_size(), 4);
        // The grid crosses in file order, last axis fastest.
        let cells = grid(&plan.axes);
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0][0], ("shards".to_string(), "1".to_string()));
        assert_eq!(cells[0][1], ("deque".to_string(), "chase_lev".to_string()));
        assert_eq!(cells[1][1], ("deque".to_string(), "locked".to_string()));
        assert_eq!(cells[2][0], ("shards".to_string(), "2".to_string()));
    }

    #[test]
    fn rejects_bad_axes_and_values() {
        // Unknown key: neither a backend param nor a config key.
        let bad_key = SMOKE.replace("shards = 1, 2", "flux_capacitor = 1, 2");
        let err = parse(&bad_key).unwrap().validate().unwrap_err();
        assert!(err.contains("flux_capacitor"), "{err}");
        // Known config key, bad value.
        let bad_value = SMOKE.replace("deque = chase_lev, locked", "deque = warp");
        let err = parse(&bad_value).unwrap().validate().unwrap_err();
        assert!(err.contains("deque"), "{err}");
        // Unknown workload.
        let bad_workload = SMOKE.replace("workload = primes", "workload = nope");
        let err = parse(&bad_workload).unwrap().validate().unwrap_err();
        assert!(err.contains("unknown workload"), "{err}");
        // Empty axis values line.
        let empty_axis = SMOKE.replace("shards = 1, 2", "shards =");
        let err = parse(&empty_axis).unwrap_err();
        assert!(err.contains("no values"), "{err}");
        // No axes at all → empty grid.
        let plan = parse("name = empty\nbackend = pipeline\n").unwrap();
        let err = plan.validate().unwrap_err();
        assert!(err.contains("no axes"), "{err}");
    }

    #[test]
    fn rejects_duplicates_and_collisions() {
        let dup_top = format!("name = twice\n{SMOKE}");
        let err = parse(&dup_top).unwrap_err();
        assert!(err.contains("duplicate key name"), "{err}");
        let dup_axis = SMOKE.replace("[fixed]", "shards = 4\n[fixed]");
        let err = parse(&dup_axis).unwrap_err();
        assert!(err.contains("duplicate axis"), "{err}");
        let collision = SMOKE.replace("scale = 0.05", "shards = 4");
        let plan = parse(&collision).unwrap();
        // Axis parsing succeeded; the axis/fixed collision surfaces in
        // validation.
        let err = plan.validate().unwrap_err();
        assert!(err.contains("collides"), "{err}");
    }

    #[test]
    fn executor_plans_reject_config_axes() {
        let plan = parse(
            "name = exec\nbackend = executor\n[axis]\ntasks = 1000, 2000\nshards = 1, 2\n",
        )
        .unwrap();
        let err = plan.validate().unwrap_err();
        assert!(err.contains("shards"), "{err}");
        let ok = parse("name = exec\nbackend = executor\n[axis]\ntasks = 1000, 2000\n").unwrap();
        ok.validate().unwrap();
        assert_eq!(ok.grid_size(), 2);
    }

    #[test]
    fn seed_roundtrips_and_unknown_keys_error_with_line_numbers() {
        let plan = parse("name = s\nseed = 7\n[axis]\nshards = 1\n").unwrap();
        assert_eq!(plan.seed, 7);
        let err = parse("name = s\nbogus = 1\n").unwrap_err();
        assert!(err.starts_with("line 2:"), "{err}");
        assert!(err.contains("unknown plan key"), "{err}");
    }

    #[test]
    fn gate_set_parses_and_rejects_duplicates() {
        let targets = parse_gate_set(DEFAULT_GATE_SET).unwrap();
        assert_eq!(targets.len(), 3);
        assert_eq!(targets[0].name, "pipeline");
        assert_eq!(targets[0].baseline, "BENCH_pipeline.json");
        assert_eq!(targets[2].bench_target, "ablation_overhead");
        let dup = "a = f.json t\na = g.json u\n";
        let err = parse_gate_set(dup).unwrap_err();
        assert!(err.contains("duplicate gate target"), "{err}");
        assert!(parse_gate_set("# only comments\n").is_err());
        // The committed gate set (or the built-in fallback) always
        // loads.
        let loaded = load_gate_set().unwrap();
        assert!(loaded.iter().any(|t| t.name == "pipeline"));
    }
}

//! Minimal JSON reader for the trajectory files.
//!
//! The repo writes `BENCH_*.json` by hand (no serde offline); the CI
//! bench gate needs to read them back to compare a fresh run against
//! the committed baseline. This is a small recursive-descent parser for
//! exactly that: full JSON value grammar, numbers as `f64`, common
//! string escapes (`\" \\ \/ \n \r \t`), no `\uXXXX` (the writers never
//! emit it).

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered key/value pairs (duplicate keys keep the first
    /// match on lookup).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup; `None` for non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse a complete JSON document (rejects trailing input).
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let value = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing input at byte {}", p.pos));
    }
    Ok(value)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, want: u8) -> Result<(), String> {
        match self.bump() {
            Some(got) if got == want => Ok(()),
            other => Err(format!(
                "expected '{}' at byte {}, got {:?}",
                want as char,
                self.pos,
                other.map(|c| c as char)
            )),
        }
    }

    fn lit(&mut self, text: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(value)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            )),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(fields)),
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, got {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, got {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out: Vec<u8> = Vec::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    return String::from_utf8(out).map_err(|e| format!("invalid utf-8: {e}"))
                }
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push(b'"'),
                    Some(b'\\') => out.push(b'\\'),
                    Some(b'/') => out.push(b'/'),
                    Some(b'n') => out.push(b'\n'),
                    Some(b'r') => out.push(b'\r'),
                    Some(b't') => out.push(b'\t'),
                    other => {
                        return Err(format!(
                            "unsupported escape {:?} at byte {}",
                            other.map(|c| c as char),
                            self.pos
                        ))
                    }
                },
                // Raw bytes (including UTF-8 continuations) pass through.
                Some(b) => out.push(b),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii digits");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?} at byte {start}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\\\"there\\\"\"").unwrap(), Json::Str("hi\n\"there\"".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let doc = r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}, "f": 0.25}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("f").and_then(Json::as_f64), Some(0.25));
        let arr = v.get("a").and_then(Json::as_array).unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").and_then(Json::as_str), Some("c"));
        assert_eq!(v.get("d").unwrap().get("e"), Some(&Json::Null));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn parses_empty_containers_and_whitespace() {
        assert_eq!(parse("{ }").unwrap(), Json::Obj(vec![]));
        assert_eq!(parse("[\n]").unwrap(), Json::Arr(vec![]));
        let v = parse("  { \"k\" : [ ] }  ").unwrap();
        assert_eq!(v.get("k").and_then(Json::as_array).map(<[Json]>::len), Some(0));
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err(), "trailing input must be rejected");
        assert!(parse("\"\\q\"").is_err(), "unknown escapes must be rejected");
    }

    #[test]
    fn roundtrips_the_executor_trajectory_schema() {
        // A representative slice of what executor_bench::to_json emits.
        let doc = "{\n  \"bench\": \"executor_overhead\",\n  \"profile\": \"release\",\n  \
                   \"baseline\": {\n    \"scheduler\": \"global-queue\",\n    \
                   \"spawn_wave_secs\": 0.123456,\n    \
                   \"queue_depth\": {\"samples\": 10, \"mean\": 1.5, \
                   \"p50\": 1, \"p99\": 3, \"max\": 4}\n  },\n  \
                   \"speedup_fut_force\": 1.250\n}\n";
        let v = parse(doc).unwrap();
        assert_eq!(v.get("bench").and_then(Json::as_str), Some("executor_overhead"));
        let base = v.get("baseline").unwrap();
        assert_eq!(base.get("scheduler").and_then(Json::as_str), Some("global-queue"));
        assert_eq!(base.get("queue_depth").unwrap().get("p99").and_then(Json::as_f64), Some(3.0));
        assert_eq!(v.get("speedup_fut_force").and_then(Json::as_f64), Some(1.25));
    }
}

//! Regeneration of the paper's evaluation artifacts (§7): Table 1,
//! Figure 3, Figure 4, plus the A1–A3 ablations from DESIGN.md.
//!
//! Shared by the `sfut` CLI subcommands and the `cargo bench` targets in
//! `benches/`, so both entry points print identical reports.
//!
//! Absolute seconds will differ from the paper's Atom D410 (see
//! EXPERIMENTS.md for the shape comparison); the qualitative findings
//! F1–F5 are what these harnesses exhibit.

use anyhow::Result;

use super::{ascii_bar_chart, render_csv, render_table, Cell, ReportTable};
use crate::config::{ChunkPolicy, Config, Mode};
use crate::coordinator::{JobRequest, Pipeline};

/// The paper's three measurement columns.
pub fn paper_modes() -> Vec<Mode> {
    vec![Mode::Seq, Mode::Par(1), Mode::Par(2)]
}

/// paper_modes plus a machine-sized column (our extension: real cores,
/// not hyperthreads).
pub fn extended_modes() -> Vec<Mode> {
    let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
    let mut modes = paper_modes();
    if n > 2 {
        modes.push(Mode::Par(n));
    }
    modes
}

/// Median seconds for one cell: `samples` timed runs (after `warmup`),
/// result verified against the oracle on the first sample only.
pub fn time_cell(pipeline: &Pipeline, req: &JobRequest, cfg: &Config) -> Result<f64> {
    for _ in 0..cfg.warmup {
        pipeline.run_opts(req, false)?;
    }
    let mut secs = Vec::with_capacity(cfg.samples);
    for i in 0..cfg.samples {
        let result = pipeline.run_opts(req, i == 0)?;
        anyhow::ensure!(
            result.verified,
            "{} failed verification against the oracle",
            req.label()
        );
        eprintln!(
            "  [{}] sample {}/{}: {:.3}s",
            req.label(),
            i + 1,
            cfg.samples,
            result.seconds
        );
        secs.push(result.seconds);
    }
    secs.sort_by(f64::total_cmp);
    Ok(secs[secs.len() / 2])
}

fn fill_table(
    pipeline: &Pipeline,
    cfg: &Config,
    table: &mut ReportTable,
    workloads: &[&str],
    modes: &[Mode],
) -> Result<()> {
    for &w in workloads {
        for &m in modes {
            let req = JobRequest::named(w, m);
            let secs = time_cell(pipeline, &req, cfg)?;
            table.set(w, &m.label(), Cell::Seconds(secs));
        }
    }
    Ok(())
}

/// **Table 1**: six workloads × {seq, par(1), par(2)} (+ par(N) when the
/// machine has more cores). Returns table + CSV + finding checks.
pub fn table1(cfg: &Config) -> Result<String> {
    let pipeline = Pipeline::new(cfg.clone())?;
    let modes = extended_modes();
    let cols: Vec<String> = modes.iter().map(Mode::label).collect();
    let mut table = ReportTable::new(
        &format!(
            "Table 1. Timings (seconds) — scale={}, fateman=(1+Σx)^{} over {} vars, primes n={}",
            cfg.scale,
            cfg.scaled_fateman_degree(),
            cfg.fateman_vars,
            cfg.scaled_primes_n()
        ),
        cols.iter().map(String::as_str).collect(),
    );
    let workloads = ["primes", "primes_x3", "stream", "stream_big", "list", "list_big"];
    fill_table(&pipeline, cfg, &mut table, &workloads, &modes)?;

    let mut out = render_table(&table);
    out.push('\n');
    out.push_str(&render_csv(&table));
    out.push('\n');
    out.push_str(&findings(&table));
    Ok(out)
}

/// Check the paper's qualitative findings against a measured table.
///
/// The checks adapt to the testbed's core count: the paper's Atom D410
/// had one core plus hyperthreading (expected speedup ≈1.2×); on a
/// 1-core container no wall-clock parallel gain is physically available,
/// so the speedup-dependent findings (F3 wall-clock form, F4) are
/// checked in their overhead form instead and flagged as such.
pub fn findings(t: &ReportTable) -> String {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut out = format!("paper findings check (testbed: {cores} core(s)):\n");
    let mut check = |name: &str, desc: &str, ok: Option<bool>| {
        let verdict = match ok {
            Some(true) => "HOLDS",
            Some(false) => "DIFFERS",
            None => "n/a (cells missing)",
        };
        out.push_str(&format!("  {name}: {desc}: {verdict}\n"));
    };
    let get = |r: &str, c: &str| t.seconds(r, c);
    // F1: primes does not scale (par(2) not faster than seq).
    check(
        "F1",
        "primes par(2) >= seq (stream sieve does not scale)",
        get("primes", "par(2)").zip(get("primes", "seq")).map(|(p, s)| p >= 0.9 * s),
    );
    // F2: stream small coefficients do not scale: par(2) >= seq.
    check(
        "F2",
        "stream par(2) >= seq (small coefficients do not scale)",
        get("stream", "par(2)").zip(get("stream", "seq")).map(|(p, s)| p >= 0.9 * s),
    );
    // F3: big coefficients compensate the parallelization overhead.
    if cores >= 2 {
        check(
            "F3",
            "stream_big par(2) < par(1) (big coefficients recover)",
            get("stream_big", "par(2)")
                .zip(get("stream_big", "par(1)"))
                .map(|(p2, p1)| p2 < p1),
        );
    } else {
        // Overhead-ratio form: the relative cost of the Future machinery
        // must shrink when elementary operations grow (the mechanism
        // behind the paper's crossover).
        let ratio = |w: &str| {
            get(w, "par(1)").zip(get(w, "seq")).map(|(p, s)| p / s)
        };
        check(
            "F3'",
            "stream_big par(1)/seq < stream par(1)/seq (overhead amortized by \
             big coefficients; wall-clock form needs >1 core)",
            ratio("stream_big").zip(ratio("stream")).map(|(big, small)| big < small),
        );
    }
    // F4: list baseline scales with hardware.
    if cores >= 2 {
        check(
            "F4",
            "list par(2) < seq (data-parallel baseline scales)",
            get("list", "par(2)").zip(get("list", "seq")).map(|(p, s)| p < s),
        );
    } else {
        check(
            "F4'",
            "list par(2) <= ~1.4x seq (data-parallel overhead is small; \
             speedup form needs >1 core)",
            get("list", "par(2)").zip(get("list", "seq")).map(|(p, s)| p <= 1.4 * s),
        );
    }
    // F5: sequential stream is in the same league as the optimized
    // iterative baseline (paper: "not worse than half as fast").
    check(
        "F5",
        "stream seq <= ~4x list seq (streaming approach is sound)",
        get("stream", "seq").zip(get("list", "seq")).map(|(st, l)| st <= 4.0 * l),
    );
    out
}

/// **Figure 3**: primes timings bar chart.
pub fn fig3(cfg: &Config) -> Result<String> {
    let pipeline = Pipeline::new(cfg.clone())?;
    let modes = paper_modes();
    let cols: Vec<String> = modes.iter().map(Mode::label).collect();
    let mut table = ReportTable::new(
        "Figure 3 data. Timings for primes (seconds)",
        cols.iter().map(String::as_str).collect(),
    );
    fill_table(
        &pipeline,
        cfg,
        &mut table,
        &["primes", "primes_x3"],
        &modes,
    )?;
    Ok(chart_from_table("Figure 3. Timings for primes (seconds)", &table))
}

/// **Figure 4**: polynomial multiplication timings bar chart.
pub fn fig4(cfg: &Config) -> Result<String> {
    let pipeline = Pipeline::new(cfg.clone())?;
    let modes = paper_modes();
    let cols: Vec<String> = modes.iter().map(Mode::label).collect();
    let mut table = ReportTable::new(
        "Figure 4 data. Timings for polynomial multiplication (seconds)",
        cols.iter().map(String::as_str).collect(),
    );
    fill_table(
        &pipeline,
        cfg,
        &mut table,
        &["stream", "stream_big", "list", "list_big"],
        &modes,
    )?;
    Ok(chart_from_table(
        "Figure 4. Timings for polynomial multiplication (seconds)",
        &table,
    ))
}

fn chart_from_table(title: &str, table: &ReportTable) -> String {
    let series: Vec<(String, Vec<(String, f64)>)> = table
        .rows()
        .iter()
        .map(|row| {
            (
                row.clone(),
                table
                    .columns
                    .iter()
                    .filter_map(|c| table.seconds(row, c).map(|s| (c.clone(), s)))
                    .collect(),
            )
        })
        .collect();
    let mut out = ascii_bar_chart(title, &series, 50);
    out.push('\n');
    out.push_str(&render_csv(table));
    out
}

/// **A1**: chunk-size sweep (the §7 improvement hypothesis, tested).
pub fn ablation_chunk(cfg: &Config, chunk_sizes: &[usize]) -> Result<String> {
    let modes = [Mode::Seq, Mode::Par(2), machine_mode()];
    let cols: Vec<String> = modes.iter().map(Mode::label).collect();
    let mut table = ReportTable::new(
        "A1. Chunked stream multiply: chunk-size sweep (seconds, chunked_big workload)",
        cols.iter().map(String::as_str).collect(),
    );
    for &chunk in chunk_sizes {
        let mut c = cfg.clone();
        c.chunk_size = chunk;
        // The sweep varies the block edge, so the adaptive sizer (which
        // would override it) is pinned off for this ablation.
        c.chunk_policy = ChunkPolicy::Fixed;
        let pipeline = Pipeline::new(c.clone())?;
        for &m in &modes {
            let req = JobRequest::named("chunked_big", m);
            let secs = time_cell(&pipeline, &req, &c)?;
            table.set(&format!("chunk={chunk}"), &m.label(), Cell::Seconds(secs));
        }
    }
    // Reference row: the unchunked stream algorithm.
    let pipeline = Pipeline::new(cfg.clone())?;
    for &m in &modes {
        let req = JobRequest::named("stream_big", m);
        let secs = time_cell(&pipeline, &req, cfg)?;
        table.set("unchunked(stream_big)", &m.label(), Cell::Seconds(secs));
    }
    let mut out = render_table(&table);
    out.push('\n');
    out.push_str(&render_csv(&table));
    Ok(out)
}

/// **A2**: kernel offload vs pure-Rust block backend on the chunked
/// workload (small coefficients: kernel-eligible path).
pub fn ablation_kernel(cfg: &Config) -> Result<String> {
    let modes = [Mode::Seq, machine_mode()];
    let cols: Vec<String> = modes.iter().map(Mode::label).collect();
    let mut table = ReportTable::new(
        "A2. Chunked multiply backend: PJRT kernel vs pure-Rust block (seconds)",
        cols.iter().map(String::as_str).collect(),
    );
    for (row, use_kernel) in [("pjrt-kernel", true), ("rust-scalar", false)] {
        let mut c = cfg.clone();
        c.use_kernel = use_kernel;
        let pipeline = Pipeline::new(c.clone())?;
        if use_kernel && pipeline.engine().is_none() {
            table.set(row, &modes[0].label(), Cell::Text("no artifacts".into()));
            continue;
        }
        for &m in &modes {
            let req = JobRequest::named("chunked", m);
            let secs = time_cell(&pipeline, &req, &c)?;
            table.set(row, &m.label(), Cell::Seconds(secs));
        }
    }
    let mut out = render_table(&table);
    out.push('\n');
    out.push_str(&render_csv(&table));
    Ok(out)
}

fn machine_mode() -> Mode {
    Mode::Par(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> Config {
        let mut cfg = Config::default();
        cfg.primes_n = 300;
        cfg.fateman_degree = 2;
        cfg.samples = 1;
        cfg.warmup = 0;
        cfg.use_kernel = false;
        cfg
    }

    #[test]
    fn table1_renders_all_rows() {
        let out = table1(&tiny_config()).unwrap();
        for row in ["primes", "primes_x3", "stream", "stream_big", "list", "list_big"] {
            assert!(out.contains(row), "missing row {row} in:\n{out}");
        }
        assert!(out.contains("paper findings check"));
        assert!(out.contains("seq"));
        assert!(out.contains("par(1)"));
        assert!(out.contains("par(2)"));
    }

    #[test]
    fn fig3_renders_chart_and_csv() {
        let out = fig3(&tiny_config()).unwrap();
        assert!(out.contains("Figure 3"));
        assert!(out.contains('#'));
        assert!(out.contains("workload,seq,par(1),par(2)"));
    }

    #[test]
    fn fig4_renders_chart_and_csv() {
        let out = fig4(&tiny_config()).unwrap();
        assert!(out.contains("Figure 4"));
        assert!(out.contains("stream_big"));
    }

    #[test]
    fn ablation_chunk_sweeps() {
        let out = ablation_chunk(&tiny_config(), &[4, 16]).unwrap();
        assert!(out.contains("chunk=4"));
        assert!(out.contains("chunk=16"));
        assert!(out.contains("unchunked(stream_big)"));
    }

    #[test]
    fn ablation_kernel_handles_missing_artifacts() {
        let mut cfg = tiny_config();
        cfg.artifacts_dir = "/nonexistent".into();
        let out = ablation_kernel(&cfg).unwrap();
        assert!(out.contains("rust-scalar"));
        assert!(out.contains("no artifacts"));
    }

    #[test]
    fn findings_report_shapes() {
        let mut t = ReportTable::new("t", vec!["seq", "par(1)", "par(2)"]);
        // Synthetic numbers shaped like the paper's Table 1.
        t.set("primes", "seq", Cell::Seconds(3.4));
        t.set("primes", "par(2)", Cell::Seconds(5.9));
        t.set("stream", "seq", Cell::Seconds(14.0));
        t.set("stream", "par(1)", Cell::Seconds(35.1));
        t.set("stream", "par(2)", Cell::Seconds(37.7));
        t.set("stream_big", "seq", Cell::Seconds(48.0));
        t.set("stream_big", "par(1)", Cell::Seconds(67.5));
        t.set("stream_big", "par(2)", Cell::Seconds(49.5));
        t.set("list", "seq", Cell::Seconds(8.2));
        t.set("list", "par(2)", Cell::Seconds(5.7));
        let report = findings(&t);
        assert!(report.contains("F1: "));
        // With the paper's own numbers, every finding holds.
        assert_eq!(report.matches("HOLDS").count(), 5, "{report}");
    }
}

//! Table 1-style report tables: named rows × named columns of optional
//! cells, rendered as aligned text (the paper's table) and CSV.

use std::collections::BTreeMap;

/// One table cell.
#[derive(Debug, Clone, PartialEq)]
pub enum Cell {
    Seconds(f64),
    Text(String),
}

impl Cell {
    fn render(&self) -> String {
        match self {
            Cell::Seconds(s) => {
                if *s >= 100.0 {
                    format!("{s:.0}")
                } else if *s >= 10.0 {
                    format!("{s:.1}")
                } else {
                    format!("{s:.2}")
                }
            }
            Cell::Text(t) => t.clone(),
        }
    }
}

/// Row-major sparse table preserving row insertion order (like the
/// paper: primes, primes_x3, stream, stream_big, list, list_big).
pub struct ReportTable {
    pub title: String,
    pub columns: Vec<String>,
    row_order: Vec<String>,
    cells: BTreeMap<(String, String), Cell>,
}

impl ReportTable {
    pub fn new(title: &str, columns: Vec<&str>) -> Self {
        ReportTable {
            title: title.to_string(),
            columns: columns.into_iter().map(str::to_string).collect(),
            row_order: Vec::new(),
            cells: BTreeMap::new(),
        }
    }

    pub fn set(&mut self, row: &str, col: &str, cell: Cell) {
        assert!(
            self.columns.iter().any(|c| c == col),
            "unknown column {col:?} (have {:?})",
            self.columns
        );
        if !self.row_order.iter().any(|r| r == row) {
            self.row_order.push(row.to_string());
        }
        self.cells.insert((row.to_string(), col.to_string()), cell);
    }

    pub fn get(&self, row: &str, col: &str) -> Option<&Cell> {
        self.cells.get(&(row.to_string(), col.to_string()))
    }

    pub fn rows(&self) -> &[String] {
        &self.row_order
    }

    /// Seconds value of a cell, if numeric.
    pub fn seconds(&self, row: &str, col: &str) -> Option<f64> {
        match self.get(row, col)? {
            Cell::Seconds(s) => Some(*s),
            Cell::Text(_) => None,
        }
    }
}

/// Aligned-text rendering (the paper's Table 1 layout).
pub fn render_table(t: &ReportTable) -> String {
    let mut out = String::new();
    out.push_str(&t.title);
    out.push('\n');
    let row_w = t
        .row_order
        .iter()
        .map(String::len)
        .chain(std::iter::once("workload".len()))
        .max()
        .unwrap_or(8);
    let col_ws: Vec<usize> = t
        .columns
        .iter()
        .map(|c| {
            t.row_order
                .iter()
                .filter_map(|r| t.get(r, c))
                .map(|cell| cell.render().len())
                .chain(std::iter::once(c.len()))
                .max()
                .unwrap_or(c.len())
        })
        .collect();
    // Header.
    out.push_str(&format!("| {:<row_w$} |", "workload"));
    for (c, w) in t.columns.iter().zip(&col_ws) {
        out.push_str(&format!(" {c:>w$} |"));
    }
    out.push('\n');
    out.push_str(&format!("|{}|", "-".repeat(row_w + 2)));
    for w in &col_ws {
        out.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    out.push('\n');
    // Body.
    for r in &t.row_order {
        out.push_str(&format!("| {r:<row_w$} |"));
        for (c, w) in t.columns.iter().zip(&col_ws) {
            let text = t.get(r, c).map(Cell::render).unwrap_or_default();
            out.push_str(&format!(" {text:>w$} |"));
        }
        out.push('\n');
    }
    out
}

/// CSV rendering for downstream plotting.
pub fn render_csv(t: &ReportTable) -> String {
    let mut out = String::from("workload");
    for c in &t.columns {
        out.push(',');
        out.push_str(c);
    }
    out.push('\n');
    for r in &t.row_order {
        out.push_str(r);
        for c in &t.columns {
            out.push(',');
            if let Some(cell) = t.get(r, c) {
                out.push_str(&cell.render());
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_formats_by_magnitude() {
        assert_eq!(Cell::Seconds(3.41).render(), "3.41");
        assert_eq!(Cell::Seconds(15.73).render(), "15.7");
        assert_eq!(Cell::Seconds(148.0).render(), "148");
    }

    #[test]
    #[should_panic(expected = "unknown column")]
    fn unknown_column_is_loud() {
        let mut t = ReportTable::new("t", vec!["a"]);
        t.set("r", "b", Cell::Seconds(1.0));
    }

    #[test]
    fn row_order_is_insertion_order() {
        let mut t = ReportTable::new("t", vec!["c"]);
        t.set("zebra", "c", Cell::Seconds(1.0));
        t.set("ant", "c", Cell::Seconds(2.0));
        assert_eq!(t.rows(), &["zebra".to_string(), "ant".to_string()]);
        let text = render_table(&t);
        let zi = text.find("zebra").unwrap();
        let ai = text.find("ant").unwrap();
        assert!(zi < ai);
    }

    #[test]
    fn seconds_accessor() {
        let mut t = ReportTable::new("t", vec!["c"]);
        t.set("r", "c", Cell::Seconds(2.5));
        assert_eq!(t.seconds("r", "c"), Some(2.5));
        assert_eq!(t.seconds("r", "missing"), None);
        t.set("r2", "c", Cell::Text("n/a".into()));
        assert_eq!(t.seconds("r2", "c"), None);
    }
}

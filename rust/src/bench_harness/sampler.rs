//! Timed sampling with robust statistics.

use std::time::{Duration, Instant};

/// Sampling controls. `SFUT_BENCH_SAMPLES` / `SFUT_BENCH_WARMUP`
/// environment variables override (CI shrinks, perf runs grow).
#[derive(Debug, Clone)]
pub struct BenchOptions {
    pub warmup: usize,
    pub samples: usize,
    /// Print progress to stderr as cells complete.
    pub verbose: bool,
}

impl Default for BenchOptions {
    fn default() -> Self {
        let samples = std::env::var("SFUT_BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(5);
        let warmup = std::env::var("SFUT_BENCH_WARMUP")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1);
        BenchOptions { warmup, samples, verbose: true }
    }
}

/// Result of measuring one cell.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<Duration>,
    /// Median — the reported statistic (robust to scheduler noise).
    pub median: Duration,
    /// Median absolute deviation — the reported spread.
    pub mad: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Measurement {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Run `f` `warmup + samples` times; keep the last `samples` timings.
pub fn measure(name: &str, opts: &BenchOptions, mut f: impl FnMut()) -> Measurement {
    assert!(opts.samples > 0, "samples must be >= 1");
    for _ in 0..opts.warmup {
        f();
    }
    let mut samples = Vec::with_capacity(opts.samples);
    for i in 0..opts.samples {
        let start = Instant::now();
        f();
        let took = start.elapsed();
        samples.push(took);
        if opts.verbose {
            eprintln!("  [{name}] sample {}/{}: {took:?}", i + 1, opts.samples);
        }
    }
    summarize(name, samples)
}

fn summarize(name: &str, samples: Vec<Duration>) -> Measurement {
    let mut sorted = samples.clone();
    sorted.sort_unstable();
    let median = percentile_sorted(&sorted, 0.5);
    let mut devs: Vec<Duration> = sorted
        .iter()
        .map(|&s| if s > median { s - median } else { median - s })
        .collect();
    devs.sort_unstable();
    let mad = percentile_sorted(&devs, 0.5);
    Measurement {
        name: name.to_string(),
        min: sorted[0],
        max: *sorted.last().unwrap(),
        median,
        mad,
        samples,
    }
}

/// Nearest-rank percentile of an ascending-sorted sample set. Shared by
/// the measurement summary here and the pipeline bench's latency
/// percentiles, so every trajectory uses one definition.
pub(crate) fn percentile_sorted(sorted: &[Duration], q: f64) -> Duration {
    debug_assert!(!sorted.is_empty());
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_odd_count() {
        let m = summarize(
            "x",
            vec![
                Duration::from_millis(10),
                Duration::from_millis(30),
                Duration::from_millis(20),
            ],
        );
        assert_eq!(m.median, Duration::from_millis(20));
        assert_eq!(m.min, Duration::from_millis(10));
        assert_eq!(m.max, Duration::from_millis(30));
        assert_eq!(m.mad, Duration::from_millis(10));
    }

    #[test]
    fn summarize_single_sample() {
        let m = summarize("x", vec![Duration::from_millis(7)]);
        assert_eq!(m.median, Duration::from_millis(7));
        assert_eq!(m.mad, Duration::ZERO);
    }

    #[test]
    fn measure_runs_warmup_plus_samples() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = AtomicUsize::new(0);
        let opts = BenchOptions { warmup: 2, samples: 3, verbose: false };
        let m = measure("count", &opts, || {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 5);
        assert_eq!(m.samples.len(), 3);
    }

    #[test]
    fn median_is_robust_to_one_outlier() {
        let m = summarize(
            "x",
            vec![
                Duration::from_millis(10),
                Duration::from_millis(11),
                Duration::from_millis(12),
                Duration::from_millis(11),
                Duration::from_millis(500), // GC-pause-style outlier
            ],
        );
        assert_eq!(m.median, Duration::from_millis(11));
    }
}

//! ASCII bar charts mirroring the paper's Figures 3 and 4 (horizontal
//! bars, one group per workload, one bar per execution mode).

/// `series`: `[(group_label, [(bar_label, seconds)])]`.
/// `width`: maximum bar width in characters.
pub fn ascii_bar_chart(
    title: &str,
    series: &[(String, Vec<(String, f64)>)],
    width: usize,
) -> String {
    let max_v = series
        .iter()
        .flat_map(|(_, bars)| bars.iter().map(|(_, v)| *v))
        .fold(0.0f64, f64::max);
    let label_w = series
        .iter()
        .flat_map(|(_, bars)| bars.iter().map(|(l, _)| l.len()))
        .max()
        .unwrap_or(4);
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (group, bars) in series {
        out.push_str(&format!("{group}\n"));
        for (label, v) in bars {
            let n = if max_v > 0.0 {
                ((v / max_v) * width as f64).round() as usize
            } else {
                0
            };
            out.push_str(&format!(
                "  {label:<label_w$} |{} {v:.2}\n",
                "#".repeat(n.max(if *v > 0.0 { 1 } else { 0 }))
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bars_scale_to_width() {
        let series = vec![(
            "g".to_string(),
            vec![("a".to_string(), 1.0), ("b".to_string(), 2.0)],
        )];
        let chart = ascii_bar_chart("t", &series, 10);
        let lines: Vec<&str> = chart.lines().collect();
        let a_bar = lines[2].matches('#').count();
        let b_bar = lines[3].matches('#').count();
        assert_eq!(b_bar, 10);
        assert_eq!(a_bar, 5);
    }

    #[test]
    fn zero_values_have_no_bar() {
        let series = vec![("g".to_string(), vec![("a".to_string(), 0.0)])];
        let chart = ascii_bar_chart("t", &series, 10);
        assert!(!chart.lines().nth(2).unwrap().contains('#'));
    }

    #[test]
    fn tiny_nonzero_values_render_one_hash() {
        let series = vec![(
            "g".to_string(),
            vec![("tiny".to_string(), 0.001), ("big".to_string(), 100.0)],
        )];
        let chart = ascii_bar_chart("t", &series, 20);
        assert!(chart.lines().nth(2).unwrap().contains('#'));
    }
}

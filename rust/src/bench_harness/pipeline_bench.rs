//! Pipeline-level throughput benchmark with a machine-readable
//! trajectory (`BENCH_pipeline.json`).
//!
//! `BENCH_executor.json` (PR 1) tracks the executor substrate;
//! this harness measures the layer the paper's construct actually
//! serves traffic through: concurrent clients driving [`Pipeline`]
//! jobs end-to-end — routing, shard lease, driver thread, adaptive
//! chunking, verification-off steady state — at shard counts
//! ∈ {1, 2, N} (N = the machine's auto count). Reported per
//! (workload, shard count) cell:
//!
//! * **jobs/sec** — batch size / median batch wall-clock, with the same
//!   warmup + median-of-samples discipline as the executor bench
//!   ([`measure`]);
//! * **p50/p95 latency** — per-job, across every post-warmup sample;
//! * **queue-wait p50/p95** — time each job spent admitted-but-waiting
//!   (admission queue + shard run queue) before a runner picked it up,
//!   from the `JobResult::queue_wait` field — the saturation signal the
//!   ingress rework added;
//! * **shed rate** — ingress submissions rejected ÷ submissions over the
//!   cell (0 under the default `block` policy; nonzero when a `shed` or
//!   `timeout` admission config is being benched);
//! * **panic/retry rate** — `jobs.panicked` / `jobs.retried` deltas ÷
//!   submissions over the cell. Production workloads must bench at 0;
//!   [`gate`] warns when a current run shows nonzero panics on any
//!   workload whose name doesn't mark it as deliberately faulty;
//! * **steal counter** — the shard pools' cumulative `tasks_stolen`.
//!
//! Seeding discipline matches the executor trajectory: `cargo test`
//! seeds the file only when absent (debug profile, smoke scale);
//! `cargo bench --bench pipeline_throughput` overwrites it with
//! release numbers. The committed file is the CI bench gate's baseline
//! (`ci/check_bench.sh` → [`gate`] → `sfut check-bench`): a fresh run
//! whose jobs/sec drops more than the threshold below a *comparable*
//! baseline (same profile and run parameters) fails the gate.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::{
    fmt_f64, measure, BenchOptions, BenchPoint, BenchReport, Provenance, BENCH_SCHEMA_VERSION,
};
use crate::config::{Config, Mode};
use crate::coordinator::{JobRequest, Pipeline, ShardSet};
use crate::workload::WorkloadRegistry;

/// Shape of one bench run: who drives how many jobs, where.
#[derive(Debug, Clone)]
pub struct PipelineBenchParams {
    /// Concurrent client threads per sample.
    pub clients: usize,
    /// Jobs each client runs per sample.
    pub jobs_per_client: usize,
    /// Shard counts to sweep (deduplicated by the caller; see
    /// [`default_shard_counts`]).
    pub shard_counts: Vec<usize>,
    /// Evaluation mode for every job (par(2) = the paper's column).
    pub mode: Mode,
    /// Workload registry names to sweep (default: the whole builtin
    /// registry — see [`trajectory_workloads`]).
    pub workloads: Vec<String>,
}

impl Default for PipelineBenchParams {
    fn default() -> Self {
        PipelineBenchParams {
            clients: 4,
            jobs_per_client: 4,
            shard_counts: default_shard_counts(2),
            mode: Mode::Par(2),
            workloads: trajectory_workloads(),
        }
    }
}

/// The trajectory's workload list: every name in the builtin registry.
/// The bench sweeps the *registry*, not a hardcoded list, so newly
/// registered plugins grow scenario columns in `BENCH_pipeline.json`
/// automatically (the gate tolerates extra workloads the committed
/// baseline has never seen; only *vanished* baseline workloads fail).
pub fn trajectory_workloads() -> Vec<String> {
    WorkloadRegistry::builtin().names()
}

/// The issue's sweep: shards ∈ {1, 2, N}, N = auto count for
/// `shard_parallelism`, deduplicated and ascending.
pub fn default_shard_counts(shard_parallelism: usize) -> Vec<usize> {
    let mut counts = vec![1, 2, ShardSet::auto_count(shard_parallelism)];
    counts.sort_unstable();
    counts.dedup();
    counts
}

/// One (workload, shard count) cell.
#[derive(Debug, Clone)]
pub struct WorkloadPoint {
    pub workload: String,
    pub shards: usize,
    /// Jobs per timed sample (clients × jobs_per_client).
    pub jobs_per_sample: u64,
    pub jobs_per_sec: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// Queue-wait percentiles across post-warmup jobs (admission +
    /// run-queue time before execution started).
    pub queue_wait_p50_ms: f64,
    pub queue_wait_p95_ms: f64,
    /// Ingress shed fraction over the whole cell (sheds ÷ submissions,
    /// warmup included; 0 under `admission = block`).
    pub shed_rate: f64,
    /// `jobs.panicked` delta ÷ submissions over the cell. Must be 0 for
    /// healthy workloads — the gate warns otherwise.
    pub panic_rate: f64,
    /// `jobs.retried` delta ÷ submissions over the cell.
    pub retry_rate: f64,
    /// Cumulative steals across the pipeline's shard pools during this
    /// cell (warmup included).
    pub tasks_stolen: u64,
    /// The cell's pre-flight job passed oracle verification.
    pub verified: bool,
}

/// The full sweep.
#[derive(Debug, Clone)]
pub struct PipelineBench {
    /// "release" or "debug" — only release points belong on the
    /// cross-PR trajectory; the gate refuses to compare across profiles.
    pub profile: &'static str,
    pub scale: f64,
    pub clients: usize,
    pub jobs_per_client: usize,
    pub mode: String,
    pub warmup: usize,
    pub samples: usize,
    pub shard_counts: Vec<usize>,
    /// Where this run came from (commit, dirty flag, toolchain, …).
    pub provenance: Provenance,
    pub points: Vec<WorkloadPoint>,
}

fn build_profile() -> &'static str {
    if cfg!(debug_assertions) {
        "debug"
    } else {
        "release"
    }
}

fn total_steals(pipeline: &Pipeline) -> u64 {
    pipeline.shards().stats().iter().map(|(_, s)| s.tasks_stolen).sum()
}

fn counter(pipeline: &Pipeline, name: &str) -> u64 {
    pipeline.metrics().snapshot().counters.get(name).copied().unwrap_or(0)
}

fn percentile_ms(sorted: &[Duration], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    super::sampler::percentile_sorted(sorted, q).as_secs_f64() * 1e3
}

/// Run the sweep: for each shard count, a fresh [`Pipeline`]; for each
/// workload, one verified pre-flight job, then `warmup + samples`
/// batches of `clients × jobs_per_client` concurrent jobs.
pub fn run(
    base: &Config,
    params: &PipelineBenchParams,
    opts: &BenchOptions,
) -> Result<PipelineBench> {
    let batch = params.clients * params.jobs_per_client;
    let mut points = Vec::new();
    for &shard_count in &params.shard_counts {
        let mut cfg = base.clone();
        cfg.shards = shard_count.max(1);
        let pipeline = Pipeline::new(cfg)?;
        let actual_shards = pipeline.shards().len();
        for workload in &params.workloads {
            let req = JobRequest::named(workload.clone(), params.mode);
            // Pre-flight: verify once against the oracle; the timed
            // jobs skip it (same discipline as paper::time_cell).
            let first = pipeline.run(&req)?;
            let steals_before = total_steals(&pipeline);
            let submitted_before = counter(&pipeline, "ingress.submitted");
            let shed_before =
                counter(&pipeline, "ingress.shed") + counter(&pipeline, "ingress.timed_out");
            let panicked_before = counter(&pipeline, "jobs.panicked");
            let retried_before = counter(&pipeline, "jobs.retried");
            // (latency, queue wait) pushed together so the warmup trim
            // below stays aligned.
            let samples = Mutex::new(Vec::<(Duration, Duration)>::new());
            let label = format!("pipeline.{workload}.shards{actual_shards}");
            let timing = measure(&label, opts, || {
                std::thread::scope(|s| {
                    for _ in 0..params.clients {
                        s.spawn(|| {
                            for _ in 0..params.jobs_per_client {
                                let t = Instant::now();
                                let res =
                                    pipeline.run_opts(&req, false).expect("bench job failed");
                                let wait = Duration::from_secs_f64(res.queue_wait.max(0.0));
                                samples.lock().unwrap().push((t.elapsed(), wait));
                                std::hint::black_box(res.seconds);
                            }
                        });
                    }
                });
            });
            // measure() ran `opts.warmup` batches before sampling; drop
            // their samples so the percentiles cover samples only.
            let mut all = samples.into_inner().unwrap();
            let keep_from = (opts.warmup * batch).min(all.len());
            let kept = all.split_off(keep_from);
            let mut lat: Vec<Duration> = kept.iter().map(|&(l, _)| l).collect();
            let mut waits: Vec<Duration> = kept.iter().map(|&(_, w)| w).collect();
            lat.sort_unstable();
            waits.sort_unstable();
            let submitted = counter(&pipeline, "ingress.submitted") - submitted_before;
            let shed = counter(&pipeline, "ingress.shed")
                + counter(&pipeline, "ingress.timed_out")
                - shed_before;
            let panicked = counter(&pipeline, "jobs.panicked") - panicked_before;
            let retried = counter(&pipeline, "jobs.retried") - retried_before;
            let rate = |n: u64| if submitted == 0 { 0.0 } else { n as f64 / submitted as f64 };
            points.push(WorkloadPoint {
                workload: workload.clone(),
                shards: actual_shards,
                jobs_per_sample: batch as u64,
                jobs_per_sec: batch as f64 / timing.median_secs().max(1e-9),
                p50_ms: percentile_ms(&lat, 0.5),
                p95_ms: percentile_ms(&lat, 0.95),
                queue_wait_p50_ms: percentile_ms(&waits, 0.5),
                queue_wait_p95_ms: percentile_ms(&waits, 0.95),
                shed_rate: rate(shed),
                panic_rate: rate(panicked),
                retry_rate: rate(retried),
                tasks_stolen: total_steals(&pipeline).saturating_sub(steals_before),
                verified: first.verified,
            });
        }
    }
    Ok(PipelineBench {
        profile: build_profile(),
        scale: base.scale,
        clients: params.clients,
        jobs_per_client: params.jobs_per_client,
        mode: params.mode.label(),
        warmup: opts.warmup,
        samples: opts.samples,
        shard_counts: params.shard_counts.clone(),
        provenance: Provenance::capture(0, base.scale),
        points,
    })
}

/// Render one cell in the unified [`BenchPoint`] shape (schema v1):
/// identity under `labels`, numbers under `metrics`, booleans under
/// `flags`. The plan runner ([`super::plan::run_plan`]) reuses this to
/// feed grid cells into the results registry.
pub fn unified_point(p: &WorkloadPoint) -> BenchPoint {
    let mut point = BenchPoint::default();
    point.labels.insert("workload".to_string(), p.workload.clone());
    point.labels.insert("shards".to_string(), p.shards.to_string());
    for (key, value) in [
        ("jobs_per_sample", p.jobs_per_sample as f64),
        ("jobs_per_sec", p.jobs_per_sec),
        ("p50_ms", p.p50_ms),
        ("p95_ms", p.p95_ms),
        ("queue_wait_p50_ms", p.queue_wait_p50_ms),
        ("queue_wait_p95_ms", p.queue_wait_p95_ms),
        ("shed_rate", p.shed_rate),
        ("panic_rate", p.panic_rate),
        ("retry_rate", p.retry_rate),
        ("tasks_stolen", p.tasks_stolen as f64),
    ] {
        point.metrics.insert(key.to_string(), value);
    }
    point.flags.insert("verified".to_string(), p.verified);
    point
}

/// Serialize to the versioned `BENCH_pipeline.json` schema (hand-rolled;
/// no serde offline). Readable back via [`BenchReport::parse`] /
/// [`gate`], which also still accept the pre-v1 flat point shape.
pub fn to_json(b: &PipelineBench) -> String {
    let shard_counts =
        b.shard_counts.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(", ");
    let points = b
        .points
        .iter()
        .map(|p| format!("    {}", unified_point(p).to_json()))
        .collect::<Vec<_>>()
        .join(",\n");
    format!(
        "{{\n\
         \x20 \"schema_version\": {},\n\
         \x20 \"bench\": \"pipeline_throughput\",\n\
         \x20 \"profile\": \"{}\",\n\
         \x20 \"scale\": {},\n\
         \x20 \"clients\": {},\n\
         \x20 \"jobs_per_client\": {},\n\
         \x20 \"mode\": \"{}\",\n\
         \x20 \"warmup\": {},\n\
         \x20 \"samples\": {},\n\
         \x20 \"shard_counts\": [{}],\n\
         \x20 \"provenance\": {},\n\
         \x20 \"points\": [\n{}\n  ]\n\
         }}\n",
        BENCH_SCHEMA_VERSION,
        b.profile,
        fmt_f64(b.scale),
        b.clients,
        b.jobs_per_client,
        b.mode,
        b.warmup,
        b.samples,
        shard_counts,
        b.provenance.to_json(),
        points,
    )
}

pub fn write_json(b: &PipelineBench, path: &Path) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(to_json(b).as_bytes())
}

/// Default artifact location: the repository root.
pub fn default_output_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_pipeline.json")
}

/// Seed the trajectory file only when none exists yet, so a debug-build
/// `cargo test` smoke run never clobbers a full-scale release data
/// point (the `profile` field in the JSON disambiguates what's there).
pub fn write_json_if_absent(b: &PipelineBench) -> std::io::Result<bool> {
    let path = default_output_path();
    if path.exists() {
        return Ok(false);
    }
    write_json(b, &path).map(|()| true)
}

/// Outcome of comparing a fresh run against the committed baseline.
#[derive(Debug, Clone, PartialEq)]
pub enum GateOutcome {
    /// Every comparable cell is within the threshold.
    Passed { cells: usize },
    /// The files are not comparable (different profile/scale/run
    /// parameters, or no overlapping cells): not a pass, not a failure —
    /// the baseline needs refreshing.
    Skipped { reason: String },
    /// At least one cell regressed beyond the threshold.
    Failed { regressions: Vec<String> },
}

/// How p95 latency / queue-wait regressions are enforced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LatencyGate {
    /// Findings are reported but never fail the gate (default).
    WarnOnly,
    /// `--latency-strict`: findings fail the gate like throughput
    /// regressions.
    Strict,
    /// Strict was requested, but the committed baseline's `note` field
    /// marks it a synthetic floor — its latency ceilings are fiction,
    /// so the strict gate auto-disarms back to warn-only rather than
    /// enforce against made-up numbers. Refresh the baseline with a
    /// measured run (see `ci/check_bench.sh`) to arm it.
    StrictDisarmedSyntheticBaseline,
}

/// A gate verdict plus its latency findings. Under
/// [`LatencyGate::WarnOnly`] (and the synthetic-disarmed state) p95
/// latency / queue-wait regressions land in `warnings`; under
/// [`LatencyGate::Strict`] they join the failing regressions.
#[derive(Debug, Clone, PartialEq)]
pub struct GateReport {
    pub outcome: GateOutcome,
    /// `… p95 regressed …` lines; empty when latency held.
    pub warnings: Vec<String>,
    /// The enforcement mode this report was produced under.
    pub latency_gate: LatencyGate,
}

/// Default p95 latency growth tolerated before a warn-only finding
/// (`sfut check-bench --latency-threshold` overrides).
pub const DEFAULT_LATENCY_THRESHOLD: f64 = 0.25;

/// Ignore latency growth below this absolute floor — micro-benchmark
/// cells jitter by fractions of a millisecond and a ratio alone would
/// cry wolf on them.
const LATENCY_WARN_FLOOR_MS: f64 = 1.0;

/// Compare two `BENCH_pipeline.json` documents: `current` fails when any
/// (workload, shards) cell's jobs/sec drops below
/// `(1 - threshold) × baseline`, and reports when a cell's p95 latency or
/// p95 queue wait grows beyond `(1 + latency_threshold) × baseline`
/// (and by more than an absolute 1 ms floor) — as warnings by default,
/// as failures under `latency_strict` (`sfut check-bench
/// --latency-strict`). Strict latency gating auto-disarms while the
/// baseline's `note` field marks it a synthetic floor, so the gate can
/// never fire on fictional ceilings. Files are only comparable when
/// profile and run parameters match — debug-vs-release or
/// different-scale comparisons are meaningless and yield
/// [`GateOutcome::Skipped`]. A malformed *current* run (missing
/// profile, missing or empty points) is an error, not a skip: a broken
/// bench writer must fail the gate, not disarm it.
pub fn gate(
    baseline: &str,
    current: &str,
    threshold: f64,
    latency_threshold: f64,
    latency_strict: bool,
) -> Result<GateReport, String> {
    let b = BenchReport::parse(baseline).map_err(|e| format!("baseline: {e}"))?;
    let c = BenchReport::parse(current).map_err(|e| format!("current: {e}"))?;
    for doc in [&b, &c] {
        if doc.bench != "pipeline_throughput" {
            return Err("not a pipeline_throughput trajectory file".to_string());
        }
    }
    // The current run comes from the harness that just ran: required
    // fields missing there mean the bench writer broke, and a broken
    // writer must not quietly skip the gate. (An *old* baseline missing
    // fields is tolerated below — it only widens the Skipped path.)
    if c.param("profile").is_none() {
        return Err("current run is missing \"profile\" — bench writer broken".to_string());
    }
    if c.points.is_empty() {
        return Err("current run has no points — bench writer broken".to_string());
    }
    let synthetic_baseline = b.note.as_deref().is_some_and(|n| n.contains("synthetic"));
    let latency_gate = if !latency_strict {
        LatencyGate::WarnOnly
    } else if synthetic_baseline {
        LatencyGate::StrictDisarmedSyntheticBaseline
    } else {
        LatencyGate::Strict
    };
    for key in ["profile", "scale", "clients", "jobs_per_client", "mode", "warmup", "samples"] {
        let (bv, cv) = (b.param(key), c.param(key));
        if bv != cv {
            return Ok(GateReport {
                outcome: GateOutcome::Skipped {
                    reason: format!(
                        "{key} differs (baseline {bv:?}, current {cv:?}); runs are not \
                         comparable — refresh the committed baseline"
                    ),
                },
                warnings: Vec::new(),
                latency_gate,
            });
        }
    }

    struct CellStats {
        workload: String,
        shards: u64,
        jobs_per_sec: f64,
        /// Optional: pre-ingress baselines lack the latency fields.
        p95_ms: Option<f64>,
        queue_wait_p95_ms: Option<f64>,
        /// Optional: pre-lifecycle baselines lack the fault-rate fields.
        panic_rate: Option<f64>,
        retry_rate: Option<f64>,
    }
    let cell = |doc: &BenchReport| -> Vec<CellStats> {
        doc.points
            .iter()
            .filter_map(|p| {
                Some(CellStats {
                    workload: p.label("workload")?.to_string(),
                    shards: p.label_u64("shards")?,
                    jobs_per_sec: p.metric("jobs_per_sec")?,
                    p95_ms: p.metric("p95_ms"),
                    queue_wait_p95_ms: p.metric("queue_wait_p95_ms"),
                    panic_rate: p.metric("panic_rate"),
                    retry_rate: p.metric("retry_rate"),
                })
            })
            .collect()
    };
    let base_cells = cell(&b);
    let cur_cells = cell(&c);
    let mut compared = 0usize;
    let mut regressions = Vec::new();
    // Latency findings are routed at the end: into `warnings` (default
    // and synthetic-disarmed strict) or into the failing `regressions`
    // (armed strict).
    let mut latency_findings = Vec::new();
    let mut warn_latency = |workload: &str, shards: u64, what: &str, base: f64, cur: f64| {
        if cur > (1.0 + latency_threshold) * base && cur - base > LATENCY_WARN_FLOOR_MS {
            // Near-zero baselines (an idle queue rounds to 0.000 ms)
            // make a percentage absurd; report absolute growth instead.
            let growth = if base > 0.01 {
                format!("+{:.0}%", (cur / base - 1.0) * 100.0)
            } else {
                format!("+{:.2}ms", cur - base)
            };
            latency_findings.push(format!(
                "{workload} @ {shards} shard(s): {what} {cur:.2}ms vs baseline \
                 {base:.2}ms ({growth})"
            ));
        }
    };
    for cur in &cur_cells {
        let Some(base) = base_cells
            .iter()
            .find(|b| b.workload == cur.workload && b.shards == cur.shards)
        else {
            continue;
        };
        compared += 1;
        if cur.jobs_per_sec < (1.0 - threshold) * base.jobs_per_sec {
            let drop_pct = (1.0 - cur.jobs_per_sec / base.jobs_per_sec.max(1e-9)) * 100.0;
            regressions.push(format!(
                "{} @ {} shard(s): {:.1} jobs/s vs baseline {:.1} (-{drop_pct:.0}%)",
                cur.workload, cur.shards, cur.jobs_per_sec, base.jobs_per_sec
            ));
        }
        // Warn-only latency checks: only when both runs carry the field.
        if let (Some(b95), Some(c95)) = (base.p95_ms, cur.p95_ms) {
            warn_latency(&cur.workload, cur.shards, "p95 latency", b95, c95);
        }
        if let (Some(bq), Some(cq)) = (base.queue_wait_p95_ms, cur.queue_wait_p95_ms) {
            warn_latency(&cur.workload, cur.shards, "p95 queue wait", bq, cq);
        }
    }
    // A workload that disappears entirely is a silent 100% regression,
    // not a pass. (Individual shard-count cells are allowed to differ —
    // the N in {1, 2, N} is machine-dependent — but the workload list is
    // config-driven, so losing a whole workload means the bench stopped
    // covering it.)
    for base in &base_cells {
        let workload = &base.workload;
        if !cur_cells.iter().any(|c| c.workload == *workload)
            && !regressions.iter().any(|r| r.starts_with(&format!("{workload} vanished")))
        {
            regressions.push(format!(
                "{workload} vanished: baseline has cells for it, current run has none"
            ));
        }
    }
    let mut warnings = Vec::new();
    if latency_gate == LatencyGate::Strict {
        regressions.extend(latency_findings.iter().map(|f| format!("latency (strict): {f}")));
    } else {
        warnings = latency_findings;
    }
    // Fault-health check on the *current* run alone (no baseline
    // needed): a healthy workload panicking during a bench is a
    // correctness smell even when throughput held. Deliberately faulty
    // workloads (the chaos plugin and its registrations) are exempt.
    // Always a warning — fault injection must not fail the perf gate.
    for cur in &cur_cells {
        if cur.workload.contains("faulty") {
            continue;
        }
        if let Some(rate) = cur.panic_rate.filter(|&r| r > 0.0) {
            let retries = cur.retry_rate.unwrap_or(0.0);
            warnings.push(format!(
                "{} @ {} shard(s): panic_rate {rate:.4} (retry_rate {retries:.4}) on a \
                 non-faulty workload — jobs panicked during the bench",
                cur.workload, cur.shards
            ));
        }
    }
    if compared == 0 && regressions.is_empty() {
        return Ok(GateReport {
            outcome: GateOutcome::Skipped {
                reason: "no overlapping (workload, shards) cells".to_string(),
            },
            warnings,
            latency_gate,
        });
    }
    let outcome = if regressions.is_empty() {
        GateOutcome::Passed { cells: compared }
    } else {
        GateOutcome::Failed { regressions }
    };
    Ok(GateReport { outcome, warnings, latency_gate })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::tiny_json::{self, Json};

    fn smoke_config() -> Config {
        let mut cfg = Config::default();
        cfg.primes_n = 400;
        cfg.fateman_degree = 2;
        cfg.chunk_size = 16;
        cfg.use_kernel = false;
        cfg.shard_parallelism = 1;
        cfg
    }

    #[test]
    fn pipeline_bench_runs_and_seeds_trajectory() {
        // Small-scale smoke: correctness of the sweep plumbing, not a
        // perf claim. Seeds BENCH_pipeline.json only if absent; the
        // full-size release run lives in
        // `cargo bench --bench pipeline_throughput`.
        let params = PipelineBenchParams {
            clients: 2,
            jobs_per_client: 2,
            shard_counts: vec![1, 2],
            mode: Mode::Par(2),
            workloads: vec!["primes".into(), "primes_chunked".into(), "chunked".into()],
        };
        let opts = BenchOptions { warmup: 1, samples: 2, verbose: false };
        let b = run(&smoke_config(), &params, &opts).unwrap();
        assert_eq!(b.points.len(), 6, "3 workloads × 2 shard counts");
        assert!(b.points.iter().all(|p| p.jobs_per_sec > 0.0));
        assert!(b.points.iter().all(|p| p.verified));
        assert!(b.points.iter().all(|p| p.p95_ms >= p.p50_ms));
        assert!(b.points.iter().all(|p| p.queue_wait_p95_ms >= p.queue_wait_p50_ms));
        // Default admission is block: nothing sheds during the sweep.
        assert!(b.points.iter().all(|p| p.shed_rate == 0.0));
        // Healthy workloads must bench fault-free.
        assert!(b.points.iter().all(|p| p.panic_rate == 0.0 && p.retry_rate == 0.0));
        assert!(b.points.iter().all(|p| p.jobs_per_sample == 4));
        assert_eq!(b.points.iter().filter(|p| p.shards == 2).count(), 3);

        let json = to_json(&b);
        assert!(json.contains("\"bench\": \"pipeline_throughput\""));
        assert!(json.contains("queue_wait_p95_ms"));
        assert!(json.contains("shed_rate"));
        assert!(json.contains("panic_rate"));
        assert!(json.contains("retry_rate"));
        let parsed = tiny_json::parse(&json).expect("self-readable JSON");
        assert_eq!(parsed.get("clients").and_then(Json::as_f64), Some(2.0));
        assert_eq!(
            parsed.get("points").and_then(Json::as_array).map(<[Json]>::len),
            Some(6)
        );
        // A run gates cleanly against itself at any threshold, with no
        // latency warnings (identical numbers).
        let report = gate(&json, &json, 0.25, DEFAULT_LATENCY_THRESHOLD, false).unwrap();
        match report.outcome {
            GateOutcome::Passed { cells } => assert_eq!(cells, 6),
            other => panic!("expected pass, got {other:?}"),
        }
        assert!(report.warnings.is_empty(), "{:?}", report.warnings);

        // Serialization to disk via a scratch path (never the trajectory).
        let tmp = std::env::temp_dir().join("sfut_bench_pipeline_smoke.json");
        write_json(&b, &tmp).expect("write smoke json");
        assert!(tmp.exists());
        let _ = std::fs::remove_file(&tmp);
        // Seed the real file only when absent.
        let _ = write_json_if_absent(&b);
        assert!(default_output_path().exists());
    }

    fn doc(profile: &str, jps_primes: f64, jps_chunked: f64) -> String {
        doc_with_latency(profile, jps_primes, jps_chunked, 10.0, 2.0)
    }

    fn doc_with_latency(
        profile: &str,
        jps_primes: f64,
        jps_chunked: f64,
        p95: f64,
        queue_p95: f64,
    ) -> String {
        format!(
            "{{\"bench\": \"pipeline_throughput\", \"profile\": \"{profile}\", \
             \"scale\": 1.0, \"clients\": 2, \"jobs_per_client\": 2, \"mode\": \"par(2)\", \
             \"points\": [\
             {{\"workload\": \"primes\", \"shards\": 1, \"jobs_per_sec\": {jps_primes}, \
               \"p95_ms\": {p95}, \"queue_wait_p95_ms\": {queue_p95}}}, \
             {{\"workload\": \"chunked\", \"shards\": 2, \"jobs_per_sec\": {jps_chunked}}}]}}"
        )
    }

    const LT: f64 = DEFAULT_LATENCY_THRESHOLD;

    #[test]
    fn gate_passes_within_threshold_and_fails_beyond() {
        let base = doc("release", 100.0, 50.0);
        // 20% down on one cell: inside a 25% threshold.
        let ok = doc("release", 80.0, 50.0);
        assert_eq!(
            gate(&base, &ok, 0.25, LT, false).unwrap().outcome,
            GateOutcome::Passed { cells: 2 }
        );
        // 40% down: out.
        let bad = doc("release", 60.0, 50.0);
        match gate(&base, &bad, 0.25, LT, false).unwrap().outcome {
            GateOutcome::Failed { regressions } => {
                assert_eq!(regressions.len(), 1);
                assert!(regressions[0].contains("primes"), "{regressions:?}");
            }
            other => panic!("expected failure, got {other:?}"),
        }
        // Improvements never fail.
        let faster = doc("release", 200.0, 90.0);
        assert_eq!(
            gate(&base, &faster, 0.25, LT, false).unwrap().outcome,
            GateOutcome::Passed { cells: 2 }
        );
    }

    #[test]
    fn gate_warns_on_latency_regressions_without_failing() {
        let base = doc_with_latency("release", 100.0, 50.0, 10.0, 2.0);
        // Throughput fine, p95 latency doubled and queue wait tripled:
        // pass + two warnings.
        let slow = doc_with_latency("release", 100.0, 50.0, 20.0, 6.0);
        let report = gate(&base, &slow, 0.25, LT, false).unwrap();
        assert_eq!(report.outcome, GateOutcome::Passed { cells: 2 });
        assert_eq!(report.warnings.len(), 2, "{:?}", report.warnings);
        assert!(report.warnings.iter().any(|w| w.contains("p95 latency")));
        assert!(report.warnings.iter().any(|w| w.contains("p95 queue wait")));
        // Growth inside the tolerance (or under the 1 ms floor) stays
        // quiet.
        let close = doc_with_latency("release", 100.0, 50.0, 10.9, 2.9);
        assert!(gate(&base, &close, 0.25, LT, false).unwrap().warnings.is_empty());
        // A permissive flag silences the doubled p95 too.
        let report = gate(&base, &slow, 0.25, 3.0, false).unwrap();
        assert!(report.warnings.is_empty(), "{:?}", report.warnings);
        // A ~0 baseline (idle queue) reports absolute growth, not a
        // nonsense percentage.
        let idle_base = doc_with_latency("release", 100.0, 50.0, 10.0, 0.0);
        let busy = doc_with_latency("release", 100.0, 50.0, 10.0, 3.0);
        let report = gate(&idle_base, &busy, 0.25, LT, false).unwrap();
        assert_eq!(report.warnings.len(), 1, "{:?}", report.warnings);
        assert!(report.warnings[0].contains("+3.00ms"), "{:?}", report.warnings);
        assert!(!report.warnings[0].contains('%'), "{:?}", report.warnings);
    }

    #[test]
    fn gate_warns_on_nonzero_panic_rate_for_healthy_workloads() {
        let base = doc("release", 100.0, 50.0);
        // A current run where `primes` panicked (and retried) during the
        // bench, `chunked` stayed clean, and a deliberately faulty chaos
        // registration panicked by design.
        let cur = "{\"bench\": \"pipeline_throughput\", \"profile\": \"release\", \
             \"scale\": 1.0, \"clients\": 2, \"jobs_per_client\": 2, \"mode\": \"par(2)\", \
             \"points\": [\
             {\"workload\": \"primes\", \"shards\": 1, \"jobs_per_sec\": 100.0, \
               \"panic_rate\": 0.1250, \"retry_rate\": 0.1250}, \
             {\"workload\": \"chunked\", \"shards\": 2, \"jobs_per_sec\": 50.0, \
               \"panic_rate\": 0.0, \"retry_rate\": 0.0}, \
             {\"workload\": \"faulty\", \"shards\": 1, \"jobs_per_sec\": 10.0, \
               \"panic_rate\": 1.0, \"retry_rate\": 1.0}]}";
        let report = gate(&base, cur, 0.25, LT, false).unwrap();
        // Warn, never fail: fault injection must not poison the perf
        // gate, and the throughput cells all held.
        assert_eq!(report.outcome, GateOutcome::Passed { cells: 2 });
        assert_eq!(report.warnings.len(), 1, "{:?}", report.warnings);
        assert!(report.warnings[0].contains("primes"), "{:?}", report.warnings);
        assert!(report.warnings[0].contains("panic_rate 0.1250"), "{:?}", report.warnings);
        assert!(report.warnings[0].contains("non-faulty"), "{:?}", report.warnings);
        // Clean runs and pre-lifecycle documents (no fault fields at
        // all) stay quiet.
        let clean = doc("release", 100.0, 50.0);
        assert!(gate(&base, &clean, 0.25, LT, false).unwrap().warnings.is_empty());
    }

    #[test]
    fn gate_tolerates_baselines_without_latency_fields() {
        // Pre-ingress baseline: no p95/queue-wait fields anywhere.
        let base = "{\"bench\": \"pipeline_throughput\", \"profile\": \"release\", \
             \"scale\": 1.0, \"clients\": 2, \"jobs_per_client\": 2, \"mode\": \"par(2)\", \
             \"points\": [\
             {\"workload\": \"primes\", \"shards\": 1, \"jobs_per_sec\": 100.0}]}";
        let cur = doc_with_latency("release", 95.0, 50.0, 400.0, 300.0);
        let report = gate(base, &cur, 0.25, LT, false).unwrap();
        assert_eq!(report.outcome, GateOutcome::Passed { cells: 1 });
        assert!(report.warnings.is_empty(), "no baseline latency → no warnings");
    }

    #[test]
    fn trajectory_workloads_track_the_registry() {
        let names = trajectory_workloads();
        // Every registered workload is swept — including plugins that
        // shipped after the enum world ended.
        for w in ["primes", "chunked_big", "fib", "msort"] {
            assert!(names.iter().any(|n| n == w), "missing {w} in {names:?}");
        }
        assert_eq!(names.len(), crate::workload::WorkloadRegistry::builtin().len());
    }

    #[test]
    fn gate_tolerates_extra_registered_workloads() {
        // A current run carrying cells for *newly registered* workloads
        // the committed baseline has never seen must pass, not fail or
        // skip: registering a plugin may not poison the perf gate. (The
        // inverse — a baseline workload vanishing — still fails; see
        // gate_fails_when_a_workload_vanishes.)
        let base = doc("release", 100.0, 50.0);
        let cur = "{\"bench\": \"pipeline_throughput\", \"profile\": \"release\", \
             \"scale\": 1.0, \"clients\": 2, \"jobs_per_client\": 2, \"mode\": \"par(2)\", \
             \"points\": [\
             {\"workload\": \"primes\", \"shards\": 1, \"jobs_per_sec\": 100.0}, \
             {\"workload\": \"chunked\", \"shards\": 2, \"jobs_per_sec\": 50.0}, \
             {\"workload\": \"fib\", \"shards\": 1, \"jobs_per_sec\": 70.0}, \
             {\"workload\": \"msort\", \"shards\": 2, \"jobs_per_sec\": 30.0}]}";
        let report = gate(&base, cur, 0.25, LT, false).unwrap();
        // Only the overlapping cells are compared; the new workloads
        // ride along un-gated until they appear in a committed baseline.
        assert_eq!(report.outcome, GateOutcome::Passed { cells: 2 });
        assert!(report.warnings.is_empty(), "{:?}", report.warnings);
    }

    #[test]
    fn gate_fails_when_a_workload_vanishes() {
        let base = doc("release", 100.0, 50.0);
        // Current run covers chunked but lost primes entirely.
        let cur = "{\"bench\": \"pipeline_throughput\", \"profile\": \"release\", \
             \"scale\": 1.0, \"clients\": 2, \"jobs_per_client\": 2, \"mode\": \"par(2)\", \
             \"points\": [\
             {\"workload\": \"chunked\", \"shards\": 2, \"jobs_per_sec\": 55.0}]}"
            .to_string();
        match gate(&base, &cur, 0.25, LT, false).unwrap().outcome {
            GateOutcome::Failed { regressions } => {
                assert!(
                    regressions.iter().any(|r| r.contains("primes vanished")),
                    "{regressions:?}"
                );
            }
            other => panic!("expected failure, got {other:?}"),
        }
    }

    #[test]
    fn gate_skips_incomparable_runs() {
        let base = doc("release", 100.0, 50.0);
        let debug = doc("debug", 10.0, 5.0);
        assert!(matches!(
            gate(&base, &debug, 0.25, LT, false).unwrap().outcome,
            GateOutcome::Skipped { .. }
        ));
        // Garbage input is an error, not a skip.
        assert!(gate("{]", &base, 0.25, LT, false).is_err());
        assert!(gate("{\"bench\": \"executor_overhead\"}", &base, 0.25, LT, false).is_err());
    }

    #[test]
    fn gate_refuses_malformed_current_runs() {
        // A broken bench writer must fail the gate, never disarm it: a
        // current run missing its profile or points is an error even
        // though the same gaps in an old *baseline* merely skip.
        let base = doc("release", 100.0, 50.0);
        let no_profile = "{\"bench\": \"pipeline_throughput\", \"points\": [\
             {\"workload\": \"primes\", \"shards\": 1, \"jobs_per_sec\": 100.0}]}";
        let err = gate(&base, no_profile, 0.25, LT, false).unwrap_err();
        assert!(err.contains("profile"), "{err}");
        let no_points = "{\"bench\": \"pipeline_throughput\", \"profile\": \"release\"}";
        assert!(gate(&base, no_points, 0.25, LT, false).is_err());
        let empty_points = "{\"bench\": \"pipeline_throughput\", \"profile\": \"release\", \
             \"points\": []}";
        assert!(gate(&base, empty_points, 0.25, LT, false).is_err());
        // The same documents on the *baseline* side stay tolerated
        // (Skipped on the profile mismatch path), because old baselines
        // predate newer fields.
        let cur = doc("release", 100.0, 50.0);
        assert!(matches!(
            gate(no_points, &cur, 0.25, LT, false).unwrap().outcome,
            GateOutcome::Skipped { .. }
        ));
    }

    /// Prefix a trajectory doc with a synthetic-floor `note`, the way
    /// the committed day-one baseline is labeled.
    fn with_synthetic_note(doc: &str) -> String {
        doc.replacen(
            "{\"bench\"",
            "{\"note\": \"synthetic conservative floor baseline\", \"bench\"",
            1,
        )
    }

    #[test]
    fn strict_latency_gate_passes_fails_and_disarms() {
        let base = doc_with_latency("release", 100.0, 50.0, 10.0, 2.0);
        let slow = doc_with_latency("release", 100.0, 50.0, 20.0, 6.0);
        let fine = doc_with_latency("release", 100.0, 50.0, 10.5, 2.1);

        // Pass: strict armed, latency held — no warnings, no failures.
        let report = gate(&base, &fine, 0.25, LT, true).unwrap();
        assert_eq!(report.latency_gate, LatencyGate::Strict);
        assert_eq!(report.outcome, GateOutcome::Passed { cells: 2 });
        assert!(report.warnings.is_empty(), "{:?}", report.warnings);

        // Fail: the same latency growth that only warns by default now
        // fails the gate.
        let warn_only = gate(&base, &slow, 0.25, LT, false).unwrap();
        assert_eq!(warn_only.latency_gate, LatencyGate::WarnOnly);
        assert_eq!(warn_only.outcome, GateOutcome::Passed { cells: 2 });
        assert_eq!(warn_only.warnings.len(), 2);
        let strict = gate(&base, &slow, 0.25, LT, true).unwrap();
        assert_eq!(strict.latency_gate, LatencyGate::Strict);
        match strict.outcome {
            GateOutcome::Failed { regressions } => {
                assert_eq!(regressions.len(), 2, "{regressions:?}");
                assert!(regressions.iter().all(|r| r.starts_with("latency (strict):")));
            }
            other => panic!("expected strict latency failure, got {other:?}"),
        }
        assert!(strict.warnings.is_empty(), "strict routes findings to failures");

        // Disarmed: a synthetic-floor baseline cannot arm the strict
        // gate — its ceilings are fiction. Findings fall back to
        // warnings and the report says why.
        let synthetic = with_synthetic_note(&base);
        let report = gate(&synthetic, &slow, 0.25, LT, true).unwrap();
        assert_eq!(report.latency_gate, LatencyGate::StrictDisarmedSyntheticBaseline);
        assert_eq!(report.outcome, GateOutcome::Passed { cells: 2 });
        assert_eq!(report.warnings.len(), 2, "{:?}", report.warnings);
    }
}

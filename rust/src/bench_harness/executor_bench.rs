//! Executor overhead benchmark with a machine-readable trajectory.
//!
//! Runs the same two workloads on every scheduler/deque variant — the
//! Mutex-queue baseline ([`Scheduler::GlobalQueue`]) and the
//! work-stealing scheduler under both per-worker deque implementations
//! ([`DequeKind::Locked`] and [`DequeKind::ChaseLev`]) — on the same
//! machine in the same process, through the harness's robust sampler
//! ([`measure`]: warmup runs absorb allocator/thread settling, the
//! reported statistic is the median over samples):
//!
//! 1. **spawn wave** — a recursive binary fan-out of trivial tasks (each
//!    task spawns two more until a budget runs out). This is the shape
//!    of a Future-stream spine: spawns originate *inside* workers, which
//!    is exactly where per-worker deques beat a global lock.
//! 2. **fut spawn+force** — one worker spawns N trivial `Fut`s; the
//!    driver forces every one. Covers the acceptance gate "spawn+force
//!    of 100k trivial tasks".
//!
//! A sampler thread records instantaneous queue depth into a
//! [`Histogram`] throughout. Results serialize to `BENCH_executor.json`
//! (rebar-style: every perf PR appends a data point to the repo's
//! trajectory — see SNIPPETS.md). Every run carries a
//! `(scheduler, deque)` label — `deque=chase_lev` vs `deque=locked` is
//! the A/B for the lock-free ring deque, recorded from the *same*
//! harness invocation so the comparison is machine- and load-fair.
//! [`gate`] (reachable via `sfut check-bench`) compares two trajectory
//! files, matching runs **only by identical label** — a chase_lev point
//! is never judged against a locked baseline.
//!
//! The JSON records the build profile; only `cargo bench` (release)
//! numbers are comparable across PRs, so the `cargo test` smoke run
//! never overwrites an existing file.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::pipeline_bench::{GateOutcome, GateReport, LatencyGate};
use super::{measure, BenchOptions, BenchPoint, BenchReport, Provenance, BENCH_SCHEMA_VERSION};
use crate::exec::{DequeKind, Executor, ExecutorConfig, Scheduler};
use crate::metrics::Histogram;
use crate::susp::{Fut, Susp};

/// Queue-depth distribution over one scheduler run (sampled, in jobs).
#[derive(Debug, Clone)]
pub struct QueueDepthStats {
    pub samples: u64,
    pub mean: f64,
    pub p50: u64,
    pub p99: u64,
    pub max: u64,
}

/// One labeled variant's measurements. Timings are medians over
/// `opts.samples` runs after `opts.warmup` warmup runs.
#[derive(Debug, Clone)]
pub struct SchedulerRun {
    /// "global-queue" | "work-stealing".
    pub scheduler: &'static str,
    /// Deque implementation label: "locked" | "chase_lev", or "none"
    /// for the global queue (it has no per-worker deques).
    pub deque: &'static str,
    pub spawn_wave_secs: f64,
    pub spawn_wave_tasks_per_sec: f64,
    pub fut_force_secs: f64,
    pub fut_force_tasks_per_sec: f64,
    /// Cumulative over warmup + samples.
    pub tasks_executed: u64,
    pub tasks_stolen: u64,
    /// Steal-half operations that moved more than one job.
    pub steals_batched: u64,
    /// Extra jobs batch steals landed in thieves' deques.
    pub jobs_migrated: u64,
    pub queue_depth: QueueDepthStats,
    /// Baseline (global-queue) median / this run's median; >1 means
    /// this variant wins. 1.0 for the baseline itself.
    pub speedup_spawn_wave: f64,
    pub speedup_fut_force: f64,
}

impl SchedulerRun {
    /// The `scheduler=… deque=…` label the gate matches on.
    pub fn label(&self) -> String {
        format!("scheduler={} deque={}", self.scheduler, self.deque)
    }
}

/// The full labeled A/B/C result.
#[derive(Debug, Clone)]
pub struct ExecutorBench {
    pub tasks: u64,
    pub parallelism: usize,
    pub warmup: usize,
    pub samples: usize,
    /// "release" or "debug" — only release points belong on the
    /// cross-PR trajectory.
    pub profile: &'static str,
    /// Where this run came from (commit, dirty flag, toolchain, …).
    pub provenance: Provenance,
    /// Global-queue baseline first, then the work-stealing deque
    /// variants, all measured in this same process.
    pub runs: Vec<SchedulerRun>,
}

impl ExecutorBench {
    /// The global-queue baseline (always the first run).
    pub fn baseline(&self) -> &SchedulerRun {
        &self.runs[0]
    }

    /// Find a run by its `(scheduler, deque)` label.
    pub fn labeled(&self, scheduler: &str, deque: &str) -> Option<&SchedulerRun> {
        self.runs.iter().find(|r| r.scheduler == scheduler && r.deque == deque)
    }
}

fn build_profile() -> &'static str {
    if cfg!(debug_assertions) {
        "debug"
    } else {
        "release"
    }
}

/// Each task spawns two successors until the shared budget is spent —
/// worker-originated spawns, the work-stealing scheduler's home turf.
fn spawn_tree(ex: &Executor, budget: &Arc<AtomicI64>) {
    for _ in 0..2 {
        if budget.fetch_sub(1, Ordering::Relaxed) > 0 {
            let ex2 = ex.clone();
            let b2 = Arc::clone(budget);
            ex.spawn(move || spawn_tree(&ex2, &b2));
        } else {
            break;
        }
    }
}

fn run_one(
    scheduler: Scheduler,
    deque: DequeKind,
    tasks: u64,
    parallelism: usize,
    opts: &BenchOptions,
) -> SchedulerRun {
    let mut cfg = ExecutorConfig::with_parallelism(parallelism);
    cfg.scheduler = scheduler;
    cfg.deque = deque;
    let ex = Executor::with_config(cfg);

    // Depth sampler: poll until told to stop.
    let hist = Arc::new(Histogram::new());
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let ex = ex.clone();
        let hist = Arc::clone(&hist);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let depth = ex.stats().queue_depth as u64;
                // The histogram buckets nanosecond durations; reuse it
                // for dimensionless depths (1 "nano" = 1 queued job).
                hist.record(Duration::from_nanos(depth));
                std::thread::sleep(Duration::from_micros(200));
            }
        })
    };

    // 1. Spawn wave (fresh budget per sample; warmup absorbs thread and
    //    allocator settling so the first-measured scheduler is not
    //    penalized for one-time process costs).
    let wave = measure("spawn_wave", opts, || {
        let budget = Arc::new(AtomicI64::new(tasks as i64));
        let ex2 = ex.clone();
        let b2 = Arc::clone(&budget);
        ex.spawn(move || spawn_tree(&ex2, &b2));
        ex.wait_idle();
    });

    // 2. Fut spawn+force: one worker produces, the driver consumes.
    let fut = measure("fut_force", opts, || {
        let exv = ex.clone();
        let n = tasks;
        let produced = Fut::spawn(&ex, move || {
            (0..n).map(|i| Fut::spawn(&exv, move || i)).collect::<Vec<_>>()
        });
        let mut checksum = 0u64;
        for f in produced.force() {
            checksum = checksum.wrapping_add(*f.force());
        }
        std::hint::black_box(checksum);
    });

    stop.store(true, Ordering::Relaxed);
    let _ = sampler.join();

    let stats = ex.stats();
    let wave_secs = wave.median_secs();
    let fut_secs = fut.median_secs();
    SchedulerRun {
        scheduler: match scheduler {
            Scheduler::GlobalQueue => "global-queue",
            Scheduler::WorkStealing => "work-stealing",
        },
        deque: match scheduler {
            Scheduler::GlobalQueue => "none",
            Scheduler::WorkStealing => deque.label(),
        },
        spawn_wave_secs: wave_secs,
        spawn_wave_tasks_per_sec: tasks as f64 / wave_secs.max(1e-9),
        fut_force_secs: fut_secs,
        fut_force_tasks_per_sec: tasks as f64 / fut_secs.max(1e-9),
        tasks_executed: stats.tasks_executed,
        tasks_stolen: stats.tasks_stolen,
        steals_batched: stats.steals_batched,
        jobs_migrated: stats.jobs_migrated,
        queue_depth: QueueDepthStats {
            samples: hist.count(),
            mean: hist.mean().as_nanos() as f64,
            p50: hist.quantile(0.5).as_nanos() as u64,
            p99: hist.quantile(0.99).as_nanos() as u64,
            max: hist.max().as_nanos() as u64,
        },
        // Filled in by `run` once the baseline is known.
        speedup_spawn_wave: 1.0,
        speedup_fut_force: 1.0,
    }
}

/// Run the full labeled comparison — the global-queue baseline, then
/// work-stealing under the locked deque, then under the Chase–Lev ring
/// — each with its own warmup so ordering does not bias the medians.
/// All datapoints come from this one invocation, so their labels are
/// comparable (same machine, same process, same background load).
pub fn run(tasks: u64, parallelism: usize, opts: &BenchOptions) -> ExecutorBench {
    let variants = [
        (Scheduler::GlobalQueue, DequeKind::ChaseLev), // deque unused
        (Scheduler::WorkStealing, DequeKind::Locked),
        (Scheduler::WorkStealing, DequeKind::ChaseLev),
    ];
    let mut runs: Vec<SchedulerRun> = variants
        .iter()
        .map(|&(s, d)| run_one(s, d, tasks, parallelism, opts))
        .collect();
    let (base_wave, base_fut) = (runs[0].spawn_wave_secs, runs[0].fut_force_secs);
    for r in &mut runs {
        r.speedup_spawn_wave = base_wave / r.spawn_wave_secs.max(1e-9);
        r.speedup_fut_force = base_fut / r.fut_force_secs.max(1e-9);
    }
    ExecutorBench {
        tasks,
        parallelism,
        warmup: opts.warmup,
        samples: opts.samples,
        profile: build_profile(),
        provenance: Provenance::capture(0, 1.0),
        runs,
    }
}

/// Render one labeled run in the unified [`BenchPoint`] shape (schema
/// v1): `(scheduler, deque)` under `labels`, everything measured under
/// `metrics` (the queue-depth histogram flattens to dotted keys). The
/// plan runner ([`super::plan::run_plan`]) reuses this to feed grid
/// cells into the results registry.
pub fn unified_point(r: &SchedulerRun) -> BenchPoint {
    let mut point = BenchPoint::default();
    point.labels.insert("scheduler".to_string(), r.scheduler.to_string());
    point.labels.insert("deque".to_string(), r.deque.to_string());
    for (key, value) in [
        ("spawn_wave_secs", r.spawn_wave_secs),
        ("spawn_wave_tasks_per_sec", r.spawn_wave_tasks_per_sec),
        ("fut_force_secs", r.fut_force_secs),
        ("fut_force_tasks_per_sec", r.fut_force_tasks_per_sec),
        ("tasks_executed", r.tasks_executed as f64),
        ("tasks_stolen", r.tasks_stolen as f64),
        ("steals_batched", r.steals_batched as f64),
        ("jobs_migrated", r.jobs_migrated as f64),
        ("speedup_spawn_wave", r.speedup_spawn_wave),
        ("speedup_fut_force", r.speedup_fut_force),
        ("queue_depth.samples", r.queue_depth.samples as f64),
        ("queue_depth.mean", r.queue_depth.mean),
        ("queue_depth.p50", r.queue_depth.p50 as f64),
        ("queue_depth.p99", r.queue_depth.p99 as f64),
        ("queue_depth.max", r.queue_depth.max as f64),
    ] {
        point.metrics.insert(key.to_string(), value);
    }
    point
}

/// Serialize to the versioned `BENCH_executor.json` schema (hand-rolled;
/// no serde offline). Readable back via [`BenchReport::parse`] /
/// [`gate`], which also still accept the pre-v1 `runs` shape.
pub fn to_json(b: &ExecutorBench) -> String {
    let points = b
        .runs
        .iter()
        .map(|r| format!("    {}", unified_point(r).to_json()))
        .collect::<Vec<_>>()
        .join(",\n");
    format!(
        "{{\n\
         \x20 \"schema_version\": {},\n\
         \x20 \"bench\": \"executor_overhead\",\n\
         \x20 \"profile\": \"{}\",\n\
         \x20 \"tasks\": {},\n\
         \x20 \"parallelism\": {},\n\
         \x20 \"warmup\": {},\n\
         \x20 \"samples\": {},\n\
         \x20 \"provenance\": {},\n\
         \x20 \"points\": [\n{}\n  ]\n\
         }}\n",
        BENCH_SCHEMA_VERSION,
        b.profile,
        b.tasks,
        b.parallelism,
        b.warmup,
        b.samples,
        b.provenance.to_json(),
        points,
    )
}

pub fn write_json(b: &ExecutorBench, path: &Path) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(to_json(b).as_bytes())
}

/// Default artifact location: the repository root.
pub fn default_output_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_executor.json")
}

/// Seed the trajectory file only when none exists yet, so a debug-build
/// `cargo test` smoke run never clobbers a full-scale release data
/// point (the `profile` field in the JSON disambiguates what's there).
pub fn write_json_if_absent(b: &ExecutorBench) -> std::io::Result<bool> {
    let path = default_output_path();
    if path.exists() {
        return Ok(false);
    }
    write_json(b, &path).map(|()| true)
}

/// Compare two `BENCH_executor.json` documents (the `sfut check-bench`
/// path for executor trajectories). Runs are matched **only on
/// identical `(scheduler, deque)` labels** — a chase_lev point is never
/// compared against a locked baseline — and a matched run fails when
/// either workload's tasks/sec drops below `(1 - threshold) ×
/// baseline`. A label present in the baseline but missing from the
/// current run is a failure (silent 100% regression), and a malformed
/// current run is an error, not a skip.
pub fn gate(baseline: &str, current: &str, threshold: f64) -> Result<GateReport, String> {
    let b = BenchReport::parse(baseline).map_err(|e| format!("baseline: {e}"))?;
    let c = BenchReport::parse(current).map_err(|e| format!("current: {e}"))?;
    for doc in [&b, &c] {
        if doc.bench != "executor_overhead" {
            return Err("not an executor_overhead trajectory file".to_string());
        }
    }
    if c.param("profile").is_none() {
        return Err("current run is missing \"profile\" — bench writer broken".to_string());
    }
    if c.points.is_empty() {
        return Err("current run has no runs — bench writer broken".to_string());
    }
    for key in ["profile", "tasks", "parallelism", "warmup", "samples"] {
        let (bv, cv) = (b.param(key), c.param(key));
        if bv != cv {
            return Ok(GateReport {
                outcome: GateOutcome::Skipped {
                    reason: format!(
                        "{key} differs (baseline {bv:?}, current {cv:?}); runs are not \
                         comparable — refresh the baseline"
                    ),
                },
                warnings: Vec::new(),
                latency_gate: LatencyGate::WarnOnly,
            });
        }
    }

    struct RunStats {
        scheduler: String,
        deque: String,
        spawn_wave: f64,
        fut_force: f64,
    }
    let read_runs = |doc: &BenchReport| -> Vec<RunStats> {
        doc.points
            .iter()
            .filter_map(|r| {
                Some(RunStats {
                    scheduler: r.label("scheduler")?.to_string(),
                    deque: r.label("deque")?.to_string(),
                    spawn_wave: r.metric("spawn_wave_tasks_per_sec")?,
                    fut_force: r.metric("fut_force_tasks_per_sec")?,
                })
            })
            .collect()
    };
    let base_runs = read_runs(&b);
    let cur_runs = read_runs(&c);
    let mut compared = 0usize;
    let mut regressions = Vec::new();
    for cur in &cur_runs {
        // Like-labeled points only.
        let Some(base) = base_runs
            .iter()
            .find(|b| b.scheduler == cur.scheduler && b.deque == cur.deque)
        else {
            continue;
        };
        compared += 1;
        for (what, b_tps, c_tps) in [
            ("spawn_wave", base.spawn_wave, cur.spawn_wave),
            ("fut_force", base.fut_force, cur.fut_force),
        ] {
            if c_tps < (1.0 - threshold) * b_tps {
                let drop_pct = (1.0 - c_tps / b_tps.max(1e-9)) * 100.0;
                regressions.push(format!(
                    "scheduler={} deque={}: {what} {:.1} tasks/s vs baseline {:.1} \
                     (-{drop_pct:.0}%)",
                    cur.scheduler, cur.deque, c_tps, b_tps
                ));
            }
        }
    }
    for base in &base_runs {
        if !cur_runs.iter().any(|c| c.scheduler == base.scheduler && c.deque == base.deque) {
            regressions.push(format!(
                "scheduler={} deque={} vanished: baseline has this labeled point, current \
                 run does not",
                base.scheduler, base.deque
            ));
        }
    }
    if compared == 0 && regressions.is_empty() {
        return Ok(GateReport {
            outcome: GateOutcome::Skipped {
                reason: "no like-labeled (scheduler, deque) runs".to_string(),
            },
            warnings: Vec::new(),
            latency_gate: LatencyGate::WarnOnly,
        });
    }
    let outcome = if regressions.is_empty() {
        GateOutcome::Passed { cells: compared }
    } else {
        GateOutcome::Failed { regressions }
    };
    Ok(GateReport { outcome, warnings: Vec::new(), latency_gate: LatencyGate::WarnOnly })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::tiny_json::{self, Json};

    #[test]
    fn ab_comparison_runs_and_emits_labeled_json() {
        // Small-scale smoke: correctness of the A/B plumbing, not a perf
        // claim. Seeds BENCH_executor.json only if no trajectory file
        // exists; the full-size release run lives in
        // `cargo bench --bench ablation_overhead`.
        let opts = BenchOptions { warmup: 1, samples: 2, verbose: false };
        let b = run(10_000, 2, &opts);
        assert_eq!(b.runs.len(), 3);
        assert_eq!(b.baseline().scheduler, "global-queue");
        assert_eq!(b.baseline().deque, "none");
        assert_eq!(b.baseline().tasks_stolen, 0, "global queue has nothing to steal");
        assert_eq!(b.baseline().speedup_spawn_wave, 1.0);
        for (scheduler, deque) in
            [("global-queue", "none"), ("work-stealing", "locked"), ("work-stealing", "chase_lev")]
        {
            let r = b.labeled(scheduler, deque).expect("labeled run present");
            assert!(r.tasks_executed >= 10_000, "{}", r.label());
            assert!(r.spawn_wave_tasks_per_sec > 0.0);
            assert!(r.fut_force_tasks_per_sec > 0.0);
            assert!(r.tasks_stolen >= r.jobs_migrated, "{}", r.label());
        }
        let json = to_json(&b);
        assert!(json.contains("\"bench\": \"executor_overhead\""));
        assert!(json.contains("\"deque\": \"chase_lev\""));
        assert!(json.contains("\"deque\": \"locked\""));
        assert!(json.contains("\"steals_batched\""));
        assert!(json.contains("\"profile\""));
        let parsed = tiny_json::parse(&json).expect("self-readable JSON");
        assert_eq!(
            parsed.get("points").and_then(Json::as_array).map(<[Json]>::len),
            Some(3)
        );
        // A run gates cleanly against itself at any threshold.
        let report = gate(&json, &json, 0.25).unwrap();
        assert_eq!(report.outcome, GateOutcome::Passed { cells: 3 });
        // Serialization to disk, via a scratch path (never the trajectory).
        let tmp = std::env::temp_dir().join("sfut_bench_executor_smoke.json");
        write_json(&b, &tmp).expect("write smoke json");
        assert!(tmp.exists());
        let _ = std::fs::remove_file(&tmp);
        // Seed the real file only when absent.
        let _ = write_json_if_absent(&b);
        assert!(default_output_path().exists());
    }

    fn doc(profile: &str, chase_lev_tps: f64, locked_tps: f64) -> String {
        format!(
            "{{\"bench\": \"executor_overhead\", \"profile\": \"{profile}\", \
             \"tasks\": 1000, \"parallelism\": 2, \"warmup\": 1, \"samples\": 2, \
             \"runs\": [\
             {{\"scheduler\": \"work-stealing\", \"deque\": \"chase_lev\", \
               \"spawn_wave_tasks_per_sec\": {chase_lev_tps}, \
               \"fut_force_tasks_per_sec\": {chase_lev_tps}}}, \
             {{\"scheduler\": \"work-stealing\", \"deque\": \"locked\", \
               \"spawn_wave_tasks_per_sec\": {locked_tps}, \
               \"fut_force_tasks_per_sec\": {locked_tps}}}]}}"
        )
    }

    #[test]
    fn gate_compares_only_like_labeled_points() {
        let base = doc("release", 1000.0, 500.0);
        // chase_lev is slower than the *locked* baseline number but fine
        // vs its own label: must pass — labels never cross-compare.
        let ok = doc("release", 900.0, 500.0);
        assert_eq!(gate(&base, &ok, 0.25).unwrap().outcome, GateOutcome::Passed { cells: 2 });
        // A 40% drop on the chase_lev label fails, and the message names
        // the label.
        let bad = doc("release", 600.0, 500.0);
        match gate(&base, &bad, 0.25).unwrap().outcome {
            GateOutcome::Failed { regressions } => {
                assert!(regressions.iter().all(|r| r.contains("deque=chase_lev")));
            }
            other => panic!("expected failure, got {other:?}"),
        }
        // A vanished label is a failure, not a silent pass.
        let only_locked = "{\"bench\": \"executor_overhead\", \"profile\": \"release\", \
             \"tasks\": 1000, \"parallelism\": 2, \"warmup\": 1, \"samples\": 2, \
             \"runs\": [{\"scheduler\": \"work-stealing\", \"deque\": \"locked\", \
             \"spawn_wave_tasks_per_sec\": 500.0, \"fut_force_tasks_per_sec\": 500.0}]}";
        match gate(&base, only_locked, 0.25).unwrap().outcome {
            GateOutcome::Failed { regressions } => {
                assert!(
                    regressions.iter().any(|r| r.contains("deque=chase_lev vanished")),
                    "{regressions:?}"
                );
            }
            other => panic!("expected failure, got {other:?}"),
        }
    }

    #[test]
    fn gate_skips_incomparable_and_rejects_malformed() {
        let base = doc("release", 1000.0, 500.0);
        let debug = doc("debug", 100.0, 50.0);
        assert!(matches!(
            gate(&base, &debug, 0.25).unwrap().outcome,
            GateOutcome::Skipped { .. }
        ));
        // Garbage or empty current runs are errors — a broken bench
        // writer must fail the gate, not disarm it.
        assert!(gate(&base, "{]", 0.25).is_err());
        assert!(gate(&base, "{\"bench\": \"executor_overhead\"}", 0.25).is_err());
        let no_runs = "{\"bench\": \"executor_overhead\", \"profile\": \"release\", \
             \"runs\": []}";
        assert!(gate(&base, no_runs, 0.25).is_err());
        // Pipeline files are rejected by the executor gate.
        assert!(gate("{\"bench\": \"pipeline_throughput\"}", &base, 0.25).is_err());
    }

    #[test]
    fn spawn_tree_spends_budget() {
        let ex = Executor::new(2);
        let budget = Arc::new(AtomicI64::new(500));
        let ex2 = ex.clone();
        let b2 = Arc::clone(&budget);
        ex.spawn(move || spawn_tree(&ex2, &b2));
        ex.wait_idle();
        assert!(budget.load(Ordering::Relaxed) <= 0);
        assert!(ex.stats().tasks_executed >= 500);
    }
}

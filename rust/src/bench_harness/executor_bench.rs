//! Executor overhead benchmark with a machine-readable trajectory.
//!
//! Runs the same two workloads on the Mutex-queue baseline
//! ([`Scheduler::GlobalQueue`]) and the work-stealing scheduler
//! ([`Scheduler::WorkStealing`]), on the same machine in the same
//! process, through the harness's robust sampler ([`measure`]: warmup
//! runs absorb allocator/thread settling, the reported statistic is the
//! median over samples):
//!
//! 1. **spawn wave** — a recursive binary fan-out of trivial tasks (each
//!    task spawns two more until a budget runs out). This is the shape
//!    of a Future-stream spine: spawns originate *inside* workers, which
//!    is exactly where per-worker deques beat a global lock.
//! 2. **fut spawn+force** — one worker spawns N trivial `Fut`s; the
//!    driver forces every one. Covers the acceptance gate "spawn+force
//!    of 100k trivial tasks".
//!
//! A sampler thread records instantaneous queue depth into a
//! [`Histogram`] throughout. Results serialize to `BENCH_executor.json`
//! (rebar-style: every perf PR appends a data point to the repo's
//! trajectory — see SNIPPETS.md). The JSON records the build profile;
//! only `cargo bench` (release) numbers are comparable across PRs, so
//! the `cargo test` smoke run never overwrites an existing file.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::{measure, BenchOptions};
use crate::exec::{Executor, ExecutorConfig, Scheduler};
use crate::metrics::Histogram;
use crate::susp::{Fut, Susp};

/// Queue-depth distribution over one scheduler run (sampled, in jobs).
#[derive(Debug, Clone)]
pub struct QueueDepthStats {
    pub samples: u64,
    pub mean: f64,
    pub p50: u64,
    pub p99: u64,
    pub max: u64,
}

/// One scheduler's measurements. Timings are medians over
/// `opts.samples` runs after `opts.warmup` warmup runs.
#[derive(Debug, Clone)]
pub struct SchedulerRun {
    pub scheduler: &'static str,
    pub spawn_wave_secs: f64,
    pub spawn_wave_tasks_per_sec: f64,
    pub fut_force_secs: f64,
    pub fut_force_tasks_per_sec: f64,
    /// Cumulative over warmup + samples.
    pub tasks_executed: u64,
    pub tasks_stolen: u64,
    pub queue_depth: QueueDepthStats,
}

/// The full A/B result.
#[derive(Debug, Clone)]
pub struct ExecutorBench {
    pub tasks: u64,
    pub parallelism: usize,
    pub warmup: usize,
    pub samples: usize,
    /// "release" or "debug" — only release points belong on the
    /// cross-PR trajectory.
    pub profile: &'static str,
    pub baseline: SchedulerRun,
    pub work_stealing: SchedulerRun,
    /// baseline median / work-stealing median (>1 means work-stealing wins).
    pub speedup_spawn_wave: f64,
    pub speedup_fut_force: f64,
}

fn build_profile() -> &'static str {
    if cfg!(debug_assertions) {
        "debug"
    } else {
        "release"
    }
}

/// Each task spawns two successors until the shared budget is spent —
/// worker-originated spawns, the work-stealing scheduler's home turf.
fn spawn_tree(ex: &Executor, budget: &Arc<AtomicI64>) {
    for _ in 0..2 {
        if budget.fetch_sub(1, Ordering::Relaxed) > 0 {
            let ex2 = ex.clone();
            let b2 = Arc::clone(budget);
            ex.spawn(move || spawn_tree(&ex2, &b2));
        } else {
            break;
        }
    }
}

fn run_one(
    scheduler: Scheduler,
    tasks: u64,
    parallelism: usize,
    opts: &BenchOptions,
) -> SchedulerRun {
    let mut cfg = ExecutorConfig::with_parallelism(parallelism);
    cfg.scheduler = scheduler;
    let ex = Executor::with_config(cfg);

    // Depth sampler: poll until told to stop.
    let hist = Arc::new(Histogram::new());
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let ex = ex.clone();
        let hist = Arc::clone(&hist);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let depth = ex.stats().queue_depth as u64;
                // The histogram buckets nanosecond durations; reuse it
                // for dimensionless depths (1 "nano" = 1 queued job).
                hist.record(Duration::from_nanos(depth));
                std::thread::sleep(Duration::from_micros(200));
            }
        })
    };

    // 1. Spawn wave (fresh budget per sample; warmup absorbs thread and
    //    allocator settling so the first-measured scheduler is not
    //    penalized for one-time process costs).
    let wave = measure("spawn_wave", opts, || {
        let budget = Arc::new(AtomicI64::new(tasks as i64));
        let ex2 = ex.clone();
        let b2 = Arc::clone(&budget);
        ex.spawn(move || spawn_tree(&ex2, &b2));
        ex.wait_idle();
    });

    // 2. Fut spawn+force: one worker produces, the driver consumes.
    let fut = measure("fut_force", opts, || {
        let exv = ex.clone();
        let n = tasks;
        let produced = Fut::spawn(&ex, move || {
            (0..n).map(|i| Fut::spawn(&exv, move || i)).collect::<Vec<_>>()
        });
        let mut checksum = 0u64;
        for f in produced.force() {
            checksum = checksum.wrapping_add(*f.force());
        }
        std::hint::black_box(checksum);
    });

    stop.store(true, Ordering::Relaxed);
    let _ = sampler.join();

    let stats = ex.stats();
    let wave_secs = wave.median_secs();
    let fut_secs = fut.median_secs();
    SchedulerRun {
        scheduler: match scheduler {
            Scheduler::GlobalQueue => "global-queue",
            Scheduler::WorkStealing => "work-stealing",
        },
        spawn_wave_secs: wave_secs,
        spawn_wave_tasks_per_sec: tasks as f64 / wave_secs.max(1e-9),
        fut_force_secs: fut_secs,
        fut_force_tasks_per_sec: tasks as f64 / fut_secs.max(1e-9),
        tasks_executed: stats.tasks_executed,
        tasks_stolen: stats.tasks_stolen,
        queue_depth: QueueDepthStats {
            samples: hist.count(),
            mean: hist.mean().as_nanos() as f64,
            p50: hist.quantile(0.5).as_nanos() as u64,
            p99: hist.quantile(0.99).as_nanos() as u64,
            max: hist.max().as_nanos() as u64,
        },
    }
}

/// Run the full A/B comparison: baseline first, then work-stealing,
/// each with its own warmup so ordering does not bias the medians.
pub fn run(tasks: u64, parallelism: usize, opts: &BenchOptions) -> ExecutorBench {
    let baseline = run_one(Scheduler::GlobalQueue, tasks, parallelism, opts);
    let work_stealing = run_one(Scheduler::WorkStealing, tasks, parallelism, opts);
    ExecutorBench {
        tasks,
        parallelism,
        warmup: opts.warmup,
        samples: opts.samples,
        profile: build_profile(),
        speedup_spawn_wave: baseline.spawn_wave_secs / work_stealing.spawn_wave_secs.max(1e-9),
        speedup_fut_force: baseline.fut_force_secs / work_stealing.fut_force_secs.max(1e-9),
        baseline,
        work_stealing,
    }
}

fn json_run(r: &SchedulerRun, indent: &str) -> String {
    format!(
        "{{\n\
         {indent}  \"scheduler\": \"{}\",\n\
         {indent}  \"spawn_wave_secs\": {:.6},\n\
         {indent}  \"spawn_wave_tasks_per_sec\": {:.1},\n\
         {indent}  \"fut_force_secs\": {:.6},\n\
         {indent}  \"fut_force_tasks_per_sec\": {:.1},\n\
         {indent}  \"tasks_executed\": {},\n\
         {indent}  \"tasks_stolen\": {},\n\
         {indent}  \"queue_depth\": {{\"samples\": {}, \"mean\": {:.1}, \
         \"p50\": {}, \"p99\": {}, \"max\": {}}}\n\
         {indent}}}",
        r.scheduler,
        r.spawn_wave_secs,
        r.spawn_wave_tasks_per_sec,
        r.fut_force_secs,
        r.fut_force_tasks_per_sec,
        r.tasks_executed,
        r.tasks_stolen,
        r.queue_depth.samples,
        r.queue_depth.mean,
        r.queue_depth.p50,
        r.queue_depth.p99,
        r.queue_depth.max,
    )
}

/// Serialize to the `BENCH_executor.json` schema (hand-rolled; no serde
/// offline).
pub fn to_json(b: &ExecutorBench) -> String {
    format!(
        "{{\n\
         \x20 \"bench\": \"executor_overhead\",\n\
         \x20 \"profile\": \"{}\",\n\
         \x20 \"tasks\": {},\n\
         \x20 \"parallelism\": {},\n\
         \x20 \"warmup\": {},\n\
         \x20 \"samples\": {},\n\
         \x20 \"baseline\": {},\n\
         \x20 \"work_stealing\": {},\n\
         \x20 \"speedup_spawn_wave\": {:.3},\n\
         \x20 \"speedup_fut_force\": {:.3}\n\
         }}\n",
        b.profile,
        b.tasks,
        b.parallelism,
        b.warmup,
        b.samples,
        json_run(&b.baseline, "  "),
        json_run(&b.work_stealing, "  "),
        b.speedup_spawn_wave,
        b.speedup_fut_force,
    )
}

pub fn write_json(b: &ExecutorBench, path: &Path) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(to_json(b).as_bytes())
}

/// Default artifact location: the repository root.
pub fn default_output_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_executor.json")
}

/// Seed the trajectory file only when none exists yet, so a debug-build
/// `cargo test` smoke run never clobbers a full-scale release data
/// point (the `profile` field in the JSON disambiguates what's there).
pub fn write_json_if_absent(b: &ExecutorBench) -> std::io::Result<bool> {
    let path = default_output_path();
    if path.exists() {
        return Ok(false);
    }
    write_json(b, &path).map(|()| true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ab_comparison_runs_and_emits_json() {
        // Small-scale smoke: correctness of the A/B plumbing, not a perf
        // claim. Seeds BENCH_executor.json only if no trajectory file
        // exists; the full-size release run lives in
        // `cargo bench --bench ablation_overhead`.
        let opts = BenchOptions { warmup: 1, samples: 2, verbose: false };
        let b = run(10_000, 2, &opts);
        assert!(b.baseline.tasks_executed >= 10_000);
        assert!(b.work_stealing.tasks_executed >= 10_000);
        assert!(b.baseline.spawn_wave_tasks_per_sec > 0.0);
        assert!(b.work_stealing.fut_force_tasks_per_sec > 0.0);
        assert_eq!(b.baseline.tasks_stolen, 0, "global queue has nothing to steal");
        let json = to_json(&b);
        assert!(json.contains("\"bench\": \"executor_overhead\""));
        assert!(json.contains("work-stealing"));
        assert!(json.contains("\"profile\""));
        // Serialization to disk, via a scratch path (never the trajectory).
        let tmp = std::env::temp_dir().join("sfut_bench_executor_smoke.json");
        write_json(&b, &tmp).expect("write smoke json");
        assert!(tmp.exists());
        let _ = std::fs::remove_file(&tmp);
        // Seed the real file only when absent.
        let _ = write_json_if_absent(&b);
        assert!(default_output_path().exists());
    }

    #[test]
    fn spawn_tree_spends_budget() {
        let ex = Executor::new(2);
        let budget = Arc::new(AtomicI64::new(500));
        let ex2 = ex.clone();
        let b2 = Arc::clone(&budget);
        ex.spawn(move || spawn_tree(&ex2, &b2));
        ex.wait_idle();
        assert!(budget.load(Ordering::Relaxed) <= 0);
        assert!(ex.stats().tasks_executed >= 500);
    }
}

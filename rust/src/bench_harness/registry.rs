//! The results registry: every plan cell ever run, one JSONL line each.
//!
//! `BENCH_registry.jsonl` at the repo root is append-only — `sfut bench
//! run <plan>` appends its cells, each stamped with the plan name,
//! backend, build profile, and full [`Provenance`] (commit, dirty flag,
//! seed, toolchain, scale, host cores). Because cells carry their
//! commit, `sfut bench report` can diff a plan's latest cells against
//! the previous commit's like-labeled cells without any baseline
//! ceremony: the registry *is* the trajectory.
//!
//! The reader is tolerant by design: unknown top-level keys are
//! ignored (future writers may stamp more), missing provenance degrades
//! to "unknown" fields, and blank lines are skipped — a registry is
//! long-lived and merges across branches, so strictness here would
//! turn history into a liability.

use std::path::{Path, PathBuf};

use super::plan::PlanReport;
use super::tiny_json::{self, Json};
use super::{BenchPoint, Provenance};

/// The committed registry location: the repository root.
pub fn default_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_registry.jsonl")
}

/// One registry line, parsed.
#[derive(Debug, Clone)]
pub struct RegistryRecord {
    pub plan: String,
    pub backend: String,
    pub profile: String,
    pub point: BenchPoint,
    pub provenance: Provenance,
}

fn record_line(report: &PlanReport, point: &BenchPoint) -> String {
    format!(
        "{{\"schema_version\": {}, \"plan\": {}, \"backend\": {}, \"profile\": {}, \
         \"point\": {}, \"provenance\": {}}}",
        super::BENCH_SCHEMA_VERSION,
        super::json_string(&report.name),
        super::json_string(report.backend.label()),
        super::json_string(report.profile),
        point.to_json(),
        report.provenance.to_json(),
    )
}

/// Append every point of a plan run to the registry (created on first
/// use). Returns the number of cells written.
pub fn append(path: &Path, report: &PlanReport) -> std::io::Result<usize> {
    use std::io::Write as _;
    let mut file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    for point in &report.points {
        writeln!(file, "{}", record_line(report, point))?;
    }
    Ok(report.points.len())
}

/// Read the whole registry. A missing file is an empty registry, not an
/// error; a malformed line is an error naming its line number.
pub fn read(path: &Path) -> Result<Vec<RegistryRecord>, String> {
    let text = match std::fs::read_to_string(path) {
        Ok(text) => text,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(format!("cannot read {}: {e}", path.display())),
    };
    let mut records = Vec::new();
    for (idx, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let doc = tiny_json::parse(line)
            .map_err(|e| format!("{} line {}: {e}", path.display(), idx + 1))?;
        let field = |key: &str| doc.get(key).and_then(Json::as_str).unwrap_or("").to_string();
        let point = doc
            .get("point")
            .and_then(|p| super::normalize_point("", p))
            .unwrap_or_default();
        let provenance = doc
            .get("provenance")
            .map(Provenance::from_json)
            .unwrap_or_else(|| Provenance::from_json(&Json::Null));
        records.push(RegistryRecord {
            plan: field("plan"),
            backend: field("backend"),
            profile: field("profile"),
            point,
            provenance,
        });
    }
    Ok(records)
}

/// The one metric a cell's report line leads with: jobs/sec where the
/// backend has it, the spawn-wave rate for executor cells, else the
/// first metric alphabetically.
pub fn primary_metric(point: &BenchPoint) -> (String, f64) {
    for key in ["jobs_per_sec", "spawn_wave_tasks_per_sec"] {
        if let Some(value) = point.metric(key) {
            return (key.to_string(), value);
        }
    }
    point
        .metrics
        .iter()
        .next()
        .map(|(k, v)| (k.clone(), *v))
        .unwrap_or_else(|| ("none".to_string(), 0.0))
}

/// Render the cross-commit report: per plan, the latest commit's cells
/// with a delta against the previous commit's like-labeled cell.
/// Dirty-tree cells are marked `*` — their numbers may not reproduce
/// from the commit they claim.
pub fn render_report(records: &[RegistryRecord], plan_filter: Option<&str>) -> String {
    let selected: Vec<&RegistryRecord> = records
        .iter()
        .filter(|r| plan_filter.map_or(true, |f| r.plan == f))
        .collect();
    if selected.is_empty() {
        return match plan_filter {
            Some(f) => format!(
                "registry has no cells for plan {f:?} — run `sfut bench run \
                 ci/plans/{f}.plan` first\n"
            ),
            None => "registry is empty — run `sfut bench run <plan>` first\n".to_string(),
        };
    }
    let mut plan_names: Vec<&str> = Vec::new();
    for r in &selected {
        if !plan_names.contains(&r.plan.as_str()) {
            plan_names.push(&r.plan);
        }
    }
    let mut out = String::new();
    for plan in plan_names {
        let rows: Vec<&RegistryRecord> =
            selected.iter().copied().filter(|r| r.plan == plan).collect();
        let mut commits: Vec<&str> = Vec::new();
        for r in &rows {
            if !commits.contains(&r.provenance.commit.as_str()) {
                commits.push(&r.provenance.commit);
            }
        }
        let latest = *commits.last().expect("rows is non-empty");
        let prev = commits.len().checked_sub(2).map(|i| commits[i]);
        out.push_str(&format!(
            "plan {plan} — {} commit(s) in registry, latest {latest}\n",
            commits.len()
        ));
        for r in rows.iter().filter(|r| r.provenance.commit == latest) {
            let labels = r
                .point
                .labels
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(" ");
            let (metric, value) = primary_metric(&r.point);
            let dirty = if r.provenance.dirty { "*" } else { "" };
            let delta = prev
                .and_then(|prev_commit| {
                    rows.iter()
                        .find(|p| {
                            p.provenance.commit == prev_commit && p.point.labels == r.point.labels
                        })
                        .map(|p| (prev_commit, primary_metric(&p.point).1))
                })
                .map(|(prev_commit, prev_value)| {
                    if prev_value.abs() > 1e-9 {
                        format!(
                            " ({:+.1}% vs {prev_commit})",
                            (value / prev_value - 1.0) * 100.0
                        )
                    } else {
                        String::new()
                    }
                })
                .unwrap_or_default();
            out.push_str(&format!(
                "  {labels}: {metric} {}{dirty}{delta}\n",
                super::fmt_f64(value)
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::plan::PlanBackend;
    use super::*;

    fn point(shards: &str, jps: f64) -> BenchPoint {
        let mut p = BenchPoint::default();
        p.labels.insert("workload".to_string(), "msort".to_string());
        p.labels.insert("shards".to_string(), shards.to_string());
        p.metrics.insert("jobs_per_sec".to_string(), jps);
        p
    }

    fn report(commit: &str, dirty: bool, points: Vec<BenchPoint>) -> PlanReport {
        PlanReport {
            name: "msort_shards".to_string(),
            backend: PlanBackend::Pipeline,
            profile: "release",
            seed: 7,
            grid_cells: points.len(),
            provenance: Provenance {
                commit: commit.to_string(),
                dirty,
                seed: 7,
                toolchain: "rustc 1.x".to_string(),
                scale: 1.0,
                host_cores: 4,
            },
            points,
        }
    }

    #[test]
    fn append_then_read_roundtrips_with_provenance() {
        let path = std::env::temp_dir().join("sfut_registry_roundtrip.jsonl");
        let _ = std::fs::remove_file(&path);
        let n = append(&path, &report("aaa", false, vec![point("1", 100.0), point("2", 150.0)]))
            .unwrap();
        assert_eq!(n, 2);
        let records = read(&path).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].plan, "msort_shards");
        assert_eq!(records[0].backend, "pipeline");
        assert_eq!(records[0].profile, "release");
        assert_eq!(records[0].provenance.commit, "aaa");
        assert_eq!(records[0].provenance.seed, 7);
        assert_eq!(records[0].provenance.host_cores, 4);
        assert_eq!(records[1].point.label("shards"), Some("2"));
        assert_eq!(records[1].point.metric("jobs_per_sec"), Some(150.0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn reader_tolerates_unknown_keys_blank_lines_and_missing_files() {
        let missing = std::env::temp_dir().join("sfut_registry_never_written.jsonl");
        let _ = std::fs::remove_file(&missing);
        assert!(read(&missing).unwrap().is_empty());

        let path = std::env::temp_dir().join("sfut_registry_tolerant.jsonl");
        std::fs::write(
            &path,
            "\n{\"plan\": \"p\", \"future_key\": {\"nested\": 1}, \"point\": \
             {\"labels\": {\"shards\": \"1\"}, \"metrics\": {\"jobs_per_sec\": 5}}}\n\n",
        )
        .unwrap();
        let records = read(&path).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].plan, "p");
        assert_eq!(records[0].point.metric("jobs_per_sec"), Some(5.0));
        // Missing provenance degrades, never errors.
        assert_eq!(records[0].provenance.commit, "unknown");
        // Malformed JSON names its line.
        std::fs::write(&path, "{\"plan\": \"p\"}\n{broken\n").unwrap();
        let err = read(&path).unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn primary_metric_prefers_throughput_keys() {
        assert_eq!(primary_metric(&point("1", 42.0)), ("jobs_per_sec".to_string(), 42.0));
        let mut exec = BenchPoint::default();
        exec.metrics.insert("spawn_wave_tasks_per_sec".to_string(), 9.0);
        assert_eq!(primary_metric(&exec), ("spawn_wave_tasks_per_sec".to_string(), 9.0));
        assert_eq!(primary_metric(&BenchPoint::default()), ("none".to_string(), 0.0));
    }

    #[test]
    fn report_diffs_latest_commit_against_previous() {
        let path = std::env::temp_dir().join("sfut_registry_diff.jsonl");
        let _ = std::fs::remove_file(&path);
        append(&path, &report("aaa", false, vec![point("8", 100.0)])).unwrap();
        append(&path, &report("bbb", true, vec![point("8", 80.0)])).unwrap();
        let records = read(&path).unwrap();
        let text = render_report(&records, None);
        assert!(text.contains("latest bbb"), "{text}");
        assert!(text.contains("-20.0% vs aaa"), "{text}");
        assert!(text.contains('*'), "dirty cells are marked: {text}");
        // Filtering on an absent plan explains itself.
        let empty = render_report(&records, Some("nope"));
        assert!(empty.contains("no cells for plan"), "{empty}");
        let _ = std::fs::remove_file(&path);
    }
}

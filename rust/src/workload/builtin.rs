//! The paper's nine Table-1 scenarios as three [`StreamWorkload`]
//! families.
//!
//! What used to be nine enum variants (and a nine-arm router `match`)
//! is three plugin types × a handful of registrations:
//!
//! * [`SieveWorkload`] — the §5 trial-division sieve (`primes`,
//!   `primes_x3`) and the §7 block-granular variant (`primes_chunked`).
//!   Param: `n` (sieve bound).
//! * [`PolyMulWorkload`] — the §6 stream multiply (`stream`,
//!   `stream_big`) and the §7 chunked improvement (`chunked`,
//!   `chunked_big`). Params: `degree`, `big_factor` (0 = machine-word
//!   coefficients), `chunked` (override the registration's algorithm).
//! * [`ListMulWorkload`] — the data-parallel collections baseline
//!   (`list`, `list_big`). Params: `degree`, `big_factor`.
//!
//! Every body is written once over `E: Eval` (an [`EvalBody`]) and
//! dispatched by [`WorkloadCtx::run_mode`]; verification recomputes the
//! oracle for the *effective* parameters, so `stream(degree=3)` and
//! `stream` verify against different products.

use std::sync::Arc;

use crate::config::{ChunkPolicy, Mode};
use crate::poly::{
    chunked_times, chunked_times_adaptive_cached, list_times_par, list_times_seq, stream_times,
    BlockMultiplier, Coeff, Polynomial,
};
use crate::sieve;
use crate::sieve::BlockSiever;
use crate::stream::CostCache;
use crate::susp::Eval;

use super::api::{
    poly_detail, EvalBody, ParamKind, ParamSpec, Params, ResultDetail, StreamWorkload,
    WorkloadCtx, WorkloadError,
};
use super::registry::WorkloadRegistry;
use super::{fateman_pair, fateman_pair_big};

/// Register the paper's nine scenarios into `reg`.
pub fn register_paper_workloads(reg: &mut WorkloadRegistry) -> Result<(), WorkloadError> {
    reg.register(Arc::new(SieveWorkload::plain(
        "primes",
        1,
        "trial-division stream sieve below n (the paper's deliberately naive §5 sieve)",
    )))?;
    reg.register(Arc::new(SieveWorkload::plain(
        "primes_x3",
        3,
        "the stream sieve at three times the configured bound",
    )))?;
    reg.register(Arc::new(SieveWorkload::chunked(
        "primes_chunked",
        "block-granular sieve (§7 improvement; kernel-offloadable)",
    )))?;
    reg.register(Arc::new(PolyMulWorkload::new(
        "stream",
        false,
        false,
        "Fateman product via the stream algorithm, machine-word coefficients",
    )))?;
    reg.register(Arc::new(PolyMulWorkload::new(
        "stream_big",
        false,
        true,
        "stream multiply with big coefficients (x big_factor)",
    )))?;
    reg.register(Arc::new(PolyMulWorkload::new(
        "chunked",
        true,
        false,
        "blocked stream multiply (§7 improvement; kernel-offloadable)",
    )))?;
    reg.register(Arc::new(PolyMulWorkload::new(
        "chunked_big",
        true,
        true,
        "blocked stream multiply with big coefficients",
    )))?;
    reg.register(Arc::new(ListMulWorkload::new(
        "list",
        false,
        "parallel-collections baseline multiply",
    )))?;
    reg.register(Arc::new(ListMulWorkload::new(
        "list_big",
        true,
        "baseline multiply with big coefficients",
    )))?;
    Ok(())
}

// ---------------------------------------------------------------------
// sieve family
// ---------------------------------------------------------------------

/// The prime-sieve family: plain stream sieve or §7 chunked blocks.
pub struct SieveWorkload {
    name: &'static str,
    describe: &'static str,
    /// Default bound = `sizes.primes_n × n_mult` (the `_x3` knob).
    n_mult: u32,
    chunked: bool,
}

impl SieveWorkload {
    pub fn plain(name: &'static str, n_mult: u32, describe: &'static str) -> SieveWorkload {
        SieveWorkload { name, describe, n_mult, chunked: false }
    }

    pub fn chunked(name: &'static str, describe: &'static str) -> SieveWorkload {
        SieveWorkload { name, describe, n_mult: 1, chunked: true }
    }

    fn effective_n(&self, ctx: &WorkloadCtx<'_>, params: &Params) -> Result<u32, WorkloadError> {
        params.get_u32("n", ctx.sizes.primes_n.saturating_mul(self.n_mult))
    }
}

struct PlainSieveBody {
    n: u32,
}

impl EvalBody for PlainSieveBody {
    type Out = Vec<u32>;

    fn run<E: Eval>(self, eval: E) -> Vec<u32> {
        sieve::primes(eval, self.n)
    }
}

struct ChunkedSieveBody {
    n: u32,
    chunk: usize,
    policy: ChunkPolicy,
    siever: Arc<dyn BlockSiever>,
    cost: CostCache,
}

impl EvalBody for ChunkedSieveBody {
    type Out = Vec<u32>;

    fn run<E: Eval>(self, eval: E) -> Vec<u32> {
        match self.policy {
            ChunkPolicy::Fixed => {
                sieve::chunked_primes_with_runtime(eval, self.n, self.chunk, self.siever)
            }
            ChunkPolicy::Adaptive => {
                sieve::chunked_primes_adaptive_cached(eval, self.n, self.siever, &self.cost)
            }
        }
    }
}

impl StreamWorkload for SieveWorkload {
    fn name(&self) -> &str {
        self.name
    }

    fn describe(&self) -> &str {
        self.describe
    }

    fn params(&self) -> Vec<ParamSpec> {
        // Bounded: the Eratosthenes oracle allocates O(n) — a wire
        // request must not be able to ask for an arbitrary allocation.
        vec![ParamSpec::new(
            "n",
            ParamKind::U32,
            "primes_n (scaled; ×3 for primes_x3)",
            "sieve bound (exclusive)",
        )
        .with_range(0, 50_000_000)]
    }

    fn run(
        &self,
        ctx: &WorkloadCtx<'_>,
        mode: Mode,
        params: &Params,
    ) -> Result<ResultDetail, WorkloadError> {
        let n = self.effective_n(ctx, params)?;
        let primes = if self.chunked {
            ctx.run_mode(
                mode,
                ChunkedSieveBody {
                    n,
                    chunk: ctx.sizes.chunk_size,
                    policy: ctx.chunk_policy,
                    siever: Arc::clone(&ctx.siever),
                    cost: ctx.cost_cache(&self.cost_key(params)),
                },
            )
        } else {
            ctx.run_mode(mode, PlainSieveBody { n })
        };
        Ok(ResultDetail::Primes {
            count: primes.len(),
            largest: primes.last().copied().unwrap_or(0),
        })
    }

    fn verify(&self, ctx: &WorkloadCtx<'_>, params: &Params, detail: &ResultDetail) -> bool {
        let Ok(n) = self.effective_n(ctx, params) else {
            return false;
        };
        let oracle = sieve::eratosthenes(n);
        matches!(detail, ResultDetail::Primes { count, largest }
            if oracle.len() == *count && oracle.last().copied().unwrap_or(0) == *largest)
    }

    fn backend(&self, ctx: &WorkloadCtx<'_>, _params: &Params) -> String {
        if self.chunked {
            ctx.siever.name().to_string()
        } else {
            "-".to_string()
        }
    }
}

// ---------------------------------------------------------------------
// Fateman-product shared pieces (stream-multiply + list families)
// ---------------------------------------------------------------------

/// Effective `(degree, big_factor)` for a Fateman-product workload
/// after param overrides; factor 0 selects the machine-word ring.
fn fateman_effective(
    ctx: &WorkloadCtx<'_>,
    params: &Params,
    big_default: bool,
) -> Result<(u32, i64), WorkloadError> {
    let degree = params.get_u32("degree", ctx.sizes.fateman_degree)?;
    if degree == 0 {
        return Err(WorkloadError::new("degree must be >= 1"));
    }
    let default_factor = if big_default { ctx.sizes.big_factor } else { 0 };
    Ok((degree, params.get_i64("big_factor", default_factor)?))
}

/// The independent oracle every Fateman family verifies against:
/// classical multiplication of the same effective pair.
fn fateman_oracle(vars: usize, degree: u32, factor: i64) -> ResultDetail {
    if factor == 0 {
        let (p, q) = fateman_pair(vars, degree);
        poly_detail(&p.mul(&q))
    } else {
        let (p, q) = fateman_pair_big(vars, degree, factor);
        poly_detail(&p.mul(&q))
    }
}

/// Shared `degree`/`big_factor` schema for the Fateman families. The
/// degree cap bounds the O(terms²) product a single request can demand
/// (degree 24 over 4 vars ≈ 20k terms already).
fn fateman_param_specs(factor_default: &'static str) -> Vec<ParamSpec> {
    vec![
        ParamSpec::new(
            "degree",
            ParamKind::U32,
            "fateman_degree (scaled)",
            "Fateman exponent k in (1+Σx)^k",
        )
        .with_range(1, 24),
        ParamSpec::new(
            "big_factor",
            ParamKind::I64,
            factor_default,
            "coefficient scale; 0 = machine words, else BigInt × factor",
        ),
    ]
}

// ---------------------------------------------------------------------
// stream-multiply family
// ---------------------------------------------------------------------

/// The Fateman-product family over the stream algorithm (§6) or the §7
/// chunked improvement, with machine-word or big coefficients.
pub struct PolyMulWorkload {
    name: &'static str,
    describe: &'static str,
    chunked: bool,
    big: bool,
}

impl PolyMulWorkload {
    pub fn new(
        name: &'static str,
        chunked: bool,
        big: bool,
        describe: &'static str,
    ) -> PolyMulWorkload {
        PolyMulWorkload { name, describe, chunked, big }
    }

    /// `(degree, big_factor, chunked)` after param overrides; factor 0
    /// selects the machine-word ring.
    fn effective(
        &self,
        ctx: &WorkloadCtx<'_>,
        params: &Params,
    ) -> Result<(u32, i64, bool), WorkloadError> {
        let (degree, factor) = fateman_effective(ctx, params, self.big)?;
        Ok((degree, factor, params.get_bool("chunked", self.chunked)?))
    }

    fn multiply<C: Coeff>(
        &self,
        ctx: &WorkloadCtx<'_>,
        mode: Mode,
        chunked: bool,
        params: &Params,
        p: &Polynomial<C>,
        q: &Polynomial<C>,
    ) -> Polynomial<C> {
        if chunked {
            ctx.run_mode(
                mode,
                ChunkedTimesBody {
                    p,
                    q,
                    chunk: ctx.sizes.chunk_size,
                    policy: ctx.chunk_policy,
                    mult: Arc::clone(&ctx.multiplier),
                    cost: ctx.cost_cache(&self.cost_key(params)),
                },
            )
        } else {
            ctx.run_mode(mode, StreamTimesBody { p, q })
        }
    }
}

struct StreamTimesBody<'a, C: Coeff> {
    p: &'a Polynomial<C>,
    q: &'a Polynomial<C>,
}

impl<C: Coeff> EvalBody for StreamTimesBody<'_, C> {
    type Out = Polynomial<C>;

    fn run<E: Eval>(self, eval: E) -> Polynomial<C> {
        stream_times(&eval, self.p, self.q)
    }
}

struct ChunkedTimesBody<'a, C: Coeff> {
    p: &'a Polynomial<C>,
    q: &'a Polynomial<C>,
    chunk: usize,
    policy: ChunkPolicy,
    mult: Arc<dyn BlockMultiplier>,
    cost: CostCache,
}

impl<C: Coeff> EvalBody for ChunkedTimesBody<'_, C> {
    type Out = Polynomial<C>;

    fn run<E: Eval>(self, eval: E) -> Polynomial<C> {
        match self.policy {
            ChunkPolicy::Fixed => chunked_times(&eval, self.p, self.q, self.chunk, self.mult),
            ChunkPolicy::Adaptive => {
                chunked_times_adaptive_cached(&eval, self.p, self.q, self.mult, &self.cost)
            }
        }
    }
}

impl StreamWorkload for PolyMulWorkload {
    fn name(&self) -> &str {
        self.name
    }

    fn describe(&self) -> &str {
        self.describe
    }

    fn params(&self) -> Vec<ParamSpec> {
        let mut specs = fateman_param_specs("0 (big_factor for _big registrations)");
        specs.push(ParamSpec::new(
            "chunked",
            ParamKind::Bool,
            "per registration",
            "use the §7 blocked algorithm",
        ));
        specs
    }

    fn run(
        &self,
        ctx: &WorkloadCtx<'_>,
        mode: Mode,
        params: &Params,
    ) -> Result<ResultDetail, WorkloadError> {
        let (degree, factor, chunked) = self.effective(ctx, params)?;
        let vars = ctx.sizes.fateman_vars;
        if factor == 0 {
            let (p, q) = fateman_pair(vars, degree);
            Ok(poly_detail(&self.multiply(ctx, mode, chunked, params, &p, &q)))
        } else {
            let (p, q) = fateman_pair_big(vars, degree, factor);
            Ok(poly_detail(&self.multiply(ctx, mode, chunked, params, &p, &q)))
        }
    }

    fn verify(&self, ctx: &WorkloadCtx<'_>, params: &Params, detail: &ResultDetail) -> bool {
        let Ok((degree, factor, _)) = self.effective(ctx, params) else {
            return false;
        };
        fateman_oracle(ctx.sizes.fateman_vars, degree, factor) == *detail
    }

    fn backend(&self, ctx: &WorkloadCtx<'_>, params: &Params) -> String {
        match self.effective(ctx, params) {
            Ok((_, _, true)) => ctx.multiplier.name().to_string(),
            _ => "-".to_string(),
        }
    }
}

// ---------------------------------------------------------------------
// list baseline family
// ---------------------------------------------------------------------

/// The parallel-collections control: classical multiply, data-parallel
/// under `par(k)`. Not stream-expressed — it exists to be measured
/// against, so it dispatches on [`Mode`] directly instead of an
/// [`EvalBody`].
pub struct ListMulWorkload {
    name: &'static str,
    describe: &'static str,
    big: bool,
}

impl ListMulWorkload {
    pub fn new(name: &'static str, big: bool, describe: &'static str) -> ListMulWorkload {
        ListMulWorkload { name, describe, big }
    }

    fn multiply<C: Coeff>(
        &self,
        ctx: &WorkloadCtx<'_>,
        mode: Mode,
        p: &Polynomial<C>,
        q: &Polynomial<C>,
    ) -> Polynomial<C> {
        match mode {
            Mode::Seq | Mode::Strict => list_times_seq(p, q),
            Mode::Par(k) => list_times_par(&ctx.executor(k), p, q),
        }
    }
}

impl StreamWorkload for ListMulWorkload {
    fn name(&self) -> &str {
        self.name
    }

    fn describe(&self) -> &str {
        self.describe
    }

    fn params(&self) -> Vec<ParamSpec> {
        fateman_param_specs("0 (big_factor for list_big)")
    }

    fn run(
        &self,
        ctx: &WorkloadCtx<'_>,
        mode: Mode,
        params: &Params,
    ) -> Result<ResultDetail, WorkloadError> {
        let (degree, factor) = fateman_effective(ctx, params, self.big)?;
        let vars = ctx.sizes.fateman_vars;
        if factor == 0 {
            let (p, q) = fateman_pair(vars, degree);
            Ok(poly_detail(&self.multiply(ctx, mode, &p, &q)))
        } else {
            let (p, q) = fateman_pair_big(vars, degree, factor);
            Ok(poly_detail(&self.multiply(ctx, mode, &p, &q)))
        }
    }

    fn verify(&self, ctx: &WorkloadCtx<'_>, params: &Params, detail: &ResultDetail) -> bool {
        let Ok((degree, factor)) = fateman_effective(ctx, params, self.big) else {
            return false;
        };
        fateman_oracle(ctx.sizes.fateman_vars, degree, factor) == *detail
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::poly::RustMultiplier;
    use crate::sieve::RustSiever;
    use crate::workload::{LocalResources, Sizes};

    fn small_sizes() -> Sizes {
        let mut cfg = Config::default();
        cfg.primes_n = 200;
        cfg.fateman_degree = 2;
        cfg.chunk_size = 16;
        Sizes::from_config(&cfg)
    }

    fn ctx<'a>(sizes: &'a Sizes, res: &'a LocalResources) -> WorkloadCtx<'a> {
        WorkloadCtx::new(
            sizes,
            ChunkPolicy::Adaptive,
            Arc::new(RustMultiplier),
            Arc::new(RustSiever),
            res,
        )
    }

    #[test]
    fn sieve_family_runs_and_verifies_outside_the_coordinator() {
        let sizes = small_sizes();
        let res = LocalResources::new();
        let ctx = ctx(&sizes, &res);
        let w = SieveWorkload::plain("primes", 1, "t");
        let detail = w.run(&ctx, Mode::Seq, &Params::new()).unwrap();
        assert!(w.verify(&ctx, &Params::new(), &detail));
        assert_eq!(detail, ResultDetail::Primes { count: 46, largest: 199 });
        // Param override re-aims both run and oracle.
        let p = Params::parse("n=50").unwrap();
        let detail = w.run(&ctx, Mode::Par(2), &p).unwrap();
        assert_eq!(detail, ResultDetail::Primes { count: 15, largest: 47 });
        assert!(w.verify(&ctx, &p, &detail));
        assert!(!w.verify(&ctx, &Params::new(), &detail), "wrong params must fail verify");
        assert_eq!(w.backend(&ctx, &Params::new()), "-");
    }

    #[test]
    fn chunked_sieve_reports_its_siever_backend() {
        let sizes = small_sizes();
        let res = LocalResources::new();
        let ctx = ctx(&sizes, &res);
        let w = SieveWorkload::chunked("primes_chunked", "t");
        let detail = w.run(&ctx, Mode::Par(2), &Params::new()).unwrap();
        assert!(w.verify(&ctx, &Params::new(), &detail));
        assert_eq!(w.backend(&ctx, &Params::new()), "rust-scalar");
    }

    #[test]
    fn poly_family_modes_agree_and_chunked_param_switches_algorithm() {
        let sizes = small_sizes();
        let res = LocalResources::new();
        let ctx = ctx(&sizes, &res);
        let w = PolyMulWorkload::new("stream", false, false, "t");
        let seq = w.run(&ctx, Mode::Seq, &Params::new()).unwrap();
        let par = w.run(&ctx, Mode::Par(2), &Params::new()).unwrap();
        let strict = w.run(&ctx, Mode::Strict, &Params::new()).unwrap();
        assert_eq!(seq, par);
        assert_eq!(seq, strict);
        assert!(w.verify(&ctx, &Params::new(), &seq));
        // chunked=true flips algorithm and backend, not the result.
        let p = Params::parse("chunked=true").unwrap();
        let chunked = w.run(&ctx, Mode::Par(2), &p).unwrap();
        assert_eq!(chunked, seq);
        assert_eq!(w.backend(&ctx, &p), "rust-scalar");
        assert_eq!(w.backend(&ctx, &Params::new()), "-");
        // big_factor switches the ring; detail differs, verify follows.
        let pb = Params::parse("big_factor=100000000001").unwrap();
        let big = w.run(&ctx, Mode::Seq, &pb).unwrap();
        assert_ne!(big, seq);
        assert!(w.verify(&ctx, &pb, &big));
    }

    #[test]
    fn list_family_baseline_verifies_under_all_modes() {
        let sizes = small_sizes();
        let res = LocalResources::new();
        let ctx = ctx(&sizes, &res);
        let w = ListMulWorkload::new("list", false, "t");
        for mode in [Mode::Seq, Mode::Strict, Mode::Par(2)] {
            let detail = w.run(&ctx, mode, &Params::new()).unwrap();
            assert!(w.verify(&ctx, &Params::new(), &detail), "{mode:?}");
        }
        let e = w.run(&ctx, Mode::Seq, &Params::parse("degree=0").unwrap()).unwrap_err();
        assert!(e.message.contains("degree"), "{e}");
    }
}

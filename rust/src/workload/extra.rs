//! Post-enum workloads: scenarios added through the public plugin API
//! alone.
//!
//! These two plugins are the existence proof for the open workload
//! surface: they implement [`StreamWorkload`] against the exported API
//! (streams, `BigInt`, `Params`, [`WorkloadCtx`]) and are *registered*
//! — no coordinator, config, router, verifier, or bench-harness code
//! changed to ship them.
//!
//! * [`FibWorkload`] (`fib`) — a big-integer Fibonacci stream: the
//!   first `n` Fibonacci numbers as a monadic stream (one suspension
//!   per element, so `par(k)` pipelines the BigInt additions exactly
//!   like the paper's Figure 1 cascade), folded into their sum.
//!   Oracle: an independent iterative loop.
//! * [`MergeSortWorkload`] (`msort`) — streaming merge sort over the
//!   existing `merge_sorted` combinator: a deterministic xorshift input
//!   is split into singleton streams and merged pairwise; under
//!   `Future` every merge level runs as suspended tasks. Oracle:
//!   `slice::sort_unstable` on the same input.

use std::sync::Arc;

use crate::bigint::BigInt;
use crate::config::Mode;
use crate::stream::Stream;
use crate::susp::Eval;

use super::api::{
    EvalBody, ParamKind, ParamSpec, Params, ResultDetail, StreamWorkload, WorkloadCtx,
    WorkloadError,
};
use super::registry::WorkloadRegistry;

/// Register the `fib` and `msort` plugins into `reg`.
pub fn register_extra_workloads(reg: &mut WorkloadRegistry) -> Result<(), WorkloadError> {
    reg.register(Arc::new(FibWorkload))?;
    reg.register(Arc::new(MergeSortWorkload))?;
    Ok(())
}

// ---------------------------------------------------------------------
// fib — big-integer Fibonacci stream
// ---------------------------------------------------------------------

/// Big-integer Fibonacci via a monadic stream; detail = decimal sum of
/// the first `n` Fibonacci numbers (F(0)=0, F(1)=1).
pub struct FibWorkload;

struct FibBody {
    n: u32,
}

impl EvalBody for FibBody {
    type Out = BigInt;

    fn run<E: Eval>(self, eval: E) -> BigInt {
        // One cons cell per Fibonacci number: under Future the whole
        // cascade of BigInt additions is scheduled at construction.
        let s: Stream<BigInt, E> = Stream::unfold(
            eval,
            (BigInt::zero(), BigInt::one(), self.n),
            |state: &mut (BigInt, BigInt, u32)| {
                if state.2 == 0 {
                    return None;
                }
                state.2 -= 1;
                let next = &state.0 + &state.1;
                let out = std::mem::replace(&mut state.0, std::mem::replace(&mut state.1, next));
                Some(out)
            },
        );
        s.fold(BigInt::zero(), |acc, x| &acc + x)
    }
}

/// Independent oracle: plain iterative accumulation.
fn fib_sum_iterative(n: u32) -> BigInt {
    let mut a = BigInt::zero();
    let mut b = BigInt::one();
    let mut sum = BigInt::zero();
    for _ in 0..n {
        sum = &sum + &a;
        let next = &a + &b;
        a = std::mem::replace(&mut b, next);
    }
    sum
}

impl FibWorkload {
    fn effective_n(&self, ctx: &WorkloadCtx<'_>, params: &Params) -> Result<u32, WorkloadError> {
        params.get_u32("n", ctx.sizes.fib_n)
    }
}

impl StreamWorkload for FibWorkload {
    fn name(&self) -> &str {
        "fib"
    }

    fn describe(&self) -> &str {
        "big-integer Fibonacci stream: sum of the first n Fibonacci numbers"
    }

    fn params(&self) -> Vec<ParamSpec> {
        // Bounded: F(n) has Θ(n) digits, so the sum costs Θ(n²) limb
        // operations — a wire request must not buy unbounded compute.
        vec![ParamSpec::new(
            "n",
            ParamKind::U32,
            "512 (scaled)",
            "how many Fibonacci numbers to stream",
        )
        .with_range(0, 10_000)]
    }

    fn run(
        &self,
        ctx: &WorkloadCtx<'_>,
        mode: Mode,
        params: &Params,
    ) -> Result<ResultDetail, WorkloadError> {
        let n = self.effective_n(ctx, params)?;
        let sum = ctx.run_mode(mode, FibBody { n });
        Ok(ResultDetail::Scalar { value: sum.to_string() })
    }

    fn verify(&self, ctx: &WorkloadCtx<'_>, params: &Params, detail: &ResultDetail) -> bool {
        let Ok(n) = self.effective_n(ctx, params) else {
            return false;
        };
        matches!(detail, ResultDetail::Scalar { value }
            if *value == fib_sum_iterative(n).to_string())
    }
}

// ---------------------------------------------------------------------
// msort — streaming merge sort
// ---------------------------------------------------------------------

/// Streaming merge sort over `Stream::merge_sorted`; detail = element
/// count plus an order-sensitive FNV-1a digest of the sorted sequence.
pub struct MergeSortWorkload;

const MSORT_DEFAULT_SEED: u64 = 0x9e37_79b9_7f4a_7c15;

/// Deterministic input: xorshift64* sequence from `seed`.
fn msort_input(n: usize, seed: u64) -> Vec<u64> {
    // xorshift state must be nonzero; 0 falls back to the default seed.
    let mut x = if seed == 0 { MSORT_DEFAULT_SEED } else { seed };
    (0..n)
        .map(|_| {
            x ^= x >> 12;
            x ^= x << 25;
            x ^= x >> 27;
            x.wrapping_mul(0x2545_f491_4f6c_dd1d)
        })
        .collect()
}

/// Order-sensitive FNV-1a over the sequence.
fn digest(items: &[u64]) -> u64 {
    let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in items {
        for b in v.to_le_bytes() {
            hash ^= b as u64;
            hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    hash
}

fn msort_stream<E: Eval>(eval: E, items: &[u64]) -> Stream<u64, E> {
    match items.len() {
        0 => Stream::Empty,
        1 => Stream::singleton(eval, items[0]),
        len => {
            let (lo, hi) = items.split_at(len / 2);
            let left = msort_stream(eval.clone(), lo);
            let right = msort_stream(eval, hi);
            left.merge_sorted(&right, |a, b| a.cmp(b))
        }
    }
}

struct MsortBody {
    items: Vec<u64>,
}

impl EvalBody for MsortBody {
    type Out = Vec<u64>;

    fn run<E: Eval>(self, eval: E) -> Vec<u64> {
        msort_stream(eval, &self.items).to_vec()
    }
}

impl MergeSortWorkload {
    fn effective(
        &self,
        ctx: &WorkloadCtx<'_>,
        params: &Params,
    ) -> Result<(usize, u64), WorkloadError> {
        let n = params.get_usize("n", ctx.sizes.msort_n)?;
        let seed = params.get_u64("seed", MSORT_DEFAULT_SEED)?;
        Ok((n, seed))
    }
}

impl StreamWorkload for MergeSortWorkload {
    fn name(&self) -> &str {
        "msort"
    }

    fn describe(&self) -> &str {
        "streaming merge sort of a deterministic pseudo-random sequence"
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![
            // Bounded: the input vec and the stream spine are O(n)
            // allocations driven straight from the wire.
            ParamSpec::new("n", ParamKind::Usize, "4096 (scaled)", "elements to sort")
                .with_range(0, 1_000_000),
            // Decimal (= 0x9e3779b97f4a7c15): the u64 parser is
            // decimal-only, so the advertised default must replay as-is.
            ParamSpec::new("seed", ParamKind::U64, "11400714819323198485", "input PRNG seed"),
        ]
    }

    fn run(
        &self,
        ctx: &WorkloadCtx<'_>,
        mode: Mode,
        params: &Params,
    ) -> Result<ResultDetail, WorkloadError> {
        let (n, seed) = self.effective(ctx, params)?;
        let sorted = ctx.run_mode(mode, MsortBody { items: msort_input(n, seed) });
        if sorted.len() != n {
            return Err(WorkloadError::new(format!(
                "merge sort lost elements: {} of {n}",
                sorted.len()
            )));
        }
        Ok(ResultDetail::Scalar { value: format!("{:016x}/{n}", digest(&sorted)) })
    }

    fn verify(&self, ctx: &WorkloadCtx<'_>, params: &Params, detail: &ResultDetail) -> bool {
        let Ok((n, seed)) = self.effective(ctx, params) else {
            return false;
        };
        let mut oracle = msort_input(n, seed);
        oracle.sort_unstable();
        matches!(detail, ResultDetail::Scalar { value }
            if *value == format!("{:016x}/{n}", digest(&oracle)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChunkPolicy, Config};
    use crate::poly::RustMultiplier;
    use crate::sieve::RustSiever;
    use crate::susp::LazyEval;
    use crate::workload::{LocalResources, Sizes};

    fn sizes() -> Sizes {
        let mut cfg = Config::default();
        cfg.scale = 0.05;
        Sizes::from_config(&cfg)
    }

    fn ctx<'a>(sizes: &'a Sizes, res: &'a LocalResources) -> WorkloadCtx<'a> {
        WorkloadCtx::new(
            sizes,
            ChunkPolicy::Adaptive,
            Arc::new(RustMultiplier),
            Arc::new(RustSiever),
            res,
        )
    }

    #[test]
    fn fib_sum_matches_known_values() {
        // F(0..10) = 0 1 1 2 3 5 8 13 21 34 → sum 88.
        assert_eq!(fib_sum_iterative(10).to_string(), "88");
        assert_eq!(fib_sum_iterative(0).to_string(), "0");
        let sizes = sizes();
        let res = LocalResources::new();
        let ctx = ctx(&sizes, &res);
        let w = FibWorkload;
        let p = Params::parse("n=10").unwrap();
        for mode in [Mode::Seq, Mode::Strict, Mode::Par(2)] {
            let detail = w.run(&ctx, mode, &p).unwrap();
            assert_eq!(detail, ResultDetail::Scalar { value: "88".into() }, "{mode:?}");
            assert!(w.verify(&ctx, &p, &detail));
        }
        // Big enough to overflow machine words: F(100) has 21 digits.
        let p = Params::parse("n=101").unwrap();
        let detail = w.run(&ctx, Mode::Seq, &p).unwrap();
        assert!(w.verify(&ctx, &p, &detail));
        match &detail {
            ResultDetail::Scalar { value } => assert!(value.len() > 19, "not big: {value}"),
            other => panic!("wrong detail kind: {other:?}"),
        }
    }

    #[test]
    fn msort_stream_sorts_and_verifies_across_modes() {
        let sizes = sizes();
        let res = LocalResources::new();
        let ctx = ctx(&sizes, &res);
        let w = MergeSortWorkload;
        let p = Params::parse("n=300,seed=42").unwrap();
        let seq = w.run(&ctx, Mode::Seq, &p).unwrap();
        assert!(w.verify(&ctx, &p, &seq));
        for mode in [Mode::Strict, Mode::Par(2)] {
            assert_eq!(w.run(&ctx, mode, &p).unwrap(), seq, "{mode:?}");
        }
        // Different seed → different digest, still verified.
        let p2 = Params::parse("n=300,seed=43").unwrap();
        let other = w.run(&ctx, Mode::Seq, &p2).unwrap();
        assert_ne!(other, seq);
        assert!(w.verify(&ctx, &p2, &other));
        assert!(!w.verify(&ctx, &p, &other), "seed mismatch must fail verify");
    }

    #[test]
    fn msort_stream_is_genuinely_sorted_and_stable_sized() {
        let input = msort_input(257, 7);
        let sorted = msort_stream(LazyEval, &input).to_vec();
        assert_eq!(sorted.len(), input.len());
        assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
        let mut oracle = input.clone();
        oracle.sort_unstable();
        assert_eq!(sorted, oracle);
        // Degenerate sizes.
        assert!(msort_stream(LazyEval, &[]).is_empty());
        assert_eq!(msort_stream(LazyEval, &[9]).to_vec(), vec![9]);
    }
}

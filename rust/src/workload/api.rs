//! The open workload-plugin API: [`StreamWorkload`], [`WorkloadCtx`],
//! and the parameter machinery.
//!
//! Before this module the coordinator served exactly the scenarios a
//! closed `Workload` enum enumerated: adding an algorithm meant editing
//! the enum, a nine-arm dispatch `match` in the router, the verifier,
//! the backend picker, and the bench harness. The paper's claim is the
//! opposite of that shape — Future-substitution parallelizes *any*
//! algorithm expressible as a Stream computation — so the workload
//! surface is now a trait:
//!
//! * [`StreamWorkload`] — name, parameter schema, `run`, `verify`, and
//!   optional backend/cost hooks. One implementation covers a *family*
//!   of scenarios via [`Params`] (`primes`/`primes_x3`/`primes_chunked`
//!   are three registrations of one sieve plugin).
//! * [`WorkloadCtx`] — everything a plugin may draw from the shard that
//!   executes it: warm `par(k)` executor pools, the memoized
//!   chunk-probe [`CostCache`]s, the block multiplier/siever backends,
//!   and the configured [`Sizes`]. Plugins never see the coordinator.
//! * [`EvalBody`] + [`WorkloadCtx::run_mode`] — the paper's
//!   substitution as a library call: write one body generic over
//!   `E: Eval` and the requested [`Mode`] selects `Lazy`, `Strict`, or
//!   `Future`-on-a-warm-pool.
//!
//! Registration happens in a
//! [`WorkloadRegistry`](super::WorkloadRegistry); the coordinator
//! dispatches by *name*, so a new algorithm ships without touching
//! config, router, verifier, or bench code (see `workload::extra` for
//! two workloads added exactly that way).

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, Mutex};

use crate::config::{ChunkPolicy, Mode};
use crate::exec::{Executor, ExecutorConfig};
use crate::poly::{BlockMultiplier, Coeff, Polynomial};
use crate::sieve::BlockSiever;
use crate::stream::CostCache;
use crate::susp::{CancelToken, Eval, FutureEval, LazyEval, StrictEval};

use super::Sizes;

/// Error raised by workload parsing, validation, registration, or
/// execution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadError {
    pub message: String,
}

impl WorkloadError {
    pub fn new(message: impl Into<String>) -> WorkloadError {
        WorkloadError { message: message.into() }
    }
}

impl fmt::Display for WorkloadError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for WorkloadError {}

/// Workload-specific result summary, used for verification and
/// reporting. The `Primes`/`Poly` variants serve the paper's original
/// families; `Scalar` is the open-world variant — any deterministic
/// rendering (digest, decimal value, …) that `seq`/`strict`/`par(k)`
/// runs of the same request must agree on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ResultDetail {
    Primes {
        count: usize,
        largest: u32,
    },
    Poly {
        terms: usize,
        /// Decimal rendering of the leading coefficient (ring-agnostic).
        leading_coeff: String,
    },
    Scalar {
        /// Opaque plugin summary; must be mode-independent.
        value: String,
    },
}

/// The standard polynomial summary: term count + leading coefficient.
/// Shared by the multiply plugins and anything else producing a
/// [`Polynomial`].
pub fn poly_detail<C: Coeff>(p: &Polynomial<C>) -> ResultDetail {
    ResultDetail::Poly {
        terms: p.num_terms(),
        leading_coeff: p.leading().map(|(_, c)| c.to_string()).unwrap_or_else(|| "0".into()),
    }
}

/// Parsed `k=v` parameters attached to a job request. Deterministically
/// ordered (BTreeMap), so [`Params::render`] round-trips through the
/// wire protocol and labels.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Params {
    map: BTreeMap<String, String>,
}

impl Params {
    pub fn new() -> Params {
        Params::default()
    }

    /// Parse the inside of a `workload(k=v,...)` spec — comma-separated
    /// `k=v` pairs, whitespace-tolerant, empty input allowed. Errors
    /// name the offending piece.
    pub fn parse(s: &str) -> Result<Params, WorkloadError> {
        let mut params = Params::new();
        for piece in s.split(',') {
            let piece = piece.trim();
            if piece.is_empty() {
                continue;
            }
            let (k, v) = piece.split_once('=').ok_or_else(|| {
                WorkloadError::new(format!("bad parameter {piece:?} (want key=value)"))
            })?;
            let (k, v) = (k.trim(), v.trim());
            if k.is_empty() || v.is_empty() {
                return Err(WorkloadError::new(format!(
                    "bad parameter {piece:?}: empty key or value"
                )));
            }
            if params.map.insert(k.to_string(), v.to_string()).is_some() {
                return Err(WorkloadError::new(format!("duplicate parameter: {k}")));
            }
        }
        Ok(params)
    }

    /// Inverse of [`Params::parse`]: `"k=v,k2=v2"` in key order.
    pub fn render(&self) -> String {
        self.map
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",")
    }

    pub fn set(&mut self, key: impl Into<String>, value: impl Into<String>) {
        self.map.insert(key.into(), value.into());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(String::as_str)
    }

    /// Remove `key`, returning its value if present. Used by the
    /// coordinator to strip reserved wire parameters (`deadline_ms`)
    /// before a plugin's schema validation sees them.
    pub fn remove(&mut self, key: &str) -> Option<String> {
        self.map.remove(key)
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.map.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    fn typed<T: std::str::FromStr>(
        &self,
        key: &str,
        kind: &str,
    ) -> Result<Option<T>, WorkloadError> {
        match self.map.get(key) {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|_| {
                WorkloadError::new(format!("bad value for param {key}: {v:?} (want {kind})"))
            }),
        }
    }

    pub fn get_u32(&self, key: &str, default: u32) -> Result<u32, WorkloadError> {
        Ok(self.typed::<u32>(key, "u32")?.unwrap_or(default))
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, WorkloadError> {
        Ok(self.typed::<u64>(key, "u64")?.unwrap_or(default))
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, WorkloadError> {
        Ok(self.typed::<usize>(key, "usize")?.unwrap_or(default))
    }

    pub fn get_i64(&self, key: &str, default: i64) -> Result<i64, WorkloadError> {
        Ok(self.typed::<i64>(key, "i64")?.unwrap_or(default))
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool, WorkloadError> {
        Ok(self.typed::<bool>(key, "true|false")?.unwrap_or(default))
    }
}

/// Declared type of one workload parameter (for validation and the
/// `workloads` listings).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    U32,
    U64,
    Usize,
    I64,
    Bool,
    /// Free-form text; plugins validate the accepted values themselves.
    Str,
}

impl ParamKind {
    pub fn label(&self) -> &'static str {
        match self {
            ParamKind::U32 => "u32",
            ParamKind::U64 => "u64",
            ParamKind::Usize => "usize",
            ParamKind::I64 => "i64",
            ParamKind::Bool => "bool",
            ParamKind::Str => "str",
        }
    }

    /// Parse `v` to its magnitude for range checking (`None` = type
    /// error; [`ParamKind::Bool`] and [`ParamKind::Str`] have no
    /// magnitude and return 0).
    fn magnitude(&self, v: &str) -> Option<u64> {
        match self {
            ParamKind::U32 => v.parse::<u32>().ok().map(u64::from),
            ParamKind::U64 => v.parse::<u64>().ok(),
            ParamKind::Usize => v.parse::<usize>().ok().map(|x| x as u64),
            ParamKind::I64 => v.parse::<i64>().ok().map(i64::unsigned_abs),
            ParamKind::Bool => v.parse::<bool>().ok().map(|_| 0),
            ParamKind::Str => Some(0),
        }
    }
}

/// Schema entry for one parameter a workload accepts. Numeric kinds
/// carry a magnitude range enforced at submit time — the wire is open
/// to any client, so a plugin must bound what a single request can ask
/// for (`msort(n=u64::MAX)` must die at validation, not as an OOM on a
/// runner thread). Default range: unbounded.
#[derive(Debug, Clone, Copy)]
pub struct ParamSpec {
    pub name: &'static str,
    pub kind: ParamKind,
    /// Human-readable default (may describe a config-derived value).
    pub default: &'static str,
    pub help: &'static str,
    /// Smallest accepted magnitude (for [`ParamKind::I64`]: of the
    /// absolute value).
    pub min: u64,
    /// Largest accepted magnitude.
    pub max: u64,
}

impl ParamSpec {
    pub const fn new(
        name: &'static str,
        kind: ParamKind,
        default: &'static str,
        help: &'static str,
    ) -> ParamSpec {
        ParamSpec { name, kind, default, help, min: 0, max: u64::MAX }
    }

    /// Restrict the accepted magnitude range (inclusive).
    pub const fn with_range(mut self, min: u64, max: u64) -> ParamSpec {
        self.min = min;
        self.max = max;
        self
    }

    /// Compact rendering for listings: `name:kind=default`, plus the
    /// accepted range when bounded.
    pub fn render(&self) -> String {
        let mut out = format!("{}:{}={}", self.name, self.kind.label(), self.default);
        if self.min != 0 || self.max != u64::MAX {
            out.push_str(&format!(" in {}..={}", self.min, self.max));
        }
        out
    }
}

/// Check that every provided parameter is declared in `specs`, parses
/// under its declared kind, and falls inside its declared range. The
/// standard implementation behind [`StreamWorkload::validate`].
pub fn validate_params(specs: &[ParamSpec], params: &Params) -> Result<(), WorkloadError> {
    for (key, value) in params.iter() {
        let Some(spec) = specs.iter().find(|s| s.name == key) else {
            let known = specs.iter().map(|s| s.name).collect::<Vec<_>>().join(", ");
            let known = if known.is_empty() { "none".to_string() } else { known };
            return Err(WorkloadError::new(format!(
                "unknown parameter: {key} (accepted: {known})"
            )));
        };
        let Some(magnitude) = spec.kind.magnitude(value) else {
            return Err(WorkloadError::new(format!(
                "bad value for param {key}: {value:?} (want {})",
                spec.kind.label()
            )));
        };
        if magnitude < spec.min || magnitude > spec.max {
            return Err(WorkloadError::new(format!(
                "out of range for param {key}: {value} (want magnitude in {}..={})",
                spec.min, spec.max
            )));
        }
    }
    Ok(())
}

/// What a plugin may draw from the execution slot running it. The
/// coordinator's `Shard` implements this (warm pools, shared cost
/// caches); [`LocalResources`] is the standalone implementation for
/// plugin unit tests and out-of-coordinator runs.
pub trait ExecResources: Send + Sync {
    /// A (warm, reusable) executor pool of `parallelism` workers.
    fn executor(&self, parallelism: usize) -> Executor;

    /// The memoized adaptive-chunking probe cost for `key` (created
    /// empty on first request).
    fn cost_cache(&self, key: &str) -> CostCache;
}

/// Self-contained [`ExecResources`]: pools and cost caches private to
/// this instance. For plugin tests and direct harness use; inside the
/// coordinator the shard's shared pools are used instead.
pub struct LocalResources {
    stack_size: usize,
    pools: Mutex<BTreeMap<usize, Executor>>,
    costs: Mutex<BTreeMap<String, CostCache>>,
}

impl LocalResources {
    pub fn new() -> LocalResources {
        LocalResources::with_stack(64 << 20)
    }

    pub fn with_stack(stack_size: usize) -> LocalResources {
        LocalResources {
            stack_size,
            pools: Mutex::new(BTreeMap::new()),
            costs: Mutex::new(BTreeMap::new()),
        }
    }
}

impl Default for LocalResources {
    fn default() -> Self {
        LocalResources::new()
    }
}

impl ExecResources for LocalResources {
    fn executor(&self, parallelism: usize) -> Executor {
        let parallelism = parallelism.max(1);
        self.pools
            .lock()
            .unwrap()
            .entry(parallelism)
            .or_insert_with(|| {
                let mut cfg = ExecutorConfig::with_parallelism(parallelism);
                cfg.stack_size = self.stack_size;
                Executor::with_config(cfg)
            })
            .clone()
    }

    fn cost_cache(&self, key: &str) -> CostCache {
        self.costs.lock().unwrap().entry(key.to_string()).or_default().clone()
    }
}

/// One stream-expressed computation body, generic over the suspension
/// strategy — the unit [`WorkloadCtx::run_mode`] dispatches. (A trait
/// rather than a closure because Rust closures cannot be generic over a
/// type parameter.)
pub trait EvalBody {
    type Out;
    fn run<E: Eval>(self, eval: E) -> Self::Out;
}

/// Everything a plugin's `run`/`verify` may use: configured sizes, the
/// chunking policy, the block backends, and the executing slot's
/// resources. Built per job by the coordinator; buildable by hand (with
/// [`LocalResources`]) everywhere else.
pub struct WorkloadCtx<'a> {
    pub sizes: &'a Sizes,
    pub chunk_policy: ChunkPolicy,
    /// Block multiplier chunked polynomial workloads use (PJRT kernel
    /// when artifacts are loaded, pure-Rust otherwise).
    pub multiplier: Arc<dyn BlockMultiplier>,
    /// Block siever chunked sieve workloads use.
    pub siever: Arc<dyn BlockSiever>,
    res: &'a dyn ExecResources,
    /// Cooperative-cancellation token for this job (never trips outside
    /// the coordinator unless a caller wires one in).
    cancel: CancelToken,
    /// Zero-based delivery attempt (> 0 on coordinator retries).
    attempt: u32,
}

impl<'a> WorkloadCtx<'a> {
    pub fn new(
        sizes: &'a Sizes,
        chunk_policy: ChunkPolicy,
        multiplier: Arc<dyn BlockMultiplier>,
        siever: Arc<dyn BlockSiever>,
        res: &'a dyn ExecResources,
    ) -> WorkloadCtx<'a> {
        WorkloadCtx {
            sizes,
            chunk_policy,
            multiplier,
            siever,
            res,
            cancel: CancelToken::new(),
            attempt: 0,
        }
    }

    /// Attach the cancellation token the deadline reaper may trip.
    pub fn with_cancel(mut self, cancel: CancelToken) -> WorkloadCtx<'a> {
        self.cancel = cancel;
        self
    }

    /// Record which delivery attempt this execution is (0 = first).
    pub fn with_attempt(mut self, attempt: u32) -> WorkloadCtx<'a> {
        self.attempt = attempt;
        self
    }

    /// This job's cancellation token. Long chunked bodies should call
    /// [`CancelToken::checkpoint`] between chunks; the coordinator also
    /// installs the token as the ambient
    /// [`CancelScope`](crate::susp::CancelScope), so stream traversal
    /// loops poll it without plugin code changes.
    pub fn cancel(&self) -> &CancelToken {
        &self.cancel
    }

    /// Zero-based delivery attempt (> 0 when the coordinator re-leased
    /// the job after a transient failure).
    pub fn attempt(&self) -> u32 {
        self.attempt
    }

    /// A warm executor pool of `parallelism` workers from the executing
    /// slot.
    pub fn executor(&self, parallelism: usize) -> Executor {
        self.res.executor(parallelism.max(1))
    }

    /// The slot's memoized chunk-probe cost for `key` (plugins usually
    /// pass their [`StreamWorkload::cost_key`]).
    pub fn cost_cache(&self, key: &str) -> CostCache {
        self.res.cost_cache(key)
    }

    /// The paper's substitution as a library call: run one generic
    /// stream body under the strategy `mode` selects — `Lazy` for
    /// `seq`, `Strict` for the control, `Future` on a warm `k`-worker
    /// pool for `par(k)`.
    pub fn run_mode<B: EvalBody>(&self, mode: Mode, body: B) -> B::Out {
        match mode {
            Mode::Seq => body.run(LazyEval),
            Mode::Strict => body.run(StrictEval),
            Mode::Par(k) => body.run(FutureEval::new(self.executor(k))),
        }
    }
}

/// An algorithm expressible as a Stream computation, packaged for the
/// coordinator. Implementations are registered in a
/// [`WorkloadRegistry`](super::WorkloadRegistry) and dispatched by name
/// — the coordinator carries no per-workload code.
///
/// Contract:
/// * `run` must be deterministic for a given `(params, sizes)` across
///   modes — `seq`, `strict`, and every `par(k)` return the same
///   [`ResultDetail`] (the conformance suite enforces this).
/// * `verify` must check against an *independent* oracle (a different
///   algorithm, not a re-run).
/// * Param handling must go through the declared schema: `validate` is
///   called at submit time, before a request occupies queue capacity.
pub trait StreamWorkload: Send + Sync + 'static {
    /// Registry key and affinity-hash input (`primes`, `fib`, …).
    fn name(&self) -> &str;

    /// One-line description for `sfut workloads` / the serve verb.
    fn describe(&self) -> &str;

    /// Declared parameter schema (empty = no parameters accepted).
    fn params(&self) -> Vec<ParamSpec>;

    /// Execute under `mode` and summarize. Runs on a shard runner
    /// thread (big stack); panics are caught and reported by the
    /// coordinator.
    fn run(
        &self,
        ctx: &WorkloadCtx<'_>,
        mode: Mode,
        params: &Params,
    ) -> Result<ResultDetail, WorkloadError>;

    /// Check `detail` against an independent oracle for the same
    /// `params`.
    fn verify(&self, ctx: &WorkloadCtx<'_>, params: &Params, detail: &ResultDetail) -> bool;

    /// Which backend served this workload's block computations
    /// (reported as `backend=` on the result line; `"-"` when none).
    fn backend(&self, _ctx: &WorkloadCtx<'_>, _params: &Params) -> String {
        "-".to_string()
    }

    /// Chunk-cost hook: the [`CostCache`] slot key this workload's
    /// adaptive chunking memoizes under. Defaults to the workload name;
    /// override to share or split probe costs across registrations.
    fn cost_key(&self, _params: &Params) -> String {
        self.name().to_string()
    }

    /// Schema-check `params` (called at submit time). The default
    /// enforces declared-and-typed via [`validate_params`].
    fn validate(&self, params: &Params) -> Result<(), WorkloadError> {
        validate_params(&self.params(), params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_parse_render_roundtrip() {
        let p = Params::parse("n=100, big_factor=7,chunked=true").unwrap();
        assert_eq!(p.get("n"), Some("100"));
        assert_eq!(p.len(), 3);
        assert_eq!(p.render(), "big_factor=7,chunked=true,n=100");
        assert_eq!(Params::parse(&p.render()).unwrap(), p);
        assert!(Params::parse("").unwrap().is_empty());
        assert!(Params::parse("  ").unwrap().is_empty());
    }

    #[test]
    fn params_parse_reports_precise_errors() {
        let e = Params::parse("n").unwrap_err();
        assert!(e.message.contains("want key=value"), "{e}");
        let e = Params::parse("n=").unwrap_err();
        assert!(e.message.contains("empty key or value"), "{e}");
        let e = Params::parse("=5").unwrap_err();
        assert!(e.message.contains("empty key or value"), "{e}");
        let e = Params::parse("n=1,n=2").unwrap_err();
        assert!(e.message.contains("duplicate parameter"), "{e}");
    }

    #[test]
    fn typed_getters_default_and_validate() {
        let p = Params::parse("n=12,neg=-3,flag=true").unwrap();
        assert_eq!(p.get_u32("n", 5).unwrap(), 12);
        assert_eq!(p.get_u32("missing", 5).unwrap(), 5);
        assert_eq!(p.get_i64("neg", 0).unwrap(), -3);
        assert!(p.get_bool("flag", false).unwrap());
        assert!(p.get_u32("neg", 0).is_err());
        let bad = Params::parse("n=many").unwrap();
        let e = bad.get_u32("n", 0).unwrap_err();
        assert!(e.message.contains("bad value for param n"), "{e}");
    }

    #[test]
    fn schema_validation_rejects_unknown_and_mistyped() {
        let specs = [
            ParamSpec::new("n", ParamKind::U32, "20000", "bound"),
            ParamSpec::new("chunked", ParamKind::Bool, "false", "use blocks"),
        ];
        validate_params(&specs, &Params::parse("n=7,chunked=true").unwrap()).unwrap();
        let e = validate_params(&specs, &Params::parse("frobnicate=1").unwrap()).unwrap_err();
        assert!(e.message.contains("unknown parameter"), "{e}");
        assert!(e.message.contains("n, chunked"), "{e}");
        let e = validate_params(&specs, &Params::parse("n=nope").unwrap()).unwrap_err();
        assert!(e.message.contains("want u32"), "{e}");
    }

    #[test]
    fn schema_validation_enforces_ranges() {
        let specs = [
            ParamSpec::new("n", ParamKind::U32, "100", "bound").with_range(1, 1000),
            ParamSpec::new("factor", ParamKind::I64, "0", "scale").with_range(0, 1000),
        ];
        validate_params(&specs, &Params::parse("n=1000").unwrap()).unwrap();
        validate_params(&specs, &Params::parse("n=1,factor=-1000").unwrap()).unwrap();
        let e = validate_params(&specs, &Params::parse("n=1001").unwrap()).unwrap_err();
        assert!(e.message.contains("out of range for param n"), "{e}");
        assert!(e.message.contains("1..=1000"), "{e}");
        let e = validate_params(&specs, &Params::parse("n=0").unwrap()).unwrap_err();
        assert!(e.message.contains("out of range"), "{e}");
        // I64 ranges bound the magnitude.
        let e = validate_params(&specs, &Params::parse("factor=-1001").unwrap()).unwrap_err();
        assert!(e.message.contains("out of range for param factor"), "{e}");
    }

    #[test]
    fn param_spec_renders_compactly() {
        let s = ParamSpec::new("n", ParamKind::U32, "20000", "bound");
        assert_eq!(s.render(), "n:u32=20000");
        let s = ParamSpec::new("n", ParamKind::U32, "20000", "bound").with_range(1, 50);
        assert_eq!(s.render(), "n:u32=20000 in 1..=50");
    }

    #[test]
    fn params_remove_strips_reserved_keys() {
        let mut p = Params::parse("deadline_ms=250,n=7").unwrap();
        assert_eq!(p.remove("deadline_ms").as_deref(), Some("250"));
        assert_eq!(p.remove("deadline_ms"), None);
        assert_eq!(p.render(), "n=7");
    }

    #[test]
    fn str_params_validate_as_text() {
        let specs = [ParamSpec::new("fail_mode", ParamKind::Str, "panic", "fault kind")];
        validate_params(&specs, &Params::parse("fail_mode=stall").unwrap()).unwrap();
        // Any text passes the kind check; semantic checks are the
        // plugin's job.
        validate_params(&specs, &Params::parse("fail_mode=whatever").unwrap()).unwrap();
        assert_eq!(specs[0].render(), "fail_mode:str=panic");
    }

    #[test]
    fn ctx_carries_cancel_token_and_attempt() {
        let res = LocalResources::new();
        let sizes = Sizes::from_config(&crate::config::Config::default());
        let ctx = WorkloadCtx::new(
            &sizes,
            ChunkPolicy::Adaptive,
            Arc::new(crate::poly::RustMultiplier),
            Arc::new(crate::sieve::RustSiever),
            &res,
        );
        assert_eq!(ctx.attempt(), 0);
        assert!(!ctx.cancel().is_cancelled());
        let token = CancelToken::new();
        let ctx = ctx.with_cancel(token.clone()).with_attempt(2);
        assert_eq!(ctx.attempt(), 2);
        token.cancel();
        assert!(ctx.cancel().is_cancelled());
    }

    #[test]
    fn local_resources_reuse_pools_and_caches() {
        let res = LocalResources::new();
        let a = res.executor(2);
        a.spawn(|| {});
        a.wait_idle();
        // Same parallelism → same pool (counters persist).
        let b = res.executor(2);
        assert_eq!(b.stats().tasks_executed, 1);
        // Cost caches are shared per key.
        res.cost_cache("w").get_or_measure(|| std::time::Duration::from_micros(5));
        assert_eq!(
            res.cost_cache("w").get(),
            Some(std::time::Duration::from_micros(5))
        );
        assert_eq!(res.cost_cache("other").get(), None);
    }
}

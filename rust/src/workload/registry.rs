//! [`WorkloadRegistry`] — the open set of workloads a coordinator
//! serves.
//!
//! The registry is the replacement for the old closed `Workload` enum's
//! `ALL`/`parse` world: the coordinator resolves requests by name
//! against whatever was registered, so the set of scenarios grows by
//! *registration*, never by editing dispatch code. `builtin()` is the
//! default population: the paper's nine Table-1 scenarios (three plugin
//! families parameterized by [`Params`](super::Params)) plus the two
//! post-enum workloads that shipped through this API alone
//! ([`workload::extra`](super::extra)).

use std::collections::BTreeMap;
use std::sync::Arc;

use super::api::{StreamWorkload, WorkloadError};

/// Name → plugin map with stable (sorted) iteration order.
pub struct WorkloadRegistry {
    map: BTreeMap<String, Arc<dyn StreamWorkload>>,
}

impl WorkloadRegistry {
    /// An empty registry (for fully custom populations).
    pub fn empty() -> WorkloadRegistry {
        WorkloadRegistry { map: BTreeMap::new() }
    }

    /// The default population: the paper's nine scenarios plus the
    /// `fib` and `msort` extensions.
    pub fn builtin() -> WorkloadRegistry {
        let mut reg = WorkloadRegistry::empty();
        super::builtin::register_paper_workloads(&mut reg)
            .expect("builtin workload names are unique");
        super::extra::register_extra_workloads(&mut reg)
            .expect("extra workload names are unique");
        reg
    }

    /// Register a plugin under its [`StreamWorkload::name`]. Duplicate
    /// names are an error — silent shadowing would make `verify`
    /// results ambiguous.
    pub fn register(&mut self, workload: Arc<dyn StreamWorkload>) -> Result<(), WorkloadError> {
        let name = workload.name().to_string();
        if name.is_empty() || name.contains(|c: char| c.is_whitespace() || "():,=".contains(c)) {
            return Err(WorkloadError::new(format!(
                "invalid workload name {name:?}: must be non-empty and free of \
                 whitespace/()/:/,/="
            )));
        }
        if self.map.contains_key(&name) {
            return Err(WorkloadError::new(format!("workload already registered: {name}")));
        }
        self.map.insert(name, workload);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&Arc<dyn StreamWorkload>> {
        self.map.get(name)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.map.keys().cloned().collect()
    }

    /// Registered plugins in name order.
    pub fn iter(&self) -> impl Iterator<Item = &Arc<dyn StreamWorkload>> {
        self.map.values()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

impl Default for WorkloadRegistry {
    fn default() -> Self {
        WorkloadRegistry::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::workload::{Params, ResultDetail, WorkloadCtx};

    struct Dummy(&'static str);

    impl StreamWorkload for Dummy {
        fn name(&self) -> &str {
            self.0
        }

        fn describe(&self) -> &str {
            "dummy"
        }

        fn params(&self) -> Vec<crate::workload::ParamSpec> {
            Vec::new()
        }

        fn run(
            &self,
            _ctx: &WorkloadCtx<'_>,
            _mode: Mode,
            _params: &Params,
        ) -> Result<ResultDetail, WorkloadError> {
            Ok(ResultDetail::Scalar { value: "0".into() })
        }

        fn verify(&self, _: &WorkloadCtx<'_>, _: &Params, _: &ResultDetail) -> bool {
            true
        }
    }

    #[test]
    fn builtin_registers_paper_and_extra_workloads() {
        let reg = WorkloadRegistry::builtin();
        for name in [
            "primes",
            "primes_x3",
            "primes_chunked",
            "stream",
            "stream_big",
            "list",
            "list_big",
            "chunked",
            "chunked_big",
            "fib",
            "msort",
        ] {
            assert!(reg.contains(name), "missing builtin workload {name}");
        }
        assert_eq!(reg.len(), 11);
        // Sorted, stable listing.
        let names = reg.names();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn duplicate_and_invalid_names_are_rejected() {
        let mut reg = WorkloadRegistry::empty();
        reg.register(Arc::new(Dummy("ok"))).unwrap();
        let e = reg.register(Arc::new(Dummy("ok"))).unwrap_err();
        assert!(e.message.contains("already registered"), "{e}");
        for bad in ["", "has space", "par(2)", "a:b", "a,b", "a=b"] {
            assert!(reg.register(Arc::new(Dummy(bad))).is_err(), "name {bad:?} must be rejected");
        }
        assert_eq!(reg.len(), 1);
    }
}

//! Deterministic fault-injection workload (`faulty`), compiled only
//! under the `chaos` feature.
//!
//! The chaos harness needs a plugin whose failures are *scripted*: the
//! `chaos_lifecycle` integration suite injects a known number of faults
//! and then reconciles wire output against the lifecycle counters
//! exactly. Randomized faults cannot be reconciled that way, so every
//! knob here is a parameter and the schedule is a pure function of
//! `(fail_mode, fail_nth, attempt)`:
//!
//! * `fail_mode=panic` — `panic!` inside `run` (exercises the
//!   coordinator's `catch_unwind` isolation and the retry path).
//! * `fail_mode=stall` — spin on 1 ms sleeps, polling the job's
//!   [`CancelToken`](crate::susp::CancelToken) checkpoint, until the
//!   deadline reaper trips it (exercises timeouts) or `stall_ms`
//!   elapses (the test misconfigured its deadline — succeed rather
//!   than hang the suite).
//! * `fail_mode=wrong_result` — return a value the oracle rejects
//!   (exercises `verified=false` reporting; *not* a transient fault,
//!   so it must not trigger retries).
//! * `fail_mode=none` — always succeed (control group).
//!
//! A fault fires while `attempt < fail_nth`: `fail_nth=1` with
//! `retry_max>=1` means "fail the first delivery, succeed on retry" —
//! the canonical retry-recovers scenario. `fail_nth=0` never fails.
//!
//! This plugin is **not** part of the default registry; chaos tests
//! register it explicitly via [`register_chaos_workloads`].

use std::sync::Arc;

use crate::config::Mode;

use super::api::{
    ParamKind, ParamSpec, Params, ResultDetail, StreamWorkload, WorkloadCtx, WorkloadError,
};
use super::registry::WorkloadRegistry;

/// Register the `faulty` plugin into `reg` (chaos builds only).
pub fn register_chaos_workloads(reg: &mut WorkloadRegistry) -> Result<(), WorkloadError> {
    reg.register(Arc::new(FaultyWorkload))?;
    Ok(())
}

const FAIL_MODES: [&str; 4] = ["panic", "stall", "wrong_result", "none"];

/// Scripted-failure workload: see the module docs for the schedule.
pub struct FaultyWorkload;

impl FaultyWorkload {
    fn expected_value(seed: u64) -> String {
        seed.to_string()
    }
}

impl StreamWorkload for FaultyWorkload {
    fn name(&self) -> &str {
        "faulty"
    }

    fn describe(&self) -> &str {
        "deterministic fault injection: scripted panics, stalls, and wrong results"
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec::new("fail_mode", ParamKind::Str, "panic", "panic|stall|wrong_result|none"),
            ParamSpec::new(
                "fail_nth",
                ParamKind::U32,
                "1",
                "fault fires while attempt < fail_nth (0 = never)",
            )
            .with_range(0, 64),
            ParamSpec::new("seed", ParamKind::U64, "0", "labels the job; success value = seed"),
            ParamSpec::new(
                "stall_ms",
                ParamKind::U64,
                "30000",
                "stall mode gives up (succeeds) after this long",
            )
            .with_range(0, 600_000),
        ]
    }

    fn validate(&self, params: &Params) -> Result<(), WorkloadError> {
        super::api::validate_params(&self.params(), params)?;
        let mode = params.get("fail_mode").unwrap_or("panic");
        if !FAIL_MODES.contains(&mode) {
            return Err(WorkloadError::new(format!(
                "bad value for param fail_mode: {mode:?} (want one of {})",
                FAIL_MODES.join("|")
            )));
        }
        Ok(())
    }

    fn run(
        &self,
        ctx: &WorkloadCtx<'_>,
        _mode: Mode,
        params: &Params,
    ) -> Result<ResultDetail, WorkloadError> {
        let fail_mode = params.get("fail_mode").unwrap_or("panic");
        let fail_nth = params.get_u32("fail_nth", 1)?;
        let seed = params.get_u64("seed", 0)?;
        let stall_ms = params.get_u64("stall_ms", 30_000)?;
        let attempt = ctx.attempt();
        if attempt < fail_nth {
            match fail_mode {
                "panic" => panic!("injected panic (attempt {attempt} seed {seed})"),
                "stall" => {
                    // Stay cancellable: the deadline reaper trips the
                    // token and the checkpoint unwinds as a timeout.
                    // The stall_ms cap keeps a misconfigured test from
                    // hanging forever.
                    for _ in 0..stall_ms {
                        ctx.cancel().checkpoint();
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                }
                "wrong_result" => {
                    return Ok(ResultDetail::Scalar {
                        value: Self::expected_value(seed.wrapping_add(1)),
                    });
                }
                _ => {}
            }
        }
        Ok(ResultDetail::Scalar { value: Self::expected_value(seed) })
    }

    fn verify(&self, _ctx: &WorkloadCtx<'_>, params: &Params, detail: &ResultDetail) -> bool {
        let Ok(seed) = params.get_u64("seed", 0) else {
            return false;
        };
        matches!(detail, ResultDetail::Scalar { value } if *value == Self::expected_value(seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChunkPolicy, Config};
    use crate::poly::RustMultiplier;
    use crate::sieve::RustSiever;
    use crate::susp::CancelToken;
    use crate::workload::api::LocalResources;
    use crate::workload::Sizes;

    fn with_ctx<R>(f: impl FnOnce(WorkloadCtx<'_>) -> R) -> R {
        let res = LocalResources::new();
        let sizes = Sizes::from_config(&Config::default());
        f(WorkloadCtx::new(
            &sizes,
            ChunkPolicy::Adaptive,
            Arc::new(RustMultiplier),
            Arc::new(RustSiever),
            &res,
        ))
    }

    #[test]
    fn schedule_is_a_pure_function_of_attempt() {
        let w = FaultyWorkload;
        let params = Params::parse("fail_mode=panic,fail_nth=1,seed=9").unwrap();
        // Attempt 0 panics…
        let panicked = with_ctx(|ctx| {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                w.run(&ctx, Mode::Seq, &params)
            }))
            .is_err()
        });
        assert!(panicked);
        // …attempt 1 (the retry) succeeds with the seed value.
        with_ctx(|ctx| {
            let ctx = ctx.with_attempt(1);
            let detail = w.run(&ctx, Mode::Seq, &params).unwrap();
            assert!(w.verify(&ctx, &params, &detail));
            assert_eq!(detail, ResultDetail::Scalar { value: "9".into() });
        });
    }

    #[test]
    fn wrong_result_fails_verification_without_panicking() {
        let w = FaultyWorkload;
        let params = Params::parse("fail_mode=wrong_result,seed=4").unwrap();
        with_ctx(|ctx| {
            let detail = w.run(&ctx, Mode::Seq, &params).unwrap();
            assert!(!w.verify(&ctx, &params, &detail));
        });
    }

    #[test]
    fn stall_unwinds_as_cancelled_when_token_trips() {
        let w = FaultyWorkload;
        let params = Params::parse("fail_mode=stall,stall_ms=60000").unwrap();
        let token = CancelToken::new();
        token.cancel();
        let payload = with_ctx(|ctx| {
            let ctx = ctx.with_cancel(token.clone());
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                w.run(&ctx, Mode::Seq, &params)
            }))
            .unwrap_err()
        });
        assert!(crate::susp::cancel::was_cancelled(&*payload));
    }

    #[test]
    fn validate_rejects_unknown_fail_modes_and_params() {
        let w = FaultyWorkload;
        w.validate(&Params::parse("fail_mode=none,fail_nth=0").unwrap()).unwrap();
        let e = w.validate(&Params::parse("fail_mode=explode").unwrap()).unwrap_err();
        assert!(e.message.contains("bad value for param fail_mode"), "{e}");
        assert!(w.validate(&Params::parse("boom=1").unwrap()).is_err());
    }
}

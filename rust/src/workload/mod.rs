//! Workloads: the open plugin surface plus the paper's generators.
//!
//! This module owns the coordinator-facing workload API:
//!
//! * [`StreamWorkload`] / [`WorkloadCtx`] / [`Params`] ([`api`]) — the
//!   plugin trait, execution context, and parameter machinery;
//! * [`WorkloadRegistry`] ([`registry`]) — the open name → plugin map
//!   the coordinator dispatches through;
//! * [`builtin`] — the paper's nine Table-1 scenarios as three plugin
//!   families (sieve, stream-multiply, list baseline);
//! * [`extra`] — workloads added through the public API alone (`fib`,
//!   `msort`), proving the coordinator needs no edits for new
//!   scenarios;
//! * `faulty` (behind the `chaos` feature) — the deterministic
//!   fault-injection plugin the chaos lifecycle suite drives. Never in
//!   the default registry.
//!
//! It also keeps the shared generators: the polynomial test case is
//! Fateman's sparse-multiplication benchmark [2] — take
//! `p = (1 + x + y + z + t)^k`, compute `p · (p + 1)`; the `_big`
//! variants scale every coefficient by 100000000001 "in order to
//! increase the footprint of elementary operations".

pub mod api;
pub mod builtin;
pub mod extra;
#[cfg(feature = "chaos")]
pub mod faulty;
pub mod registry;

pub use api::{
    poly_detail, validate_params, EvalBody, ExecResources, LocalResources, ParamKind, ParamSpec,
    Params, ResultDetail, StreamWorkload, WorkloadCtx, WorkloadError,
};
pub use builtin::{ListMulWorkload, PolyMulWorkload, SieveWorkload};
pub use extra::{FibWorkload, MergeSortWorkload};
#[cfg(feature = "chaos")]
pub use faulty::{register_chaos_workloads, FaultyWorkload};
pub use registry::WorkloadRegistry;

use crate::bigint::BigInt;
use crate::config::Config;
use crate::poly::Polynomial;

/// The Fateman pair `(p, p+1)` over `vars` variables at degree `k`,
/// with `i64` coefficients.
pub fn fateman_pair(vars: usize, k: u32) -> (Polynomial<i64>, Polynomial<i64>) {
    let mut base = Polynomial::one(vars);
    for i in 0..vars {
        base = base.add(&Polynomial::var(vars, i));
    }
    let p = base.pow(k);
    let q = p.add(&Polynomial::one(vars));
    (p, q)
}

/// The `_big` variant: coefficients lifted to [`BigInt`] and scaled by
/// `factor` (the paper's 100000000001).
pub fn fateman_pair_big(
    vars: usize,
    k: u32,
    factor: i64,
) -> (Polynomial<BigInt>, Polynomial<BigInt>) {
    let (p, q) = fateman_pair(vars, k);
    let f = BigInt::from(factor);
    (
        p.map_coeffs(|c| &BigInt::from(*c) * &f),
        q.map_coeffs(|c| &BigInt::from(*c) * &f),
    )
}

/// Workload sizes derived from a [`Config`] (applies `scale`). The
/// per-plugin *defaults* — every field can be overridden per request
/// through [`Params`].
pub struct Sizes {
    pub primes_n: u32,
    pub fateman_vars: usize,
    pub fateman_degree: u32,
    pub big_factor: i64,
    pub chunk_size: usize,
    /// Default Fibonacci-stream length for the `fib` workload.
    pub fib_n: u32,
    /// Default element count for the `msort` workload.
    pub msort_n: usize,
}

impl Sizes {
    pub fn from_config(cfg: &Config) -> Sizes {
        Sizes {
            primes_n: cfg.scaled_primes_n(),
            fateman_vars: cfg.fateman_vars,
            fateman_degree: cfg.scaled_fateman_degree(),
            big_factor: cfg.big_factor,
            chunk_size: cfg.chunk_size,
            fib_n: ((512.0 * cfg.scale) as u32).max(8),
            msort_n: ((4096.0 * cfg.scale) as usize).max(16),
        }
    }
}

/// Expected number of terms of `(1 + Σ xᵢ)^k` over `v` variables:
/// `C(k + v, v)`.
pub fn fateman_terms(vars: usize, k: u32) -> u64 {
    let v = vars as u64;
    let k = k as u64;
    // C(k+v, v) with small v: multiply carefully.
    let mut num = 1u64;
    for i in 1..=v {
        num = num * (k + i) / i;
    }
    num
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fateman_term_counts() {
        // (1+x+y+z)^2 = C(5,3) = 10 terms.
        let (p, _) = fateman_pair(3, 2);
        assert_eq!(p.num_terms() as u64, fateman_terms(3, 2));
        // Paper-adjacent scale: 4 vars, degree 12 → C(16,4) = 1820.
        assert_eq!(fateman_terms(4, 12), 1820);
        // Fateman's original: 3 vars, degree 20 → C(23,3) = 1771.
        assert_eq!(fateman_terms(3, 20), 1771);
    }

    #[test]
    fn fateman_pair_properties() {
        let (p, q) = fateman_pair(4, 3);
        assert_eq!(p.num_terms() as u64, fateman_terms(4, 3));
        // q = p + 1: constant coefficient differs by one.
        assert_eq!(q.sub(&p), Polynomial::one(4));
        // Leading coefficient of (1+Σx)^k is 1 (pure power term).
        assert_eq!(p.leading().unwrap().1, 1);
    }

    #[test]
    fn big_variant_scales_coefficients() {
        let (p, _) = fateman_pair(3, 2);
        let (pb, qb) = fateman_pair_big(3, 2, 100_000_000_001);
        assert_eq!(pb.num_terms(), p.num_terms());
        let f = BigInt::from(100_000_000_001i64);
        // Constant term of p is 1 → becomes the factor itself.
        let konst = pb
            .terms()
            .iter()
            .find(|(m, _)| m.is_one())
            .map(|(_, c)| c.clone())
            .unwrap();
        assert_eq!(konst, f);
        assert!(!qb.is_zero());
    }

    #[test]
    fn product_term_count_matches_formula() {
        // p·(p+1) has the terms of p^2 plus those of p: same support as
        // (1+Σx)^(2k) since supp(p) ⊂ supp(p²).
        let (p, q) = fateman_pair(3, 3);
        let prod = p.mul(&q);
        assert_eq!(prod.num_terms() as u64, fateman_terms(3, 6));
    }

    #[test]
    fn sizes_apply_scale() {
        let mut cfg = Config::default();
        cfg.scale = 0.25;
        let s = Sizes::from_config(&cfg);
        assert_eq!(s.primes_n, 5000);
        assert!(s.fateman_degree < cfg.fateman_degree);
        assert_eq!(s.fib_n, 128);
        assert_eq!(s.msort_n, 1024);
        // Tiny scales floor out instead of degenerating to zero.
        cfg.scale = 0.001;
        let s = Sizes::from_config(&cfg);
        assert_eq!(s.fib_n, 8);
        assert_eq!(s.msort_n, 16);
    }
}

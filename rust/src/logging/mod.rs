//! Logging: a minimal, dependency-light `log` backend.
//!
//! Level comes from `SFUT_LOG` (`error|warn|info|debug|trace`, default
//! `warn`); output is stderr with elapsed-time stamps and thread names,
//! so pipeline traces read like:
//!
//! ```text
//! [   0.013s INFO  sfut-xla-engine] compiled poly_outer_64x64
//! [   0.471s DEBUG sfut-driver-stream.par(2)] job finished in 0.45s
//! ```

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger {
    start: Instant,
    level: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record<'_>) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let thread = std::thread::current();
        eprintln!(
            "[{t:>8.3}s {:<5} {}] {}",
            record.level(),
            thread.name().unwrap_or("?"),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger (idempotent). Reads `SFUT_LOG` for the level.
pub fn init() {
    let level = match std::env::var("SFUT_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Warn,
    };
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now(), level });
    // Err means a logger is already set (tests, double init) — fine.
    let _ = log::set_logger(logger);
    log::set_max_level(LevelFilter::Trace.min(level.to_level_filter()));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        log::info!("logging smoke test (visible only with SFUT_LOG=info)");
    }
}

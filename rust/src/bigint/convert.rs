//! Conversions between [`BigInt`] and native integers / strings.

use super::{BigInt, Sign};

impl From<u64> for BigInt {
    fn from(v: u64) -> Self {
        BigInt {
            sign: if v == 0 { Sign::Zero } else { Sign::Positive },
            limbs: u128_limbs(v as u128),
        }
    }
}

impl From<u128> for BigInt {
    fn from(v: u128) -> Self {
        BigInt {
            sign: if v == 0 { Sign::Zero } else { Sign::Positive },
            limbs: u128_limbs(v),
        }
    }
}

impl From<i64> for BigInt {
    fn from(v: i64) -> Self {
        BigInt::from(v as i128)
    }
}

impl From<i32> for BigInt {
    fn from(v: i32) -> Self {
        BigInt::from(v as i128)
    }
}

impl From<i128> for BigInt {
    fn from(v: i128) -> Self {
        let sign = match v {
            0 => Sign::Zero,
            _ if v < 0 => Sign::Negative,
            _ => Sign::Positive,
        };
        BigInt { sign, limbs: u128_limbs(v.unsigned_abs()) }
    }
}

fn u128_limbs(mut v: u128) -> Vec<u32> {
    let mut limbs = Vec::new();
    while v != 0 {
        limbs.push(v as u32);
        v >>= 32;
    }
    limbs
}

impl BigInt {
    /// Lossy conversion to `i128`; `None` when out of range.
    pub fn to_i128(&self) -> Option<i128> {
        if self.limbs.len() > 4 {
            return None;
        }
        let mut mag: u128 = 0;
        for (i, &l) in self.limbs.iter().enumerate() {
            mag |= (l as u128) << (32 * i);
        }
        match self.sign {
            Sign::Zero => Some(0),
            Sign::Positive => (mag <= i128::MAX as u128).then_some(mag as i128),
            Sign::Negative => {
                (mag <= i128::MAX as u128 + 1).then(|| (mag as i128).wrapping_neg())
            }
        }
    }

    /// Approximate conversion to `f64` (used by the PJRT kernel bridge
    /// for small-coefficient blocks; exactness is checked by the caller).
    pub fn to_f64(&self) -> f64 {
        let mut mag = 0f64;
        for &l in self.limbs.iter().rev() {
            mag = mag * 4294967296.0 + l as f64;
        }
        match self.sign {
            Sign::Negative => -mag,
            _ => mag,
        }
    }
}

/// Error parsing a decimal string into [`BigInt`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseBigIntError(pub String);

impl std::fmt::Display for ParseBigIntError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid BigInt literal: {}", self.0)
    }
}

impl std::error::Error for ParseBigIntError {}

impl std::str::FromStr for BigInt {
    type Err = ParseBigIntError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let (neg, digits) = match s.strip_prefix('-') {
            Some(rest) => (true, rest),
            None => (false, s.strip_prefix('+').unwrap_or(s)),
        };
        if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
            return Err(ParseBigIntError(s.to_string()));
        }
        // Horner over chunks of 9 decimal digits (10^9 < 2^32).
        let mut acc = BigInt::zero();
        let chunk_mul = BigInt::from(1_000_000_000u64);
        let bytes = digits.as_bytes();
        let mut i = 0;
        // First (short) chunk.
        let first = bytes.len() % 9;
        if first > 0 {
            let v: u64 = digits[..first].parse().unwrap();
            acc = BigInt::from(v);
            i = first;
        }
        while i < bytes.len() {
            let v: u64 = digits[i..i + 9].parse().unwrap();
            acc = &acc * &chunk_mul + BigInt::from(v);
            i += 9;
        }
        if neg && !acc.is_zero() {
            acc = acc.neg();
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_native_roundtrips() {
        for v in [0i128, 1, -1, i64::MAX as i128, i64::MIN as i128, i128::MAX, i128::MIN] {
            assert_eq!(BigInt::from(v).to_i128(), Some(v), "{v}");
        }
    }

    #[test]
    fn out_of_range_to_i128_is_none() {
        let too_big = &BigInt::from(i128::MAX) * &BigInt::from(2i64);
        assert_eq!(too_big.to_i128(), None);
    }

    #[test]
    fn parse_and_print_roundtrip() {
        for s in ["0", "1", "-1", "100000000001", "-987654321098765432109876543210"] {
            let v: BigInt = s.parse().unwrap();
            assert_eq!(v.to_string(), s, "{s}");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        for s in ["", "-", "+", "12a", " 1", "1 ", "--2"] {
            assert!(s.parse::<BigInt>().is_err(), "{s:?}");
        }
    }

    #[test]
    fn parse_accepts_plus_and_minus_zero() {
        assert_eq!("+7".parse::<BigInt>().unwrap(), BigInt::from(7i64));
        assert_eq!("-0".parse::<BigInt>().unwrap(), BigInt::zero());
    }

    #[test]
    fn to_f64_is_close_for_moderate_values() {
        let v: BigInt = "100000000001".parse().unwrap();
        assert_eq!(v.to_f64(), 100000000001.0);
        let neg = BigInt::from(-12345i64);
        assert_eq!(neg.to_f64(), -12345.0);
    }
}

//! Full division and gcd for [`BigInt`] — required by the exact rational
//! field ([`crate::rational`]) that the Gröbner application runs on
//! (floating-point Buchberger is numerically unstable: cancellation
//! residues become spurious basis elements).

use std::cmp::Ordering;

use super::arith::{mag_cmp, mag_sub};
use super::{BigInt, Sign};

impl BigInt {
    /// Truncated division: returns `(q, r)` with `self = q·other + r`,
    /// `|r| < |other|`, and `r` carrying the sign of `self` (like Rust's
    /// `/` and `%` on integers). Panics on division by zero.
    pub fn divmod(&self, other: &BigInt) -> (BigInt, BigInt) {
        assert!(!other.is_zero(), "BigInt division by zero");
        if self.is_zero() {
            return (BigInt::zero(), BigInt::zero());
        }
        let (qm, rm) = mag_divmod(&self.limbs, &other.limbs);
        let q_sign = if self.sign == other.sign { Sign::Positive } else { Sign::Negative };
        let q = BigInt { sign: q_sign, limbs: qm }.normalize();
        let r = BigInt { sign: self.sign, limbs: rm }.normalize();
        (q, r)
    }

    /// Quotient of truncated division.
    pub fn div(&self, other: &BigInt) -> BigInt {
        self.divmod(other).0
    }

    /// Remainder of truncated division.
    pub fn rem(&self, other: &BigInt) -> BigInt {
        self.divmod(other).1
    }

    /// Exact division: panics if `other` does not divide `self`.
    pub fn div_exact(&self, other: &BigInt) -> BigInt {
        let (q, r) = self.divmod(other);
        assert!(r.is_zero(), "div_exact: {other} does not divide {self}");
        q
    }

    /// Greatest common divisor (always non-negative; `gcd(0,0) = 0`).
    pub fn gcd(&self, other: &BigInt) -> BigInt {
        let mut a = self.abs();
        let mut b = other.abs();
        while !b.is_zero() {
            let r = a.rem(&b);
            a = b;
            b = r.abs();
        }
        a
    }
}

/// Magnitude division, little-endian u32 limbs: schoolbook long division
/// with a 64-bit trial quotient per output limb (Knuth D, simplified via
/// the shift-and-subtract refinement loop).
fn mag_divmod(a: &[u32], b: &[u32]) -> (Vec<u32>, Vec<u32>) {
    match mag_cmp(a, b) {
        Ordering::Less => return (Vec::new(), a.to_vec()),
        Ordering::Equal => return (vec![1], Vec::new()),
        Ordering::Greater => {}
    }
    if b.len() == 1 {
        let (q, r) = super::arith::mag_divmod_small(a, b[0]);
        return (q, if r == 0 { Vec::new() } else { vec![r] });
    }

    // Long division producing one u32 quotient limb per step, msb-first.
    // rem holds the running remainder (always < b after each step).
    let mut quotient = vec![0u32; a.len()];
    let mut rem: Vec<u32> = Vec::new();
    for i in (0..a.len()).rev() {
        // rem = rem << 32 | a[i]
        rem.insert(0, a[i]);
        while rem.last() == Some(&0) {
            rem.pop();
        }
        if mag_cmp(&rem, b) == Ordering::Less {
            continue;
        }
        // Binary-search the quotient limb: the largest q with q·b ≤ rem.
        // (32 fixed iterations beats Knuth-style trial+refine here and
        // cannot degenerate on unnormalized divisors.)
        let mut lo = 1u64; // rem >= b, so q >= 1
        let mut hi = u32::MAX as u64;
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if mag_cmp(&mag_mul_small(b, mid as u32), &rem) == Ordering::Greater {
                hi = mid - 1;
            } else {
                lo = mid;
            }
        }
        let q = lo as u32;
        let prod = mag_mul_small(b, q);
        rem = trim(mag_sub(&rem, &prod));
        quotient[i] = q;
    }
    (trim(quotient), rem)
}

fn mag_mul_small(b: &[u32], q: u32) -> Vec<u32> {
    if q == 0 {
        return Vec::new();
    }
    let mut out = Vec::with_capacity(b.len() + 1);
    let mut carry = 0u64;
    for &limb in b {
        let t = limb as u64 * q as u64 + carry;
        out.push(t as u32);
        carry = t >> 32;
    }
    if carry != 0 {
        out.push(carry as u32);
    }
    trim(out)
}

fn trim(mut v: Vec<u32>) -> Vec<u32> {
    while v.last() == Some(&0) {
        v.pop();
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::{runner, Gen};

    fn big(s: &str) -> BigInt {
        s.parse().unwrap()
    }

    #[test]
    fn small_divisions() {
        let (q, r) = BigInt::from(17i64).divmod(&BigInt::from(5i64));
        assert_eq!((q, r), (BigInt::from(3i64), BigInt::from(2i64)));
        let (q, r) = BigInt::from(-17i64).divmod(&BigInt::from(5i64));
        assert_eq!((q, r), (BigInt::from(-3i64), BigInt::from(-2i64)));
        let (q, r) = BigInt::from(17i64).divmod(&BigInt::from(-5i64));
        assert_eq!((q, r), (BigInt::from(-3i64), BigInt::from(2i64)));
        let (q, r) = BigInt::from(-17i64).divmod(&BigInt::from(-5i64));
        assert_eq!((q, r), (BigInt::from(3i64), BigInt::from(-2i64)));
    }

    #[test]
    #[should_panic(expected = "division by zero")]
    fn divide_by_zero_panics() {
        let _ = BigInt::from(1i64).divmod(&BigInt::zero());
    }

    #[test]
    fn multi_limb_division() {
        let a = big("340282366920938463463374607431768211456"); // 2^128
        let b = big("18446744073709551616"); // 2^64
        let (q, r) = a.divmod(&b);
        assert_eq!(q, b);
        assert!(r.is_zero());
        // Non-exact case.
        let (q, r) = big("1000000000000000000000000000000000000007")
            .divmod(&big("1000000000000000000003"));
        assert_eq!(&q * &big("1000000000000000000003") + &r,
                   big("1000000000000000000000000000000000000007"));
    }

    #[test]
    fn prop_divmod_identity_i128() {
        let mut r = runner(1500);
        r.run(|g: &mut Gen| {
            let a = g.i64_any() as i128;
            let mut b = g.i64_any() as i128;
            if b == 0 {
                b = 7;
            }
            let (q, rem) = BigInt::from(a).divmod(&BigInt::from(b));
            assert_eq!(q, BigInt::from(a / b), "{a}/{b}");
            assert_eq!(rem, BigInt::from(a % b), "{a}%{b}");
        });
    }

    #[test]
    fn prop_divmod_identity_multilimb() {
        let mut r = runner(300);
        r.run(|g: &mut Gen| {
            // Random big a (up to 8 limbs), smaller b (up to 4 limbs).
            let a = BigInt {
                sign: Sign::Positive,
                limbs: g.vec(1..9, |g| g.u32_any()),
            }
            .normalize();
            let b = BigInt {
                sign: Sign::Positive,
                limbs: g.vec(1..5, |g| g.u32_any()),
            }
            .normalize();
            if b.is_zero() {
                return;
            }
            let (q, rem) = a.divmod(&b);
            assert_eq!(&(&q * &b) + &rem, a, "identity a={a} b={b}");
            assert!(rem.abs() < b.abs(), "remainder bound a={a} b={b}");
        });
    }

    #[test]
    fn gcd_basics() {
        assert_eq!(BigInt::from(12i64).gcd(&BigInt::from(18i64)), BigInt::from(6i64));
        assert_eq!(BigInt::from(-12i64).gcd(&BigInt::from(18i64)), BigInt::from(6i64));
        assert_eq!(BigInt::from(7i64).gcd(&BigInt::zero()), BigInt::from(7i64));
        assert_eq!(BigInt::zero().gcd(&BigInt::zero()), BigInt::zero());
        // Big coprime pair.
        let a = big("100000000001"); // 11 × 909090909... actually 100000000001 = 11·9090909091
        let b = big("99999999999");
        let g = a.gcd(&b);
        assert_eq!(a.rem(&g), BigInt::zero());
        assert_eq!(b.rem(&g), BigInt::zero());
    }

    #[test]
    fn div_exact_roundtrip() {
        let a = big("123456789123456789123456789");
        let b = big("987654321987654321");
        let prod = &a * &b;
        assert_eq!(prod.div_exact(&a), b);
        assert_eq!(prod.div_exact(&b), a);
    }

    #[test]
    #[should_panic(expected = "div_exact")]
    fn div_exact_rejects_inexact() {
        let _ = BigInt::from(10i64).div_exact(&BigInt::from(3i64));
    }
}

//! Magnitude arithmetic and operator impls for [`BigInt`].

use std::cmp::Ordering;
use std::ops::{Add, Mul, Neg, Sub};

use super::{BigInt, Sign};

/// Operand size (in limbs) above which multiplication switches from
/// schoolbook to Karatsuba. Chosen by the §Perf sweep in
/// `benches/ablation_overhead.rs`; 32 limbs ≈ 1024 bits.
pub const KARATSUBA_THRESHOLD: usize = 32;

// ---------------------------------------------------------------------
// magnitude primitives (little-endian u32 slices)
// ---------------------------------------------------------------------

pub(crate) fn mag_cmp(a: &[u32], b: &[u32]) -> Ordering {
    if a.len() != b.len() {
        return a.len().cmp(&b.len());
    }
    for (x, y) in a.iter().rev().zip(b.iter().rev()) {
        match x.cmp(y) {
            Ordering::Equal => continue,
            ord => return ord,
        }
    }
    Ordering::Equal
}

pub(crate) fn mag_add(a: &[u32], b: &[u32]) -> Vec<u32> {
    let (long, short) = if a.len() >= b.len() { (a, b) } else { (b, a) };
    let mut out = Vec::with_capacity(long.len() + 1);
    let mut carry = 0u64;
    for i in 0..long.len() {
        let s = long[i] as u64 + *short.get(i).unwrap_or(&0) as u64 + carry;
        out.push(s as u32);
        carry = s >> 32;
    }
    if carry != 0 {
        out.push(carry as u32);
    }
    out
}

/// `a - b`; requires `a >= b` (caller compares magnitudes first).
pub(crate) fn mag_sub(a: &[u32], b: &[u32]) -> Vec<u32> {
    debug_assert!(mag_cmp(a, b) != Ordering::Less, "mag_sub underflow");
    let mut out = Vec::with_capacity(a.len());
    let mut borrow = 0i64;
    for i in 0..a.len() {
        let d = a[i] as i64 - *b.get(i).unwrap_or(&0) as i64 - borrow;
        if d < 0 {
            out.push((d + (1i64 << 32)) as u32);
            borrow = 1;
        } else {
            out.push(d as u32);
            borrow = 0;
        }
    }
    debug_assert_eq!(borrow, 0);
    out
}

/// Schoolbook O(n·m) product.
fn mag_mul_school(a: &[u32], b: &[u32]) -> Vec<u32> {
    if a.is_empty() || b.is_empty() {
        return Vec::new();
    }
    let mut out = vec![0u32; a.len() + b.len()];
    for (i, &ai) in a.iter().enumerate() {
        if ai == 0 {
            continue;
        }
        let mut carry = 0u64;
        let ai = ai as u64;
        for (j, &bj) in b.iter().enumerate() {
            let t = ai * bj as u64 + out[i + j] as u64 + carry;
            out[i + j] = t as u32;
            carry = t >> 32;
        }
        let mut k = i + b.len();
        while carry != 0 {
            let t = out[k] as u64 + carry;
            out[k] = t as u32;
            carry = t >> 32;
            k += 1;
        }
    }
    out
}

/// Karatsuba product: T(n) = 3·T(n/2) + O(n).
fn mag_mul_karatsuba(a: &[u32], b: &[u32]) -> Vec<u32> {
    let n = a.len().min(b.len());
    if n < KARATSUBA_THRESHOLD {
        return mag_mul_school(a, b);
    }
    let half = a.len().max(b.len()) / 2;
    let (a_lo, a_hi) = split(a, half);
    let (b_lo, b_hi) = split(b, half);

    let z0 = mag_mul_karatsuba(a_lo, b_lo);
    let z2 = mag_mul_karatsuba(a_hi, b_hi);
    let a_sum = mag_add(a_lo, a_hi);
    let b_sum = mag_add(b_lo, b_hi);
    let z1_full = mag_mul_karatsuba(&a_sum, &b_sum);
    // z1 = z1_full - z0 - z2  (non-negative by construction)
    let z1 = mag_sub(&trim(z1_full), &trim(mag_add(&z0, &z2)));

    // out = z0 + z1 << (32*half) + z2 << (64*half)
    let mut out = z0;
    add_shifted(&mut out, &z1, half);
    add_shifted(&mut out, &z2, 2 * half);
    out
}

fn split(x: &[u32], at: usize) -> (&[u32], &[u32]) {
    if x.len() <= at {
        (x, &[])
    } else {
        x.split_at(at)
    }
}

fn trim(mut v: Vec<u32>) -> Vec<u32> {
    while v.last() == Some(&0) {
        v.pop();
    }
    v
}

/// `acc += x << (32*shift)` in place.
fn add_shifted(acc: &mut Vec<u32>, x: &[u32], shift: usize) {
    if acc.len() < shift + x.len() + 1 {
        acc.resize(shift + x.len() + 1, 0);
    }
    let mut carry = 0u64;
    for (i, &xi) in x.iter().enumerate() {
        let t = acc[shift + i] as u64 + xi as u64 + carry;
        acc[shift + i] = t as u32;
        carry = t >> 32;
    }
    let mut k = shift + x.len();
    while carry != 0 {
        let t = acc[k] as u64 + carry;
        acc[k] = t as u32;
        carry = t >> 32;
        k += 1;
    }
}

pub(crate) fn mag_mul(a: &[u32], b: &[u32]) -> Vec<u32> {
    if a.len().min(b.len()) >= KARATSUBA_THRESHOLD {
        mag_mul_karatsuba(a, b)
    } else {
        mag_mul_school(a, b)
    }
}

/// Divide magnitude by a single small divisor; returns (quotient, rem).
pub(crate) fn mag_divmod_small(a: &[u32], d: u32) -> (Vec<u32>, u32) {
    assert!(d != 0, "division by zero");
    let mut out = vec![0u32; a.len()];
    let mut rem = 0u64;
    for i in (0..a.len()).rev() {
        let cur = (rem << 32) | a[i] as u64;
        out[i] = (cur / d as u64) as u32;
        rem = cur % d as u64;
    }
    (out, rem as u32)
}

// ---------------------------------------------------------------------
// signed operations
// ---------------------------------------------------------------------

fn add_signed(a: &BigInt, b: &BigInt) -> BigInt {
    match (a.sign, b.sign) {
        (Sign::Zero, _) => b.clone(),
        (_, Sign::Zero) => a.clone(),
        (sa, sb) if sa == sb => {
            BigInt { sign: sa, limbs: mag_add(&a.limbs, &b.limbs) }.normalize()
        }
        (sa, _) => match mag_cmp(&a.limbs, &b.limbs) {
            Ordering::Equal => BigInt::zero(),
            Ordering::Greater => {
                BigInt { sign: sa, limbs: mag_sub(&a.limbs, &b.limbs) }.normalize()
            }
            Ordering::Less => BigInt {
                sign: if sa == Sign::Positive { Sign::Negative } else { Sign::Positive },
                limbs: mag_sub(&b.limbs, &a.limbs),
            }
            .normalize(),
        },
    }
}

fn mul_signed(a: &BigInt, b: &BigInt) -> BigInt {
    if a.is_zero() || b.is_zero() {
        return BigInt::zero();
    }
    let sign = if a.sign == b.sign { Sign::Positive } else { Sign::Negative };
    BigInt { sign, limbs: mag_mul(&a.limbs, &b.limbs) }.normalize()
}

impl PartialOrd for BigInt {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for BigInt {
    fn cmp(&self, other: &Self) -> Ordering {
        let rank = |s: Sign| match s {
            Sign::Negative => 0,
            Sign::Zero => 1,
            Sign::Positive => 2,
        };
        match rank(self.sign).cmp(&rank(other.sign)) {
            Ordering::Equal => {}
            ord => return ord,
        }
        match self.sign {
            Sign::Zero => Ordering::Equal,
            Sign::Positive => mag_cmp(&self.limbs, &other.limbs),
            Sign::Negative => mag_cmp(&other.limbs, &self.limbs),
        }
    }
}

macro_rules! forward_binop {
    ($trait:ident, $method:ident, $f:ident) => {
        impl $trait for &BigInt {
            type Output = BigInt;
            fn $method(self, rhs: &BigInt) -> BigInt {
                $f(self, rhs)
            }
        }
        impl $trait for BigInt {
            type Output = BigInt;
            fn $method(self, rhs: BigInt) -> BigInt {
                $f(&self, &rhs)
            }
        }
        impl $trait<&BigInt> for BigInt {
            type Output = BigInt;
            fn $method(self, rhs: &BigInt) -> BigInt {
                $f(&self, rhs)
            }
        }
        impl $trait<BigInt> for &BigInt {
            type Output = BigInt;
            fn $method(self, rhs: BigInt) -> BigInt {
                $f(self, &rhs)
            }
        }
    };
}

fn sub_signed(a: &BigInt, b: &BigInt) -> BigInt {
    add_signed(a, &b.neg())
}

forward_binop!(Add, add, add_signed);
forward_binop!(Sub, sub, sub_signed);
forward_binop!(Mul, mul, mul_signed);

impl Neg for BigInt {
    type Output = BigInt;
    fn neg(self) -> BigInt {
        BigInt::neg(&self)
    }
}

impl Neg for &BigInt {
    type Output = BigInt;
    fn neg(self) -> BigInt {
        BigInt::neg(self)
    }
}

impl std::hash::Hash for BigInt {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        std::mem::discriminant(&self.sign).hash(state);
        self.limbs.hash(state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn big(v: i128) -> BigInt {
        BigInt::from(v)
    }

    #[test]
    fn mag_add_carries_across_limbs() {
        assert_eq!(mag_add(&[u32::MAX], &[1]), vec![0, 1]);
        assert_eq!(mag_add(&[u32::MAX, u32::MAX], &[1]), vec![0, 0, 1]);
    }

    #[test]
    fn mag_sub_borrows() {
        // mag_sub may leave trailing zero limbs; callers normalize.
        assert_eq!(trim(mag_sub(&[0, 1], &[1])), vec![u32::MAX]);
    }

    #[test]
    fn schoolbook_known_product() {
        // (2^32 - 1)^2 = 2^64 - 2^33 + 1
        let p = mag_mul_school(&[u32::MAX], &[u32::MAX]);
        assert_eq!(p, vec![1, u32::MAX - 1]);
    }

    #[test]
    fn karatsuba_matches_schoolbook() {
        // Deterministic pseudo-random limbs, sizes straddling the
        // threshold (including asymmetric operands).
        let mut seed = 0x9E3779B97F4A7C15u64;
        let mut next = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            seed as u32
        };
        for (na, nb) in [(40, 40), (64, 64), (100, 3), (3, 100), (65, 33), (128, 96)] {
            let a: Vec<u32> = (0..na).map(|_| next()).collect();
            let b: Vec<u32> = (0..nb).map(|_| next()).collect();
            let k = trim(mag_mul_karatsuba(&a, &b));
            let s = trim(mag_mul_school(&a, &b));
            assert_eq!(k, s, "sizes {na}x{nb}");
        }
    }

    #[test]
    fn divmod_small_roundtrip() {
        let x = big(123456789012345678901234567890);
        let (q, r) = mag_divmod_small(&x.limbs, 7);
        let q = BigInt { sign: Sign::Positive, limbs: q }.normalize();
        assert_eq!(&q * &big(7) + big(r as i128), x);
    }

    #[test]
    fn signed_cmp_total_order() {
        let vals = [big(-10), big(-1), big(0), big(1), big(10), big(1i128 << 90)];
        for (i, a) in vals.iter().enumerate() {
            for (j, b) in vals.iter().enumerate() {
                assert_eq!(a.cmp(b), i.cmp(&j), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn big_coefficient_workload_shape() {
        // The paper's stream_big factor.
        let f = big(100000000001);
        let mut acc = BigInt::one();
        for _ in 0..20 {
            acc = &acc * &f;
        }
        // 100000000001^20 has exactly 221 decimal digits.
        assert_eq!(acc.to_string().len(), 221);
        assert!(acc.limb_len() > 20);
    }
}

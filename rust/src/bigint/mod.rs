//! Arbitrary-precision signed integers.
//!
//! Substrate for the paper's `stream_big` / `list_big` workloads, whose
//! whole point is coefficients too large for machine words (the paper
//! scales Fateman's coefficients by 100000000001 so that each elementary
//! multiply-add has a footprint big enough to amortize task overhead).
//! Scala gets `BigInt` from the JVM; nothing equivalent is available
//! offline, so it is built here: sign-magnitude representation over `u32`
//! limbs (little-endian), schoolbook + Karatsuba multiplication, and long
//! division sufficient for decimal printing and divisibility tests.

mod arith;
mod convert;
mod display;
mod divide;

pub use arith::KARATSUBA_THRESHOLD;

/// Sign of a [`BigInt`]. Zero is always `Sign::Zero` with empty limbs —
/// a canonical-form invariant checked by `debug_assert_canonical`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sign {
    Negative,
    Zero,
    Positive,
}

/// Arbitrary-precision signed integer, sign-magnitude over little-endian
/// `u32` limbs.
///
/// Invariants (canonical form):
/// * no trailing zero limb (the most significant limb is nonzero);
/// * `sign == Sign::Zero` iff `limbs.is_empty()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BigInt {
    pub(crate) sign: Sign,
    /// Little-endian magnitude.
    pub(crate) limbs: Vec<u32>,
}

impl BigInt {
    pub const fn zero() -> Self {
        BigInt { sign: Sign::Zero, limbs: Vec::new() }
    }

    pub fn one() -> Self {
        BigInt::from(1i64)
    }

    pub fn is_zero(&self) -> bool {
        self.sign == Sign::Zero
    }

    pub fn is_negative(&self) -> bool {
        self.sign == Sign::Negative
    }

    pub fn is_positive(&self) -> bool {
        self.sign == Sign::Positive
    }

    /// Number of limbs in the magnitude (0 for zero). Proxy for the
    /// "footprint of elementary operations" knob the paper turns.
    pub fn limb_len(&self) -> usize {
        self.limbs.len()
    }

    /// Number of significant bits in the magnitude (0 for zero).
    pub fn bit_len(&self) -> usize {
        match self.limbs.last() {
            None => 0,
            Some(&top) => (self.limbs.len() - 1) * 32 + (32 - top.leading_zeros() as usize),
        }
    }

    pub fn abs(&self) -> BigInt {
        BigInt {
            sign: if self.is_zero() { Sign::Zero } else { Sign::Positive },
            limbs: self.limbs.clone(),
        }
    }

    pub fn neg(&self) -> BigInt {
        BigInt {
            sign: match self.sign {
                Sign::Negative => Sign::Positive,
                Sign::Zero => Sign::Zero,
                Sign::Positive => Sign::Negative,
            },
            limbs: self.limbs.clone(),
        }
    }

    /// Restore canonical form after limb surgery.
    pub(crate) fn normalize(mut self) -> Self {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
        if self.limbs.is_empty() {
            self.sign = Sign::Zero;
        } else if self.sign == Sign::Zero {
            self.sign = Sign::Positive;
        }
        self
    }

    /// Canonical-form check (used by property tests).
    pub fn is_canonical(&self) -> bool {
        self.limbs.last() != Some(&0) && (self.limbs.is_empty() == (self.sign == Sign::Zero))
    }
}

impl Default for BigInt {
    fn default() -> Self {
        BigInt::zero()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::{runner, Gen};

    fn big(v: i128) -> BigInt {
        BigInt::from(v)
    }

    #[test]
    fn zero_is_canonical() {
        let z = BigInt::zero();
        assert!(z.is_zero());
        assert_eq!(z.limb_len(), 0);
        assert_eq!(z.bit_len(), 0);
        assert_eq!(big(5) + big(-5), z);
    }

    #[test]
    fn bit_len_matches_known_values() {
        assert_eq!(big(1).bit_len(), 1);
        assert_eq!(big(255).bit_len(), 8);
        assert_eq!(big(256).bit_len(), 9);
        assert_eq!(big(1i128 << 100).bit_len(), 101);
    }

    #[test]
    fn abs_neg_roundtrip() {
        let v = big(-42);
        assert_eq!(v.abs(), big(42));
        assert_eq!(v.neg(), big(42));
        assert_eq!(v.neg().neg(), v);
        assert_eq!(BigInt::zero().neg(), BigInt::zero());
    }

    #[test]
    fn prop_i64_arith_agrees_with_i128() {
        // Property: BigInt arithmetic agrees with native i128 on values
        // that fit — covers add/sub/mul sign combinations exhaustively
        // under random sampling.
        let mut r = runner(2000);
        r.run(|g: &mut Gen| {
            let a = g.i64_any() as i128;
            let b = g.i64_any() as i128;
            let (ba, bb) = (BigInt::from(a), BigInt::from(b));
            assert_eq!(&ba + &bb, BigInt::from(a + b), "add {a} {b}");
            assert_eq!(&ba - &bb, BigInt::from(a - b), "sub {a} {b}");
            assert_eq!(&ba * &bb, BigInt::from(a * b), "mul {a} {b}");
            assert_eq!(ba.cmp(&bb), a.cmp(&b), "cmp {a} {b}");
        });
    }

    #[test]
    fn prop_ring_axioms() {
        let mut r = runner(500);
        r.run(|g: &mut Gen| {
            let a = BigInt::from(g.i64_any());
            let b = BigInt::from(g.i64_any());
            let c = BigInt::from(g.i64_any());
            // commutativity
            assert_eq!(&a + &b, &b + &a);
            assert_eq!(&a * &b, &b * &a);
            // associativity
            assert_eq!((&a + &b) + &c, &a + &(&b + &c));
            assert_eq!((&a * &b) * &c, &a * &(&b * &c));
            // distributivity
            assert_eq!(&a * &(&b + &c), &(&a * &b) + &(&a * &c));
            // identities
            assert_eq!(&a + &BigInt::zero(), a);
            assert_eq!(&a * &BigInt::one(), a);
            assert_eq!(&a * &BigInt::zero(), BigInt::zero());
        });
    }
}

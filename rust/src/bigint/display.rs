//! Decimal formatting for [`BigInt`].

use super::arith::mag_divmod_small;
use super::{BigInt, Sign};

impl std::fmt::Display for BigInt {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_zero() {
            return f.write_str("0");
        }
        // Repeated division by 10^9 produces 9-digit chunks, least
        // significant first.
        let mut chunks: Vec<u32> = Vec::new();
        let mut mag = self.limbs.clone();
        while !mag.is_empty() {
            let (q, r) = mag_divmod_small(&mag, 1_000_000_000);
            chunks.push(r);
            mag = q;
            while mag.last() == Some(&0) {
                mag.pop();
            }
        }
        let mut s = String::new();
        if self.sign == Sign::Negative {
            s.push('-');
        }
        let mut iter = chunks.iter().rev();
        if let Some(first) = iter.next() {
            s.push_str(&first.to_string());
        }
        for chunk in iter {
            s.push_str(&format!("{chunk:09}"));
        }
        f.write_str(&s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_zero() {
        assert_eq!(BigInt::zero().to_string(), "0");
    }

    #[test]
    fn displays_with_inner_zero_padding() {
        // 2^64 = 18446744073709551616: middle chunks must be zero-padded.
        let v = BigInt::from(1u128 << 64);
        assert_eq!(v.to_string(), "18446744073709551616");
    }

    #[test]
    fn displays_negative() {
        assert_eq!(BigInt::from(-100000000001i64).to_string(), "-100000000001");
    }

    #[test]
    fn matches_i128_display_on_range() {
        for v in [-1_000_000_007i128, -1, 0, 7, 999_999_999, 1_000_000_000, i128::MAX] {
            assert_eq!(BigInt::from(v).to_string(), v.to_string());
        }
    }
}

//! Deterministic property-testing harness (offline `proptest` stand-in).
//!
//! Usage:
//! ```
//! use stream_future::testkit::prop::{runner, Gen};
//! let mut r = runner(200);
//! r.run(|g: &mut Gen| {
//!     let x = g.i64_in(-100..=100);
//!     assert_eq!(x + 0, x);
//! });
//! ```
//!
//! Failures print the case seed; re-run a single counterexample with
//! `SFUT_PROP_SEED=<seed> cargo test <name>`.

use std::ops::{Range, RangeInclusive};

/// SplitMix64-seeded xoshiro-style generator. Plenty for test data; not
/// for cryptography.
pub struct Gen {
    state: u64,
}

impl Gen {
    pub fn from_seed(seed: u64) -> Self {
        // SplitMix64 scramble so consecutive seeds decorrelate.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        Gen { state: (z ^ (z >> 31)) | 1 }
    }

    pub fn u64_any(&mut self) -> u64 {
        // xorshift64*
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    pub fn u32_any(&mut self) -> u32 {
        (self.u64_any() >> 32) as u32
    }

    pub fn i64_any(&mut self) -> i64 {
        self.u64_any() as i64
    }

    pub fn bool(&mut self) -> bool {
        self.u64_any() & 1 == 1
    }

    /// Uniform in `[0, n)`; `n > 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Multiply-shift; bias negligible for test purposes.
        ((self.u64_any() as u128 * n as u128) >> 64) as u64
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(!r.is_empty());
        r.start + self.below((r.end - r.start) as u64) as usize
    }

    pub fn i64_in(&mut self, r: RangeInclusive<i64>) -> i64 {
        let (lo, hi) = (*r.start(), *r.end());
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u128;
        let off = (self.u64_any() as u128 * span) >> 64;
        (lo as i128 + off as i128) as i64
    }

    pub fn u32_in(&mut self, r: Range<u32>) -> u32 {
        assert!(!r.is_empty());
        r.start + self.below((r.end - r.start) as u64) as u32
    }

    /// A vector whose length is drawn from `len` and whose elements come
    /// from `f`.
    pub fn vec<T>(&mut self, len: Range<usize>, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize_in(len);
        (0..n).map(|_| f(self)).collect()
    }

    /// Pick one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0..xs.len())]
    }
}

/// Property runner: executes the property for `cases` independent seeds.
pub struct Runner {
    cases: u64,
    base_seed: u64,
}

/// Construct a [`Runner`]. Honors `SFUT_PROP_SEED` (run exactly that one
/// case) and `SFUT_PROP_CASES` (override the case count).
pub fn runner(cases: u64) -> Runner {
    let cases = std::env::var("SFUT_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(cases);
    Runner { cases, base_seed: 0xC0FFEE }
}

impl Runner {
    pub fn run<F: FnMut(&mut Gen)>(&mut self, mut property: F) {
        if let Ok(seed) = std::env::var("SFUT_PROP_SEED") {
            let seed: u64 = seed.parse().expect("SFUT_PROP_SEED must be a u64");
            let mut g = Gen::from_seed(seed);
            property(&mut g);
            return;
        }
        for case in 0..self.cases {
            let seed = self.base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
            let mut g = Gen::from_seed(seed);
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                property(&mut g);
            }));
            if let Err(p) = outcome {
                eprintln!(
                    "property failed at case {case} (re-run with SFUT_PROP_SEED={seed})"
                );
                std::panic::resume_unwind(p);
            }
        }
    }
}

//! Loom-lite: an in-tree deterministic interleaving explorer for the
//! lock-free core.
//!
//! The executor's correctness rests on hand-rolled atomics — the
//! Chase–Lev ring with epoch-style buffer retirement
//! ([`crate::exec::ChaseLevDeque`]) and the `Fut` state machine
//! ([`crate::susp::Fut`]). Stress tests explore a vanishing fraction of
//! their interleavings; this module explores them *systematically*, the
//! way `loom` would, without the (unvendorable) dependency.
//!
//! # How it works
//!
//! The shim types [`ModelAtomicU64`], [`ModelAtomicUsize`],
//! [`ModelMutex`] and [`model_fence`] compile straight to
//! `std::sync::atomic` normally. Under the `model` cargo feature every
//! load/store/CAS/fence becomes a *yield point*: logical threads run
//! co-operatively, one at a time, and a virtual scheduler
//! ([`sched`]) decides who performs the next atomic operation. A
//! complete run is therefore described exactly by its decision trace,
//! and the explorer enumerates traces two ways:
//!
//! * **bounded-depth DFS with a preemption bound**
//!   ([`explore_dfs`]) — systematic enumeration of every schedule
//!   whose involuntary context switches stay under the bound (the
//!   classic result: almost all concurrency bugs need ≤ 2
//!   preemptions);
//! * **seeded random schedules** ([`explore_random`]) — a SplitMix64
//!   stream of schedules for bulk coverage, each one replayable from
//!   its 64-bit seed alone.
//!
//! A failing run prints `SFUT_MODEL_SEED=<seed>` (the idiom of
//! [`crate::testkit::prop`]); [`replay_seed`] re-runs exactly that
//! interleaving, and `SFUT_MODEL_SEED` in the environment pins an
//! entire exploration to one schedule for debugging.
//!
//! # What is modeled
//!
//! [`deque`] ports the Chase–Lev algorithm — including grow-under-steal
//! (buffer retirement becomes an assertable `freed` flag, so a
//! use-after-free is a *deterministic assertion*, not a crash that
//! depends on the allocator) and the wrapping-`u64` `top`/`bottom`
//! indices — onto the shims with `u64` payloads standing in for boxed
//! jobs. [`fut`] ports the EMPTY → RUNNING → READY/PANICKED machine
//! with the promise drop-guard; the production callback mutex becomes
//! per-waiter atomic slots so exactly-once delivery is a checkable
//! CAS-win, which is the same obligation the mutex+recheck protocol
//! discharges. [`racy`] holds deliberately broken fixtures (publication
//! in the wrong order, a load/store counter) that the suite uses to
//! prove the checker *finds* bugs and that seeds replay byte-identically.
//!
//! Limitations, stated plainly: exploration is over *interleavings* of
//! sequentially-consistent atomic steps (loom's default strategy too).
//! Memory-order parameters are accepted and forwarded so the ports read
//! like the production code, but weak-memory reorderings are out of
//! scope — those are what the Miri/TSan CI steps are for.
//!
//! # Usage
//!
//! ```text
//! cargo test --features model --test model_check
//! SFUT_MODEL_SEED=0x1234 cargo test --features model --test model_check -- replays
//! ```

pub mod atomic;
pub mod deque;
pub mod fut;
pub mod racy;
#[cfg(feature = "model")]
pub(crate) mod sched;

pub use atomic::{model_fence, ModelAtomicU64, ModelAtomicUsize, ModelMutex};

/// One logical thread of a modeled scenario.
pub type LogicalThread = Box<dyn FnOnce() + Send + 'static>;

/// One fresh instance of a modeled scenario: the logical threads to
/// interleave, plus an optional post-run check that the controller
/// runs after every thread has finished (joins synchronize, so it sees
/// all effects). Whole-run invariants — "every pushed job was claimed
/// exactly once" — live in the check; a panic there is a [`Failure`]
/// with the run's trace, replayable like any other.
pub struct Scenario {
    pub threads: Vec<LogicalThread>,
    pub check: Option<Box<dyn FnOnce() + Send + 'static>>,
}

impl Scenario {
    pub fn new(threads: Vec<LogicalThread>) -> Self {
        Scenario { threads, check: None }
    }

    pub fn with_check(
        threads: Vec<LogicalThread>,
        check: impl FnOnce() + Send + 'static,
    ) -> Self {
        Scenario { threads, check: Some(Box::new(check)) }
    }
}

/// What one exploration produced.
#[derive(Debug, Clone)]
pub struct Report {
    /// Schedules actually run.
    pub schedules: usize,
    /// Distinct decision traces among them (DFS runs are distinct by
    /// construction; random runs are deduplicated by trace hash).
    pub distinct: usize,
    /// First failing schedule, if any (exploration stops on it).
    pub failure: Option<Failure>,
}

/// A failing schedule, replayable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Failure {
    /// Seed that regenerates the schedule (random mode; DFS failures
    /// carry the trace only).
    pub seed: Option<u64>,
    /// The decision trace: which logical thread performed each step.
    pub trace: Vec<usize>,
    /// The panic payload of the failing logical thread.
    pub message: String,
}

/// Environment variable that pins exploration to one seed (printed by
/// any failing run).
pub const SEED_ENV: &str = "SFUT_MODEL_SEED";

#[cfg(feature = "model")]
mod explore {
    use super::sched::{self, DfsSource, RandomSource, ScheduleSource};
    use super::{Failure, Report, Scenario, SEED_ENV};
    use std::collections::HashSet;

    fn env_seed() -> Option<u64> {
        let raw = std::env::var(SEED_ENV).ok()?;
        let raw = raw.trim();
        let parsed = raw
            .strip_prefix("0x")
            .map(|h| u64::from_str_radix(h, 16))
            .unwrap_or_else(|| raw.parse());
        parsed.ok()
    }

    fn hash_trace(trace: &[usize]) -> u64 {
        // FNV-1a, good enough to deduplicate decision traces.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &d in trace {
            h ^= d as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    fn run_one(
        source: &mut dyn ScheduleSource,
        seed: Option<u64>,
        setup: &dyn Fn() -> Scenario,
    ) -> Result<Vec<usize>, Failure> {
        let scenario = setup();
        let outcome = sched::run_schedule(source, scenario.threads);
        let failure = outcome.failure.or_else(|| {
            // Post-run invariant check, on the controller thread (the
            // shims no-op their yield there). Its panic is a failure
            // attributed to this run's trace.
            scenario.check.and_then(|check| {
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(check))
                    .err()
                    .map(sched::panic_message)
            })
        });
        match failure {
            None => Ok(outcome.trace),
            Some(message) => {
                let f = Failure { seed, trace: outcome.trace, message };
                match f.seed {
                    Some(s) => eprintln!(
                        "model: schedule FAILED — replay with {SEED_ENV}={s:#x} \
                         (trace {:?}): {}",
                        f.trace, f.message
                    ),
                    None => eprintln!(
                        "model: DFS schedule FAILED (trace {:?}): {}",
                        f.trace, f.message
                    ),
                }
                Err(f)
            }
        }
    }

    /// Run `schedules` seeded random interleavings of the scenario
    /// `setup` builds (a fresh instance per schedule). Stops at the
    /// first failure. `SFUT_MODEL_SEED` in the environment pins the
    /// whole exploration to that single seed.
    pub fn explore_random(
        seed0: u64,
        schedules: usize,
        setup: impl Fn() -> Scenario,
    ) -> Report {
        if let Some(pinned) = env_seed() {
            return replay_seed(pinned, setup);
        }
        let mut seen = HashSet::new();
        let mut report = Report { schedules: 0, distinct: 0, failure: None };
        for k in 0..schedules {
            // Decorrelate per-run seeds so a failure replays from one
            // 64-bit number, not (base, index).
            let seed = sched::splitmix64(seed0 ^ (k as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
            let mut source = RandomSource::new(seed);
            report.schedules += 1;
            match run_one(&mut source, Some(seed), &setup) {
                Ok(trace) => {
                    if seen.insert(hash_trace(&trace)) {
                        report.distinct += 1;
                    }
                }
                Err(f) => {
                    report.failure = Some(f);
                    break;
                }
            }
        }
        report
    }

    /// Systematic bounded search: every schedule reachable with at most
    /// `preemption_bound` involuntary context switches, capped at
    /// `max_schedules` runs. Stops at the first failure.
    pub fn explore_dfs(
        preemption_bound: usize,
        max_schedules: usize,
        setup: impl Fn() -> Scenario,
    ) -> Report {
        let mut source = DfsSource::new(preemption_bound);
        let mut report = Report { schedules: 0, distinct: 0, failure: None };
        loop {
            if report.schedules >= max_schedules {
                break;
            }
            report.schedules += 1;
            match run_one(&mut source, None, &setup) {
                Ok(_) => {
                    // DFS traces are distinct by construction.
                    report.distinct += 1;
                }
                Err(f) => {
                    report.failure = Some(f);
                    break;
                }
            }
            if !source.advance() {
                break;
            }
        }
        report
    }

    /// Re-run exactly one seeded schedule (the replay path a failing
    /// run's `SFUT_MODEL_SEED=<seed>` line points at).
    pub fn replay_seed(seed: u64, setup: impl Fn() -> Scenario) -> Report {
        let mut source = RandomSource::new(seed);
        let mut report = Report { schedules: 1, distinct: 1, failure: None };
        if let Err(f) = run_one(&mut source, Some(seed), &setup) {
            report.failure = Some(f);
        }
        report
    }
}

#[cfg(feature = "model")]
pub use explore::{explore_dfs, explore_random, replay_seed};

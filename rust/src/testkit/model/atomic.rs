//! Shim atomics: `std::sync::atomic` normally, scheduler-routed under
//! the `model` feature.
//!
//! The shims keep the full `Ordering` surface so ported code reads
//! exactly like the production code it mirrors; under `model` the
//! ordering is forwarded to the underlying atomic but exploration
//! itself is over sequentially-consistent interleavings (see the
//! module docs).

use std::sync::atomic::{fence, AtomicU64, AtomicUsize, Ordering};

/// Yield the virtual-scheduler floor (no-op without the `model`
/// feature, or outside a model run).
#[inline]
fn hook() {
    #[cfg(feature = "model")]
    super::sched::yield_point();
}

/// An atomic fence that is a schedule point under the `model` feature.
#[inline]
pub fn model_fence(order: Ordering) {
    hook();
    fence(order);
}

macro_rules! model_atomic {
    ($name:ident, $inner:ty, $prim:ty) => {
        /// Shim atomic: a plain std atomic whose every operation is a
        /// virtual-scheduler yield point under the `model` feature.
        #[derive(Debug, Default)]
        pub struct $name {
            cell: $inner,
        }

        impl $name {
            pub const fn new(v: $prim) -> Self {
                Self { cell: <$inner>::new(v) }
            }

            #[inline]
            pub fn load(&self, order: Ordering) -> $prim {
                hook();
                self.cell.load(order)
            }

            #[inline]
            pub fn store(&self, v: $prim, order: Ordering) {
                hook();
                self.cell.store(v, order);
            }

            #[inline]
            pub fn swap(&self, v: $prim, order: Ordering) -> $prim {
                hook();
                self.cell.swap(v, order)
            }

            #[inline]
            pub fn compare_exchange(
                &self,
                current: $prim,
                new: $prim,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$prim, $prim> {
                hook();
                self.cell.compare_exchange(current, new, success, failure)
            }

            #[inline]
            pub fn fetch_add(&self, v: $prim, order: Ordering) -> $prim {
                hook();
                self.cell.fetch_add(v, order)
            }

            #[inline]
            pub fn fetch_sub(&self, v: $prim, order: Ordering) -> $prim {
                hook();
                self.cell.fetch_sub(v, order)
            }
        }
    };
}

model_atomic!(ModelAtomicU64, AtomicU64, u64);
model_atomic!(ModelAtomicUsize, AtomicUsize, usize);

/// A mutex whose lock acquisition is built on [`ModelAtomicUsize`], so
/// contention is part of the explored schedule instead of an opaque OS
/// block (a parked `std::sync::Mutex` waiter would deadlock the
/// cooperative scheduler: it blocks without yielding the floor).
///
/// It is a real spinlock in both configurations: the CAS pair provides
/// acquire/release mutual exclusion, so the `RefCell` inside is only
/// ever touched by the lock holder. Model scenarios keep critical
/// sections short and single-owner where possible (the ports only
/// contend on it deliberately).
pub struct ModelMutex<T> {
    locked: ModelAtomicUsize,
    data: std::cell::RefCell<T>,
}

// SAFETY: `data` is only borrowed between winning the `locked` CAS
// (Acquire) and the guard's release store (Release), so accesses from
// different threads are mutually excluded and ordered; the RefCell's
// own borrow bookkeeping therefore runs under mutual exclusion too.
// `T: Send` is required so the protected value may move between the
// threads that take turns holding the lock.
unsafe impl<T: Send> Send for ModelMutex<T> {}
// SAFETY: as above — `&ModelMutex<T>` only exposes `data` through the
// lock protocol, which serializes all access.
unsafe impl<T: Send> Sync for ModelMutex<T> {}

impl<T> ModelMutex<T> {
    pub fn new(value: T) -> Self {
        ModelMutex { locked: ModelAtomicUsize::new(0), data: std::cell::RefCell::new(value) }
    }

    pub fn lock(&self) -> ModelMutexGuard<'_, T> {
        // Each failed CAS is a yield point under `model`, so the lock
        // holder is always schedulable and the spin terminates; without
        // the feature this is an ordinary (short-critical-section)
        // spinlock.
        while self
            .locked
            .compare_exchange(0, 1, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            std::hint::spin_loop();
        }
        ModelMutexGuard { lock: self, inner: Some(self.data.borrow_mut()) }
    }
}

pub struct ModelMutexGuard<'a, T> {
    lock: &'a ModelMutex<T>,
    /// `Some` until drop: the borrow must end *before* the release
    /// store, or the next lock winner would trip the RefCell.
    inner: Option<std::cell::RefMut<'a, T>>,
}

impl<T> std::ops::Deref for ModelMutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard alive")
    }
}

impl<T> std::ops::DerefMut for ModelMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard alive")
    }
}

impl<T> Drop for ModelMutexGuard<'_, T> {
    fn drop(&mut self) {
        self.inner = None;
        self.lock.locked.store(0, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shims_behave_like_std_atomics() {
        let a = ModelAtomicU64::new(5);
        assert_eq!(a.load(Ordering::SeqCst), 5);
        a.store(7, Ordering::SeqCst);
        assert_eq!(a.swap(9, Ordering::SeqCst), 7);
        assert_eq!(a.compare_exchange(9, 11, Ordering::SeqCst, Ordering::Relaxed), Ok(9));
        assert_eq!(a.compare_exchange(9, 13, Ordering::SeqCst, Ordering::Relaxed), Err(11));
        assert_eq!(a.fetch_add(1, Ordering::SeqCst), 11);
        assert_eq!(a.fetch_sub(2, Ordering::SeqCst), 12);
        assert_eq!(a.load(Ordering::SeqCst), 10);
        model_fence(Ordering::SeqCst);
        let u = ModelAtomicUsize::new(1);
        assert_eq!(u.fetch_add(2, Ordering::SeqCst), 1);
    }

    #[test]
    fn model_mutex_excludes_and_releases() {
        let m = std::sync::Arc::new(ModelMutex::new(0u64));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = std::sync::Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    *m.lock() += 1;
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*m.lock(), 4000);
    }
}

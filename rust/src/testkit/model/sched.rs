//! The virtual scheduler behind the `model` feature.
//!
//! Logical threads are real OS threads run *co-operatively*: exactly
//! one holds the floor at any moment, and it yields it back at every
//! shim atomic operation ([`yield_point`]). The controlling thread
//! (the test, inside [`run_schedule`]) then consults a
//! [`ScheduleSource`] for who runs next. A complete run is thus
//! reproduced exactly by its decision trace — the property the
//! replay-seed machinery and the DFS both stand on.
//!
//! Logical threads must terminate under *any* schedule (bounded loops
//! only — a model scenario polls a bounded number of times instead of
//! spinning until a condition holds), because the sources' default
//! policy is "keep running the current thread": an unbounded spin
//! would otherwise never yield the floor in a way that lets the DFS
//! finish a run.

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

use super::LogicalThread;

/// SplitMix64 — the same tiny deterministic generator the prop harness
/// family uses; good enough to pick schedule branches.
pub(crate) fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Picks the next logical thread to run at each decision point.
pub(crate) trait ScheduleSource {
    /// `runnable` is non-empty and sorted; `prev` is the thread that
    /// performed the previous step (None at the first step).
    fn choose(&mut self, runnable: &[usize], prev: Option<usize>) -> usize;
    /// A new run is starting; reset per-run state.
    fn reset(&mut self);
}

/// Uniformly random choice from a seed; the trace is a pure function
/// of the seed, which is what makes one-number replay possible.
pub(crate) struct RandomSource {
    state: u64,
}

impl RandomSource {
    pub(crate) fn new(seed: u64) -> Self {
        RandomSource { state: seed }
    }
}

impl ScheduleSource for RandomSource {
    fn choose(&mut self, runnable: &[usize], _prev: Option<usize>) -> usize {
        self.state = splitmix64(self.state);
        runnable[(self.state % runnable.len() as u64) as usize]
    }

    fn reset(&mut self) {}
}

/// One explored decision point of the DFS.
struct Frame {
    /// The choice this run takes at this step.
    choice: usize,
    /// Unexplored alternatives at this step (within the preemption
    /// bound at the time the frontier was opened).
    alternatives: Vec<usize>,
    /// Involuntary switches in the prefix *including* this choice.
    preemptions: usize,
    /// True when the previous thread could not continue here, so
    /// picking any alternative is free (not a preemption).
    free_choice: bool,
}

/// Depth-first enumeration of schedules with a preemption bound. The
/// default policy is "continue the previous thread" (no preemption);
/// each frontier records the runnable alternatives that still fit the
/// bound, and [`DfsSource::advance`] backtracks to the deepest one.
pub(crate) struct DfsSource {
    bound: usize,
    path: Vec<Frame>,
    pos: usize,
}

impl DfsSource {
    pub(crate) fn new(bound: usize) -> Self {
        DfsSource { bound, path: Vec::new(), pos: 0 }
    }

    /// Move to the next unexplored prefix. Returns false when the
    /// bounded space is exhausted.
    pub(crate) fn advance(&mut self) -> bool {
        // A run may terminate before consuming the whole recorded
        // prefix (a different interleaving can finish in fewer steps);
        // frames beyond the last consulted step belong to no run and
        // must not be backtracked into.
        self.path.truncate(self.pos);
        while let Some(mut frame) = self.path.pop() {
            if let Some(alt) = frame.alternatives.pop() {
                // Re-derive the preemption count for the new choice:
                // the popped frame's count was for its old
                // (continuation) choice. Alternatives always differ
                // from the default, so taking one costs a preemption
                // exactly when the default was a continuation.
                let before = self.path.last().map_or(0, |f| f.preemptions);
                frame.preemptions = before + usize::from(!frame.free_choice);
                frame.choice = alt;
                self.path.push(frame);
                return true;
            }
        }
        false
    }
}

impl ScheduleSource for DfsSource {
    fn choose(&mut self, runnable: &[usize], prev: Option<usize>) -> usize {
        if self.pos < self.path.len() {
            let frame = &self.path[self.pos];
            self.pos += 1;
            debug_assert!(runnable.contains(&frame.choice), "DFS replay diverged");
            return frame.choice;
        }
        // New frontier: default to continuing the previous thread (no
        // preemption); fall back to the lowest runnable id.
        let continues = prev.filter(|p| runnable.contains(p));
        let choice = continues.unwrap_or(runnable[0]);
        let preemptions_before = self.path.last().map_or(0, |f| f.preemptions);
        // Alternatives cost one preemption each when the previous
        // thread could have continued; when it could not (blocked or
        // finished), trying a different thread is a free choice.
        let costs_preemption = continues.is_some();
        let alternatives = if !costs_preemption || preemptions_before < self.bound {
            runnable.iter().copied().filter(|&r| r != choice).collect()
        } else {
            Vec::new()
        };
        self.path.push(Frame {
            choice,
            alternatives,
            preemptions: preemptions_before,
            free_choice: !costs_preemption,
        });
        self.pos += 1;
        choice
    }

    fn reset(&mut self) {
        self.pos = 0;
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum LState {
    Ready,
    Finished,
}

struct Central {
    /// Which logical thread holds the floor; None = controller's turn.
    active: Option<usize>,
    state: Vec<LState>,
    trace: Vec<usize>,
    failure: Option<String>,
}

pub(crate) struct Sched {
    central: Mutex<Central>,
    cv: Condvar,
}

thread_local! {
    /// The scheduler this OS thread participates in, if any. Shim
    /// atomics consult this: unregistered threads (normal test code,
    /// or shim use outside a model run) perform their operation
    /// directly without yielding.
    static CURRENT: RefCell<Option<(Arc<Sched>, usize)>> = const { RefCell::new(None) };
}

/// Yield the floor at an atomic operation. No-op outside a model run.
pub(crate) fn yield_point() {
    let current = CURRENT.with(|c| c.borrow().clone());
    if let Some((sched, id)) = current {
        sched.pause(id);
    }
}

impl Sched {
    fn new(n: usize) -> Self {
        Sched {
            central: Mutex::new(Central {
                active: None,
                state: vec![LState::Ready; n],
                trace: Vec::new(),
                failure: None,
            }),
            cv: Condvar::new(),
        }
    }

    /// Hand the floor back to the controller and wait to be granted it
    /// again.
    fn pause(&self, id: usize) {
        let mut c = self.central.lock().unwrap();
        c.active = None;
        self.cv.notify_all();
        while c.active != Some(id) {
            c = self.cv.wait(c).unwrap();
        }
    }

    fn wait_for_turn(&self, id: usize) {
        let mut c = self.central.lock().unwrap();
        while c.active != Some(id) {
            c = self.cv.wait(c).unwrap();
        }
    }

    fn finish(&self, id: usize, failure: Option<String>) {
        let mut c = self.central.lock().unwrap();
        c.state[id] = LState::Finished;
        if c.failure.is_none() {
            c.failure = failure;
        }
        c.active = None;
        self.cv.notify_all();
    }
}

pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "logical thread panicked (non-string payload)".to_string()
    }
}

fn thread_main(sched: Arc<Sched>, id: usize, body: LogicalThread) {
    CURRENT.with(|c| *c.borrow_mut() = Some((sched.clone(), id)));
    sched.wait_for_turn(id);
    let result = catch_unwind(AssertUnwindSafe(body));
    CURRENT.with(|c| *c.borrow_mut() = None);
    sched.finish(id, result.err().map(panic_message));
}

pub(crate) struct RunOutcome {
    pub(crate) trace: Vec<usize>,
    pub(crate) failure: Option<String>,
}

/// Run the logical threads to completion under one schedule. The
/// calling thread acts as controller: it owns every decision point and
/// records the trace.
pub(crate) fn run_schedule(
    source: &mut dyn ScheduleSource,
    threads: Vec<LogicalThread>,
) -> RunOutcome {
    source.reset();
    let n = threads.len();
    let sched = Arc::new(Sched::new(n));
    let handles: Vec<_> = threads
        .into_iter()
        .enumerate()
        .map(|(id, body)| {
            let s = Arc::clone(&sched);
            std::thread::Builder::new()
                .name(format!("model-l{id}"))
                .spawn(move || thread_main(s, id, body))
                .expect("spawn logical thread")
        })
        .collect();
    loop {
        let mut c = sched.central.lock().unwrap();
        while c.active.is_some() {
            c = sched.cv.wait(c).unwrap();
        }
        let runnable: Vec<usize> =
            (0..n).filter(|&i| c.state[i] == LState::Ready).collect();
        if runnable.is_empty() {
            break;
        }
        let prev = c.trace.last().copied();
        let choice = source.choose(&runnable, prev);
        debug_assert!(runnable.contains(&choice), "source chose a non-runnable thread");
        c.trace.push(choice);
        c.active = Some(choice);
        drop(c);
        sched.cv.notify_all();
    }
    for h in handles {
        let _ = h.join();
    }
    let c = sched.central.lock().unwrap();
    RunOutcome { trace: c.trace.clone(), failure: c.failure.clone() }
}

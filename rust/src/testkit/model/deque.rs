//! Model port of [`crate::exec::ChaseLevDeque`] onto the shim atomics.
//!
//! The port is line-for-line faithful to the production algorithm —
//! same loads, stores, CASes and fences in the same order, including
//! the grow-under-steal retirement protocol and the wrapping-`u64`
//! `top`/`bottom` indices — with two modeling substitutions:
//!
//! * **Jobs are nonzero `u64` payloads** instead of boxed closures, so
//!   a slot is one shim atomic and a racing read is a value the
//!   claiming CAS validates (exactly the production
//!   `MaybeUninit`-bit-copy discipline, made checkable).
//! * **Buffers are pre-allocated immutable rings with a `freed` flag**
//!   instead of heap pointers. `grow` switches `current` to the next
//!   ring and `retire` marks quiescent rings freed; a thief asserts
//!   `freed == 0` *after* its slot read, which turns a use-after-free
//!   into a deterministic, replayable assertion instead of a crash
//!   that depends on the allocator.
//!
//! Owner-only methods (`push`/`pop`/`drain`) carry the production
//! contract by convention — model scenarios give them to exactly one
//! logical thread.

use std::sync::atomic::Ordering;

use super::atomic::{model_fence, ModelAtomicU64, ModelAtomicUsize, ModelMutex};

/// Mirror of the production steal-half cap.
pub const MAX_STEAL_BATCH: usize = 16;

/// One pre-allocated ring generation.
struct Ring {
    mask: u64,
    slots: Vec<ModelAtomicU64>,
    /// Set by `retire` once the ring is quiescent; a thief observing 1
    /// after a slot read has read freed memory in production terms.
    freed: ModelAtomicUsize,
}

impl Ring {
    fn new(cap: usize) -> Self {
        assert!(cap.is_power_of_two());
        Ring {
            mask: cap as u64 - 1,
            slots: (0..cap).map(|_| ModelAtomicU64::new(0)).collect(),
            freed: ModelAtomicUsize::new(0),
        }
    }

    fn capacity(&self) -> u64 {
        self.mask + 1
    }

    fn write(&self, index: u64, job: u64) {
        self.slots[(index & self.mask) as usize].store(job, Ordering::Relaxed);
    }

    fn read(&self, index: u64) -> u64 {
        self.slots[(index & self.mask) as usize].load(Ordering::Relaxed)
    }
}

/// The modeled Chase–Lev deque. See the module docs for the mapping to
/// the production type.
pub struct ModelChaseLev {
    /// Thief end. Only ever advances (wrapping); claimed by CAS.
    top: ModelAtomicU64,
    /// Owner end. Owner-written; thieves read it with Acquire.
    bottom: ModelAtomicU64,
    /// Index into `rings` of the current generation (the production
    /// `AtomicPtr<Buffer>`, made an index so rings can outlive
    /// retirement and keep their `freed` flag observable).
    current: ModelAtomicUsize,
    /// Thieves currently inside a ring-dereference window.
    pins: ModelAtomicUsize,
    rings: Vec<Ring>,
    /// Replaced ring indices awaiting quiescence (`pins == 0`).
    /// Owner-only in practice (`retire` runs inside owner `grow`).
    limbo: ModelMutex<Vec<usize>>,
}

impl ModelChaseLev {
    /// A deque whose ring starts at `base_cap` slots and may grow at
    /// most `grows` times (the scenario sizes the pre-allocation).
    pub fn new(base_cap: usize, grows: usize) -> Self {
        Self::with_start_index(0, base_cap, grows)
    }

    /// Start both indices at `start` — same test hook as the production
    /// `ChaseLevDeque::with_start_index`, so wraparound across the
    /// `u64` boundary is reachable in bounded model time.
    pub fn with_start_index(start: u64, base_cap: usize, grows: usize) -> Self {
        ModelChaseLev {
            top: ModelAtomicU64::new(start),
            bottom: ModelAtomicU64::new(start),
            current: ModelAtomicUsize::new(0),
            pins: ModelAtomicUsize::new(0),
            rings: (0..=grows).map(|g| Ring::new(base_cap << g)).collect(),
            limbo: ModelMutex::new(Vec::new()),
        }
    }

    /// Owner push (bottom). Jobs are nonzero (0 is the unwritten-slot
    /// sentinel).
    pub fn push(&self, job: u64) {
        assert!(job != 0, "model jobs are nonzero u64 payloads");
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut cur = self.current.load(Ordering::Relaxed);
        if b.wrapping_sub(t) >= self.rings[cur].capacity() {
            self.grow(t, b, cur);
            cur = self.current.load(Ordering::Relaxed);
        }
        self.rings[cur].write(b, job);
        // Publish the slot before the index: a thief that observes the
        // new bottom (Acquire) must observe the written job.
        model_fence(Ordering::Release);
        self.bottom.store(b.wrapping_add(1), Ordering::Relaxed);
    }

    /// Owner pop (bottom, LIFO).
    pub fn pop(&self) -> Option<u64> {
        let b = self.bottom.load(Ordering::Relaxed).wrapping_sub(1);
        let cur = self.current.load(Ordering::Relaxed);
        self.bottom.store(b, Ordering::Relaxed);
        // Order the bottom decrement against thieves' top CAS: either a
        // concurrent thief sees the reduced bottom and aborts, or we
        // see its advanced top below.
        model_fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        let len = b.wrapping_sub(t) as i64;
        if len < 0 {
            // Was empty: restore the canonical empty state.
            self.bottom.store(t, Ordering::Relaxed);
            return None;
        }
        let job = self.rings[cur].read(b);
        if len > 0 {
            // More than one element: the bottom one is ours without
            // synchronization.
            return Some(job);
        }
        // Exactly one element: race thieves for it on `top`.
        let won = self
            .top
            .compare_exchange(t, t.wrapping_add(1), Ordering::SeqCst, Ordering::Relaxed)
            .is_ok();
        self.bottom.store(t.wrapping_add(1), Ordering::Relaxed);
        won.then_some(job)
    }

    /// Thief pop (top, FIFO). `None` means empty or lost the claiming
    /// race.
    pub fn steal(&self) -> Option<u64> {
        let t = self.top.load(Ordering::Acquire);
        // Order the top load before the bottom load: pairs with the
        // owner's pop fence.
        model_fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if (b.wrapping_sub(t) as i64) <= 0 {
            return None;
        }
        // Dereference window: pin so a concurrent grow cannot retire
        // the ring under us.
        self.pins.fetch_add(1, Ordering::SeqCst);
        let cur = self.current.load(Ordering::SeqCst);
        let ring = &self.rings[cur];
        let job = ring.read(t);
        // The checkable form of the production use-after-free hazard:
        // the slot read above must have come from a ring that was not
        // freed at read time. `retire`'s SeqCst argument (pin RMW vs
        // buffer publish) is exactly what this assertion model-checks.
        assert!(
            ring.freed.load(Ordering::SeqCst) == 0,
            "use-after-free: thief read slot {t} from a retired ring"
        );
        let won = self
            .top
            .compare_exchange(t, t.wrapping_add(1), Ordering::SeqCst, Ordering::Relaxed)
            .is_ok();
        self.pins.fetch_sub(1, Ordering::SeqCst);
        // A lost CAS means the value read is not ours — discarded
        // uninterpreted, as in production.
        won.then_some(job)
    }

    /// Steal-half: the production `steal_batch_and_pop` loop shape — a
    /// goal of half the observed length (capped), taken as a sequence
    /// of single top-CAS steals, stopping at the first failure.
    pub fn steal_half(&self) -> Vec<u64> {
        let goal = self.len().div_ceil(2).min(MAX_STEAL_BATCH);
        let mut out = Vec::new();
        for _ in 0..goal.max(1) {
            match self.steal() {
                Some(job) => out.push(job),
                None => break,
            }
        }
        out
    }

    /// Queued jobs (instantaneous snapshot).
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b.wrapping_sub(t) as i64).max(0) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Owner exit path: pop until empty (LIFO order).
    pub fn drain(&self) -> Vec<u64> {
        let mut out = Vec::new();
        while let Some(job) = self.pop() {
            out.push(job);
        }
        out
    }

    /// Owner-only: switch to the next (double-capacity) ring, copying
    /// the live window `[t, b)`. `t` may be stale — copying a few
    /// already-claimed slots is harmless, they are value-copies no one
    /// will interpret.
    fn grow(&self, t: u64, b: u64, cur: usize) {
        let next = cur + 1;
        assert!(
            next < self.rings.len(),
            "model scenario under-provisioned rings (grow #{next} requested)"
        );
        let mut i = t;
        while i != b {
            let v = self.rings[cur].read(i);
            self.rings[next].write(i, v);
            i = i.wrapping_add(1);
        }
        self.current.store(next, Ordering::SeqCst);
        self.retire(cur);
    }

    /// Park a replaced ring; mark the limbo list freed if no thief is
    /// pinned — the same SeqCst argument as the production `retire`: a
    /// pin RMW not observed here is later in the SeqCst total order, so
    /// that thief's subsequent `current` load returns the new ring.
    fn retire(&self, old: usize) {
        let mut limbo = self.limbo.lock();
        limbo.push(old);
        if self.pins.load(Ordering::SeqCst) == 0 {
            for idx in limbo.drain(..) {
                self.rings[idx].freed.store(1, Ordering::SeqCst);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifo_pop_fifo_steal() {
        let d = ModelChaseLev::new(4, 1);
        for j in 1..=3 {
            d.push(j);
        }
        assert_eq!(d.len(), 3);
        assert_eq!(d.steal(), Some(1));
        assert_eq!(d.pop(), Some(3));
        assert_eq!(d.pop(), Some(2));
        assert_eq!(d.pop(), None);
        assert_eq!(d.steal(), None);
    }

    #[test]
    fn grow_preserves_live_window() {
        let d = ModelChaseLev::new(2, 2);
        for j in 1..=7 {
            d.push(j);
        }
        let mut seen = Vec::new();
        while let Some(j) = d.steal() {
            seen.push(j);
        }
        assert_eq!(seen, vec![1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn wraparound_indices() {
        let d = ModelChaseLev::with_start_index(u64::MAX - 2, 2, 2);
        for j in 1..=6 {
            d.push(j);
        }
        assert_eq!(d.len(), 6);
        assert_eq!(d.steal(), Some(1));
        assert_eq!(d.drain(), vec![6, 5, 4, 3, 2]);
        assert!(d.is_empty());
    }

    #[test]
    fn steal_half_takes_oldest_half() {
        let d = ModelChaseLev::new(8, 0);
        for j in 1..=6 {
            d.push(j);
        }
        assert_eq!(d.steal_half(), vec![1, 2, 3]);
        assert_eq!(d.drain(), vec![6, 5, 4]);
    }

    #[test]
    fn steal_half_caps_at_batch_limit() {
        let d = ModelChaseLev::new(64, 0);
        for j in 1..=60 {
            d.push(j);
        }
        let batch = d.steal_half();
        assert_eq!(batch.len(), MAX_STEAL_BATCH);
        assert_eq!(batch[0], 1);
    }
}

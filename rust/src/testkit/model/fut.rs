//! Model port of the [`crate::susp::Fut`] state machine onto the shim
//! atomics.
//!
//! The production machine is EMPTY → RUNNING → READY/PANICKED with the
//! value published *before* the Release state store, a promise
//! drop-guard that panick-completes an abandoned future, and an
//! `on_complete` callback protocol whose obligation is **exactly-once
//! delivery** no matter how registration races completion.
//!
//! The port keeps the state machine verbatim and replaces the
//! production callback mutex with per-waiter atomic slots
//! (0 = none, 1 = registered, 2 = delivered): the completer's sweep and
//! the registrant's re-check both race a CAS `1 → 2`, and whoever wins
//! delivers. That winning CAS is the same obligation the production
//! mutex+recheck protocol discharges, made directly checkable — a
//! double delivery or a delivery with an unpublished value is an
//! assertion inside [`ModelFut::deliver`], found (and replayed) by the
//! explorer.

use std::cell::Cell;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::atomic::{ModelAtomicU64, ModelAtomicUsize};

pub const EMPTY: u64 = 0;
pub const RUNNING: u64 = 1;
pub const READY: u64 = 2;
pub const PANICKED: u64 = 3;

/// Per-waiter callback slot states.
const SLOT_NONE: u64 = 0;
const SLOT_REGISTERED: u64 = 1;
const SLOT_DELIVERED: u64 = 2;

/// The modeled future. Values are nonzero `u64` payloads (0 is the
/// unpublished sentinel, which is what makes publication order
/// assertable).
pub struct ModelFut {
    state: ModelAtomicU64,
    value: ModelAtomicU64,
    /// One callback slot per waiter.
    slots: Vec<ModelAtomicU64>,
    /// Delivery counters per waiter — the exactly-once ledger.
    deliveries: Vec<ModelAtomicUsize>,
}

impl ModelFut {
    pub fn new(waiters: usize) -> Self {
        ModelFut {
            state: ModelAtomicU64::new(EMPTY),
            value: ModelAtomicU64::new(0),
            slots: (0..waiters).map(|_| ModelAtomicU64::new(SLOT_NONE)).collect(),
            deliveries: (0..waiters).map(|_| ModelAtomicUsize::new(0)).collect(),
        }
    }

    /// Claim the right to run (EMPTY → RUNNING). At most one caller
    /// wins.
    pub fn try_start(&self) -> bool {
        self.state
            .compare_exchange(EMPTY, RUNNING, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
    }

    /// Publish a result: value first, then the Release state store,
    /// then sweep registered waiters. `v` must be nonzero.
    pub fn complete(&self, v: u64) {
        assert!(v != 0, "model values are nonzero u64 payloads");
        assert!(
            self.value.compare_exchange(0, v, Ordering::Relaxed, Ordering::Relaxed).is_ok(),
            "double completion: value already published"
        );
        self.state.store(READY, Ordering::Release);
        self.sweep();
    }

    /// Publish a panic outcome (no value), then sweep.
    pub fn complete_panicked(&self) {
        self.state.store(PANICKED, Ordering::Release);
        self.sweep();
    }

    /// Completer side of delivery: claim every registered slot.
    fn sweep(&self) {
        for i in 0..self.slots.len() {
            if self.slots[i]
                .compare_exchange(
                    SLOT_REGISTERED,
                    SLOT_DELIVERED,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                )
                .is_ok()
            {
                self.deliver(i);
            }
        }
    }

    /// Waiter `i` asks to be notified on completion. Exactly one
    /// delivery happens regardless of how this races `complete`:
    /// either the fast path fires inline, or the slot is registered
    /// and the re-check races the completer's sweep on the `1 → 2`
    /// CAS — the winner delivers.
    pub fn on_complete(&self, i: usize) {
        let s = self.state.load(Ordering::Acquire);
        if s >= READY {
            // Already complete: deliver inline if nobody has.
            if self.slots[i]
                .compare_exchange(SLOT_NONE, SLOT_DELIVERED, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                self.deliver(i);
            }
            return;
        }
        assert!(
            self.slots[i]
                .compare_exchange(
                    SLOT_NONE,
                    SLOT_REGISTERED,
                    Ordering::AcqRel,
                    Ordering::Relaxed
                )
                .is_ok(),
            "waiter {i} registered twice"
        );
        // Completion may have landed between the state load and the
        // registration — re-check, and race the sweep for the claim.
        let s2 = self.state.load(Ordering::Acquire);
        if s2 >= READY
            && self.slots[i]
                .compare_exchange(
                    SLOT_REGISTERED,
                    SLOT_DELIVERED,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                )
                .is_ok()
        {
            self.deliver(i);
        }
    }

    /// The delivery ledger: asserts the two obligations the model
    /// checks — a delivery only after completion with the value
    /// published (publication order), and at most one per waiter
    /// (exactly-once).
    fn deliver(&self, i: usize) {
        let s = self.state.load(Ordering::Acquire);
        assert!(
            s == READY || s == PANICKED,
            "delivery to waiter {i} before completion (state {s})"
        );
        if s == READY {
            assert!(
                self.value.load(Ordering::Acquire) != 0,
                "waiter {i} observed READY with unpublished value"
            );
        }
        let prev = self.deliveries[i].fetch_add(1, Ordering::SeqCst);
        assert!(prev == 0, "waiter {i} delivered twice");
    }

    pub fn state(&self) -> u64 {
        self.state.load(Ordering::Acquire)
    }

    pub fn value(&self) -> u64 {
        self.value.load(Ordering::Acquire)
    }

    pub fn delivery_count(&self, i: usize) -> usize {
        self.deliveries[i].load(Ordering::SeqCst)
    }
}

/// The promise drop-guard: single owner of the completion right. If it
/// is dropped without completing (the production "runner died" path),
/// the future is panick-completed so waiters are still delivered
/// exactly once.
pub struct ModelFutPromise {
    fut: Arc<ModelFut>,
    done: Cell<bool>,
}

impl ModelFutPromise {
    /// Claim the future (EMPTY → RUNNING); `None` if someone already
    /// has.
    pub fn claim(fut: Arc<ModelFut>) -> Option<Self> {
        fut.try_start().then(|| ModelFutPromise { fut, done: Cell::new(false) })
    }

    /// Complete with a value; consumes the promise.
    pub fn complete(self, v: u64) {
        self.fut.complete(v);
        self.done.set(true);
    }
}

impl Drop for ModelFutPromise {
    fn drop(&mut self) {
        if !self.done.get() {
            self.fut.complete_panicked();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_then_register_delivers_inline() {
        let f = ModelFut::new(2);
        assert!(f.try_start());
        assert!(!f.try_start());
        f.complete(42);
        assert_eq!(f.state(), READY);
        assert_eq!(f.value(), 42);
        f.on_complete(0);
        f.on_complete(1);
        assert_eq!(f.delivery_count(0), 1);
        assert_eq!(f.delivery_count(1), 1);
    }

    #[test]
    fn register_then_complete_sweeps() {
        let f = ModelFut::new(2);
        assert!(f.try_start());
        f.on_complete(0);
        f.on_complete(1);
        assert_eq!(f.delivery_count(0), 0);
        f.complete(7);
        assert_eq!(f.delivery_count(0), 1);
        assert_eq!(f.delivery_count(1), 1);
    }

    #[test]
    fn promise_drop_guard_panick_completes() {
        let f = Arc::new(ModelFut::new(1));
        f.on_complete(0);
        {
            let p = ModelFutPromise::claim(Arc::clone(&f)).expect("first claim wins");
            assert!(ModelFutPromise::claim(Arc::clone(&f)).is_none());
            drop(p);
        }
        assert_eq!(f.state(), PANICKED);
        assert_eq!(f.delivery_count(0), 1);
    }

    #[test]
    fn promise_complete_suppresses_guard() {
        let f = Arc::new(ModelFut::new(1));
        let p = ModelFutPromise::claim(Arc::clone(&f)).unwrap();
        p.complete(9);
        assert_eq!(f.state(), READY);
        assert_eq!(f.value(), 9);
    }
}

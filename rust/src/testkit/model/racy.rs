//! Deliberately racy fixtures — the model checker's own test subjects.
//!
//! These types contain real concurrency bugs on purpose. The model
//! suite uses them to prove two things about the checker itself:
//!
//! 1. **It finds bugs.** Exploration over a fixture must produce a
//!    failure (if the checker passes a known-broken type, the checker
//!    is broken).
//! 2. **Failures replay.** A random-mode failure prints a seed;
//!    re-running with that seed must reproduce the *identical* failing
//!    interleaving — same decision trace, same panic message,
//!    byte-for-byte.
//!
//! Nothing outside the model suite should use these types.

use std::sync::atomic::Ordering;

use super::atomic::ModelAtomicU64;
use super::fut::READY;

/// A counter incremented with a separate load and store — the textbook
/// lost update. Two concurrent [`RacyCounter::increment`] calls can
/// interleave load/load/store/store and lose one increment.
pub struct RacyCounter {
    n: ModelAtomicU64,
}

impl RacyCounter {
    pub fn new() -> Self {
        RacyCounter { n: ModelAtomicU64::new(0) }
    }

    /// BUG (deliberate): read-modify-write as two independent atomic
    /// operations instead of one `fetch_add`.
    pub fn increment(&self) {
        let v = self.n.load(Ordering::SeqCst);
        self.n.store(v + 1, Ordering::SeqCst);
    }

    pub fn get(&self) -> u64 {
        self.n.load(Ordering::SeqCst)
    }
}

impl Default for RacyCounter {
    fn default() -> Self {
        Self::new()
    }
}

/// A future-like publisher with the publication order inverted — the
/// exact bug the real `Fut` protocol (value first, then the Release
/// state store) exists to prevent. An observer that polls
/// [`BrokenPublish::poll`] can see READY while the value is still the
/// unpublished sentinel 0.
pub struct BrokenPublish {
    state: ModelAtomicU64,
    value: ModelAtomicU64,
}

impl BrokenPublish {
    pub fn new() -> Self {
        BrokenPublish { state: ModelAtomicU64::new(0), value: ModelAtomicU64::new(0) }
    }

    /// BUG (deliberate): state is stored READY *before* the value is
    /// published.
    pub fn complete(&self, v: u64) {
        assert!(v != 0, "model values are nonzero u64 payloads");
        self.state.store(READY, Ordering::Release);
        self.value.store(v, Ordering::Relaxed);
    }

    /// `Some(value)` once READY is observed — possibly `Some(0)` under
    /// the buggy ordering, which is what a scenario asserts against.
    pub fn poll(&self) -> Option<u64> {
        if self.state.load(Ordering::Acquire) == READY {
            Some(self.value.load(Ordering::Acquire))
        } else {
            None
        }
    }
}

impl Default for BrokenPublish {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn racy_counter_is_fine_sequentially() {
        let c = RacyCounter::new();
        c.increment();
        c.increment();
        assert_eq!(c.get(), 2);
    }

    #[test]
    fn broken_publish_is_fine_sequentially() {
        let p = BrokenPublish::new();
        assert_eq!(p.poll(), None);
        p.complete(5);
        assert_eq!(p.poll(), Some(5));
    }
}

//! In-repo test utilities.
//!
//! `proptest`/`quickcheck` are not available offline, so [`prop`] provides
//! a deterministic property-testing harness: a splittable xorshift
//! generator, size-aware combinators, and a runner that reports the
//! failing seed so any counterexample is reproducible with
//! `SFUT_PROP_SEED=<seed>`. [`wire`] is the shared wire-protocol
//! support: one parser for the coordinator's `err` line taxonomy (so
//! suites don't each re-implement fragments of the grammar) and a
//! blocking client for the framed binary protocol. [`model`] is the
//! deterministic interleaving explorer ("loom-lite") for the lock-free
//! core: shim atomics that become scheduler yield points under
//! `--features model`, with model ports of the Chase–Lev deque and the
//! `Fut` state machine checked by `rust/tests/model_check.rs`.

pub mod model;
pub mod prop;
pub mod wire;

/// Run `f` on a thread with a `stack_mb`-megabyte stack and propagate
/// its result (and panics). Deep-recursion paths (long Lazy filter
/// chains) need more than the 2 MB default of libtest threads; the CLI
/// and benches use `Config::stack_size` the same way.
pub fn with_stack<R: Send + 'static>(
    stack_mb: usize,
    f: impl FnOnce() -> R + Send + 'static,
) -> R {
    std::thread::Builder::new()
        .stack_size(stack_mb << 20)
        .spawn(f)
        .expect("spawn big-stack thread")
        .join()
        .unwrap_or_else(|p| std::panic::resume_unwind(p))
}

#[cfg(test)]
mod tests {
    use super::prop::{runner, Gen};

    #[test]
    fn runner_is_deterministic_given_seed() {
        let collect = |seed: u64| {
            let mut out = Vec::new();
            let mut g = Gen::from_seed(seed);
            for _ in 0..10 {
                out.push(g.u64_any());
            }
            out
        };
        assert_eq!(collect(42), collect(42));
        assert_ne!(collect(42), collect(43));
    }

    #[test]
    fn ranges_respected() {
        let mut r = runner(500);
        r.run(|g: &mut Gen| {
            let v = g.usize_in(3..10);
            assert!((3..10).contains(&v), "{v}");
            let w = g.i64_in(-5..=5);
            assert!((-5..=5).contains(&w), "{w}");
        });
    }

    #[test]
    fn vec_gen_respects_len() {
        let mut r = runner(100);
        r.run(|g: &mut Gen| {
            let v = g.vec(0..8, |g| g.u32_any());
            assert!(v.len() < 8);
        });
    }
}

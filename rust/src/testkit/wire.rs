//! Wire-level test support shared by the integration suites and the
//! ingress bench harness: a typed parser for the coordinator's `err`
//! line taxonomy (the grammar documented in
//! [`crate::coordinator`], "Failure semantics") and a small blocking
//! client for the framed binary protocol.
//!
//! The parser exists so tests assert against *parsed fields* instead of
//! each re-implementing `starts_with`/`contains` fragments of the
//! grammar — one place to update if the taxonomy ever changes, and the
//! chaos/saturation suites stop drifting from each other.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpStream};

use crate::coordinator::frame::{self, Frame, FrameKind};

/// Ticket state codes carried in `Ticket` frame payloads (see the
/// coordinator module docs, "Wire protocol").
pub const STATE_EMPTY: u8 = 0;
pub const STATE_RUNNING: u8 = 1;
pub const STATE_READY: u8 = 2;
pub const STATE_PANICKED: u8 = 3;

/// One parsed line of the documented `err` taxonomy. Lines are
/// accepted with or without the leading `err ` tag — error Display
/// forms (e.g. `Pipeline::run` errors) carry the same grammar minus
/// the tag.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ErrLine {
    /// `err admission=<policy> workload=<w> mode=<m> [waited_ms=<ms>]
    /// [queue_depth=<d>]` — the bounded queue applied its policy.
    Admission {
        policy: String,
        workload: String,
        mode: String,
        waited_ms: Option<u64>,
        queue_depth: Option<u64>,
    },
    /// `err rejected workload=<w> mode=<m> reason: <text>` — refused
    /// at submit time (validation, unknown workload, open breaker).
    Rejected { workload: String, mode: String, reason: String },
    /// `err panicked workload=<w> mode=<m> reason=<text>` — reason is
    /// always the last field and may contain spaces.
    Panicked { workload: String, mode: String, reason: String },
    /// `err timeout workload=<w> mode=<m> deadline_ms=<n>` — the job
    /// blew its execution deadline.
    JobTimeout { workload: String, mode: String, deadline_ms: u64 },
    /// `err timeout ticket=<id> waited_ms=<n>` — a protocol `wait`
    /// gave up; the ticket stays addressable.
    WaitTimeout { ticket: u64, waited_ms: u64 },
    /// `err closed ticket=<id>` — session drain resolved a parked wait.
    Closed { ticket: u64 },
    /// `err ticket released: <id>` — the ticket was evicted by the
    /// per-session cap.
    Released { ticket: u64 },
    /// Any other `err …` line (abandoned tickets, unknown commands,
    /// protocol errors).
    Other { message: String },
}

/// Parse one response line against the documented `err` taxonomy.
/// Returns `None` for lines that are not errors at all (`ok …`,
/// `ticket id=…`, untagged lines outside the grammar); a tagged
/// `err …` line always parses, falling back to [`ErrLine::Other`].
pub fn parse_err_line(line: &str) -> Option<ErrLine> {
    let (tagged, body) = match line.strip_prefix("err ") {
        Some(rest) => (true, rest),
        None => (false, line),
    };
    match parse_body(body) {
        Some(parsed) => Some(parsed),
        None if tagged => Some(ErrLine::Other { message: body.to_string() }),
        None => None,
    }
}

/// Whitespace-token field scanner: the value of the first `key=` token.
fn field(body: &str, key: &str) -> Option<String> {
    let prefix = format!("{key}=");
    body.split_whitespace()
        .find_map(|tok| tok.strip_prefix(&prefix))
        .map(str::to_string)
}

fn num_field(body: &str, key: &str) -> Option<u64> {
    field(body, key)?.parse().ok()
}

fn parse_body(body: &str) -> Option<ErrLine> {
    let first = body.split_whitespace().next()?;
    if let Some(policy) = first.strip_prefix("admission=") {
        return Some(ErrLine::Admission {
            policy: policy.to_string(),
            workload: field(body, "workload")?,
            mode: field(body, "mode")?,
            waited_ms: num_field(body, "waited_ms"),
            queue_depth: num_field(body, "queue_depth"),
        });
    }
    match first {
        "rejected" => Some(ErrLine::Rejected {
            workload: field(body, "workload")?,
            mode: field(body, "mode")?,
            reason: body.split_once("reason: ")?.1.to_string(),
        }),
        "panicked" => Some(ErrLine::Panicked {
            workload: field(body, "workload")?,
            mode: field(body, "mode")?,
            // Always the last field; runs to end of line (spaces legal).
            reason: body.split_once("reason=")?.1.to_string(),
        }),
        "timeout" => {
            if let Some(ticket) = num_field(body, "ticket") {
                Some(ErrLine::WaitTimeout { ticket, waited_ms: num_field(body, "waited_ms")? })
            } else {
                Some(ErrLine::JobTimeout {
                    workload: field(body, "workload")?,
                    mode: field(body, "mode")?,
                    deadline_ms: num_field(body, "deadline_ms")?,
                })
            }
        }
        "closed" => Some(ErrLine::Closed { ticket: num_field(body, "ticket")? }),
        "ticket" => {
            let id = body.strip_prefix("ticket released: ")?.trim().parse().ok()?;
            Some(ErrLine::Released { ticket: id })
        }
        _ => None,
    }
}

/// Blocking client for the framed wire protocol — the test/bench
/// counterpart of the reactor. Performs the magic+version handshake on
/// connect; send and receive are split so tests can pipeline many
/// requests into one write before draining replies.
pub struct FramedClient {
    stream: TcpStream,
}

/// A server reply to `Submit`: either an assigned ticket or one err
/// taxonomy line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitReply {
    Ticket { id: u64, state: u8 },
    Err(String),
}

impl FramedClient {
    /// Connect, send the preamble, and consume the server's `Hello`.
    pub fn connect(addr: SocketAddr) -> io::Result<FramedClient> {
        let mut stream = TcpStream::connect(addr)?;
        stream.write_all(&frame::preamble())?;
        stream.flush()?;
        let hello = frame::read_frame(&mut stream)?
            .ok_or_else(|| io::Error::new(io::ErrorKind::UnexpectedEof, "no hello frame"))?;
        match hello.kind {
            FrameKind::Hello => Ok(FramedClient { stream }),
            FrameKind::Err => Err(io::Error::other(format!(
                "handshake rejected: {}",
                String::from_utf8_lossy(&hello.payload)
            ))),
            other => Err(io::Error::other(format!("unexpected handshake frame: {other:?}"))),
        }
    }

    /// Raw bytes, no framing — for malformed-input conformance tests.
    pub fn send_raw(&mut self, bytes: &[u8]) -> io::Result<()> {
        self.stream.write_all(bytes)?;
        self.stream.flush()
    }

    pub fn send(&mut self, f: &Frame) -> io::Result<()> {
        self.send_raw(&f.encode())
    }

    pub fn send_submit(&mut self, spec: &str) -> io::Result<()> {
        self.send(&Frame::new(FrameKind::Submit, spec.as_bytes().to_vec()))
    }

    pub fn send_wait(&mut self, id: u64) -> io::Result<()> {
        self.send(&Frame::new(FrameKind::Wait, id.to_le_bytes().to_vec()))
    }

    pub fn send_poll(&mut self, id: u64) -> io::Result<()> {
        self.send(&Frame::new(FrameKind::Poll, id.to_le_bytes().to_vec()))
    }

    /// Next frame, or `None` on clean EOF.
    pub fn recv(&mut self) -> io::Result<Option<Frame>> {
        frame::read_frame(&mut self.stream)
    }

    /// Next frame; EOF is an error (the caller expected a reply).
    pub fn recv_expect(&mut self) -> io::Result<Frame> {
        self.recv()?
            .ok_or_else(|| io::Error::new(io::ErrorKind::UnexpectedEof, "server closed mid-reply"))
    }

    /// Submit one spec and read its reply.
    pub fn submit(&mut self, spec: &str) -> io::Result<SubmitReply> {
        self.send_submit(spec)?;
        let f = self.recv_expect()?;
        Self::submit_reply(&f)
    }

    /// Decode a `Submit` reply frame (`Ticket` or `Err`).
    pub fn submit_reply(f: &Frame) -> io::Result<SubmitReply> {
        match f.kind {
            FrameKind::Ticket => {
                let (id, rest) = frame::take_ticket_id(&f.payload)
                    .ok_or_else(|| io::Error::other("short ticket payload"))?;
                let state = rest.first().copied().unwrap_or(STATE_EMPTY);
                Ok(SubmitReply::Ticket { id, state })
            }
            FrameKind::Err => Ok(SubmitReply::Err(Self::line_of(f)?)),
            other => Err(io::Error::other(format!("unexpected submit reply: {other:?}"))),
        }
    }

    /// Wait for a ticket: returns the terminal line — `ok …` from a
    /// `Result` frame or one err taxonomy line from an `Err` frame.
    pub fn wait(&mut self, id: u64) -> io::Result<String> {
        self.send_wait(id)?;
        let f = self.recv_expect()?;
        match f.kind {
            FrameKind::Result | FrameKind::Err => Self::line_of(&f),
            other => Err(io::Error::other(format!("unexpected wait reply: {other:?}"))),
        }
    }

    /// Poll a ticket's state code without blocking on the result.
    pub fn poll(&mut self, id: u64) -> io::Result<u8> {
        self.send_poll(id)?;
        let f = self.recv_expect()?;
        match f.kind {
            FrameKind::Ticket => {
                let (_, rest) = frame::take_ticket_id(&f.payload)
                    .ok_or_else(|| io::Error::other("short ticket payload"))?;
                Ok(rest.first().copied().unwrap_or(STATE_EMPTY))
            }
            FrameKind::Err => Err(io::Error::other(Self::line_of(&f)?)),
            other => Err(io::Error::other(format!("unexpected poll reply: {other:?}"))),
        }
    }

    /// The registered-workload listing.
    pub fn workloads(&mut self) -> io::Result<String> {
        self.send(&Frame::new(FrameKind::Workloads, Vec::new()))?;
        let f = self.recv_expect()?;
        match f.kind {
            FrameKind::WorkloadsReply => {
                String::from_utf8(f.payload).map_err(|_| io::Error::other("non-utf8 listing"))
            }
            other => Err(io::Error::other(format!("unexpected workloads reply: {other:?}"))),
        }
    }

    /// Extract the UTF-8 line carried after the ticket id of a
    /// `Result`/`Err` payload (id 0 = no ticket).
    pub fn line_of(f: &Frame) -> io::Result<String> {
        let (_, rest) = frame::take_ticket_id(&f.payload)
            .ok_or_else(|| io::Error::other("short line payload"))?;
        String::from_utf8(rest.to_vec()).map_err(|_| io::Error::other("non-utf8 line"))
    }

    /// Half-close the write side (the framed analogue of the text
    /// sessions' `shutdown(Write)` script style).
    pub fn shutdown_write(&mut self) -> io::Result<()> {
        self.stream.shutdown(std::net::Shutdown::Write)
    }

    /// Drain every remaining frame until EOF.
    pub fn drain(&mut self) -> io::Result<Vec<Frame>> {
        let mut frames = Vec::new();
        while let Some(f) = self.recv()? {
            frames.push(f);
        }
        Ok(frames)
    }

    pub fn stream(&mut self) -> &mut TcpStream {
        &mut self.stream
    }
}

/// Read whatever the peer sends until EOF, raw (for sessions the
/// server is expected to close after a protocol error).
pub fn read_to_eof(stream: &mut TcpStream) -> io::Result<Vec<u8>> {
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_admission_lines() {
        let shed = parse_err_line("err admission=shed workload=primes mode=par(2) queue_depth=1");
        assert_eq!(
            shed,
            Some(ErrLine::Admission {
                policy: "shed".into(),
                workload: "primes".into(),
                mode: "par(2)".into(),
                waited_ms: None,
                queue_depth: Some(1),
            })
        );
        let timeout = parse_err_line(
            "err admission=timeout workload=stream mode=seq waited_ms=25 queue_depth=4",
        )
        .unwrap();
        match timeout {
            ErrLine::Admission { policy, waited_ms, queue_depth, .. } => {
                assert_eq!(policy, "timeout");
                assert_eq!(waited_ms, Some(25));
                assert_eq!(queue_depth, Some(4));
            }
            other => panic!("{other:?}"),
        }
        let closed = parse_err_line("err admission=closed workload=primes mode=seq").unwrap();
        assert!(matches!(closed, ErrLine::Admission { ref policy, .. } if policy == "closed"));
    }

    #[test]
    fn parses_terminal_outcome_lines_with_or_without_tag() {
        let p = parse_err_line(
            "err panicked workload=faulty(fail_mode=panic,seed=7) mode=seq \
             reason=injected panic (attempt 0 seed 7)",
        )
        .unwrap();
        assert_eq!(
            p,
            ErrLine::Panicked {
                workload: "faulty(fail_mode=panic,seed=7)".into(),
                mode: "seq".into(),
                reason: "injected panic (attempt 0 seed 7)".into(),
            }
        );
        // Display forms carry the same grammar minus the tag.
        let t = parse_err_line("timeout workload=faulty(x=1) mode=seq deadline_ms=120").unwrap();
        assert_eq!(
            t,
            ErrLine::JobTimeout {
                workload: "faulty(x=1)".into(),
                mode: "seq".into(),
                deadline_ms: 120,
            }
        );
        let r = parse_err_line("err rejected workload=faulty mode=seq reason: breaker open: x")
            .unwrap();
        assert!(matches!(r, ErrLine::Rejected { ref reason, .. } if reason == "breaker open: x"));
    }

    #[test]
    fn parses_ticket_lines() {
        assert_eq!(
            parse_err_line("err timeout ticket=3 waited_ms=5000"),
            Some(ErrLine::WaitTimeout { ticket: 3, waited_ms: 5000 })
        );
        assert_eq!(parse_err_line("err closed ticket=9"), Some(ErrLine::Closed { ticket: 9 }));
        assert_eq!(
            parse_err_line("err ticket released: 4"),
            Some(ErrLine::Released { ticket: 4 })
        );
    }

    #[test]
    fn non_err_lines_do_not_parse() {
        assert_eq!(parse_err_line("ok workload=primes verified=true"), None);
        assert_eq!(parse_err_line("ticket id=1 state=running"), None);
        // A tagged line outside the structured grammar still classifies.
        assert_eq!(
            parse_err_line("err unknown command: frobnicate"),
            Some(ErrLine::Other { message: "unknown command: frobnicate".into() })
        );
        assert!(matches!(
            parse_err_line("err job ticket abandoned: promise dropped before completion"),
            Some(ErrLine::Other { .. })
        ));
    }
}

//! Cooperative cancellation for suspended computations.
//!
//! A [`CancelToken`] is a shared flag a *reaper* (or any supervisor)
//! sets when a computation has outlived its deadline. Cancellation is
//! cooperative: nothing is killed — the computation observes the flag
//! at its own safe points and unwinds by panicking with the private
//! [`Cancelled`] marker payload, which the job boundary's
//! `catch_unwind` recognizes (via [`was_cancelled`]) and classifies as
//! a timeout rather than a crash.
//!
//! Two polling styles are supported:
//!
//! * **Explicit** — code that holds a token (e.g. a workload reading
//!   `WorkloadCtx::cancel`) calls [`CancelToken::checkpoint`] in its
//!   loops.
//! * **Ambient** — the coordinator installs the job's token in a
//!   thread-local [`CancelScope`] around the workload call; generic
//!   library loops that cannot thread a token through their signatures
//!   (the stream traversal in `Stream::fold`/`iter`, which forces one
//!   chunk suspension per step) call the free [`checkpoint`] and pick
//!   it up ambiently. Code running outside any scope (unit tests,
//!   benches, plain library use) sees a no-op.
//!
//! Tasks already fanned out to pool workers don't see the runner
//! thread's scope; chunk producers instead capture [`active`] at
//! stream-construction time (on the runner thread) and short-circuit
//! their per-chunk work once the token trips, so a cancelled job's
//! residual tasks degrade to near-free no-ops instead of burning pool
//! capacity.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Shared cancellation flag. Cloning shares the flag.
#[derive(Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Trip the flag. Idempotent; never blocks.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }

    /// Unwind with the [`Cancelled`] marker if the flag is tripped —
    /// the explicit safe point for loops that hold a token.
    pub fn checkpoint(&self) {
        if self.is_cancelled() {
            std::panic::panic_any(Cancelled);
        }
    }
}

/// Panic payload marking a cooperative-cancellation unwind. Private to
/// the crate's classification logic by convention: anything catching
/// panics at a job boundary should test [`was_cancelled`] before
/// treating the payload as a crash.
pub struct Cancelled;

/// Whether a caught panic payload is the cancellation marker.
pub fn was_cancelled(payload: &(dyn std::any::Any + Send)) -> bool {
    payload.is::<Cancelled>()
}

thread_local! {
    static SCOPE: RefCell<Vec<CancelToken>> = const { RefCell::new(Vec::new()) };
}

/// RAII installer for the ambient token: while alive, [`active`] and
/// the free [`checkpoint`] on this thread observe `token`. Scopes nest
/// (innermost wins).
pub struct CancelScope {
    _priv: (),
}

impl CancelScope {
    pub fn enter(token: CancelToken) -> CancelScope {
        SCOPE.with(|s| s.borrow_mut().push(token));
        CancelScope { _priv: () }
    }
}

impl Drop for CancelScope {
    fn drop(&mut self) {
        SCOPE.with(|s| {
            s.borrow_mut().pop();
        });
    }
}

/// The innermost ambient token installed on this thread, if any.
/// Chunk producers capture this at stream-construction time so their
/// closures can short-circuit on worker threads.
pub fn active() -> Option<CancelToken> {
    SCOPE.with(|s| s.borrow().last().cloned())
}

/// Ambient safe point: unwind with [`Cancelled`] if the innermost
/// scoped token is tripped. A no-op outside any scope.
pub fn checkpoint() {
    if let Some(token) = active() {
        token.checkpoint();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_trips_once_and_stays_tripped() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        t.checkpoint(); // no-op while clear
        let shared = t.clone();
        shared.cancel();
        assert!(t.is_cancelled(), "clones share the flag");
        let p = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t.checkpoint()))
            .expect_err("tripped checkpoint must unwind");
        assert!(was_cancelled(&*p), "payload must be the cancellation marker");
    }

    #[test]
    fn ambient_scope_installs_and_restores() {
        assert!(active().is_none());
        checkpoint(); // no-op outside any scope
        let outer = CancelToken::new();
        {
            let _s = CancelScope::enter(outer.clone());
            assert!(active().is_some());
            let inner = CancelToken::new();
            inner.cancel();
            {
                let _s2 = CancelScope::enter(inner);
                let p = std::panic::catch_unwind(checkpoint).expect_err("inner token tripped");
                assert!(was_cancelled(&*p));
            }
            // Inner scope popped: the clear outer token is back.
            checkpoint();
        }
        assert!(active().is_none(), "scope must restore on drop");
    }

    #[test]
    fn ordinary_panics_are_not_cancellation() {
        let p = std::panic::catch_unwind(|| panic!("boom")).expect_err("panics");
        assert!(!was_cancelled(&*p));
    }
}

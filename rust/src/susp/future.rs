//! The Future monad: a value computed asynchronously from the moment of
//! construction (§1, Figure 1 of the paper).
//!
//! Scala's `Future` is completion-callback based; `Await.result` blocks
//! with `scala.concurrent.blocking` so the pool compensates. [`Fut`]
//! mirrors that:
//!
//! * `Fut::spawn(exec, f)` schedules `f` immediately.
//! * `map`/`and_then` attach continuations — executed inline if already
//!   complete, otherwise registered; **no worker thread ever parks to
//!   implement `map`**, which is what lets `par(1)` run arbitrarily deep
//!   pipelines.
//! * `force` parks the caller (condvar) under managed blocking — the
//!   paper's `Await.result(tl, Duration.Inf)`.
//!
//! The completed value lives in a write-once [`OnceLock`] *outside* the
//! callback mutex, so `force` hands out plain shared references with no
//! aliasing hazards and readers never contend once complete.

use std::sync::{Arc, Condvar, Mutex, OnceLock};

use super::{Eval, Susp};
use crate::exec::Executor;

/// Turn a panic payload into a printable message.
pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

type Callback<T> = Box<dyn FnOnce(&Result<T, String>) + Send + 'static>;

struct Inner<T> {
    /// Write-once result; `Err` carries the producing task's panic message.
    value: OnceLock<Result<T, String>>,
    /// Callbacks registered before completion. `None` after completion.
    pending: Mutex<Option<Vec<Callback<T>>>>,
    done: Condvar,
    exec: Executor,
}

/// A value being computed asynchronously on an [`Executor`].
pub struct Fut<T>(Arc<Inner<T>>);

impl<T> Clone for Fut<T> {
    fn clone(&self) -> Self {
        Fut(Arc::clone(&self.0))
    }
}

impl<T: Send + Sync + 'static> Fut<T> {
    /// Schedule `f` on `exec` immediately; the returned future completes
    /// when it finishes.
    pub fn spawn<F: FnOnce() -> T + Send + 'static>(exec: &Executor, f: F) -> Self {
        let fut = Fut::incomplete(exec.clone());
        let completer = fut.clone();
        exec.spawn(move || {
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
                .map_err(|p| panic_message(&*p));
            completer.complete(res);
        });
        fut
    }

    /// An already-completed future (`Future.successful`).
    pub fn ready(exec: &Executor, value: T) -> Self {
        let fut = Fut::incomplete(exec.clone());
        fut.complete(Ok(value));
        fut
    }

    fn incomplete(exec: Executor) -> Self {
        Fut(Arc::new(Inner {
            value: OnceLock::new(),
            pending: Mutex::new(Some(Vec::new())),
            done: Condvar::new(),
            exec,
        }))
    }

    /// Complete with `res`; runs registered callbacks on the calling
    /// thread (which is a pool worker for spawned futures, matching
    /// Scala's run-on-the-EC behaviour).
    fn complete(&self, res: Result<T, String>) {
        self.0.value.set(res).ok().expect("future completed twice");
        let callbacks = {
            let mut pending = self.0.pending.lock().unwrap();
            pending.take().expect("future completed twice")
        };
        self.0.done.notify_all();
        let res = self.0.value.get().expect("just set");
        for cb in callbacks {
            cb(res);
        }
    }

    /// Register `cb` to run with the result; runs inline when already
    /// complete.
    pub fn on_complete<F: FnOnce(&Result<T, String>) + Send + 'static>(&self, cb: F) {
        {
            let mut pending = self.0.pending.lock().unwrap();
            if let Some(cbs) = pending.as_mut() {
                cbs.push(Box::new(cb));
                return;
            }
        }
        cb(self.0.value.get().expect("no pending list implies completed"));
    }

    /// Pipeline a transformation: the returned future completes with
    /// `f(value)` once `self` completes. No thread parks; the continuation
    /// runs as its own pool task (the paper's `map` creates a *new*
    /// parallel stage — running it inline on the completer would
    /// serialize the pipeline).
    pub fn and_then<U, F>(&self, f: F) -> Fut<U>
    where
        U: Send + Sync + 'static,
        F: FnOnce(T) -> U + Send + 'static,
        T: Clone,
    {
        let out = Fut::incomplete(self.0.exec.clone());
        let completer = out.clone();
        self.on_complete(move |res| match res {
            Ok(v) => {
                let v = v.clone();
                let exec = completer.0.exec.clone();
                let completer2 = completer.clone();
                exec.spawn(move || {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || f(v)))
                        .map_err(|p| panic_message(&*p));
                    completer2.complete(r);
                });
            }
            Err(e) => completer.complete(Err(e.clone())),
        });
        out
    }

    /// Monadic bind over futures (callback-chained, non-blocking). Used by
    /// the paper's `plus` for `for (sx <- tailx; sy <- taily) yield ...`.
    pub fn bind<U, F>(&self, f: F) -> Fut<U>
    where
        U: Clone + Send + Sync + 'static,
        F: FnOnce(T) -> Fut<U> + Send + 'static,
        T: Clone,
    {
        let out = Fut::incomplete(self.0.exec.clone());
        let completer = out.clone();
        self.on_complete(move |res| match res {
            Ok(v) => {
                let v = v.clone();
                let exec = completer.0.exec.clone();
                let completer2 = completer.clone();
                exec.spawn(move || {
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || f(v))) {
                        Ok(mid) => {
                            let completer3 = completer2.clone();
                            mid.on_complete(move |r| completer3.complete(r.clone()));
                        }
                        Err(p) => completer2.complete(Err(panic_message(&*p))),
                    }
                });
            }
            Err(e) => completer.complete(Err(e.clone())),
        });
        out
    }

    /// The executor this future's continuations run on.
    pub fn executor(&self) -> &Executor {
        &self.0.exec
    }
}

impl<T: Send + Sync + 'static> Susp<T> for Fut<T> {
    /// `Await.result(self, Duration.Inf)` — parks under managed blocking,
    /// so calling it from a worker cannot starve the pool (§6: "this is
    /// not considered good in a regular use of Futures, but we have not
    /// been able to avoid it").
    fn force(&self) -> &T {
        if self.0.value.get().is_none() {
            Executor::blocking(|| {
                let mut pending = self.0.pending.lock().unwrap();
                while pending.is_some() {
                    pending = self.0.done.wait(pending).unwrap();
                }
            });
        }
        match self.0.value.get().expect("woken implies completed") {
            Ok(v) => v,
            Err(msg) => panic!("forced a failed Future: {msg}"),
        }
    }

    fn is_ready(&self) -> bool {
        self.0.value.get().is_some()
    }

    fn into_ready(self) -> Option<T> {
        let inner = Arc::try_unwrap(self.0).ok()?;
        match inner.value.into_inner()? {
            Ok(v) => Some(v),
            Err(_) => None,
        }
    }
}

/// Strategy selecting [`Fut`] suspensions — the paper's parallel mode
/// (`par(n)` columns of Table 1). Carries the executor the way Scala code
/// carries an implicit `ExecutionContext`.
#[derive(Clone, Debug)]
pub struct FutureEval {
    exec: Executor,
}

impl FutureEval {
    pub fn new(exec: Executor) -> Self {
        FutureEval { exec }
    }
}

impl Eval for FutureEval {
    type Cell<T: Send + Sync + 'static> = Fut<T>;

    fn suspend<T, F>(&self, f: F) -> Fut<T>
    where
        T: Send + Sync + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        Fut::spawn(&self.exec, f)
    }

    fn ready<T>(&self, value: T) -> Fut<T>
    where
        T: Send + Sync + 'static,
    {
        Fut::ready(&self.exec, value)
    }

    fn map<T, U, F>(&self, cell: &Fut<T>, f: F) -> Fut<U>
    where
        T: Clone + Send + Sync + 'static,
        U: Send + Sync + 'static,
        F: FnOnce(T) -> U + Send + 'static,
    {
        cell.and_then(f)
    }

    fn flat_map<T, U, F>(&self, cell: &Fut<T>, f: F) -> Fut<U>
    where
        T: Clone + Send + Sync + 'static,
        U: Clone + Send + Sync + 'static,
        F: FnOnce(T) -> Fut<U> + Send + 'static,
    {
        cell.bind(f)
    }

    fn executor(&self) -> Option<&Executor> {
        Some(&self.exec)
    }

    fn label(&self) -> String {
        format!("par({})", self.exec.parallelism())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn force_blocks_until_complete() {
        let ex = Executor::new(2);
        let fut = Fut::spawn(&ex, || {
            std::thread::sleep(Duration::from_millis(30));
            99
        });
        assert_eq!(*fut.force(), 99);
    }

    #[test]
    fn map_chain_completes_without_forcing() {
        let ex = Executor::new(2);
        let base = Fut::spawn(&ex, || 1u64);
        let mut cur = base;
        for _ in 0..100 {
            cur = cur.and_then(|x| x + 1);
        }
        assert_eq!(*cur.force(), 101);
    }

    #[test]
    fn deep_pipeline_on_one_worker() {
        // Callback chaining means par(1) can run a deep dependency chain:
        // nothing parks a worker except explicit force.
        let ex = Executor::new(1);
        let mut cur = Fut::spawn(&ex, || 0u64);
        for _ in 0..2_000 {
            cur = cur.and_then(|x| x + 1);
        }
        assert_eq!(*cur.force(), 2_000);
    }

    #[test]
    fn bind_sequences_futures() {
        let ex = Executor::new(2);
        let ex2 = ex.clone();
        let fut = Fut::spawn(&ex, || 6).bind(move |x| Fut::spawn(&ex2, move || x * 7));
        assert_eq!(*fut.force(), 42);
    }

    #[test]
    #[should_panic(expected = "failed Future")]
    fn failed_future_panics_at_force() {
        let ex = Executor::new(1);
        let fut: Fut<u32> = Fut::spawn(&ex, || panic!("task died"));
        fut.force();
    }

    #[test]
    fn failure_propagates_through_map() {
        let ex = Executor::new(1);
        let fut: Fut<u32> = Fut::spawn(&ex, || panic!("root cause"));
        let mapped = fut.and_then(|x| x + 1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| *mapped.force()));
        assert!(r.is_err());
    }

    #[test]
    fn on_complete_runs_inline_when_done() {
        let ex = Executor::new(1);
        let fut = Fut::ready(&ex, 5);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        fut.on_complete(move |r| {
            assert_eq!(*r.as_ref().unwrap(), 5);
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn many_concurrent_futures() {
        let ex = Executor::new(4);
        let futs: Vec<Fut<usize>> =
            (0..500).map(|i| Fut::spawn(&ex, move || i * i)).collect();
        for (i, f) in futs.iter().enumerate() {
            assert_eq!(*f.force(), i * i);
        }
    }

    #[test]
    fn force_from_worker_uses_managed_blocking() {
        // A worker forcing a future produced by a queued task: par(1)
        // would deadlock without compensation.
        let ex = Executor::new(1);
        let eval = FutureEval::new(ex.clone());
        let inner = eval.suspend(|| 11);
        let outer = eval.suspend(move || *inner.force() * 2);
        assert_eq!(*outer.force(), 22);
    }

    #[test]
    fn callbacks_registered_concurrently_all_fire() {
        let ex = Executor::new(4);
        let fut = Fut::spawn(&ex, || {
            std::thread::sleep(Duration::from_millis(10));
            1u32
        });
        let hits = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..16 {
                let fut = fut.clone();
                let hits = hits.clone();
                s.spawn(move || {
                    fut.on_complete(move |_| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    });
                });
            }
        });
        fut.force();
        ex.wait_idle();
        assert_eq!(hits.load(Ordering::SeqCst), 16);
    }
}

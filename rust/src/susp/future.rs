//! The Future monad: a value computed asynchronously from the moment of
//! construction (§1, Figure 1 of the paper).
//!
//! Scala's `Future` is completion-callback based; `Await.result` blocks
//! with `scala.concurrent.blocking` so the pool compensates. [`Fut`]
//! mirrors that:
//!
//! * `Fut::spawn(exec, f)` schedules `f` immediately.
//! * `map`/`and_then` attach continuations — executed inline if already
//!   complete, otherwise registered; **no worker thread ever parks to
//!   implement `map`**, which is what lets `par(1)` run arbitrarily deep
//!   pipelines.
//! * `force` parks the caller (condvar) under managed blocking — the
//!   paper's `Await.result(tl, Duration.Inf)`.
//!
//! The cell is an atomic state machine:
//!
//! ```text
//! EMPTY ──(worker picks task up)──▶ RUNNING ──▶ READY
//!   │                                  └──────▶ PANICKED
//!   └──(completed inline / ready())──────────▶ READY | PANICKED
//! ```
//!
//! `state` is a single `AtomicU8` published with Release ordering *after*
//! the value is written to its `OnceLock`, so `is_ready`, `try_result`,
//! the `force` fast path, and the inline branch of `on_complete` are all
//! lock-free loads. The callback `Mutex` is only touched on the slow
//! (still-pending) path: registering a callback before completion, or
//! parking a forcing thread. Already-complete cells built by
//! [`Fut::ready`] / the inline `and_then` fast path never allocate a
//! callback list at all.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use super::{Eval, Susp};
use crate::exec::Executor;

/// Turn a panic payload into a printable message.
pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Observable lifecycle of a [`Fut`] cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FutState {
    /// Spawned but not yet picked up by a worker.
    Empty,
    /// A worker is executing the producing closure.
    Running,
    /// Completed with a value.
    Ready,
    /// The producing closure panicked; forcing re-raises.
    Panicked,
}

const EMPTY: u8 = 0;
const RUNNING: u8 = 1;
const READY: u8 = 2;
const PANICKED: u8 = 3;

type Callback<T> = Box<dyn FnOnce(&Result<T, String>) + Send + 'static>;

thread_local! {
    /// Depth of nested inline completions on this thread. Stream
    /// combinators recurse through `Eval::map` (`map_elems` builds the
    /// next cell inside the mapped closure); over an already-complete
    /// spine the inline fast path would turn that into caller-stack
    /// recursion as deep as the stream. Past [`MAX_INLINE_DEPTH`] the
    /// fast path defers to the task-spawn slow path, which unwinds the
    /// stack and continues on a fresh worker frame (a trampoline).
    static INLINE_DEPTH: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// Inline completions nest at most this deep before trampolining.
///
/// The bound trades spawn amortization against parallelism over
/// already-complete spines: a `map_elems` chain over a ready spine dives
/// through `Eval::map` *before* computing each (possibly heavy) head, so
/// one dive serializes up to `MAX_INLINE_DEPTH` heads onto the current
/// thread, while each trampoline point spawns the next segment's task
/// before this segment unwinds — segments run concurrently. A small
/// bound keeps heavy chunked workloads (few, ~200µs blocks from the
/// adaptive sizer) spread across workers at ~`N/MAX_INLINE_DEPTH`-way
/// concurrency, while cheap post-hoc walks still save 8× on task spawns.
const MAX_INLINE_DEPTH: usize = 8;

struct InlineGuard;

impl InlineGuard {
    fn try_enter() -> Option<InlineGuard> {
        INLINE_DEPTH.with(|d| {
            if d.get() >= MAX_INLINE_DEPTH {
                None
            } else {
                d.set(d.get() + 1);
                Some(InlineGuard)
            }
        })
    }
}

impl Drop for InlineGuard {
    fn drop(&mut self) {
        INLINE_DEPTH.with(|d| d.set(d.get() - 1));
    }
}

struct Inner<T> {
    /// EMPTY → RUNNING → READY/PANICKED. Stored Release after `value` is
    /// set; loaded Acquire on every fast path.
    state: AtomicU8,
    /// Write-once result; `Err` carries the producing task's panic message.
    value: OnceLock<Result<T, String>>,
    /// Callbacks registered before completion. `None` after completion
    /// (and from birth for cells born complete).
    pending: Mutex<Option<Vec<Callback<T>>>>,
    done: Condvar,
    exec: Executor,
}

/// A value being computed asynchronously on an [`Executor`].
pub struct Fut<T>(Arc<Inner<T>>);

impl<T> Clone for Fut<T> {
    fn clone(&self) -> Self {
        Fut(Arc::clone(&self.0))
    }
}

impl<T: Send + Sync + 'static> Fut<T> {
    /// Schedule `f` on `exec` immediately; the returned future completes
    /// when it finishes.
    pub fn spawn<F: FnOnce() -> T + Send + 'static>(exec: &Executor, f: F) -> Self {
        let fut = Fut::incomplete(exec.clone());
        let completer = fut.clone();
        exec.spawn(move || {
            completer.mark_running();
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
                .map_err(|p| panic_message(&*p));
            completer.complete(res);
        });
        fut
    }

    /// An already-completed future (`Future.successful`). Never touches
    /// the executor and never allocates a callback list.
    pub fn ready(exec: &Executor, value: T) -> Self {
        Fut::completed(exec.clone(), Ok(value))
    }

    fn incomplete(exec: Executor) -> Self {
        Fut(Arc::new(Inner {
            state: AtomicU8::new(EMPTY),
            value: OnceLock::new(),
            pending: Mutex::new(Some(Vec::new())),
            done: Condvar::new(),
            exec,
        }))
    }

    /// A cell born complete (fast paths; nothing to synchronize — the
    /// `Arc` publication orders the plain stores for any later reader).
    fn completed(exec: Executor, res: Result<T, String>) -> Self {
        let state = if res.is_ok() { READY } else { PANICKED };
        let inner = Inner {
            state: AtomicU8::new(state),
            value: OnceLock::new(),
            pending: Mutex::new(None),
            done: Condvar::new(),
            exec,
        };
        inner.value.set(res).ok().expect("fresh OnceLock accepts one set");
        Fut(Arc::new(inner))
    }

    fn mark_running(&self) {
        // Only meaningful from EMPTY; completion may already have been
        // observed by nobody else, so a failed CAS is fine (and
        // impossible in practice: the worker owns the transition).
        let _ = self.0.state.compare_exchange(
            EMPTY,
            RUNNING,
            Ordering::AcqRel,
            Ordering::Relaxed,
        );
    }

    /// Current lifecycle state (lock-free).
    pub fn state(&self) -> FutState {
        match self.0.state.load(Ordering::Acquire) {
            EMPTY => FutState::Empty,
            RUNNING => FutState::Running,
            READY => FutState::Ready,
            _ => FutState::Panicked,
        }
    }

    /// Lock-free peek: `Some` once complete, `None` while pending. Never
    /// blocks, never takes the callback lock.
    pub fn try_result(&self) -> Option<&Result<T, String>> {
        if self.0.state.load(Ordering::Acquire) >= READY {
            Some(self.0.value.get().expect("state READY/PANICKED implies value set"))
        } else {
            None
        }
    }

    /// Complete with `res`; runs registered callbacks on the calling
    /// thread (which is a pool worker for spawned futures, matching
    /// Scala's run-on-the-EC behaviour).
    fn complete(&self, res: Result<T, String>) {
        let state = if res.is_ok() { READY } else { PANICKED };
        self.0.value.set(res).ok().expect("future completed twice");
        // Publish the value before taking the callback list: a registrant
        // that misses the pending list must find the value ready.
        self.0.state.store(state, Ordering::Release);
        let callbacks = {
            let mut pending = self.0.pending.lock().unwrap();
            pending.take().expect("future completed twice")
        };
        self.0.done.notify_all();
        let res = self.0.value.get().expect("just set");
        for cb in callbacks {
            cb(res);
        }
    }

    /// Register `cb` to run with the result; runs inline when already
    /// complete (without touching the callback lock).
    pub fn on_complete<F: FnOnce(&Result<T, String>) + Send + 'static>(&self, cb: F) {
        if let Some(res) = self.try_result() {
            cb(res);
            return;
        }
        {
            let mut pending = self.0.pending.lock().unwrap();
            if let Some(cbs) = pending.as_mut() {
                cbs.push(Box::new(cb));
                return;
            }
        }
        cb(self.0.value.get().expect("no pending list implies completed"));
    }

    /// Pipeline a transformation: the returned future completes with
    /// `f(value)` once `self` completes.
    ///
    /// * **Source still pending** (the pipeline-parallel case): no thread
    ///   parks; the continuation runs as its own pool task (the paper's
    ///   `map` creates a *new* parallel stage — running it inline on the
    ///   completer would serialize the pipeline).
    /// * **Source already complete**: there is no pipeline left to
    ///   overlap with, so `f` runs inline on the caller and the result
    ///   cell is born complete — no task spawn, no callback list, no
    ///   condvar. This is the inline-completion fast path `FutureEval::
    ///   map` relies on to make post-hoc walks over finished streams
    ///   cheap.
    pub fn and_then<U, F>(&self, f: F) -> Fut<U>
    where
        U: Send + Sync + 'static,
        F: FnOnce(T) -> U + Send + 'static,
        T: Clone,
    {
        if let Some(res) = self.try_result() {
            match res {
                Ok(v) => {
                    // Bounded: past MAX_INLINE_DEPTH fall through to the
                    // spawn path, which trampolines onto a worker stack.
                    if let Some(_guard) = InlineGuard::try_enter() {
                        let v = v.clone();
                        let out =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || f(v)))
                                .map_err(|p| panic_message(&*p));
                        return Fut::completed(self.0.exec.clone(), out);
                    }
                }
                Err(e) => return Fut::completed(self.0.exec.clone(), Err(e.clone())),
            }
        }
        let out = Fut::incomplete(self.0.exec.clone());
        let completer = out.clone();
        self.on_complete(move |res| match res {
            Ok(v) => {
                let v = v.clone();
                let exec = completer.0.exec.clone();
                let completer2 = completer.clone();
                exec.spawn(move || {
                    completer2.mark_running();
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || f(v)))
                        .map_err(|p| panic_message(&*p));
                    completer2.complete(r);
                });
            }
            Err(e) => completer.complete(Err(e.clone())),
        });
        out
    }

    /// Monadic bind over futures (callback-chained, non-blocking). Used by
    /// the paper's `plus` for `for (sx <- tailx; sy <- taily) yield ...`.
    /// Same inline fast path as [`Fut::and_then`]: a complete source runs
    /// `f` on the caller and returns the inner future directly (zero new
    /// cells on success).
    pub fn bind<U, F>(&self, f: F) -> Fut<U>
    where
        U: Clone + Send + Sync + 'static,
        F: FnOnce(T) -> Fut<U> + Send + 'static,
        T: Clone,
    {
        if let Some(res) = self.try_result() {
            match res {
                Ok(v) => {
                    if let Some(_guard) = InlineGuard::try_enter() {
                        let v = v.clone();
                        return match std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            move || f(v),
                        )) {
                            Ok(mid) => mid,
                            Err(p) => {
                                Fut::completed(self.0.exec.clone(), Err(panic_message(&*p)))
                            }
                        };
                    }
                }
                Err(e) => return Fut::completed(self.0.exec.clone(), Err(e.clone())),
            }
        }
        let out = Fut::incomplete(self.0.exec.clone());
        let completer = out.clone();
        self.on_complete(move |res| match res {
            Ok(v) => {
                let v = v.clone();
                let exec = completer.0.exec.clone();
                let completer2 = completer.clone();
                exec.spawn(move || {
                    completer2.mark_running();
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || f(v))) {
                        Ok(mid) => {
                            let completer3 = completer2.clone();
                            mid.on_complete(move |r| completer3.complete(r.clone()));
                        }
                        Err(p) => completer2.complete(Err(panic_message(&*p))),
                    }
                });
            }
            Err(e) => completer.complete(Err(e.clone())),
        });
        out
    }

    /// The executor this future's continuations run on.
    pub fn executor(&self) -> &Executor {
        &self.0.exec
    }

    /// Block until complete and return the raw outcome — [`Susp::force`]
    /// without the re-raise: a failed cell comes back as `Err`, not a
    /// panic. Parks under managed blocking like `force`; the ready case
    /// is a single Acquire load.
    pub fn wait_result(&self) -> &Result<T, String> {
        if self.0.state.load(Ordering::Acquire) < READY {
            Executor::blocking(|| {
                let mut pending = self.0.pending.lock().unwrap();
                while pending.is_some() {
                    pending = self.0.done.wait(pending).unwrap();
                }
            });
        }
        self.0.value.get().expect("woken implies completed")
    }

    /// Bounded [`Fut::wait_result`]: block for at most `timeout`, then
    /// give up. `Some` carries the raw outcome (value or failure message)
    /// exactly as `wait_result` would have returned it; `None` means the
    /// future is still pending — the caller keeps the handle and may wait
    /// again later. Parks under managed blocking so calling it from a
    /// pool worker cannot starve the pool; the ready case is a single
    /// Acquire load.
    pub fn wait_timeout(&self, timeout: std::time::Duration) -> Option<&Result<T, String>> {
        if self.0.state.load(Ordering::Acquire) < READY {
            let deadline = std::time::Instant::now() + timeout;
            let completed = Executor::blocking(|| {
                let mut pending = self.0.pending.lock().unwrap();
                // `pending` is `None` from the moment `complete` takes the
                // callback list, so `is_some` doubles as "still pending".
                while pending.is_some() {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        return false;
                    }
                    let (guard, res) =
                        self.0.done.wait_timeout(pending, deadline - now).unwrap();
                    pending = guard;
                    if res.timed_out() && pending.is_some() {
                        return false;
                    }
                }
                true
            });
            if !completed {
                return None;
            }
        }
        Some(self.0.value.get().expect("woken implies completed"))
    }

    /// An explicitly-completed cell: the future/promise pair. The
    /// returned [`Fut`] behaves exactly like a spawned one (lock-free
    /// ready paths, inline `and_then`/`bind` fast paths, managed-blocking
    /// `force`), but nothing is scheduled — the producer completes it
    /// through the [`FutPromise`] whenever it finishes. This is what lets
    /// layers *above* the stream machinery (the coordinator's
    /// [`JobTicket`](crate::coordinator::JobTicket)) hand out the same
    /// future cells the paper's cons cells are built from.
    pub fn promise(exec: &Executor) -> (Fut<T>, FutPromise<T>) {
        let fut = Fut::incomplete(exec.clone());
        (fut.clone(), FutPromise { fut, completed: false })
    }
}

/// The producer half of [`Fut::promise`]: single-use, not cloneable, and
/// self-failing — dropping an unfulfilled promise completes the future
/// with an error instead of stranding its waiters forever (a runner
/// thread that panics or a pipeline that shuts down mid-queue still
/// resolves every ticket).
pub struct FutPromise<T: Send + Sync + 'static> {
    fut: Fut<T>,
    completed: bool,
}

impl<T: Send + Sync + 'static> FutPromise<T> {
    /// Complete the paired future with `value`; registered callbacks run
    /// inline on this thread (the run-on-the-completer behaviour of
    /// [`Fut::complete`]).
    pub fn fulfill(mut self, value: T) {
        self.completed = true;
        self.fut.mark_running();
        self.fut.complete(Ok(value));
    }

    /// Complete the paired future as failed; forcing it re-raises `msg`.
    pub fn fail(mut self, msg: impl Into<String>) {
        self.completed = true;
        self.fut.mark_running();
        self.fut.complete(Err(msg.into()));
    }

    /// Mark the paired future as being produced (`Empty` → `Running`),
    /// so observers polling [`Fut::state`] can tell in-progress from
    /// still-queued. Idempotent; completion overwrites it either way.
    pub fn start(&self) {
        self.fut.mark_running();
    }

    /// The paired future (for producers that also observe).
    pub fn fut(&self) -> &Fut<T> {
        &self.fut
    }
}

impl<T: Send + Sync + 'static> Drop for FutPromise<T> {
    fn drop(&mut self) {
        if !self.completed {
            self.fut.mark_running();
            self.fut.complete(Err("promise dropped before completion".to_string()));
        }
    }
}

impl<T: Send + Sync + 'static> Susp<T> for Fut<T> {
    /// `Await.result(self, Duration.Inf)` — parks under managed blocking,
    /// so calling it from a worker cannot starve the pool (§6: "this is
    /// not considered good in a regular use of Futures, but we have not
    /// been able to avoid it"). The ready case is a single Acquire load.
    fn force(&self) -> &T {
        match self.wait_result() {
            Ok(v) => v,
            Err(msg) => panic!("forced a failed Future: {msg}"),
        }
    }

    fn is_ready(&self) -> bool {
        self.0.state.load(Ordering::Acquire) >= READY
    }

    fn into_ready(self) -> Option<T> {
        let inner = Arc::try_unwrap(self.0).ok()?;
        match inner.value.into_inner()? {
            Ok(v) => Some(v),
            Err(_) => None,
        }
    }
}

/// Strategy selecting [`Fut`] suspensions — the paper's parallel mode
/// (`par(n)` columns of Table 1). Carries the executor the way Scala code
/// carries an implicit `ExecutionContext`.
#[derive(Clone, Debug)]
pub struct FutureEval {
    exec: Executor,
}

impl FutureEval {
    pub fn new(exec: Executor) -> Self {
        FutureEval { exec }
    }
}

impl Eval for FutureEval {
    type Cell<T: Send + Sync + 'static> = Fut<T>;

    fn suspend<T, F>(&self, f: F) -> Fut<T>
    where
        T: Send + Sync + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        Fut::spawn(&self.exec, f)
    }

    fn ready<T>(&self, value: T) -> Fut<T>
    where
        T: Send + Sync + 'static,
    {
        Fut::ready(&self.exec, value)
    }

    /// Callback chaining; inline completion when the source is already
    /// ready (see [`Fut::and_then`]).
    fn map<T, U, F>(&self, cell: &Fut<T>, f: F) -> Fut<U>
    where
        T: Clone + Send + Sync + 'static,
        U: Send + Sync + 'static,
        F: FnOnce(T) -> U + Send + 'static,
    {
        cell.and_then(f)
    }

    fn flat_map<T, U, F>(&self, cell: &Fut<T>, f: F) -> Fut<U>
    where
        T: Clone + Send + Sync + 'static,
        U: Clone + Send + Sync + 'static,
        F: FnOnce(T) -> Fut<U> + Send + 'static,
    {
        cell.bind(f)
    }

    fn executor(&self) -> Option<&Executor> {
        Some(&self.exec)
    }

    fn label(&self) -> String {
        format!("par({})", self.exec.parallelism())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn force_blocks_until_complete() {
        let ex = Executor::new(2);
        let fut = Fut::spawn(&ex, || {
            std::thread::sleep(Duration::from_millis(30));
            99
        });
        assert_eq!(*fut.force(), 99);
        assert_eq!(fut.state(), FutState::Ready);
    }

    #[test]
    fn map_chain_completes_without_forcing() {
        let ex = Executor::new(2);
        let base = Fut::spawn(&ex, || 1u64);
        let mut cur = base;
        for _ in 0..100 {
            cur = cur.and_then(|x| x + 1);
        }
        assert_eq!(*cur.force(), 101);
    }

    #[test]
    fn deep_pipeline_on_one_worker() {
        // Callback chaining means par(1) can run a deep dependency chain:
        // nothing parks a worker except explicit force.
        let ex = Executor::new(1);
        let mut cur = Fut::spawn(&ex, || 0u64);
        for _ in 0..2_000 {
            cur = cur.and_then(|x| x + 1);
        }
        assert_eq!(*cur.force(), 2_000);
    }

    #[test]
    fn bind_sequences_futures() {
        let ex = Executor::new(2);
        let ex2 = ex.clone();
        let fut = Fut::spawn(&ex, || 6).bind(move |x| Fut::spawn(&ex2, move || x * 7));
        assert_eq!(*fut.force(), 42);
    }

    #[test]
    #[should_panic(expected = "failed Future")]
    fn failed_future_panics_at_force() {
        let ex = Executor::new(1);
        let fut: Fut<u32> = Fut::spawn(&ex, || panic!("task died"));
        fut.force();
    }

    #[test]
    fn failure_propagates_through_map() {
        let ex = Executor::new(1);
        let fut: Fut<u32> = Fut::spawn(&ex, || panic!("root cause"));
        let mapped = fut.and_then(|x| x + 1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| *mapped.force()));
        assert!(r.is_err());
    }

    #[test]
    fn failure_propagates_through_inline_map() {
        // Same, but the map is attached after the failure is complete, so
        // it takes the inline fast path.
        let ex = Executor::new(1);
        let fut: Fut<u32> = Fut::spawn(&ex, || panic!("root cause"));
        ex.wait_idle();
        assert_eq!(fut.state(), FutState::Panicked);
        let mapped = fut.and_then(|x| x + 1);
        assert_eq!(mapped.state(), FutState::Panicked);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| *mapped.force()));
        assert!(r.is_err());
    }

    #[test]
    fn ready_source_maps_inline_on_caller() {
        let ex = Executor::new(2);
        let fut = Fut::ready(&ex, 5u32);
        let caller = std::thread::current().id();
        let ran_on = Arc::new(Mutex::new(None));
        let ran_on2 = ran_on.clone();
        let mapped = fut.and_then(move |x| {
            *ran_on2.lock().unwrap() = Some(std::thread::current().id());
            x * 2
        });
        // Born complete: no task was spawned, f already ran, on the caller.
        assert!(mapped.is_ready());
        assert_eq!(*mapped.force(), 10);
        assert_eq!(ran_on.lock().unwrap().unwrap(), caller);
    }

    #[test]
    fn inline_map_panic_is_contained() {
        let ex = Executor::new(1);
        let fut = Fut::ready(&ex, 1u32);
        let mapped: Fut<u32> = fut.and_then(|_| panic!("inline boom"));
        assert_eq!(mapped.state(), FutState::Panicked);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| *mapped.force()));
        assert!(r.is_err());
    }

    #[test]
    fn bind_on_ready_source_returns_inner_directly() {
        let ex = Executor::new(2);
        let ex2 = ex.clone();
        let fut = Fut::ready(&ex, 6u32);
        let out = fut.bind(move |x| Fut::ready(&ex2, x * 7));
        assert_eq!(*out.force(), 42);
    }

    #[test]
    fn state_machine_transitions() {
        let ex = Executor::new(1);
        let fut = Fut::ready(&ex, 1u32);
        assert_eq!(fut.state(), FutState::Ready);
        assert!(fut.try_result().is_some());
        // Gate the producer on a channel so the pending observation
        // cannot race the worker (no sleep-based timing).
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let slow = Fut::spawn(&ex, move || {
            rx.recv().unwrap();
            2u32
        });
        // Pending from the outside: Empty or Running, never Ready.
        assert!(matches!(slow.state(), FutState::Empty | FutState::Running));
        assert!(slow.try_result().is_none());
        tx.send(()).unwrap();
        slow.force();
        assert_eq!(slow.state(), FutState::Ready);
    }

    #[test]
    fn on_complete_runs_inline_when_done() {
        let ex = Executor::new(1);
        let fut = Fut::ready(&ex, 5);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        fut.on_complete(move |r| {
            assert_eq!(*r.as_ref().unwrap(), 5);
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn many_concurrent_futures() {
        let ex = Executor::new(4);
        let futs: Vec<Fut<usize>> =
            (0..500).map(|i| Fut::spawn(&ex, move || i * i)).collect();
        for (i, f) in futs.iter().enumerate() {
            assert_eq!(*f.force(), i * i);
        }
    }

    #[test]
    fn force_from_worker_uses_managed_blocking() {
        // A worker forcing a future produced by a queued task: par(1)
        // would deadlock without compensation.
        let ex = Executor::new(1);
        let eval = FutureEval::new(ex.clone());
        let inner = eval.suspend(|| 11);
        let outer = eval.suspend(move || *inner.force() * 2);
        assert_eq!(*outer.force(), 22);
    }

    #[test]
    fn promise_fulfills_waiters_across_threads() {
        let ex = Executor::new(2);
        let (fut, promise) = Fut::<u32>::promise(&ex);
        assert!(matches!(fut.state(), FutState::Empty));
        let waiter = {
            let fut = fut.clone();
            std::thread::spawn(move || *fut.force())
        };
        std::thread::sleep(Duration::from_millis(10));
        promise.fulfill(7);
        assert_eq!(waiter.join().unwrap(), 7);
        assert_eq!(fut.state(), FutState::Ready);
    }

    #[test]
    fn promise_chains_like_any_future() {
        // A promise-backed cell supports the same combinators as a
        // spawned one: continuations attach before completion and fire
        // when the producer fulfills.
        let ex = Executor::new(2);
        let (fut, promise) = Fut::<u32>::promise(&ex);
        let doubled = fut.and_then(|x| x * 2);
        assert!(!doubled.is_ready());
        promise.fulfill(21);
        assert_eq!(*doubled.force(), 42);
    }

    #[test]
    fn promise_fail_and_drop_poison_the_future() {
        let ex = Executor::new(1);
        let (fut, promise) = Fut::<u32>::promise(&ex);
        promise.fail("producer died");
        assert_eq!(fut.state(), FutState::Panicked);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| *fut.force()));
        assert!(r.is_err());
        // Dropping an unfulfilled promise must resolve waiters too.
        let (fut2, promise2) = Fut::<u32>::promise(&ex);
        drop(promise2);
        assert_eq!(fut2.state(), FutState::Panicked);
    }

    #[test]
    fn dropped_promise_fails_dependents_through_and_then_chain() {
        // Simulated runner death: continuations were attached while the
        // promise was alive, then the producer unwinds without fulfilling.
        // Every dependent in the chain must resolve (with the drop-guard
        // failure), not strand its waiters.
        let ex = Executor::new(2);
        let (fut, promise) = Fut::<u32>::promise(&ex);
        let chained = fut.and_then(|x| x + 1).and_then(|x| x * 2);
        assert!(!chained.is_ready());
        drop(promise);
        ex.wait_idle();
        assert_eq!(chained.state(), FutState::Panicked);
        match chained.wait_result() {
            Ok(_) => panic!("dropped promise must fail dependents"),
            Err(msg) => assert!(msg.contains("promise dropped"), "got: {msg}"),
        }
    }

    #[test]
    fn dropped_promise_fails_dependents_through_bind_chain() {
        let ex = Executor::new(2);
        let ex2 = ex.clone();
        let (fut, promise) = Fut::<u32>::promise(&ex);
        let bound = fut.bind(move |x| Fut::spawn(&ex2, move || x * 7));
        assert!(!bound.is_ready());
        drop(promise);
        ex.wait_idle();
        assert_eq!(bound.state(), FutState::Panicked);
        let msg = bound.wait_result().as_ref().expect_err("must fail");
        assert!(msg.contains("promise dropped"), "got: {msg}");
    }

    #[test]
    fn dropped_promise_observed_after_the_fact_still_fails_inline_maps() {
        // A continuation attached *after* the drop takes the inline fast
        // path and must see the same failure.
        let ex = Executor::new(1);
        let (fut, promise) = Fut::<u32>::promise(&ex);
        drop(promise);
        let mapped = fut.and_then(|x| x + 1);
        assert_eq!(mapped.state(), FutState::Panicked);
        let msg = mapped.wait_result().as_ref().expect_err("must fail");
        assert!(msg.contains("promise dropped"), "got: {msg}");
    }

    #[test]
    fn wait_timeout_returns_none_while_pending_and_some_when_done() {
        let ex = Executor::new(2);
        let (fut, promise) = Fut::<u32>::promise(&ex);
        // Pending: a short bounded wait gives up without resolving.
        let before = std::time::Instant::now();
        assert!(fut.wait_timeout(Duration::from_millis(20)).is_none());
        assert!(before.elapsed() >= Duration::from_millis(20));
        // The handle is still usable afterwards.
        promise.fulfill(9);
        match fut.wait_timeout(Duration::from_millis(20)) {
            Some(Ok(v)) => assert_eq!(*v, 9),
            other => panic!("expected Ok(9), got {other:?}"),
        }
        // Ready case never waits.
        let ready = Fut::ready(&ex, 3u32);
        assert_eq!(ready.wait_timeout(Duration::ZERO), Some(&Ok(3)));
    }

    #[test]
    fn wait_timeout_wakes_on_completion_mid_wait() {
        let ex = Executor::new(2);
        let (fut, promise) = Fut::<u32>::promise(&ex);
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(15));
            promise.fulfill(44);
        });
        // Generous bound: completion arrives well before it.
        match fut.wait_timeout(Duration::from_secs(10)) {
            Some(Ok(v)) => assert_eq!(*v, 44),
            other => panic!("expected Ok(44), got {other:?}"),
        }
        producer.join().unwrap();
    }

    #[test]
    fn wait_timeout_surfaces_failures_like_wait_result() {
        let ex = Executor::new(1);
        let (fut, promise) = Fut::<u32>::promise(&ex);
        promise.fail("producer died");
        match fut.wait_timeout(Duration::ZERO) {
            Some(Err(msg)) => assert!(msg.contains("producer died")),
            other => panic!("expected failure, got {other:?}"),
        }
    }

    #[test]
    fn callbacks_registered_concurrently_all_fire() {
        let ex = Executor::new(4);
        let fut = Fut::spawn(&ex, || {
            std::thread::sleep(Duration::from_millis(10));
            1u32
        });
        let hits = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..16 {
                let fut = fut.clone();
                let hits = hits.clone();
                s.spawn(move || {
                    fut.on_complete(move |_| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    });
                });
            }
        });
        fut.force();
        ex.wait_idle();
        assert_eq!(hits.load(Ordering::SeqCst), 16);
    }
}

//! Strict (call-by-value) suspensions: evaluate immediately on the
//! calling thread. Degenerate member of the monad family — useful as a
//! control in tests and in the overhead ablation (`benches/
//! ablation_overhead.rs`): it measures what the algorithms cost with the
//! monadic plumbing but *zero* deferral.

use std::sync::Arc;

use super::{Eval, Susp};

/// An already-evaluated value behind an `Arc`.
pub struct Strict<T>(Arc<T>);

impl<T> Clone for Strict<T> {
    fn clone(&self) -> Self {
        Strict(Arc::clone(&self.0))
    }
}

impl<T: Send + Sync + 'static> Susp<T> for Strict<T> {
    fn force(&self) -> &T {
        &self.0
    }

    fn is_ready(&self) -> bool {
        true
    }

    fn into_ready(self) -> Option<T> {
        Arc::try_unwrap(self.0).ok()
    }
}

/// Strategy that evaluates suspensions immediately (call-by-value).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StrictEval;

impl Eval for StrictEval {
    type Cell<T: Send + Sync + 'static> = Strict<T>;

    fn suspend<T, F>(&self, f: F) -> Strict<T>
    where
        T: Send + Sync + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        Strict(Arc::new(f()))
    }

    fn ready<T>(&self, value: T) -> Strict<T>
    where
        T: Send + Sync + 'static,
    {
        Strict(Arc::new(value))
    }

    fn label(&self) -> String {
        "strict".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::susp::Eval;

    #[test]
    fn strict_evaluates_immediately() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let hit = std::sync::Arc::new(AtomicBool::new(false));
        let h = hit.clone();
        let cell = StrictEval.suspend(move || h.store(true, Ordering::SeqCst));
        assert!(hit.load(Ordering::SeqCst), "strict must run before suspend returns");
        cell.force();
    }

    #[test]
    fn map_applies() {
        let c = StrictEval.ready(2);
        let m = StrictEval.map(&c, |x| x * 21);
        assert_eq!(*m.force(), 42);
    }
}

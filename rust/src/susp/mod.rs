//! Suspension monads — the paper's §3.
//!
//! The paper observes that Scala's `Stream` hides a *suspension* in every
//! cons cell (`tl: => Stream[A]`) and that the by-name parameter behaves
//! like a `Lazy` monad. Abstracting the cell over the monad, and then
//! substituting `Future` for `Lazy`, turns every algorithm written against
//! the monadic interface into a pipeline-parallel one.
//!
//! This module is the Rust rendition:
//!
//! * [`Lazy<T>`] — a memoized thunk; `map` composes thunks. Semantically
//!   the paper's `Lazy` monad (`lazy val apply = value`).
//! * [`Fut<T>`] — a value being computed on an [`Executor`] *starting at
//!   construction time*; `map` chains a continuation (no worker blocks),
//!   [`Fut::force`] is the paper's `Await.result(tl, Duration.Inf)` and
//!   uses managed blocking when called from a worker.
//! * [`Strict<T>`] — evaluate immediately on the calling thread; useful as
//!   a degenerate control in tests and overhead benches.
//!
//! The strategy is selected by an [`Eval`] implementation ([`LazyEval`],
//! [`FutureEval`], [`StrictEval`]); stream code is generic over it, which
//! is the Rust spelling of the paper's "substitute Future for Lazy".

pub mod cancel;
mod future;
mod lazy;
mod strict;

pub use cancel::{CancelScope, CancelToken, Cancelled};
pub use future::{Fut, FutPromise, FutState, FutureEval};
pub use lazy::{Lazy, LazyEval};
pub use strict::{Strict, StrictEval};

/// Render a panic payload as text (re-exported for driver threads that
/// join panicking workloads).
pub fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    future::panic_message(p)
}

use crate::exec::Executor;

/// A forceable suspended value. `force` blocks (for [`Fut`]) or evaluates
/// (for [`Lazy`]) and always memoizes: the closure runs at most once.
///
/// A suspension whose closure panicked re-raises the panic at every
/// `force` site (the paper's failed Future).
pub trait Susp<T>: Clone + Send + Sync + 'static {
    /// Force and return a shared reference to the value.
    fn force(&self) -> &T;

    /// Whether the value has been computed (never blocks).
    fn is_ready(&self) -> bool;

    /// Consume this handle and return the value if it is both computed
    /// and uniquely owned; `None` otherwise (pending, shared, or
    /// poisoned). Used by `Stream`'s iterative `Drop` to dismantle long
    /// cons chains without recursion — never blocks.
    fn into_ready(self) -> Option<T>;
}

/// An evaluation strategy: how to suspend a computation, and how to
/// transform a suspended value without forcing it on the current thread.
/// This is the paper's monad, reified as a strategy object so that
/// [`FutureEval`] can carry its `Executor` (Scala's implicit
/// `ExecutionContext`).
pub trait Eval: Clone + Send + Sync + 'static {
    type Cell<T: Send + Sync + 'static>: Susp<T>;

    /// `Future { value }` / `Lazy { value }`: wrap a computation. For
    /// [`FutureEval`] the computation is scheduled immediately.
    fn suspend<T, F>(&self, f: F) -> Self::Cell<T>
    where
        T: Send + Sync + 'static,
        F: FnOnce() -> T + Send + 'static;

    /// An already-available value (`Future.successful`).
    fn ready<T>(&self, value: T) -> Self::Cell<T>
    where
        T: Send + Sync + 'static;

    /// The monadic `map`: transform the suspended value, preserving
    /// laziness/asynchrony (the consumer of the result must not force the
    /// input on the calling thread). Default goes through [`Eval::suspend`];
    /// [`FutureEval`] overrides it with callback chaining so no worker
    /// thread parks.
    fn map<T, U, F>(&self, cell: &Self::Cell<T>, f: F) -> Self::Cell<U>
    where
        T: Clone + Send + Sync + 'static,
        U: Send + Sync + 'static,
        F: FnOnce(T) -> U + Send + 'static,
    {
        let cell = cell.clone();
        self.suspend(move || f(cell.force().clone()))
    }

    /// The monadic `flatMap` (used by the paper's `plus` for the
    /// `for (sx <- tailx; sy <- taily) yield ...` comprehension).
    ///
    /// Default = `map` then join-via-`map`: stage 1 runs `f` inside the
    /// strategy's own `map` (yielding the inner cell without touching it
    /// on the calling thread), stage 2 chains through `map` of that
    /// stage to extract the value with exactly one force + clone. The
    /// old default did both forces inside a single fresh suspension on
    /// the calling worker, bypassing whatever cheap `map` the strategy
    /// provides. [`FutureEval`] still overrides this with true callback
    /// chaining ([`Fut::bind`]).
    fn flat_map<T, U, F>(&self, cell: &Self::Cell<T>, f: F) -> Self::Cell<U>
    where
        T: Clone + Send + Sync + 'static,
        U: Clone + Send + Sync + 'static,
        F: FnOnce(T) -> Self::Cell<U> + Send + 'static,
    {
        let mid: Self::Cell<Self::Cell<U>> = self.map(cell, f);
        self.map(&mid, |inner| inner.force().clone())
    }

    /// The executor backing this strategy, if any. Sequential strategies
    /// return `None`.
    fn executor(&self) -> Option<&Executor> {
        None
    }

    /// Human-readable name used in reports ("seq", "par(2)", ...).
    fn label(&self) -> String;
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn exercise_strategy<E: Eval>(eval: E) {
        // suspend + force
        let cell = eval.suspend(|| 20 + 1);
        assert_eq!(*cell.force(), 21);
        // memoization: closure runs once
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = count.clone();
        let cell = eval.suspend(move || {
            c2.fetch_add(1, Ordering::SeqCst);
            7
        });
        assert_eq!(*cell.force(), 7);
        assert_eq!(*cell.force(), 7);
        assert_eq!(count.load(Ordering::SeqCst), 1);
        // ready
        let r = eval.ready(5);
        assert_eq!(*r.force(), 5);
        assert!(r.is_ready());
        // map preserves value
        let m = eval.map(&r, |x| x * 3);
        assert_eq!(*m.force(), 15);
        // map chains
        let m2 = eval.map(&m, |x| x + 1);
        assert_eq!(*m2.force(), 16);
        // flat_map
        let eval2 = eval.clone();
        let fm = eval.flat_map(&r, move |x| eval2.ready(x + 100));
        assert_eq!(*fm.force(), 105);
    }

    #[test]
    fn lazy_obeys_susp_contract() {
        exercise_strategy(LazyEval);
    }

    #[test]
    fn strict_obeys_susp_contract() {
        exercise_strategy(StrictEval);
    }

    #[test]
    fn future_obeys_susp_contract() {
        let ex = Executor::new(2);
        exercise_strategy(FutureEval::new(ex));
    }

    #[test]
    fn future_par1_obeys_susp_contract() {
        // par(1): the paper's overhead-isolation configuration. Must not
        // deadlock even though map chains depend on one worker.
        let ex = Executor::new(1);
        exercise_strategy(FutureEval::new(ex));
    }

    #[test]
    fn lazy_is_actually_lazy() {
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = count.clone();
        let cell = LazyEval.suspend(move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(count.load(Ordering::SeqCst), 0, "lazy must not run before force");
        cell.force();
        assert_eq!(count.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn future_starts_eagerly() {
        // The defining difference from Lazy: computation begins at
        // construction (Figure 1 of the paper).
        let ex = Executor::new(2);
        let eval = FutureEval::new(ex.clone());
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = count.clone();
        let _cell = eval.suspend(move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        ex.wait_idle();
        assert_eq!(count.load(Ordering::SeqCst), 1, "future must run without force");
    }

    #[test]
    fn labels() {
        assert_eq!(LazyEval.label(), "seq");
        assert_eq!(StrictEval.label(), "strict");
        let ex = Executor::new(3);
        assert_eq!(FutureEval::new(ex).label(), "par(3)");
    }
}

//! The Lazy monad (§3 of the paper): a memoized thunk.
//!
//! ```text
//! object Future {                          // the paper names it Future
//!   def apply[A](value: => A) = new Future[A] { lazy val apply = value }
//! }
//! ```
//!
//! `Lazy<T>` is exactly `lazy val`: the closure runs on first `force`, on
//! the forcing thread, and the result is memoized. Panics are memoized
//! too (a poisoned `lazy val` in Scala rethrows).

use std::sync::{Arc, Mutex, OnceLock};

use super::{Eval, Susp};

type Thunk<T> = Box<dyn FnOnce() -> T + Send + 'static>;

struct Inner<T> {
    thunk: Mutex<Option<Thunk<T>>>,
    value: OnceLock<Result<T, String>>,
}

/// A memoized, thread-safe suspended value.
pub struct Lazy<T>(Arc<Inner<T>>);

impl<T> Clone for Lazy<T> {
    fn clone(&self) -> Self {
        Lazy(Arc::clone(&self.0))
    }
}

impl<T: Send + Sync + 'static> Lazy<T> {
    /// Suspend `f`; it will run at most once, on the first forcing thread.
    pub fn new<F: FnOnce() -> T + Send + 'static>(f: F) -> Self {
        Lazy(Arc::new(Inner {
            thunk: Mutex::new(Some(Box::new(f))),
            value: OnceLock::new(),
        }))
    }

    /// An already-evaluated value.
    pub fn ready(value: T) -> Self {
        let cell = Lazy(Arc::new(Inner { thunk: Mutex::new(None), value: OnceLock::new() }));
        cell.0.value.set(Ok(value)).ok().expect("fresh OnceLock");
        cell
    }
}

impl<T: Send + Sync + 'static> Susp<T> for Lazy<T> {
    fn force(&self) -> &T {
        let result = self.0.value.get_or_init(|| {
            let thunk = self
                .0
                .thunk
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .take()
                .expect("lazy thunk already taken without value set");
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(thunk)) {
                Ok(v) => Ok(v),
                Err(p) => Err(crate::susp::future::panic_message(&p)),
            }
        });
        match result {
            Ok(v) => v,
            Err(msg) => panic!("forced a poisoned Lazy: {msg}"),
        }
    }

    fn is_ready(&self) -> bool {
        self.0.value.get().is_some()
    }

    fn into_ready(self) -> Option<T> {
        let inner = Arc::try_unwrap(self.0).ok()?;
        match inner.value.into_inner()? {
            Ok(v) => Some(v),
            Err(_) => None,
        }
    }
}

/// Strategy selecting [`Lazy`] suspensions — the paper's sequential mode
/// (`seq` column of Table 1).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LazyEval;

impl Eval for LazyEval {
    type Cell<T: Send + Sync + 'static> = Lazy<T>;

    fn suspend<T, F>(&self, f: F) -> Lazy<T>
    where
        T: Send + Sync + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        Lazy::new(f)
    }

    fn ready<T>(&self, value: T) -> Lazy<T>
    where
        T: Send + Sync + 'static,
    {
        Lazy::ready(value)
    }

    fn label(&self) -> String {
        "seq".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn concurrent_force_runs_thunk_once() {
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        let cell = Lazy::new(move || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            c.fetch_add(1, Ordering::SeqCst)
        });
        std::thread::scope(|s| {
            for _ in 0..8 {
                let cell = cell.clone();
                s.spawn(move || {
                    cell.force();
                });
            }
        });
        assert_eq!(count.load(Ordering::SeqCst), 1);
    }

    #[test]
    #[should_panic(expected = "poisoned Lazy")]
    fn poisoned_lazy_rethrows() {
        let cell: Lazy<u32> = Lazy::new(|| panic!("inner"));
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| cell.force()));
        // Second force observes the poison, not a double-run.
        cell.force();
    }

    #[test]
    fn ready_is_ready() {
        let cell = Lazy::ready(3);
        assert!(cell.is_ready());
        assert_eq!(*cell.force(), 3);
    }

    #[test]
    fn deep_map_chain_does_not_overflow() {
        // Chained maps force iteratively enough for the sieve's depth.
        let mut cell = Lazy::ready(0u64);
        for _ in 0..10_000 {
            let prev = cell.clone();
            cell = Lazy::new(move || prev.force() + 1);
        }
        // Force on a big-stack thread, as stream consumers do.
        let v = std::thread::Builder::new()
            .stack_size(512 << 20)
            .spawn(move || *cell.force())
            .unwrap()
            .join()
            .unwrap();
        assert_eq!(v, 10_000);
    }
}

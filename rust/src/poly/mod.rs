//! Sparse multivariate polynomial algebra — the substrate for the
//! paper's second example (§6) and its evaluation workloads
//! (`stream`, `stream_big`, `list`, `list_big`).
//!
//! The paper uses the *distributive representation*
//! `x = c₀m₀ + c₁m₁ + … + cₙmₙ` with terms ordered by a monomial order;
//! multiplication decomposes into multiply-by-a-term and streaming
//! addition (Figure 2). This module provides:
//!
//! * [`Monomial`] — exponent vectors under graded-lex order;
//! * [`Coeff`] — the coefficient-ring abstraction ([`i64`], [`i128`],
//!   [`BigInt`](crate::bigint::BigInt), [`f64`]); the `_big` workloads
//!   swap rings exactly as the paper swaps `Int` for scaled `BigInt`;
//! * [`Polynomial`] — strict sorted-term polynomials with the classical
//!   iterative arithmetic (the `list` baseline's core);
//! * [`stream_mul`] — the paper's stream algorithm (`times` / `multiply`
//!   / `plus`), generic over the evaluation strategy;
//! * [`list_mul`] — the parallel-collections control [4];
//! * [`chunked_mul`] — the §7 chunking improvement, with a pluggable
//!   dense block multiplier so the AOT Pallas kernel can take the
//!   per-block outer product (see `runtime::KernelMultiplier`).

pub mod chunked_mul;
mod division;
pub mod groebner;
pub mod list_mul;
mod monomial;
mod parse;
mod polynomial;
mod ring;
pub mod stream_mul;

pub use chunked_mul::{
    adaptive_poly_chunk, adaptive_poly_chunk_cached, chunked_times, chunked_times_adaptive,
    chunked_times_adaptive_cached, BlockMultiplier, RustMultiplier, TermBlock,
};
pub use division::FieldCoeff;
pub use list_mul::{list_times_par, list_times_seq};
pub use monomial::Monomial;
pub use parse::parse_polynomial;
pub use polynomial::{Polynomial, Term};
pub use ring::Coeff;
pub use stream_mul::{multiply, plus, stream_times, times, PolyStream};

//! Strict sparse polynomials: sorted term vectors and the classical
//! iterative arithmetic (the optimized imperative implementation the
//! paper's `list` baseline is built on).

use std::collections::BTreeMap;

use super::{Coeff, Monomial};

/// One term `c·m`.
pub type Term<C> = (Monomial, C);

/// Sparse polynomial in distributive representation: terms sorted by
/// monomial order, **descending**, no zero coefficients, no duplicate
/// monomials (canonical form).
#[derive(Debug, Clone, PartialEq)]
pub struct Polynomial<C: Coeff> {
    nvars: usize,
    terms: Vec<Term<C>>,
}

impl<C: Coeff> Polynomial<C> {
    pub fn zero(nvars: usize) -> Self {
        Polynomial { nvars, terms: Vec::new() }
    }

    pub fn one(nvars: usize) -> Self {
        Polynomial { nvars, terms: vec![(Monomial::one(nvars), C::one())] }
    }

    /// The variable `x_i` as a polynomial.
    pub fn var(nvars: usize, i: usize) -> Self {
        Polynomial { nvars, terms: vec![(Monomial::var(nvars, i), C::one())] }
    }

    pub fn constant(nvars: usize, c: C) -> Self {
        if c.is_zero() {
            return Self::zero(nvars);
        }
        Polynomial { nvars, terms: vec![(Monomial::one(nvars), c)] }
    }

    /// Build from arbitrary terms: sorts, combines duplicates, drops
    /// zeros.
    pub fn from_terms(nvars: usize, terms: Vec<Term<C>>) -> Self {
        let mut map: BTreeMap<Monomial, C> = BTreeMap::new();
        for (m, c) in terms {
            assert_eq!(m.nvars(), nvars, "term variable count mismatch");
            match map.get_mut(&m) {
                Some(acc) => *acc = acc.add(&c),
                None => {
                    map.insert(m, c);
                }
            }
        }
        let terms: Vec<Term<C>> =
            map.into_iter().rev().filter(|(_, c)| !c.is_zero()).collect();
        Polynomial { nvars, terms }
    }

    /// Terms in descending monomial order.
    pub fn terms(&self) -> &[Term<C>] {
        &self.terms
    }

    pub fn into_terms(self) -> Vec<Term<C>> {
        self.terms
    }

    pub fn nvars(&self) -> usize {
        self.nvars
    }

    pub fn is_zero(&self) -> bool {
        self.terms.is_empty()
    }

    pub fn num_terms(&self) -> usize {
        self.terms.len()
    }

    /// Leading (largest) monomial.
    pub fn leading(&self) -> Option<&Term<C>> {
        self.terms.first()
    }

    pub fn degree(&self) -> u32 {
        self.terms.iter().map(|(m, _)| m.degree()).max().unwrap_or(0)
    }

    /// Canonical-form check (used by property tests).
    pub fn is_canonical(&self) -> bool {
        self.terms.windows(2).all(|w| w[0].0 > w[1].0)
            && self.terms.iter().all(|(m, c)| !c.is_zero() && m.nvars() == self.nvars)
    }

    // -----------------------------------------------------------------
    // classical arithmetic (merge-based, the `list` baseline's core)
    // -----------------------------------------------------------------

    /// Addition by sorted merge — the imperative counterpart of the
    /// paper's streaming `plus`.
    pub fn add(&self, other: &Polynomial<C>) -> Polynomial<C> {
        assert_eq!(self.nvars, other.nvars, "mixed variable counts");
        let mut out = Vec::with_capacity(self.terms.len() + other.terms.len());
        let (mut i, mut j) = (0, 0);
        while i < self.terms.len() && j < other.terms.len() {
            let (ma, ca) = &self.terms[i];
            let (mb, cb) = &other.terms[j];
            match ma.cmp(mb) {
                std::cmp::Ordering::Greater => {
                    out.push((ma.clone(), ca.clone()));
                    i += 1;
                }
                std::cmp::Ordering::Less => {
                    out.push((mb.clone(), cb.clone()));
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    let c = ca.add(cb);
                    if !c.is_zero() {
                        out.push((ma.clone(), c));
                    }
                    i += 1;
                    j += 1;
                }
            }
        }
        out.extend_from_slice(&self.terms[i..]);
        out.extend(other.terms[j..].iter().cloned());
        Polynomial { nvars: self.nvars, terms: out }
    }

    pub fn sub(&self, other: &Polynomial<C>) -> Polynomial<C> {
        self.add(&other.neg())
    }

    pub fn neg(&self) -> Polynomial<C> {
        Polynomial {
            nvars: self.nvars,
            terms: self.terms.iter().map(|(m, c)| (m.clone(), c.neg())).collect(),
        }
    }

    /// Multiply by one term (`multiply(x, m, c)` in strict form). Order
    /// is preserved because the monomial order is multiplication-
    /// compatible.
    pub fn mul_term(&self, m: &Monomial, c: &C) -> Polynomial<C> {
        if c.is_zero() {
            return Polynomial::zero(self.nvars);
        }
        Polynomial {
            nvars: self.nvars,
            terms: self
                .terms
                .iter()
                .map(|(tm, tc)| (tm.mul(m), tc.mul(c)))
                .filter(|(_, c)| !c.is_zero())
                .collect(),
        }
    }

    /// Classical iterative product: accumulate `x·(b·t)` over the terms
    /// of `other` into a tree map (the well-optimized imperative
    /// implementation the paper credits `list` with being).
    pub fn mul(&self, other: &Polynomial<C>) -> Polynomial<C> {
        assert_eq!(self.nvars, other.nvars, "mixed variable counts");
        let mut acc: BTreeMap<Monomial, C> = BTreeMap::new();
        for (mb, cb) in &other.terms {
            for (ma, ca) in &self.terms {
                let m = ma.mul(mb);
                let c = ca.mul(cb);
                match acc.get_mut(&m) {
                    Some(slot) => *slot = slot.add(&c),
                    None => {
                        acc.insert(m, c);
                    }
                }
            }
        }
        let terms: Vec<Term<C>> =
            acc.into_iter().rev().filter(|(_, c)| !c.is_zero()).collect();
        Polynomial { nvars: self.nvars, terms }
    }

    /// Exponentiation by repeated squaring.
    pub fn pow(&self, mut e: u32) -> Polynomial<C> {
        let mut base = self.clone();
        let mut acc = Polynomial::one(self.nvars);
        while e > 0 {
            if e & 1 == 1 {
                acc = acc.mul(&base);
            }
            e >>= 1;
            if e > 0 {
                base = base.mul(&base);
            }
        }
        acc
    }

    /// Map coefficients into another ring (e.g. `i64 → BigInt` for the
    /// `_big` workloads).
    pub fn map_coeffs<D: Coeff>(&self, f: impl Fn(&C) -> D) -> Polynomial<D> {
        Polynomial {
            nvars: self.nvars,
            terms: self
                .terms
                .iter()
                .map(|(m, c)| (m.clone(), f(c)))
                .filter(|(_, c)| !c.is_zero())
                .collect(),
        }
    }

    /// Scale every coefficient (the paper's ×100000000001 knob).
    pub fn scale(&self, k: &C) -> Polynomial<C> {
        self.mul_term(&Monomial::one(self.nvars), k)
    }
}

impl<C: Coeff> std::fmt::Display for Polynomial<C> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_zero() {
            return f.write_str("0");
        }
        let mut first = true;
        for (m, c) in &self.terms {
            if !first {
                f.write_str(" + ")?;
            }
            first = false;
            if m.is_one() {
                write!(f, "{c}")?;
            } else if *c == C::one() {
                write!(f, "{m}")?;
            } else {
                write!(f, "{c}*{m}")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bigint::BigInt;
    use crate::testkit::prop::{runner, Gen};

    fn xyz() -> (Polynomial<i64>, Polynomial<i64>, Polynomial<i64>) {
        (Polynomial::var(3, 0), Polynomial::var(3, 1), Polynomial::var(3, 2))
    }

    /// Random small polynomial for property tests.
    pub(crate) fn random_poly(g: &mut Gen, nvars: usize, max_terms: usize) -> Polynomial<i64> {
        let terms = g.vec(0..max_terms.max(1), |g| {
            let exps: Vec<u16> = (0..nvars).map(|_| g.u32_in(0..5) as u16).collect();
            (Monomial::from_exps(exps), g.i64_in(-9..=9))
        });
        Polynomial::from_terms(nvars, terms)
    }

    #[test]
    fn canonical_construction() {
        let m = Monomial::from_exps;
        let p = Polynomial::from_terms(
            2,
            vec![
                (m(vec![1, 0]), 2i64),
                (m(vec![0, 1]), 3),
                (m(vec![1, 0]), -2), // cancels the first
                (m(vec![0, 0]), 0),  // dropped
            ],
        );
        assert_eq!(p.num_terms(), 1);
        assert!(p.is_canonical());
        assert_eq!(p.to_string(), "3*y");
    }

    #[test]
    fn add_merges_and_cancels() {
        let (x, y, _) = xyz();
        let a = x.add(&y);
        let b = x.neg();
        assert_eq!(a.add(&b), y);
        assert!(a.sub(&a).is_zero());
    }

    #[test]
    fn binomial_square() {
        let (x, y, _) = xyz();
        let p = x.add(&y); // x + y
        let sq = p.mul(&p);
        // x^2 + 2xy + y^2
        assert_eq!(sq.num_terms(), 3);
        assert_eq!(sq.to_string(), "x^2 + 2*x*y + y^2");
    }

    #[test]
    fn pow_matches_repeated_mul() {
        let (x, y, z) = xyz();
        let p = x.add(&y).add(&z).add(&Polynomial::one(3));
        let mut byhand = Polynomial::one(3);
        for _ in 0..5 {
            byhand = byhand.mul(&p);
        }
        assert_eq!(p.pow(5), byhand);
        assert_eq!(p.pow(0), Polynomial::one(3));
        // (1+x+y+z)^5 over 3 vars has C(8,3) = 56 terms.
        assert_eq!(p.pow(5).num_terms(), 56);
    }

    #[test]
    fn mul_term_preserves_order() {
        let (x, y, _) = xyz();
        let p = x.add(&y).pow(3);
        let q = p.mul_term(&Monomial::var(3, 2), &7);
        assert!(q.is_canonical());
        assert_eq!(q.num_terms(), p.num_terms());
    }

    #[test]
    fn zero_cases() {
        let z: Polynomial<i64> = Polynomial::zero(2);
        let one = Polynomial::one(2);
        assert!(z.mul(&one).is_zero());
        assert_eq!(one.mul(&one), one);
        assert!(one.mul_term(&Monomial::one(2), &0).is_zero());
        assert_eq!(z.to_string(), "0");
        assert_eq!(Polynomial::<i64>::constant(2, 0), z);
    }

    #[test]
    fn map_coeffs_to_bigint() {
        let (x, y, _) = xyz();
        let p = x.add(&y).pow(4);
        let big = p.map_coeffs(|c| BigInt::from(*c));
        assert_eq!(big.num_terms(), p.num_terms());
        let rescaled = big.scale(&BigInt::from(100_000_000_001i64));
        assert_eq!(rescaled.leading().unwrap().1, BigInt::from(100_000_000_001i64));
    }

    #[test]
    fn prop_ring_axioms_for_polynomials() {
        let mut r = runner(150);
        r.run(|g: &mut Gen| {
            let a = random_poly(g, 3, 6);
            let b = random_poly(g, 3, 6);
            let c = random_poly(g, 3, 6);
            assert_eq!(a.add(&b), b.add(&a));
            assert_eq!(a.mul(&b), b.mul(&a));
            assert_eq!(a.add(&b).add(&c), a.add(&b.add(&c)));
            assert_eq!(a.mul(&b).mul(&c), a.mul(&b.mul(&c)));
            assert_eq!(a.mul(&b.add(&c)), a.mul(&b).add(&a.mul(&c)));
            assert!(a.add(&b).is_canonical());
            assert!(a.mul(&b).is_canonical());
            assert!(a.sub(&a).is_zero());
        });
    }

    #[test]
    fn display_reads_naturally() {
        let (x, y, _) = xyz();
        let p = x.mul(&x).add(&y.scale(&-2)).add(&Polynomial::constant(3, 5));
        assert_eq!(p.to_string(), "x^2 + -2*y + 5");
    }
}

//! Chunked polynomial multiplication — the §7 improvement hypothesis
//! ("grouping [elementary computations] in bigger chunks may provide
//! better efficiency"), implemented and evaluated (benches A1/A2).
//!
//! The elementary unit becomes a *block pair*: a block of `x` terms × a
//! block of `y` terms produces all `Bx·By` pairwise term products in one
//! task. The dense inner computation (exponent broadcast-add +
//! coefficient outer product) is behind [`BlockMultiplier`], so the
//! AOT-compiled Pallas kernel (`runtime::KernelMultiplier`) can take it
//! on the hot path; [`RustMultiplier`] is the portable fallback and the
//! oracle.
//!
//! The kernel carries coefficients in `f64` lanes, which is exact only
//! while every pairwise product stays within ±2⁵³. Each block pair is
//! checked ([`TermBlock::kernel_exact_with`]); ineligible pairs (the
//! `_big` BigInt workloads) automatically take the generic path — this
//! is also measured, as A2's crossover.

use std::sync::Arc;

use super::{Coeff, Monomial, Polynomial, Term};
use crate::stream::{ChunkSizer, CostCache, Stream};
use crate::susp::Eval;

/// A dense block of terms in struct-of-arrays layout, matching the AOT
/// kernel's calling convention: `exps` is row-major `[count × nvars]`
/// `i32`, `coefs` is `[count]` `f64`.
#[derive(Debug, Clone, PartialEq)]
pub struct TermBlock {
    pub nvars: usize,
    pub exps: Vec<i32>,
    pub coefs: Vec<f64>,
}

impl TermBlock {
    pub fn count(&self) -> usize {
        self.coefs.len()
    }

    /// Pack generic terms; `None` if any coefficient is not exactly
    /// representable in `f64`.
    pub fn pack<C: Coeff>(nvars: usize, terms: &[Term<C>]) -> Option<TermBlock> {
        let mut exps = Vec::with_capacity(terms.len() * nvars);
        let mut coefs = Vec::with_capacity(terms.len());
        for (m, c) in terms {
            debug_assert_eq!(m.nvars(), nvars);
            exps.extend(m.exps().iter().map(|&e| e as i32));
            coefs.push(c.to_exact_f64()?);
        }
        Some(TermBlock { nvars, exps, coefs })
    }

    /// Unpack into generic terms; `None` if any coefficient fails the
    /// exact reverse conversion.
    pub fn unpack<C: Coeff>(&self) -> Option<Vec<Term<C>>> {
        let mut out = Vec::with_capacity(self.count());
        for i in 0..self.count() {
            let exps: Vec<u16> = self.exps[i * self.nvars..(i + 1) * self.nvars]
                .iter()
                .map(|&e| u16::try_from(e).ok())
                .collect::<Option<_>>()?;
            let c = C::from_exact_f64(self.coefs[i])?;
            out.push((Monomial::from_exps(exps), c));
        }
        Some(out)
    }

    /// Would every pairwise coefficient product of `self × other` stay
    /// exact in f64?
    pub fn kernel_exact_with(&self, other: &TermBlock) -> bool {
        let max_a = self.coefs.iter().fold(0f64, |m, c| m.max(c.abs()));
        let max_b = other.coefs.iter().fold(0f64, |m, c| m.max(c.abs()));
        max_a * max_b <= 9_007_199_254_740_992.0 // 2^53
    }
}

/// Dense per-block-pair outer product. Implementations must return
/// exactly `x.count() * y.count()` products in row-major order
/// (`out[i*ny + j] = x[i] * y[j]`).
pub trait BlockMultiplier: Send + Sync + 'static {
    fn outer_product(&self, x: &TermBlock, y: &TermBlock) -> TermBlock;

    /// Diagnostic name for reports.
    fn name(&self) -> &'static str;

    /// Largest block rows supported per side (AOT artifacts have fixed
    /// shapes; the chunker respects this).
    fn max_block(&self) -> usize {
        usize::MAX
    }
}

/// Portable scalar implementation — the oracle the kernel is tested
/// against, and the fallback when artifacts are absent or a block is
/// not exactly representable.
pub struct RustMultiplier;

impl BlockMultiplier for RustMultiplier {
    fn outer_product(&self, x: &TermBlock, y: &TermBlock) -> TermBlock {
        assert_eq!(x.nvars, y.nvars, "mixed variable counts");
        let v = x.nvars;
        let (nx, ny) = (x.count(), y.count());
        let mut exps = Vec::with_capacity(nx * ny * v);
        let mut coefs = Vec::with_capacity(nx * ny);
        for i in 0..nx {
            let xe = &x.exps[i * v..(i + 1) * v];
            for j in 0..ny {
                let ye = &y.exps[j * v..(j + 1) * v];
                exps.extend(xe.iter().zip(ye).map(|(&a, &b)| a + b));
                coefs.push(x.coefs[i] * y.coefs[j]);
            }
        }
        TermBlock { nvars: v, exps, coefs }
    }

    fn name(&self) -> &'static str {
        "rust-scalar"
    }
}

/// Generic (ring-exact) pairwise block product, used when the f64 path
/// is not exact.
fn generic_block_product<C: Coeff>(
    nvars: usize,
    xs: &[Term<C>],
    ys: &[Term<C>],
) -> Polynomial<C> {
    let mut terms = Vec::with_capacity(xs.len() * ys.len());
    for (mx, cx) in xs {
        for (my, cy) in ys {
            terms.push((mx.mul(my), cx.mul(cy)));
        }
    }
    Polynomial::from_terms(nvars, terms)
}

/// Chunked product: blocks of `x` × blocks of `y`, one suspension (task)
/// per block pair, partial products merged by sorted addition.
pub fn chunked_times<C: Coeff, E: Eval>(
    eval: &E,
    x: &Polynomial<C>,
    y: &Polynomial<C>,
    chunk_size: usize,
    multiplier: Arc<dyn BlockMultiplier>,
) -> Polynomial<C> {
    assert_eq!(x.nvars(), y.nvars(), "mixed variable counts");
    assert!(chunk_size > 0, "chunk_size must be positive");
    let nvars = x.nvars();
    if x.is_zero() || y.is_zero() {
        return Polynomial::zero(nvars);
    }
    let chunk = chunk_size.min(multiplier.max_block());

    let x_blocks: Vec<Arc<Vec<Term<C>>>> =
        x.terms().chunks(chunk).map(|c| Arc::new(c.to_vec())).collect();
    let y_blocks: Vec<Arc<Vec<Term<C>>>> =
        y.terms().chunks(chunk).map(|c| Arc::new(c.to_vec())).collect();

    // All block pairs, streamed: one task per pair under Future.
    let pairs: Vec<(Arc<Vec<Term<C>>>, Arc<Vec<Term<C>>>)> = x_blocks
        .iter()
        .flat_map(|bx| y_blocks.iter().map(move |by| (Arc::clone(bx), Arc::clone(by))))
        .collect();

    // Captured on the constructing thread (the coordinator runner, when
    // inside a job's cancel scope): chunk tasks run on pool workers that
    // can't see that scope, so each task re-checks the captured token
    // and degrades to a free zero partial once the job is cancelled —
    // residual fan-out stops burning pool capacity.
    let cancel = crate::susp::cancel::active();
    let mult = Arc::clone(&multiplier);
    let partials: Stream<Polynomial<C>, E> =
        Stream::from_vec(eval.clone(), pairs).map_elems(move |(bx, by)| {
            if cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
                return Polynomial::zero(nvars);
            }
            block_pair_product(nvars, bx, by, &*mult)
        });

    // Sequential sorted merge of the pipeline's outputs.
    partials.fold(Polynomial::zero(nvars), |acc, p| acc.add(p))
}

/// Pick a block edge for [`chunked_times`] adaptively: probe the real
/// per-term-pair cost through [`block_pair_product`], then size blocks so
/// one task (≈ `chunk²` pairs) costs about `sizer.target_task`, halving
/// as needed until at least `oversubscription × parallelism` block pairs
/// exist. The result respects `multiplier.max_block()`.
pub fn adaptive_poly_chunk<C: Coeff>(
    x: &Polynomial<C>,
    y: &Polynomial<C>,
    parallelism: usize,
    sizer: &ChunkSizer,
    multiplier: &dyn BlockMultiplier,
) -> usize {
    adaptive_poly_chunk_cached(x, y, parallelism, sizer, multiplier, &CostCache::new())
}

/// [`adaptive_poly_chunk`] with the per-pair probe memoized in `cost`:
/// the first call through a given cache measures through the real
/// multiplier, repeated jobs (each coordinator shard keeps one cache per
/// workload) reuse the measurement and skip the probe entirely.
pub fn adaptive_poly_chunk_cached<C: Coeff>(
    x: &Polynomial<C>,
    y: &Polynomial<C>,
    parallelism: usize,
    sizer: &ChunkSizer,
    multiplier: &dyn BlockMultiplier,
    cost: &CostCache,
) -> usize {
    let (nx, ny) = (x.terms().len(), y.terms().len());
    let hi = sizer
        .max_chunk
        .min(multiplier.max_block())
        .max(sizer.min_chunk.max(1));
    if nx == 0 || ny == 0 {
        return sizer.min_chunk.max(1);
    }

    // Probe a small sample block pair through the real code path.
    let nvars = x.nvars();
    let per_pair = cost.get_or_measure(|| {
        let sx = Arc::new(x.terms()[..nx.min(8)].to_vec());
        let sy = Arc::new(y.terms()[..ny.min(8)].to_vec());
        let pairs = sx.len() * sy.len();
        ChunkSizer::probe_cost(pairs, || {
            std::hint::black_box(block_pair_product(nvars, &sx, &sy, multiplier));
        })
    });

    // One task covers chunk² pairs: chunk = sqrt(target / per_pair).
    let per = per_pair.as_nanos().max(1) as f64;
    let target = sizer.target_task.as_nanos().max(1) as f64;
    let mut chunk = ((target / per).sqrt() as usize).max(1);

    // Coverage: keep halving until enough block pairs exist to feed (and
    // let thieves balance) every worker.
    let want_pairs = parallelism.max(1) * sizer.oversubscription.max(1);
    loop {
        let bx = nx.div_ceil(chunk);
        let by = ny.div_ceil(chunk);
        if bx * by >= want_pairs || chunk == 1 {
            break;
        }
        chunk = (chunk / 2).max(1);
    }
    chunk.clamp(sizer.min_chunk.max(1), hi)
}

/// [`chunked_times`] with the block edge picked by
/// [`adaptive_poly_chunk`] from measured cost and the strategy's
/// parallelism, instead of a caller-supplied constant.
pub fn chunked_times_adaptive<C: Coeff, E: Eval>(
    eval: &E,
    x: &Polynomial<C>,
    y: &Polynomial<C>,
    multiplier: Arc<dyn BlockMultiplier>,
) -> Polynomial<C> {
    chunked_times_adaptive_cached(eval, x, y, multiplier, &CostCache::new())
}

/// [`chunked_times_adaptive`] with the probe memoized in `cost` — the
/// coordinator's entry point for repeated jobs on a shard.
pub fn chunked_times_adaptive_cached<C: Coeff, E: Eval>(
    eval: &E,
    x: &Polynomial<C>,
    y: &Polynomial<C>,
    multiplier: Arc<dyn BlockMultiplier>,
    cost: &CostCache,
) -> Polynomial<C> {
    let parallelism = eval.executor().map(|e| e.parallelism()).unwrap_or(1);
    let chunk = adaptive_poly_chunk_cached(
        x,
        y,
        parallelism,
        &ChunkSizer::default(),
        &*multiplier,
        cost,
    );
    chunked_times(eval, x, y, chunk, multiplier)
}

fn block_pair_product<C: Coeff>(
    nvars: usize,
    bx: &Arc<Vec<Term<C>>>,
    by: &Arc<Vec<Term<C>>>,
    multiplier: &dyn BlockMultiplier,
) -> Polynomial<C> {
    // Try the dense f64 path (kernel-offloadable).
    if let (Some(px), Some(py)) = (TermBlock::pack(nvars, bx), TermBlock::pack(nvars, by)) {
        if px.kernel_exact_with(&py) {
            let out = multiplier.outer_product(&px, &py);
            debug_assert_eq!(out.count(), px.count() * py.count());
            if let Some(terms) = out.unpack::<C>() {
                return Polynomial::from_terms(nvars, terms);
            }
        }
    }
    // Ring-exact fallback (BigInt / overflow-risk blocks).
    generic_block_product(nvars, bx, by)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bigint::BigInt;
    use crate::exec::Executor;
    use crate::poly::parse_polynomial;
    use crate::susp::{FutureEval, LazyEval};
    use crate::testkit::prop::{runner, Gen};

    fn p(s: &str) -> Polynomial<i64> {
        parse_polynomial(s, &["x", "y", "z"]).unwrap()
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let a = p("3*x^2*y - 4*z + 7");
        let block = TermBlock::pack(3, a.terms()).unwrap();
        assert_eq!(block.count(), 3);
        let back: Vec<Term<i64>> = block.unpack().unwrap();
        assert_eq!(back, a.terms());
    }

    #[test]
    fn pack_rejects_inexact() {
        let huge = Polynomial::constant(2, (1i64 << 53) + 1);
        assert!(TermBlock::pack(2, huge.terms()).is_none());
    }

    #[test]
    fn unpack_rejects_fractional() {
        let b = TermBlock { nvars: 1, exps: vec![0], coefs: vec![0.5] };
        assert!(b.unpack::<i64>().is_none());
    }

    #[test]
    fn kernel_exactness_guard() {
        let small = TermBlock { nvars: 1, exps: vec![0], coefs: vec![1e6] };
        let big = TermBlock { nvars: 1, exps: vec![0], coefs: vec![1e12] };
        assert!(small.kernel_exact_with(&small));
        assert!(!big.kernel_exact_with(&big));
    }

    #[test]
    fn rust_multiplier_outer_product() {
        let x = TermBlock { nvars: 2, exps: vec![1, 0, 0, 1], coefs: vec![2.0, 3.0] };
        let y = TermBlock { nvars: 2, exps: vec![1, 1], coefs: vec![5.0] };
        let out = RustMultiplier.outer_product(&x, &y);
        assert_eq!(out.count(), 2);
        assert_eq!(out.exps, vec![2, 1, 1, 2]);
        assert_eq!(out.coefs, vec![10.0, 15.0]);
    }

    #[test]
    fn chunked_matches_classical() {
        let a = p("1 + x + y + z").pow(4);
        let b = a.add(&Polynomial::one(3));
        let want = a.mul(&b);
        for chunk in [1, 2, 7, 64, 1000] {
            let got = chunked_times(&LazyEval, &a, &b, chunk, Arc::new(RustMultiplier));
            assert_eq!(got, want, "chunk={chunk}");
        }
    }

    #[test]
    fn chunked_future_matches() {
        let a = p("1 + x + y + z").pow(5);
        let b = a.clone();
        let want = a.mul(&b);
        let ex = Executor::new(4);
        let eval = FutureEval::new(ex);
        assert_eq!(chunked_times(&eval, &a, &b, 32, Arc::new(RustMultiplier)), want);
    }

    #[test]
    fn chunked_bigint_takes_generic_path() {
        let factor = BigInt::from(100_000_000_001i64);
        let a = p("1 + x + y").pow(3).map_coeffs(|c| BigInt::from(*c).mul(&factor));
        let b = a.clone();
        let want = a.mul(&b);
        let got = chunked_times(&LazyEval, &a, &b, 16, Arc::new(RustMultiplier));
        assert_eq!(got, want);
    }

    #[test]
    fn zero_operands() {
        let a = p("x + 1");
        let z = Polynomial::<i64>::zero(3);
        assert!(chunked_times(&LazyEval, &a, &z, 8, Arc::new(RustMultiplier)).is_zero());
        assert!(chunked_times(&LazyEval, &z, &a, 8, Arc::new(RustMultiplier)).is_zero());
    }

    #[test]
    fn adaptive_chunk_is_sane() {
        let a = p("1 + x + y + z").pow(4);
        let chunk =
            adaptive_poly_chunk(&a, &a, 4, &crate::stream::ChunkSizer::default(), &RustMultiplier);
        assert!(chunk >= 1);
        assert!(chunk <= 1 << 16);
        // Zero polynomial degenerates safely.
        let z = Polynomial::<i64>::zero(3);
        let chunk =
            adaptive_poly_chunk(&a, &z, 4, &crate::stream::ChunkSizer::default(), &RustMultiplier);
        assert_eq!(chunk, 1);
    }

    #[test]
    fn adaptive_matches_classical() {
        let a = p("1 + x + y + z").pow(4);
        let b = a.add(&Polynomial::one(3));
        let want = a.mul(&b);
        let got = chunked_times_adaptive(&LazyEval, &a, &b, Arc::new(RustMultiplier));
        assert_eq!(got, want);
        let ex = Executor::new(3);
        let eval = FutureEval::new(ex);
        let got = chunked_times_adaptive(&eval, &a, &b, Arc::new(RustMultiplier));
        assert_eq!(got, want);
    }

    #[test]
    fn cached_adaptive_reuses_probe_cost() {
        let a = p("1 + x + y + z").pow(4);
        let b = a.add(&Polynomial::one(3));
        let want = a.mul(&b);
        let cache = crate::stream::CostCache::new();
        let got =
            chunked_times_adaptive_cached(&LazyEval, &a, &b, Arc::new(RustMultiplier), &cache);
        assert_eq!(got, want);
        let first_cost = cache.get().expect("first job seeds the cache");
        let got =
            chunked_times_adaptive_cached(&LazyEval, &a, &b, Arc::new(RustMultiplier), &cache);
        assert_eq!(got, want);
        assert_eq!(cache.get(), Some(first_cost), "repeat jobs must not re-probe");
        // A pre-seeded cache bypasses the probe entirely and still picks
        // a sane chunk.
        let seeded = crate::stream::CostCache::new();
        let _ = seeded.get_or_measure(|| std::time::Duration::from_micros(1));
        let chunk = adaptive_poly_chunk_cached(
            &a,
            &b,
            2,
            &crate::stream::ChunkSizer::default(),
            &RustMultiplier,
            &seeded,
        );
        assert!(chunk >= 1);
    }

    #[test]
    fn prop_chunked_equals_classical() {
        let mut r = runner(40);
        r.run(|g: &mut Gen| {
            let a = random_poly(g, 2, 9);
            let b = random_poly(g, 2, 9);
            let chunk = g.usize_in(1..10);
            let got = chunked_times(&LazyEval, &a, &b, chunk, Arc::new(RustMultiplier));
            assert_eq!(got, a.mul(&b), "a={a} b={b} chunk={chunk}");
        });
    }

    fn random_poly(g: &mut Gen, nvars: usize, max_terms: usize) -> Polynomial<i64> {
        let terms = g.vec(0..max_terms.max(1), |g| {
            let exps: Vec<u16> = (0..nvars).map(|_| g.u32_in(0..5) as u16).collect();
            (Monomial::from_exps(exps), g.i64_in(-9..=9))
        });
        Polynomial::from_terms(nvars, terms)
    }
}

//! Polynomial division: term divisibility, multivariate division with
//! remainder, exact division, derivatives and evaluation — the algebra
//! the Gröbner application (and the test suite's inverses) needs.

use super::{Coeff, Monomial, Polynomial};

impl Monomial {
    /// Does `self` divide `other` (componentwise `≤`)?
    pub fn divides(&self, other: &Monomial) -> bool {
        debug_assert_eq!(self.nvars(), other.nvars());
        self.exps().iter().zip(other.exps()).all(|(&a, &b)| a <= b)
    }

    /// `self / other`; caller guarantees `other.divides(self)`.
    pub fn div(&self, other: &Monomial) -> Monomial {
        debug_assert!(other.divides(self), "{other} does not divide {self}");
        Monomial::from_exps(
            self.exps().iter().zip(other.exps()).map(|(&a, &b)| a - b).collect(),
        )
    }

    /// Least common multiple (componentwise max) — the S-polynomial's
    /// pivot monomial.
    pub fn lcm(&self, other: &Monomial) -> Monomial {
        debug_assert_eq!(self.nvars(), other.nvars());
        Monomial::from_exps(
            self.exps().iter().zip(other.exps()).map(|(&a, &b)| a.max(b)).collect(),
        )
    }

    /// Are the two monomials coprime (disjoint support)? Buchberger's
    /// first criterion skips such pairs.
    pub fn coprime(&self, other: &Monomial) -> bool {
        self.exps().iter().zip(other.exps()).all(|(&a, &b)| a == 0 || b == 0)
    }
}

/// A field-like coefficient: adds exact division. Implemented for `f64`
/// and for rationals-over-i64 workloads via exact integer division when
/// it is exact (panics otherwise — the Gröbner example uses f64).
pub trait FieldCoeff: Coeff {
    fn div(&self, other: &Self) -> Self;
}

impl FieldCoeff for f64 {
    fn div(&self, other: &Self) -> Self {
        self / other
    }
}

impl<C: Coeff> Polynomial<C> {
    /// Formal partial derivative with respect to variable `var`.
    pub fn derivative(&self, var: usize) -> Polynomial<C>
    where
        C: From<i64>,
    {
        assert!(var < self.nvars(), "variable index out of range");
        let terms = self
            .terms()
            .iter()
            .filter(|(m, _)| m.exps()[var] > 0)
            .map(|(m, c)| {
                let e = m.exps()[var];
                let mut exps = m.exps().to_vec();
                exps[var] = e - 1;
                (Monomial::from_exps(exps), c.mul(&C::from(e as i64)))
            })
            .collect();
        Polynomial::from_terms(self.nvars(), terms)
    }

    /// Evaluate at a point (Horner-free straightforward evaluation; the
    /// workloads are sparse so per-term powering is fine).
    pub fn eval(&self, point: &[C]) -> C {
        assert_eq!(point.len(), self.nvars(), "point arity mismatch");
        let mut acc = C::zero();
        for (m, c) in self.terms() {
            let mut term = c.clone();
            for (i, &e) in m.exps().iter().enumerate() {
                for _ in 0..e {
                    term = term.mul(&point[i]);
                }
            }
            acc = acc.add(&term);
        }
        acc
    }
}

impl<C: FieldCoeff> Polynomial<C> {
    /// Multivariate division with remainder by a list of divisors
    /// (the generalized division algorithm): returns `(quotients, r)`
    /// with `self = Σ qᵢ·dᵢ + r` and no term of `r` divisible by any
    /// divisor's leading monomial.
    pub fn div_rem(&self, divisors: &[Polynomial<C>]) -> (Vec<Polynomial<C>>, Polynomial<C>) {
        assert!(!divisors.is_empty(), "need at least one divisor");
        for d in divisors {
            assert!(!d.is_zero(), "division by the zero polynomial");
            assert_eq!(d.nvars(), self.nvars(), "mixed variable counts");
        }
        let nvars = self.nvars();
        let mut quotients = vec![Polynomial::zero(nvars); divisors.len()];
        let mut remainder = Polynomial::zero(nvars);
        let mut p = self.clone();
        while let Some((lm, lc)) = p.leading().map(|(m, c)| (m.clone(), c.clone())) {
            let mut reduced = false;
            for (i, d) in divisors.iter().enumerate() {
                let (dm, dc) = d.leading().expect("nonzero divisor");
                if dm.divides(&lm) {
                    let qm = lm.div(dm);
                    let qc = FieldCoeff::div(&lc, dc);
                    let qterm = Polynomial::from_terms(nvars, vec![(qm.clone(), qc.clone())]);
                    quotients[i] = quotients[i].add(&qterm);
                    p = p.sub(&d.mul_term(&qm, &qc));
                    reduced = true;
                    break;
                }
            }
            if !reduced {
                // Leading term is irreducible: move it to the remainder.
                let head = Polynomial::from_terms(nvars, vec![(lm.clone(), lc.clone())]);
                remainder = remainder.add(&head);
                p = p.sub(&head);
            }
        }
        (quotients, remainder)
    }

    /// Normal form of `self` modulo `divisors` (the remainder only).
    pub fn normal_form(&self, divisors: &[Polynomial<C>]) -> Polynomial<C> {
        self.div_rem(divisors).1
    }

    /// Scale so the leading coefficient is 1.
    pub fn monic(&self) -> Polynomial<C> {
        match self.leading() {
            None => self.clone(),
            Some((_, lc)) => {
                let inv_scale = lc.clone();
                self.map_coeffs(|c| FieldCoeff::div(c, &inv_scale))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::parse_polynomial;

    const XY: &[&str] = &["x", "y"];

    fn p(s: &str) -> Polynomial<f64> {
        parse_polynomial(s, XY).unwrap()
    }

    #[test]
    fn monomial_divides_div_lcm() {
        let a = Monomial::from_exps(vec![1, 2]);
        let b = Monomial::from_exps(vec![2, 2]);
        assert!(a.divides(&b));
        assert!(!b.divides(&a));
        assert_eq!(b.div(&a), Monomial::from_exps(vec![1, 0]));
        assert_eq!(a.lcm(&b), b);
        let c = Monomial::from_exps(vec![0, 3]);
        let d = Monomial::from_exps(vec![2, 0]);
        assert!(c.coprime(&d));
        assert!(!a.coprime(&b));
    }

    #[test]
    fn division_identity_holds() {
        let f = p("x^2*y + x*y^2 + y^2");
        let d1 = p("x*y - 1");
        let d2 = p("y^2 - 1");
        let (qs, r) = f.div_rem(&[d1.clone(), d2.clone()]);
        // f = q1*d1 + q2*d2 + r (the CLO textbook example).
        let recombined = qs[0].mul(&d1).add(&qs[1].mul(&d2)).add(&r);
        assert_eq!(recombined, f);
        // No remainder term divisible by a leading monomial.
        for (m, _) in r.terms() {
            assert!(!d1.leading().unwrap().0.divides(m));
            assert!(!d2.leading().unwrap().0.divides(m));
        }
    }

    #[test]
    fn exact_division_has_zero_remainder() {
        let a = p("x + y + 1");
        let b = p("x - y + 2");
        let prod = a.mul(&b);
        let (qs, r) = prod.div_rem(&[a.clone()]);
        assert!(r.is_zero());
        assert_eq!(qs[0], b);
    }

    #[test]
    fn normal_form_of_member_is_zero() {
        let d = p("x^2 - y");
        let f = d.mul(&p("3*x*y + 7"));
        assert!(f.normal_form(&[d]).is_zero());
    }

    #[test]
    fn derivative_rules() {
        let f: Polynomial<i64> =
            parse_polynomial("x^3 + 2*x*y^2 + 5*y + 7", XY).unwrap();
        assert_eq!(
            f.derivative(0),
            parse_polynomial::<i64>("3*x^2 + 2*y^2", XY).unwrap()
        );
        assert_eq!(
            f.derivative(1),
            parse_polynomial::<i64>("4*x*y + 5", XY).unwrap()
        );
        // d/dx of a constant is zero.
        let k: Polynomial<i64> = parse_polynomial("42", XY).unwrap();
        assert!(k.derivative(0).is_zero());
    }

    #[test]
    fn eval_matches_hand_computation() {
        let f: Polynomial<i64> = parse_polynomial("x^2*y - 3*x + 1", XY).unwrap();
        assert_eq!(f.eval(&[2, 5]), 4 * 5 - 6 + 1);
        assert_eq!(f.eval(&[0, 0]), 1);
    }

    #[test]
    fn monic_normalizes_leading_coefficient() {
        let f = p("4*x^2 + 2*y");
        let m = f.monic();
        assert_eq!(m.leading().unwrap().1, 1.0);
        // x^2 + 0.5*y
        let want = p("x^2").add(&p("y").mul_term(&Monomial::one(2), &0.5));
        assert_eq!(m, want);
        // Monic of zero is zero.
        assert!(Polynomial::<f64>::zero(2).monic().is_zero());
    }
}

//! The paper's §6: sparse polynomial multiplication as a stream
//! computation.
//!
//! ```text
//! type T = Stream[(Array[N], C)]
//! def times(x: T, y: T) = (zero /: y) { (l, r) =>
//!   val (a, b) = r
//!   l + multiply(x, a, b)
//! }
//! ```
//!
//! `multiply` (by one term) and `plus` (streaming merge-add) are
//! expressed recursively over the monadic stream, so the whole
//! multiplication becomes the pipeline of Figure 2: under the Future
//! strategy every `multiply` stage and every `plus` merge stage runs as
//! its own chain of tasks.
//!
//! Faithfulness notes:
//! * the cancellation case in `plus` forces the tail (`result.tail`),
//!   which the paper concedes "results in a call to Await.result … we
//!   have not been able to avoid it";
//! * the equal-monomial case uses the `for (sx <- tailx; sy <- taily)`
//!   comprehension, i.e. `flatMap` + `map` over the suspended tails.

use super::{Coeff, Monomial, Polynomial, Term};
use crate::stream::Stream;
use crate::susp::Eval;

/// The paper's `type T = Stream[(Array[N], C)]`.
pub type PolyStream<C, E> = Stream<Term<C>, E>;

/// Multiply a term stream by a single term `c·m` — the paper's
/// `multiply(x, m, c)`.
///
/// ```text
/// case (s, a)#::tail => {
///   val (sm, ac) = (s * m, a * c)
///   val result = (sm, ac)#::tail.map(multiply(_, m, c))
///   if (!ac.isZero) result else result.tail
/// }
/// ```
pub fn multiply<C: Coeff, E: Eval>(
    x: &PolyStream<C, E>,
    m: &Monomial,
    c: &C,
) -> PolyStream<C, E> {
    match x.uncons() {
        None => Stream::Empty,
        Some(((s, a), tail, eval)) => {
            let (sm, ac) = (s.mul(m), a.mul(c));
            let (m2, c2) = (m.clone(), c.clone());
            let mapped = eval.map(tail, move |t: PolyStream<C, E>| multiply(&t, &m2, &c2));
            let result = Stream::cons_cell(eval.clone(), (sm, ac), mapped);
            if !ac_is_zero(&result) {
                result
            } else {
                // Coefficient cancelled (possible in non-domain rings):
                // drop the head, forcing the tail as the paper does.
                result.tail().expect("cons has a tail").clone()
            }
        }
    }
}

fn ac_is_zero<C: Coeff, E: Eval>(s: &PolyStream<C, E>) -> bool {
    s.head().map(|(_, c)| c.is_zero()).unwrap_or(false)
}

/// Streaming merge-add — the paper's `plus(x, y)`, including the
/// flatMap/map comprehension on the equal-monomial branch and the forced
/// tail on cancellation.
pub fn plus<C: Coeff, E: Eval>(
    x: &PolyStream<C, E>,
    y: &PolyStream<C, E>,
) -> PolyStream<C, E> {
    match (x.uncons(), y.uncons()) {
        (None, _) => y.clone(),
        (_, None) => x.clone(),
        (Some(((s, a), tailx, eval)), Some(((t, b), taily, _))) => {
            match s.cmp(t) {
                std::cmp::Ordering::Greater => {
                    // (s, a) #:: tailx.map(plus(_, y))
                    let y2 = y.clone();
                    let merged =
                        eval.map(tailx, move |tx: PolyStream<C, E>| plus(&tx, &y2));
                    Stream::cons_cell(eval.clone(), (s.clone(), a.clone()), merged)
                }
                std::cmp::Ordering::Less => {
                    // (t, b) #:: taily.map(plus(x, _))
                    let x2 = x.clone();
                    let merged =
                        eval.map(taily, move |ty: PolyStream<C, E>| plus(&x2, &ty));
                    Stream::cons_cell(eval.clone(), (t.clone(), b.clone()), merged)
                }
                std::cmp::Ordering::Equal => {
                    let c = a.add(b);
                    // for (sx <- tailx; sy <- taily) yield plus(sx, sy)
                    let taily2 = taily.clone();
                    let eval2 = eval.clone();
                    let both = eval.flat_map(tailx, move |tx: PolyStream<C, E>| {
                        eval2.map(&taily2, move |ty: PolyStream<C, E>| plus(&tx, &ty))
                    });
                    let result = Stream::cons_cell(eval.clone(), (s.clone(), c.clone()), both);
                    if !c.is_zero() {
                        result
                    } else {
                        // Cancellation: the paper's forced result.tail
                        // (the unavoidable Await.result).
                        result.tail().expect("cons has a tail").clone()
                    }
                }
            }
        }
    }
}

/// The paper's `times`: fold `multiply`-and-`plus` over the terms of `y`.
pub fn times<C: Coeff, E: Eval>(
    eval: &E,
    x: &Polynomial<C>,
    y: &Polynomial<C>,
) -> PolyStream<C, E> {
    assert_eq!(x.nvars(), y.nvars(), "mixed variable counts");
    let x_stream: PolyStream<C, E> = Stream::from_vec(eval.clone(), x.terms().to_vec());
    let mut acc: PolyStream<C, E> = Stream::Empty;
    for (m, c) in y.terms() {
        let product = multiply(&x_stream, m, c);
        acc = plus(&acc, &product);
    }
    acc
}

/// Run [`times`] to completion and collect into a strict [`Polynomial`]
/// (the paper's final `.force`).
pub fn stream_times<C: Coeff, E: Eval>(
    eval: &E,
    x: &Polynomial<C>,
    y: &Polynomial<C>,
) -> Polynomial<C> {
    let result = times(eval, x, y);
    collect(x.nvars(), &result)
}

/// Collect a (sorted, canonical) term stream into a strict polynomial,
/// verifying canonical form on the way out.
pub fn collect<C: Coeff, E: Eval>(nvars: usize, s: &PolyStream<C, E>) -> Polynomial<C> {
    let terms = s.to_vec();
    debug_assert!(
        terms.windows(2).all(|w| w[0].0 > w[1].0),
        "stream result not strictly descending"
    );
    // From_terms re-canonicalizes defensively (cheap: input is sorted).
    Polynomial::from_terms(nvars, terms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Executor;
    use crate::poly::parse_polynomial;
    use crate::susp::{FutureEval, LazyEval, StrictEval};
    use crate::testkit::prop::{runner, Gen};

    const XYZ: &[&str] = &["x", "y", "z"];

    fn p(s: &str) -> Polynomial<i64> {
        parse_polynomial(s, XYZ).unwrap()
    }

    fn stream_of<E: Eval>(eval: &E, poly: &Polynomial<i64>) -> PolyStream<i64, E> {
        Stream::from_vec(eval.clone(), poly.terms().to_vec())
    }

    #[test]
    fn multiply_by_term_matches_strict() {
        let a = p("x^2 + 2*x*y + y^2");
        let m = Monomial::from_exps(vec![0, 0, 1]);
        let got = collect(3, &multiply(&stream_of(&LazyEval, &a), &m, &3));
        assert_eq!(got, a.mul_term(&m, &3));
    }

    #[test]
    fn multiply_by_zero_coefficient() {
        let a = p("x + y");
        let got = collect(3, &multiply(&stream_of(&LazyEval, &a), &Monomial::one(3), &0));
        assert!(got.is_zero());
    }

    #[test]
    fn plus_merges_disjoint() {
        let a = p("x^2");
        let b = p("y + 1");
        let got = collect(3, &plus(&stream_of(&LazyEval, &a), &stream_of(&LazyEval, &b)));
        assert_eq!(got, a.add(&b));
    }

    #[test]
    fn plus_combines_equal_monomials() {
        let a = p("x + y");
        let b = p("x - y");
        let got = collect(3, &plus(&stream_of(&LazyEval, &a), &stream_of(&LazyEval, &b)));
        assert_eq!(got, p("2*x"));
    }

    #[test]
    fn plus_with_cancellation_forces_tail() {
        // x - x cancels at the head: exercises the paper's Await path.
        let a = p("x + 1");
        let b = p("-x + 2");
        let got = collect(3, &plus(&stream_of(&LazyEval, &a), &stream_of(&LazyEval, &b)));
        assert_eq!(got, p("3"));
    }

    #[test]
    fn plus_total_cancellation_gives_zero() {
        let a = p("x^2 + y + 4");
        let got = collect(3, &plus(&stream_of(&LazyEval, &a), &stream_of(&LazyEval, &a.neg())));
        assert!(got.is_zero());
    }

    #[test]
    fn times_matches_classical_small() {
        let a = p("x + y + 1");
        let b = p("x - y + 2");
        assert_eq!(stream_times(&LazyEval, &a, &b), a.mul(&b));
    }

    #[test]
    fn times_with_zero_and_one() {
        let a = p("x^2 + 3*y");
        let zero = Polynomial::<i64>::zero(3);
        let one = Polynomial::<i64>::one(3);
        assert!(stream_times(&LazyEval, &a, &zero).is_zero());
        assert!(stream_times(&LazyEval, &zero, &a).is_zero());
        assert_eq!(stream_times(&LazyEval, &a, &one), a);
    }

    #[test]
    fn all_strategies_agree_on_fateman_slice() {
        // (1+x+y+z)^4 × ((1+x+y+z)^4 + 1): the paper's benchmark shape,
        // scaled down.
        let base = p("1 + x + y + z").pow(4);
        let other = base.add(&Polynomial::one(3));
        let want = base.mul(&other);
        assert_eq!(stream_times(&LazyEval, &base, &other), want);
        assert_eq!(stream_times(&StrictEval, &base, &other), want);
        let ex = Executor::new(4);
        assert_eq!(stream_times(&FutureEval::new(ex), &base, &other), want);
        let ex1 = Executor::new(1);
        assert_eq!(stream_times(&FutureEval::new(ex1), &base, &other), want);
    }

    #[test]
    fn bigint_coefficients_roundtrip() {
        use crate::bigint::BigInt;
        let factor = BigInt::from(100_000_000_001i64);
        let base = p("1 + x + y + z").pow(3).map_coeffs(|c| BigInt::from(*c).mul(&factor));
        let other = base.clone();
        let want = base.mul(&other);
        let ex = Executor::new(2);
        assert_eq!(stream_times(&FutureEval::new(ex), &base, &other), want);
    }

    #[test]
    fn prop_stream_times_equals_classical() {
        let mut r = runner(60);
        r.run(|g: &mut Gen| {
            let a = random_poly(g, 3, 7);
            let b = random_poly(g, 3, 7);
            assert_eq!(stream_times(&LazyEval, &a, &b), a.mul(&b), "a={a} b={b}");
        });
    }

    #[test]
    fn prop_future_stream_times_equals_classical() {
        let ex = Executor::new(3);
        let eval = FutureEval::new(ex);
        let mut r = runner(25);
        r.run(move |g: &mut Gen| {
            let a = random_poly(g, 2, 6);
            let b = random_poly(g, 2, 6);
            assert_eq!(stream_times(&eval, &a, &b), a.mul(&b), "a={a} b={b}");
        });
    }

    /// Random small polynomial (duplicated from polynomial.rs tests to
    /// keep modules self-contained).
    fn random_poly(g: &mut Gen, nvars: usize, max_terms: usize) -> Polynomial<i64> {
        let terms = g.vec(0..max_terms.max(1), |g| {
            let exps: Vec<u16> = (0..nvars).map(|_| g.u32_in(0..5) as u16).collect();
            (Monomial::from_exps(exps), g.i64_in(-9..=9))
        });
        Polynomial::from_terms(nvars, terms)
    }
}

//! The `list` / `list_big` control workloads — "straightforward
//! parallelization of polynomial multiplication using parallel
//! collections" [4]: map `x·(bᵢtᵢ)` over the terms of `y` in parallel,
//! then reduce the partial products by `+`.
//!
//! Sequentially this degenerates to the classical iterative algorithm
//! (the paper's observation 3 baseline: "a well optimized classical
//! iterative/imperative implementation").

use super::{Coeff, Polynomial};
use crate::exec::Executor;
use crate::par::{par_map, par_reduce};

/// Sequential baseline: accumulate term-by-term products iteratively.
pub fn list_times_seq<C: Coeff>(x: &Polynomial<C>, y: &Polynomial<C>) -> Polynomial<C> {
    x.mul(y)
}

/// Parallel-collections baseline: `y.par.map(term => x*term).reduce(_+_)`.
///
/// Scala's parallel collections split the source into one partition per
/// task (a few per worker), run the sequential fold *within* each
/// partition, and combine partitions with the reducer — `aggregate`
/// semantics. We mirror that: y's terms are partitioned, each partition
/// computes its partial product with the optimized sequential kernel,
/// and the few partials are tree-reduced. (A first version reduced one
/// partial *per term*, which buries the baseline in merge traffic the
/// Scala splitter never generates — see EXPERIMENTS.md §Perf.)
pub fn list_times_par<C: Coeff>(
    exec: &Executor,
    x: &Polynomial<C>,
    y: &Polynomial<C>,
) -> Polynomial<C> {
    assert_eq!(x.nvars(), y.nvars(), "mixed variable counts");
    let nvars = x.nvars();
    if x.is_zero() || y.is_zero() {
        return Polynomial::zero(nvars);
    }
    // One partition per task slot (4 per worker limits stragglers).
    let partitions = (exec.parallelism() * 4).max(1);
    let per = y.num_terms().div_ceil(partitions);
    let parts: Vec<Polynomial<C>> = y
        .terms()
        .chunks(per)
        .map(|terms| Polynomial::from_terms(nvars, terms.to_vec()))
        .collect();
    let x = x.clone();
    let partials = par_map(exec, &parts, move |part| x.mul(part));
    par_reduce(exec, partials, Polynomial::zero(nvars), |a, b| a.add(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bigint::BigInt;
    use crate::poly::parse_polynomial;
    use crate::testkit::prop::{runner, Gen};
    use crate::poly::Monomial;

    fn p(s: &str) -> Polynomial<i64> {
        parse_polynomial(s, &["x", "y", "z"]).unwrap()
    }

    #[test]
    fn par_matches_seq_small() {
        let ex = Executor::new(4);
        let a = p("x + y + 1").pow(3);
        let b = p("x - z + 2").pow(3);
        assert_eq!(list_times_par(&ex, &a, &b), list_times_seq(&a, &b));
    }

    #[test]
    fn par_with_one_worker() {
        let ex = Executor::new(1);
        let a = p("x^2 + y");
        let b = p("z + 1");
        assert_eq!(list_times_par(&ex, &a, &b), a.mul(&b));
    }

    #[test]
    fn zero_operands() {
        let ex = Executor::new(2);
        let a = p("x + 1");
        let z = Polynomial::<i64>::zero(3);
        assert!(list_times_par(&ex, &a, &z).is_zero());
        assert!(list_times_par(&ex, &z, &a).is_zero());
    }

    #[test]
    fn bigint_parallel_product() {
        let ex = Executor::new(3);
        let factor = BigInt::from(100_000_000_001i64);
        let a = p("1 + x + y + z").pow(4).map_coeffs(|c| BigInt::from(*c).mul(&factor));
        let b = a.clone();
        assert_eq!(list_times_par(&ex, &a, &b), a.mul(&b));
    }

    #[test]
    fn prop_par_equals_seq() {
        let ex = Executor::new(4);
        let mut r = runner(40);
        r.run(move |g: &mut Gen| {
            let a = random_poly(g, 3, 8);
            let b = random_poly(g, 3, 8);
            assert_eq!(list_times_par(&ex, &a, &b), a.mul(&b), "a={a} b={b}");
        });
    }

    fn random_poly(g: &mut Gen, nvars: usize, max_terms: usize) -> Polynomial<i64> {
        let terms = g.vec(0..max_terms.max(1), |g| {
            let exps: Vec<u16> = (0..nvars).map(|_| g.u32_in(0..5) as u16).collect();
            (Monomial::from_exps(exps), g.i64_in(-9..=9))
        });
        Polynomial::from_terms(nvars, terms)
    }
}

//! Coefficient-ring abstraction.
//!
//! The paper's evaluation turns exactly one knob between `stream` and
//! `stream_big`: the coefficient ring (machine integers vs JVM `BigInt`
//! scaled by 100000000001) — "in order to increase the footprint of
//! elementary operations". [`Coeff`] makes that knob a type parameter.

use crate::bigint::BigInt;

/// A commutative ring of coefficients. All operations are by-reference
/// (big coefficients must not be copied to be added).
pub trait Coeff:
    Clone + Send + Sync + PartialEq + std::fmt::Debug + std::fmt::Display + 'static
{
    fn zero() -> Self;
    fn one() -> Self;
    fn is_zero(&self) -> bool;
    fn add(&self, other: &Self) -> Self;
    fn mul(&self, other: &Self) -> Self;
    fn neg(&self) -> Self;

    /// `self + other * k` — the fused step of the accumulating baselines.
    fn add_mul(&self, other: &Self, k: &Self) -> Self {
        self.add(&other.mul(k))
    }

    /// Exact value as `f64` when representable (the PJRT kernel path
    /// carries coefficients as f64 lanes; `None` opts a block out of
    /// kernel offload).
    fn to_exact_f64(&self) -> Option<f64>;

    /// Inverse of [`Coeff::to_exact_f64`].
    fn from_exact_f64(v: f64) -> Option<Self>;
}

/// Largest integer magnitude `f64` holds exactly.
const F64_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53

impl Coeff for i64 {
    fn zero() -> Self {
        0
    }

    fn one() -> Self {
        1
    }

    fn is_zero(&self) -> bool {
        *self == 0
    }

    fn add(&self, other: &Self) -> Self {
        self.checked_add(*other).expect("i64 coefficient overflow in add")
    }

    fn mul(&self, other: &Self) -> Self {
        self.checked_mul(*other).expect("i64 coefficient overflow in mul")
    }

    fn neg(&self) -> Self {
        self.checked_neg().expect("i64 coefficient overflow in neg")
    }

    fn to_exact_f64(&self) -> Option<f64> {
        let v = *self as f64;
        (v.abs() <= F64_EXACT && v as i64 == *self).then_some(v)
    }

    fn from_exact_f64(v: f64) -> Option<Self> {
        (v.fract() == 0.0 && v.abs() <= F64_EXACT).then_some(v as i64)
    }
}

impl Coeff for i128 {
    fn zero() -> Self {
        0
    }

    fn one() -> Self {
        1
    }

    fn is_zero(&self) -> bool {
        *self == 0
    }

    fn add(&self, other: &Self) -> Self {
        self.checked_add(*other).expect("i128 coefficient overflow in add")
    }

    fn mul(&self, other: &Self) -> Self {
        self.checked_mul(*other).expect("i128 coefficient overflow in mul")
    }

    fn neg(&self) -> Self {
        self.checked_neg().expect("i128 coefficient overflow in neg")
    }

    fn to_exact_f64(&self) -> Option<f64> {
        let v = *self as f64;
        (v.abs() <= F64_EXACT && v as i128 == *self).then_some(v)
    }

    fn from_exact_f64(v: f64) -> Option<Self> {
        (v.fract() == 0.0 && v.abs() <= F64_EXACT).then_some(v as i128)
    }
}

impl Coeff for BigInt {
    fn zero() -> Self {
        BigInt::zero()
    }

    fn one() -> Self {
        BigInt::one()
    }

    fn is_zero(&self) -> bool {
        BigInt::is_zero(self)
    }

    fn add(&self, other: &Self) -> Self {
        self + other
    }

    fn mul(&self, other: &Self) -> Self {
        self * other
    }

    fn neg(&self) -> Self {
        BigInt::neg(self)
    }

    fn to_exact_f64(&self) -> Option<f64> {
        self.to_i128().and_then(|v| v.to_exact_f64())
    }

    fn from_exact_f64(v: f64) -> Option<Self> {
        i128::from_exact_f64(v).map(BigInt::from)
    }
}

/// Floating coefficients are used by kernel cross-checks, not by the
/// paper's workloads (exact arithmetic there).
impl Coeff for f64 {
    fn zero() -> Self {
        0.0
    }

    fn one() -> Self {
        1.0
    }

    fn is_zero(&self) -> bool {
        *self == 0.0
    }

    fn add(&self, other: &Self) -> Self {
        self + other
    }

    fn mul(&self, other: &Self) -> Self {
        self * other
    }

    fn neg(&self) -> Self {
        -self
    }

    fn to_exact_f64(&self) -> Option<f64> {
        Some(*self)
    }

    fn from_exact_f64(v: f64) -> Option<Self> {
        Some(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercise_ring<C: Coeff + From<i32>>() {
        let two: C = 2.into();
        let three: C = 3.into();
        assert_eq!(two.add(&three), 5.into());
        assert_eq!(two.mul(&three), 6.into());
        assert_eq!(two.neg().add(&two), C::zero());
        assert!(C::zero().is_zero());
        assert!(!C::one().is_zero());
        assert_eq!(two.add_mul(&three, &two), 8.into());
    }

    #[test]
    fn i64_ring() {
        exercise_ring::<i64>();
    }

    #[test]
    fn i128_ring() {
        exercise_ring::<i128>();
    }

    #[test]
    fn bigint_ring() {
        exercise_ring::<BigInt>();
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn i64_overflow_is_loud() {
        i64::MAX.add(&1);
    }

    #[test]
    fn exact_f64_roundtrip() {
        assert_eq!(12345i64.to_exact_f64(), Some(12345.0));
        assert_eq!(i64::from_exact_f64(12345.0), Some(12345));
        // 2^53 + 1 is not exactly representable.
        let big = (1i64 << 53) + 1;
        assert_eq!(big.to_exact_f64(), None);
        assert_eq!(i64::from_exact_f64(0.5), None);
        // BigInt beyond i128 range is not representable either.
        let huge: BigInt = "123456789012345678901234567890123456789012".parse().unwrap();
        assert_eq!(huge.to_exact_f64(), None);
        assert_eq!(BigInt::from(7i64).to_exact_f64(), Some(7.0));
    }
}

//! A small polynomial expression parser for examples and the CLI:
//! sums of terms like `3*x^2*y - 4*z + 7`, variables drawn from a
//! caller-provided name list.

use super::{Coeff, Monomial, Polynomial, Term};

/// Parse error with position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParsePolyError {
    pub message: String,
    pub at: usize,
}

impl std::fmt::Display for ParsePolyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse error at byte {}: {}", self.at, self.message)
    }
}

impl std::error::Error for ParsePolyError {}

/// Parse `text` into a polynomial over `names`. Coefficient literals go
/// through the ring's exact-f64 conversion (every ring here represents
/// small integers exactly).
pub fn parse_polynomial<C: Coeff>(
    text: &str,
    names: &[&str],
) -> Result<Polynomial<C>, ParsePolyError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0, names };
    let terms = p.expression()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing input"));
    }
    Ok(Polynomial::from_terms(names.len(), terms))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    names: &'a [&'a str],
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> ParsePolyError {
        ParsePolyError { message: message.to_string(), at: self.pos }
    }

    fn skip_ws(&mut self) {
        while self.bytes.get(self.pos).is_some_and(|b| b.is_ascii_whitespace()) {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn expression<C: Coeff>(&mut self) -> Result<Vec<Term<C>>, ParsePolyError> {
        let mut terms = Vec::new();
        let mut sign = 1i64;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            sign = -1;
        } else if self.peek() == Some(b'+') {
            self.pos += 1;
        }
        loop {
            let (m, c) = self.term::<C>(sign)?;
            terms.push((m, c));
            match self.peek() {
                Some(b'+') => {
                    self.pos += 1;
                    sign = 1;
                }
                Some(b'-') => {
                    self.pos += 1;
                    sign = -1;
                }
                _ => break,
            }
        }
        Ok(terms)
    }

    fn term<C: Coeff>(&mut self, sign: i64) -> Result<Term<C>, ParsePolyError> {
        let mut coeff: i64 = sign;
        let mut exps = vec![0u16; self.names.len()];
        loop {
            match self.peek() {
                Some(b) if b.is_ascii_digit() => {
                    let n = self.number()?;
                    coeff = coeff
                        .checked_mul(n)
                        .ok_or_else(|| self.err("coefficient overflow"))?;
                }
                Some(b) if b.is_ascii_alphabetic() => {
                    let (idx, e) = self.variable_power()?;
                    exps[idx] = exps[idx]
                        .checked_add(e)
                        .ok_or_else(|| self.err("exponent overflow"))?;
                }
                _ => return Err(self.err("expected a number or variable")),
            }
            if self.peek() == Some(b'*') {
                self.pos += 1;
                continue;
            }
            break;
        }
        let c = C::from_exact_f64(coeff as f64)
            .ok_or_else(|| self.err("coefficient not representable in this ring"))?;
        Ok((Monomial::from_exps(exps), c))
    }

    fn number(&mut self) -> Result<i64, ParsePolyError> {
        self.skip_ws();
        let start = self.pos;
        while self.bytes.get(self.pos).is_some_and(|b| b.is_ascii_digit()) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .unwrap()
            .parse()
            .map_err(|_| self.err("bad number"))
    }

    fn variable_power(&mut self) -> Result<(usize, u16), ParsePolyError> {
        self.skip_ws();
        let start = self.pos;
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| b.is_ascii_alphanumeric() && !b.is_ascii_whitespace())
            && !matches!(self.bytes.get(self.pos), Some(b'^'))
        {
            // Stop variable names at operators.
            if matches!(self.bytes[self.pos], b'*' | b'+' | b'-') {
                break;
            }
            self.pos += 1;
        }
        let name = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let idx = self
            .names
            .iter()
            .position(|n| *n == name)
            .ok_or_else(|| self.err(&format!("unknown variable: {name}")))?;
        let mut e = 1u16;
        if self.peek() == Some(b'^') {
            self.pos += 1;
            let n = self.number()?;
            e = u16::try_from(n).map_err(|_| self.err("exponent out of range"))?;
        }
        Ok((idx, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const XYZ: &[&str] = &["x", "y", "z"];

    fn parse(s: &str) -> Polynomial<i64> {
        parse_polynomial(s, XYZ).unwrap()
    }

    #[test]
    fn parses_constants_and_vars() {
        assert_eq!(parse("7").to_string(), "7");
        assert_eq!(parse("x").to_string(), "x");
        assert_eq!(parse("-x").to_string(), "-1*x");
    }

    #[test]
    fn parses_products_and_powers() {
        assert_eq!(parse("3*x^2*y").to_string(), "3*x^2*y");
        assert_eq!(parse("x*x*x"), parse("x^3"));
        assert_eq!(parse("2*3*x"), parse("6*x"));
    }

    #[test]
    fn parses_sums_with_signs() {
        let p = parse("x^2 - 2*x + 1");
        assert_eq!(p.num_terms(), 3);
        assert_eq!(p, parse("1 + x^2 - 2*x"));
    }

    #[test]
    fn combines_like_terms() {
        assert_eq!(parse("x + x"), parse("2*x"));
        assert!(parse("x - x").is_zero());
    }

    #[test]
    fn parse_mul_roundtrip() {
        let a = parse("x + y + 1");
        let b = parse("x - y");
        let prod = a.mul(&b);
        assert_eq!(prod, parse("x^2 - y^2 + x - y"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_polynomial::<i64>("", XYZ).is_err());
        assert!(parse_polynomial::<i64>("x +", XYZ).is_err());
        assert!(parse_polynomial::<i64>("q", XYZ).is_err());
        assert!(parse_polynomial::<i64>("x^99999999", XYZ).is_err());
        assert!(parse_polynomial::<i64>("x y", XYZ).is_err());
    }
}

//! Monomials: exponent vectors under graded-lexicographic order.
//!
//! The paper's `Array[N]` with an order `s > t` (its `plus` branches on
//! the comparison). Graded-lex (total degree first, then lexicographic)
//! is the order Fateman's benchmark [2] and most CA systems default to;
//! any total order compatible with multiplication works for the
//! algorithm.

use std::sync::Arc;

/// An exponent vector. Immutable and cheaply cloneable (terms are copied
/// between tasks constantly in the stream algorithm).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Monomial {
    exps: Arc<[u16]>,
}

impl Monomial {
    /// The constant monomial `1` over `nvars` variables.
    pub fn one(nvars: usize) -> Self {
        Monomial { exps: vec![0u16; nvars].into() }
    }

    /// A single variable `x_i`.
    pub fn var(nvars: usize, i: usize) -> Self {
        assert!(i < nvars, "variable index out of range");
        let mut exps = vec![0u16; nvars];
        exps[i] = 1;
        Monomial { exps: exps.into() }
    }

    pub fn from_exps(exps: Vec<u16>) -> Self {
        Monomial { exps: exps.into() }
    }

    pub fn exps(&self) -> &[u16] {
        &self.exps
    }

    pub fn nvars(&self) -> usize {
        self.exps.len()
    }

    /// Total degree.
    pub fn degree(&self) -> u32 {
        self.exps.iter().map(|&e| e as u32).sum()
    }

    pub fn is_one(&self) -> bool {
        self.exps.iter().all(|&e| e == 0)
    }

    /// Monomial product — elementwise exponent addition (`s * m` in the
    /// paper's `multiply`). Panics on exponent overflow rather than
    /// silently wrapping.
    pub fn mul(&self, other: &Monomial) -> Monomial {
        assert_eq!(self.nvars(), other.nvars(), "mixed variable counts");
        let exps: Vec<u16> = self
            .exps
            .iter()
            .zip(other.exps.iter())
            .map(|(&a, &b)| a.checked_add(b).expect("exponent overflow"))
            .collect();
        Monomial { exps: exps.into() }
    }

    /// Render with the given variable names (falls back to `x{i}`).
    pub fn render(&self, names: &[&str]) -> String {
        if self.is_one() {
            return "1".to_string();
        }
        let mut parts = Vec::new();
        for (i, &e) in self.exps.iter().enumerate() {
            if e == 0 {
                continue;
            }
            let name = names.get(i).copied().map(str::to_string).unwrap_or(format!("x{i}"));
            if e == 1 {
                parts.push(name);
            } else {
                parts.push(format!("{name}^{e}"));
            }
        }
        parts.join("*")
    }
}

impl PartialOrd for Monomial {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Monomial {
    /// Graded-lex: higher total degree first; ties broken
    /// lexicographically on the exponent vector.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        debug_assert_eq!(self.nvars(), other.nvars(), "mixed variable counts");
        self.degree()
            .cmp(&other.degree())
            .then_with(|| self.exps.iter().cmp(other.exps.iter()))
    }
}

impl std::fmt::Display for Monomial {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render(&["x", "y", "z", "t", "u", "v", "w", "s"]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(exps: &[u16]) -> Monomial {
        Monomial::from_exps(exps.to_vec())
    }

    #[test]
    fn one_and_var() {
        assert!(Monomial::one(3).is_one());
        assert_eq!(Monomial::var(3, 1).exps(), &[0, 1, 0]);
        assert_eq!(Monomial::var(3, 1).degree(), 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn var_out_of_range_panics() {
        Monomial::var(2, 5);
    }

    #[test]
    fn product_adds_exponents() {
        assert_eq!(m(&[1, 2, 0]).mul(&m(&[0, 1, 3])), m(&[1, 3, 3]));
        assert_eq!(m(&[1, 1]).mul(&Monomial::one(2)), m(&[1, 1]));
    }

    #[test]
    #[should_panic(expected = "exponent overflow")]
    fn product_overflow_panics() {
        m(&[u16::MAX]).mul(&m(&[1]));
    }

    #[test]
    fn graded_lex_order() {
        // Degree dominates.
        assert!(m(&[2, 0]) > m(&[0, 1]));
        // Same degree: lexicographic.
        assert!(m(&[1, 1]) > m(&[0, 2]));
        assert!(m(&[2, 0]) > m(&[1, 1]));
        // Equal.
        assert_eq!(m(&[1, 2]).cmp(&m(&[1, 2])), std::cmp::Ordering::Equal);
    }

    #[test]
    fn order_compatible_with_multiplication() {
        // s > t implies s*m > t*m — required for the stream algorithm's
        // merge to stay sorted under multiply-by-a-term.
        let pairs = [
            (m(&[2, 0, 1]), m(&[1, 1, 1])),
            (m(&[0, 3, 0]), m(&[0, 1, 1])),
            (m(&[5, 0, 0]), m(&[0, 0, 4])),
        ];
        let mults = [m(&[1, 0, 2]), m(&[0, 0, 0]), m(&[3, 3, 3])];
        for (s, t) in &pairs {
            let ord = s.cmp(t);
            for mm in &mults {
                assert_eq!(s.mul(mm).cmp(&t.mul(mm)), ord, "{s} vs {t} times {mm}");
            }
        }
    }

    #[test]
    fn display_forms() {
        assert_eq!(Monomial::one(4).to_string(), "1");
        assert_eq!(m(&[1, 0, 2, 0]).to_string(), "x*z^2");
        assert_eq!(m(&[0, 1, 0, 1]).to_string(), "y*t");
        // Falls back past the provided names.
        let wide = Monomial::var(9, 8);
        assert_eq!(wide.render(&["x"]), "x8");
    }
}

//! Gröbner bases via Buchberger's algorithm — the domain application the
//! paper's own references motivate ([5] Kredel, [6] Melenk & Neun, [9]
//! Schwab all study *parallel polynomial operations in the (large)
//! Buchberger algorithm*).
//!
//! The algorithm is the classical pair-queue Buchberger with the two
//! standard criteria (coprime leading monomials; pair already covered),
//! in two execution flavours:
//!
//! * [`buchberger_seq`] — sequential reference;
//! * [`buchberger_par`] — S-polynomial construction and reduction of a
//!   *generation* of pairs fanned out over the executor (the
//!   data-parallel shape [6] describes), with the basis updated between
//!   generations.
//!
//! Coefficients must form an exact field: use
//! [`Rational`](crate::rational::Rational). (An earlier `f64` attempt
//! demonstrated the classic failure mode — 1e-17 cancellation residues
//! surviving as spurious leading terms and collapsing the computed
//! variety; see EXPERIMENTS.md §Numerics.)

use crate::exec::Executor;
use crate::par::par_map;
use crate::poly::{FieldCoeff, Polynomial};

/// Build the S-polynomial of `f` and `g`:
/// `S(f,g) = (lcm/lt(f))·f − (lcm/lt(g))·g`.
pub fn s_polynomial<C: FieldCoeff>(f: &Polynomial<C>, g: &Polynomial<C>) -> Polynomial<C> {
    let (fm, fc) = f.leading().expect("nonzero f");
    let (gm, gc) = g.leading().expect("nonzero g");
    let lcm = fm.lcm(gm);
    let a = f.mul_term(&lcm.div(fm), &FieldCoeff::div(&C::one(), fc));
    let b = g.mul_term(&lcm.div(gm), &FieldCoeff::div(&C::one(), gc));
    a.sub(&b)
}

fn criteria_skip<C: FieldCoeff>(f: &Polynomial<C>, g: &Polynomial<C>) -> bool {
    // Buchberger's first criterion: coprime leading monomials reduce to
    // zero — skip the pair.
    let (fm, _) = f.leading().expect("nonzero");
    let (gm, _) = g.leading().expect("nonzero");
    fm.coprime(gm)
}

/// Sequential Buchberger. Returns a reduced, monic Gröbner basis.
pub fn buchberger_seq<C: FieldCoeff>(generators: &[Polynomial<C>]) -> Vec<Polynomial<C>> {
    let mut basis: Vec<Polynomial<C>> =
        generators.iter().filter(|p| !p.is_zero()).cloned().collect();
    let mut pairs: Vec<(usize, usize)> = all_pairs(basis.len());
    while let Some((i, j)) = pairs.pop() {
        if criteria_skip(&basis[i], &basis[j]) {
            continue;
        }
        let s = s_polynomial(&basis[i], &basis[j]);
        let r = s.normal_form(&basis);
        if !r.is_zero() {
            let k = basis.len();
            for i in 0..k {
                pairs.push((i, k));
            }
            basis.push(r);
        }
    }
    reduce_basis(basis)
}

/// Generation-parallel Buchberger: each round reduces *all* outstanding
/// pairs in parallel against the current basis, then admits the new
/// non-zero remainders at once (deduplicated by leading monomial). This
/// is the fan-out/fan-in structure of [6]; it may do slightly more
/// reductions than the sequential version but produces the same reduced
/// basis.
pub fn buchberger_par<C: FieldCoeff>(
    exec: &Executor,
    generators: &[Polynomial<C>],
) -> Vec<Polynomial<C>> {
    let mut basis: Vec<Polynomial<C>> =
        generators.iter().filter(|p| !p.is_zero()).cloned().collect();
    let mut pairs: Vec<(usize, usize)> = all_pairs(basis.len());
    while !pairs.is_empty() {
        let snapshot = basis.clone();
        let todo: Vec<(usize, usize)> = std::mem::take(&mut pairs);
        let reduced: Vec<Polynomial<C>> = par_map(exec, &todo, move |&(i, j)| {
            if criteria_skip(&snapshot[i], &snapshot[j]) {
                Polynomial::zero(snapshot[i].nvars())
            } else {
                s_polynomial(&snapshot[i], &snapshot[j]).normal_form(&snapshot)
            }
        });
        // Admit new elements one at a time, re-reducing against the
        // growing basis so intra-generation duplicates collapse.
        for r in reduced {
            if r.is_zero() {
                continue;
            }
            let r = r.normal_form(&basis);
            if r.is_zero() {
                continue;
            }
            let k = basis.len();
            for i in 0..k {
                pairs.push((i, k));
            }
            basis.push(r);
        }
    }
    reduce_basis(basis)
}

fn all_pairs(n: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(n * (n.saturating_sub(1)) / 2);
    for j in 1..n {
        for i in 0..j {
            out.push((i, j));
        }
    }
    out
}

/// Inter-reduce and normalize: drop basis elements whose leading
/// monomial is divisible by another's, reduce each against the rest,
/// make monic, sort descending by leading monomial.
pub fn reduce_basis<C: FieldCoeff>(mut basis: Vec<Polynomial<C>>) -> Vec<Polynomial<C>> {
    // Drop redundant leading terms.
    let mut keep: Vec<Polynomial<C>> = Vec::new();
    for (i, p) in basis.iter().enumerate() {
        let (pm, _) = p.leading().expect("nonzero basis element");
        let redundant = basis.iter().enumerate().any(|(j, q)| {
            if i == j {
                return false;
            }
            let (qm, _) = q.leading().expect("nonzero");
            // Divisible by a *different* leading monomial, or an equal one
            // kept earlier.
            qm.divides(pm) && (qm != pm || j < i)
        });
        if !redundant {
            keep.push(p.clone());
        }
    }
    basis = keep;
    // Tail-reduce each against the others.
    let mut out: Vec<Polynomial<C>> = Vec::with_capacity(basis.len());
    for i in 0..basis.len() {
        let others: Vec<Polynomial<C>> = basis
            .iter()
            .enumerate()
            .filter(|&(j, _)| j != i)
            .map(|(_, q)| q.clone())
            .collect();
        let p = if others.is_empty() {
            basis[i].clone()
        } else {
            basis[i].normal_form(&others)
        };
        if !p.is_zero() {
            out.push(p.monic());
        }
    }
    out.sort_by(|a, b| {
        b.leading().expect("nonzero").0.cmp(&a.leading().expect("nonzero").0)
    });
    out
}

/// Is `basis` a Gröbner basis? (Every S-polynomial reduces to zero —
/// Buchberger's criterion; used by tests and the example as the
/// independent check.)
pub fn is_groebner<C: FieldCoeff>(basis: &[Polynomial<C>]) -> bool {
    for j in 1..basis.len() {
        for i in 0..j {
            if criteria_skip(&basis[i], &basis[j]) {
                continue;
            }
            if !s_polynomial(&basis[i], &basis[j]).normal_form(basis).is_zero() {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::parse_polynomial;
    use crate::rational::Rational;

    fn p2(s: &str) -> Polynomial<Rational> {
        parse_polynomial(s, &["x", "y"]).unwrap()
    }

    fn p3(s: &str) -> Polynomial<Rational> {
        parse_polynomial(s, &["x", "y", "z"]).unwrap()
    }

    #[test]
    fn s_polynomial_cancels_leading_terms() {
        let f = p2("x^2*y - 1");
        let g = p2("x*y^2 - x");
        let s = s_polynomial(&f, &g);
        // lcm = x^2 y^2; S = y·f/1 - x·g/1 = (x^2y^2 - y) - (x^2y^2 - x^2)
        assert_eq!(s, p2("x^2 - y"));
    }

    #[test]
    fn textbook_example_cox_little_oshea() {
        // I = <x^3 - 2xy, x^2 y - 2y^2 + x> (CLO §2.7): the reduced
        // grlex Gröbner basis is {x^2, xy, y^2 - x/2}.
        let f1 = p2("x^3 - 2*x*y");
        let f2 = p2("x^2*y - 2*y^2 + x");
        let basis = buchberger_seq(&[f1, f2]);
        assert!(is_groebner(&basis), "basis fails Buchberger's criterion");
        assert_eq!(basis.len(), 3);
        let rendered: Vec<String> = basis.iter().map(|p| p.to_string()).collect();
        assert!(rendered.contains(&"x^2".to_string()), "{rendered:?}");
        assert!(rendered.contains(&"x*y".to_string()), "{rendered:?}");
        assert!(rendered.iter().any(|s| s.starts_with("y^2")), "{rendered:?}");
        // Exact arithmetic: the third element is y^2 - x/2 precisely.
        assert!(rendered.contains(&"y^2 + -1/2*x".to_string()), "{rendered:?}");
    }

    #[test]
    fn parallel_matches_sequential() {
        let gens = [p3("x^2 + y + z - 1"), p3("x + y^2 + z - 1"), p3("x + y + z^2 - 1")];
        let seq = buchberger_seq(&gens);
        let ex = Executor::new(3);
        let par = buchberger_par(&ex, &gens);
        assert!(is_groebner(&seq));
        assert!(is_groebner(&par));
        assert_eq!(seq.len(), par.len(), "seq={seq:?}\npar={par:?}");
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn groebner_of_groebner_is_fixed_point() {
        let gens = [p2("x^2 - y"), p2("y^2 - x")];
        let basis = buchberger_seq(&gens);
        let again = buchberger_seq(&basis);
        assert_eq!(basis, again);
    }

    #[test]
    fn single_generator_is_its_own_basis() {
        let f = p2("x^2*y - 3");
        let basis = buchberger_seq(&[f.clone()]);
        assert_eq!(basis, vec![f.monic()]);
        assert!(is_groebner(&basis));
    }

    #[test]
    fn membership_test_via_normal_form() {
        // x^2+y+z-1 etc. generate an ideal containing their combinations.
        let gens = [p3("x^2 + y + z - 1"), p3("x + y^2 + z - 1")];
        let basis = buchberger_seq(&gens);
        let member = gens[0].mul(&p3("x + y")).add(&gens[1].mul(&p3("z^2")));
        assert!(member.normal_form(&basis).is_zero());
        let non_member = p3("x + 1");
        assert!(!non_member.normal_form(&basis).is_zero());
    }

    #[test]
    fn criteria_skip_on_coprime_leads() {
        let f = p2("x^3 + y");
        let g = p2("y^4 + x");
        assert!(criteria_skip(&f, &g));
    }
}

//! Artifact manifest: which AOT-compiled HLO modules exist and at which
//! shapes. Written by `python -m compile.aot`, parsed with the same
//! TOML-subset parser the config system uses.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::parse_toml_subset;

/// Kind + compiled shape of one artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `poly_block_outer`: term-block outer product.
    PolyOuter { bx: usize, by: usize, nvars: usize },
    /// `sieve_block_mask`: trial-division survivor mask.
    SieveMask { candidates: usize, primes: usize },
}

/// One manifest entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub kind: ArtifactKind,
}

/// Parse `<dir>/manifest.toml` into artifact specs.
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let manifest_path = dir.join("manifest.toml");
    let text = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {}", manifest_path.display()))?;
    let values = parse_toml_subset(&text).map_err(|e| anyhow::anyhow!("{e}"))?;

    // Group the flattened `section.key` entries back into sections.
    let mut sections: std::collections::BTreeMap<String, Vec<(String, String)>> =
        Default::default();
    for (k, v) in &values {
        let Some((section, key)) = k.split_once('.') else {
            bail!("manifest key outside a section: {k}");
        };
        sections
            .entry(section.to_string())
            .or_default()
            .push((key.to_string(), v.as_raw_string()));
    }

    let mut specs = Vec::new();
    for (name, kvs) in sections {
        let get = |key: &str| -> Result<String> {
            kvs.iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v.clone())
                .with_context(|| format!("artifact {name}: missing key {key}"))
        };
        let get_usize = |key: &str| -> Result<usize> {
            get(key)?.parse().with_context(|| format!("artifact {name}: bad {key}"))
        };
        let kind = match get("kind")?.as_str() {
            "poly_outer" => ArtifactKind::PolyOuter {
                bx: get_usize("bx")?,
                by: get_usize("by")?,
                nvars: get_usize("nvars")?,
            },
            "sieve_mask" => ArtifactKind::SieveMask {
                candidates: get_usize("candidates")?,
                primes: get_usize("primes")?,
            },
            other => bail!("artifact {name}: unknown kind {other}"),
        };
        let path = dir.join(get("path")?);
        specs.push(ArtifactSpec { name: name.clone(), path, kind });
    }
    if specs.is_empty() {
        bail!("manifest at {} lists no artifacts", manifest_path.display());
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(content: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "sfut-manifest-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.toml"), content).unwrap();
        dir
    }

    #[test]
    fn parses_both_kinds() {
        let dir = write_manifest(
            "[poly_outer_8x8]\npath = \"p.hlo.txt\"\nkind = \"poly_outer\"\n\
             bx = 8\nby = 8\nnvars = 4\n\
             [sieve_mask_128x16]\npath = \"s.hlo.txt\"\nkind = \"sieve_mask\"\n\
             candidates = 128\nprimes = 16\n",
        );
        let specs = load_manifest(&dir).unwrap();
        assert_eq!(specs.len(), 2);
        let poly = specs.iter().find(|s| s.name == "poly_outer_8x8").unwrap();
        assert_eq!(poly.kind, ArtifactKind::PolyOuter { bx: 8, by: 8, nvars: 4 });
        assert!(poly.path.ends_with("p.hlo.txt"));
    }

    #[test]
    fn missing_key_is_reported() {
        let dir = write_manifest("[a]\npath = \"x\"\nkind = \"poly_outer\"\nbx = 8\n");
        let err = load_manifest(&dir).unwrap_err();
        assert!(err.to_string().contains("missing key") || format!("{err:#}").contains("by"));
    }

    #[test]
    fn unknown_kind_is_reported() {
        let dir = write_manifest("[a]\npath = \"x\"\nkind = \"mystery\"\n");
        assert!(load_manifest(&dir).is_err());
    }

    #[test]
    fn empty_manifest_is_error() {
        let dir = write_manifest("# nothing here\n");
        assert!(load_manifest(&dir).is_err());
    }
}

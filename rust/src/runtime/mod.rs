//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, built
//! once by `make artifacts` — Python never runs on the request path) and
//! execute them from the Rust hot path.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so the
//! client and its compiled executables live on a dedicated **engine
//! thread**; callers talk to it through a channel-based service façade
//! ([`XlaEngine`]). Kernel calls are block-granular (a 128×128 term
//! outer product per request), so a single service thread sustains the
//! pipeline easily; the A2 ablation measures the handoff cost.
//!
//! [`KernelMultiplier`] / [`KernelSiever`] adapt the engine to the
//! algorithm-side traits (`poly::BlockMultiplier`, `sieve::BlockSiever`),
//! padding ragged blocks to the artifact's compiled shape and slicing
//! results back.
//!
//! Everything degrades gracefully: if the artifacts directory is missing
//! the caller falls back to the pure-Rust block implementations (see
//! `coordinator::Pipeline`).

mod artifacts;
mod engine;
mod multiplier;

pub use artifacts::{load_manifest, ArtifactKind, ArtifactSpec};
pub use engine::{EngineStats, XlaEngine};
pub use multiplier::{KernelMultiplier, KernelSiever};

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.toml").exists().then_some(dir)
    }

    #[test]
    fn manifest_loads_when_built() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let specs = load_manifest(&dir).unwrap();
        assert!(specs.iter().any(|s| matches!(s.kind, ArtifactKind::PolyOuter { .. })));
        assert!(specs.iter().any(|s| matches!(s.kind, ArtifactKind::SieveMask { .. })));
        for s in &specs {
            assert!(s.path.exists(), "{} missing", s.path.display());
        }
    }

    #[test]
    fn engine_runs_poly_outer_artifact() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let engine = XlaEngine::start(&dir).unwrap();
        let (bx, by, v) = engine.smallest_poly_shape().unwrap();
        let x_exps = vec![0i32; bx * v];
        let x_coefs: Vec<f64> = (0..bx).map(|i| i as f64).collect();
        let y_exps = vec![1i32; by * v];
        let y_coefs: Vec<f64> = (0..by).map(|i| (i + 1) as f64).collect();
        let (oe, oc) = engine.poly_outer(bx, by, &x_exps, &x_coefs, &y_exps, &y_coefs).unwrap();
        assert_eq!(oe.len(), bx * by * v);
        assert_eq!(oc.len(), bx * by);
        // Row-major check: out[i*by + j] = xc[i] * yc[j].
        assert_eq!(oc[by + 2], 1.0 * 3.0);
        assert!(oe.iter().all(|&e| e == 1));
        assert!(engine.stats().poly_calls >= 1);
    }

    #[test]
    fn engine_runs_sieve_artifact() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let engine = XlaEngine::start(&dir).unwrap();
        let (b, p) = engine.smallest_sieve_shape().unwrap();
        let sentinel = i32::MAX;
        let mut primes = vec![sentinel; p];
        primes[0] = 2;
        primes[1] = 3;
        let cands: Vec<i32> = (10..10 + b as i32).collect();
        let mask = engine.sieve_mask(&cands, &primes).unwrap();
        assert_eq!(mask.len(), b);
        for (i, &c) in cands.iter().enumerate() {
            let want = (c % 2 != 0 && c % 3 != 0) as i32;
            assert_eq!(mask[i], want, "candidate {c}");
        }
    }

    #[test]
    fn missing_dir_is_clean_error() {
        let err = XlaEngine::start(Path::new("/nonexistent/artifacts"));
        assert!(err.is_err());
    }
}

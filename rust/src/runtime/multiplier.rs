//! Adapters from the [`XlaEngine`] to the algorithm-side traits:
//! ragged-block padding, shape selection, and result slicing.

use std::sync::Arc;

use super::XlaEngine;
use crate::poly::{BlockMultiplier, TermBlock};
use crate::sieve::BlockSiever;

/// Pad-value for unused prime lanes: larger than every candidate, so
/// `c % SENTINEL == c != 0` never eliminates (matches
/// python/compile/kernels/sievemask.py's contract).
pub const PRIME_SENTINEL: i32 = i32::MAX;

/// [`BlockMultiplier`] backed by the AOT `poly_outer` artifact.
///
/// Blocks are padded with zero coefficients up to the compiled shape;
/// zero products are dropped again by `TermBlock::unpack` →
/// `Polynomial::from_terms`. Exponent vectors are padded to the
/// artifact's `nvars` with zero exponents.
pub struct KernelMultiplier {
    engine: Arc<XlaEngine>,
}

impl KernelMultiplier {
    pub fn new(engine: Arc<XlaEngine>) -> Self {
        KernelMultiplier { engine }
    }

    /// Pad `block` to (rows, nvars_padded); returns (exps, coefs).
    fn pad(block: &TermBlock, rows: usize, nvars_pad: usize) -> (Vec<i32>, Vec<f64>) {
        let n = block.count();
        debug_assert!(n <= rows && block.nvars <= nvars_pad);
        let mut exps = vec![0i32; rows * nvars_pad];
        for i in 0..n {
            exps[i * nvars_pad..i * nvars_pad + block.nvars]
                .copy_from_slice(&block.exps[i * block.nvars..(i + 1) * block.nvars]);
        }
        let mut coefs = vec![0f64; rows];
        coefs[..n].copy_from_slice(&block.coefs);
        (exps, coefs)
    }
}

impl BlockMultiplier for KernelMultiplier {
    fn outer_product(&self, x: &TermBlock, y: &TermBlock) -> TermBlock {
        assert_eq!(x.nvars, y.nvars, "mixed variable counts");
        let (nx, ny) = (x.count(), y.count());
        let (bx, by, nvars_pad) = self
            .engine
            .pick_poly_shape(nx, ny)
            .expect("engine has no poly artifacts");
        assert!(
            nx <= bx && ny <= by,
            "block {nx}x{ny} exceeds largest compiled shape {bx}x{by} \
             (chunked_times clamps chunk_size to max_block)"
        );
        assert!(x.nvars <= nvars_pad, "nvars {} exceeds artifact width {nvars_pad}", x.nvars);

        let (xe, xc) = Self::pad(x, bx, nvars_pad);
        let (ye, yc) = Self::pad(y, by, nvars_pad);
        let (oe, oc) = self
            .engine
            .poly_outer(bx, by, &xe, &xc, &ye, &yc)
            .expect("poly_outer artifact execution failed");

        // Slice the (bx × by) padded result back to (nx × ny), row-major,
        // restoring the caller's nvars.
        let v = x.nvars;
        let mut exps = Vec::with_capacity(nx * ny * v);
        let mut coefs = Vec::with_capacity(nx * ny);
        for i in 0..nx {
            for j in 0..ny {
                let row = i * by + j;
                exps.extend_from_slice(&oe[row * nvars_pad..row * nvars_pad + v]);
                coefs.push(oc[row]);
            }
        }
        TermBlock { nvars: v, exps, coefs }
    }

    fn name(&self) -> &'static str {
        "pjrt-kernel"
    }

    fn max_block(&self) -> usize {
        self.engine.largest_poly_shape().map(|(bx, by, _)| bx.min(by)).unwrap_or(0)
    }
}

/// [`BlockSiever`] backed by the AOT `sieve_mask` artifact.
///
/// Candidate blocks are padded with a repeat of the first candidate (its
/// mask lanes are discarded); primes are padded with [`PRIME_SENTINEL`].
/// Prime vectors wider than the artifact are split and the masks ANDed.
pub struct KernelSiever {
    engine: Arc<XlaEngine>,
}

impl KernelSiever {
    pub fn new(engine: Arc<XlaEngine>) -> Self {
        KernelSiever { engine }
    }
}

impl BlockSiever for KernelSiever {
    fn survivors(&self, candidates: &[u32], primes: &[u32]) -> Vec<bool> {
        if candidates.is_empty() {
            return Vec::new();
        }
        let shapes = self.engine.sieve_shapes().to_vec();
        assert!(!shapes.is_empty(), "engine has no sieve artifacts");
        // Smallest candidate shape that fits, else the largest (split).
        let &(cand_b, prime_p) = shapes
            .iter()
            .find(|&&(b, _)| b >= candidates.len())
            .unwrap_or_else(|| shapes.last().unwrap());

        let mut out = vec![true; candidates.len()];
        for chunk_start in (0..candidates.len()).step_by(cand_b) {
            let chunk = &candidates[chunk_start..(chunk_start + cand_b).min(candidates.len())];
            let mut cands = vec![chunk[0] as i32; cand_b];
            for (i, &c) in chunk.iter().enumerate() {
                cands[i] = i32::try_from(c).expect("candidate fits i32");
            }
            // Split wide prime vectors; AND the masks.
            let mut prime_chunks: Vec<Vec<i32>> = Vec::new();
            if primes.is_empty() {
                prime_chunks.push(vec![PRIME_SENTINEL; prime_p]);
            }
            for ps in primes.chunks(prime_p) {
                let mut padded = vec![PRIME_SENTINEL; prime_p];
                for (i, &p) in ps.iter().enumerate() {
                    padded[i] = i32::try_from(p).expect("prime fits i32");
                }
                prime_chunks.push(padded);
            }
            for padded in &prime_chunks {
                let mask = self
                    .engine
                    .sieve_mask(&cands, padded)
                    .expect("sieve_mask artifact execution failed");
                for (i, &m) in mask.iter().take(chunk.len()).enumerate() {
                    if m == 0 {
                        out[chunk_start + i] = false;
                    }
                }
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "pjrt-kernel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::RustMultiplier;
    use crate::sieve::RustSiever;
    use crate::testkit::prop::{runner, Gen};
    use std::path::Path;

    fn engine() -> Option<Arc<XlaEngine>> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.toml").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Arc::new(XlaEngine::start(&dir).unwrap()))
    }

    fn random_block(g: &mut Gen, count: usize, nvars: usize) -> TermBlock {
        TermBlock {
            nvars,
            exps: (0..count * nvars).map(|_| g.u32_in(0..20) as i32).collect(),
            coefs: (0..count).map(|_| g.i64_in(-999..=999) as f64).collect(),
        }
    }

    #[test]
    fn kernel_multiplier_matches_rust_oracle() {
        let Some(engine) = engine() else { return };
        let km = KernelMultiplier::new(engine);
        let mut r = runner(20);
        r.run(|g: &mut Gen| {
            let nx = g.usize_in(1..33);
            let ny = g.usize_in(1..33);
            let v = g.usize_in(1..8);
            let x = random_block(g, nx, v);
            let y = random_block(g, ny, v);
            let got = km.outer_product(&x, &y);
            let want = RustMultiplier.outer_product(&x, &y);
            assert_eq!(got, want, "nx={nx} ny={ny} v={v}");
        });
    }

    #[test]
    fn kernel_multiplier_handles_full_blocks() {
        let Some(engine) = engine() else { return };
        let km = KernelMultiplier::new(engine);
        let max = km.max_block();
        let mut g = Gen::from_seed(7);
        let x = random_block(&mut g, max, 8);
        let y = random_block(&mut g, max, 8);
        let got = km.outer_product(&x, &y);
        let want = RustMultiplier.outer_product(&x, &y);
        assert_eq!(got, want);
    }

    #[test]
    fn kernel_siever_matches_rust_oracle() {
        let Some(engine) = engine() else { return };
        let ks = KernelSiever::new(engine);
        let mut r = runner(10);
        r.run(|g: &mut Gen| {
            let n = g.usize_in(1..700);
            let candidates: Vec<u32> = (0..n).map(|_| g.u32_in(2..100_000)).collect();
            let nprimes = g.usize_in(0..80); // > artifact width: forces split
            let primes: Vec<u32> = (0..nprimes).map(|_| g.u32_in(2..300)).collect();
            let got = ks.survivors(&candidates, &primes);
            let want = RustSiever.survivors(&candidates, &primes);
            assert_eq!(got, want, "n={n} nprimes={nprimes}");
        });
    }

    #[test]
    fn empty_candidates() {
        let Some(engine) = engine() else { return };
        let ks = KernelSiever::new(engine);
        assert!(ks.survivors(&[], &[2, 3]).is_empty());
    }
}

//! The PJRT engine-service thread.
//!
//! Owns the (non-`Send`) `PjRtClient` and all compiled executables;
//! serves block-kernel requests over an MPSC channel. Startup compiles
//! every artifact in the manifest eagerly, so the first hot-path call
//! pays no compile latency.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use log::{debug, info};

use super::artifacts::{load_manifest, ArtifactKind, ArtifactSpec};
use crate::exec::Executor;

#[cfg(not(feature = "xla"))]
use self::xla_stub as xla;

/// Offline stand-in for the `xla` crate, used when the `xla` feature is
/// off (the default — the real crate is not vendored). Only
/// `PjRtClient::cpu` is ever reached: it fails with a clean error, the
/// engine thread reports startup failure, and every caller falls back to
/// the pure-Rust block implementations. The remaining types exist so the
/// engine code typechecks; their bodies are unreachable (the client is
/// uninhabited, so no executable or literal can ever be constructed).
#[cfg(not(feature = "xla"))]
#[allow(dead_code)]
mod xla_stub {
    use std::fmt;
    use std::path::Path;

    /// Uninhabited: proves the unreachable method bodies sound.
    enum Never {}

    #[derive(Debug)]
    pub struct Unavailable;

    impl fmt::Display for Unavailable {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str(
                "PJRT support not compiled in (enable the `xla` cargo feature \
                 and add the xla crate); falling back to pure-Rust kernels",
            )
        }
    }

    pub struct PjRtClient(Never);

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Unavailable> {
            Err(Unavailable)
        }

        pub fn platform_name(&self) -> String {
            match self.0 {}
        }

        pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, Unavailable> {
            match self.0 {}
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, Unavailable> {
            Err(Unavailable)
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    pub struct PjRtLoadedExecutable(Never);

    impl PjRtLoadedExecutable {
        pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Unavailable> {
            match self.0 {}
        }
    }

    pub struct PjRtBuffer(Never);

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Unavailable> {
            match self.0 {}
        }
    }

    pub struct Literal(Never);

    impl Literal {
        pub fn vec1<T>(_values: &[T]) -> Literal {
            unreachable!("xla stub: no Literal can exist without a client")
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Unavailable> {
            match self.0 {}
        }

        pub fn to_tuple1(self) -> Result<Literal, Unavailable> {
            match self.0 {}
        }

        pub fn to_tuple2(self) -> Result<(Literal, Literal), Unavailable> {
            match self.0 {}
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Unavailable> {
            match self.0 {}
        }
    }
}

/// Request/response protocol between callers and the engine thread.
enum Request {
    PolyOuter {
        bx: usize,
        by: usize,
        x_exps: Vec<i32>,
        x_coefs: Vec<f64>,
        y_exps: Vec<i32>,
        y_coefs: Vec<f64>,
        reply: mpsc::SyncSender<Result<(Vec<i32>, Vec<f64>)>>,
    },
    SieveMask {
        candidates: Vec<i32>,
        primes: Vec<i32>,
        reply: mpsc::SyncSender<Result<Vec<i32>>>,
    },
    Shutdown,
}

/// Instantaneous engine counters.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub poly_calls: u64,
    pub sieve_calls: u64,
    pub total_exec_nanos: u64,
}

struct Shared {
    poly_calls: AtomicU64,
    sieve_calls: AtomicU64,
    total_exec_nanos: AtomicU64,
}

/// Handle to the engine-service thread. Cheap to clone; the thread shuts
/// down when the last handle drops.
#[derive(Clone)]
pub struct XlaEngine {
    tx: mpsc::Sender<Request>,
    /// Compiled poly shapes (bx, by) → nvars.
    poly_shapes: BTreeMap<(usize, usize), usize>,
    /// Compiled sieve shapes (candidates, primes).
    sieve_shapes: Vec<(usize, usize)>,
    shared: Arc<Shared>,
    platform: String,
}

impl XlaEngine {
    /// Load the manifest in `dir`, compile every artifact on a fresh
    /// engine thread, and return a handle once everything is ready.
    pub fn start(dir: &Path) -> Result<XlaEngine> {
        let specs = load_manifest(dir)?;
        let shared = Arc::new(Shared {
            poly_calls: AtomicU64::new(0),
            sieve_calls: AtomicU64::new(0),
            total_exec_nanos: AtomicU64::new(0),
        });
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<String>>(1);
        let specs_for_thread = specs.clone();
        let shared2 = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("sfut-xla-engine".to_string())
            .spawn(move || engine_thread(specs_for_thread, rx, ready_tx, shared2))
            .context("spawning engine thread")?;
        let platform = ready_rx
            .recv()
            .context("engine thread died during startup")??;

        let mut poly_shapes = BTreeMap::new();
        let mut sieve_shapes = Vec::new();
        for s in &specs {
            match s.kind {
                ArtifactKind::PolyOuter { bx, by, nvars } => {
                    poly_shapes.insert((bx, by), nvars);
                }
                ArtifactKind::SieveMask { candidates, primes } => {
                    sieve_shapes.push((candidates, primes));
                }
            }
        }
        sieve_shapes.sort_unstable();
        Ok(XlaEngine { tx, poly_shapes, sieve_shapes, shared, platform })
    }

    /// PJRT platform name ("Host" for the CPU plugin).
    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Compiled poly-outer shapes, ascending.
    pub fn poly_shapes(&self) -> Vec<(usize, usize, usize)> {
        self.poly_shapes.iter().map(|(&(bx, by), &v)| (bx, by, v)).collect()
    }

    pub fn smallest_poly_shape(&self) -> Option<(usize, usize, usize)> {
        self.poly_shapes().into_iter().next()
    }

    /// Largest compiled (bx, by, nvars).
    pub fn largest_poly_shape(&self) -> Option<(usize, usize, usize)> {
        self.poly_shapes().into_iter().last()
    }

    /// Pick the smallest compiled poly shape fitting (nx, ny); falls back
    /// to the largest shape (caller then splits).
    pub fn pick_poly_shape(&self, nx: usize, ny: usize) -> Option<(usize, usize, usize)> {
        self.poly_shapes()
            .into_iter()
            .find(|&(bx, by, _)| bx >= nx && by >= ny)
            .or_else(|| self.largest_poly_shape())
    }

    pub fn sieve_shapes(&self) -> &[(usize, usize)] {
        &self.sieve_shapes
    }

    pub fn smallest_sieve_shape(&self) -> Option<(usize, usize)> {
        self.sieve_shapes.first().copied()
    }

    /// Execute the poly-outer artifact compiled at exactly `(bx, by)`.
    /// Inputs must already be padded: `x_exps.len() == bx * nvars`, etc.
    pub fn poly_outer(
        &self,
        bx: usize,
        by: usize,
        x_exps: &[i32],
        x_coefs: &[f64],
        y_exps: &[i32],
        y_coefs: &[f64],
    ) -> Result<(Vec<i32>, Vec<f64>)> {
        let nvars = *self
            .poly_shapes
            .get(&(bx, by))
            .ok_or_else(|| anyhow!("no poly_outer artifact compiled at {bx}x{by}"))?;
        anyhow::ensure!(x_exps.len() == bx * nvars, "x_exps len");
        anyhow::ensure!(x_coefs.len() == bx, "x_coefs len");
        anyhow::ensure!(y_exps.len() == by * nvars, "y_exps len");
        anyhow::ensure!(y_coefs.len() == by, "y_coefs len");
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::PolyOuter {
                bx,
                by,
                x_exps: x_exps.to_vec(),
                x_coefs: x_coefs.to_vec(),
                y_exps: y_exps.to_vec(),
                y_coefs: y_coefs.to_vec(),
                reply,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        self.shared.poly_calls.fetch_add(1, Ordering::Relaxed);
        // A pool worker may be the caller: park under managed blocking.
        Executor::blocking(|| rx.recv()).map_err(|_| anyhow!("engine dropped reply"))?
    }

    /// Execute the sieve-mask artifact compiled at exactly
    /// `(candidates.len(), primes.len())`.
    pub fn sieve_mask(&self, candidates: &[i32], primes: &[i32]) -> Result<Vec<i32>> {
        let shape = (candidates.len(), primes.len());
        anyhow::ensure!(
            self.sieve_shapes.contains(&shape),
            "no sieve_mask artifact compiled at {}x{}",
            shape.0,
            shape.1
        );
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::SieveMask {
                candidates: candidates.to_vec(),
                primes: primes.to_vec(),
                reply,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        self.shared.sieve_calls.fetch_add(1, Ordering::Relaxed);
        Executor::blocking(|| rx.recv()).map_err(|_| anyhow!("engine dropped reply"))?
    }

    pub fn stats(&self) -> EngineStats {
        EngineStats {
            poly_calls: self.shared.poly_calls.load(Ordering::Relaxed),
            sieve_calls: self.shared.sieve_calls.load(Ordering::Relaxed),
            total_exec_nanos: self.shared.total_exec_nanos.load(Ordering::Relaxed),
        }
    }

    /// Eager shutdown (otherwise happens when the last handle drops).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

/// Body of the engine thread: compile everything, then serve.
fn engine_thread(
    specs: Vec<ArtifactSpec>,
    rx: mpsc::Receiver<Request>,
    ready_tx: mpsc::SyncSender<Result<String>>,
    shared: Arc<Shared>,
) {
    let setup = || -> Result<(
        xla::PjRtClient,
        BTreeMap<(usize, usize), (xla::PjRtLoadedExecutable, usize)>,
        BTreeMap<(usize, usize), xla::PjRtLoadedExecutable>,
    )> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        let mut poly = BTreeMap::new();
        let mut sieve = BTreeMap::new();
        for spec in &specs {
            let proto = xla::HloModuleProto::from_text_file(&spec.path)
                .map_err(|e| anyhow!("parsing {}: {e}", spec.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", spec.name))?;
            match spec.kind {
                ArtifactKind::PolyOuter { bx, by, nvars } => {
                    poly.insert((bx, by), (exe, nvars));
                }
                ArtifactKind::SieveMask { candidates, primes } => {
                    sieve.insert((candidates, primes), exe);
                }
            }
        }
        Ok((client, poly, sieve))
    };

    let (client, poly, sieve) = match setup() {
        Ok(v) => v,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    info!(
        "xla engine ready: platform={}, {} poly + {} sieve executables",
        client.platform_name(),
        poly.len(),
        sieve.len()
    );
    let _ = ready_tx.send(Ok(client.platform_name()));

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::PolyOuter { bx, by, x_exps, x_coefs, y_exps, y_coefs, reply } => {
                let start = Instant::now();
                debug!("poly_outer {bx}x{by}");
                let result = run_poly(&poly, bx, by, &x_exps, &x_coefs, &y_exps, &y_coefs);
                shared
                    .total_exec_nanos
                    .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
                let _ = reply.send(result);
            }
            Request::SieveMask { candidates, primes, reply } => {
                let start = Instant::now();
                let result = run_sieve(&sieve, &candidates, &primes);
                shared
                    .total_exec_nanos
                    .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
                let _ = reply.send(result);
            }
        }
    }
    drop(client);
}

fn run_poly(
    poly: &BTreeMap<(usize, usize), (xla::PjRtLoadedExecutable, usize)>,
    bx: usize,
    by: usize,
    x_exps: &[i32],
    x_coefs: &[f64],
    y_exps: &[i32],
    y_coefs: &[f64],
) -> Result<(Vec<i32>, Vec<f64>)> {
    let Some((exe, nvars)) = poly.get(&(bx, by)) else {
        bail!("no poly executable at {bx}x{by}");
    };
    let v = *nvars as i64;
    let xe = xla::Literal::vec1(x_exps)
        .reshape(&[bx as i64, v])
        .map_err(|e| anyhow!("reshape x_exps: {e}"))?;
    let xc = xla::Literal::vec1(x_coefs);
    let ye = xla::Literal::vec1(y_exps)
        .reshape(&[by as i64, v])
        .map_err(|e| anyhow!("reshape y_exps: {e}"))?;
    let yc = xla::Literal::vec1(y_coefs);
    let result = exe
        .execute::<xla::Literal>(&[xe, xc, ye, yc])
        .map_err(|e| anyhow!("execute poly_outer: {e}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetch result: {e}"))?;
    let (oe, oc) = result.to_tuple2().map_err(|e| anyhow!("untuple: {e}"))?;
    Ok((
        oe.to_vec::<i32>().map_err(|e| anyhow!("exps to_vec: {e}"))?,
        oc.to_vec::<f64>().map_err(|e| anyhow!("coefs to_vec: {e}"))?,
    ))
}

fn run_sieve(
    sieve: &BTreeMap<(usize, usize), xla::PjRtLoadedExecutable>,
    candidates: &[i32],
    primes: &[i32],
) -> Result<Vec<i32>> {
    let shape = (candidates.len(), primes.len());
    let Some(exe) = sieve.get(&shape) else {
        bail!("no sieve executable at {}x{}", shape.0, shape.1);
    };
    let c = xla::Literal::vec1(candidates);
    let p = xla::Literal::vec1(primes);
    let result = exe
        .execute::<xla::Literal>(&[c, p])
        .map_err(|e| anyhow!("execute sieve_mask: {e}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetch result: {e}"))?;
    let mask = result.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
    mask.to_vec::<i32>().map_err(|e| anyhow!("mask to_vec: {e}"))
}

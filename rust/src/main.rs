//! `sfut` — CLI launcher for the stream-future reproduction.
//!
//! ```text
//! sfut run <spec> <mode> [options]         run one cell; spec = name[(k=v,...)],
//!                                          e.g. `run fib(n=64) par(2)`
//! sfut workloads [options]                 list every registered workload with its
//!                                          parameter schema
//! sfut table1 [options]                    regenerate Table 1
//! sfut fig3 [options]                      regenerate Figure 3
//! sfut fig4 [options]                      regenerate Figure 4
//! sfut serve [options]                     line-protocol request loop on stdio
//! sfut info [options]                      platform / artifact / config report
//! sfut bench run <plan-file>               execute a declarative ablation plan
//!                                          (see ci/plans/*.plan) and append every
//!                                          cell, provenance-stamped, to
//!                                          BENCH_registry.jsonl
//! sfut bench gate <target|all> [<a> <b>]   perf-regression gate; with no files,
//!                                          gates the working-tree BENCH files of
//!                                          every plan-declared target (missing
//!                                          baseline = UNARMED, not a failure)
//! sfut bench list [gates]                  list committed plans and gate targets
//!                                          (`gates` = machine-readable gate set)
//! sfut bench report [plan]                 diff registry cells across commits
//! sfut check-bench <a> <b>                 deprecated alias for
//!                                          `sfut bench gate <target> <a> <b>`
//! sfut lint [--json]                       repo-invariant static analysis over
//!                                          rust/src + rust/tests (SAFETY comments,
//!                                          metric-name taxonomy, config-key docs,
//!                                          err-line hygiene); exits non-zero on
//!                                          findings
//! ```
//!
//! options:
//!   --config <file>          TOML-subset config file
//!   --set <key>=<value>      override one config key (repeatable)
//!   --scale <f>              shorthand for --set scale=<f>
//!   --no-kernel              shorthand for --set use_kernel=false
//!   --samples <n>            bench samples per cell
//!   --queue-depth <n>        shorthand for --set queue_depth=<n>
//!   --admission <policy>     shorthand for --set admission=<policy>
//!                            (block | shed | timeout(MS))
//!   --deque <kind>           shorthand for --set deque=<kind>
//!                            (chase_lev | locked)
//!   --wire <protocol>        shorthand for --set wire=<protocol>
//!                            (framed | text) — TCP listener wire mode
//!   --poller <backend>       shorthand for --set poller=<backend>
//!                            (poll | epoll | auto) — framed readiness
//!                            backend; auto = epoll on linux, else poll
//!   --reactors <n>           shorthand for --set reactors=<n> — framed
//!                            reactor threads (0 = auto from cores)
//!   --threshold <f>          bench gate regression tolerance (default 0.25)
//!   --latency-threshold <f>  bench gate p95 growth tolerated before a
//!                            finding (default 0.25)
//!   --latency-strict         bench gate: p95 latency/queue-wait findings
//!                            fail the gate instead of warning (auto-disarms
//!                            while the baseline's note marks it synthetic)
//!
//! (clap is unavailable offline; parsing is hand-rolled and strict —
//! unknown flags are errors, not surprises.)

use std::io::{stdin, stdout, BufReader};
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{bail, Context, Result};
use stream_future::bench_harness::paper;
use stream_future::bench_harness::{plan, registry};
use stream_future::config::Config;
use stream_future::coordinator::{serve, JobRequest, Pipeline};

struct Cli {
    command: String,
    positional: Vec<String>,
    config_file: Option<PathBuf>,
    overrides: Vec<(String, String)>,
    threshold: Option<f64>,
    latency_threshold: Option<f64>,
    latency_strict: bool,
    json: bool,
}

fn parse_args(mut args: impl Iterator<Item = String>) -> Result<Cli> {
    let command = args.next().unwrap_or_else(|| "help".to_string());
    let mut cli = Cli {
        command,
        positional: Vec::new(),
        config_file: None,
        overrides: Vec::new(),
        threshold: None,
        latency_threshold: None,
        latency_strict: false,
        json: false,
    };
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--config" => {
                let v = args.next().context("--config needs a path")?;
                cli.config_file = Some(PathBuf::from(v));
            }
            "--set" => {
                let v = args.next().context("--set needs key=value")?;
                let (k, val) = v.split_once('=').context("--set needs key=value")?;
                cli.overrides.push((k.to_string(), val.to_string()));
            }
            "--scale" => {
                let v = args.next().context("--scale needs a number")?;
                cli.overrides.push(("scale".to_string(), v));
            }
            "--samples" => {
                let v = args.next().context("--samples needs a number")?;
                cli.overrides.push(("samples".to_string(), v));
            }
            "--no-kernel" => {
                cli.overrides.push(("use_kernel".to_string(), "false".to_string()));
            }
            "--queue-depth" => {
                let v = args.next().context("--queue-depth needs a number")?;
                cli.overrides.push(("queue_depth".to_string(), v));
            }
            "--admission" => {
                let v = args
                    .next()
                    .context("--admission needs a policy (block | shed | timeout(MS))")?;
                cli.overrides.push(("admission".to_string(), v));
            }
            "--deque" => {
                let v = args.next().context("--deque needs a kind (chase_lev | locked)")?;
                cli.overrides.push(("deque".to_string(), v));
            }
            "--wire" => {
                let v = args.next().context("--wire needs a protocol (framed | text)")?;
                cli.overrides.push(("wire".to_string(), v));
            }
            "--poller" => {
                let v = args.next().context("--poller needs a backend (poll | epoll | auto)")?;
                cli.overrides.push(("poller".to_string(), v));
            }
            "--reactors" => {
                let v = args.next().context("--reactors needs a count (0 = auto)")?;
                cli.overrides.push(("reactors".to_string(), v));
            }
            "--latency-strict" => {
                cli.latency_strict = true;
            }
            "--json" => {
                cli.json = true;
            }
            "--latency-threshold" => {
                let v = args.next().context("--latency-threshold needs a number > 0")?;
                let t: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad --latency-threshold value: {v}"))?;
                if !(t > 0.0) {
                    bail!("--latency-threshold must be > 0, got {v}");
                }
                cli.latency_threshold = Some(t);
            }
            "--threshold" => {
                let v = args.next().context("--threshold needs a number in (0, 1)")?;
                let t: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad --threshold value: {v}"))?;
                if !(t > 0.0 && t < 1.0) {
                    bail!("--threshold must be in (0, 1), got {v}");
                }
                cli.threshold = Some(t);
            }
            other if other.starts_with("--") => bail!("unknown flag: {other}"),
            other => cli.positional.push(other.to_string()),
        }
    }
    let gate_command = matches!(cli.command.as_str(), "check-bench" | "bench");
    if cli.threshold.is_some() && !gate_command {
        bail!("--threshold only applies to bench gate / check-bench");
    }
    if cli.latency_threshold.is_some() && !gate_command {
        bail!("--latency-threshold only applies to bench gate / check-bench");
    }
    if cli.latency_strict && !gate_command {
        bail!("--latency-strict only applies to bench gate / check-bench");
    }
    if cli.json && cli.command != "lint" {
        bail!("--json only applies to lint");
    }
    Ok(cli)
}

fn load_config(cli: &Cli) -> Result<Config> {
    Config::load(cli.config_file.as_deref(), &cli.overrides).map_err(|e| anyhow::anyhow!("{e}"))
}

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Run one baseline-vs-current gate over a pair of unified-schema (or
/// legacy) bench files. Dispatches on the current run's trajectory
/// kind; a current file that does not even parse to a known kind is a
/// hard error — a broken bench writer must fail the gate, never skip
/// it.
fn gate_files(
    baseline_path: &Path,
    current_path: &Path,
    threshold: f64,
    latency_threshold: f64,
    latency_strict: bool,
    latency_flags_given: bool,
) -> Result<()> {
    let baseline = std::fs::read_to_string(baseline_path)
        .with_context(|| format!("reading baseline {}", baseline_path.display()))?;
    let current = std::fs::read_to_string(current_path)
        .with_context(|| format!("reading current {}", current_path.display()))?;
    use stream_future::bench_harness::tiny_json::{self, Json};
    use stream_future::bench_harness::{executor_bench, pipeline_bench};
    use stream_future::bench_harness::{GateOutcome, LatencyGate};
    let kind = tiny_json::parse(&current)
        .map_err(|e| anyhow::anyhow!("current run is not valid JSON: {e}"))?
        .get("bench")
        .and_then(Json::as_str)
        .map(str::to_string)
        .context("current run has no \"bench\" field — bench writer broken")?;
    let report = match kind.as_str() {
        "pipeline_throughput" => {
            pipeline_bench::gate(&baseline, &current, threshold, latency_threshold, latency_strict)
        }
        "executor_overhead" => {
            // Executor trajectories carry no latency cells; make inert
            // flags visible instead of silently accepting them.
            if latency_flags_given {
                eprintln!(
                    "note: --latency-strict/--latency-threshold do not apply to \
                     executor_overhead trajectories (throughput-only gate)"
                );
            }
            executor_bench::gate(&baseline, &current, threshold)
        }
        "ingress_wire_saturation" => stream_future::bench_harness::ingress_bench::gate(
            &baseline,
            &current,
            threshold,
            latency_threshold,
            latency_strict,
        ),
        other => bail!("unknown trajectory kind: {other}"),
    }
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    match report.latency_gate {
        LatencyGate::WarnOnly => {}
        LatencyGate::Strict => println!("latency gate: STRICT (armed)"),
        LatencyGate::StrictDisarmedSyntheticBaseline => println!(
            "latency gate: strict requested but DISARMED — the committed \
             baseline's note marks it a synthetic floor; refresh it with a \
             measured run to arm (see ci/check_bench.sh)"
        ),
    }
    // Warn-only findings (p95 latency/queue-wait growth, nonzero panic
    // rates on non-faulty workloads) print regardless of the throughput
    // verdict; under --latency-strict the latency ones appear as
    // REGRESSION lines instead.
    for w in &report.warnings {
        eprintln!("WARNING (warn-only): {w}");
    }
    match report.outcome {
        GateOutcome::Passed { cells } => {
            println!(
                "bench gate PASSED: {cells} cell(s) within {:.0}% of baseline \
                 ({} latency warning(s))",
                threshold * 100.0,
                report.warnings.len()
            );
            Ok(())
        }
        GateOutcome::Skipped { reason } => {
            println!("bench gate SKIPPED: {reason}");
            Ok(())
        }
        GateOutcome::Failed { regressions } => {
            for r in &regressions {
                eprintln!("REGRESSION: {r}");
            }
            bail!("bench gate FAILED: {} regression(s) beyond tolerance", regressions.len());
        }
    }
}

/// The `sfut bench` family: `run <plan>`, `gate <target|all> [<a> <b>]`,
/// `list [gates]`, `report [plan]`.
fn bench_command(cli: &Cli) -> Result<()> {
    let threshold = cli.threshold.unwrap_or(0.25);
    let latency_threshold = cli
        .latency_threshold
        .unwrap_or(stream_future::bench_harness::DEFAULT_LATENCY_THRESHOLD);
    let latency_flags_given = cli.latency_strict || cli.latency_threshold.is_some();
    match cli.positional.first().map(String::as_str) {
        Some("run") => {
            if cli.positional.len() != 2 {
                bail!("usage: sfut bench run <plan-file> [--config <file>] [--set k=v]");
            }
            let base = load_config(cli)?;
            let plan = plan::load(Path::new(&cli.positional[1]))
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let report = plan::run_plan(&plan, &base)?;
            print!("{}", report.render());
            let path = registry::default_path();
            let cells = registry::append(&path, &report)
                .with_context(|| format!("appending to {}", path.display()))?;
            println!("appended {cells} cell(s) to {}", path.display());
            Ok(())
        }
        Some("gate") => {
            let gate_set = plan::load_gate_set().map_err(|e| anyhow::anyhow!("{e}"))?;
            let known = || {
                gate_set.iter().map(|t| t.name.as_str()).collect::<Vec<_>>().join(", ")
            };
            match cli.positional.len() {
                // Explicit files: `bench gate <target> <baseline> <current>`.
                4 => {
                    let target = cli.positional[1].as_str();
                    if target != "all" && !gate_set.iter().any(|t| t.name == target) {
                        bail!("unknown gate target: {target} (declared: {}, or all)", known());
                    }
                    gate_files(
                        Path::new(&cli.positional[2]),
                        Path::new(&cli.positional[3]),
                        threshold,
                        latency_threshold,
                        cli.latency_strict,
                        latency_flags_given,
                    )
                }
                // No files: gate the working-tree BENCH files of every
                // selected plan-declared target.
                2 => {
                    let target = cli.positional[1].as_str();
                    let selected: Vec<_> = gate_set
                        .iter()
                        .filter(|t| target == "all" || t.name == target)
                        .collect();
                    if selected.is_empty() {
                        bail!("unknown gate target: {target} (declared: {}, or all)", known());
                    }
                    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
                    let mut failed: Vec<String> = Vec::new();
                    for t in selected {
                        let committed = root.join(&t.baseline);
                        if !committed.exists() {
                            println!(
                                "gate {}: UNARMED — no committed {} (commit a measured \
                                 baseline to arm; see ci/check_bench.sh)",
                                t.name, t.baseline
                            );
                            continue;
                        }
                        let snapshot =
                            PathBuf::from(format!("{}.baseline", committed.display()));
                        let baseline =
                            if snapshot.exists() { snapshot } else { committed.clone() };
                        println!(
                            "gate {}: {} vs {}",
                            t.name,
                            baseline.display(),
                            committed.display()
                        );
                        if let Err(e) = gate_files(
                            &baseline,
                            &committed,
                            threshold,
                            latency_threshold,
                            cli.latency_strict,
                            latency_flags_given,
                        ) {
                            eprintln!("gate {} FAILED: {e:#}", t.name);
                            failed.push(t.name.clone());
                        }
                    }
                    if !failed.is_empty() {
                        bail!("{} gate(s) failed: {}", failed.len(), failed.join(", "));
                    }
                    Ok(())
                }
                _ => bail!(
                    "usage: sfut bench gate <target|all> [<baseline.json> <current.json>] \
                     [--threshold 0.25] [--latency-threshold 0.25] [--latency-strict]"
                ),
            }
        }
        Some("list") => {
            if cli.positional.get(1).map(String::as_str) == Some("gates") {
                // Machine-readable: one `name baseline bench_target`
                // line per gate — ci/check_bench.sh consumes this.
                for t in plan::load_gate_set().map_err(|e| anyhow::anyhow!("{e}"))? {
                    println!("{} {} {}", t.name, t.baseline, t.bench_target);
                }
                return Ok(());
            }
            let plans = plan::load_all_plans().map_err(|e| anyhow::anyhow!("{e}"))?;
            if plans.is_empty() {
                println!("no plans committed under {}", plan::plans_dir().display());
            } else {
                println!("plans (sfut bench run <file>):");
                for (p, path) in &plans {
                    let axes: Vec<String> = p
                        .axes
                        .iter()
                        .map(|a| format!("{}×{}", a.key, a.values.len()))
                        .collect();
                    println!(
                        "  {:<14} {:<9} {:>4} cell(s)  [{}]  {}",
                        p.name,
                        p.backend.label(),
                        p.grid_size(),
                        axes.join(" "),
                        path.display()
                    );
                }
            }
            println!("gate targets (sfut bench gate <name|all>):");
            for t in plan::load_gate_set().map_err(|e| anyhow::anyhow!("{e}"))? {
                println!("  {:<10} baseline {} ({})", t.name, t.baseline, t.bench_target);
            }
            Ok(())
        }
        Some("report") => {
            let path = registry::default_path();
            let records = registry::read(&path).map_err(|e| anyhow::anyhow!("{e}"))?;
            print!(
                "{}",
                registry::render_report(&records, cli.positional.get(1).map(String::as_str))
            );
            Ok(())
        }
        Some(other) => bail!("unknown bench subcommand: {other} (try run, gate, list or report)"),
        None => bail!("usage: sfut bench <run|gate|list|report> ... (try `sfut help`)"),
    }
}

fn real_main() -> Result<()> {
    stream_future::logging::init();
    let cli = parse_args(std::env::args().skip(1))?;
    match cli.command.as_str() {
        "run" => {
            if cli.positional.len() != 2 {
                bail!("usage: sfut run <workload[(k=v,...)]> <mode>");
            }
            let cfg = load_config(&cli)?;
            let pipeline = Pipeline::new(cfg)?;
            let req = JobRequest::parse(&cli.positional.join(" "))
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let result = pipeline.run(&req)?;
            println!("{}", result.render_line());
            if !result.verified {
                bail!("result failed verification against the oracle");
            }
            Ok(())
        }
        "workloads" => {
            // Config flags are accepted (and validated) for symmetry
            // with every other subcommand; the registry itself is
            // config-independent.
            let _ = load_config(&cli)?;
            let registry = stream_future::workload::WorkloadRegistry::builtin();
            println!("registered workloads ({}):", registry.len());
            for w in registry.iter() {
                let params: Vec<String> =
                    w.params().iter().map(|p| format!("{} ({})", p.render(), p.help)).collect();
                let params = if params.is_empty() { "-".to_string() } else { params.join("; ") };
                println!("  {:<16} {}", w.name(), w.describe());
                println!("  {:<16} params: {params}", "");
            }
            println!(
                "run one with: sfut run <name>[(k=v,...)] <seq|strict|par(N)> — e.g. \
                 `sfut run fib(n=64) par(2)`"
            );
            Ok(())
        }
        "table1" => {
            let cfg = load_config(&cli)?;
            let report = paper::table1(&cfg)?;
            print!("{report}");
            Ok(())
        }
        "fig3" => {
            let cfg = load_config(&cli)?;
            let report = paper::fig3(&cfg)?;
            print!("{report}");
            Ok(())
        }
        "fig4" => {
            let cfg = load_config(&cli)?;
            let report = paper::fig4(&cfg)?;
            print!("{report}");
            Ok(())
        }
        "serve" => {
            let cfg = load_config(&cli)?;
            let pipeline = Pipeline::new(cfg)?;
            if let Some(addr) = cli.positional.first() {
                // `sfut serve <addr>` — TCP mode; runs until killed.
                let server = stream_future::coordinator::TcpServer::start(
                    std::sync::Arc::new(pipeline),
                    addr.as_str(),
                )?;
                eprintln!("sfut serve: listening on {}", server.local_addr());
                loop {
                    std::thread::sleep(std::time::Duration::from_secs(3600));
                }
            }
            eprintln!("sfut serve: type `help` for commands");
            let jobs = serve(&pipeline, BufReader::new(stdin()), stdout())?;
            eprintln!("served {jobs} jobs");
            Ok(())
        }
        "bench" => bench_command(&cli),
        "check-bench" => {
            if cli.positional.len() != 2 {
                bail!(
                    "usage: sfut check-bench <baseline.json> <current.json> \
                     [--threshold 0.25] [--latency-threshold 0.25] [--latency-strict]"
                );
            }
            eprintln!(
                "note: `sfut check-bench` is deprecated — use \
                 `sfut bench gate <target> <baseline> <current>`"
            );
            gate_files(
                Path::new(&cli.positional[0]),
                Path::new(&cli.positional[1]),
                cli.threshold.unwrap_or(0.25),
                cli.latency_threshold
                    .unwrap_or(stream_future::bench_harness::DEFAULT_LATENCY_THRESHOLD),
                cli.latency_strict,
                cli.latency_strict || cli.latency_threshold.is_some(),
            )
        }
        "lint" => {
            if !cli.positional.is_empty() {
                bail!("usage: sfut lint [--json]");
            }
            let root = std::env::current_dir().context("resolving cwd for sfut lint")?;
            let findings = stream_future::lint::run(&root)?;
            for f in &findings {
                if cli.json {
                    println!("{}", f.render_json());
                } else {
                    println!("{}", f.render());
                }
            }
            if findings.is_empty() {
                if !cli.json {
                    println!("sfut lint: clean");
                }
                Ok(())
            } else {
                bail!("sfut lint: {} finding(s)", findings.len())
            }
        }
        "info" => {
            let cfg = load_config(&cli)?;
            println!("config: {cfg:#?}");
            let pipeline = Pipeline::new(cfg)?;
            match pipeline.engine() {
                Some(engine) => {
                    println!("pjrt platform: {}", engine.platform());
                    println!("poly artifacts: {:?}", engine.poly_shapes());
                    println!("sieve artifacts: {:?}", engine.sieve_shapes());
                }
                None => println!("pjrt engine: disabled (no artifacts or use_kernel=false)"),
            }
            println!(
                "machine parallelism: {}",
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            );
            Ok(())
        }
        "help" | "--help" | "-h" => {
            let registry = stream_future::workload::WorkloadRegistry::builtin();
            println!(
                "sfut — reproduction of 'Parallelizing Stream with Future' (Jolly, 2013)\n\
                 \n\
                 usage: sfut <command> [options]\n\
                 \n\
                 commands:\n\
                 \x20 run <spec> <mode>       run one cell; spec = name[(k=v,...)] \
                 (e.g. `run fib(n=64) par(2)`)\n\
                 \x20 workloads               list registered workloads + param schemas\n\
                 \x20 table1                  regenerate the paper's Table 1\n\
                 \x20 fig3                    regenerate Figure 3 (primes chart)\n\
                 \x20 fig4                    regenerate Figure 4 (polynomial chart)\n\
                 \x20 serve                   request loop on stdin/stdout\n\
                 \x20 info                    platform / artifact / config report\n\
                 \x20 bench run <plan>        execute an ablation plan (ci/plans/*.plan), \
                 append cells to BENCH_registry.jsonl\n\
                 \x20 bench gate <t|all>      perf-regression gate over the plan-declared \
                 gate set (or explicit <baseline> <current> files)\n\
                 \x20 bench list [gates]      list committed plans and gate targets\n\
                 \x20 bench report [plan]     diff registry cells across commits\n\
                 \x20 check-bench <a> <b>     deprecated alias for `bench gate`\n\
                 \x20 lint [--json]           repo-invariant static analysis \
                 (SAFETY comments, metric taxonomy, config-key docs, err-line hygiene)\n\
                 \n\
                 options: --config <file> | --set k=v | --scale <f> | --samples <n> | \
                 --no-kernel | --queue-depth <n> | --admission <block|shed|timeout(MS)> | \
                 --deque <chase_lev|locked> | --wire <framed|text> | \
                 --poller <poll|epoll|auto> | --reactors <n> | \
                 --threshold <f> | --latency-threshold <f> | --latency-strict | --json\n\
                 config keys (--set k=v): primes_n fateman_vars fateman_degree big_factor \
                 chunk_size chunk_policy shards shard_parallelism queue_depth admission \
                 dispatchers migrate_threshold deadline_ms retry_max retry_backoff_ms \
                 breaker_threshold artifacts_dir use_kernel stack_size deque wire poller \
                 reactors reuseport samples warmup scale\n\
                 workloads: {}\n\
                 modes: seq strict par(N)",
                registry.names().join(" ")
            );
            Ok(())
        }
        other => bail!("unknown command: {other} (try `sfut help`)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> impl Iterator<Item = String> + '_ {
        s.split_whitespace().map(str::to_string)
    }

    #[test]
    fn parses_run_command() {
        let cli = parse_args(args("run primes seq --scale 0.5 --no-kernel")).unwrap();
        assert_eq!(cli.command, "run");
        assert_eq!(cli.positional, vec!["primes", "seq"]);
        assert!(cli.overrides.contains(&("scale".to_string(), "0.5".to_string())));
        assert!(cli.overrides.contains(&("use_kernel".to_string(), "false".to_string())));
    }

    #[test]
    fn rejects_unknown_flags() {
        assert!(parse_args(args("run --frobnicate")).is_err());
        assert!(parse_args(args("table1 --set novalue")).is_err());
    }

    #[test]
    fn parses_lint_command() {
        let cli = parse_args(args("lint")).unwrap();
        assert_eq!(cli.command, "lint");
        assert!(!cli.json);
        let cli = parse_args(args("lint --json")).unwrap();
        assert!(cli.json);
        // --json is lint-specific, mirroring the gate-flag validation.
        assert!(parse_args(args("run primes seq --json")).is_err());
    }

    #[test]
    fn parses_check_bench_command() {
        let cli = parse_args(args("check-bench a.json b.json --threshold 0.4")).unwrap();
        assert_eq!(cli.command, "check-bench");
        assert_eq!(cli.positional, vec!["a.json", "b.json"]);
        assert_eq!(cli.threshold, Some(0.4));
        assert!(parse_args(args("check-bench a b --threshold 1.5")).is_err());
        assert!(parse_args(args("check-bench a b --threshold soon")).is_err());
        assert!(
            parse_args(args("run primes seq --threshold 0.1")).is_err(),
            "--threshold must be rejected outside the gate commands"
        );
    }

    #[test]
    fn parses_bench_family() {
        let cli = parse_args(args("bench run ci/plans/smoke.plan --set scale=0.05")).unwrap();
        assert_eq!(cli.command, "bench");
        assert_eq!(cli.positional, vec!["run", "ci/plans/smoke.plan"]);
        assert!(cli.overrides.contains(&("scale".to_string(), "0.05".to_string())));
        let cli = parse_args(args("bench gate pipeline a.json b.json --threshold 0.4")).unwrap();
        assert_eq!(cli.positional, vec!["gate", "pipeline", "a.json", "b.json"]);
        assert_eq!(cli.threshold, Some(0.4));
        let cli = parse_args(args("bench gate all --latency-strict")).unwrap();
        assert!(cli.latency_strict);
        let cli = parse_args(args("bench report smoke")).unwrap();
        assert_eq!(cli.positional, vec!["report", "smoke"]);
    }

    #[test]
    fn parses_ingress_flags() {
        let cli = parse_args(args("serve --queue-depth 16 --admission shed")).unwrap();
        assert!(cli.overrides.contains(&("queue_depth".to_string(), "16".to_string())));
        assert!(cli.overrides.contains(&("admission".to_string(), "shed".to_string())));
        let cli = parse_args(args("run primes seq --admission timeout(250)")).unwrap();
        assert!(cli
            .overrides
            .contains(&("admission".to_string(), "timeout(250)".to_string())));
        assert!(parse_args(args("serve --queue-depth")).is_err());
    }

    #[test]
    fn parses_latency_threshold_for_gate_commands_only() {
        let cli = parse_args(args("check-bench a.json b.json --latency-threshold 0.5")).unwrap();
        assert_eq!(cli.latency_threshold, Some(0.5));
        assert!(parse_args(args("check-bench a b --latency-threshold nope")).is_err());
        assert!(parse_args(args("check-bench a b --latency-threshold 0")).is_err());
        assert!(
            parse_args(args("run primes seq --latency-threshold 0.5")).is_err(),
            "--latency-threshold must be rejected outside the gate commands"
        );
    }

    #[test]
    fn parses_latency_strict_for_gate_commands_only() {
        let cli = parse_args(args("check-bench a.json b.json --latency-strict")).unwrap();
        assert!(cli.latency_strict);
        let cli = parse_args(args("check-bench a.json b.json")).unwrap();
        assert!(!cli.latency_strict);
        assert!(
            parse_args(args("run primes seq --latency-strict")).is_err(),
            "--latency-strict must be rejected outside the gate commands"
        );
    }

    #[test]
    fn parses_deque_shorthand() {
        let cli = parse_args(args("run primes seq --deque locked")).unwrap();
        assert!(cli.overrides.contains(&("deque".to_string(), "locked".to_string())));
        assert!(parse_args(args("run primes seq --deque")).is_err());
    }

    #[test]
    fn parses_wire_shorthand() {
        let cli = parse_args(args("serve 127.0.0.1:0 --wire framed")).unwrap();
        assert!(cli.overrides.contains(&("wire".to_string(), "framed".to_string())));
        assert!(parse_args(args("serve --wire")).is_err());
    }

    #[test]
    fn parses_poller_and_reactors_shorthand() {
        let cli =
            parse_args(args("serve 127.0.0.1:0 --wire framed --poller epoll --reactors 4"))
                .unwrap();
        assert!(cli.overrides.contains(&("poller".to_string(), "epoll".to_string())));
        assert!(cli.overrides.contains(&("reactors".to_string(), "4".to_string())));
        assert!(parse_args(args("serve --poller")).is_err());
        assert!(parse_args(args("serve --reactors")).is_err());
    }

    #[test]
    fn set_splits_on_first_equals() {
        let cli = parse_args(args("run --set artifacts_dir=/a/b=c")).unwrap();
        assert_eq!(cli.overrides[0], ("artifacts_dir".to_string(), "/a/b=c".to_string()));
    }
}

//! The paper's monadic Stream (§4).
//!
//! A `Stream<T, E>` is a cons list whose tail is suspended in the monad
//! selected by the [`Eval`] strategy `E`:
//!
//! ```text
//! case class Cons[+A](hd: A, tl: Future[Stream[A]]) extends Stream[A]
//! ```
//!
//! * With [`LazyEval`](crate::susp::LazyEval) this is Scala's `Stream`
//!   (memoizing, demand-driven, sequential).
//! * With [`FutureEval`](crate::susp::FutureEval) every tail starts
//!   computing asynchronously the moment its cell is constructed
//!   (Figure 1) — the same algorithm code becomes pipeline-parallel.
//!
//! Following the paper, combinators never force the tail on the calling
//! thread; they *forward* the suspension with [`Eval::map`] /
//! [`Eval::flat_map`]. The only forcing entry points are [`Stream::tail`]
//! (the paper's `Await.result`), the scan loop inside [`Stream::filter`]
//! / [`Stream::dropped`] (the paper's `while (!rest.isEmpty && ...)`),
//! and the terminal consumers (`force`, `to_vec`, `fold`, `iter`).

mod chunked;
mod ops;
mod ops2;

pub use chunked::{Chunk, ChunkSizer, ChunkedStream, CostCache};

use std::sync::Arc;

use crate::susp::{Eval, Susp};

/// Element bound: everything a head must satisfy to cross task
/// boundaries. Blanket-implemented.
pub trait Elem: Clone + Send + Sync + 'static {}
impl<T: Clone + Send + Sync + 'static> Elem for T {}

/// A monadic stream. Cheap to clone (empty or one `Arc`).
pub enum Stream<T: Elem, E: Eval> {
    Empty,
    Cons(Arc<Cons<T, E>>),
}

/// An elementary cell: evaluated head, suspended tail, plus the strategy
/// handle (the paper's implicit ExecutionContext travels with the cell).
pub struct Cons<T: Elem, E: Eval> {
    head: T,
    /// `None` only transiently during iterative drop.
    tail: Option<E::Cell<Stream<T, E>>>,
    eval: E,
}

impl<T: Elem, E: Eval> Clone for Stream<T, E> {
    fn clone(&self) -> Self {
        match self {
            Stream::Empty => Stream::Empty,
            Stream::Cons(c) => Stream::Cons(Arc::clone(c)),
        }
    }
}

impl<T: Elem, E: Eval> Drop for Cons<T, E> {
    /// Dismantle memoized chains iteratively. The default recursive drop
    /// of a linked spine overflows the stack on long streams (the paper's
    /// workloads run to tens of thousands of cells); instead, steal each
    /// uniquely-owned, already-computed tail and unlink it in a loop.
    /// (§Perf opt-1: the stolen tail slot is an `Option` taken in place,
    /// so teardown allocates nothing.)
    fn drop(&mut self) {
        let mut cell = self.tail.take();
        while let Some(c) = cell {
            match c.into_ready() {
                Some(Stream::Cons(arc)) => match Arc::try_unwrap(arc) {
                    Ok(mut cons) => {
                        cell = cons.tail.take();
                        // `cons` drops here with an empty tail slot: no
                        // recursion.
                    }
                    Err(_shared) => break, // another handle owns the rest
                },
                _ => break, // empty, pending, shared, or poisoned
            }
        }
    }
}

impl<T: Elem, E: Eval> Stream<T, E> {
    /// The empty stream.
    pub fn empty() -> Self {
        Stream::Empty
    }

    /// `cons(hd, tl)` with an already-suspended tail — the paper's `#::`.
    pub fn cons_cell(eval: E, head: T, tail: E::Cell<Stream<T, E>>) -> Self {
        Stream::Cons(Arc::new(Cons { head, tail: Some(tail), eval }))
    }

    /// `cons(hd, suspend(tl))`: suspend a tail computation. For the
    /// Future strategy the computation is scheduled immediately.
    pub fn cons_with(
        eval: E,
        head: T,
        tail: impl FnOnce() -> Stream<T, E> + Send + 'static,
    ) -> Self {
        let cell = eval.suspend(tail);
        Stream::cons_cell(eval, head, cell)
    }

    /// A single-element stream.
    pub fn singleton(eval: E, head: T) -> Self {
        let cell = eval.ready(Stream::Empty);
        Stream::cons_cell(eval, head, cell)
    }

    pub fn is_empty(&self) -> bool {
        matches!(self, Stream::Empty)
    }

    /// Head of a non-empty stream.
    pub fn head(&self) -> Option<&T> {
        match self {
            Stream::Empty => None,
            Stream::Cons(c) => Some(&c.head),
        }
    }

    /// The paper's *extractor*: head plus the still-suspended tail cell.
    /// This is the non-forcing access path every combinator uses.
    pub fn uncons(&self) -> Option<(&T, &E::Cell<Stream<T, E>>, &E)> {
        match self {
            Stream::Empty => None,
            Stream::Cons(c) => {
                Some((&c.head, c.tail.as_ref().expect("tail present outside drop"), &c.eval))
            }
        }
    }

    /// Force the tail — the paper's
    /// `override def tail = Await.result(tl, Duration.Inf)`.
    pub fn tail(&self) -> Option<&Stream<T, E>> {
        match self {
            Stream::Empty => None,
            Stream::Cons(c) => Some(c.tail.as_ref().expect("tail present outside drop").force()),
        }
    }

    /// Whether the tail has been computed (never blocks) — the paper's
    /// `tailDefined`.
    pub fn tail_defined(&self) -> bool {
        match self {
            Stream::Empty => false,
            Stream::Cons(c) => {
                c.tail.as_ref().expect("tail present outside drop").is_ready()
            }
        }
    }

    /// The strategy handle carried by this stream, if non-empty.
    pub fn eval(&self) -> Option<&E> {
        match self {
            Stream::Empty => None,
            Stream::Cons(c) => Some(&c.eval),
        }
    }
}

// ---------------------------------------------------------------------
// constructors
// ---------------------------------------------------------------------

impl<E: Eval> Stream<u32, E> {
    /// `Stream.range(lo, hi, 1)` — the paper's sieve input. With the
    /// Future strategy this schedules the whole cascade of cells
    /// immediately, one task per cell (Figure 1).
    pub fn range(eval: E, lo: u32, hi: u32) -> Self {
        if lo >= hi {
            return Stream::Empty;
        }
        let e2 = eval.clone();
        Stream::cons_with(eval, lo, move || Stream::range(e2, lo + 1, hi))
    }
}

impl<T: Elem, E: Eval> Stream<T, E> {
    /// The paper's `Stream.apply`: lift a strict sequence into the
    /// monadic stream (each tail wrapped via `suspend`).
    pub fn from_vec(eval: E, items: Vec<T>) -> Self {
        Self::from_iter_inner(eval, items.into_iter())
    }

    fn from_iter_inner(eval: E, mut items: impl Iterator<Item = T> + Send + 'static) -> Self {
        match items.next() {
            None => Stream::Empty,
            Some(head) => {
                let e2 = eval.clone();
                Stream::cons_with(eval, head, move || Self::from_iter_inner(e2, items))
            }
        }
    }

    /// Unfold: `seed -> Option<(elem, seed)>`.
    pub fn unfold<S, F>(eval: E, seed: S, step: F) -> Self
    where
        S: Send + 'static,
        F: FnMut(&mut S) -> Option<T> + Send + Clone + 'static,
    {
        let mut seed = seed;
        let mut step0 = step.clone();
        match step0(&mut seed) {
            None => Stream::Empty,
            Some(head) => {
                let e2 = eval.clone();
                Stream::cons_with(eval, head, move || Stream::unfold(e2, seed, step))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Executor;
    use crate::susp::{FutureEval, LazyEval, StrictEval};

    fn strategies() -> (LazyEval, StrictEval, FutureEval) {
        (LazyEval, StrictEval, FutureEval::new(Executor::new(2)))
    }

    #[test]
    fn empty_stream_basics() {
        let s: Stream<u32, LazyEval> = Stream::empty();
        assert!(s.is_empty());
        assert!(s.head().is_none());
        assert!(s.tail().is_none());
        assert!(s.uncons().is_none());
        assert!(!s.tail_defined());
    }

    #[test]
    fn range_produces_sequence_under_all_strategies() {
        let (lz, st, fut) = strategies();
        assert_eq!(Stream::range(lz, 2, 7).to_vec(), vec![2, 3, 4, 5, 6]);
        assert_eq!(Stream::range(st, 2, 7).to_vec(), vec![2, 3, 4, 5, 6]);
        assert_eq!(Stream::range(fut, 2, 7).to_vec(), vec![2, 3, 4, 5, 6]);
    }

    #[test]
    fn empty_range() {
        let s = Stream::range(LazyEval, 5, 5);
        assert!(s.is_empty());
        let s = Stream::range(LazyEval, 7, 3);
        assert!(s.is_empty());
    }

    #[test]
    fn from_vec_roundtrip() {
        let v = vec!["a".to_string(), "b".to_string(), "c".to_string()];
        let s = Stream::from_vec(LazyEval, v.clone());
        assert_eq!(s.to_vec(), v);
    }

    #[test]
    fn lazy_tail_not_defined_until_forced() {
        let s = Stream::range(LazyEval, 0, 10);
        assert!(!s.tail_defined());
        s.tail();
        assert!(s.tail_defined());
    }

    #[test]
    fn future_tail_computes_without_forcing() {
        // Figure 1: construction alone triggers the cascade.
        let ex = Executor::new(2);
        let s = Stream::range(FutureEval::new(ex.clone()), 0, 50);
        ex.wait_idle();
        assert!(s.tail_defined());
        // And the whole spine is complete:
        let mut cur = s.clone();
        let mut n = 0;
        while let Some(t) = cur.tail() {
            assert!(cur.tail_defined());
            cur = t.clone();
            n += 1;
        }
        assert_eq!(n, 50);
    }

    #[test]
    fn unfold_terminates() {
        let s = Stream::unfold(LazyEval, 0u32, |st| {
            if *st >= 4 {
                None
            } else {
                *st += 1;
                Some(*st * 10)
            }
        });
        assert_eq!(s.to_vec(), vec![10, 20, 30, 40]);
    }

    #[test]
    fn singleton_has_one_element() {
        let s = Stream::singleton(LazyEval, 9);
        assert_eq!(s.to_vec(), vec![9]);
    }

    #[test]
    fn clone_shares_cells() {
        let s = Stream::range(LazyEval, 0, 3);
        let s2 = s.clone();
        s.tail();
        // Memoization is shared: the clone sees the forced tail.
        assert!(s2.tail_defined());
    }
}
